//! The paper's deep-dive configuration: HAN on DBLP — Table 3 metrics,
//! Fig 4 roofline placement, and the stage/kernel-type breakdowns, in
//! one run.
//!
//! ```sh
//! cargo run --release --example characterize_han_dblp [-- --scale 0.5]
//! ```

use hgnn_char::cli::Args;
use hgnn_char::datasets::DatasetId;
use hgnn_char::gpumodel::{roofline, GpuModel};
use hgnn_char::models::ModelId;
use hgnn_char::profiler::StageId;
use hgnn_char::report;
use hgnn_char::session::{Profiling, Session};

fn main() -> hgnn_char::Result<()> {
    let args = Args::flags_from_env();
    let mut session = Session::builder()
        .dataset(DatasetId::Dblp)
        .scale(args.scale()?)
        .model(ModelId::Han)
        .profiling(Profiling::Traces)
        .build()?;
    println!("{}", session.graph().stats_line());
    println!("{}\n", session.plan().describe(session.graph()));

    let run = session.run()?;

    // -- Fig 2 row + Fig 3 rows ------------------------------------------
    println!("{}", report::fig2_row("HAN", "DB", &run.profile));
    print!("{}", report::fig3_rows("HAN", "DB", &run.profile));
    println!();

    // -- Table 3 ------------------------------------------------------------
    for stage in StageId::GPU_STAGES {
        println!("{}", report::table3_stage(stage, &run.profile.kernel_table(stage)));
    }

    // -- Fig 4 roofline -------------------------------------------------------
    let gpu = GpuModel::default();
    let mut points = Vec::new();
    for stage in StageId::GPU_STAGES {
        for (name, m, _) in run.profile.kernel_table(stage) {
            if !points.iter().any(|p: &roofline::RooflinePoint| p.name == name) {
                points.push(roofline::place(&gpu.spec, &name, m.ai, m.achieved_gflops));
            }
        }
    }
    println!("{}", roofline::ascii_chart(&gpu.spec, &points));
    Ok(())
}
