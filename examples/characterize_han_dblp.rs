//! The paper's deep-dive configuration: HAN on DBLP — Table 3 metrics,
//! Fig 4 roofline placement, and the stage/kernel-type breakdowns, in
//! one run.
//!
//! ```sh
//! cargo run --release --example characterize_han_dblp [-- --scale 0.5]
//! ```

use hgnn_char::cli::Args;
use hgnn_char::datasets::{self, DatasetId};
use hgnn_char::engine::{Backend, Engine};
use hgnn_char::gpumodel::{roofline, GpuModel};
use hgnn_char::models::{self, ModelConfig};
use hgnn_char::profiler::StageId;
use hgnn_char::report;

fn main() -> hgnn_char::Result<()> {
    let args = Args::flags_from_env();
    let scale = args.scale()?;
    let hg = datasets::build(DatasetId::Dblp, &scale)?;
    println!("{}", hg.stats_line());
    let plan = models::han_plan(&hg, &ModelConfig::default())?;
    println!("{}\n", plan.describe(&hg));

    let mut engine = Engine::new(Backend::native());
    let run = engine.run(&plan, &hg)?;

    // -- Fig 2 row + Fig 3 rows ------------------------------------------
    println!("{}", report::fig2_row("HAN", "DB", &run.profile));
    print!("{}", report::fig3_rows("HAN", "DB", &run.profile));
    println!();

    // -- Table 3 ------------------------------------------------------------
    for stage in StageId::GPU_STAGES {
        println!("{}", report::table3_stage(stage, &run.profile.kernel_table(stage)));
    }

    // -- Fig 4 roofline -------------------------------------------------------
    let gpu = GpuModel::default();
    let mut points = Vec::new();
    for stage in StageId::GPU_STAGES {
        for (name, m, _) in run.profile.kernel_table(stage) {
            if !points.iter().any(|p: &roofline::RooflinePoint| p.name == name) {
                points.push(roofline::place(&gpu.spec, &name, m.ai, m.achieved_gflops));
            }
        }
    }
    println!("{}", roofline::ascii_chart(&gpu.spec, &points));
    Ok(())
}
