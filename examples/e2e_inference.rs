//! **End-to-end driver**: serve batched HGNN inference requests through
//! a `Session`, with Python nowhere on the request path.
//!
//! Pipeline exercised, all layers composing:
//!   L3 Rust: dataset synthesis → metapath Subgraph Build → `Session`
//!            (PJRT backend, ELL conversion inside the artifact input
//!            assembly) → dynamic-batching server
//!   L2 JAX:  HAN forward (FP/NA/SA), AOT-lowered to HLO text
//!   L1 Pallas: dense_matmul / sddmm_ell / seg_softmax / ell_spmm
//!
//! The serving model: the session's whole-model artifact computes
//! full-graph HAN embeddings once and reuses them across batches
//! (`Session::run_batch`); requests ask for per-node rows. PJRT
//! executables are not `Send` (Rc internals), which is exactly why
//! `Server::start_session` builds the session *inside* the dispatcher
//! thread. When artifacts are missing (or the crate was built without
//! the `pjrt` feature) the driver falls back to the native backend so
//! the serving path is still demonstrated end-to-end.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_inference
//! ```

use std::time::Instant;

use hgnn_char::prelude::*;
use hgnn_char::util::Pcg32;

fn main() -> hgnn_char::Result<()> {
    let n_requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(256);

    // ---------------- choose backend: PJRT if artifacts compile -----------
    // Build a probe session up front to (a) report which backend serves
    // and (b) cross-check PJRT vs native numerics when both are live.
    let base = Session::builder().dataset(DatasetId::Imdb).scale(DatasetScale::ci());
    let probe = Session::builder()
        .dataset(DatasetId::Imdb)
        .scale(DatasetScale::ci())
        .pjrt("artifacts")
        .build()
        .and_then(|mut s| s.run().map(|run| (s, run)));

    let use_pjrt = match probe {
        Ok((session, run)) => {
            println!("PJRT backend live ({:?})", session.backend_caps());
            // sanity: PJRT output vs the native engine on the same plan.
            // The artifact computes on ELL-truncated adjacency, so allow
            // a loose tolerance; shapes must agree exactly.
            let mut native = Session::builder()
                .dataset(DatasetId::Imdb)
                .scale(DatasetScale::ci())
                .build()?;
            let nat = native.run()?;
            assert_eq!(run.output.shape(), nat.output.shape());
            let diff = run.output.max_abs_diff(&nat.output)?;
            println!("PJRT vs native cross-check: max |Δ| = {diff:.2e}");
            // Loose guard: the artifact computes on ELL-truncated
            // adjacency while the native session uses the full graph, so
            // exact 1e-3 agreement lives in integration_runtime.rs (which
            // truncates both sides). Garbage output must still abort.
            assert!(
                diff.is_finite() && diff < 1.0,
                "PJRT output diverged from native (max |Δ| = {diff:.2e})"
            );
            true
        }
        Err(e) => {
            println!("PJRT unavailable ({e}); serving on the native backend");
            false
        }
    };

    // ---------------- serving loop ----------------------------------------
    println!("\nserving {n_requests} embedding requests (batched inference)...");
    let builder = if use_pjrt { base.pjrt("artifacts") } else { base };
    let server = builder.serve(ServeConfig {
        max_batch: 32,
        flush_after: std::time::Duration::from_millis(5),
        ..ServeConfig::default()
    });

    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut rng = Pcg32::seeded(7);
    for _ in 0..n_requests {
        let node = rng.gen_range(4096) as u32; // ids wrap modulo output rows
        pending.push(server.submit(node)?);
    }
    let mut ok = 0;
    for rx in pending {
        if rx.recv().is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed();
    let stats = server.shutdown();

    println!("\n=== e2e serving report (recorded in EXPERIMENTS.md) ===");
    println!("requests completed : {ok}/{n_requests}");
    println!("batches executed   : {} (mean batch {:.1})", stats.batches, stats.mean_batch);
    println!(
        "latency            : p50 {}  p95 {}  max {}",
        hgnn_char::util::human_time(stats.latency.median),
        hgnn_char::util::human_time(stats.latency.p95),
        hgnn_char::util::human_time(stats.latency.max),
    );
    println!(
        "throughput         : {:.0} req/s over {:.2}s wall",
        stats.throughput_rps,
        wall.as_secs_f64()
    );
    Ok(())
}
