//! **End-to-end driver** (DESIGN.md deliverable b): serve batched HGNN
//! inference requests over the AOT-compiled JAX/Pallas model via PJRT,
//! with Python nowhere on the request path.
//!
//! Pipeline exercised, all layers composing:
//!   L3 Rust: dataset synthesis → metapath Subgraph Build → ELL
//!            conversion → dynamic-batching server → PJRT execution
//!   L2 JAX:  HAN forward (FP/NA/SA), AOT-lowered to HLO text
//!   L1 Pallas: dense_matmul / sddmm_ell / seg_softmax / ell_spmm
//!
//! The serving model: the compiled artifact computes full-graph HAN
//! embeddings; requests ask for per-node embeddings. The server batches
//! requests (size- and time-bounded), runs one PJRT forward per batch
//! (features perturbed per batch to defeat trivial caching, as a real
//! feature-store refresh would), and replies with the requested rows.
//! Latency/throughput are reported and recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_inference
//! ```

use std::sync::Arc;
use std::time::Instant;

use hgnn_char::coordinator::{ServeConfig, Server};
use hgnn_char::datasets::{self, DatasetId, DatasetScale};
use hgnn_char::engine::{Backend, Engine};
use hgnn_char::graph::Csr;
use hgnn_char::metapath::{Metapath, Subgraph, SubgraphSet};
use hgnn_char::models::{self, ModelConfig, ModelId, ModelPlan, ModelWeights};
use hgnn_char::runtime::PjrtRuntime;
use hgnn_char::tensor::Tensor;
use hgnn_char::util::Pcg32;

const ELL_K: usize = 64;

fn ell_tensors(adj: &Csr, k: usize) -> (Tensor, Tensor, Csr) {
    let (ell, _) = adj.to_ell(k);
    let mut idx = Tensor::zeros(adj.n_rows, k);
    let mut mask = Tensor::zeros(adj.n_rows, k);
    for r in 0..adj.n_rows {
        let (cols, valid) = ell.row_slots(r);
        for j in 0..k {
            idx.set(r, j, cols[j] as f32);
            mask.set(r, j, if valid[j] { 1.0 } else { 0.0 });
        }
    }
    (idx, mask, ell.to_csr())
}

/// Assemble the 13 artifact inputs (see python/compile/aot.py) from the
/// plan's weights, the feature matrix and the ELL adjacency tensors.
/// The plan's weights are stored type-indexed; the artifact's projection
/// weight slot is the movie type's.
fn mk_inputs_for(x: &Tensor, plan: &ModelPlan, ells: &[(Tensor, Tensor)]) -> Vec<Tensor> {
    let h = plan.config.hidden_dim;
    let s = plan.config.semantic_dim;
    let proj = plan.weights.proj.values().next().expect("projection weight");
    vec![
        x.clone(),
        proj.clone(),
        ells[0].0.clone(),
        ells[0].1.clone(),
        ells[1].0.clone(),
        ells[1].1.clone(),
        Tensor::from_vec(1, h, plan.weights.attn_l[0].clone()).unwrap(),
        Tensor::from_vec(1, h, plan.weights.attn_r[0].clone()).unwrap(),
        Tensor::from_vec(1, h, plan.weights.attn_l[1].clone()).unwrap(),
        Tensor::from_vec(1, h, plan.weights.attn_r[1].clone()).unwrap(),
        plan.weights.sem_w.clone().unwrap(),
        Tensor::from_vec(1, s, plan.weights.sem_b.clone()).unwrap(),
        plan.weights.sem_q.clone().unwrap(),
    ]
}

fn main() -> hgnn_char::Result<()> {
    // ---------------- setup: graph, plan, artifact ------------------------
    let hg = datasets::build(DatasetId::Imdb, &DatasetScale::ci())?;
    println!("dataset: {}", hg.stats_line());
    let config = ModelConfig::default();
    let base = models::han_plan(&hg, &config)?;

    let rt = PjrtRuntime::new("artifacts")?;
    println!("PJRT platform: {}", rt.platform());
    let artifact = rt.compile_by_name("han_imdb_ci_full")?;
    println!("compiled artifact: {}", artifact.entry.name);

    // ELL inputs + the truncated-adjacency plan for the native cross-check
    let mut ells = Vec::new();
    let mut subgraphs = Vec::new();
    for sg in &base.subgraphs.subgraphs {
        let (idx, mask, trunc) = ell_tensors(&sg.adj, ELL_K);
        ells.push((idx, mask));
        subgraphs.push(Subgraph {
            metapath: Some(Metapath::parse(&sg.name)?),
            name: sg.name.clone(),
            dst_type: sg.dst_type,
            src_type: sg.src_type,
            adj: trunc,
        });
    }
    let subgraphs = SubgraphSet { subgraphs, build_nanos: 0 };
    let weights = ModelWeights::init(ModelId::Han, &hg, &subgraphs, &config);
    let plan = ModelPlan {
        model: ModelId::Han,
        config: config.clone(),
        subgraphs,
        weights,
        target: base.target,
    };

    // sanity: PJRT output matches native engine before serving
    let m_ty = hg.type_by_tag('M')?;
    let native = Engine::new(Backend::native_no_traces()).run(&plan, &hg)?;
    let inputs = mk_inputs_for(hg.features(m_ty), &plan, &ells);
    let refs: Vec<&Tensor> = inputs.iter().collect();
    let pjrt_out = artifact.execute(&refs)?;
    let diff = pjrt_out[0].max_abs_diff(&native.output)?;
    println!("PJRT vs native cross-check: max |Δ| = {diff:.2e} (must be < 1e-3)");
    assert!(diff < 1e-3);

    // ---------------- serving loop ----------------------------------------
    let n_requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(256);
    let base_features = hg.features(m_ty).clone();
    let features_main = base_features.clone();
    let plan_arc = Arc::new(plan);
    let plan_exec = Arc::clone(&plan_arc);
    let ells_exec = ells.clone();

    println!("\nserving {n_requests} embedding requests (batched PJRT inference)...");
    // PJRT executables are not Send (Rc internals), so the executor —
    // including its own runtime + compiled artifact — is constructed
    // inside the dispatcher thread via start_with.
    let server = Server::start_with(
        ServeConfig { max_batch: 32, flush_after: std::time::Duration::from_millis(5) },
        move || {
            let rt = PjrtRuntime::new("artifacts").expect("PJRT client (dispatcher)");
            let artifact =
                rt.compile_by_name("han_imdb_ci_full").expect("compile artifact");
            let mut batch_no = 0u64;
            move |ids: &[u32]| -> hgnn_char::Result<Vec<Vec<f32>>> {
                // refresh features per batch (simulated feature-store update)
                batch_no += 1;
                let mut rng = Pcg32::new(batch_no, 42);
                let mut x = base_features.clone();
                for v in x.as_mut_slice().iter_mut().take(64) {
                    *v += rng.gen_normal() * 1e-3;
                }
                let inputs = mk_inputs_for(&x, &plan_exec, &ells_exec);
                let refs: Vec<&Tensor> = inputs.iter().collect();
                let out = artifact.execute(&refs)?;
                let z = &out[0];
                Ok(ids
                    .iter()
                    .map(|&i| z.row(i as usize % z.rows()).to_vec())
                    .collect())
            }
        },
    );
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut rng = Pcg32::seeded(7);
    for _ in 0..n_requests {
        let node = rng.gen_range(features_main.rows()) as u32;
        pending.push(server.submit(node)?);
    }
    let mut ok = 0;
    for rx in pending {
        if rx.recv().is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed();
    let stats = server.shutdown();

    println!("\n=== e2e serving report (recorded in EXPERIMENTS.md) ===");
    println!("requests completed : {ok}/{n_requests}");
    println!("batches executed   : {} (mean batch {:.1})", stats.batches, stats.mean_batch);
    println!(
        "latency            : p50 {}  p95 {}  max {}",
        hgnn_char::util::human_time(stats.latency.median),
        hgnn_char::util::human_time(stats.latency.p95),
        hgnn_char::util::human_time(stats.latency.max),
    );
    println!(
        "throughput         : {:.0} req/s over {:.2}s wall",
        stats.throughput_rps,
        wall.as_secs_f64()
    );
    Ok(())
}
