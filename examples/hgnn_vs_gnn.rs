//! The paper's §4.5 comparison study (Fig 5): what makes HGNN execution
//! different from GNN execution — dropout sweep, metapath sweep, and the
//! parallel-NA timeline with its NA→SA barrier.
//!
//! ```sh
//! cargo run --release --example hgnn_vs_gnn [-- --scale ci]
//! ```

use hgnn_char::cli::Args;
use hgnn_char::datasets::DatasetId;
use hgnn_char::models::{sweeps, ModelId};
use hgnn_char::report;
use hgnn_char::session::{SchedulePolicy, Session};

fn main() -> hgnn_char::Result<()> {
    let args = Args::flags_from_env();
    let scale = args.scale()?;

    println!("== Fig 5(a): NA time vs edge dropout (HAN vs GCN, Reddit-sim) ==");
    for (label, series) in sweeps::fig5a_dropout_sweep(&scale)? {
        println!(
            "{}",
            report::sweep_series(&label, "dropout", "NA (modeled ms)", &series)
        );
    }

    println!("== Fig 5(b): NA time vs #metapaths (HAN, DBLP) ==");
    let series = sweeps::fig5b_metapath_sweep(&scale)?;
    println!(
        "{}",
        report::sweep_series("HAN-DB", "#metapaths", "NA (modeled ms)", &series)
    );

    println!("== Fig 5(c): timeline — inter-subgraph parallelism + barrier ==");
    let run = Session::builder()
        .dataset(DatasetId::Dblp)
        .scale(scale)
        .model(ModelId::Han)
        .schedule(SchedulePolicy::InterSubgraphParallel { workers: 4 })
        .build()?
        .run()?;
    println!("{}", run.profile.timeline().render(96));
    println!("{}", run.report.summary());
    Ok(())
}
