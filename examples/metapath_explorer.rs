//! The paper's §4.6 exploration (Fig 6) as an interactive-style tool:
//! walk metapaths of growing length over each HG, report sparsity,
//! instance counts, and the fitted §5 correlation model; then sweep the
//! metapath count and report total time.
//!
//! ```sh
//! cargo run --release --example metapath_explorer [-- --scale 0.25]
//! ```

use hgnn_char::cli::Args;
use hgnn_char::datasets::{self, DatasetId};
use hgnn_char::metapath::{count_instances, fit_sparsity_model, sparsity::sparsity_sweep, Metapath};
use hgnn_char::models::sweeps;
use hgnn_char::report;

fn main() -> hgnn_char::Result<()> {
    let args = Args::flags_from_env();
    let scale = args.scale()?;

    for (dataset, seed) in
        [(DatasetId::Imdb, "MAM"), (DatasetId::Acm, "PAP"), (DatasetId::Dblp, "APA")]
    {
        let hg = datasets::build(dataset, &scale)?;
        println!("== {} ==", hg.stats_line());
        let pts = sparsity_sweep(&hg, seed, 3)?;
        for p in &pts {
            let mp = Metapath::parse(&p.name)?;
            let instances = count_instances(&hg, &mp)?;
            println!(
                "  {:<12} len {:>2}  nnz {:>10}  sparsity {:.4}  instances {}",
                p.name,
                p.length,
                p.nnz,
                p.sparsity,
                hgnn_char::util::human_count(instances as f64),
            );
        }
        if let Some(model) = fit_sparsity_model(&pts) {
            println!(
                "  fitted §5 model: log10(density) = {:.3} + {:.3}·len  (r² {:.3})",
                model.intercept, model.slope, model.r2
            );
            println!(
                "  extrapolation: predicted sparsity at len 8 = {:.4}\n",
                model.predict_sparsity(8)
            );
        }
    }

    println!("== Fig 6(b): total time vs #metapaths (HAN, DBLP) ==");
    let series = sweeps::fig6b_total_time_sweep(&scale)?;
    println!(
        "{}",
        report::sweep_series("HAN-DB", "#metapaths", "total (modeled ms)", &series)
    );
    Ok(())
}
