//! Quickstart: build a dataset, run HAN, print the paper-style profile.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use hgnn_char::datasets::{self, DatasetId, DatasetScale};
use hgnn_char::engine::{Backend, Engine};
use hgnn_char::models::{self, ModelConfig};
use hgnn_char::profiler::StageId;
use hgnn_char::report;

fn main() -> hgnn_char::Result<()> {
    // 1. Synthesize IMDB at the paper's published statistics (Table 2).
    let hg = datasets::build(DatasetId::Imdb, &DatasetScale::paper())?;
    println!("{}\n", hg.stats_line());

    // 2. Build the HAN execution plan: Subgraph Build (metapath walk on
    //    MDM + MAM) plus deterministic weights.
    let plan = models::han_plan(&hg, &ModelConfig::default())?;
    println!("{}\n", plan.describe(&hg));

    // 3. Run inference on the native substrate with full profiling.
    let mut engine = Engine::new(Backend::native());
    let run = engine.run(&plan, &hg)?;

    // 4. The paper's three analyses, one call each.
    println!("{}", run.profile.stage_breakdown());
    println!("kernel table for Neighbor Aggregation (cf. paper Table 3):");
    println!(
        "{}",
        report::table3_stage(
            StageId::NeighborAggregation,
            &run.profile.kernel_table(StageId::NeighborAggregation)
        )
    );
    println!(
        "output embeddings: {} x {} (‖Z‖_F = {:.3})",
        run.output.rows(),
        run.output.cols(),
        run.output.frob_norm()
    );
    Ok(())
}
