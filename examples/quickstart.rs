//! Quickstart: one `Session` — build a dataset, run HAN, print the
//! paper-style profile, then swap the schedule policy on the same
//! session state.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use hgnn_char::prelude::*;

fn main() -> hgnn_char::Result<()> {
    // 1. One session composes dataset × model × backend × schedule ×
    //    profiling, and owns graph + plan + cached state across runs.
    //    IMDB is synthesized at the paper's published statistics
    //    (Table 2); the plan is HAN over the MDM + MAM metapaths.
    let mut session = Session::builder()
        .dataset(DatasetId::Imdb)
        .model(ModelId::Han)
        .profiling(Profiling::Traces)
        .build()?;
    println!("{}\n", session.graph().stats_line());
    println!("{}\n", session.plan().describe(session.graph()));

    // 2. Run inference on the native backend with full profiling.
    let run = session.run()?;

    // 3. The paper's three analyses, one call each.
    println!("{}", run.profile.stage_breakdown());
    println!("kernel table for Neighbor Aggregation (cf. paper Table 3):");
    println!(
        "{}",
        report::table3_stage(
            StageId::NeighborAggregation,
            &run.profile.kernel_table(StageId::NeighborAggregation)
        )
    );
    println!(
        "output embeddings: {} x {} (‖Z‖_F = {:.3})",
        run.output.rows(),
        run.output.cols(),
        run.output.frob_norm()
    );

    // 4. Same session, different schedule: the plan, weights and graph
    //    are reused — only the execution policy changes.
    session.set_schedule(SchedulePolicy::InterSubgraphParallel { workers: 4 });
    let par = session.run()?;
    println!("\n{}", par.report.summary());
    Ok(())
}
