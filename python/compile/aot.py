"""AOT pipeline: lower the L2 JAX models (with L1 Pallas kernels inside)
to HLO **text** artifacts the Rust PJRT runtime loads.

Run once via `make artifacts`:

    cd python && python -m compile.aot --out-dir ../artifacts

Outputs `<name>.hlo.txt` per artifact plus `manifest.json` (parsed by
rust/src/runtime/manifest.rs). HLO text — not `.serialize()` — is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
that xla_extension 0.5.1 rejects; the text parser reassigns ids.

Artifact shapes mirror the Rust CI-scale datasets (DatasetScale::ci():
topology and feature dims / 16) so integration tests can feed real
graph tensors; see rust/tests/integration_runtime.rs.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M

HIDDEN = 64
SEM = 128

# CI-scale dataset dimensions (must match rust DatasetScale::ci():
# round(count/16), feature dims round(dim/16) floored at 4).
IMDB_CI_MOVIES = round(4278 / 16)  # 267
IMDB_CI_MOVIE_FEAT = round(3066 / 16)  # 192
REDDIT_CI_NODES = round(232965 / 10 / 16)  # 1456
REDDIT_CI_FEAT = round(602 / 16)  # 38
ELL_K = 64  # padded neighbor slots per node


def spec(rows: int, cols: int):
    return jax.ShapeDtypeStruct((rows, cols), jnp.float32)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Artifact definitions
# ---------------------------------------------------------------------------


def han_imdb_ci():
    """Full HAN forward at IMDB CI scale, P=2 metapaths (MDM, MAM)."""
    n, feat = IMDB_CI_MOVIES, IMDB_CI_MOVIE_FEAT

    def fn(x, w_proj, idx0, mask0, idx1, mask1, al0, ar0, al1, ar1, sem_w, sem_b, sem_q):
        adjs = [M.EllAdj(idx0, mask0), M.EllAdj(idx1, mask1)]
        z = M.han_forward(
            x,
            w_proj,
            adjs,
            [al0.reshape(-1), al1.reshape(-1)],
            [ar0.reshape(-1), ar1.reshape(-1)],
            sem_w,
            sem_b.reshape(-1),
            sem_q,
        )
        return (z,)

    inputs = [
        ("x_movie", n, feat),
        ("w_proj", feat, HIDDEN),
        ("ell_idx_mdm", n, ELL_K),
        ("ell_mask_mdm", n, ELL_K),
        ("ell_idx_mam", n, ELL_K),
        ("ell_mask_mam", n, ELL_K),
        ("attn_l_mdm", 1, HIDDEN),
        ("attn_r_mdm", 1, HIDDEN),
        ("attn_l_mam", 1, HIDDEN),
        ("attn_r_mam", 1, HIDDEN),
        ("sem_w", HIDDEN, SEM),
        ("sem_b", 1, SEM),
        ("sem_q", SEM, 1),
    ]
    outputs = [("z", n, HIDDEN)]
    return "han_imdb_ci_full", "han", "imdb", "full", fn, inputs, outputs


def gcn_reddit_ci():
    """GCN baseline forward at Reddit-sim CI scale."""
    n, feat = REDDIT_CI_NODES, REDDIT_CI_FEAT

    def fn(x, w_proj, idx, mask):
        return (M.gcn_forward(x, w_proj, M.EllAdj(idx, mask)),)

    inputs = [
        ("x", n, feat),
        ("w_proj", feat, HIDDEN),
        ("ell_idx", n, ELL_K),
        ("ell_mask", n, ELL_K),
    ]
    outputs = [("z", n, HIDDEN)]
    return "gcn_reddit_ci_full", "gcn", "reddit", "full", fn, inputs, outputs


def kernel_dense_matmul():
    """Standalone Pallas tiled matmul (runtime microbench)."""

    def fn(a, b):
        from compile.kernels.dense import dense_matmul

        return (dense_matmul(a, b),)

    inputs = [("a", 128, 256), ("b", 256, 64)]
    outputs = [("c", 128, 64)]
    return "kernel_dense_matmul", "kernel", "none", "dense_matmul", fn, inputs, outputs


def kernel_ell_spmm():
    """Standalone Pallas ELL segment reduction. `gathered` travels as
    2-D [N*K, F] (the Rust runtime speaks 2-D) and is reshaped inside."""
    n, k, f = 256, 16, 64

    def fn(gathered2d, weights, mask):
        from compile.kernels.ellspmm import ell_spmm

        return (ell_spmm(gathered2d.reshape(n, k, f), weights, mask),)

    inputs = [("gathered", n * k, f), ("weights", n, k), ("mask", n, k)]
    outputs = [("out", n, f)]
    return "kernel_ell_spmm", "kernel", "none", "ell_spmm", fn, inputs, outputs


ARTIFACTS: Sequence[Callable] = (
    han_imdb_ci,
    gcn_reddit_ci,
    kernel_dense_matmul,
    kernel_ell_spmm,
)


def build_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"artifacts": []}
    for make in ARTIFACTS:
        name, model, dataset, stage, fn, inputs, outputs = make()
        example = [spec(r, c) for (_, r, c) in inputs]
        print(f"lowering {name} ({len(inputs)} inputs)...", flush=True)
        lowered = jax.jit(fn).lower(*example)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "file": fname,
                "model": model,
                "dataset": dataset,
                "stage": stage,
                "inputs": [
                    {"name": n_, "shape": [r, c]} for (n_, r, c) in inputs
                ],
                "outputs": [
                    {"name": n_, "shape": [r, c]} for (n_, r, c) in outputs
                ],
            }
        )
        print(f"  wrote {fname} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['artifacts'])} artifacts -> {out_dir}/manifest.json")
    return manifest


def hlo_stats(text: str) -> dict:
    """Instruction histogram of an HLO module — the L2 perf-pass audit
    (EXPERIMENTS.md §Perf): fusion quality shows up as few, large fusion
    ops and no stray transpose/copy chains."""
    import re

    ops: dict = {}
    for line in text.splitlines():
        m = re.match(r"\s*(%\S+|ROOT \S+)? ?\S* = \S+ (\w+)\(", line)
        if m:
            ops[m.group(2)] = ops.get(m.group(2), 0) + 1
    total = sum(ops.values())
    return {"total_instructions": total, "ops": ops}


def print_stats(out_dir: str) -> None:
    """`python -m compile.aot --stats`: per-artifact HLO op histogram."""
    with open(os.path.join(out_dir, "manifest.json")) as f:
        manifest = json.load(f)
    for entry in manifest["artifacts"]:
        with open(os.path.join(out_dir, entry["file"])) as f:
            stats = hlo_stats(f.read())
        top = sorted(stats["ops"].items(), key=lambda kv: -kv[1])[:8]
        print(f"{entry['name']}: {stats['total_instructions']} instructions")
        for op, n in top:
            print(f"    {op:<24} {n}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--stats", action="store_true", help="print HLO op histograms")
    args = ap.parse_args()
    if args.stats:
        print_stats(args.out_dir)
    else:
        build_all(args.out_dir)


if __name__ == "__main__":
    main()
