"""L1 Pallas kernel: tiled dense matmul (the paper's `sgemm`).

TPU adaptation of the CUDA threadblock-tiled sgemm (DESIGN.md
§Hardware-Adaptation): output tiles of (bm, bn) are produced by a
sequential K-loop over (bm, bk)x(bk, bn) VMEM-resident operand tiles —
the HBM<->VMEM schedule is expressed entirely through BlockSpec index
maps, with the K axis as the innermost grid dimension so the output
block is revisited and accumulated in place (the "reduction tree" the
paper identifies as the dominant compute shape).

VMEM budget per grid step (fp32):
    bm*bk + bk*bn + bm*bn floats = (64*256 + 256*128 + 64*128) * 4
    = 64 KiB + 128 KiB + 32 KiB = 224 KiB  << 16 MiB VMEM.
MXU: the (bm, bk) x (bk, bn) inner matmul maps onto 128x128 systolic
passes with full lanes when bn is a multiple of 128.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret-mode lowering emits plain HLO (see
/opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes (fp32). Chosen in the L1 perf pass — see
# EXPERIMENTS.md §Perf for the iteration log.
BM, BK, BN = 64, 256, 128


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (i, j, k) grid step: o[i,j] += x[i,k] @ w[k,j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(x: jax.Array, rows: int, cols: int) -> jax.Array:
    return jnp.pad(x, ((0, rows - x.shape[0]), (0, cols - x.shape[1])))


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def dense_matmul(x: jax.Array, w: jax.Array, *, bm: int = BM, bk: int = BK, bn: int = BN):
    """`x @ w` via the Pallas tiled kernel; arbitrary 2-D shapes.

    Inputs are zero-padded up to tile multiples inside the jit (XLA fuses
    the pad/slice with neighbors), so callers never see the tiling.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims {k} vs {k2}"
    bm_, bk_, bn_ = min(bm, _ceil_mult(m, 8)), min(bk, _ceil_mult(k, 8)), min(bn, _ceil_mult(n, 8))
    mp, kp, np_ = _round_up(m, bm_), _round_up(k, bk_), _round_up(n, bn_)
    xp = _pad_to(x, mp, kp)
    wp = _pad_to(w, kp, np_)
    grid = (mp // bm_, np_ // bn_, kp // bk_)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _ceil_mult(x: int, m: int) -> int:
    """Smallest multiple of `m` >= x (used to shrink tiles for tiny dims)."""
    return _round_up(max(x, 1), m)


def dense_matmul_bias(x: jax.Array, w: jax.Array, b: jax.Array, *, bm: int = BM, bk: int = BK, bn: int = BN):
    """Fused linear layer: `x @ w + b` (bias add fuses into the epilogue)."""
    return dense_matmul(x, w, bm=bm, bk=bk, bn=bn) + b.reshape(1, -1)
