"""L1 Pallas kernels: element-wise maps (the paper's `uEleWise`).

Only ELU is needed as a standalone kernel (GAT output activation); the
remaining EW work in the models (tanh, broadcast scaling) fuses into
neighboring XLA ops at L2 and would gain nothing from a hand kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256


def _elu_kernel(x_ref, o_ref):
    x = x_ref[...]
    o_ref[...] = jnp.where(x >= 0, x, jnp.expm1(x))


@functools.partial(jax.jit, static_argnames=("bn",))
def elu(x: jax.Array, *, bn: int = BLOCK_ROWS):
    """ELU over a 2-D tensor via a row-blocked Pallas map."""
    n, f = x.shape
    bn_ = min(bn, n)
    np_ = _round_up(n, bn_)
    xp = jnp.pad(x, ((0, np_ - n), (0, 0)))
    out = pl.pallas_call(
        _elu_kernel,
        grid=(np_ // bn_,),
        in_specs=[pl.BlockSpec((bn_, f), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bn_, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, f), jnp.float32),
        interpret=True,
    )(xp)
    return out[:n]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
