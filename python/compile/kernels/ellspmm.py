"""L1 Pallas kernel: masked ELL segment reduction (the paper's `SpMMCsr`).

TPU adaptation of the warp-per-row CSR SpMM (DESIGN.md
§Hardware-Adaptation): CUDA's dynamic row lengths become an ELL layout —
every node row is padded to K neighbor slots with a validity mask — so
the reduction has the static shape Pallas/MXU need. The irregular gather
itself (`x[idx]`) is hoisted to L2 as an XLA `take`; the Pallas kernel
owns the hot reduction:

    out[n, f] = sum_k  w[n, k] * mask[n, k] * gathered[n, k, f]

VMEM per grid step: (bn*K*F + 2*bn*K + bn*F) * 4 bytes; with the default
bn=8, K<=128, F<=128 that is <= 4.5 MiB, inside the 16 MiB budget.
The K-axis reduction is a lane-dimension tree sum (reduction-tree
compute graph, as the paper highlights for all dominant kernels).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN_NODES = 64  # node rows per grid step (perf pass: 8 -> 32 -> 64, see EXPERIMENTS.md)


def _ellspmm_kernel(g_ref, w_ref, m_ref, o_ref):
    g = g_ref[...]  # [bn, K, F]
    w = (w_ref[...] * m_ref[...])[..., None]  # [bn, K, 1]
    o_ref[...] = jnp.sum(g * w, axis=1)


@functools.partial(jax.jit, static_argnames=("bn",))
def ell_spmm(gathered: jax.Array, weights: jax.Array, mask: jax.Array, *, bn: int = BN_NODES):
    """Masked weighted reduction over the ELL K axis.

    gathered: [N, K, F] neighbor features (already gathered at L2)
    weights:  [N, K]    per-slot weights (attention or 1/deg)
    mask:     [N, K]    1.0 for valid slots, 0.0 for padding
    returns   [N, F]
    """
    n, k, f = gathered.shape
    assert weights.shape == (n, k) and mask.shape == (n, k)
    bn_ = min(bn, n)
    np_ = _round_up(n, bn_)
    g = jnp.pad(gathered, ((0, np_ - n), (0, 0), (0, 0)))
    w = jnp.pad(weights, ((0, np_ - n), (0, 0)))
    m = jnp.pad(mask, ((0, np_ - n), (0, 0)))
    out = pl.pallas_call(
        _ellspmm_kernel,
        grid=(np_ // bn_,),
        in_specs=[
            pl.BlockSpec((bn_, k, f), lambda i: (i, 0, 0)),
            pl.BlockSpec((bn_, k), lambda i: (i, 0)),
            pl.BlockSpec((bn_, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn_, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, f), jnp.float32),
        interpret=True,
    )(g, w, m)
    return out[:n]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
