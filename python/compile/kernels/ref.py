"""Pure-jnp oracles for every Pallas kernel — the CORE correctness
signal: pytest asserts kernel == ref under allclose across hypothesis
shape sweeps (python/tests/test_kernels.py)."""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e9


def dense_matmul_ref(x, w):
    """Oracle for kernels.dense.dense_matmul."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def dense_matmul_bias_ref(x, w, b):
    """Oracle for kernels.dense.dense_matmul_bias."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32) + b.reshape(1, -1)


def ell_spmm_ref(gathered, weights, mask):
    """Oracle for kernels.ellspmm.ell_spmm."""
    w = (weights * mask)[..., None]
    return jnp.sum(gathered * w, axis=1)


def sddmm_ell_ref(s_dst, s_src_gathered, mask, slope=0.2):
    """Oracle for kernels.sddmm.sddmm_ell."""
    e = s_dst[:, None] + s_src_gathered
    e = jnp.where(e >= 0, e, slope * e)
    return jnp.where(mask > 0, e, NEG_INF)


def seg_softmax_ref(logits, mask):
    """Oracle for kernels.softmax.seg_softmax."""
    mx = jnp.max(logits, axis=1, keepdims=True)
    ex = jnp.exp(logits - mx) * mask
    denom = jnp.maximum(jnp.sum(ex, axis=1, keepdims=True), 1e-20)
    return ex / denom


def elu_ref(x):
    """Oracle for kernels.elementwise.elu."""
    return jnp.where(x >= 0, x, jnp.expm1(x))
