"""L1 Pallas kernel: ELL SDDMM (the paper's `SDDMMCoo`).

Computes GAT edge logits over the padded neighbor layout:

    logits[n, k] = leakyrelu(s_dst[n] + s_src_gathered[n, k])

The per-node attention terms s_dst/s_src are dense matvec products
computed at L2 (DGL lowers them as broadcast-mul + reduce); the gather
of s_src along neighbor indices is an XLA take. Padding slots are
masked to a large negative value so the downstream segment softmax
assigns them zero weight.

VMEM per grid step: 3 * bn * K * 4 bytes — trivially small; this kernel
is bandwidth-shaped (the paper places SDDMM far below the roofline
ridge at AI 0.14-0.49).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN_NODES = 64
NEG_INF = -1e9


def _sddmm_kernel(sd_ref, ss_ref, m_ref, o_ref, *, slope: float):
    sd = sd_ref[...]  # [bn, 1]
    ss = ss_ref[...]  # [bn, K]
    m = m_ref[...]  # [bn, K]
    e = sd + ss
    e = jnp.where(e >= 0, e, slope * e)
    o_ref[...] = jnp.where(m > 0, e, NEG_INF)


@functools.partial(jax.jit, static_argnames=("slope", "bn"))
def sddmm_ell(
    s_dst: jax.Array,
    s_src_gathered: jax.Array,
    mask: jax.Array,
    *,
    slope: float = 0.2,
    bn: int = BN_NODES,
):
    """Edge logits over the ELL layout.

    s_dst:          [N]     destination attention terms
    s_src_gathered: [N, K]  source attention terms per neighbor slot
    mask:           [N, K]  validity
    returns         [N, K]  leaky-relu logits, NEG_INF at padding
    """
    n, k = s_src_gathered.shape
    assert s_dst.shape == (n,) and mask.shape == (n, k)
    bn_ = min(bn, n)
    np_ = _round_up(n, bn_)
    sd = jnp.pad(s_dst.reshape(n, 1), ((0, np_ - n), (0, 0)))
    ss = jnp.pad(s_src_gathered, ((0, np_ - n), (0, 0)))
    m = jnp.pad(mask, ((0, np_ - n), (0, 0)))
    kernel = functools.partial(_sddmm_kernel, slope=slope)
    out = pl.pallas_call(
        kernel,
        grid=(np_ // bn_,),
        in_specs=[
            pl.BlockSpec((bn_, 1), lambda i: (i, 0)),
            pl.BlockSpec((bn_, k), lambda i: (i, 0)),
            pl.BlockSpec((bn_, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn_, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, k), jnp.float32),
        interpret=True,
    )(sd, ss, m)
    return out[:n]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
