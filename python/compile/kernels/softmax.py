"""L1 Pallas kernel: masked segment softmax over the ELL K axis
(DGL's `edge_softmax`).

    w[n, k] = exp(e[n,k] - max_k e[n,:]) / sum_k exp(...)    over valid k

Padding slots carry NEG_INF logits (from `sddmm_ell`) and therefore get
exactly zero weight; rows with no valid slots produce all-zero weights
(guarded denominator) rather than NaN — mirroring DGL's behavior on
isolated nodes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN_NODES = 64


def _segsoftmax_kernel(e_ref, m_ref, o_ref):
    e = e_ref[...]  # [bn, K]
    m = m_ref[...]  # [bn, K]
    mx = jnp.max(e, axis=1, keepdims=True)
    ex = jnp.exp(e - mx) * m
    denom = jnp.sum(ex, axis=1, keepdims=True)
    o_ref[...] = ex / jnp.maximum(denom, 1e-20)


@functools.partial(jax.jit, static_argnames=("bn",))
def seg_softmax(logits: jax.Array, mask: jax.Array, *, bn: int = BN_NODES):
    """Masked softmax over axis 1. logits/mask: [N, K] -> weights [N, K]."""
    n, k = logits.shape
    assert mask.shape == (n, k)
    bn_ = min(bn, n)
    np_ = _round_up(n, bn_)
    e = jnp.pad(logits, ((0, np_ - n), (0, 0)))
    m = jnp.pad(mask, ((0, np_ - n), (0, 0)))
    out = pl.pallas_call(
        _segsoftmax_kernel,
        grid=(np_ // bn_,),
        in_specs=[
            pl.BlockSpec((bn_, k), lambda i: (i, 0)),
            pl.BlockSpec((bn_, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn_, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, k), jnp.float32),
        interpret=True,
    )(e, m)
    return out[:n]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
