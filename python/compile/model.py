"""L2: the HGNN forward passes in JAX, calling the L1 Pallas kernels.

The stages mirror rust/src/engine/stages.rs exactly (same math, ELL
instead of CSR) so the PJRT artifacts and the native Rust engine agree
numerically — rust/tests/integration_runtime.rs asserts it.

Adjacency enters as ELL arrays (`idx` [N, K] int-valued, `mask` [N, K]
float) because Pallas needs static shapes; indices travel as f32 (the
Rust runtime feeds f32 literals; values < 2^24 are exact) and are cast
on entry.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from compile.kernels.dense import dense_matmul, dense_matmul_bias
from compile.kernels.elementwise import elu
from compile.kernels.ellspmm import ell_spmm
from compile.kernels.sddmm import sddmm_ell
from compile.kernels.softmax import seg_softmax


class EllAdj(NamedTuple):
    """One metapath subgraph in ELL form."""

    idx: jax.Array  # [N, K] neighbor row ids (f32-carried ints)
    mask: jax.Array  # [N, K] 1.0 valid / 0.0 padding


def _gather_rows(h: jax.Array, idx: jax.Array) -> jax.Array:
    """L2 irregular gather (XLA take): [N_src, F], [N, K] -> [N, K, F]."""
    return jnp.take(h, idx.astype(jnp.int32), axis=0)


def han_na_one_subgraph(
    h: jax.Array,
    adj: EllAdj,
    attn_l: jax.Array,
    attn_r: jax.Array,
    slope: float = 0.2,
) -> jax.Array:
    """HAN Neighbor Aggregation for one metapath subgraph (GAT).

    Mirrors the kernel sequence the paper profiles: attention terms
    (broadcast-mul + reduce), SDDMM, edge softmax, weighted SpMM, ELU.
    """
    s_dst = jnp.sum(h * attn_l.reshape(1, -1), axis=1)  # [N]
    s_src = jnp.sum(h * attn_r.reshape(1, -1), axis=1)  # [N]
    s_src_g = jnp.take(s_src, adj.idx.astype(jnp.int32), axis=0)  # [N, K]
    logits = sddmm_ell(s_dst, s_src_g, adj.mask, slope=slope)
    weights = seg_softmax(logits, adj.mask)
    gathered = _gather_rows(h, adj.idx)  # [N, K, F]
    agg = ell_spmm(gathered, weights, adj.mask)
    return elu(agg)


def mean_na_one_subgraph(h_src: jax.Array, adj: EllAdj) -> jax.Array:
    """R-GCN / GCN mean Neighbor Aggregation for one subgraph."""
    deg = jnp.sum(adj.mask, axis=1, keepdims=True)  # [N, 1]
    weights = adj.mask / jnp.maximum(deg, 1.0)
    gathered = _gather_rows(h_src, adj.idx)
    return ell_spmm(gathered, weights, adj.mask)


def semantic_attention(
    na_results: Sequence[jax.Array],
    sem_w: jax.Array,
    sem_b: jax.Array,
    sem_q: jax.Array,
) -> jax.Array:
    """HAN Semantic Aggregation: the paper's §4.4 pipeline.

    Concat -> sgemm(+bias) -> tanh -> sgemm -> per-metapath mean ->
    softmax -> broadcast scale -> Reduce.
    """
    p = len(na_results)
    n, f = na_results[0].shape
    stacked = jnp.concatenate(na_results, axis=0)  # [P*N, F]  (Concat, DR)
    t = jnp.tanh(dense_matmul_bias(stacked, sem_w, sem_b))  # sgemm + uEleWise
    scores = dense_matmul(t, sem_q).reshape(p, n)  # sgemm
    beta_raw = jnp.mean(scores, axis=1)  # Reduce
    beta = jax.nn.softmax(beta_raw)  # uEleWise
    scaled = stacked * jnp.repeat(beta, n)[:, None]  # vEleWise
    return jnp.sum(scaled.reshape(p, n, f), axis=0)  # Reduce


def han_forward(
    x: jax.Array,
    w_proj: jax.Array,
    adjs: Sequence[EllAdj],
    attn_l: Sequence[jax.Array],
    attn_r: Sequence[jax.Array],
    sem_w: jax.Array,
    sem_b: jax.Array,
    sem_q: jax.Array,
    slope: float = 0.2,
):
    """Full HAN inference: FP -> NA per metapath -> SA."""
    h = dense_matmul(x, w_proj)  # ② FP (sgemm)
    na = [
        han_na_one_subgraph(h, adj, al, ar, slope)  # ③ NA
        for adj, al, ar in zip(adjs, attn_l, attn_r)
    ]
    return semantic_attention(na, sem_w, sem_b, sem_q)  # ④ SA


def gcn_forward(x: jax.Array, w_proj: jax.Array, adj: EllAdj):
    """GCN baseline: FP then mean NA (no SA)."""
    h = dense_matmul(x, w_proj)
    return mean_na_one_subgraph(h, adj)


def rgcn_forward(
    xs: Sequence[jax.Array],
    w_projs: Sequence[jax.Array],
    adjs: Sequence[EllAdj],
    src_of: Sequence[int],
    dst_rows: Sequence[int],
    target_relations: Sequence[int],
):
    """R-GCN: per-type FP, per-relation mean NA, sum SA over the
    relations targeting the output type.

    src_of[r]  — node-type index of relation r's source side
    dst_rows[r] — row count of relation r's destination side (static)
    target_relations — relation indices summed into the output
    """
    hs = [dense_matmul(x, w) for x, w in zip(xs, w_projs)]
    na = [mean_na_one_subgraph(hs[src_of[r]], adjs[r]) for r in range(len(adjs))]
    del dst_rows  # shapes are static; kept for call-site documentation
    out = na[target_relations[0]]
    for r in target_relations[1:]:
        out = out + na[r]
    return out


# ---------------------------------------------------------------------------
# ELL preprocessing (build-time only; the Rust side has its own in
# graph/sparse.rs — to_ell — with identical truncation semantics)
# ---------------------------------------------------------------------------


def csr_to_ell(indptr, indices, n_rows: int, k: int):
    """Convert CSR arrays to (idx, mask) ELL numpy arrays with row
    truncation at k (deterministic prefix, matching Csr::to_ell)."""
    import numpy as np

    idx = np.zeros((n_rows, k), dtype=np.float32)
    mask = np.zeros((n_rows, k), dtype=np.float32)
    for r in range(n_rows):
        row = indices[indptr[r] : indptr[r + 1]][:k]
        idx[r, : len(row)] = row
        mask[r, : len(row)] = 1.0
    return idx, mask
