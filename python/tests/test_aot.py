"""AOT pipeline smoke tests: lowering to HLO text and manifest schema.

The full round-trip (HLO text -> rust PJRT -> numerics) is covered by
rust/tests/integration_runtime.rs; here we validate the Python side in
isolation so `pytest` fails fast when a jax upgrade breaks lowering.
"""

import json

import jax
import jax.numpy as jnp

from compile import aot
from compile import model as M

jax.config.update("jax_platforms", "cpu")


class TestHloEmission:
    def test_tiny_pallas_fn_lowers_to_hlo_text(self):
        def fn(a, b):
            from compile.kernels.dense import dense_matmul

            return (dense_matmul(a, b),)

        lowered = jax.jit(fn).lower(aot.spec(16, 16), aot.spec(16, 16))
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), text[:80]
        # jax >= 0.5 ids must have been reassigned by the text path —
        # the text parser guarantees this; just check it is plain text
        assert "ENTRY" in text

    def test_artifact_defs_are_consistent(self):
        for make in aot.ARTIFACTS:
            name, model, dataset, stage, fn, inputs, outputs = make()
            assert name and model and dataset and stage
            assert len(inputs) >= 1 and len(outputs) >= 1
            for n_, r, c in inputs + outputs:
                assert isinstance(n_, str) and r > 0 and c > 0

    def test_build_all_writes_manifest(self, tmp_path):
        # build only the two kernel artifacts (fast) by monkeypatching
        import compile.aot as A

        saved = A.ARTIFACTS
        try:
            A.ARTIFACTS = (A.kernel_dense_matmul, A.kernel_ell_spmm)
            manifest = A.build_all(str(tmp_path))
        finally:
            A.ARTIFACTS = saved
        assert len(manifest["artifacts"]) == 2
        on_disk = json.loads((tmp_path / "manifest.json").read_text())
        assert on_disk == manifest
        for entry in on_disk["artifacts"]:
            hlo = (tmp_path / entry["file"]).read_text()
            assert hlo.startswith("HloModule")
            for spec_ in entry["inputs"] + entry["outputs"]:
                assert len(spec_["shape"]) == 2

    def test_ci_dims_match_rust_datasetscale(self):
        # DatasetScale::ci() == round(x/16); these constants must agree
        # with rust/src/datasets (integration_runtime feeds real tensors)
        assert aot.IMDB_CI_MOVIES == round(4278 / 16)
        assert aot.IMDB_CI_MOVIE_FEAT == round(3066 / 16)
        assert aot.REDDIT_CI_NODES == round(232965 / 10 / 16)
        assert aot.REDDIT_CI_FEAT == round(602 / 16)


class TestEllPreprocessing:
    def test_csr_to_ell_matches_rust_semantics(self):
        import numpy as np

        # same example as rust graph::sparse tests
        indptr = np.array([0, 2, 2, 5])
        indices = np.array([1, 3, 0, 1, 2])
        idx, mask = M.csr_to_ell(indptr, indices, 3, 3)
        assert mask.sum() == 5
        idx2, mask2 = M.csr_to_ell(indptr, indices, 3, 2)
        assert mask2.sum() == 4  # one truncated

    def test_han_artifact_shapes_execute(self):
        # run the exact artifact function with real arrays (small adj)
        name, _, _, _, fn, inputs, outputs = aot.han_imdb_ci()
        args = [jnp.zeros((r, c), jnp.float32) for (_, r, c) in inputs]
        (z,) = fn(*args)
        assert z.shape == tuple(outputs[0][1:])
