"""L1 correctness: every Pallas kernel against its pure-jnp oracle.

Hypothesis sweeps shapes; fixed cases cover the paper-relevant sizes and
the degenerate edges (all-masked rows, single row, non-tile-multiple
dims).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.dense import dense_matmul, dense_matmul_bias
from compile.kernels.elementwise import elu
from compile.kernels.ellspmm import ell_spmm
from compile.kernels.sddmm import sddmm_ell
from compile.kernels.softmax import seg_softmax

jax.config.update("jax_platforms", "cpu")

HYPO = settings(max_examples=12, deadline=None)


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


def assert_close(a, b, rtol=1e-5, atol=1e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# dense_matmul
# ---------------------------------------------------------------------------


class TestDenseMatmul:
    @pytest.mark.parametrize(
        "m,k,n",
        [(1, 1, 1), (64, 256, 128), (67, 190, 33), (128, 64, 64), (267, 192, 64)],
    )
    def test_fixed_shapes(self, m, k, n):
        x, w = rand(0, m, k), rand(1, k, n)
        assert_close(dense_matmul(x, w), ref.dense_matmul_ref(x, w), rtol=1e-4, atol=1e-4)

    @HYPO
    @given(
        m=st.integers(1, 96),
        k=st.integers(1, 96),
        n=st.integers(1, 96),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shapes(self, m, k, n, seed):
        x, w = rand(seed, m, k), rand(seed + 1, k, n)
        assert_close(dense_matmul(x, w), ref.dense_matmul_ref(x, w), rtol=1e-4, atol=1e-4)

    def test_bias(self):
        x, w = rand(2, 32, 48), rand(3, 48, 16)
        b = rand(4, 16)
        assert_close(
            dense_matmul_bias(x, w, b),
            ref.dense_matmul_bias_ref(x, w, b),
            rtol=1e-4,
            atol=1e-4,
        )

    def test_zeros(self):
        x = jnp.zeros((16, 16))
        w = rand(5, 16, 16)
        assert_close(dense_matmul(x, w), jnp.zeros((16, 16)))

    def test_one_hot_selects_rows(self):
        # one-hot features (DBLP-style) select weight rows exactly
        x = jnp.eye(8, dtype=jnp.float32)
        w = rand(6, 8, 12)
        assert_close(dense_matmul(x, w), w)


# ---------------------------------------------------------------------------
# ell_spmm
# ---------------------------------------------------------------------------


def random_ell(seed, n, k, n_src):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, n_src, size=(n, k)).astype(np.float32)
    mask = (rng.random((n, k)) < 0.7).astype(np.float32)
    return jnp.asarray(idx), jnp.asarray(mask)


class TestEllSpmm:
    @pytest.mark.parametrize("n,k,f", [(8, 4, 16), (267, 64, 64), (9, 1, 8), (1, 16, 128)])
    def test_fixed_shapes(self, n, k, f):
        idx, mask = random_ell(n * k, n, k, n)
        h = rand(7, n, f)
        gathered = jnp.take(h, idx.astype(jnp.int32), axis=0)
        w = jnp.abs(rand(8, n, k))
        assert_close(
            ell_spmm(gathered, w, mask),
            ref.ell_spmm_ref(gathered, w, mask),
            rtol=1e-5,
            atol=1e-5,
        )

    @HYPO
    @given(
        n=st.integers(1, 64),
        k=st.integers(1, 32),
        f=st.integers(1, 96),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shapes(self, n, k, f, seed):
        idx, mask = random_ell(seed, n, k, max(n, 2))
        gathered = rand(seed, n, k, f)
        w = rand(seed + 1, n, k)
        assert_close(
            ell_spmm(gathered, w, mask),
            ref.ell_spmm_ref(gathered, w, mask),
            rtol=1e-4,
            atol=1e-4,
        )

    def test_fully_masked_row_is_zero(self):
        gathered = rand(9, 4, 8, 16)
        w = jnp.ones((4, 8))
        mask = jnp.zeros((4, 8)).at[1:].set(1.0)
        out = ell_spmm(gathered, w, mask)
        assert_close(out[0], jnp.zeros(16))

    def test_uniform_weights_mean_equivalence(self):
        # mean NA: weights 1/deg reproduces the mean of valid neighbors
        n, k, f = 6, 5, 8
        idx, mask = random_ell(11, n, k, n)
        h = rand(12, n, f)
        gathered = jnp.take(h, idx.astype(jnp.int32), axis=0)
        deg = jnp.maximum(mask.sum(axis=1, keepdims=True), 1.0)
        out = ell_spmm(gathered, mask / deg, mask)
        manual = (gathered * mask[..., None]).sum(axis=1) / deg
        assert_close(out, manual, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# sddmm + seg_softmax
# ---------------------------------------------------------------------------


class TestSddmmSoftmax:
    @pytest.mark.parametrize("n,k", [(4, 4), (267, 64), (1, 1), (100, 7)])
    def test_sddmm_matches_ref(self, n, k):
        s_dst = rand(13, n).reshape(n)
        s_src_g = rand(14, n, k)
        _, mask = random_ell(15, n, k, n)
        assert_close(
            sddmm_ell(s_dst, s_src_g, mask),
            ref.sddmm_ell_ref(s_dst, s_src_g, mask),
            rtol=1e-5,
            atol=1e-5,
        )

    @HYPO
    @given(n=st.integers(1, 64), k=st.integers(1, 32), seed=st.integers(0, 2**16))
    def test_softmax_hypothesis(self, n, k, seed):
        logits = rand(seed, n, k)
        _, mask = random_ell(seed + 1, n, k, 4)
        masked_logits = jnp.where(mask > 0, logits, ref.NEG_INF)
        assert_close(
            seg_softmax(masked_logits, mask),
            ref.seg_softmax_ref(masked_logits, mask),
            rtol=1e-5,
            atol=1e-6,
        )

    def test_softmax_rows_sum_to_one(self):
        n, k = 10, 8
        logits = rand(16, n, k)
        mask = jnp.ones((n, k))
        w = seg_softmax(logits, mask)
        assert_close(w.sum(axis=1), jnp.ones(n), rtol=1e-5, atol=1e-5)

    def test_softmax_all_masked_row_is_zero(self):
        logits = jnp.full((2, 4), ref.NEG_INF)
        mask = jnp.zeros((2, 4))
        w = seg_softmax(logits, mask)
        assert_close(w, jnp.zeros((2, 4)))

    def test_sddmm_negative_slope(self):
        s_dst = jnp.array([-1.0])
        s_src = jnp.array([[-1.0]])
        mask = jnp.ones((1, 1))
        out = sddmm_ell(s_dst, s_src, mask, slope=0.1)
        assert_close(out, jnp.array([[-0.2]]), rtol=1e-6, atol=1e-7)

    def test_softmax_stability_large_logits(self):
        logits = jnp.array([[1e4, 1e4]])
        mask = jnp.ones((1, 2))
        w = seg_softmax(logits, mask)
        assert_close(w, jnp.array([[0.5, 0.5]]), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# elu
# ---------------------------------------------------------------------------


class TestElu:
    @HYPO
    @given(n=st.integers(1, 300), f=st.integers(1, 80), seed=st.integers(0, 2**16))
    def test_hypothesis(self, n, f, seed):
        x = rand(seed, n, f) * 3.0
        assert_close(elu(x), ref.elu_ref(x), rtol=1e-5, atol=1e-6)

    def test_identity_for_positive(self):
        x = jnp.abs(rand(17, 8, 8)) + 0.1
        assert_close(elu(x), x)
