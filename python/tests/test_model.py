"""L2 correctness: model forwards — shapes, stage semantics, invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

jax.config.update("jax_platforms", "cpu")


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


def toy_ell(seed, n, k):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, n, size=(n, k)).astype(np.float32)
    mask = (rng.random((n, k)) < 0.6).astype(np.float32)
    return M.EllAdj(jnp.asarray(idx), jnp.asarray(mask))


class TestHan:
    N, FEAT, H, K, S = 23, 17, 16, 6, 32

    def params(self):
        return dict(
            x=rand(0, self.N, self.FEAT),
            w_proj=rand(1, self.FEAT, self.H),
            adjs=[toy_ell(2, self.N, self.K), toy_ell(3, self.N, self.K)],
            attn_l=[rand(4, self.H), rand(5, self.H)],
            attn_r=[rand(6, self.H), rand(7, self.H)],
            sem_w=rand(8, self.H, self.S),
            sem_b=rand(9, self.S),
            sem_q=rand(10, self.S, 1),
        )

    def test_output_shape(self):
        z = M.han_forward(**self.params())
        assert z.shape == (self.N, self.H)
        assert bool(jnp.isfinite(z).all())

    def test_sa_is_convex_combination_of_na(self):
        p = self.params()
        h = ref.dense_matmul_ref(p["x"], p["w_proj"])
        na = [
            M.han_na_one_subgraph(h, adj, al, ar)
            for adj, al, ar in zip(p["adjs"], p["attn_l"], p["attn_r"])
        ]
        z = M.semantic_attention(na, p["sem_w"], p["sem_b"], p["sem_q"])
        lo = jnp.minimum(na[0], na[1]) - 1e-5
        hi = jnp.maximum(na[0], na[1]) + 1e-5
        assert bool(((z >= lo) & (z <= hi)).all())

    def test_attention_weights_respond_to_structure(self):
        # empty adjacency (all-masked) produces ELU(0)=0 NA output
        p = self.params()
        h = ref.dense_matmul_ref(p["x"], p["w_proj"])
        empty = M.EllAdj(jnp.zeros((self.N, self.K)), jnp.zeros((self.N, self.K)))
        na = M.han_na_one_subgraph(h, empty, p["attn_l"][0], p["attn_r"][0])
        np.testing.assert_allclose(np.asarray(na), 0.0, atol=1e-6)

    def test_jit_lowers(self):
        # the exact path aot.py takes: jit + lower + HLO text
        p = self.params()

        def fn(x, w):
            return (M.han_forward(
                x, w, p["adjs"], p["attn_l"], p["attn_r"], p["sem_w"], p["sem_b"], p["sem_q"]
            ),)

        lowered = jax.jit(fn).lower(
            jax.ShapeDtypeStruct((self.N, self.FEAT), jnp.float32),
            jax.ShapeDtypeStruct((self.FEAT, self.H), jnp.float32),
        )
        text = str(lowered.compiler_ir("stablehlo"))
        assert "stablehlo" in text or "module" in text


class TestMeanNa:
    def test_matches_manual_mean(self):
        n, k, f = 9, 4, 8
        adj = toy_ell(20, n, k)
        h = rand(21, n, f)
        out = M.mean_na_one_subgraph(h, adj)
        gathered = jnp.take(h, adj.idx.astype(jnp.int32), axis=0)
        deg = jnp.maximum(adj.mask.sum(axis=1, keepdims=True), 1.0)
        manual = (gathered * adj.mask[..., None]).sum(axis=1) / deg
        np.testing.assert_allclose(np.asarray(out), np.asarray(manual), rtol=1e-5, atol=1e-5)

    def test_gcn_forward_shape(self):
        n, feat, h = 31, 12, 16
        z = M.gcn_forward(rand(22, n, feat), rand(23, feat, h), toy_ell(24, n, h))
        assert z.shape == (n, h)


class TestRgcn:
    def test_sum_over_target_relations(self):
        # two node types; rel0: t1 -> t0, rel1: t0 -> t0
        n0, n1, f0, f1, h, k = 7, 5, 6, 4, 8, 3
        xs = [rand(30, n0, f0), rand(31, n1, f1)]
        ws = [rand(32, f0, h), rand(33, f1, h)]
        rng = np.random.default_rng(34)
        adj0 = M.EllAdj(
            jnp.asarray(rng.integers(0, n1, (n0, k)).astype(np.float32)),
            jnp.asarray((rng.random((n0, k)) < 0.5).astype(np.float32)),
        )
        adj1 = M.EllAdj(
            jnp.asarray(rng.integers(0, n0, (n0, k)).astype(np.float32)),
            jnp.asarray((rng.random((n0, k)) < 0.5).astype(np.float32)),
        )
        out = M.rgcn_forward(xs, ws, [adj0, adj1], src_of=[1, 0], dst_rows=[n0, n0],
                             target_relations=[0, 1])
        assert out.shape == (n0, h)
        # manual: sum of the two mean aggregations
        na0 = M.mean_na_one_subgraph(ref.dense_matmul_ref(xs[1], ws[1]), adj0)
        na1 = M.mean_na_one_subgraph(ref.dense_matmul_ref(xs[0], ws[0]), adj1)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(na0 + na1), rtol=1e-5, atol=1e-5
        )


class TestCsrToEll:
    def test_roundtrip_and_truncation(self):
        indptr = np.array([0, 2, 2, 5])
        indices = np.array([1, 3, 0, 1, 2])
        idx, mask = M.csr_to_ell(indptr, indices, 3, 2)
        assert mask[0].tolist() == [1.0, 1.0]
        assert mask[1].tolist() == [0.0, 0.0]
        # row 2 truncated to first 2 of 3
        assert idx[2].tolist() == [0.0, 1.0]
        assert mask[2].tolist() == [1.0, 1.0]
