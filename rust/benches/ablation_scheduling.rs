//! Bench: **§5 guideline ablations** — quantify the paper's proposed
//! software optimizations with the coordinator's schedule policies:
//!
//! 1. execution-bound-aware kernel mixing (overlap compute-bound DM with
//!    memory-bound TB/EW/DR kernels);
//! 2. subgraph-level FP+NA fusion;
//! 3. inter-subgraph parallelism (the Fig 5c observation applied).
//!
//! Reported numbers are modeled-T4 makespans; wallclock of the native
//! execution is also shown for the record.
//!
//! Run: `cargo bench --bench ablation_scheduling`

use hgnn_char::bench::{bench, header, BenchConfig};
use hgnn_char::datasets::{DatasetId, DatasetScale};
use hgnn_char::models::ModelId;
use hgnn_char::session::{SchedulePolicy, Session};

fn scale() -> DatasetScale {
    if std::env::var("QUICK_BENCH").is_ok() {
        DatasetScale::ci()
    } else {
        DatasetScale::factor(0.5)
    }
}

fn main() {
    header(
        "§5 guideline ablations — scheduling policies",
        "sequential vs inter-subgraph parallel vs fused vs bound-aware mixing",
    );
    let cfg = BenchConfig::from_env();
    let policies = SchedulePolicy::all(4);
    for model in [ModelId::Han, ModelId::Rgcn] {
        for dataset in [DatasetId::Dblp, DatasetId::Acm] {
            println!("\n### {} on {} ###", model.name(), dataset.name());
            // one session per (model, dataset): the policy swaps between
            // runs while graph/plan/scratch are reused
            let mut session = Session::builder()
                .dataset(dataset)
                .scale(scale())
                .model(model)
                .build()
                .unwrap();
            let mut baseline = None;
            for policy in policies {
                session.set_schedule(policy);
                let r = bench(
                    &format!("{} wall", policy.label()),
                    &BenchConfig { iters: cfg.iters.min(3), ..cfg.clone() },
                    || session.run().unwrap(),
                );
                let run = session.run().unwrap();
                let makespan = run.report.modeled_makespan_ns;
                let base = *baseline.get_or_insert(makespan);
                println!(
                    "  {}   vs-seq {:.2}x   ({})",
                    run.report.summary(),
                    base / makespan.max(1.0),
                    r.line()
                );
            }
        }
    }
    println!("\n(ablation reading: the gap between 'sequential' and the other rows is");
    println!(" the modeled benefit of each §5 guideline on this workload mix)");
}
