//! Bench: **simulation-cluster execution — wire cost of the distributed
//! forward**.
//!
//! The distributed executor runs the same owner-computes FP/NA/SA plan
//! as the in-process sharded path, but every halo row, merge row and
//! control message crosses the length-prefixed wire codec through the
//! coordinator's stop-and-wait protocol. This bench quantifies that
//! overhead: each cell builds a session with `.cluster(ClusterSpec)` at
//! workers ∈ {1, 2, 4} over the deterministic [`SimTransport`] and
//! times `Session::run` end-to-end, reporting the frames and payload
//! bytes the wave moved.
//!
//! Expected qualitative trend: wall time *rises* with worker count —
//! the sim transport serializes the protocol on one thread, so this
//! sweep isolates codec + protocol cost, not parallel speedup (that is
//! `shard_scaling`'s job). Wire bytes grow with the halo surface of the
//! partition; frames grow roughly linearly in workers per wave.
//!
//! Every cell cross-checks against the monolithic forward (a cheap
//! frob-norm fingerprint; `tests/integration_cluster.rs` pins exact
//! bytes), so the protocol can never converge to a different answer.
//!
//! Run: `cargo bench --bench cluster_scaling`

use hgnn_char::bench::{bench, header, BenchConfig};
use hgnn_char::cluster::ClusterSpec;
use hgnn_char::datasets::{DatasetId, DatasetScale};
use hgnn_char::models::ModelId;
use hgnn_char::session::{Session, SessionBuilder};

fn scale() -> DatasetScale {
    if std::env::var("QUICK_BENCH").is_ok() {
        DatasetScale::ci()
    } else {
        DatasetScale::factor(0.5)
    }
}

fn builder() -> SessionBuilder {
    Session::builder()
        .dataset(DatasetId::Dblp)
        .scale(scale())
        .model(ModelId::Han)
}

fn main() {
    header(
        "cluster_scaling",
        "distributed forward over the sim cluster (HAN on synthesized DBLP): \
         workers ∈ {1,2,4}, one shard per worker, stop-and-wait wire protocol",
    );
    let config = BenchConfig::from_env();

    // monolithic reference output fingerprint (bit-identity smoke check)
    let mut reference = builder().build().expect("monolithic session");
    let ref_norm = reference.run().expect("monolithic run").output.frob_norm();

    for workers in [1usize, 2, 4] {
        let mut session = builder()
            .cluster(ClusterSpec::new(workers))
            .build()
            .expect("cluster session");
        // warm + verify against the monolithic forward
        let warm = session.run().expect("cluster run");
        assert!(
            (warm.output.frob_norm() - ref_norm).abs() < 1e-9,
            "distributed output diverged from the monolithic forward"
        );
        let before = session.cluster().expect("cluster").transport_stats();
        let waves_before = session.cluster_stats().expect("stats").waves;
        let result = bench(&format!("forward workers={workers}"), &config, || {
            session.run().expect("cluster run")
        });
        let after = session.cluster().expect("cluster").transport_stats();
        let waves = session.cluster_stats().expect("stats").waves - waves_before;
        let frames = (after.delivered - before.delivered) / waves.max(1);
        let bytes = (after.bytes - before.bytes) / waves.max(1);
        println!(
            "{}  wire/wave: {frames} frame(s), {:.1} KiB",
            result.line(),
            bytes as f64 / 1024.0
        );
    }
}
