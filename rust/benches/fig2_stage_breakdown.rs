//! Bench: regenerate **Fig 2** — execution-time breakdown of the
//! inference phase across {RGCN, HAN, MAGNN} × {IMDB, ACM, DBLP}.
//!
//! Paper reference values (averages across models/datasets):
//! FP 19%, NA 74%, SA 7%; Subgraph Build excluded (CPU-side).
//!
//! Run: `cargo bench --bench fig2_stage_breakdown`
//! (QUICK_BENCH=1 switches to CI scale.)

use hgnn_char::bench::{bench, header, BenchConfig};
use hgnn_char::datasets::{DatasetId, DatasetScale};
use hgnn_char::models::ModelId;
use hgnn_char::profiler::{Profile, StageId};
use hgnn_char::report;
use hgnn_char::session::Session;

fn scale() -> DatasetScale {
    if std::env::var("QUICK_BENCH").is_ok() {
        DatasetScale::ci()
    } else {
        DatasetScale::paper()
    }
}

fn main() {
    header(
        "Fig 2 — stage time breakdown",
        "inference stage shares (modeled T4) per model x dataset",
    );
    let cfg = BenchConfig::from_env();
    let mut profiles: Vec<Profile> = Vec::new();
    for model in ModelId::HGNNS {
        for dataset in DatasetId::HETERO {
            let mut session = Session::builder()
                .dataset(dataset)
                .scale(scale())
                .model(model)
                .build()
                .unwrap();
            // wallclock of the native execution (for the bench harness);
            // the session reuses graph/plan/scratch across iterations
            let r = bench(
                &format!("{}/{}", model.name(), dataset.abbrev()),
                &BenchConfig { iters: cfg.iters.min(3), ..cfg.clone() },
                || session.run().unwrap(),
            );
            println!("{}", r.line());
            let run = session.run().unwrap();
            println!("  {}", report::fig2_row(model.name(), dataset.abbrev(), &run.profile));
            profiles.push(run.profile);
        }
    }
    let refs: Vec<&Profile> = profiles.iter().collect();
    let avg = report::average_stage_pct(&refs);
    println!("\n=== Fig 2 reproduction summary (average) ===");
    println!(
        "{}",
        report::compare("FP share", 19.0, avg[&StageId::FeatureProjection], "%")
    );
    println!(
        "{}",
        report::compare("NA share", 74.0, avg[&StageId::NeighborAggregation], "%")
    );
    println!(
        "{}",
        report::compare("SA share", 7.0, avg[&StageId::SemanticAggregation], "%")
    );
    let na = avg[&StageId::NeighborAggregation];
    println!(
        "\npaper claim 'Neighbor Aggregation dominates': {}",
        if na > avg[&StageId::FeatureProjection] && na > avg[&StageId::SemanticAggregation] {
            "REPRODUCED"
        } else {
            "NOT reproduced at this scale"
        }
    );
}
