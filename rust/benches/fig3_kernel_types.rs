//! Bench: regenerate **Fig 3** — execution-time breakdown by CUDA-kernel
//! type (DM / TB / EW / DR) within each stage, per model and dataset.
//!
//! Paper qualitative reference: FP ≈ pure DM; NA ≈ TB + EW;
//! SA ≈ DM + EW + DR (with DR = the expensive Concat).
//!
//! Run: `cargo bench --bench fig3_kernel_types`

use hgnn_char::bench::header;
use hgnn_char::datasets::{DatasetId, DatasetScale};
use hgnn_char::kernels::KernelType;
use hgnn_char::models::ModelId;
use hgnn_char::profiler::StageId;
use hgnn_char::report;
use hgnn_char::session::Session;

fn scale() -> DatasetScale {
    if std::env::var("QUICK_BENCH").is_ok() {
        DatasetScale::ci()
    } else {
        DatasetScale::paper()
    }
}

fn main() {
    header(
        "Fig 3 — kernel-type breakdown per stage",
        "DM / TB / EW / DR shares of each stage (modeled T4)",
    );
    let mut checks_passed = 0;
    let mut checks_total = 0;
    for model in ModelId::HGNNS {
        for dataset in DatasetId::HETERO {
            let run = Session::builder()
                .dataset(dataset)
                .scale(scale())
                .model(model)
                .build()
                .unwrap()
                .run()
                .unwrap();
            print!("{}", report::fig3_rows(model.name(), dataset.abbrev(), &run.profile));

            // structural checks against the paper's qualitative claims
            let ktt = run.profile.kernel_type_times();
            let share = |stage: StageId, t: KernelType| -> f64 {
                let total: f64 = KernelType::ALL
                    .iter()
                    .map(|&k| ktt.get(&(stage, k)).copied().unwrap_or(0.0))
                    .sum();
                if total == 0.0 {
                    return 0.0;
                }
                100.0 * ktt.get(&(stage, t)).copied().unwrap_or(0.0) / total
            };
            checks_total += 2;
            if share(StageId::FeatureProjection, KernelType::DenseMatmul) > 99.0 {
                checks_passed += 1;
            }
            if share(StageId::NeighborAggregation, KernelType::TopologyBased)
                + share(StageId::NeighborAggregation, KernelType::ElementWise)
                > 90.0
            {
                checks_passed += 1;
            }
        }
    }
    println!("\n=== Fig 3 reproduction summary ===");
    println!("  FP=DM and NA=TB+EW checks: {checks_passed}/{checks_total} passed");
    println!("  (paper: FP dominated by sgemm; NA by SpMM/SDDMM/elementwise)");
}
