//! Bench: regenerate **Fig 4** — every major kernel of HAN-on-DBLP
//! placed on the T4's single-precision roofline.
//!
//! Paper reference points: ridge at 9.37 FLOP/B; sgemm AI 26.8 (above
//! the ridge, compute-bound); SpMMCsr 0.49, SDDMM 0.14, uEleWise 0.1,
//! Reduce 0.34 (all memory-bound).
//!
//! Run: `cargo bench --bench fig4_roofline`

use std::collections::BTreeMap;

use hgnn_char::bench::header;
use hgnn_char::datasets::DatasetScale;
use hgnn_char::datasets::DatasetId;
use hgnn_char::gpumodel::{roofline, GpuModel};
use hgnn_char::models::ModelId;
use hgnn_char::profiler::StageId;
use hgnn_char::report;
use hgnn_char::session::{Profiling, Session};

fn scale() -> DatasetScale {
    if std::env::var("QUICK_BENCH").is_ok() {
        DatasetScale::ci()
    } else {
        DatasetScale::paper()
    }
}

fn main() {
    header(
        "Fig 4 — kernels on the FP32 roofline (HAN, DBLP)",
        "AI and achieved GFLOP/s per kernel, modeled T4",
    );
    let run = Session::builder()
        .dataset(DatasetId::Dblp)
        .scale(scale())
        .model(ModelId::Han)
        .profiling(Profiling::Traces)
        .build()
        .unwrap()
        .run()
        .unwrap();
    let gpu = GpuModel::default();

    // aggregate by kernel name across stages (the paper plots one point
    // per kernel)
    let mut by_name: BTreeMap<String, (f64, f64)> = BTreeMap::new();
    for stage in StageId::GPU_STAGES {
        for (name, m, _) in run.profile.kernel_table(stage) {
            // keep the heaviest instance per name
            let entry = by_name.entry(name).or_insert((m.ai, m.achieved_gflops));
            if m.achieved_gflops > entry.1 {
                *entry = (m.ai, m.achieved_gflops);
            }
        }
    }
    let points: Vec<_> = by_name
        .iter()
        .map(|(name, &(ai, gf))| roofline::place(&gpu.spec, name, ai, gf))
        .collect();
    println!("{}", roofline::ascii_chart(&gpu.spec, &points));

    println!("=== Fig 4 reproduction summary ===");
    println!("{}", report::compare("roofline ridge", 9.37, gpu.spec.ridge_ai(), " F/B"));
    let paper_ai: &[(&str, f64, bool)] = &[
        ("sgemm", 26.8, true),
        ("SpMMCsr", 0.49, false),
        ("SDDMMCoo", 0.14, false),
        ("uEleWise", 0.1, false),
        ("Reduce", 0.34, false),
    ];
    let mut bound_ok = 0;
    for (name, ai_paper, compute_bound) in paper_ai {
        if let Some(p) = points.iter().find(|p| p.name == *name) {
            println!("{}", report::compare(&format!("{name} AI"), *ai_paper, p.ai, " F/B"));
            if p.compute_bound == *compute_bound {
                bound_ok += 1;
            }
        }
    }
    println!(
        "  memory/compute-bound classification matches paper: {bound_ok}/{} kernels",
        paper_ai.len()
    );
}
