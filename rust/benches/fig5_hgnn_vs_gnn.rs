//! Bench: regenerate **Fig 5** — the HGNN-vs-GNN comparison.
//!
//! * (a) NA time rises as edge dropout falls (avg #neighbors grows),
//!   for both HAN and GCN on the Reddit-sim graph.
//! * (b) NA time rises further with the number of metapaths — the
//!   HGNN-only effect (each metapath adds a subgraph to aggregate).
//! * (c) Timeline: inter-subgraph parallelism inside NA, and the hard
//!   NA→SA barrier.
//!
//! Run: `cargo bench --bench fig5_hgnn_vs_gnn`

use hgnn_char::bench::header;
use hgnn_char::datasets::{DatasetId, DatasetScale};
use hgnn_char::models::{sweeps, ModelId};
use hgnn_char::report;
use hgnn_char::session::{SchedulePolicy, Session};

fn scale() -> DatasetScale {
    if std::env::var("QUICK_BENCH").is_ok() {
        DatasetScale::ci()
    } else {
        // Reddit-sim at 1/10-node default inside the generator; sweeps
        // at half scale keep the 1-core wallclock tractable.
        DatasetScale::factor(0.5)
    }
}

fn main() {
    header(
        "Fig 5 — HGNN vs GNN comparison",
        "(a) NA vs dropout  (b) NA vs #metapaths  (c) NA/SA timeline",
    );

    // ---------------- (a) dropout sweep ---------------------------------
    println!("--- Fig 5(a): NA time vs edge dropout (Reddit-sim) ---");
    let series = sweeps::fig5a_dropout_sweep(&scale()).unwrap();
    let mut monotone = true;
    for (label, pts) in &series {
        println!(
            "{}",
            report::sweep_series(label, "dropout", "NA time (modeled ms)", pts)
        );
        // dropout falls along the sweep => time rises
        monotone &= pts.windows(2).all(|w| w[1].1 >= w[0].1 * 0.98);
    }
    println!(
        "paper claim 'NA time increases with avg #neighbors': {}",
        if monotone { "REPRODUCED (both models)" } else { "NOT reproduced" }
    );
    let han_growth = {
        let pts = &series[0].1;
        pts.last().unwrap().1 / pts.first().unwrap().1.max(1e-9)
    };
    println!("HAN NA growth from 0.9 to 0.0 dropout: {han_growth:.1}x\n");

    // ---------------- (b) metapath sweep ---------------------------------
    println!("--- Fig 5(b): NA time vs #metapaths (HAN, DBLP) ---");
    let pts = sweeps::fig5b_metapath_sweep(&scale()).unwrap();
    println!(
        "{}",
        report::sweep_series("HAN-DB", "#metapaths", "NA time (modeled ms)", &pts)
    );
    let rising = pts.windows(2).all(|w| w[1].1 >= w[0].1 * 0.999);
    println!(
        "paper claim 'NA time increases with #metapaths': {}\n",
        if rising { "REPRODUCED" } else { "NOT reproduced" }
    );

    // ---------------- (c) timeline ---------------------------------------
    println!("--- Fig 5(c): timeline (HAN, DBLP, 4 NA streams) ---");
    let run = Session::builder()
        .dataset(DatasetId::Dblp)
        .scale(scale())
        .model(ModelId::Han)
        .schedule(SchedulePolicy::InterSubgraphParallel { workers: 4 })
        .build()
        .unwrap()
        .run()
        .unwrap();
    let tl = run.profile.timeline();
    println!("{}", tl.render(96));
    println!(
        "inter-subgraph parallelism visible: {}",
        if tl.has_cross_lane_overlap() { "REPRODUCED" } else { "NOT reproduced" }
    );
    println!(
        "NA→SA barrier present: {}",
        if !tl.barriers.is_empty() { "REPRODUCED" } else { "NOT reproduced" }
    );
    println!("{}", run.report.summary());
}
