//! Bench: regenerate **Fig 6** — the execution-exploration studies.
//!
//! * (a) subgraph sparsity falls as metapath length grows, on all three
//!   HGs; plus the §5 guideline-3 correlation model fit.
//! * (b) total execution time rises with the number of metapaths.
//!
//! Run: `cargo bench --bench fig6_exploration`

use hgnn_char::bench::header;
use hgnn_char::datasets::{self, DatasetId, DatasetScale};
use hgnn_char::metapath::{fit_sparsity_model, sparsity::sparsity_sweep};
use hgnn_char::models::sweeps;
use hgnn_char::report;

fn scale() -> DatasetScale {
    if std::env::var("QUICK_BENCH").is_ok() {
        DatasetScale::ci()
    } else {
        DatasetScale::paper()
    }
}

fn main() {
    header(
        "Fig 6 — exploration",
        "(a) sparsity vs metapath length + correlation model  (b) total time vs #metapaths",
    );

    // ---------------- (a) sparsity sweep ---------------------------------
    println!("--- Fig 6(a): subgraph sparsity vs metapath length ---");
    let mut all_decreasing = true;
    for (seed, dataset) in
        [("MAM", DatasetId::Imdb), ("PAP", DatasetId::Acm), ("APA", DatasetId::Dblp)]
    {
        let hg = datasets::build(dataset, &scale()).unwrap();
        let pts = sparsity_sweep(&hg, seed, 3).unwrap();
        let series: Vec<(f64, f64)> =
            pts.iter().map(|p| (p.length as f64, p.sparsity)).collect();
        println!(
            "{}",
            report::sweep_series(
                &format!("{} (seed {})", dataset.abbrev(), seed),
                "metapath length",
                "sparsity",
                &series
            )
        );
        all_decreasing &= series.windows(2).all(|w| w[1].1 <= w[0].1 + 1e-12);
        if let Some(model) = fit_sparsity_model(&pts) {
            println!(
                "  §5 guideline-3 model: log10(density) = {:.3} + {:.3}·len, r² = {:.3}",
                model.intercept, model.slope, model.r2
            );
            for p in &pts {
                println!(
                    "    len {}: measured sparsity {:.4}, model {:.4}",
                    p.length,
                    p.sparsity,
                    model.predict_sparsity(p.length)
                );
            }
        }
        println!();
    }
    println!(
        "paper claim 'sparsity decreases with metapath length': {}\n",
        if all_decreasing { "REPRODUCED (all 3 datasets)" } else { "NOT reproduced" }
    );

    // ---------------- (b) total time sweep --------------------------------
    println!("--- Fig 6(b): total time vs #metapaths (HAN, DBLP) ---");
    let sweep_scale = if std::env::var("QUICK_BENCH").is_ok() {
        DatasetScale::ci()
    } else {
        DatasetScale::factor(0.5) // full model on 6 metapaths: keep tractable
    };
    let pts = sweeps::fig6b_total_time_sweep(&sweep_scale).unwrap();
    println!(
        "{}",
        report::sweep_series("HAN-DB", "#metapaths", "total time (modeled ms)", &pts)
    );
    let rising = pts.windows(2).all(|w| w[1].1 >= w[0].1 * 0.999);
    println!(
        "paper claim 'total time increases with #metapaths': {}",
        if rising { "REPRODUCED" } else { "NOT reproduced" }
    );
    println!(
        "growth 1 → {} metapaths: {:.1}x",
        pts.len(),
        pts.last().unwrap().1 / pts[0].1.max(1e-9)
    );
}
