//! Bench: kernel substrate microbenchmarks — wallclock throughput of the
//! native kernels (the L3 perf-pass instrument) plus the PJRT-compiled
//! Pallas kernels when artifacts are present.
//!
//! This is the before/after harness for EXPERIMENTS.md §Perf: sgemm
//! blocking variants, packed-vs-unpacked sgemm at the Fig 4 FP roofline
//! sizes (with a >= 1.3x-at-large-size verdict), SpMM over increasing
//! density, SIMD-vs-scalar SpMM at the Fig 4 NA sizes (same verdict
//! scheme, bitwise cross-checked), the intra-kernel thread-scaling
//! sweep (1/2/4/8 pool threads over sgemm + SpMM, with a speedup-at-4
//! verdict and a bit-identity cross-check), the serve-path steady-state
//! allocation check (the scratch arena at work, counted by a wrapping
//! global allocator), and the AOT kernel round-trip cost.
//!
//! Run: `cargo bench --bench kernel_microbench`

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use hgnn_char::bench::{bench, header, BenchConfig};
use hgnn_char::datasets::{DatasetId, DatasetScale};
use hgnn_char::graph::sparse::Coo;
use hgnn_char::kernels::dense::{
    sgemm_compute, sgemm_naive, sgemm_packed_compute, GemmBlocking, PackedB,
};
use hgnn_char::kernels::sparse_ops::{spmm_csr, SpmmReduce};
use hgnn_char::kernels::Ctx;
use hgnn_char::parallel;
use hgnn_char::sampler::SamplingSpec;
use hgnn_char::session::Session;
use hgnn_char::tensor::Tensor;
use hgnn_char::util::Pcg32;

/// Counting wrapper around the system allocator: the instrument behind
/// the serve-path steady-state allocation check.
struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Heap allocations performed while `f` runs (process-wide; run the
/// serving loop single-threaded for a stable count).
fn allocs_during(f: impl FnOnce()) -> u64 {
    let before = ALLOCATIONS.load(Ordering::Relaxed);
    f();
    ALLOCATIONS.load(Ordering::Relaxed) - before
}

fn main() {
    header(
        "kernel microbenchmarks",
        "native kernel wallclock + PJRT AOT kernel round-trip",
    );
    let cfg = BenchConfig::from_env();
    let quick = std::env::var("QUICK_BENCH").is_ok();
    let mut rng = Pcg32::seeded(1234);

    // ---------------- sgemm blocking sweep -------------------------------
    println!("--- sgemm (m=k=1024, n=64): blocking variants ---");
    let (m, k, n) = if quick { (256, 256, 64) } else { (1024, 1024, 64) };
    let a = Tensor::randn(m, k, 1.0, &mut rng);
    let b = Tensor::randn(k, n, 1.0, &mut rng);
    let gflops = |nanos: f64| 2.0 * m as f64 * k as f64 * n as f64 / nanos;
    if quick {
        let r = bench("sgemm naive", &cfg, || sgemm_naive(&a, &b));
        println!("{}   {:.2} GF/s", r.line(), gflops(r.wall.median));
    } else {
        let r = bench("sgemm naive (baseline)", &cfg, || sgemm_naive(&a, &b));
        println!("{}   {:.2} GF/s", r.line(), gflops(r.wall.median));
    }
    for (mc, nc, kc) in [(32, 64, 64), (64, 256, 256), (128, 256, 512), (64, 512, 128)] {
        let blk = GemmBlocking { mc, nc, kc };
        let r = bench(&format!("sgemm blocked {mc}x{nc}x{kc}"), &cfg, || {
            sgemm_compute(&a, &b, blk)
        });
        println!("{}   {:.2} GF/s", r.line(), gflops(r.wall.median));
    }

    // ---------------- packed vs unpacked sgemm (fig4 FP sizes) -------------
    // B-panel packing: the weight matrix is packed once into contiguous
    // (kc x nc) tiles and reused across calls (`PackCache` on `Ctx`), so
    // the inner microkernel streams B sequentially instead of striding.
    // Sizes follow the paper's Fig 4 FP operands (HAN-DBLP: [N x feat]
    // x [feat x hidden], N up to 4057, feat 334, hidden 64).
    println!("\n--- packed vs unpacked sgemm (fig4 FP roofline sizes) ---");
    let blk = GemmBlocking::default();
    let fp_sizes: &[(usize, usize, usize)] = if quick {
        &[(256, 334, 64)]
    } else {
        &[(256, 334, 64), (1024, 334, 64), (4057, 334, 64)]
    };
    let mut pack_ratio_at_large = 0.0f64;
    for &(pm, pk, pn) in fp_sizes {
        let pa = Tensor::randn(pm, pk, 1.0, &mut rng);
        let pb = Tensor::randn(pk, pn, 1.0, &mut rng);
        let r_unpacked = bench(&format!("sgemm unpacked {pm}x{pk}x{pn}"), &cfg, || {
            sgemm_compute(&pa, &pb, blk)
        });
        let packed = PackedB::pack(&pb, blk);
        let r_packed = bench(&format!("sgemm packed   {pm}x{pk}x{pn}"), &cfg, || {
            sgemm_packed_compute(&pa, &packed)
        });
        let gf = |nanos: f64| 2.0 * pm as f64 * pk as f64 * pn as f64 / nanos;
        pack_ratio_at_large = r_unpacked.wall.median / r_packed.wall.median.max(1.0);
        println!(
            "{}   {:.2} GF/s",
            r_unpacked.line(),
            gf(r_unpacked.wall.median)
        );
        println!(
            "{}   {:.2} GF/s   ({pack_ratio_at_large:.2}x vs unpacked)",
            r_packed.line(),
            gf(r_packed.wall.median)
        );
        let bitwise = sgemm_packed_compute(&pa, &packed)
            .allclose(&sgemm_compute(&pa, &pb, blk), 0.0, 0.0);
        assert!(bitwise, "packed sgemm must be bit-identical to unpacked");
    }
    if !quick {
        println!(
            "verdict: {} (target >= 1.3x packed-vs-unpacked at the large FP size)",
            if pack_ratio_at_large >= 1.3 { "PASS" } else { "MISS" }
        );
    }

    // ---------------- SpMM density sweep ----------------------------------
    println!("\n--- SpMMCsr: density sweep (n=4096 nodes, f=64) ---");
    let nodes = if quick { 512 } else { 4096 };
    let f = 64;
    let x = Tensor::randn(nodes, f, 1.0, &mut rng);
    for avg_deg in [2usize, 8, 32, 128] {
        let mut edges = Vec::with_capacity(nodes * avg_deg);
        for d in 0..nodes as u32 {
            for _ in 0..avg_deg {
                edges.push((d, rng.gen_range(nodes) as u32));
            }
        }
        let adj = Coo::from_edges(nodes, nodes, edges).unwrap().to_csr();
        let nnz = adj.nnz();
        let r = bench(&format!("spmm avg_deg={avg_deg} (nnz={nnz})"), &cfg, || {
            let mut ctx = Ctx::default();
            spmm_csr(&mut ctx, &adj, &x, None, SpmmReduce::Sum).unwrap()
        });
        let gbps = (nnz * f * 4) as f64 / r.wall.median;
        println!("{}   gather {gbps:.2} GB/s", r.line());
    }

    // ---------------- SIMD vs scalar SpMM (fig4 NA sizes) ------------------
    // The lane-array accumulators in `spmm_csr` vs a deliberately scalar
    // per-element gather loop — same edge order, bit-identical output;
    // the paper's NA kernels are memory-bound, so the win caps at the
    // gather bandwidth rather than lane count.
    println!("\n--- SIMD vs scalar SpMM (fig4 NA roofline sizes) ---");
    let simd_nodes = if quick { 512 } else { 4096 };
    let mut simd_ratio_at_large = 0.0f64;
    let mut large_label = String::new();
    for &(avg_deg, f) in if quick {
        &[(8usize, 64usize)][..]
    } else {
        &[(8usize, 64usize), (32, 64), (32, 256)][..]
    } {
        let x = Tensor::randn(simd_nodes, f, 1.0, &mut rng);
        let mut edges = Vec::with_capacity(simd_nodes * avg_deg);
        for d in 0..simd_nodes as u32 {
            for _ in 0..avg_deg {
                edges.push((d, rng.gen_range(simd_nodes) as u32));
            }
        }
        let adj = Coo::from_edges(simd_nodes, simd_nodes, edges).unwrap().to_csr();
        let xs = x.as_slice();
        let scalar = || {
            let mut out = vec![0.0f32; simd_nodes * f];
            for d in 0..simd_nodes {
                let (lo, hi) = (adj.indptr[d] as usize, adj.indptr[d + 1] as usize);
                for e in lo..hi {
                    let s = adj.indices[e] as usize * f;
                    for j in 0..f {
                        out[d * f + j] += xs[s + j];
                    }
                }
            }
            out
        };
        let r_scalar = bench(&format!("spmm scalar deg={avg_deg} f={f}"), &cfg, &scalar);
        let r_simd = parallel::with_threads(1, || {
            bench(&format!("spmm simd   deg={avg_deg} f={f}"), &cfg, || {
                let mut ctx = Ctx::default();
                spmm_csr(&mut ctx, &adj, &x, None, SpmmReduce::Sum).unwrap()
            })
        });
        simd_ratio_at_large = r_scalar.wall.median / r_simd.wall.median.max(1.0);
        large_label = format!("deg={avg_deg} f={f}");
        println!("{}", r_scalar.line());
        println!("{}   ({simd_ratio_at_large:.2}x vs scalar)", r_simd.line());
        let mut ctx = Ctx::default();
        let simd_out = spmm_csr(&mut ctx, &adj, &x, None, SpmmReduce::Sum).unwrap();
        assert_eq!(simd_out.as_slice(), &scalar()[..], "SIMD spmm must match scalar bitwise");
    }
    if !quick {
        println!(
            "verdict: {} (target >= 1.3x SIMD-vs-scalar at the large NA size, {large_label})",
            if simd_ratio_at_large >= 1.3 { "PASS" } else { "MISS" }
        );
    }

    // ---------------- intra-kernel thread scaling --------------------------
    // The worker pool's row-blocked kernels: 1/2/4/8 pool threads over
    // the compute-bound sgemm and the memory-bound SpMM (paper §4: FP
    // and NA saturate different resources; both carry intra-kernel data
    // parallelism). Outputs are bit-identical at every width.
    println!("\n--- intra-kernel thread scaling (shared worker pool) ---");
    let (sm, sk, sn) = if quick { (256, 256, 64) } else { (1024, 1024, 128) };
    let sa = Tensor::randn(sm, sk, 1.0, &mut rng);
    let sb = Tensor::randn(sk, sn, 1.0, &mut rng);
    let blk = GemmBlocking::default();
    let snodes = if quick { 512 } else { 8192 };
    let sf = if quick { 64 } else { 128 };
    let sdeg = 32usize;
    let sx = Tensor::randn(snodes, sf, 1.0, &mut rng);
    let mut sedges = Vec::with_capacity(snodes * sdeg);
    for d in 0..snodes as u32 {
        for _ in 0..sdeg {
            sedges.push((d, rng.gen_range(snodes) as u32));
        }
    }
    let sadj = Coo::from_edges(snodes, snodes, sedges).unwrap().to_csr();
    let reference_mm = parallel::with_threads(1, || sgemm_compute(&sa, &sb, blk));
    let reference_sp = parallel::with_threads(1, || {
        let mut ctx = Ctx::default();
        spmm_csr(&mut ctx, &sadj, &sx, None, SpmmReduce::Sum).unwrap()
    });
    let mut mm_ns = Vec::new();
    let mut sp_ns = Vec::new();
    for t in [1usize, 2, 4, 8] {
        parallel::with_threads(t, || {
            let r = bench(&format!("sgemm {sm}x{sk}x{sn} threads={t}"), &cfg, || {
                sgemm_compute(&sa, &sb, blk)
            });
            let gfs = 2.0 * sm as f64 * sk as f64 * sn as f64 / r.wall.median;
            println!("{}   {gfs:.2} GF/s", r.line());
            mm_ns.push(r.wall.median);
            let out = sgemm_compute(&sa, &sb, blk);
            assert!(
                out.allclose(&reference_mm, 0.0, 0.0),
                "sgemm at {t} threads must be bit-identical to serial"
            );
            let r = bench(
                &format!("spmm n={snodes} deg={sdeg} f={sf} threads={t}"),
                &cfg,
                || {
                    let mut ctx = Ctx::default();
                    spmm_csr(&mut ctx, &sadj, &sx, None, SpmmReduce::Sum).unwrap()
                },
            );
            let gbps = (sadj.nnz() * sf * 4) as f64 / r.wall.median;
            println!("{}   gather {gbps:.2} GB/s", r.line());
            sp_ns.push(r.wall.median);
            let mut ctx = Ctx::default();
            let out = spmm_csr(&mut ctx, &sadj, &sx, None, SpmmReduce::Sum).unwrap();
            assert!(
                out.allclose(&reference_sp, 0.0, 0.0),
                "spmm at {t} threads must be bit-identical to serial"
            );
        });
    }
    let mm_speedup = mm_ns[0] / mm_ns[2].max(1.0);
    let sp_speedup = sp_ns[0] / sp_ns[2].max(1.0);
    println!(
        "speedup at 4 threads vs 1: sgemm {mm_speedup:.2}x, spmm {sp_speedup:.2}x \
         (outputs bit-identical at every width)"
    );
    if !quick {
        println!(
            "verdict: {} (target >= 1.5x at 4 threads for both kernels)",
            if mm_speedup >= 1.5 && sp_speedup >= 1.5 { "PASS" } else { "MISS" }
        );
    }

    // ---------------- serve-path steady-state allocations ------------------
    // The scratch arena recycles the stage outputs of every served
    // batch, so steady-state dispatches stop allocating the dominant
    // tensors: warm dispatch allocation counts must sit well below the
    // cold first dispatch, and arena hits must accumulate.
    println!("\n--- serve-path steady-state allocations (scratch arena) ---");
    let mut serve_session = Session::builder()
        .dataset(DatasetId::Imdb)
        .scale(DatasetScale::ci())
        .sampling(SamplingSpec::uniform(8, 1))
        .threads(1)
        .build()
        .unwrap();
    let batch_ids: Vec<u32> = (0..32).collect();
    let cold = allocs_during(|| {
        serve_session.run_batch(&batch_ids).unwrap();
    });
    for _ in 0..3 {
        serve_session.run_batch(&batch_ids).unwrap();
    }
    let warm = allocs_during(|| {
        serve_session.run_batch(&batch_ids).unwrap();
    });
    let stats = serve_session.arena_stats();
    println!(
        "dispatch allocations: cold {cold}, warm {warm} ({:.0}% removed)",
        100.0 * (1.0 - warm as f64 / cold.max(1) as f64)
    );
    println!(
        "arena: {} hits, {} misses, {} buffers held",
        stats.hits, stats.misses, stats.held
    );
    assert!(stats.hits > 0, "steady-state dispatches must draw from the arena");
    println!(
        "verdict: {} (warm dispatch must allocate less than cold)",
        if warm < cold { "PASS" } else { "MISS" }
    );

    // ---------------- Session repeat-run reuse -----------------------------
    // The seed rebuilt graph + plan + engine at every call site
    // (`Engine::new(Backend::native_no_traces())` ~30 times across the
    // tree); a Session builds once and reuses plan, weights, and the
    // kernel-context scratch across runs. Three rungs of reuse:
    //   cold      — rebuild everything per iteration (seed behavior)
    //   warm      — one session, full forward per iteration
    //   batch     — one session, cached embeddings served per iteration
    println!("\n--- Session repeat-run reuse (HAN/IMDB, ci scale) ---");
    let scfg = BenchConfig { iters: cfg.iters.min(5), ..cfg.clone() };
    let r_cold = bench("cold: rebuild session per run", &scfg, || {
        Session::builder()
            .dataset(DatasetId::Imdb)
            .scale(DatasetScale::ci())
            .build()
            .unwrap()
            .run()
            .unwrap()
    });
    println!("{}", r_cold.line());
    let mut session = Session::builder()
        .dataset(DatasetId::Imdb)
        .scale(DatasetScale::ci())
        .build()
        .unwrap();
    let r_warm = bench("warm: reused session, full run", &scfg, || session.run().unwrap());
    println!("{}", r_warm.line());
    let ids: Vec<u32> = (0..64).collect();
    let r_batch = bench("batch: cached embeddings, 64 ids", &scfg, || {
        session.run_batch(&ids).unwrap()
    });
    println!("{}", r_batch.line());
    println!(
        "repeat-run speedup: warm {:.2}x, batch {:.0}x vs cold rebuild",
        r_cold.wall.median / r_warm.wall.median.max(1.0),
        r_cold.wall.median / r_batch.wall.median.max(1.0),
    );

    // ---------------- PJRT AOT kernels -------------------------------------
    println!("\n--- PJRT AOT Pallas kernels (requires `make artifacts`) ---");
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !root.join("manifest.json").exists() {
        println!("  (skipped: artifacts not built)");
        return;
    }
    let rt = hgnn_char::runtime::PjrtRuntime::new(root).unwrap();
    let art = rt.compile_by_name("kernel_dense_matmul").unwrap();
    let a = Tensor::randn(128, 256, 1.0, &mut rng);
    let b = Tensor::randn(256, 64, 1.0, &mut rng);
    let r = bench("pjrt dense_matmul 128x256x64", &cfg, || art.execute(&[&a, &b]).unwrap());
    println!("{}", r.line());
    let art = rt.compile_by_name("kernel_ell_spmm").unwrap();
    let gathered = Tensor::randn(256 * 16, 64, 1.0, &mut rng);
    let weights = Tensor::randn(256, 16, 1.0, &mut rng);
    let mask = Tensor::full(256, 16, 1.0);
    let r = bench("pjrt ell_spmm 256x16x64", &cfg, || {
        art.execute(&[&gathered, &weights, &mask]).unwrap()
    });
    println!("{}", r.line());
}
