//! Bench: kernel substrate microbenchmarks — wallclock throughput of the
//! native kernels (the L3 perf-pass instrument) plus the PJRT-compiled
//! Pallas kernels when artifacts are present.
//!
//! This is the before/after harness for EXPERIMENTS.md §Perf: sgemm
//! blocking variants, SpMM over increasing density, and the AOT kernel
//! round-trip cost.
//!
//! Run: `cargo bench --bench kernel_microbench`

use hgnn_char::bench::{bench, header, BenchConfig};
use hgnn_char::datasets::{DatasetId, DatasetScale};
use hgnn_char::graph::sparse::Coo;
use hgnn_char::kernels::dense::{sgemm_compute, sgemm_naive, GemmBlocking};
use hgnn_char::kernels::sparse_ops::{spmm_csr, SpmmReduce};
use hgnn_char::kernels::Ctx;
use hgnn_char::session::Session;
use hgnn_char::tensor::Tensor;
use hgnn_char::util::Pcg32;

fn main() {
    header(
        "kernel microbenchmarks",
        "native kernel wallclock + PJRT AOT kernel round-trip",
    );
    let cfg = BenchConfig::from_env();
    let quick = std::env::var("QUICK_BENCH").is_ok();
    let mut rng = Pcg32::seeded(1234);

    // ---------------- sgemm blocking sweep -------------------------------
    println!("--- sgemm (m=k=1024, n=64): blocking variants ---");
    let (m, k, n) = if quick { (256, 256, 64) } else { (1024, 1024, 64) };
    let a = Tensor::randn(m, k, 1.0, &mut rng);
    let b = Tensor::randn(k, n, 1.0, &mut rng);
    let gflops = |nanos: f64| 2.0 * m as f64 * k as f64 * n as f64 / nanos;
    if quick {
        let r = bench("sgemm naive", &cfg, || sgemm_naive(&a, &b));
        println!("{}   {:.2} GF/s", r.line(), gflops(r.wall.median));
    } else {
        let r = bench("sgemm naive (baseline)", &cfg, || sgemm_naive(&a, &b));
        println!("{}   {:.2} GF/s", r.line(), gflops(r.wall.median));
    }
    for (mc, nc, kc) in [(32, 64, 64), (64, 256, 256), (128, 256, 512), (64, 512, 128)] {
        let blk = GemmBlocking { mc, nc, kc };
        let r = bench(&format!("sgemm blocked {mc}x{nc}x{kc}"), &cfg, || {
            sgemm_compute(&a, &b, blk)
        });
        println!("{}   {:.2} GF/s", r.line(), gflops(r.wall.median));
    }

    // ---------------- SpMM density sweep ----------------------------------
    println!("\n--- SpMMCsr: density sweep (n=4096 nodes, f=64) ---");
    let nodes = if quick { 512 } else { 4096 };
    let f = 64;
    let x = Tensor::randn(nodes, f, 1.0, &mut rng);
    for avg_deg in [2usize, 8, 32, 128] {
        let mut edges = Vec::with_capacity(nodes * avg_deg);
        for d in 0..nodes as u32 {
            for _ in 0..avg_deg {
                edges.push((d, rng.gen_range(nodes) as u32));
            }
        }
        let adj = Coo::from_edges(nodes, nodes, edges).unwrap().to_csr();
        let nnz = adj.nnz();
        let r = bench(&format!("spmm avg_deg={avg_deg} (nnz={nnz})"), &cfg, || {
            let mut ctx = Ctx::default();
            spmm_csr(&mut ctx, &adj, &x, None, SpmmReduce::Sum).unwrap()
        });
        let gbps = (nnz * f * 4) as f64 / r.wall.median;
        println!("{}   gather {gbps:.2} GB/s", r.line());
    }

    // ---------------- Session repeat-run reuse -----------------------------
    // The seed rebuilt graph + plan + engine at every call site
    // (`Engine::new(Backend::native_no_traces())` ~30 times across the
    // tree); a Session builds once and reuses plan, weights, and the
    // kernel-context scratch across runs. Three rungs of reuse:
    //   cold      — rebuild everything per iteration (seed behavior)
    //   warm      — one session, full forward per iteration
    //   batch     — one session, cached embeddings served per iteration
    println!("\n--- Session repeat-run reuse (HAN/IMDB, ci scale) ---");
    let scfg = BenchConfig { iters: cfg.iters.min(5), ..cfg.clone() };
    let r_cold = bench("cold: rebuild session per run", &scfg, || {
        Session::builder()
            .dataset(DatasetId::Imdb)
            .scale(DatasetScale::ci())
            .build()
            .unwrap()
            .run()
            .unwrap()
    });
    println!("{}", r_cold.line());
    let mut session = Session::builder()
        .dataset(DatasetId::Imdb)
        .scale(DatasetScale::ci())
        .build()
        .unwrap();
    let r_warm = bench("warm: reused session, full run", &scfg, || session.run().unwrap());
    println!("{}", r_warm.line());
    let ids: Vec<u32> = (0..64).collect();
    let r_batch = bench("batch: cached embeddings, 64 ids", &scfg, || {
        session.run_batch(&ids).unwrap()
    });
    println!("{}", r_batch.line());
    println!(
        "repeat-run speedup: warm {:.2}x, batch {:.0}x vs cold rebuild",
        r_cold.wall.median / r_warm.wall.median.max(1.0),
        r_cold.wall.median / r_batch.wall.median.max(1.0),
    );

    // ---------------- PJRT AOT kernels -------------------------------------
    println!("\n--- PJRT AOT Pallas kernels (requires `make artifacts`) ---");
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !root.join("manifest.json").exists() {
        println!("  (skipped: artifacts not built)");
        return;
    }
    let rt = hgnn_char::runtime::PjrtRuntime::new(root).unwrap();
    let art = rt.compile_by_name("kernel_dense_matmul").unwrap();
    let a = Tensor::randn(128, 256, 1.0, &mut rng);
    let b = Tensor::randn(256, 64, 1.0, &mut rng);
    let r = bench("pjrt dense_matmul 128x256x64", &cfg, || art.execute(&[&a, &b]).unwrap());
    println!("{}", r.line());
    let art = rt.compile_by_name("kernel_ell_spmm").unwrap();
    let gathered = Tensor::randn(256 * 16, 64, 1.0, &mut rng);
    let weights = Tensor::randn(256, 16, 1.0, &mut rng);
    let mask = Tensor::full(256, 16, 1.0);
    let r = bench("pjrt ell_spmm 256x16x64", &cfg, || {
        art.execute(&[&gathered, &weights, &mask]).unwrap()
    });
    println!("{}", r.line());
}
