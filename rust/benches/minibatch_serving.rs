//! Bench: **mini-batch sampled serving vs full-graph batch execution**.
//!
//! The serving question the sampler answers: when embeddings must be
//! fresh per dispatch (feature-store refresh, online updates), what does
//! one batch cost? The full-graph path pays a whole forward regardless
//! of batch size; the sampled path executes FP/NA/SA over the batch's
//! metapath neighborhood only, so cost tracks the batch. Expected
//! qualitative trend: sampled wins by a wide margin at small batches
//! (<= 64) and the gap narrows as the batch approaches graph scale.
//!
//! Also reports the end-to-end serving loop (`Server::start_session`)
//! with one sampled subgraph per dispatch.
//!
//! Run: `cargo bench --bench minibatch_serving`

use hgnn_char::bench::{bench, header, BenchConfig};
use hgnn_char::datasets::{DatasetId, DatasetScale};
use hgnn_char::models::ModelId;
use hgnn_char::session::{SamplingSpec, ServeConfig, Session};

fn scale() -> DatasetScale {
    if std::env::var("QUICK_BENCH").is_ok() {
        DatasetScale::ci()
    } else {
        DatasetScale::factor(0.25)
    }
}

const FANOUT: usize = 16;

fn main() {
    header(
        "mini-batch sampled serving vs full-graph batch execution",
        "fresh embeddings per dispatch: full forward vs sampled subgraph (HAN, IMDB synth)",
    );
    let cfg = BenchConfig::from_env();

    let mut full = Session::builder()
        .dataset(DatasetId::Imdb)
        .scale(scale())
        .model(ModelId::Han)
        .build()
        .unwrap();
    let mut sampled = Session::builder()
        .dataset(DatasetId::Imdb)
        .scale(scale())
        .model(ModelId::Han)
        .sampling(SamplingSpec::uniform(FANOUT, 1))
        .build()
        .unwrap();
    let n = full.graph().node_type(full.plan().target).count as u32;
    println!("{}  (target nodes: {n}, fanout {FANOUT})\n", full.graph().stats_line());

    for &bs in &[1usize, 8, 16, 64, 256] {
        let ids: Vec<u32> = (0..bs as u32).map(|i| i % n).collect();
        let s = sampled.sample_batch(&ids).unwrap();
        println!("batch {bs:>4}  ({})", s.stats_line());
        let rf = bench(&format!("full-graph forward, batch={bs}"), &cfg, || {
            full.invalidate(); // embeddings must be fresh per dispatch
            full.run_batch(&ids).unwrap()
        });
        let rs = bench(&format!("sampled subgraph,   batch={bs}"), &cfg, || {
            sampled.run_batch(&ids).unwrap()
        });
        println!("  {}", rf.line());
        println!("  {}", rs.line());
        println!(
            "  -> sampled speedup {:.2}x{}\n",
            rf.wall.mean / rs.wall.mean.max(1.0),
            if rf.wall.mean > rs.wall.mean { "  (sampled wins)" } else { "" }
        );
    }

    // end-to-end serving loop: typed batches, one sampled subgraph per
    // dispatch inside the dispatcher thread
    let server = Session::builder()
        .dataset(DatasetId::Imdb)
        .scale(scale())
        .model(ModelId::Han)
        .sampling(SamplingSpec::uniform(FANOUT, 1))
        .serve(ServeConfig::default());
    let t0 = std::time::Instant::now();
    let receivers: Vec<_> = (0..256u32)
        .collect::<Vec<_>>()
        .chunks(16)
        .map(|c| server.submit_batch(c).unwrap())
        .collect();
    for rx in receivers {
        let _ = rx.recv();
    }
    let wall = t0.elapsed();
    let stats = server.shutdown();
    println!(
        "serving loop: {} requests in {} dispatches (mean batch {:.1}) in {:.1} ms -> {:.0} req/s",
        stats.completed,
        stats.batches,
        stats.mean_batch,
        wall.as_secs_f64() * 1e3,
        stats.throughput_rps,
    );
}
