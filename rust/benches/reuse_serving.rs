//! Bench: **cross-request reuse — served-batch latency vs request
//! overlap × cache capacity**.
//!
//! The serving question the reuse caches answer: when request streams
//! overlap (Zipfian seed popularity — the "millions of users" regime),
//! how much of each sampled batch's stage-②/③ work is redundant, and
//! how much capacity does it take to stop paying it? Each sweep cell
//! runs the same deterministic batch sequence through a fresh session;
//! only the cache capacity changes, so latency differences are the
//! caches' doing. Expected qualitative trend: at fixed overlap,
//! served-batch latency **monotonically improves with capacity** (more
//! resident rows → higher hit rate → fewer sgemm/SpMM invocations),
//! dropping toward pure gather cost as the hit rate saturates; sharper
//! overlap (larger Zipf exponent) reaches the floor at smaller
//! capacity. Capacity 0 is the no-cache baseline.
//!
//! Also reports the end-to-end serving loop (`Server::start_session`)
//! with one shared cache across every dispatch.
//!
//! Run: `cargo bench --bench reuse_serving`

use std::time::Instant;

use hgnn_char::bench::{header, sink};
use hgnn_char::datasets::{DatasetId, DatasetScale};
use hgnn_char::kernels::quant::QuantSpec;
use hgnn_char::models::ModelId;
use hgnn_char::reuse::ReuseSpec;
use hgnn_char::session::{SamplingSpec, ServeConfig, Session, SessionBuilder};
use hgnn_char::util::Pcg32;

fn scale() -> DatasetScale {
    if std::env::var("QUICK_BENCH").is_ok() {
        DatasetScale::ci()
    } else {
        DatasetScale::factor(0.25)
    }
}

fn builder() -> SessionBuilder {
    Session::builder()
        .dataset(DatasetId::Imdb)
        .scale(scale())
        .model(ModelId::Han)
        // full fanout: every row is coverage-exact, so both caches apply
        .sampling(SamplingSpec::uniform(usize::MAX, 1))
}

/// Zipfian id sampler: node id r drawn with weight 1/(r+1)^s.
struct Zipf {
    cdf: Vec<f64>,
    rng: Pcg32,
}

impl Zipf {
    fn new(n: usize, s: f64, seed: u64) -> Zipf {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for r in 0..n {
            acc += 1.0 / ((r + 1) as f64).powf(s);
            cdf.push(acc);
        }
        for v in cdf.iter_mut() {
            *v /= acc;
        }
        Zipf { cdf, rng: Pcg32::new(seed, 0) }
    }

    fn next(&mut self) -> u32 {
        let u = self.rng.gen_f64();
        let i = match self.cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) | Err(i) => i,
        };
        i.min(self.cdf.len() - 1) as u32
    }
}

const BATCH: usize = 32;

fn main() {
    header(
        "cross-request reuse: served-batch latency vs overlap x capacity",
        "Zipfian request streams over sampled HAN batches (IMDB synth); times are wall",
    );
    let quick = std::env::var("QUICK_BENCH").is_ok();
    let batches = if quick { 30 } else { 120 };

    let probe = builder().build().unwrap();
    let n = probe.graph().node_type(probe.plan().target).count;
    let total: usize = probe.graph().node_types().iter().map(|t| t.count).sum();
    println!(
        "{}  (target nodes: {n}, total nodes: {total}, batch {BATCH}, {batches} timed batches)\n",
        probe.graph().stats_line()
    );
    drop(probe);

    let caps = [0usize, (total / 8).max(1), (total / 2).max(1), 2 * total];
    for &(s, label) in &[(0.0f64, "uniform"), (0.8, "zipf-0.8"), (1.4, "zipf-1.4")] {
        println!("-- request overlap: {label} (Zipf exponent {s}) --");
        let mut base_mean: Option<f64> = None;
        let mut prev = f64::INFINITY;
        let mut monotone = true;
        for &cap in &caps {
            let mut b = builder();
            if cap > 0 {
                b = b.reuse(ReuseSpec::rows(cap));
            }
            let mut session = b.build().unwrap();
            // identical deterministic batch sequence in every cell
            let mut zipf = Zipf::new(n, s, 0xC0FFEE);
            // warm-up: let the caches reach steady state before timing
            for _ in 0..3 {
                let ids: Vec<u32> = (0..BATCH).map(|_| zipf.next()).collect();
                sink(session.run_batch(&ids).unwrap());
            }
            let t0 = Instant::now();
            for _ in 0..batches {
                let ids: Vec<u32> = (0..BATCH).map(|_| zipf.next()).collect();
                sink(session.run_batch(&ids).unwrap());
            }
            let mean_ms = t0.elapsed().as_secs_f64() * 1e3 / batches as f64;
            let hit = match session.reuse_stats() {
                Some(r) => format!(
                    "proj hit {:>5.1}%, agg hit {:>5.1}%",
                    100.0 * r.proj_hit_rate(),
                    100.0 * r.agg_hit_rate()
                ),
                None => "no cache".to_string(),
            };
            let speedup = base_mean.map(|b| b / mean_ms.max(1e-9)).unwrap_or(1.0);
            if base_mean.is_none() {
                base_mean = Some(mean_ms);
            }
            println!(
                "  cap {cap:>6} rows  {mean_ms:>9.3} ms/batch  [{hit}]  {speedup:.2}x vs no-cache"
            );
            // allow 10% wall noise before declaring non-monotonicity
            if mean_ms > prev * 1.10 {
                monotone = false;
            }
            prev = mean_ms;
        }
        println!(
            "  -> latency non-increasing with capacity: {}\n",
            if monotone { "yes" } else { "NO (wall noise or regression)" }
        );
    }

    // quantized reuse serving: same Zipf stream with cache rows stored as
    // f16/int8 (and fake-quantized FP weights); reports latency alongside
    // the logit error the smaller formats buy it with
    println!("-- quantized reuse serving (cap {} rows, zipf-1.2) --", 2 * total);
    let qbatches = if quick { 10 } else { 40 };
    let formats: [(Option<QuantSpec>, &str); 3] =
        [(None, "f32"), (Some(QuantSpec::F16), "f16"), (Some(QuantSpec::Int8), "int8")];
    let mut f32_out: Vec<Vec<f32>> = Vec::new();
    let mut f32_ms = 0.0f64;
    for &(spec, name) in &formats {
        let mut b = builder().reuse(ReuseSpec::rows(2 * total));
        if let Some(spec) = spec {
            b = b.quantize(spec);
        }
        let mut session = b.build().unwrap();
        // identical deterministic batch sequence in every cell
        let mut zipf = Zipf::new(n, 1.2, 0xBEEF);
        for _ in 0..3 {
            let ids: Vec<u32> = (0..BATCH).map(|_| zipf.next()).collect();
            sink(session.run_batch(&ids).unwrap());
        }
        let mut outs: Vec<Vec<f32>> = Vec::new();
        let t0 = Instant::now();
        for _ in 0..qbatches {
            let ids: Vec<u32> = (0..BATCH).map(|_| zipf.next()).collect();
            outs.extend(session.run_batch(&ids).unwrap());
        }
        let mean_ms = t0.elapsed().as_secs_f64() * 1e3 / qbatches as f64;
        if spec.is_none() {
            println!("  {name:>4}  {mean_ms:>9.3} ms/batch  (f32 reference)");
            f32_out = outs;
            f32_ms = mean_ms;
        } else {
            let mut max_err = 0.0f64;
            let mut sum_err = 0.0f64;
            let mut count = 0u64;
            for (a, b) in f32_out.iter().zip(&outs) {
                for (&x, &y) in a.iter().zip(b) {
                    let e = (f64::from(x) - f64::from(y)).abs();
                    max_err = max_err.max(e);
                    sum_err += e;
                    count += 1;
                }
            }
            let mean_err = sum_err / count.max(1) as f64;
            println!(
                "  {name:>4}  {mean_ms:>9.3} ms/batch  ({:.2}x vs f32)  \
                 max abs logit err {max_err:.3e}, mean {mean_err:.3e}",
                f32_ms / mean_ms.max(1e-9)
            );
        }
    }
    println!();

    // end-to-end serving loop: one shared cache across every dispatch
    let server = builder()
        .reuse(ReuseSpec::rows(2 * total))
        .serve(ServeConfig::default());
    let mut zipf = Zipf::new(n, 1.2, 0xFEED);
    let t0 = Instant::now();
    let receivers: Vec<_> = (0..16)
        .map(|_| {
            let ids: Vec<u32> = (0..BATCH).map(|_| zipf.next()).collect();
            server.submit_batch(&ids).unwrap()
        })
        .collect();
    for rx in receivers {
        let _ = rx.recv();
    }
    let wall = t0.elapsed();
    let stats = server.shutdown();
    println!(
        "serving loop: {} rows in {} dispatches in {:.1} ms ({:.0} rows/s)",
        stats.completed,
        stats.batches,
        wall.as_secs_f64() * 1e3,
        stats.throughput_rps,
    );
    if let Some(r) = &stats.reuse {
        println!("{}", r.line());
    }
}
