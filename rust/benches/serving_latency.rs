//! Bench: **async serving — open-loop tail latency vs offered load ×
//! admission policy**.
//!
//! The serving question the async runtime answers: as offered load
//! sweeps through and past capacity, what happens to the p99
//! queue-to-reply latency — and what does admission control buy? Each
//! cell offers an *open-loop* single-id request stream (submissions are
//! paced by a target rate, never by completions — the regime where
//! queues actually grow) against a synthetic executor with a fixed
//! per-id cost, so capacity is known exactly and differences between
//! cells are the runtime's doing. Expected qualitative trends:
//!
//! * p99 **degrades monotonically with offered load** under either
//!   policy (more queueing → longer tails);
//! * without admission the overloaded cell (2× capacity) queues
//!   unboundedly and p99 grows with the experiment length, while
//!   **with admission** (token bucket at capacity + bounded queue) the
//!   excess is shed as typed rejects and p99 stays near the
//!   bounded-queue drain time.
//!
//! Also reports the session-backed path (`serve_async` over sampled
//! HAN batches) with two priority classes.
//!
//! Run: `cargo bench --bench serving_latency`

use std::time::{Duration, Instant};

use hgnn_char::bench::{header, sink};
use hgnn_char::datasets::{DatasetId, DatasetScale};
use hgnn_char::models::ModelId;
use hgnn_char::sampler::SamplingSpec;
use hgnn_char::serving::{AsyncServer, ServingConfig, SubmitOpts};
use hgnn_char::session::Session;
use hgnn_char::util::human_time;
use hgnn_char::Result;

/// Synthetic per-id execution cost: capacity is exactly 1e6/30 ids/s.
const COST_PER_ID_US: u64 = 30;

fn delay_exec(ids: &[u32]) -> Result<Vec<Vec<f32>>> {
    std::thread::sleep(Duration::from_micros(COST_PER_ID_US * ids.len() as u64));
    Ok(ids.iter().map(|&i| vec![i as f32]).collect())
}

/// One open-loop cell: pace `requests` single-id submissions at
/// `offered` ids/s, then drain. Returns (p50_ns, p99_ns, reject rate).
fn open_loop_cell(config: ServingConfig, offered: f64, requests: usize) -> (u64, u64, f64) {
    let server = AsyncServer::start(config, delay_exec);
    let interval = Duration::from_secs_f64(1.0 / offered);
    let mut receivers = Vec::with_capacity(requests);
    let mut rejected = 0usize;
    let t0 = Instant::now();
    for i in 0..requests {
        // open loop: the next submission is due at i*interval whether or
        // not anything has completed — rate pressure, not lockstep
        let due = interval * i as u32;
        let now = t0.elapsed();
        if due > now {
            std::thread::sleep(due - now);
        }
        match server.submit(&[i as u32], SubmitOpts::default()) {
            Ok(rx) => receivers.push(rx),
            Err(_) => rejected += 1,
        }
    }
    for rx in receivers {
        sink(rx.recv().ok());
    }
    let stats = server.shutdown();
    let c = &stats.classes[0];
    (c.p50_ns, c.p99_ns, rejected as f64 / requests as f64)
}

fn main() {
    header(
        "async serving: open-loop p99 vs offered load x admission policy",
        "paced single-id streams against a fixed-cost executor; times are wall",
    );
    let quick = std::env::var("QUICK_BENCH").is_ok();
    let requests = if quick { 250 } else { 1500 };
    let capacity = 1e6 / COST_PER_ID_US as f64;
    println!(
        "executor: {COST_PER_ID_US} µs/id  =>  capacity {capacity:.0} ids/s  \
         ({requests} requests per cell)\n"
    );

    let fractions = [0.2f64, 0.5, 1.0, 2.0];
    let mut overload_p99 = [0u64; 2]; // [no admission, admission] at 2x
    for (p, (policy, admission)) in
        [("no admission (unbounded queue)", false), ("admission (bucket at capacity, queue 64)", true)]
            .into_iter()
            .enumerate()
    {
        println!("-- policy: {policy} --");
        let mut prev_p99 = 0u64;
        let mut monotone = true;
        for &frac in &fractions {
            let mut config = ServingConfig {
                max_batch: 16,
                flush_after: Duration::from_millis(1),
                priority_lanes: 1,
                queue_cap: usize::MAX / 2,
                ..Default::default()
            };
            if admission {
                config.queue_cap = 64;
                config.admission_qps = Some(capacity);
                config.admission_burst = Some(64.0);
            }
            let (p50, p99, reject) = open_loop_cell(config, capacity * frac, requests);
            println!(
                "  offered {frac:>3.1}x capacity   p50 {:>10}   p99 {:>10}   reject {:>5.1}%",
                human_time(p50 as f64),
                human_time(p99 as f64),
                100.0 * reject
            );
            // allow 30% wall noise before declaring non-monotonicity
            if (p99 as f64) < prev_p99 as f64 * 0.70 {
                monotone = false;
            }
            prev_p99 = prev_p99.max(p99);
            if frac == 2.0 {
                overload_p99[p] = p99;
            }
        }
        println!(
            "  -> p99 non-decreasing with offered load: {}\n",
            if monotone { "yes" } else { "NO (wall noise or regression)" }
        );
    }
    println!(
        "overload (2x) p99: no-admission {} vs admission {}  ->  admission bounds the tail: {}\n",
        human_time(overload_p99[0] as f64),
        human_time(overload_p99[1] as f64),
        if overload_p99[1] < overload_p99[0] { "yes" } else { "NO (wall noise or regression)" }
    );

    // ---- session-backed path: sampled HAN batches, two classes -------
    let batches: usize = if quick { 12 } else { 48 };
    let server = Session::builder()
        .dataset(DatasetId::Imdb)
        .scale(DatasetScale::ci())
        .model(ModelId::Han)
        .sampling(SamplingSpec::uniform(usize::MAX, 1))
        .serve_async(ServingConfig {
            max_batch: 16,
            flush_after: Duration::from_millis(1),
            priority_lanes: 2,
            ..Default::default()
        });
    let t0 = Instant::now();
    let receivers: Vec<_> = (0..batches)
        .filter_map(|i| {
            let ids: Vec<u32> = (0..8u32).map(|k| (i * 8 + k as usize) as u32 % 97).collect();
            server.submit(&ids, SubmitOpts::class(i % 2)).ok()
        })
        .collect();
    for rx in receivers {
        sink(rx.recv().ok());
    }
    let wall = t0.elapsed();
    let stats = server.shutdown();
    println!(
        "session-backed (sampled HAN, IMDB ci): {} ids in {} dispatches in {:.1} ms",
        stats.completed,
        stats.batches,
        wall.as_secs_f64() * 1e3
    );
    for c in stats.classes.iter().filter(|c| c.requests > 0) {
        println!(
            "  class {}: {} reqs  p50 {:>10}  p95 {:>10}  p99 {:>10}",
            c.class,
            c.requests,
            human_time(c.p50_ns as f64),
            human_time(c.p95_ns as f64),
            human_time(c.p99_ns as f64)
        );
    }
}
