//! Bench: **sharded execution — strong scaling of the full forward**.
//!
//! The scaling question the partition subsystem answers: with the graph
//! fixed, how does end-to-end inference latency fall as the
//! degree-balanced shard count grows? Each sweep cell builds a session
//! over the same synthesized graph with `.partition(PartitionSpec)` at
//! shards ∈ {1, 2, 4, 8} (threads = shards) and times `Session::run`
//! end-to-end — stage-② FP and stage-③ NA execute per shard on real
//! threads, with the halo exchange and owner-computes merges (and the
//! serial stage-④ SA) on the critical path. The 1-shard cell is the
//! baseline: the same sharded code path, so the sweep isolates
//! *parallelism*, not dispatch overhead differences.
//!
//! Expected qualitative trend: near-linear speedup while shards ≤
//! physical cores and the NA stage dominates (the paper's ~74% NA /
//! ~19% FP split caps the Amdahl ceiling around `1/(0.07 + 0.93/K)`),
//! flattening once threads oversubscribe cores or the serial SA + merge
//! tail dominates. The acceptance bar for this repo: **≥ 1.5× at 4
//! shards over the 1-shard baseline** on a ≥ 2-core box.
//!
//! Every cell also cross-checks bit-identity against the unsharded
//! forward (a cheap frob-norm fingerprint; the integration suite pins
//! exact bytes), so a speedup can never come from computing less.
//!
//! Run: `cargo bench --bench shard_scaling`

use hgnn_char::bench::{bench, header, BenchConfig};
use hgnn_char::datasets::{DatasetId, DatasetScale};
use hgnn_char::models::ModelId;
use hgnn_char::partition::PartitionSpec;
use hgnn_char::session::{Session, SessionBuilder};

fn scale() -> DatasetScale {
    if std::env::var("QUICK_BENCH").is_ok() {
        DatasetScale::ci()
    } else {
        DatasetScale::factor(0.5)
    }
}

fn builder() -> SessionBuilder {
    Session::builder()
        .dataset(DatasetId::Dblp)
        .scale(scale())
        .model(ModelId::Han)
}

fn main() {
    header(
        "shard_scaling",
        "strong scaling of the sharded forward (HAN on synthesized DBLP): \
         shards ∈ {1,2,4,8}, threads = shards, degree-balanced LPT partition",
    );
    let config = BenchConfig::from_env();

    // unsharded reference output fingerprint (bit-identity smoke check)
    let mut reference = builder().build().expect("unsharded session");
    let ref_norm = reference.run().expect("unsharded run").output.frob_norm();

    let mut baseline_ns = 0.0f64;
    let mut at4 = None;
    for shards in [1usize, 2, 4, 8] {
        let mut session = builder()
            .partition(PartitionSpec::new(shards))
            .build()
            .expect("sharded session");
        let info = session.partition().expect("partitioned").info();
        // warm + verify against the unsharded forward
        let warm = session.run().expect("sharded run");
        assert!(
            (warm.output.frob_norm() - ref_norm).abs() < 1e-9,
            "sharded output diverged from the unsharded forward"
        );
        let result = bench(&format!("forward shards={shards}"), &config, || {
            session.run().expect("sharded run")
        });
        let speedup = if shards == 1 {
            baseline_ns = result.wall.median;
            1.0
        } else if result.wall.median > 0.0 {
            baseline_ns / result.wall.median
        } else {
            1.0
        };
        if shards == 4 {
            at4 = Some(speedup);
        }
        println!(
            "{}  speedup {:>5.2}x  [{}]",
            result.line(),
            speedup,
            info.label()
        );
    }

    if let Some(s4) = at4 {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        println!();
        println!(
            "verdict: 4-shard speedup {s4:.2}x over the 1-shard baseline on {cores} \
             core(s) — {}",
            if s4 >= 1.5 {
                "meets the >= 1.5x strong-scaling bar"
            } else if cores < 2 {
                "below 1.5x (expected: single-core box, no real parallelism available)"
            } else {
                "below the 1.5x bar — investigate imbalance/halo overhead"
            }
        );
    }
}
