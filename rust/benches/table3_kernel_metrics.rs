//! Bench: regenerate **Table 3** — per-kernel profiling metrics of the
//! major kernels of HAN on DBLP: share of stage time, % of peak
//! performance, DRAM bandwidth utilization, shared-memory bandwidth
//! utilization, L2 hit rate.
//!
//! Paper reference rows (HAN-DB):
//!   FP  sgemm    97.4% time, 95.9% peak, 33.6% DRAM, 24.3% SMEM, 82.7% L2
//!   NA  SpMMCsr  85.9% time,  3.9% peak, 74.3% DRAM,    0% SMEM, 31.4% L2
//!   NA  SDDMM     8.4% time,  6.5% peak, 44.0% DRAM,    0% SMEM, 67.6% L2
//!   SA  sgemm    47.8% time,        -    42.4% DRAM, 21.4% SMEM, 83.3% L2
//!   SA  uEleWise 20.0% time,  0.9% peak, 82.4% DRAM,    0% SMEM, 50.0% L2
//!   SA  Reduce   11.0% time,  3.1% peak, 88.3% DRAM,    0% SMEM, 25.2% L2
//!   SA  Concat   17.5% time,        -    81.6% DRAM,    0% SMEM, 50.0% L2
//!
//! Run: `cargo bench --bench table3_kernel_metrics`

use hgnn_char::bench::header;
use hgnn_char::datasets::{DatasetId, DatasetScale};
use hgnn_char::models::ModelId;
use hgnn_char::profiler::StageId;
use hgnn_char::report;
use hgnn_char::session::{Profiling, Session};

fn scale() -> DatasetScale {
    if std::env::var("QUICK_BENCH").is_ok() {
        DatasetScale::ci()
    } else {
        DatasetScale::paper()
    }
}

fn main() {
    header(
        "Table 3 — per-kernel metrics (HAN, DBLP)",
        "modeled Nsight-Compute-style counters per kernel",
    );
    let run = Session::builder()
        .dataset(DatasetId::Dblp)
        .scale(scale())
        .model(ModelId::Han)
        .profiling(Profiling::Traces)
        .build()
        .unwrap()
        .run()
        .unwrap();

    for stage in StageId::GPU_STAGES {
        println!("{}", report::table3_stage(stage, &run.profile.kernel_table(stage)));
    }

    println!("=== Table 3 reproduction summary (paper vs measured) ===");
    let fp = run.profile.kernel_table(StageId::FeatureProjection);
    if let Some((_, m, share)) = fp.iter().find(|(n, _, _)| n == "sgemm") {
        println!("{}", report::compare("FP sgemm time share", 97.4, *share, "%"));
        println!("{}", report::compare("FP sgemm peak perf", 95.9, m.peak_perf_pct, "%"));
        println!("{}", report::compare("FP sgemm L2 hit", 82.7, m.l2_hit_pct, "%"));
        println!("{}", report::compare("FP sgemm DRAM BW util", 33.6, m.dram_bw_util_pct, "%"));
    }
    let na = run.profile.kernel_table(StageId::NeighborAggregation);
    if let Some((_, m, share)) = na.iter().find(|(n, _, _)| n == "SpMMCsr") {
        println!("{}", report::compare("NA SpMMCsr time share", 85.9, *share, "%"));
        println!("{}", report::compare("NA SpMMCsr peak perf", 3.9, m.peak_perf_pct, "%"));
        println!("{}", report::compare("NA SpMMCsr DRAM BW util", 74.3, m.dram_bw_util_pct, "%"));
        println!("{}", report::compare("NA SpMMCsr L2 hit", 31.4, m.l2_hit_pct, "%"));
    }
    let sa = run.profile.kernel_table(StageId::SemanticAggregation);
    for (paper_name, paper_share) in
        [("sgemm", 47.8), ("uEleWise", 20.0), ("Reduce", 11.0), ("Concat", 17.5)]
    {
        if let Some((_, _, share)) = sa.iter().find(|(n, _, _)| n == paper_name) {
            println!(
                "{}",
                report::compare(&format!("SA {paper_name} time share"), paper_share, *share, "%")
            );
        }
    }
    println!("\nkey claims:");
    let spmm = na.iter().find(|(n, _, _)| n == "SpMMCsr");
    println!(
        "  'SpMM dominates NA'           : {}",
        spmm.map(|(_, _, s)| *s > 50.0).unwrap_or(false)
    );
    println!(
        "  'SpMM memory-bound (low peak)': {}",
        spmm.map(|(_, m, _)| m.peak_perf_pct < 15.0).unwrap_or(false)
    );
    let concat = sa.iter().find(|(n, _, _)| n == "Concat");
    println!(
        "  'data rearrangement expensive': {} (Concat share {:.1}%)",
        concat.map(|(_, _, s)| *s > 5.0).unwrap_or(false),
        concat.map(|(_, _, s)| *s).unwrap_or(0.0)
    );
}
