//! Bench: **mini-batch training — fused vs unfused backward schedule**.
//!
//! The training-side question from the kernel-fusion minibatch work:
//! the backward pass launches a swarm of small per-relation gradient
//! kernels (grad-SpMM per subgraph, per-metapath attention backward),
//! and at serving-style batch sizes the dispatch overhead rivals the
//! math. The fused schedule batches adjacent per-relation gradient
//! kernels of a stage into one dispatch per kernel name. Each sweep
//! cell trains one seeded epoch twice — fused and unfused — from the
//! same initial weights, so the gradient math is bit-identical and the
//! only difference is the dispatch count and its wall-time echo.
//! Expected qualitative trend: fused backward dispatches are
//! **strictly fewer** for every model × batch size, with the gap
//! widening for models with more subgraphs (MAGNN > HAN > R-GCN) and
//! smaller batches (more batches per epoch → more swarms to merge).
//!
//! Run: `cargo bench --bench train_epoch`

use hgnn_char::bench::header;
use hgnn_char::datasets::{DatasetId, DatasetScale};
use hgnn_char::models::ModelId;
use hgnn_char::session::Session;
use hgnn_char::train::{OptimizerSpec, TrainConfig};
use hgnn_char::util::human_time;

fn scale() -> DatasetScale {
    if std::env::var("QUICK_BENCH").is_ok() {
        DatasetScale::ci()
    } else {
        DatasetScale::factor(0.25)
    }
}

fn epoch(model: ModelId, batch: usize, fused: bool) -> (f64, usize, u64) {
    let config = TrainConfig {
        epochs: 1,
        batch,
        optimizer: OptimizerSpec::sgd(0.05),
        seed: 0x7A11,
        classes: 4,
        fused,
    };
    let mut session = Session::builder()
        .dataset(DatasetId::Imdb)
        .scale(scale())
        .model(model)
        .build()
        .unwrap();
    session.init_weights(config.seed).unwrap();
    let report = session.fit(&config).unwrap();
    let e = &report.epochs[0];
    (e.loss, e.backward_dispatches, e.epoch_nanos)
}

fn main() {
    header(
        "training epoch: fused vs unfused backward kernel schedule",
        "one seeded epoch per cell, identical init; dispatch counts are exact, times are wall",
    );
    let batches: &[(usize, &str)] = &[(32, "32"), (128, "128"), (usize::MAX, "full")];
    let mut all_fewer = true;
    for model in [ModelId::Rgcn, ModelId::Han, ModelId::Magnn] {
        println!("-- {model:?} --");
        for &(batch, label) in batches {
            let (loss_f, disp_f, nanos_f) = epoch(model, batch, true);
            let (loss_u, disp_u, nanos_u) = epoch(model, batch, false);
            let fewer = disp_f < disp_u;
            all_fewer &= fewer;
            let bitwise = if loss_f.to_bits() == loss_u.to_bits() { "yes" } else { "NO" };
            println!(
                "  batch {label:>4}  fused {disp_f:>5} dispatches / {:>9}   unfused {disp_u:>5} / \
                 {:>9}   loss bit-identical: {bitwise}",
                human_time(nanos_f as f64),
                human_time(nanos_u as f64),
            );
            if !fewer {
                println!("     ^ fused NOT fewer ({disp_f} vs {disp_u})");
            }
        }
    }
    println!(
        "\n-> fused backward dispatches strictly fewer in every cell: {}",
        if all_fewer { "yes" } else { "NO (fusion regression)" }
    );
}
