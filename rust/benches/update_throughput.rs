//! Bench: **streaming graph updates — epoch-flip cost and served tail
//! latency under churn**.
//!
//! Two questions the dynamic subsystem answers:
//!
//! 1. *What does a flip cost?* The barrier recomputes NA only for the
//!    touched destination rows over compact patch sub-CSRs, so the
//!    pause should scale with the number of touched rows — not with the
//!    graph. The sweep grows updates-per-flip and reports the pause,
//!    the recomputed row count and the evictions per flip.
//! 2. *What does churn do to serving?* The same request stream is
//!    replayed against an [`hgnn_char::serving::AsyncServer`] while an
//!    updater applies batches and flips at increasing rates. Because
//!    the barrier runs strictly between waves, p50 should barely move
//!    and p99 should degrade gracefully (bounded by the flip pause),
//!    never reject.
//!
//! Run: `cargo bench --bench update_throughput`

use std::time::{Duration, Instant};

use hgnn_char::bench::header;
use hgnn_char::datasets::{DatasetId, DatasetScale};
use hgnn_char::dynamic::{DynamicSpec, GraphUpdate};
use hgnn_char::graph::HeteroGraph;
use hgnn_char::models::ModelId;
use hgnn_char::serving::{ServingConfig, SubmitOpts};
use hgnn_char::session::{Session, SessionBuilder};
use hgnn_char::util::{human_time, Pcg32};

fn scale() -> DatasetScale {
    if std::env::var("QUICK_BENCH").is_ok() {
        DatasetScale::ci()
    } else {
        DatasetScale::factor(0.25)
    }
}

fn builder() -> SessionBuilder {
    Session::builder()
        .dataset(DatasetId::Imdb)
        .scale(scale())
        .model(ModelId::Han)
        .dynamic(DynamicSpec::default())
}

/// `n` random updates valid against the base counts: edge inserts
/// (duplicates are no-ops; new edges touch their destination row) mixed
/// with feature rewrites (each evicts one projection key). No node
/// growth, so request ids stay valid across every flip.
fn churn(hg: &HeteroGraph, n: usize, rng: &mut Pcg32) -> Vec<GraphUpdate> {
    (0..n)
        .map(|i| {
            if i % 4 == 3 {
                let ty = rng.gen_range(hg.node_types().len());
                let t = hg.node_type(ty);
                GraphUpdate::SetFeatures {
                    ty,
                    node: rng.gen_range(t.count) as u32,
                    features: vec![rng.gen_f32(); t.feat_dim],
                }
            } else {
                let rel = rng.gen_range(hg.relations().len());
                let r = hg.relation(rel);
                GraphUpdate::AddEdge {
                    relation: rel,
                    dst: rng.gen_range(r.adj.n_rows) as u32,
                    src: rng.gen_range(r.adj.n_cols) as u32,
                }
            }
        })
        .collect()
}

fn percentile(sorted: &[Duration], p: f64) -> Duration {
    let i = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[i]
}

const BATCH: usize = 16;

fn main() {
    header(
        "streaming updates: epoch-flip cost and served tail latency under churn",
        "HAN over IMDB synth; flips patch a materialized forward in place",
    );
    let quick = std::env::var("QUICK_BENCH").is_ok();

    let probe = builder().build().unwrap();
    let base = probe.graph().clone();
    let n_target = base.node_type(probe.plan().target).count;
    println!("{}  (target nodes: {n_target}, batch {BATCH})\n", base.stats_line());
    drop(probe);

    // -- 1: flip cost vs updates per flip ---------------------------------
    println!("-- epoch-flip cost vs updates per flip (patching the full forward) --");
    let mut session = builder().build().unwrap();
    let _ = session.run().unwrap(); // materialize the NA bank the flips patch
    let mut rng = Pcg32::new(0xD15C0, 7);
    let mut rows_seen: Vec<usize> = Vec::new();
    for &n in &[1usize, 8, 64, 256] {
        let updates = churn(session.graph(), n, &mut rng);
        session.apply_updates(updates).unwrap();
        let t0 = Instant::now();
        let report = session.flip_epoch().unwrap();
        let wall = t0.elapsed();
        rows_seen.push(report.na_rows_recomputed);
        println!(
            "  {n:>4} updates/flip  pause {:>9}  na rows {:>6}  evicted agg {:>5}  \
             shards {:>2}  wall {:>9}",
            human_time(report.pause_nanos as f64),
            report.na_rows_recomputed,
            report.evicted_agg,
            report.shards_patched,
            human_time(wall.as_nanos() as f64),
        );
    }
    let scales = rows_seen.last().copied().unwrap_or(0) >= rows_seen.first().copied().unwrap_or(0);
    println!(
        "  -> recomputed rows grow with churn, not with the graph: {}\n",
        if scales { "yes" } else { "NO (duplicate-heavy stream or regression)" }
    );

    // -- 2: served tail latency under a concurrent update stream ----------
    println!("-- served p50/p99 while an updater applies batches and flips --");
    let batches = if quick { 24 } else { 96 };
    let sweeps: [(&str, usize, usize); 3] = [
        ("baseline: no updates        ", 0, 0),
        ("gentle:   8 upd every 8 waves", 8, 8),
        ("churny:  32 upd every 2 waves", 2, 32),
    ];
    let mut p99_base: Option<f64> = None;
    for &(label, every, per) in &sweeps {
        let server = builder().serve_async(ServingConfig {
            max_batch: BATCH,
            flush_after: Duration::from_millis(1),
            ..Default::default()
        });
        let mut rng = Pcg32::new(0xFACADE, 11);
        let mut lat: Vec<Duration> = Vec::with_capacity(batches);
        let mut flip_rxs = Vec::new();
        for b in 0..batches {
            if every > 0 && b > 0 && b % every == 0 {
                let updates = churn(&base, per, &mut rng);
                let _ = server.apply_updates(updates);
                if let Ok(rx) = server.flip_epoch() {
                    flip_rxs.push(rx);
                }
            }
            let ids: Vec<u32> = (0..BATCH).map(|_| rng.gen_range(n_target) as u32).collect();
            let t0 = Instant::now();
            let rx = server.submit(&ids, SubmitOpts::default()).unwrap();
            rx.recv().unwrap().unwrap();
            lat.push(t0.elapsed());
        }
        let mut pauses: Vec<u64> = Vec::new();
        for rx in flip_rxs {
            if let Ok(Ok(report)) = rx.recv() {
                pauses.push(report.pause_nanos);
            }
        }
        let _ = server.shutdown();
        lat.sort();
        let (p50, p99) = (percentile(&lat, 0.50), percentile(&lat, 0.99));
        let pause = if pauses.is_empty() {
            "-".to_string()
        } else {
            human_time(pauses.iter().sum::<u64>() as f64 / pauses.len() as f64)
        };
        println!(
            "  {label}  p50 {:>9}  p99 {:>9}  flips {:>3}  mean pause {:>9}",
            human_time(p50.as_nanos() as f64),
            human_time(p99.as_nanos() as f64),
            pauses.len(),
            pause,
        );
        match p99_base {
            None => p99_base = Some(p99.as_nanos() as f64),
            Some(b0) => {
                let ratio = p99.as_nanos() as f64 / b0.max(1.0);
                println!("      -> p99 vs baseline: {ratio:.2}x (barrier runs between waves)");
            }
        }
    }
}
