//! Bench harness (criterion is not in the offline vendor set).
//!
//! Provides warmup + repeated timing with robust statistics, wallclock
//! budgeting, and a uniform report format. Every `[[bench]]` target in
//! Cargo.toml is a `harness = false` binary built on this module.

use std::time::Instant;

use crate::util::stats::Summary;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark id.
    pub name: String,
    /// Per-iteration wallclock statistics (nanoseconds).
    pub wall: Summary,
    /// Iterations measured.
    pub iters: usize,
}

impl BenchResult {
    /// One-line report.
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (median {:>12}, mad {:>10}, n={})",
            self.name,
            crate::util::human_time(self.wall.mean),
            crate::util::human_time(self.wall.median),
            crate::util::human_time(self.wall.mad),
            self.iters,
        )
    }
}

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Warmup iterations (not measured).
    pub warmup_iters: usize,
    /// Measured iterations.
    pub iters: usize,
    /// Hard per-benchmark wallclock budget in seconds; measurement stops
    /// early once exceeded (keeps paper-scale benches tractable on CI).
    pub budget_secs: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 1, iters: 5, budget_secs: 60.0 }
    }
}

impl BenchConfig {
    /// Fast config for smoke runs (`QUICK_BENCH=1`).
    pub fn quick() -> BenchConfig {
        BenchConfig { warmup_iters: 0, iters: 2, budget_secs: 10.0 }
    }

    /// Select quick mode when the `QUICK_BENCH` env var is set.
    pub fn from_env() -> BenchConfig {
        if std::env::var("QUICK_BENCH").is_ok() {
            BenchConfig::quick()
        } else {
            BenchConfig::default()
        }
    }
}

/// Measure a closure. The closure's return value is passed through a
/// black-box sink so the optimizer cannot elide the work.
pub fn bench<T>(name: &str, config: &BenchConfig, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..config.warmup_iters {
        sink(f());
    }
    let started = Instant::now();
    let mut samples = Vec::with_capacity(config.iters);
    for _ in 0..config.iters.max(1) {
        let t0 = Instant::now();
        sink(f());
        samples.push(t0.elapsed().as_nanos() as f64);
        if started.elapsed().as_secs_f64() > config.budget_secs {
            break;
        }
    }
    BenchResult { name: name.to_string(), wall: Summary::of(&samples), iters: samples.len() }
}

/// Optimizer-opaque value sink (std::hint::black_box wrapper).
#[inline]
pub fn sink<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Standard header every bench binary prints.
pub fn header(figure: &str, description: &str) {
    println!("==================================================================");
    println!("hgnn-char bench: {figure}");
    println!("  {description}");
    println!("  (times are modeled NVIDIA T4 latencies unless marked 'wall')");
    println!("==================================================================");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures() {
        let cfg = BenchConfig { warmup_iters: 1, iters: 3, budget_secs: 5.0 };
        let r = bench("spin", &cfg, || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert_eq!(r.iters, 3);
        assert!(r.wall.mean > 0.0);
        assert!(r.line().contains("spin"));
    }

    #[test]
    fn budget_stops_early() {
        let cfg = BenchConfig { warmup_iters: 0, iters: 1000, budget_secs: 0.05 };
        let r = bench("sleepy", &cfg, || std::thread::sleep(std::time::Duration::from_millis(20)));
        assert!(r.iters < 1000, "budget should cut iterations, ran {}", r.iters);
    }

    #[test]
    fn quick_config() {
        let q = BenchConfig::quick();
        assert!(q.iters < BenchConfig::default().iters);
    }
}
