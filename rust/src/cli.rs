//! Tiny CLI argument parser (clap is not in the offline vendor set).
//!
//! Grammar: `hgnn-char <command> [positional...] [--flag [value]]...`.
//! Both `--key value` and `--key=value` bind; a value token may be a
//! negative number (`--offset -3`, `--offset=-3`). Flags with no
//! following value (or followed by another flag) are booleans.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Args {
    /// First token (the subcommand).
    pub command: String,
    /// Remaining positionals.
    pub positional: Vec<String>,
    /// `--key value` / `--switch` flags.
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut args = Args::default();
        let mut iter = tokens.into_iter().peekable();
        if let Some(first) = iter.next() {
            args.command = first;
        }
        while let Some(tok) = iter.next() {
            if let Some(key) = tok.strip_prefix("--") {
                // `--key=value` binds inline (empty value allowed:
                // `--name=` is the empty string, not a boolean)
                if let Some((key, value)) = key.split_once('=') {
                    args.flags.insert(key.to_string(), value.to_string());
                    continue;
                }
                let value = match iter.peek() {
                    Some(next) if !next.starts_with("--") => iter.next().unwrap(),
                    _ => "true".to_string(),
                };
                args.flags.insert(key.to_string(), value);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Parse flags only (no leading subcommand) — what examples use, so
    /// `cargo run --example foo -- --scale ci` works.
    pub fn flags_from_env() -> Args {
        Args::parse(std::iter::once(String::new()).chain(std::env::args().skip(1)))
    }

    /// String flag with default.
    pub fn flag_str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Usize flag with default.
    pub fn flag_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| Error::config(format!("--{key} {v}: {e}"))),
        }
    }

    /// i64 flag with default (accepts negative values: `--shift -3` or
    /// `--shift=-3`).
    pub fn flag_i64(&self, key: &str, default: i64) -> Result<i64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| Error::config(format!("--{key} {v}: {e}"))),
        }
    }

    /// f64 flag with default.
    pub fn flag_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| Error::config(format!("--{key} {v}: {e}"))),
        }
    }

    /// Boolean switch.
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// Partition spec from `--shards K [--shard-threads T]` (default
    /// `None`: unsharded). `--shards 0` / `--shard-threads 0` are
    /// rejected at parse level, as is `--shard-threads` without
    /// `--shards`; `T` defaults to `K`.
    pub fn partition(&self) -> Result<Option<crate::partition::PartitionSpec>> {
        if !self.has("shards") {
            if self.has("shard-threads") {
                return Err(Error::config("--shard-threads requires --shards"));
            }
            return Ok(None);
        }
        let shards = self.flag_usize("shards", 0)?;
        if shards == 0 {
            return Err(Error::config("--shards must be >= 1"));
        }
        let threads = self.flag_usize("shard-threads", shards)?;
        if threads == 0 {
            return Err(Error::config("--shard-threads must be >= 1"));
        }
        Ok(Some(crate::partition::PartitionSpec::new(shards).with_threads(threads)))
    }

    /// Cluster spec from `--cluster N` (default `None`: in-process
    /// execution). `N` is the worker count; `--cluster 0` is rejected
    /// at parse level, mirroring `--shards`. Composes with `--shards K`
    /// (K shards placed onto the N workers; without it the session
    /// defaults to one shard per worker).
    pub fn cluster(&self) -> Result<Option<crate::cluster::ClusterSpec>> {
        if !self.has("cluster") {
            return Ok(None);
        }
        let workers = self.flag_usize("cluster", 0)?;
        if workers == 0 {
            return Err(Error::config("--cluster must be >= 1"));
        }
        Ok(Some(crate::cluster::ClusterSpec::new(workers)))
    }

    /// Worker-pool width from `--threads N` (default `None`: the
    /// process default — `HGNN_THREADS`, else available parallelism).
    /// `--threads 0` is rejected at parse level, mirroring `--shards`.
    /// Composes freely with `--shards`/`--shard-threads`: those split
    /// work across shard tasks, `--threads` caps the one pool that
    /// executes both the tasks and the intra-kernel row blocks.
    pub fn threads(&self) -> Result<Option<usize>> {
        if !self.has("threads") {
            return Ok(None);
        }
        let t = self.flag_usize("threads", 0)?;
        if t == 0 {
            return Err(Error::config("--threads must be >= 1"));
        }
        Ok(Some(t))
    }

    /// Serving-runtime tuning from `--deadline-ms D --priority-lanes P
    /// --admission-qps Q --queue-cap C` (all optional; `None`/defaults
    /// mean "feature off" / the [`crate::serving::ServingConfig`]
    /// default). Zero (or non-positive QPS) is rejected at parse level,
    /// mirroring `--shards`.
    pub fn serve_tuning(&self) -> Result<ServeTuning> {
        let mut tuning = ServeTuning::default();
        if self.has("deadline-ms") {
            let d = self.flag_usize("deadline-ms", 0)?;
            if d == 0 {
                return Err(Error::config("--deadline-ms must be >= 1"));
            }
            tuning.deadline_ms = Some(d as u64);
        }
        if self.has("priority-lanes") {
            let p = self.flag_usize("priority-lanes", 0)?;
            if p == 0 {
                return Err(Error::config("--priority-lanes must be >= 1"));
            }
            tuning.priority_lanes = p;
        }
        if self.has("admission-qps") {
            let q = self.flag_f64("admission-qps", 0.0)?;
            if !q.is_finite() || q <= 0.0 {
                return Err(Error::config("--admission-qps must be > 0"));
            }
            tuning.admission_qps = Some(q);
        }
        if self.has("queue-cap") {
            let c = self.flag_usize("queue-cap", 0)?;
            if c == 0 {
                return Err(Error::config("--queue-cap must be >= 1"));
            }
            tuning.queue_cap = Some(c);
        }
        Ok(tuning)
    }

    /// Streaming-update spec from `--update-stream <file>
    /// [--epoch-every <n>]` (default `None`: static serving).
    /// `--epoch-every` counts served batches between epoch flips
    /// (default 1: flip after every batch) and is rejected at parse
    /// level when zero or orphaned, mirroring `--shard-threads`.
    pub fn update_stream(&self) -> Result<Option<UpdateStreamSpec>> {
        if !self.has("update-stream") {
            if self.has("epoch-every") {
                return Err(Error::config("--epoch-every requires --update-stream"));
            }
            return Ok(None);
        }
        let path = self.flag_str("update-stream", "");
        if path.is_empty() || path == "true" {
            return Err(Error::config("--update-stream needs a file path"));
        }
        let epoch_every = self.flag_usize("epoch-every", 1)?;
        if epoch_every == 0 {
            return Err(Error::config("--epoch-every must be >= 1"));
        }
        Ok(Some(UpdateStreamSpec { path, epoch_every }))
    }

    /// Training hyperparameters from `train --epochs N --lr X
    /// --optimizer sgd|adam --batch B [--seed S] [--classes C]
    /// [--no-fuse]`. Degenerate values (zero epochs/batch/classes,
    /// non-positive or non-finite learning rate, unknown optimizer
    /// name) are rejected at parse level, mirroring `--shards`.
    pub fn train_config(&self) -> Result<crate::train::TrainConfig> {
        let defaults = crate::train::TrainConfig::default();
        let lr = self.flag_f64("lr", 0.05)? as f32;
        let optimizer =
            crate::train::OptimizerSpec::parse(&self.flag_str("optimizer", "sgd"), lr)?;
        let config = crate::train::TrainConfig {
            epochs: self.flag_usize("epochs", defaults.epochs)?,
            batch: self.flag_usize("batch", defaults.batch)?,
            optimizer,
            seed: self.flag_usize("seed", defaults.seed as usize)? as u64,
            classes: self.flag_usize("classes", defaults.classes)?,
            fused: !self.has("no-fuse"),
        };
        config.validate()?;
        Ok(config)
    }

    /// Quantized feature-projection format from `--quantize f16|int8`
    /// (default `None`: the all-f32 path). A bare `--quantize` switch
    /// or an unknown format name is rejected at parse level, mirroring
    /// `--threads`.
    pub fn quantize(&self) -> Result<Option<crate::kernels::quant::QuantSpec>> {
        match self.flags.get("quantize") {
            None => Ok(None),
            Some(v) => match crate::kernels::quant::QuantSpec::parse(v) {
                Some(spec) => Ok(Some(spec)),
                None => Err(Error::config(format!("--quantize '{v}': expected f16 or int8"))),
            },
        }
    }

    /// Dataset scale from `--scale paper|ci|<factor>` (default paper).
    pub fn scale(&self) -> Result<crate::datasets::DatasetScale> {
        match self.flag_str("scale", "paper").as_str() {
            "paper" => Ok(crate::datasets::DatasetScale::paper()),
            "ci" => Ok(crate::datasets::DatasetScale::ci()),
            other => {
                let f: f64 = other
                    .parse()
                    .map_err(|_| Error::config(format!("--scale '{other}'")))?;
                if f <= 0.0 || f > 1.0 {
                    return Err(Error::config("--scale factor must be in (0, 1]"));
                }
                Ok(crate::datasets::DatasetScale::factor(f))
            }
        }
    }
}

/// Streaming-update replay parsed by [`Args::update_stream`]: a file of
/// graph updates (see [`crate::dynamic::parse_update_stream`]) applied
/// through the serving epoch barrier while the demo loop submits.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct UpdateStreamSpec {
    /// Path to the update-stream file (`--update-stream`).
    pub path: String,
    /// Served batches between epoch flips (`--epoch-every`, default 1).
    pub epoch_every: usize,
}

/// Serving-runtime tuning knobs parsed by [`Args::serve_tuning`].
///
/// `None` fields inherit the [`crate::serving::ServingConfig`] defaults;
/// `priority_lanes` defaults to 1 (a single class, legacy behavior).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeTuning {
    /// Per-request deadline in milliseconds (`--deadline-ms`).
    pub deadline_ms: Option<u64>,
    /// Number of priority classes (`--priority-lanes`).
    pub priority_lanes: usize,
    /// Token-bucket admission rate in node-ids/sec (`--admission-qps`).
    pub admission_qps: Option<f64>,
    /// Bounded submit-queue depth in requests (`--queue-cap`).
    pub queue_cap: Option<usize>,
}

impl Default for ServeTuning {
    fn default() -> Self {
        ServeTuning {
            deadline_ms: None,
            priority_lanes: 1,
            admission_qps: None,
            queue_cap: None,
        }
    }
}

/// The top-level usage text.
pub const USAGE: &str = "\
hgnn-char — characterizing & understanding HGNNs (paper reproduction)

USAGE: hgnn-char <command> [options]

COMMANDS:
  list                           datasets, models, metapaths
  run --model M --dataset D      profile one inference run
      [--scale paper|ci|F] [--policy seq|par|fused|mix] [--workers N]
      [--shards K]                 degree-balanced sharded execution
                                   (subsumes --policy: FP/NA per shard)
      [--shard-threads T]          threads driving the shards (default K)
      [--threads N]                intra-kernel worker-pool width
                                   (default: all cores; HGNN_THREADS
                                   overrides the default)
      [--cluster N]                distributed execution: place shards
                                   onto N workers over the wire protocol
                                   (sim transport by default; sockets
                                   with --features cluster-sockets)
      [--quantize f16|int8]        quantized feature projection: FP
                                   weights stored round-tripped through
                                   the format; prints the accuracy
                                   delta vs an f32 baseline run
  figure <2|3|4|5a|5b|5c|6a|6b>  regenerate a paper figure
      [--scale ...]
  table <3>                      regenerate a paper table
  timeline --model M --dataset D render the Fig 5c-style timeline
  artifacts [--dir artifacts]    list AOT artifacts + PJRT platform
  serve [--requests N]           demo of the batched serving loop
      [--batch B]                  submit typed batches of B ids
      [--fanout K]                 mini-batch metapath sampling, K
                                   neighbors per node per layer
      [--sample-layers L]          sampling depth (default 1)
      [--reuse-cap N]              cross-request reuse caches, N rows
                                   per cache (requires --fanout)
      [--shards K]                 shard-affine serving: batches group
                                   by owner shard, caches go per-shard
      [--shard-threads T]          threads driving the shards (default K)
      [--threads N]                intra-kernel worker-pool width
      [--deadline-ms D]            per-request deadline; late requests
                                   get a typed DeadlineExceeded reply
      [--priority-lanes P]         priority classes (0 = most urgent);
                                   demo round-robins submits over them
      [--admission-qps Q]          token-bucket admission rate in node
                                   ids/sec; over-rate submits are
                                   rejected with a typed Overloaded
      [--queue-cap C]              bounded submit queue depth (default
                                   4096); overflow rejects as QueueFull
      [--update-stream FILE]       replay streaming graph updates from
                                   FILE (lines: edge/node/feat) through
                                   the epoch barrier while serving
      [--epoch-every N]            served batches between epoch flips
                                   (default 1; requires --update-stream)
      [--quantize f16|int8]        quantized serving: FP weights and
                                   reuse-cache rows stored in the
                                   format (2-4x smaller residency)
  train --model M --dataset D    mini-batch training on synthetic labels
      [--epochs N]                 epochs to run (default 3)
      [--lr X]                     learning rate (default 0.05)
      [--optimizer sgd|adam]       update rule (default sgd)
      [--batch B]                  seeds per mini-batch (default 256)
      [--seed S] [--classes C]     task seed / label classes
      [--no-fuse]                  dispatch the backward kernel swarm
                                   unfused (default: one dispatch per
                                   kernel per stage)
      [--fanout K]                 sampled mini-batches, K neighbors
                                   per node per layer
      [--sample-layers L]          sampling depth (default 1)
      [--shards K] [--threads N]   compose exactly as under run
  help                           this text
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn basic_parse() {
        let a = parse("run --model han --dataset imdb --verbose");
        assert_eq!(a.command, "run");
        assert_eq!(a.flag_str("model", ""), "han");
        assert_eq!(a.flag_str("dataset", ""), "imdb");
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn positionals() {
        let a = parse("figure 5a --scale ci");
        assert_eq!(a.command, "figure");
        assert_eq!(a.positional, vec!["5a"]);
        assert_eq!(a.flag_str("scale", "paper"), "ci");
    }

    #[test]
    fn typed_flags() {
        let a = parse("run --workers 4 --dropout 0.5");
        assert_eq!(a.flag_usize("workers", 1).unwrap(), 4);
        assert_eq!(a.flag_f64("dropout", 0.0).unwrap(), 0.5);
        assert_eq!(a.flag_usize("missing", 7).unwrap(), 7);
        let bad = parse("run --workers nope");
        assert!(bad.flag_usize("workers", 1).is_err());
    }

    #[test]
    fn scale_parsing() {
        assert_eq!(parse("x --scale ci").scale().unwrap(), crate::datasets::DatasetScale::ci());
        assert_eq!(
            parse("x").scale().unwrap(),
            crate::datasets::DatasetScale::paper()
        );
        let custom = parse("x --scale 0.5").scale().unwrap();
        assert!((custom.topo_factor - 0.5).abs() < 1e-12);
        assert!(parse("x --scale 2.0").scale().is_err());
        assert!(parse("x --scale nah").scale().is_err());
    }

    #[test]
    fn empty_args() {
        let a = Args::parse(Vec::<String>::new());
        assert_eq!(a.command, "");
    }

    #[test]
    fn key_equals_value_syntax() {
        let a = parse("run --model=han --workers=4 --dropout=0.5 --verbose");
        assert_eq!(a.flag_str("model", ""), "han");
        assert_eq!(a.flag_usize("workers", 1).unwrap(), 4);
        assert_eq!(a.flag_f64("dropout", 0.0).unwrap(), 0.5);
        assert!(a.has("verbose"));
        // the '=' form must NOT create a mangled "model=han" key
        assert!(!a.has("model=han"));
        // and must not steal the next token
        let b = parse("figure 5a --scale=ci");
        assert_eq!(b.positional, vec!["5a"]);
        assert_eq!(b.scale().unwrap(), crate::datasets::DatasetScale::ci());
    }

    #[test]
    fn equals_value_edge_cases() {
        // empty value stays the empty string (distinct from boolean true)
        let a = parse("x --name=");
        assert_eq!(a.flag_str("name", "def"), "");
        // only the first '=' splits
        let a = parse("x --expr=a=b");
        assert_eq!(a.flag_str("expr", ""), "a=b");
    }

    #[test]
    fn negative_number_values() {
        // space-separated: a negative number is a value, not a flag
        let a = parse("run --shift -3 --temp -0.5");
        assert_eq!(a.flag_i64("shift", 0).unwrap(), -3);
        assert_eq!(a.flag_f64("temp", 0.0).unwrap(), -0.5);
        // '=' form
        let a = parse("run --shift=-7 --temp=-2.25");
        assert_eq!(a.flag_i64("shift", 0).unwrap(), -7);
        assert_eq!(a.flag_f64("temp", 0.0).unwrap(), -2.25);
        // defaults & errors
        assert_eq!(a.flag_i64("missing", -1).unwrap(), -1);
        assert!(parse("run --shift=nope").flag_i64("shift", 0).is_err());
    }

    #[test]
    fn shards_flag_parsing() {
        // absent: unsharded
        assert_eq!(parse("run").partition().unwrap(), None);
        // present: spec with threads defaulting to shards
        let spec = parse("run --shards 4").partition().unwrap().unwrap();
        assert_eq!(spec.shards, 4);
        assert_eq!(spec.threads, 4);
        let spec = parse("run --shards=8 --shard-threads=2").partition().unwrap().unwrap();
        assert_eq!(spec.shards, 8);
        assert_eq!(spec.threads, 2);
        // zero is rejected in both spellings, for both flags
        assert!(parse("run --shards 0").partition().is_err());
        assert!(parse("run --shards=0").partition().is_err());
        assert!(parse("run --shards 2 --shard-threads 0").partition().is_err());
        assert!(parse("run --shards=2 --shard-threads=0").partition().is_err());
        // non-numeric and orphaned thread caps are rejected
        assert!(parse("run --shards nah").partition().is_err());
        assert!(parse("run --shard-threads 2").partition().is_err());
    }

    #[test]
    fn threads_flag_parsing() {
        // absent: inherit the process default
        assert_eq!(parse("run").threads().unwrap(), None);
        // present in both spellings
        assert_eq!(parse("run --threads 4").threads().unwrap(), Some(4));
        assert_eq!(parse("run --threads=8").threads().unwrap(), Some(8));
        // zero is rejected in both spellings, like --shards
        assert!(parse("run --threads 0").threads().is_err());
        assert!(parse("run --threads=0").threads().is_err());
        // non-numeric rejected
        assert!(parse("run --threads nah").threads().is_err());
        // bare switch (no value) rejected: "true" is not a width
        assert!(parse("run --threads").threads().is_err());
    }

    #[test]
    fn threads_compose_with_shards_and_serve_flags() {
        // pool cap + shard split + serving flags all bind independently
        let a = parse(
            "serve --requests 64 --fanout 8 --batch 4 --reuse-cap 128 \
             --shards 2 --shard-threads 2 --threads 4",
        );
        assert_eq!(a.threads().unwrap(), Some(4));
        let spec = a.partition().unwrap().unwrap();
        assert_eq!((spec.shards, spec.threads), (2, 2));
        assert_eq!(a.flag_usize("fanout", 0).unwrap(), 8);
        // run spelling with '=' interleaved
        let a = parse("run --shards=4 --threads=2 --model han");
        assert_eq!(a.threads().unwrap(), Some(2));
        assert_eq!(a.partition().unwrap().unwrap().shards, 4);
        assert_eq!(a.flag_str("model", ""), "han");
    }

    #[test]
    fn shards_compose_with_serve_flags() {
        // the full sharded-serving incantation parses with every flag
        // bound to its own value (no token stealing between flags)
        let a = parse(
            "serve --requests 64 --fanout 8 --batch 4 --reuse-cap 128 \
             --shards 2 --shard-threads 2",
        );
        assert_eq!(a.command, "serve");
        assert_eq!(a.flag_usize("requests", 0).unwrap(), 64);
        assert_eq!(a.flag_usize("fanout", 0).unwrap(), 8);
        assert_eq!(a.flag_usize("batch", 1).unwrap(), 4);
        assert_eq!(a.flag_usize("reuse-cap", 0).unwrap(), 128);
        let spec = a.partition().unwrap().unwrap();
        assert_eq!((spec.shards, spec.threads), (2, 2));
        // '=' spelling interleaved with space spelling
        let a = parse("serve --fanout=8 --shards 4 --reuse-cap=64");
        assert_eq!(a.flag_usize("fanout", 0).unwrap(), 8);
        assert_eq!(a.flag_usize("reuse-cap", 0).unwrap(), 64);
        assert_eq!(a.partition().unwrap().unwrap().shards, 4);
    }

    #[test]
    fn serve_tuning_defaults_and_values() {
        // absent: all knobs inherit defaults
        let t = parse("serve").serve_tuning().unwrap();
        assert_eq!(t, ServeTuning::default());
        assert_eq!(t.priority_lanes, 1);
        assert_eq!(t.deadline_ms, None);
        // all four knobs bind, both spellings
        let t = parse(
            "serve --deadline-ms 50 --priority-lanes=2 \
             --admission-qps 500.5 --queue-cap=64",
        )
        .serve_tuning()
        .unwrap();
        assert_eq!(t.deadline_ms, Some(50));
        assert_eq!(t.priority_lanes, 2);
        assert_eq!(t.admission_qps, Some(500.5));
        assert_eq!(t.queue_cap, Some(64));
    }

    #[test]
    fn serve_tuning_rejects_degenerate_values() {
        assert!(parse("serve --deadline-ms 0").serve_tuning().is_err());
        assert!(parse("serve --priority-lanes=0").serve_tuning().is_err());
        assert!(parse("serve --admission-qps 0").serve_tuning().is_err());
        assert!(parse("serve --admission-qps=-5").serve_tuning().is_err());
        assert!(parse("serve --admission-qps nan").serve_tuning().is_err());
        assert!(parse("serve --queue-cap 0").serve_tuning().is_err());
        // non-numeric values are parse errors, not silent defaults
        assert!(parse("serve --deadline-ms nah").serve_tuning().is_err());
        assert!(parse("serve --queue-cap nah").serve_tuning().is_err());
        // bare switch (no value) rejected: "true" is not a number
        assert!(parse("serve --deadline-ms").serve_tuning().is_err());
    }

    #[test]
    fn serve_tuning_composes_with_serve_flags() {
        let a = parse(
            "serve --requests 64 --fanout 8 --batch 4 --reuse-cap 128 \
             --shards 2 --deadline-ms 20 --priority-lanes 2 \
             --admission-qps 1000 --queue-cap 256",
        );
        let t = a.serve_tuning().unwrap();
        assert_eq!(t.deadline_ms, Some(20));
        assert_eq!(t.priority_lanes, 2);
        assert_eq!(t.admission_qps, Some(1000.0));
        assert_eq!(t.queue_cap, Some(256));
        assert_eq!(a.partition().unwrap().unwrap().shards, 2);
        assert_eq!(a.flag_usize("fanout", 0).unwrap(), 8);
    }

    #[test]
    fn update_stream_flag_parsing() {
        // absent: static serving
        assert_eq!(parse("serve").update_stream().unwrap(), None);
        // present: spec with epoch-every defaulting to 1
        let spec = parse("serve --update-stream updates.txt").update_stream().unwrap().unwrap();
        assert_eq!(spec.path, "updates.txt");
        assert_eq!(spec.epoch_every, 1);
        let spec = parse("serve --update-stream=u.txt --epoch-every=4")
            .update_stream()
            .unwrap()
            .unwrap();
        assert_eq!(spec.path, "u.txt");
        assert_eq!(spec.epoch_every, 4);
        // degenerate values rejected at parse level
        assert!(parse("serve --update-stream u.txt --epoch-every 0").update_stream().is_err());
        assert!(parse("serve --update-stream u.txt --epoch-every nah").update_stream().is_err());
        // bare switch (no path) and orphaned --epoch-every rejected
        assert!(parse("serve --update-stream").update_stream().is_err());
        assert!(parse("serve --update-stream=").update_stream().is_err());
        assert!(parse("serve --epoch-every 2").update_stream().is_err());
        // composes with the rest of the serving incantation
        let a = parse(
            "serve --requests 64 --fanout 8 --shards 2 \
             --update-stream u.txt --epoch-every 8",
        );
        assert_eq!(a.update_stream().unwrap().unwrap().epoch_every, 8);
        assert_eq!(a.partition().unwrap().unwrap().shards, 2);
    }

    #[test]
    fn usage_mentions_serve_tuning_flags() {
        for flag in [
            "--deadline-ms",
            "--priority-lanes",
            "--admission-qps",
            "--queue-cap",
            "--update-stream",
            "--epoch-every",
            "--cluster",
            "--quantize",
        ] {
            assert!(USAGE.contains(flag), "usage missing {flag}");
        }
    }

    #[test]
    fn quantize_flag_parsing() {
        use crate::kernels::quant::QuantSpec;
        // absent: the default all-f32 path
        assert_eq!(parse("run").quantize().unwrap(), None);
        // both formats, both spellings
        assert_eq!(parse("run --quantize f16").quantize().unwrap(), Some(QuantSpec::F16));
        assert_eq!(parse("serve --quantize=int8").quantize().unwrap(), Some(QuantSpec::Int8));
        // unknown formats and the bare switch are rejected
        assert!(parse("run --quantize fp8").quantize().is_err());
        assert!(parse("run --quantize").quantize().is_err());
        assert!(parse("run --quantize=").quantize().is_err());
        // composes with the serving incantation
        let a = parse("serve --fanout 8 --reuse-cap 128 --quantize f16 --shards 2");
        assert_eq!(a.quantize().unwrap(), Some(QuantSpec::F16));
        assert_eq!(a.partition().unwrap().unwrap().shards, 2);
    }

    #[test]
    fn cluster_flag_parsing() {
        // absent: in-process execution
        assert!(parse("run").cluster().unwrap().is_none());
        // present in both spellings
        assert_eq!(parse("run --cluster 4").cluster().unwrap().unwrap().workers, 4);
        assert_eq!(parse("run --cluster=2").cluster().unwrap().unwrap().workers, 2);
        // zero is rejected in both spellings, like --shards
        assert!(parse("run --cluster 0").cluster().is_err());
        assert!(parse("run --cluster=0").cluster().is_err());
        // non-numeric rejected
        assert!(parse("run --cluster nah").cluster().is_err());
        // bare switch (no value) rejected: "true" is not a worker count
        assert!(parse("run --cluster").cluster().is_err());
        // composes with --shards: K shards over N workers
        let a = parse("run --cluster 2 --shards 4");
        assert_eq!(a.cluster().unwrap().unwrap().workers, 2);
        assert_eq!(a.partition().unwrap().unwrap().shards, 4);
    }

    #[test]
    fn usage_mentions_every_command() {
        for cmd in ["list", "run", "figure", "table", "timeline", "artifacts", "serve", "train"] {
            assert!(USAGE.contains(cmd), "usage missing {cmd}");
        }
    }

    #[test]
    fn train_config_defaults_and_values() {
        // absent flags inherit the TrainConfig defaults (fused on)
        let cfg = parse("train").train_config().unwrap();
        assert_eq!(cfg.epochs, crate::train::TrainConfig::default().epochs);
        assert!(cfg.fused);
        assert_eq!(cfg.optimizer, crate::train::OptimizerSpec::sgd(0.05));
        // every knob binds, both spellings
        let cfg = parse(
            "train --epochs 5 --lr=0.01 --optimizer adam --batch=32 \
             --seed 9 --classes=3 --no-fuse",
        )
        .train_config()
        .unwrap();
        assert_eq!(cfg.epochs, 5);
        assert_eq!(cfg.batch, 32);
        assert_eq!(cfg.optimizer, crate::train::OptimizerSpec::adam(0.01));
        assert_eq!(cfg.seed, 9);
        assert_eq!(cfg.classes, 3);
        assert!(!cfg.fused);
    }

    #[test]
    fn train_config_rejects_degenerate_values() {
        assert!(parse("train --epochs 0").train_config().is_err());
        assert!(parse("train --batch=0").train_config().is_err());
        assert!(parse("train --classes 1").train_config().is_err());
        assert!(parse("train --lr 0").train_config().is_err());
        assert!(parse("train --lr=-0.5").train_config().is_err());
        assert!(parse("train --lr nan").train_config().is_err());
        assert!(parse("train --optimizer lion").train_config().is_err());
        // non-numeric values are parse errors, not silent defaults
        assert!(parse("train --epochs nah").train_config().is_err());
        // bare switch (no value) rejected: "true" is not a number
        assert!(parse("train --lr").train_config().is_err());
    }

    #[test]
    fn train_config_composes_with_threads_and_shards() {
        let a = parse("train --epochs 2 --batch 16 --threads 4 --shards 2 --fanout 8");
        assert!(a.train_config().is_ok());
        assert_eq!(a.threads().unwrap(), Some(4));
        assert_eq!(a.partition().unwrap().unwrap().shards, 2);
        assert_eq!(a.flag_usize("fanout", 0).unwrap(), 8);
    }

    #[test]
    fn flags_only_parse() {
        let a = Args::parse(
            ["", "--scale", "ci"].iter().map(|s| s.to_string()),
        );
        assert_eq!(a.flag_str("scale", "paper"), "ci");
        assert!(a.positional.is_empty());
    }
}
