//! Distributed shard workers with a deterministic simulation cluster.
//!
//! The paper's scaling guideline — exploit inter-stage and
//! inter-partition parallelism — stops at one device. This module
//! promotes the owner-computes shards of [`crate::partition`] from
//! scoped threads to isolated *workers* behind a message fabric: a
//! coordinator places shards onto workers, ships stage requests and
//! collects stage responses over a length-prefixed [`wire`] codec, and
//! survives worker death by re-placing orphaned shards from its
//! retained [`crate::partition::Partition`] and replaying the in-flight
//! wave.
//!
//! The acceptance story is the test harness itself: with
//! [`SimTransport`] every delivery, fault and timeout is a function of
//! a seed and a [`crate::testutil::VirtualClock`], so any cluster
//! behavior — including which heartbeat drops and which worker gets
//! retired — reproduces exactly. The protocol is a stop-and-wait loop
//! ([`Cluster::stage_round`]): the coordinator retransmits request
//! frames with *unchanged* sequence numbers on a retry cadence,
//! receivers deduplicate by `(sender, seq)`, and responses are
//! accumulated by semantic key so a retransmitted attempt can never
//! double-deliver a logical message.

pub mod transport;
pub mod wire;

#[cfg(feature = "cluster-sockets")]
pub mod sockets;

pub use transport::{Endpoint, FaultSpec, SimTransport, Transport, TransportStats};
pub use wire::{Frame, Message, RowBlock, COORDINATOR};

#[cfg(feature = "cluster-sockets")]
pub use sockets::SocketTransport;

use crate::serving::clock::Nanos;
use crate::{Error, Result};

use std::collections::{BTreeMap, BTreeSet};
use std::time::Duration;

/// Cluster shape and protocol timing. All durations are interpreted on
/// the *transport* clock — virtual for the simulator — so none of them
/// introduce wall-clock dependence.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    /// Number of workers shards are placed onto.
    pub workers: usize,
    /// How often an idle-or-busy worker emits a heartbeat.
    pub heartbeat_interval: Duration,
    /// Silence threshold after which the coordinator retires a worker.
    pub heartbeat_timeout: Duration,
    /// Retransmit cadence for unacknowledged request frames; also the
    /// virtual-time step of one protocol iteration.
    pub retry_interval: Duration,
    /// Protocol-iteration bound per stage round (stall detector).
    pub max_rounds: usize,
    /// Seeded drop/dup/delay schedule applied by the transport.
    pub fault: FaultSpec,
    /// Deterministic kill schedule: worker `w` dies when wave `n`
    /// begins (`(n, w)` entries; waves count from 1).
    pub kill_at_wave: Vec<(u64, usize)>,
    /// Deterministic mid-wave kill schedule: worker `w` dies as soon as
    /// the transport's total sent-frame counter reaches `n`.
    pub kill_after_sends: Vec<(u64, usize)>,
}

impl ClusterSpec {
    /// Defaults: heartbeat every 50ms, retire after 200ms of silence,
    /// retransmit every 50ms, no faults, no scheduled kills.
    pub fn new(workers: usize) -> ClusterSpec {
        ClusterSpec {
            workers,
            heartbeat_interval: Duration::from_millis(50),
            heartbeat_timeout: Duration::from_millis(200),
            retry_interval: Duration::from_millis(50),
            max_rounds: 10_000,
            fault: FaultSpec::none(),
            kill_at_wave: Vec::new(),
            kill_after_sends: Vec::new(),
        }
    }

    /// Set the seeded fault schedule.
    pub fn with_fault(mut self, fault: FaultSpec) -> ClusterSpec {
        self.fault = fault;
        self
    }

    /// Schedule worker `worker` to die when wave `wave` begins.
    pub fn kill_at_wave(mut self, wave: u64, worker: usize) -> ClusterSpec {
        self.kill_at_wave.push((wave, worker));
        self
    }

    /// Schedule worker `worker` to die once `sends` total frames have
    /// been sent — a deterministic way to kill *mid*-wave.
    pub fn kill_after_sends(mut self, sends: u64, worker: usize) -> ClusterSpec {
        self.kill_after_sends.push((sends, worker));
        self
    }
}

/// Coordinator-side view of one worker.
#[derive(Debug, Clone)]
struct WorkerState {
    /// Whether the simulated process is running (kills clear this; the
    /// coordinator cannot observe it directly — only via silence).
    alive: bool,
    /// Retired by the coordinator: shards re-placed, never reused.
    retired: bool,
    /// Draining: stays live for current shards but receives no
    /// re-placements.
    draining: bool,
    /// Transport-clock time of the last frame received from it.
    last_seen: Nanos,
    /// Last heartbeat emission time (worker-side state).
    last_heartbeat: Option<Nanos>,
}

/// Counters describing cluster-level events; all deterministic under
/// [`SimTransport`], so tests pin them exactly.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ClusterStats {
    /// Waves started via [`Cluster::begin_wave`].
    pub waves: u64,
    /// Shards re-placed after worker retirement.
    pub replaced_shards: u64,
    /// Workers retired (heartbeat timeout or explicit).
    pub retired_workers: u64,
    /// Heartbeat frames the coordinator accepted.
    pub heartbeats: u64,
    /// Request retransmission bursts.
    pub retransmits: u64,
}

/// The coordinator plus its (simulated, in-process) workers.
///
/// One `Cluster` owns the placement map, the failure detector and the
/// wire protocol; the *compute* a worker performs is supplied per stage
/// by the caller as a closure (see [`Cluster::stage_round`]), which
/// keeps this module free of any dependency on the execution layer.
pub struct Cluster {
    spec: ClusterSpec,
    transport: Box<dyn Transport>,
    /// shard → owning worker.
    placement: Vec<usize>,
    workers: Vec<WorkerState>,
    next_seq: u64,
    /// Coordinator-side dedup of `(from, seq)`.
    coord_seen: BTreeSet<(u32, u64)>,
    /// Per-worker dedup of `(from, seq)`.
    worker_seen: Vec<BTreeSet<(u32, u64)>>,
    stats: ClusterStats,
    wave: u64,
    /// Shards re-placed since the last [`Cluster::take_replacements`]
    /// call — the session drains this to rebuild reuse-cache lanes.
    replacements: Vec<usize>,
}

impl Cluster {
    /// Place `num_shards` shards round-robin onto the spec's workers
    /// and announce the placement with `Place` control frames.
    pub fn new(
        spec: ClusterSpec,
        num_shards: usize,
        transport: Box<dyn Transport>,
    ) -> Result<Cluster> {
        if spec.workers == 0 {
            return Err(Error::config("cluster: at least one worker required"));
        }
        if num_shards == 0 {
            return Err(Error::config("cluster: at least one shard required"));
        }
        let now = transport.now();
        let mut cluster = Cluster {
            placement: (0..num_shards).map(|s| s % spec.workers).collect(),
            workers: vec![
                WorkerState {
                    alive: true,
                    retired: false,
                    draining: false,
                    last_seen: now,
                    last_heartbeat: None,
                };
                spec.workers
            ],
            worker_seen: vec![BTreeSet::new(); spec.workers],
            transport,
            next_seq: 0,
            coord_seen: BTreeSet::new(),
            stats: ClusterStats::default(),
            wave: 0,
            replacements: Vec::new(),
            spec,
        };
        for s in 0..num_shards {
            let w = cluster.placement[s];
            cluster.send_control(
                Endpoint::Worker(w as u32),
                Message::Place { shard: s as u32, worker: w as u32 },
            )?;
        }
        Ok(cluster)
    }

    /// The spec this cluster was built from.
    pub fn spec(&self) -> &ClusterSpec {
        &self.spec
    }

    /// Current shard → worker placement.
    pub fn placement(&self) -> &[usize] {
        &self.placement
    }

    /// Owner of `shard`.
    pub fn worker_for(&self, shard: usize) -> usize {
        self.placement[shard]
    }

    /// Workers that are alive and not retired, ascending.
    pub fn live_workers(&self) -> Vec<usize> {
        (0..self.workers.len())
            .filter(|&w| self.workers[w].alive && !self.workers[w].retired)
            .collect()
    }

    /// Workers not yet retired (the coordinator's optimistic view —
    /// it cannot see `alive` directly).
    pub fn active_workers(&self) -> Vec<usize> {
        (0..self.workers.len()).filter(|&w| !self.workers[w].retired).collect()
    }

    /// Whether `worker` is alive and not retired.
    pub fn is_live(&self, worker: usize) -> bool {
        self.workers.get(worker).map(|w| w.alive && !w.retired).unwrap_or(false)
    }

    /// Cluster event counters.
    pub fn stats(&self) -> ClusterStats {
        self.stats
    }

    /// Transport delivery counters (frames/bytes; dup/drop/delay).
    pub fn transport_stats(&self) -> TransportStats {
        self.transport.stats()
    }

    /// Current wave number (0 before the first [`Cluster::begin_wave`]).
    pub fn wave(&self) -> u64 {
        self.wave
    }

    /// Shards re-placed since the last call; the session layer uses
    /// this to rebuild the affected reuse-cache lanes cold.
    pub fn take_replacements(&mut self) -> Vec<usize> {
        std::mem::take(&mut self.replacements)
    }

    fn seq(&mut self) -> u64 {
        let s = self.next_seq;
        self.next_seq += 1;
        s
    }

    fn send_control(&mut self, to: Endpoint, msg: Message) -> Result<()> {
        let frame = Frame { seq: self.seq(), from: COORDINATOR, msg };
        self.transport.send(to, frame)
    }

    /// Start a wave: bump the counter, apply the wave-indexed kill
    /// schedule, then broadcast `Epoch` to every non-retired worker.
    pub fn begin_wave(&mut self) -> Result<u64> {
        self.wave += 1;
        self.stats.waves += 1;
        let kills: Vec<usize> = self
            .spec
            .kill_at_wave
            .iter()
            .filter(|&&(n, _)| n == self.wave)
            .map(|&(_, w)| w)
            .collect();
        for w in kills {
            self.kill_worker(w);
        }
        for w in self.active_workers() {
            self.send_control(Endpoint::Worker(w as u32), Message::Epoch { epoch: self.wave })?;
        }
        Ok(self.wave)
    }

    /// Simulate a worker process dying: it stops polling, computing and
    /// heartbeating. The coordinator only learns of this via silence.
    pub fn kill_worker(&mut self, worker: usize) {
        if let Some(w) = self.workers.get_mut(worker) {
            w.alive = false;
        }
    }

    /// Mark a worker as draining: it keeps serving its current shards
    /// but is skipped when orphans need a new home.
    pub fn drain_worker(&mut self, worker: usize) -> Result<()> {
        if let Some(w) = self.workers.get_mut(worker) {
            w.draining = true;
        }
        self.send_control(Endpoint::Worker(worker as u32), Message::Drain { worker: worker as u32 })
    }

    /// Retire a worker and re-place its shards onto the live worker
    /// with the fewest shards (ties → lowest id). Returns the orphaned
    /// shards (now re-placed). Refuses to retire the last non-retired
    /// worker — there would be nowhere to re-place.
    pub fn retire_worker(&mut self, worker: usize) -> Result<Vec<usize>> {
        if worker >= self.workers.len() {
            return Err(Error::config(format!("cluster: unknown worker {worker}")));
        }
        if self.workers[worker].retired {
            return Ok(Vec::new());
        }
        if self.active_workers().len() <= 1 {
            return Err(Error::Runtime(format!(
                "cluster: cannot retire worker {worker}: it is the last one standing"
            )));
        }
        self.workers[worker].retired = true;
        self.workers[worker].alive = false;
        self.stats.retired_workers += 1;
        self.send_control(
            Endpoint::Worker(worker as u32),
            Message::Retire { worker: worker as u32 },
        )?;

        let orphans: Vec<usize> =
            (0..self.placement.len()).filter(|&s| self.placement[s] == worker).collect();
        for &s in &orphans {
            let target = self.replacement_target()?;
            self.placement[s] = target;
            self.replacements.push(s);
            self.stats.replaced_shards += 1;
            self.send_control(
                Endpoint::Worker(target as u32),
                Message::Place { shard: s as u32, worker: target as u32 },
            )?;
        }
        Ok(orphans)
    }

    /// Least-loaded non-retired, non-draining worker (ties → lowest
    /// id); falls back to draining workers rather than failing.
    fn replacement_target(&self) -> Result<usize> {
        let candidates: Vec<usize> = {
            let fresh: Vec<usize> = self
                .active_workers()
                .into_iter()
                .filter(|&w| !self.workers[w].draining)
                .collect();
            if fresh.is_empty() { self.active_workers() } else { fresh }
        };
        candidates
            .into_iter()
            .map(|w| (self.placement.iter().filter(|&&o| o == w).count(), w))
            .min()
            .map(|(_, w)| w)
            .ok_or_else(|| Error::Runtime("cluster: no live worker to re-place onto".into()))
    }

    /// Run `iters` idle protocol iterations: heartbeats flow, the
    /// failure detector runs, virtual time advances — but no stage
    /// requests are outstanding. Returns workers retired while idle.
    pub fn run_idle(&mut self, iters: usize) -> Result<Vec<usize>> {
        let mut retired = Vec::new();
        for _ in 0..iters {
            self.pump_heartbeats()?;
            self.coordinator_drain_control();
            retired.extend(self.detect_failures()?);
            self.transport.advance(self.spec.retry_interval);
        }
        Ok(retired)
    }

    /// Worker-side heartbeat emission (alive workers only; subject to
    /// transport faults like any other frame).
    fn pump_heartbeats(&mut self) -> Result<()> {
        let now = self.transport.now();
        let interval = self.spec.heartbeat_interval.as_nanos() as Nanos;
        for w in 0..self.workers.len() {
            if !self.workers[w].alive || self.workers[w].retired {
                continue;
            }
            let due = match self.workers[w].last_heartbeat {
                None => true,
                Some(t) => now.saturating_sub(t) >= interval,
            };
            if due {
                self.workers[w].last_heartbeat = Some(now);
                let frame = Frame {
                    seq: self.seq(),
                    from: w as u32,
                    msg: Message::Heartbeat { worker: w as u32 },
                };
                self.transport.send(Endpoint::Coordinator, frame)?;
            }
        }
        Ok(())
    }

    /// Drain the coordinator inbox outside a stage round: only control
    /// frames (heartbeats) are expected; anything else is stale data
    /// from a finished round and is deduped then ignored.
    fn coordinator_drain_control(&mut self) {
        let now = self.transport.now();
        for frame in self.transport.poll(Endpoint::Coordinator) {
            if !self.coord_seen.insert((frame.from, frame.seq)) {
                continue;
            }
            if let Some(ws) = self.workers.get_mut(frame.from as usize) {
                ws.last_seen = now;
            }
            if matches!(frame.msg, Message::Heartbeat { .. }) {
                self.stats.heartbeats += 1;
            }
        }
    }

    /// Retire every non-retired worker silent past the timeout (except
    /// the last one standing). Returns the workers retired.
    fn detect_failures(&mut self) -> Result<Vec<usize>> {
        let now = self.transport.now();
        let timeout = self.spec.heartbeat_timeout.as_nanos() as Nanos;
        let mut retired = Vec::new();
        for w in 0..self.workers.len() {
            if self.workers[w].retired {
                continue;
            }
            if now.saturating_sub(self.workers[w].last_seen) > timeout {
                if self.active_workers().len() <= 1 {
                    continue; // nowhere to re-place; keep waiting
                }
                self.retire_worker(w)?;
                retired.push(w);
            }
        }
        Ok(retired)
    }

    fn apply_send_kills(&mut self) {
        let sent = self.transport.stats().sent;
        let due: Vec<usize> = self
            .spec
            .kill_after_sends
            .iter()
            .filter(|&&(n, w)| sent >= n && self.workers[w].alive && !self.workers[w].retired)
            .map(|&(_, w)| w)
            .collect();
        for w in due {
            self.kill_worker(w);
        }
    }

    /// Run one stop-and-wait stage round over all shards.
    ///
    /// * `request(s)` yields the request messages for shard `s` (each
    ///   must carry `shard == s`); an empty request skips the shard.
    /// * `respond(s, msgs)` is the *worker-side compute*: invoked once
    ///   per placement attempt when the full request has arrived, with
    ///   the request messages in semantic-key order. Re-placement
    ///   replays the wave by invoking it again on the new owner, so it
    ///   must be deterministic and re-runnable.
    /// * `expected(s)` is how many response messages (distinct semantic
    ///   keys) the coordinator must collect for shard `s`.
    ///
    /// Returns each shard's responses in semantic-key order. The loop
    /// retransmits stale requests with unchanged seqs, dedups receipts
    /// by `(sender, seq)`, re-sends cached responses when a duplicate
    /// request signals a lost reply, retires silent workers and replays
    /// their shards — all in virtual time, bounded by
    /// [`ClusterSpec::max_rounds`].
    pub fn stage_round(
        &mut self,
        num_shards: usize,
        request: &mut dyn FnMut(usize) -> Result<Vec<Message>>,
        respond: &mut dyn FnMut(usize, &[Message]) -> Result<Vec<Message>>,
        expected: &dyn Fn(usize) -> usize,
    ) -> Result<Vec<Vec<Message>>> {
        if num_shards != self.placement.len() {
            return Err(Error::shape(format!(
                "cluster: stage round over {num_shards} shards but {} placed",
                self.placement.len()
            )));
        }
        // Coordinator-side per-shard state.
        let mut req_frames: Vec<Vec<Frame>> = Vec::with_capacity(num_shards);
        for s in 0..num_shards {
            let msgs = request(s)?;
            let mut frames = Vec::with_capacity(msgs.len());
            for msg in msgs {
                if msg.shard() != Some(s as u32) {
                    return Err(Error::config(format!(
                        "cluster: request message for shard {s} carries shard {:?}",
                        msg.shard()
                    )));
                }
                frames.push(Frame { seq: self.seq(), from: COORDINATOR, msg });
            }
            req_frames.push(frames);
        }
        let want: Vec<usize> = (0..num_shards).map(|s| expected(s)).collect();
        let mut got: Vec<BTreeMap<(u8, u64), Message>> = vec![BTreeMap::new(); num_shards];
        let mut last_tx: Vec<Option<Nanos>> = vec![None; num_shards];
        // Worker-side per-shard state (reset when a shard is re-placed).
        let mut inbox: Vec<BTreeMap<(u8, u64), Message>> = vec![BTreeMap::new(); num_shards];
        let mut resp_frames: Vec<Option<Vec<Frame>>> = vec![None; num_shards];

        let retry = self.spec.retry_interval.as_nanos() as Nanos;
        let complete = |got: &[BTreeMap<(u8, u64), Message>], want: &[usize], s: usize| {
            req_frames[s].is_empty() || got[s].len() >= want[s]
        };

        for _round in 0..self.spec.max_rounds {
            self.apply_send_kills();
            let now = self.transport.now();

            // Coordinator TX: first send or retransmit stale requests.
            for s in 0..num_shards {
                if complete(&got, &want, s) {
                    continue;
                }
                let due = match last_tx[s] {
                    None => true,
                    Some(t) => now.saturating_sub(t) >= retry,
                };
                if due {
                    if last_tx[s].is_some() {
                        self.stats.retransmits += 1;
                    }
                    last_tx[s] = Some(now);
                    let owner = self.placement[s] as u32;
                    for frame in req_frames[s].clone() {
                        self.transport.send(Endpoint::Worker(owner), frame)?;
                    }
                }
            }

            self.pump_heartbeats()?;

            // Worker RX + compute.
            for w in 0..self.workers.len() {
                if !self.workers[w].alive || self.workers[w].retired {
                    continue;
                }
                for frame in self.transport.poll(Endpoint::Worker(w as u32)) {
                    let fresh = self.worker_seen[w].insert((frame.from, frame.seq));
                    let Some(shard) = frame.msg.shard() else {
                        continue; // control/broadcast frame: deduped, no inbox
                    };
                    let s = shard as usize;
                    if s >= num_shards {
                        continue;
                    }
                    if fresh {
                        inbox[s].insert(frame.msg.semantic_key(), frame.msg);
                    } else if self.placement[s] == w {
                        // Duplicate request: our reply was likely lost —
                        // re-send the cached response frames verbatim.
                        if let Some(cached) = &resp_frames[s] {
                            for f in cached.clone() {
                                self.transport.send(Endpoint::Coordinator, f)?;
                            }
                        }
                    }
                }
                // Compute any owned shard whose request is complete.
                for s in 0..num_shards {
                    if self.placement[s] != w
                        || resp_frames[s].is_some()
                        || req_frames[s].is_empty()
                        || inbox[s].len() < req_frames[s].len()
                        || complete(&got, &want, s)
                    {
                        continue;
                    }
                    let msgs: Vec<Message> = inbox[s].values().cloned().collect();
                    let replies = respond(s, &msgs)?;
                    let mut frames = Vec::with_capacity(replies.len());
                    for msg in replies {
                        frames.push(Frame { seq: self.seq(), from: w as u32, msg });
                    }
                    for f in &frames {
                        self.transport.send(Endpoint::Coordinator, f.clone())?;
                    }
                    resp_frames[s] = Some(frames);
                }
            }

            // Coordinator RX: collect responses by semantic key.
            let now = self.transport.now();
            for frame in self.transport.poll(Endpoint::Coordinator) {
                if !self.coord_seen.insert((frame.from, frame.seq)) {
                    continue;
                }
                if let Some(ws) = self.workers.get_mut(frame.from as usize) {
                    ws.last_seen = now;
                }
                match &frame.msg {
                    Message::Heartbeat { .. } => self.stats.heartbeats += 1,
                    _ => {
                        if let Some(shard) = frame.msg.shard() {
                            let s = shard as usize;
                            if s < num_shards {
                                got[s].insert(frame.msg.semantic_key(), frame.msg);
                            }
                        }
                    }
                }
            }

            if (0..num_shards).all(|s| complete(&got, &want, s)) {
                return Ok(got.into_iter().map(|m| m.into_values().collect()).collect());
            }

            // Failure detection: silent workers retire, their shards
            // re-place, and the in-flight wave replays on the new owner:
            // protocol state for a moved shard resets so the new owner
            // starts cold and the coordinator resends immediately.
            let before = self.placement.clone();
            if !self.detect_failures()?.is_empty() {
                for s in 0..num_shards {
                    if before[s] != self.placement[s] && !complete(&got, &want, s) {
                        inbox[s].clear();
                        resp_frames[s] = None;
                        last_tx[s] = None;
                    }
                }
            }

            self.transport.advance(self.spec.retry_interval);
        }
        Err(Error::Runtime(format!(
            "cluster: stage round stalled after {} iterations (wave {}); live workers: {:?}",
            self.spec.max_rounds,
            self.wave,
            self.live_workers()
        )))
    }
}

impl std::fmt::Debug for Cluster {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Cluster")
            .field("workers", &self.spec.workers)
            .field("placement", &self.placement)
            .field("wave", &self.wave)
            .field("stats", &self.stats)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim_cluster(workers: usize, shards: usize, fault: FaultSpec) -> Cluster {
        let mut spec = ClusterSpec::new(workers);
        spec.fault = fault.clone();
        Cluster::new(spec, shards, Box::new(SimTransport::faulty(fault))).unwrap()
    }

    /// An echo stage: request names the shard's ids, the worker doubles
    /// them into a response block.
    fn echo_round(cluster: &mut Cluster, shards: usize) -> Result<Vec<Vec<Message>>> {
        cluster.stage_round(
            shards,
            &mut |s| {
                Ok(vec![Message::BatchRows {
                    shard: s as u32,
                    block: RowBlock::ids_only(vec![s as u32, s as u32 + 10]),
                }])
            },
            &mut |s, msgs| {
                let Message::BatchRows { block, .. } = &msgs[0] else { panic!("request shape") };
                let data: Vec<f32> = block.ids.iter().map(|&i| i as f32 * 2.0).collect();
                Ok(vec![Message::BatchRows {
                    shard: s as u32,
                    block: RowBlock { ids: block.ids.clone(), cols: 1, data },
                }])
            },
            &|_| 1,
        )
    }

    #[test]
    fn round_robin_initial_placement() {
        let c = sim_cluster(2, 5, FaultSpec::none());
        assert_eq!(c.placement(), &[0, 1, 0, 1, 0]);
        assert_eq!(c.live_workers(), vec![0, 1]);
    }

    #[test]
    fn zero_workers_rejected() {
        assert!(Cluster::new(ClusterSpec::new(0), 1, Box::new(SimTransport::new())).is_err());
    }

    #[test]
    fn echo_round_clean_transport() {
        let mut c = sim_cluster(2, 4, FaultSpec::none());
        c.begin_wave().unwrap();
        let out = echo_round(&mut c, 4).unwrap();
        for (s, msgs) in out.iter().enumerate() {
            assert_eq!(msgs.len(), 1);
            let Message::BatchRows { block, .. } = &msgs[0] else { panic!() };
            assert_eq!(block.data, vec![s as f32 * 2.0, (s + 10) as f32 * 2.0]);
        }
        assert_eq!(c.stats().retransmits, 0, "clean wire needs no retries");
    }

    #[test]
    fn echo_round_survives_chaos_and_reproduces() {
        let run = |seed: u64| {
            let mut c = sim_cluster(2, 4, FaultSpec::chaos(seed));
            c.begin_wave().unwrap();
            let out = echo_round(&mut c, 4).unwrap();
            (out, c.stats(), c.transport_stats())
        };
        let (o1, s1, t1) = run(7);
        let (o2, s2, t2) = run(7);
        assert_eq!(o1, o2, "same seed → byte-identical responses");
        assert_eq!(s1, s2, "same seed → identical cluster events");
        assert_eq!(t1, t2, "same seed → identical wire history");
        assert!(t1.dropped > 0 || t1.duplicated > 0 || t1.delayed > 0, "chaos was live: {t1:?}");
    }

    #[test]
    fn empty_requests_skip_shards() {
        let mut c = sim_cluster(2, 3, FaultSpec::none());
        let out = c
            .stage_round(
                3,
                &mut |s| {
                    if s == 1 {
                        Ok(vec![Message::BatchRows {
                            shard: 1,
                            block: RowBlock::ids_only(vec![9]),
                        }])
                    } else {
                        Ok(Vec::new())
                    }
                },
                &mut |s, _| {
                    assert_eq!(s, 1, "only the requested shard computes");
                    Ok(vec![Message::BatchRows { shard: 1, block: RowBlock::empty() }])
                },
                &|s| usize::from(s == 1),
            )
            .unwrap();
        assert!(out[0].is_empty() && out[2].is_empty());
        assert_eq!(out[1].len(), 1);
    }

    #[test]
    fn mid_round_kill_replaces_and_replays() {
        // Kill worker 1 after the very first frames go out: its shards
        // re-place onto worker 0 and the round still completes.
        let mut spec = ClusterSpec::new(2);
        spec.kill_after_sends.push((3, 1));
        let mut c = Cluster::new(spec, 4, Box::new(SimTransport::new())).unwrap();
        c.begin_wave().unwrap();
        let mut computed: Vec<usize> = Vec::new();
        let out = c
            .stage_round(
                4,
                &mut |s| {
                    Ok(vec![Message::BatchRows {
                        shard: s as u32,
                        block: RowBlock::ids_only(vec![s as u32]),
                    }])
                },
                &mut |s, _| {
                    computed.push(s);
                    Ok(vec![Message::BatchRows { shard: s as u32, block: RowBlock::empty() }])
                },
                &|_| 1,
            )
            .unwrap();
        assert_eq!(out.len(), 4);
        assert_eq!(c.stats().retired_workers, 1);
        assert_eq!(c.stats().replaced_shards, 2, "shards 1 and 3 re-placed");
        assert_eq!(c.placement(), &[0, 0, 0, 0], "all shards on the survivor");
        let moved = c.take_replacements();
        assert_eq!(moved, vec![1, 3]);
        assert!(c.take_replacements().is_empty(), "drained");
    }

    #[test]
    fn replacement_prefers_least_loaded_and_skips_draining() {
        let mut c = sim_cluster(3, 6, FaultSpec::none());
        assert_eq!(c.placement(), &[0, 1, 2, 0, 1, 2]);
        c.drain_worker(0).unwrap();
        c.kill_worker(1);
        let orphans = c.retire_worker(1).unwrap();
        assert_eq!(orphans, vec![1, 4]);
        // worker 0 is draining → both orphans land on worker 2
        assert_eq!(c.placement(), &[0, 2, 2, 0, 2, 2]);
    }

    #[test]
    fn last_worker_cannot_retire() {
        let mut c = sim_cluster(2, 2, FaultSpec::none());
        c.retire_worker(0).unwrap();
        let err = c.retire_worker(1).unwrap_err();
        assert!(err.to_string().contains("last one standing"), "{err}");
    }

    #[test]
    fn idle_silence_retires_dead_worker() {
        let mut c = sim_cluster(2, 2, FaultSpec::none());
        c.kill_worker(1);
        // timeout 200ms / 50ms per idle iteration → retired within 10
        let retired = c.run_idle(10).unwrap();
        assert_eq!(retired, vec![1]);
        assert_eq!(c.placement(), &[0, 0]);
        assert!(c.stats().heartbeats > 0, "survivor kept heartbeating");
    }

    #[test]
    fn all_workers_dead_stalls_with_typed_error() {
        let mut spec = ClusterSpec::new(1);
        spec.max_rounds = 8;
        let mut c = Cluster::new(spec, 1, Box::new(SimTransport::new())).unwrap();
        c.kill_worker(0);
        let err = echo_round(&mut c, 1).unwrap_err();
        assert!(err.to_string().contains("stalled"), "{err}");
    }

    #[test]
    fn wave_indexed_kills_fire_on_begin_wave() {
        let spec = ClusterSpec::new(2).kill_at_wave(2, 0);
        let mut c = Cluster::new(spec, 2, Box::new(SimTransport::new())).unwrap();
        c.begin_wave().unwrap();
        assert!(c.is_live(0), "wave 1: not yet");
        c.begin_wave().unwrap();
        assert!(!c.is_live(0), "wave 2: killed");
        // the next round detects the silence and re-places shard 0
        let out = echo_round(&mut c, 2).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(c.placement(), &[1, 1]);
    }
}
