//! Real socket-pair transport (feature `cluster-sockets`).
//!
//! One `UnixStream::pair()` per worker: the coordinator holds one end,
//! the worker the other, and every frame genuinely traverses the kernel
//! as the length-prefixed byte stream from [`super::wire`]. Compute
//! still runs in-process (the protocol driver is the same
//! single-threaded loop as the simulator), so this transport isolates
//! exactly one variable versus [`super::SimTransport`]: the wire.
//!
//! Writes are staged through a userspace buffer and flushed
//! opportunistically on every `send`/`poll`, so a full kernel socket
//! buffer can never deadlock the single-threaded driver. Time is a
//! logical counter bumped by `advance` — no wall-clock dependence, so
//! heartbeat/timeout behavior matches the simulator exactly.

use crate::serving::clock::Nanos;
use crate::{Error, Result};

use std::io::{Read, Write};
use std::os::unix::net::UnixStream;
use std::sync::Mutex;
use std::time::Duration;

use super::transport::{Endpoint, Transport, TransportStats};
use super::wire::{decode_frame, encode_frame, Frame, MAX_FRAME_LEN};

struct Io {
    stream: UnixStream,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
}

impl Io {
    fn new(stream: UnixStream) -> Result<Io> {
        stream.set_nonblocking(true)?;
        Ok(Io { stream, rbuf: Vec::new(), wbuf: Vec::new() })
    }

    /// Queue encoded bytes and push as much as the kernel will take.
    fn send(&mut self, bytes: &[u8]) -> Result<()> {
        self.wbuf.extend_from_slice(bytes);
        self.flush()
    }

    fn flush(&mut self) -> Result<()> {
        while !self.wbuf.is_empty() {
            match self.stream.write(&self.wbuf) {
                Ok(0) => return Err(Error::config("socket transport: peer closed")),
                Ok(n) => {
                    self.wbuf.drain(..n);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => return Err(e.into()),
            }
        }
        Ok(())
    }

    /// Drain the kernel receive buffer, then peel complete frames off
    /// the reassembly buffer.
    fn recv(&mut self) -> Result<Vec<Frame>> {
        let mut chunk = [0u8; 16 * 1024];
        loop {
            match self.stream.read(&mut chunk) {
                Ok(0) => break,
                Ok(n) => self.rbuf.extend_from_slice(&chunk[..n]),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => return Err(e.into()),
            }
        }
        let mut frames = Vec::new();
        loop {
            if self.rbuf.len() < 4 {
                break;
            }
            let len = u32::from_le_bytes(self.rbuf[..4].try_into().expect("4 bytes")) as usize;
            if len > MAX_FRAME_LEN {
                return Err(Error::config(format!(
                    "socket transport: frame length {len} exceeds cap"
                )));
            }
            if self.rbuf.len() < 4 + len {
                break;
            }
            frames.push(decode_frame(&self.rbuf[4..4 + len])?);
            self.rbuf.drain(..4 + len);
        }
        Ok(frames)
    }
}

/// Socket-pair fabric: the "real wire" implementation behind
/// `cli run --cluster N` when built with `--features cluster-sockets`.
pub struct SocketTransport {
    /// Coordinator-side stream per worker (index = worker id).
    coord_side: Vec<Mutex<Io>>,
    /// Worker-side stream per worker.
    worker_side: Vec<Mutex<Io>>,
    now: Mutex<Nanos>,
    stats: Mutex<TransportStats>,
}

impl SocketTransport {
    /// Open one socket pair per worker.
    pub fn new(workers: usize) -> Result<SocketTransport> {
        let mut coord_side = Vec::with_capacity(workers);
        let mut worker_side = Vec::with_capacity(workers);
        for _ in 0..workers {
            let (a, b) = UnixStream::pair()?;
            coord_side.push(Mutex::new(Io::new(a)?));
            worker_side.push(Mutex::new(Io::new(b)?));
        }
        Ok(SocketTransport {
            coord_side,
            worker_side,
            now: Mutex::new(0),
            stats: Mutex::new(TransportStats::default()),
        })
    }

    fn io_for(&self, to: Endpoint, from: u32) -> Result<&Mutex<Io>> {
        match to {
            // Coordinator inbox: write on the sender's worker-side end.
            Endpoint::Coordinator => self
                .worker_side
                .get(from as usize)
                .ok_or_else(|| {
                    Error::config(format!("socket transport: unknown sender worker {from}"))
                }),
            // Worker inbox: write on the coordinator-side end.
            Endpoint::Worker(w) => self
                .coord_side
                .get(w as usize)
                .ok_or_else(|| Error::config(format!("socket transport: unknown worker {w}"))),
        }
    }
}

impl Transport for SocketTransport {
    fn send(&self, to: Endpoint, frame: Frame) -> Result<()> {
        let bytes = encode_frame(&frame);
        {
            let mut stats = self.stats.lock().unwrap_or_else(|e| e.into_inner());
            stats.sent += 1;
            stats.delivered += 1;
            stats.bytes += bytes.len() as u64;
        }
        self.io_for(to, frame.from)?
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .send(&bytes)
    }

    fn poll(&self, at: Endpoint) -> Vec<Frame> {
        // Opportunistically drain every pending userspace write first so
        // a full kernel buffer always makes progress.
        for io in self.coord_side.iter().chain(self.worker_side.iter()) {
            let _ = io.lock().unwrap_or_else(|e| e.into_inner()).flush();
        }
        let mut frames = Vec::new();
        match at {
            Endpoint::Coordinator => {
                for io in &self.coord_side {
                    if let Ok(got) = io.lock().unwrap_or_else(|e| e.into_inner()).recv() {
                        frames.extend(got);
                    }
                }
            }
            Endpoint::Worker(w) => {
                if let Some(io) = self.worker_side.get(w as usize) {
                    if let Ok(got) = io.lock().unwrap_or_else(|e| e.into_inner()).recv() {
                        frames.extend(got);
                    }
                }
            }
        }
        frames
    }

    fn now(&self) -> Nanos {
        *self.now.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn advance(&self, by: Duration) {
        *self.now.lock().unwrap_or_else(|e| e.into_inner()) += by.as_nanos() as Nanos;
    }

    fn stats(&self) -> TransportStats {
        *self.stats.lock().unwrap_or_else(|e| e.into_inner())
    }
}

#[cfg(test)]
mod tests {
    use super::super::wire::{Message, RowBlock};
    use super::*;

    #[test]
    fn frames_cross_the_kernel_both_ways() {
        let t = SocketTransport::new(2).unwrap();
        let down = Frame {
            seq: 1,
            from: super::super::wire::COORDINATOR,
            msg: Message::Epoch { epoch: 7 },
        };
        let up = Frame {
            seq: 2,
            from: 1,
            msg: Message::FpRows {
                shard: 1,
                ty: 0,
                block: RowBlock { ids: vec![4, 8], cols: 1, data: vec![0.5, -1.5] },
            },
        };
        t.send(Endpoint::Worker(1), down.clone()).unwrap();
        t.send(Endpoint::Coordinator, up.clone()).unwrap();
        assert!(t.poll(Endpoint::Worker(0)).is_empty(), "per-worker isolation");
        assert_eq!(t.poll(Endpoint::Worker(1)), vec![down]);
        assert_eq!(t.poll(Endpoint::Coordinator), vec![up]);
        assert_eq!(t.stats().sent, 2);
        assert!(t.stats().bytes > 0);
    }

    #[test]
    fn large_frames_survive_partial_writes() {
        // Bigger than the kernel socket buffer: forces the userspace
        // write buffer + reassembly path.
        let t = SocketTransport::new(1).unwrap();
        let rows = 3000usize;
        let cols = 64u32;
        let block = RowBlock {
            ids: (0..rows as u32).collect(),
            cols,
            data: (0..rows * cols as usize).map(|i| i as f32).collect(),
        };
        let frame = Frame {
            seq: 9,
            from: super::super::wire::COORDINATOR,
            msg: Message::Halo { shard: 0, ty: 0, block },
        };
        t.send(Endpoint::Worker(0), frame.clone()).unwrap();
        // Repeated polls flush pending writes and reassemble.
        let mut got = Vec::new();
        for _ in 0..64 {
            got.extend(t.poll(Endpoint::Worker(0)));
            if !got.is_empty() {
                break;
            }
        }
        assert_eq!(got, vec![frame]);
    }

    #[test]
    fn logical_clock_only_moves_on_advance() {
        let t = SocketTransport::new(1).unwrap();
        assert_eq!(t.now(), 0);
        t.advance(Duration::from_millis(5));
        assert_eq!(t.now(), 5_000_000);
    }
}
