//! Message transports for the cluster: the object-safe [`Transport`]
//! trait plus the deterministic in-process [`SimTransport`].
//!
//! `SimTransport` is the acceptance story of the whole cluster: it is
//! driven by a [`crate::testutil::VirtualClock`] (time only moves when
//! the protocol loop calls [`Transport::advance`]), delivers frames in
//! `(due, send-order)` order, and injects faults — drop, duplicate,
//! delay — from a seeded PCG schedule. Because the protocol driver is
//! single-threaded, the fault RNG is consulted in a deterministic
//! order, so *every* cluster behavior (including which heartbeat gets
//! dropped and which worker gets spuriously retired) reproduces exactly
//! from `FaultSpec::seed`.

use crate::serving::clock::{Clock, Nanos};
use crate::testutil::VirtualClock;
use crate::util::Pcg32;
use crate::Result;

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::wire::Frame;

/// A message destination: the coordinator or one worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Endpoint {
    /// The coordinator's inbox.
    Coordinator,
    /// Worker `w`'s inbox.
    Worker(u32),
}

/// Seeded fault-injection schedule for [`SimTransport`]. Each `send`
/// draws from a PCG32 stream in order: drop? duplicate? delay? — so a
/// given seed fixes the fate of every frame in a run.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    /// RNG seed for the fault schedule.
    pub seed: u64,
    /// Probability a frame is silently dropped.
    pub drop: f64,
    /// Probability a frame is delivered twice.
    pub dup: f64,
    /// Probability a frame's delivery is delayed by `delay_ns`.
    pub delay: f64,
    /// Virtual delay applied to delayed frames, in nanoseconds.
    pub delay_ns: u64,
}

impl FaultSpec {
    /// No faults: every frame delivered exactly once, immediately.
    pub fn none() -> FaultSpec {
        FaultSpec { seed: 0, drop: 0.0, dup: 0.0, delay: 0.0, delay_ns: 0 }
    }

    /// A lossy-but-livable schedule for tests: some drops, dups and
    /// delays, all reproducible from `seed`.
    pub fn chaos(seed: u64) -> FaultSpec {
        FaultSpec {
            seed,
            drop: 0.15,
            dup: 0.15,
            delay: 0.25,
            delay_ns: Duration::from_millis(120).as_nanos() as u64,
        }
    }
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec::none()
    }
}

/// Delivery counters a transport maintains; the distributed executor
/// turns the per-stage deltas into `WireTransfer` profile kernels.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransportStats {
    /// Frames handed to `send` (before faults).
    pub sent: u64,
    /// Frames actually enqueued/delivered (dup counts twice).
    pub delivered: u64,
    /// Frames dropped by fault injection.
    pub dropped: u64,
    /// Extra deliveries created by duplication.
    pub duplicated: u64,
    /// Frames whose delivery was delayed.
    pub delayed: u64,
    /// Payload bytes handed to `send` (encoded frame length).
    pub bytes: u64,
}

/// An object-safe message fabric between the coordinator and workers.
///
/// The contract the protocol loop relies on:
/// * `send` is fire-and-forget; reliability is the caller's job
///   (retransmit with the *same* `seq`, dedup on receive).
/// * `poll` drains every frame due at `at` by the transport's own
///   clock, in a deterministic order.
/// * `now`/`advance` expose that clock: virtual for [`SimTransport`]
///   (nothing moves unless the driver advances), logical-but-real-IO
///   for the socket transport.
pub trait Transport: Send {
    /// Enqueue one frame for `to`. Faults (drop/dup/delay) are applied
    /// here, at send time, from the seeded schedule.
    fn send(&self, to: Endpoint, frame: Frame) -> Result<()>;
    /// Drain all frames currently deliverable at `at`.
    fn poll(&self, at: Endpoint) -> Vec<Frame>;
    /// Transport-clock time in nanoseconds.
    fn now(&self) -> Nanos;
    /// Advance the transport clock (virtual time for the simulator).
    fn advance(&self, by: Duration);
    /// Snapshot of delivery counters.
    fn stats(&self) -> TransportStats;
}

struct SimInner {
    rng: Pcg32,
    /// Per-endpoint mailbox: (due, send-order) → frame. BTreeMap keys
    /// give the deterministic delivery order `poll` promises.
    queues: BTreeMap<Endpoint, BTreeMap<(Nanos, u64), Frame>>,
    order: u64,
    stats: TransportStats,
}

/// In-process deterministic transport over a [`VirtualClock`].
pub struct SimTransport {
    clock: Arc<VirtualClock>,
    fault: FaultSpec,
    inner: Mutex<SimInner>,
}

impl SimTransport {
    /// A fault-free transport with its own private virtual clock.
    pub fn new() -> SimTransport {
        SimTransport::with_clock(Arc::new(VirtualClock::new()), FaultSpec::none())
    }

    /// A faulty transport with its own private virtual clock.
    pub fn faulty(fault: FaultSpec) -> SimTransport {
        SimTransport::with_clock(Arc::new(VirtualClock::new()), fault)
    }

    /// Build over a shared clock — lets a test drive the serving
    /// runtime and the cluster fabric from one `VirtualClock`.
    pub fn with_clock(clock: Arc<VirtualClock>, fault: FaultSpec) -> SimTransport {
        SimTransport {
            clock,
            inner: Mutex::new(SimInner {
                rng: Pcg32::new(fault.seed, 0xC1_05_7E),
                queues: BTreeMap::new(),
                order: 0,
                stats: TransportStats::default(),
            }),
            fault,
        }
    }

    /// The clock this transport is driven by.
    pub fn clock(&self) -> Arc<VirtualClock> {
        Arc::clone(&self.clock)
    }
}

impl Default for SimTransport {
    fn default() -> Self {
        SimTransport::new()
    }
}

impl Transport for SimTransport {
    fn send(&self, to: Endpoint, frame: Frame) -> Result<()> {
        let now = self.clock.now();
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let inner = &mut *inner;
        inner.stats.sent += 1;
        inner.stats.bytes += super::wire::encode_frame(&frame).len() as u64;
        // One draw per fault class per send keeps the schedule stable:
        // adding a dup never shifts whether the *next* frame drops.
        let u_drop = inner.rng.gen_f64();
        let u_dup = inner.rng.gen_f64();
        let u_delay = inner.rng.gen_f64();
        if u_drop < self.fault.drop {
            inner.stats.dropped += 1;
            return Ok(());
        }
        let due = if u_delay < self.fault.delay { now + self.fault.delay_ns } else { now };
        if u_delay < self.fault.delay {
            inner.stats.delayed += 1;
        }
        let copies = if u_dup < self.fault.dup {
            inner.stats.duplicated += 1;
            2
        } else {
            1
        };
        let queue = inner.queues.entry(to).or_default();
        for _ in 0..copies {
            let key = (due, inner.order);
            inner.order += 1;
            inner.stats.delivered += 1;
            queue.insert(key, frame.clone());
        }
        Ok(())
    }

    fn poll(&self, at: Endpoint) -> Vec<Frame> {
        let now = self.clock.now();
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let Some(queue) = inner.queues.get_mut(&at) else { return Vec::new() };
        let pending = queue.split_off(&(now + 1, 0));
        let due = std::mem::replace(queue, pending);
        due.into_values().collect()
    }

    fn now(&self) -> Nanos {
        self.clock.now()
    }

    fn advance(&self, by: Duration) {
        self.clock.advance(by);
    }

    fn stats(&self) -> TransportStats {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).stats
    }
}

#[cfg(test)]
mod tests {
    use super::super::wire::Message;
    use super::*;

    fn frame(seq: u64) -> Frame {
        Frame { seq, from: 0, msg: Message::Heartbeat { worker: 0 } }
    }

    #[test]
    fn delivers_in_send_order() {
        let t = SimTransport::new();
        for seq in 0..5 {
            t.send(Endpoint::Coordinator, frame(seq)).unwrap();
        }
        let got: Vec<u64> = t.poll(Endpoint::Coordinator).iter().map(|f| f.seq).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
        assert!(t.poll(Endpoint::Coordinator).is_empty(), "poll drains");
    }

    #[test]
    fn endpoints_are_isolated() {
        let t = SimTransport::new();
        t.send(Endpoint::Worker(0), frame(1)).unwrap();
        t.send(Endpoint::Worker(1), frame(2)).unwrap();
        assert_eq!(t.poll(Endpoint::Worker(0)).len(), 1);
        assert_eq!(t.poll(Endpoint::Worker(1)).len(), 1);
        assert!(t.poll(Endpoint::Coordinator).is_empty());
    }

    #[test]
    fn delayed_frames_wait_for_virtual_time() {
        let fault = FaultSpec { seed: 1, drop: 0.0, dup: 0.0, delay: 1.0, delay_ns: 1_000 };
        let t = SimTransport::faulty(fault);
        t.send(Endpoint::Coordinator, frame(9)).unwrap();
        assert!(t.poll(Endpoint::Coordinator).is_empty(), "not due yet");
        t.advance(Duration::from_nanos(999));
        assert!(t.poll(Endpoint::Coordinator).is_empty(), "still early");
        t.advance(Duration::from_nanos(1));
        assert_eq!(t.poll(Endpoint::Coordinator).len(), 1, "due exactly at delay");
        assert_eq!(t.stats().delayed, 1);
    }

    #[test]
    fn drop_and_dup_counters() {
        let all_drop = FaultSpec { seed: 2, drop: 1.0, dup: 0.0, delay: 0.0, delay_ns: 0 };
        let t = SimTransport::faulty(all_drop);
        t.send(Endpoint::Coordinator, frame(1)).unwrap();
        assert!(t.poll(Endpoint::Coordinator).is_empty());
        assert_eq!(t.stats().dropped, 1);

        let all_dup = FaultSpec { seed: 2, drop: 0.0, dup: 1.0, delay: 0.0, delay_ns: 0 };
        let t = SimTransport::faulty(all_dup);
        t.send(Endpoint::Coordinator, frame(1)).unwrap();
        let got = t.poll(Endpoint::Coordinator);
        assert_eq!(got.len(), 2, "duplicated delivery");
        assert_eq!(got[0], got[1], "same seq on both copies");
        assert_eq!(t.stats().duplicated, 1);
        assert_eq!(t.stats().delivered, 2);
    }

    #[test]
    fn fault_schedule_reproduces_from_seed() {
        let run = |seed: u64| -> (TransportStats, Vec<u64>) {
            let t = SimTransport::faulty(FaultSpec::chaos(seed));
            for seq in 0..200 {
                t.send(Endpoint::Coordinator, frame(seq)).unwrap();
            }
            t.advance(Duration::from_secs(1));
            let seqs = t.poll(Endpoint::Coordinator).iter().map(|f| f.seq).collect();
            (t.stats(), seqs)
        };
        let (s1, q1) = run(42);
        let (s2, q2) = run(42);
        assert_eq!(s1, s2, "same seed → same fate for every frame");
        assert_eq!(q1, q2, "same seed → same delivery order");
        let (s3, _) = run(43);
        assert_ne!(s1, s3, "different seed → different schedule");
        assert!(
            s1.dropped > 0 && s1.duplicated > 0 && s1.delayed > 0,
            "chaos exercises all faults: {s1:?}"
        );
    }

    #[test]
    fn shared_clock_moves_the_transport() {
        let clock = Arc::new(VirtualClock::new());
        let t = SimTransport::with_clock(Arc::clone(&clock), FaultSpec::none());
        assert_eq!(t.now(), 0);
        clock.advance(Duration::from_millis(2));
        assert_eq!(t.now(), 2_000_000, "external advance is visible");
    }
}
