//! Length-prefixed wire codec for the simulation cluster.
//!
//! Every frame that crosses a [`super::Transport`] is one
//! `[u32 len][u64 seq][u32 from][u8 tag][payload]` record, little-endian
//! throughout. `len` counts the bytes after the length word, `seq` is a
//! coordinator-global sequence number used for receiver-side
//! deduplication (retransmits and transport-duplicated frames carry the
//! same `seq`), and `from` names the sender ([`COORDINATOR`] or a worker
//! id). Row payloads ship `f32` values as raw little-endian bytes, so a
//! tensor row survives the wire bit-identically — the property every
//! cluster-vs-monolith test in `tests/integration_cluster.rs` pins.
//!
//! The codec is symmetric: [`encode_frame`]/[`decode_frame`] work on
//! byte slices for the in-process [`super::SimTransport`], and
//! [`write_frame`]/[`read_frame`] stream the same bytes over any
//! `io::Write`/`io::Read` pair for the feature-gated socket transport.

use crate::{Error, Result};

/// Sender id used by the coordinator in frame headers.
pub const COORDINATOR: u32 = u32::MAX;

/// Upper bound on a decoded frame body; guards against allocating from
/// a corrupt length word when reading off a real socket.
pub const MAX_FRAME_LEN: usize = 1 << 30;

/// A dense block of tensor rows keyed by global node ids: the payload
/// of every data-plane message (halo pushes, shard-merge rows, served
/// batch rows). `data` holds `ids.len() * cols` f32 values row-major;
/// empty blocks (`ids` empty, `cols` 0) are first-class so a shard with
/// no halo for some type still completes the protocol round.
#[derive(Debug, Clone, PartialEq)]
pub struct RowBlock {
    /// Global row ids, in the order `data` rows are laid out.
    pub ids: Vec<u32>,
    /// Row width in f32 values.
    pub cols: u32,
    /// Row-major values, `ids.len() * cols` long.
    pub data: Vec<f32>,
}

impl RowBlock {
    /// An empty block (zero rows, zero width).
    pub fn empty() -> RowBlock {
        RowBlock { ids: Vec::new(), cols: 0, data: Vec::new() }
    }

    /// Ids-only block (width 0): used for request payloads that name
    /// rows without carrying values, e.g. a served batch's seed ids.
    pub fn ids_only(ids: Vec<u32>) -> RowBlock {
        RowBlock { ids, cols: 0, data: Vec::new() }
    }

    /// Internal consistency check: `data` length matches `ids × cols`.
    pub fn validate(&self) -> Result<()> {
        let want = self.ids.len() * self.cols as usize;
        if self.data.len() != want {
            return Err(Error::shape(format!(
                "RowBlock: {} ids × {} cols wants {} values, has {}",
                self.ids.len(),
                self.cols,
                want,
                self.data.len()
            )));
        }
        Ok(())
    }
}

/// Every message the cluster exchanges. Control messages (place,
/// heartbeat, drain, retire) and broadcasts (epoch, weights) are
/// coordinator-plane; the `RowBlock`-carrying variants are the data
/// plane of one execution wave.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Control: shard `shard` is now owned by worker `worker`.
    Place {
        /// Shard index.
        shard: u32,
        /// Owning worker id.
        worker: u32,
    },
    /// Control: liveness beacon from a worker.
    Heartbeat {
        /// Sending worker id.
        worker: u32,
    },
    /// Control: worker should stop accepting new shards.
    Drain {
        /// Worker being drained.
        worker: u32,
    },
    /// Control: worker is removed from the cluster.
    Retire {
        /// Retired worker id.
        worker: u32,
    },
    /// Broadcast: an execution wave / epoch boundary.
    Epoch {
        /// Monotone epoch (wave) counter.
        epoch: u64,
    },
    /// Broadcast: a new weight version (payload is opaque here; the
    /// simulation cluster versions weights rather than shipping them).
    Weights {
        /// Monotone weight version.
        version: u64,
        /// Serialized weight delta (opaque to the codec).
        payload: Vec<u8>,
    },
    /// Data: projected halo rows pushed to the shard that reads them.
    Halo {
        /// Destination shard.
        shard: u32,
        /// Node type the rows belong to.
        ty: u32,
        /// The rows (may be empty).
        block: RowBlock,
    },
    /// Data: stage-② projected rows for a shard's owned nodes.
    FpRows {
        /// Producing shard.
        shard: u32,
        /// Node type the rows belong to.
        ty: u32,
        /// The rows.
        block: RowBlock,
    },
    /// Data: stage-③ owner-computes merge rows for one subgraph.
    NaRows {
        /// Producing shard.
        shard: u32,
        /// Metapath subgraph index.
        subgraph: u32,
        /// The rows.
        block: RowBlock,
    },
    /// Data: served batch output rows for a shard's seed group.
    BatchRows {
        /// Producing shard.
        shard: u32,
        /// The rows (ids are the seed ids).
        block: RowBlock,
    },
}

impl Message {
    /// Wire tag byte for this variant.
    pub fn tag(&self) -> u8 {
        match self {
            Message::Place { .. } => 0,
            Message::Heartbeat { .. } => 1,
            Message::Drain { .. } => 2,
            Message::Retire { .. } => 3,
            Message::Epoch { .. } => 4,
            Message::Weights { .. } => 5,
            Message::Halo { .. } => 6,
            Message::FpRows { .. } => 7,
            Message::NaRows { .. } => 8,
            Message::BatchRows { .. } => 9,
        }
    }

    /// Semantic key: identifies *what* a message is about independent of
    /// which delivery attempt carried it, so retransmitted or
    /// transport-duplicated copies of the same logical message collapse
    /// into one slot on the receiver. Data-plane keys combine the
    /// per-shard stream (type / subgraph index); control keys are flat.
    pub fn semantic_key(&self) -> (u8, u64) {
        let sub = match self {
            Message::Place { shard, .. } => *shard as u64,
            Message::Heartbeat { worker }
            | Message::Drain { worker }
            | Message::Retire { worker } => *worker as u64,
            Message::Epoch { .. } => 0,
            Message::Weights { version, .. } => *version,
            Message::Halo { ty, .. } | Message::FpRows { ty, .. } => *ty as u64,
            Message::NaRows { subgraph, .. } => *subgraph as u64,
            Message::BatchRows { .. } => 0,
        };
        (self.tag(), sub)
    }

    /// The shard a data-plane message belongs to (`None` for control
    /// and broadcast messages).
    pub fn shard(&self) -> Option<u32> {
        match self {
            Message::Halo { shard, .. }
            | Message::FpRows { shard, .. }
            | Message::NaRows { shard, .. }
            | Message::BatchRows { shard, .. } => Some(*shard),
            _ => None,
        }
    }
}

/// One framed message: header fields plus the payload.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    /// Coordinator-global sequence number (dedup key together with
    /// `from`; duplicates carry the same value).
    pub seq: u64,
    /// Sender: [`COORDINATOR`] or a worker id.
    pub from: u32,
    /// The message.
    pub msg: Message,
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_block(buf: &mut Vec<u8>, b: &RowBlock) {
    put_u32(buf, b.ids.len() as u32);
    for id in &b.ids {
        put_u32(buf, *id);
    }
    put_u32(buf, b.cols);
    put_u32(buf, b.data.len() as u32);
    for v in &b.data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(Error::config(format!(
                "wire: truncated frame (want {} bytes at offset {}, have {})",
                n,
                self.pos,
                self.buf.len()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn block(&mut self) -> Result<RowBlock> {
        let n_ids = self.u32()? as usize;
        let mut ids = Vec::with_capacity(n_ids.min(MAX_FRAME_LEN / 4));
        for _ in 0..n_ids {
            ids.push(self.u32()?);
        }
        let cols = self.u32()?;
        let n_data = self.u32()? as usize;
        let mut data = Vec::with_capacity(n_data.min(MAX_FRAME_LEN / 4));
        for _ in 0..n_data {
            data.push(self.f32()?);
        }
        let b = RowBlock { ids, cols, data };
        b.validate()?;
        Ok(b)
    }

    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(Error::config(format!(
                "wire: {} trailing bytes after frame payload",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

/// Encode a frame as `[u32 len][u64 seq][u32 from][u8 tag][payload]`.
pub fn encode_frame(frame: &Frame) -> Vec<u8> {
    let mut body = Vec::new();
    put_u64(&mut body, frame.seq);
    put_u32(&mut body, frame.from);
    body.push(frame.msg.tag());
    match &frame.msg {
        Message::Place { shard, worker } => {
            put_u32(&mut body, *shard);
            put_u32(&mut body, *worker);
        }
        Message::Heartbeat { worker }
        | Message::Drain { worker }
        | Message::Retire { worker } => put_u32(&mut body, *worker),
        Message::Epoch { epoch } => put_u64(&mut body, *epoch),
        Message::Weights { version, payload } => {
            put_u64(&mut body, *version);
            put_u32(&mut body, payload.len() as u32);
            body.extend_from_slice(payload);
        }
        Message::Halo { shard, ty, block } | Message::FpRows { shard, ty, block } => {
            put_u32(&mut body, *shard);
            put_u32(&mut body, *ty);
            put_block(&mut body, block);
        }
        Message::NaRows { shard, subgraph, block } => {
            put_u32(&mut body, *shard);
            put_u32(&mut body, *subgraph);
            put_block(&mut body, block);
        }
        Message::BatchRows { shard, block } => {
            put_u32(&mut body, *shard);
            put_block(&mut body, block);
        }
    }
    let mut out = Vec::with_capacity(4 + body.len());
    put_u32(&mut out, body.len() as u32);
    out.extend_from_slice(&body);
    out
}

/// Decode one frame from a body slice (the bytes *after* the length
/// word). Rejects unknown tags, truncated payloads and trailing bytes.
pub fn decode_frame(body: &[u8]) -> Result<Frame> {
    let mut r = Reader::new(body);
    let seq = r.u64()?;
    let from = r.u32()?;
    let tag = r.u8()?;
    let msg = match tag {
        0 => Message::Place { shard: r.u32()?, worker: r.u32()? },
        1 => Message::Heartbeat { worker: r.u32()? },
        2 => Message::Drain { worker: r.u32()? },
        3 => Message::Retire { worker: r.u32()? },
        4 => Message::Epoch { epoch: r.u64()? },
        5 => {
            let version = r.u64()?;
            let n = r.u32()? as usize;
            Message::Weights { version, payload: r.take(n)?.to_vec() }
        }
        6 => Message::Halo { shard: r.u32()?, ty: r.u32()?, block: r.block()? },
        7 => Message::FpRows { shard: r.u32()?, ty: r.u32()?, block: r.block()? },
        8 => Message::NaRows { shard: r.u32()?, subgraph: r.u32()?, block: r.block()? },
        9 => Message::BatchRows { shard: r.u32()?, block: r.block()? },
        other => return Err(Error::config(format!("wire: unknown message tag {other}"))),
    };
    r.done()?;
    Ok(Frame { seq, from, msg })
}

/// Stream-encode a frame onto an `io::Write` (socket transport path).
pub fn write_frame(w: &mut dyn std::io::Write, frame: &Frame) -> Result<()> {
    w.write_all(&encode_frame(frame))?;
    Ok(())
}

/// Stream-decode one frame from an `io::Read` (socket transport path):
/// reads the length word, then exactly that many body bytes.
pub fn read_frame(r: &mut dyn std::io::Read) -> Result<Frame> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let len = u32::from_le_bytes(len4) as usize;
    if len > MAX_FRAME_LEN {
        return Err(Error::config(format!("wire: frame length {len} exceeds cap")));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    decode_frame(&body)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Message) {
        let frame = Frame { seq: 7, from: 3, msg };
        let bytes = encode_frame(&frame);
        let len = u32::from_le_bytes(bytes[..4].try_into().unwrap()) as usize;
        assert_eq!(len + 4, bytes.len(), "length word covers the body");
        let back = decode_frame(&bytes[4..]).expect("decode");
        assert_eq!(back, frame);
    }

    #[test]
    fn every_variant_roundtrips() {
        let block = RowBlock {
            ids: vec![0, 5, 9],
            cols: 2,
            data: vec![1.0, -2.5, f32::MIN_POSITIVE, 0.0, 3.25, -0.0],
        };
        roundtrip(Message::Place { shard: 1, worker: 2 });
        roundtrip(Message::Heartbeat { worker: 0 });
        roundtrip(Message::Drain { worker: 4 });
        roundtrip(Message::Retire { worker: 9 });
        roundtrip(Message::Epoch { epoch: u64::MAX });
        roundtrip(Message::Weights { version: 3, payload: vec![1, 2, 3] });
        roundtrip(Message::Weights { version: 0, payload: Vec::new() });
        roundtrip(Message::Halo { shard: 0, ty: 1, block: block.clone() });
        roundtrip(Message::Halo { shard: 0, ty: 1, block: RowBlock::empty() });
        roundtrip(Message::FpRows { shard: 2, ty: 0, block: block.clone() });
        roundtrip(Message::NaRows { shard: 1, subgraph: 3, block: block.clone() });
        roundtrip(Message::BatchRows { shard: 0, block });
    }

    #[test]
    fn f32_payload_is_bit_exact() {
        // NaN payloads and signed zeros must survive the wire unchanged.
        let raw = [f32::NAN, -0.0, f32::INFINITY, 1.0e-44];
        let block = RowBlock { ids: vec![1, 2], cols: 2, data: raw.to_vec() };
        let frame =
            Frame { seq: 0, from: COORDINATOR, msg: Message::BatchRows { shard: 0, block } };
        let back = decode_frame(&encode_frame(&frame)[4..]).unwrap();
        let Message::BatchRows { block, .. } = back.msg else { panic!("variant") };
        for (a, b) in raw.iter().zip(&block.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "bit-exact f32 transfer");
        }
    }

    #[test]
    fn truncated_and_trailing_bytes_rejected() {
        let frame = Frame { seq: 1, from: 0, msg: Message::Epoch { epoch: 42 } };
        let bytes = encode_frame(&frame);
        assert!(decode_frame(&bytes[4..bytes.len() - 1]).is_err(), "truncated");
        let mut extra = bytes[4..].to_vec();
        extra.push(0xFF);
        assert!(decode_frame(&extra).is_err(), "trailing");
    }

    #[test]
    fn unknown_tag_rejected() {
        let mut body = Vec::new();
        body.extend_from_slice(&1u64.to_le_bytes());
        body.extend_from_slice(&0u32.to_le_bytes());
        body.push(250);
        assert!(decode_frame(&body).is_err());
    }

    #[test]
    fn stream_io_roundtrip() {
        let frames = vec![
            Frame { seq: 1, from: COORDINATOR, msg: Message::Epoch { epoch: 1 } },
            Frame {
                seq: 2,
                from: 0,
                msg: Message::FpRows {
                    shard: 0,
                    ty: 0,
                    block: RowBlock { ids: vec![3], cols: 1, data: vec![0.5] },
                },
            },
        ];
        let mut buf = Vec::new();
        for f in &frames {
            write_frame(&mut buf, f).unwrap();
        }
        let mut cursor = std::io::Cursor::new(buf);
        for f in &frames {
            assert_eq!(&read_frame(&mut cursor).unwrap(), f);
        }
    }

    #[test]
    fn semantic_keys_distinguish_streams() {
        let b = RowBlock::empty();
        let a = Message::Halo { shard: 0, ty: 1, block: b.clone() };
        let c = Message::Halo { shard: 0, ty: 2, block: b.clone() };
        assert_ne!(a.semantic_key(), c.semantic_key());
        // same logical message from two delivery attempts → same key
        assert_eq!(a.semantic_key(), a.clone().semantic_key());
        assert_ne!(
            Message::NaRows { shard: 0, subgraph: 1, block: b.clone() }.semantic_key(),
            Message::FpRows { shard: 0, ty: 1, block: b }.semantic_key()
        );
    }

    #[test]
    fn row_block_validation() {
        assert!(RowBlock::empty().validate().is_ok());
        assert!(RowBlock::ids_only(vec![1, 2, 3]).validate().is_ok());
        let bad = RowBlock { ids: vec![1], cols: 2, data: vec![0.0] };
        assert!(bad.validate().is_err());
    }
}
