//! The L3 coordinator: scheduling policies over the staged execution.
//!
//! The paper's §4.5/§5 observations are about *schedules*, not kernels:
//! Neighbor Aggregation of different subgraphs is independent
//! (inter-subgraph parallelism, Fig 5c), a hard barrier separates NA from
//! SA, and the §5 guidelines propose execution-bound-aware kernel mixing
//! and subgraph-level FP+NA fusion. This module implements those
//! schedules over the engine's stage entry points:
//!
//! * [`SchedulePolicy::Sequential`] — DGL's default serial stream (what
//!   the paper profiles).
//! * [`SchedulePolicy::InterSubgraphParallel`] — NA subgraphs spread over
//!   `workers` concurrent streams (LPT assignment).
//! * [`SchedulePolicy::FusedSubgraph`] — §5 guideline 2: each worker task
//!   fuses a subgraph's Feature Projection with its Neighbor Aggregation,
//!   so FP work overlaps other subgraphs' NA instead of serializing.
//! * [`SchedulePolicy::BoundAwareMixing`] — §5 guideline 1: co-schedule
//!   compute-bound (DM) kernels with memory-bound (TB/EW/DR) kernels;
//!   modeled co-run time is `max` of the two resource demands.
//!
//! Native execution happens on real threads (crossbeam scoped); the
//! *makespan* numbers reported for the ablations come from the modeled
//! T4 schedule, which is the honest instrument available without the
//! paper's hardware (DESIGN.md §4).

pub mod schedule;
pub mod serve;

use std::collections::BTreeMap;

use crossbeam_utils::thread as cb_thread;

use crate::engine::{feature_projection, neighbor_aggregation, semantic_aggregation, Backend};
use crate::gpumodel::GpuModel;
use crate::graph::HeteroGraph;
use crate::kernels::dense::GemmBlocking;
use crate::kernels::Ctx;
use crate::models::ModelPlan;
use crate::profiler::{Profile, StageId};
use crate::tensor::Tensor;
use crate::{Error, Result};

pub use schedule::{lpt_assign, ScheduleReport};
pub use serve::{ServeConfig, ServeStats, Server};

/// How the coordinator schedules the stages.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulePolicy {
    /// Serial FP → NA(sg0..sgP) → SA, single stream.
    Sequential,
    /// FP serial, NA subgraphs across `workers` streams, barrier, SA.
    InterSubgraphParallel {
        /// Concurrent NA streams.
        workers: usize,
    },
    /// Per-subgraph (FP+NA) fused tasks across `workers` streams.
    FusedSubgraph {
        /// Concurrent task streams.
        workers: usize,
    },
    /// Inter-subgraph parallel + compute/memory co-scheduling analysis.
    BoundAwareMixing {
        /// Concurrent NA streams.
        workers: usize,
    },
}

impl SchedulePolicy {
    /// Short label for reports.
    pub fn label(self) -> String {
        match self {
            SchedulePolicy::Sequential => "sequential".into(),
            SchedulePolicy::InterSubgraphParallel { workers } => {
                format!("inter-subgraph x{workers}")
            }
            SchedulePolicy::FusedSubgraph { workers } => format!("fused-subgraph x{workers}"),
            SchedulePolicy::BoundAwareMixing { workers } => format!("bound-aware-mix x{workers}"),
        }
    }
}

/// Coordinator output: results + profile + schedule analysis.
#[derive(Debug)]
pub struct CoordRun {
    /// Final target-type embeddings.
    pub output: Tensor,
    /// Per-subgraph NA results.
    pub na_results: Vec<Tensor>,
    /// Kernel profile (worker-attributed).
    pub profile: Profile,
    /// Modeled schedule analysis.
    pub report: ScheduleReport,
}

/// The coordinator.
#[derive(Debug)]
pub struct Coordinator {
    backend: Backend,
    gpu: GpuModel,
}

impl Coordinator {
    /// New coordinator over a backend with the default T4 model.
    pub fn new(backend: Backend) -> Coordinator {
        Coordinator { backend, gpu: GpuModel::default() }
    }

    /// Override the GPU model.
    pub fn with_gpu_model(mut self, gpu: GpuModel) -> Coordinator {
        self.gpu = gpu;
        self
    }

    fn blocking(&self) -> GemmBlocking {
        match self.backend {
            Backend::Native { blocking, .. } => blocking,
        }
    }

    fn mk_ctx(&self) -> Ctx {
        match self.backend {
            Backend::Native { record_traces, .. } => {
                Ctx { events: Vec::new(), record_traces }
            }
        }
    }

    /// Execute a plan under a scheduling policy.
    pub fn run(
        &self,
        plan: &ModelPlan,
        hg: &HeteroGraph,
        policy: SchedulePolicy,
    ) -> Result<CoordRun> {
        match policy {
            SchedulePolicy::Sequential => self.run_scheduled(plan, hg, 1, false, policy),
            SchedulePolicy::InterSubgraphParallel { workers } => {
                self.run_scheduled(plan, hg, workers.max(1), false, policy)
            }
            SchedulePolicy::FusedSubgraph { workers } => {
                self.run_fused(plan, hg, workers.max(1), policy)
            }
            SchedulePolicy::BoundAwareMixing { workers } => {
                self.run_scheduled(plan, hg, workers.max(1), true, policy)
            }
        }
    }

    /// FP serial → NA across workers (real threads) → barrier → SA.
    fn run_scheduled(
        &self,
        plan: &ModelPlan,
        hg: &HeteroGraph,
        workers: usize,
        mixing: bool,
        policy: SchedulePolicy,
    ) -> Result<CoordRun> {
        let blocking = self.blocking();
        let mut profile = Profile {
            subgraph_build_nanos: plan.subgraphs.build_nanos,
            ..Default::default()
        };

        // ② FP (single stream, worker 0)
        let mut ctx = self.mk_ctx();
        let projected = feature_projection(&mut ctx, plan, hg, blocking)?;
        profile.record(ctx.drain(), StageId::FeatureProjection, None, 0, 0);

        // estimate per-subgraph NA cost for LPT assignment (nnz is the
        // dominant cost driver for every NA variant)
        let costs: Vec<f64> = plan
            .subgraphs
            .subgraphs
            .iter()
            .map(|sg| sg.adj.nnz() as f64 + 1.0)
            .collect();
        let assignment = lpt_assign(&costs, workers);

        // ③ NA on real threads, one per worker
        let p = plan.num_subgraphs();
        let mut results: Vec<Option<(usize, Vec<crate::kernels::KernelExec>, Tensor)>> =
            (0..p).map(|_| None).collect();
        let record_traces = matches!(self.backend, Backend::Native { record_traces: true, .. });
        let worker_outputs: Result<Vec<Vec<(usize, Vec<crate::kernels::KernelExec>, Tensor)>>> =
            cb_thread::scope(|scope| {
                let mut handles = Vec::new();
                for w in 0..workers {
                    let my_subgraphs: Vec<usize> = (0..p)
                        .filter(|&i| assignment[i] == w)
                        .collect();
                    let projected = &projected;
                    let handle = scope.spawn(move |_| -> Result<Vec<_>> {
                        let mut out = Vec::new();
                        for i in my_subgraphs {
                            let mut wctx =
                                Ctx { events: Vec::new(), record_traces };
                            let t = neighbor_aggregation(
                                &mut wctx, plan, i, projected, blocking,
                            )?;
                            out.push((i, wctx.drain(), t));
                        }
                        Ok(out)
                    });
                    handles.push(handle);
                }
                handles
                    .into_iter()
                    .map(|h| h.join().expect("NA worker panicked"))
                    .collect()
            })
            .expect("thread scope");
        for per_worker in worker_outputs? {
            for (i, events, t) in per_worker {
                results[i] = Some((i, events, t));
            }
        }
        let mut na_results = Vec::with_capacity(p);
        for (i, slot) in results.into_iter().enumerate() {
            let (_, events, t) = slot.ok_or_else(|| {
                Error::config(format!("subgraph {i} was never scheduled"))
            })?;
            profile.record(
                events,
                StageId::NeighborAggregation,
                Some(&plan.subgraphs.subgraphs[i].name),
                assignment[i],
                0,
            );
            na_results.push(t);
        }

        // barrier, then ④ SA on worker 0
        let mut ctx = self.mk_ctx();
        let output = semantic_aggregation(&mut ctx, plan, &na_results, blocking)?;
        profile.record(ctx.drain(), StageId::SemanticAggregation, None, 0, 0);

        profile.attach_metrics(&self.gpu);
        let report = schedule::analyze(&profile, workers, mixing, policy, &self.gpu);
        Ok(CoordRun { output, na_results, profile, report })
    }

    /// §5 guideline 2: per-subgraph fused (FP + NA) tasks.
    ///
    /// Each worker projects the types *its* subgraphs need (first use
    /// wins; shared types are projected once, by the worker that reaches
    /// them first in task order) and runs NA immediately — FP no longer
    /// serializes ahead of all NA.
    fn run_fused(
        &self,
        plan: &ModelPlan,
        hg: &HeteroGraph,
        workers: usize,
        policy: SchedulePolicy,
    ) -> Result<CoordRun> {
        let blocking = self.blocking();
        let mut profile = Profile {
            subgraph_build_nanos: plan.subgraphs.build_nanos,
            ..Default::default()
        };

        // assign subgraphs to workers by cost (nnz + projection need)
        let costs: Vec<f64> = plan
            .subgraphs
            .subgraphs
            .iter()
            .map(|sg| sg.adj.nnz() as f64 + 1.0)
            .collect();
        let assignment = lpt_assign(&costs, workers);

        // each worker owns the projections its tasks need; types shared
        // across workers are projected redundantly — that duplication is
        // the fusion trade-off the ablation quantifies.
        let p = plan.num_subgraphs();
        let record_traces = matches!(self.backend, Backend::Native { record_traces: true, .. });
        type TaskOut = (usize, Vec<crate::kernels::KernelExec>, Tensor);
        let worker_outputs: Result<Vec<Vec<TaskOut>>> = cb_thread::scope(|scope| {
            let mut handles = Vec::new();
            for w in 0..workers {
                let my_subgraphs: Vec<usize> =
                    (0..p).filter(|&i| assignment[i] == w).collect();
                let handle = scope.spawn(move |_| -> Result<Vec<TaskOut>> {
                    let mut out = Vec::new();
                    let mut local_proj: BTreeMap<usize, Tensor> = BTreeMap::new();
                    for i in my_subgraphs {
                        let mut wctx = Ctx { events: Vec::new(), record_traces };
                        let sg = &plan.subgraphs.subgraphs[i];
                        for ty in [sg.src_type, sg.dst_type] {
                            if !local_proj.contains_key(&ty) {
                                if let Some(w_ty) = plan.weights.proj.get(&ty) {
                                    let x = plan
                                        .weights
                                        .embed
                                        .get(&ty)
                                        .unwrap_or_else(|| hg.features(ty));
                                    let h = crate::kernels::dense::sgemm(
                                        &mut wctx, x, w_ty, blocking,
                                    )?;
                                    local_proj.insert(ty, h);
                                }
                            }
                        }
                        let t = neighbor_aggregation(
                            &mut wctx, plan, i, &local_proj, blocking,
                        )?;
                        out.push((i, wctx.drain(), t));
                    }
                    Ok(out)
                });
                handles.push(handle);
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("fused worker panicked"))
                .collect()
        })
        .expect("thread scope");

        let mut results: Vec<Option<Tensor>> = (0..p).map(|_| None).collect();
        for per_worker in worker_outputs? {
            for (i, events, t) in per_worker {
                // fused tasks attribute *all* their kernels (including the
                // projection sgemms) to NA — that is what fusion means
                // for the schedule
                profile.record(
                    events,
                    StageId::NeighborAggregation,
                    Some(&plan.subgraphs.subgraphs[i].name),
                    assignment[i],
                    0,
                );
                results[i] = Some(t);
            }
        }
        let na_results: Vec<Tensor> = results
            .into_iter()
            .enumerate()
            .map(|(i, r)| r.ok_or_else(|| Error::config(format!("subgraph {i} missing"))))
            .collect::<Result<_>>()?;

        let mut ctx = self.mk_ctx();
        let output = semantic_aggregation(&mut ctx, plan, &na_results, blocking)?;
        profile.record(ctx.drain(), StageId::SemanticAggregation, None, 0, 0);

        profile.attach_metrics(&self.gpu);
        let report = schedule::analyze(&profile, workers, false, policy, &self.gpu);
        Ok(CoordRun { output, na_results, profile, report })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{self, DatasetId, DatasetScale};
    use crate::models::{self, ModelConfig, ModelId};

    fn setup() -> (HeteroGraph, ModelPlan) {
        let hg = datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap();
        let plan = models::han_plan(&hg, &ModelConfig::default()).unwrap();
        (hg, plan)
    }

    #[test]
    fn all_policies_agree_numerically() {
        let (hg, plan) = setup();
        let coord = Coordinator::new(Backend::native());
        let seq = coord.run(&plan, &hg, SchedulePolicy::Sequential).unwrap();
        for policy in [
            SchedulePolicy::InterSubgraphParallel { workers: 2 },
            SchedulePolicy::FusedSubgraph { workers: 2 },
            SchedulePolicy::BoundAwareMixing { workers: 2 },
        ] {
            let run = coord.run(&plan, &hg, policy).unwrap();
            assert!(
                run.output.allclose(&seq.output, 1e-4, 1e-5),
                "{} diverges from sequential",
                policy.label()
            );
        }
    }

    #[test]
    fn parallel_makespan_not_worse() {
        let (hg, plan) = setup();
        let coord = Coordinator::new(Backend::native());
        let seq = coord.run(&plan, &hg, SchedulePolicy::Sequential).unwrap();
        let par = coord
            .run(&plan, &hg, SchedulePolicy::InterSubgraphParallel { workers: 4 })
            .unwrap();
        assert!(
            par.report.modeled_makespan_ns <= seq.report.modeled_makespan_ns + 1.0,
            "parallel {} vs sequential {}",
            par.report.modeled_makespan_ns,
            seq.report.modeled_makespan_ns
        );
    }

    #[test]
    fn parallel_timeline_overlaps_and_has_barrier() {
        let (hg, plan) = setup();
        let coord = Coordinator::new(Backend::native());
        let par = coord
            .run(&plan, &hg, SchedulePolicy::InterSubgraphParallel { workers: 2 })
            .unwrap();
        let tl = par.profile.timeline();
        assert!(tl.has_cross_lane_overlap(), "expected inter-subgraph parallelism");
        assert!(
            tl.barriers.iter().any(|(l, _)| l.contains("NA")),
            "expected NA→SA barrier"
        );
    }

    #[test]
    fn workers_attributed() {
        let (hg, plan) = setup();
        let coord = Coordinator::new(Backend::native());
        let par = coord
            .run(&plan, &hg, SchedulePolicy::InterSubgraphParallel { workers: 2 })
            .unwrap();
        let na_workers: std::collections::BTreeSet<usize> = par
            .profile
            .kernels
            .iter()
            .filter(|k| k.stage == StageId::NeighborAggregation)
            .map(|k| k.worker)
            .collect();
        assert_eq!(na_workers.len(), 2, "both workers should run NA");
    }

    #[test]
    fn fused_moves_fp_into_na() {
        let (hg, plan) = setup();
        let coord = Coordinator::new(Backend::native());
        let fused =
            coord.run(&plan, &hg, SchedulePolicy::FusedSubgraph { workers: 2 }).unwrap();
        let fp_time: f64 = fused
            .profile
            .kernels
            .iter()
            .filter(|k| k.stage == StageId::FeatureProjection)
            .map(|k| k.exec.wall_nanos as f64)
            .sum();
        assert_eq!(fp_time, 0.0, "fused schedule has no separate FP stage");
    }

    #[test]
    fn policy_labels() {
        assert_eq!(SchedulePolicy::Sequential.label(), "sequential");
        assert!(SchedulePolicy::FusedSubgraph { workers: 3 }.label().contains('3'));
    }
}
