//! The L3 coordinator — scheduling analysis plus deprecated shims.
//!
//! The paper's §4.5/§5 observations are about *schedules*, not kernels:
//! Neighbor Aggregation of different subgraphs is independent
//! (inter-subgraph parallelism, Fig 5c), a hard barrier separates NA
//! from SA, and the §5 guidelines propose execution-bound-aware kernel
//! mixing and subgraph-level FP+NA fusion. Those schedules are now
//! implemented once, in [`crate::session::exec`], and reached through
//! [`crate::session::Session`] with any [`SchedulePolicy`] × any
//! [`crate::session::ExecBackend`]. What remains here:
//!
//! * [`schedule`] — LPT assignment and the modeled-makespan analysis
//!   ([`ScheduleReport`]), the instrument behind the ablations;
//! * [`serve`] — the dynamic-batching serving loop, which executes
//!   batches through a session;
//! * [`Coordinator`] — a thin, deprecated wrapper kept so existing
//!   `Coordinator::new(backend).run(plan, hg, policy)` call sites keep
//!   working; it forwards to the session executor.

pub mod schedule;
pub mod serve;

use crate::engine::Backend;
use crate::gpumodel::GpuModel;
use crate::graph::HeteroGraph;
use crate::models::ModelPlan;
use crate::profiler::Profile;
use crate::session::{exec, NativeBackend};
use crate::tensor::Tensor;
use crate::Result;

pub use crate::session::SchedulePolicy;
pub use schedule::{lpt_assign, ScheduleReport};
pub use serve::{ServeConfig, ServeStats, Server};

/// Coordinator output: results + profile + schedule analysis.
#[derive(Debug)]
pub struct CoordRun {
    /// Final target-type embeddings.
    pub output: Tensor,
    /// Per-subgraph NA results.
    pub na_results: Vec<Tensor>,
    /// Kernel profile (worker-attributed).
    pub profile: Profile,
    /// Modeled schedule analysis.
    pub report: ScheduleReport,
}

/// The coordinator — a deprecated shim over the session executor; see
/// the module docs. New code: [`crate::session::Session`] with
/// `.schedule(policy)`.
#[derive(Debug)]
pub struct Coordinator {
    backend: NativeBackend,
    gpu: GpuModel,
}

impl Coordinator {
    /// New coordinator over a backend with the default T4 model.
    ///
    /// **Deprecated:** build a [`crate::session::Session`] instead.
    pub fn new(backend: Backend) -> Coordinator {
        Coordinator { backend: NativeBackend::from(backend), gpu: GpuModel::default() }
    }

    /// Override the GPU model.
    pub fn with_gpu_model(mut self, gpu: GpuModel) -> Coordinator {
        self.gpu = gpu;
        self
    }

    /// Execute a plan under a scheduling policy.
    pub fn run(
        &self,
        plan: &ModelPlan,
        hg: &HeteroGraph,
        policy: SchedulePolicy,
    ) -> Result<CoordRun> {
        let mut scratch = crate::kernels::Ctx {
            record_traces: self.backend.record_traces,
            ..Default::default()
        };
        let run = exec::execute(&self.backend, &self.gpu, plan, hg, policy, &mut scratch)?;
        Ok(CoordRun {
            output: run.output,
            na_results: run.na_results,
            profile: run.profile,
            report: run.report,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{self, DatasetId, DatasetScale};
    use crate::models::{self, ModelConfig};
    use crate::profiler::StageId;

    fn setup() -> (HeteroGraph, ModelPlan) {
        let hg = datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap();
        let plan = models::han_plan(&hg, &ModelConfig::default()).unwrap();
        (hg, plan)
    }

    #[test]
    fn all_policies_agree_numerically() {
        let (hg, plan) = setup();
        let coord = Coordinator::new(Backend::native());
        let seq = coord.run(&plan, &hg, SchedulePolicy::Sequential).unwrap();
        for policy in [
            SchedulePolicy::InterSubgraphParallel { workers: 2 },
            SchedulePolicy::FusedSubgraph { workers: 2 },
            SchedulePolicy::BoundAwareMixing { workers: 2 },
        ] {
            let run = coord.run(&plan, &hg, policy).unwrap();
            assert!(
                run.output.allclose(&seq.output, 1e-4, 1e-5),
                "{} diverges from sequential",
                policy.label()
            );
        }
    }

    #[test]
    fn parallel_makespan_not_worse() {
        let (hg, plan) = setup();
        let coord = Coordinator::new(Backend::native());
        let seq = coord.run(&plan, &hg, SchedulePolicy::Sequential).unwrap();
        let par = coord
            .run(&plan, &hg, SchedulePolicy::InterSubgraphParallel { workers: 4 })
            .unwrap();
        assert!(
            par.report.modeled_makespan_ns <= seq.report.modeled_makespan_ns + 1.0,
            "parallel {} vs sequential {}",
            par.report.modeled_makespan_ns,
            seq.report.modeled_makespan_ns
        );
    }

    #[test]
    fn parallel_timeline_overlaps_and_has_barrier() {
        let (hg, plan) = setup();
        let coord = Coordinator::new(Backend::native());
        let par = coord
            .run(&plan, &hg, SchedulePolicy::InterSubgraphParallel { workers: 2 })
            .unwrap();
        let tl = par.profile.timeline();
        assert!(tl.has_cross_lane_overlap(), "expected inter-subgraph parallelism");
        assert!(
            tl.barriers.iter().any(|(l, _)| l.contains("NA")),
            "expected NA→SA barrier"
        );
    }

    #[test]
    fn workers_attributed() {
        let (hg, plan) = setup();
        let coord = Coordinator::new(Backend::native());
        let par = coord
            .run(&plan, &hg, SchedulePolicy::InterSubgraphParallel { workers: 2 })
            .unwrap();
        let na_workers: std::collections::BTreeSet<usize> = par
            .profile
            .kernels
            .iter()
            .filter(|k| k.stage == StageId::NeighborAggregation)
            .map(|k| k.worker)
            .collect();
        assert_eq!(na_workers.len(), 2, "both workers should run NA");
    }

    #[test]
    fn fused_moves_fp_into_na() {
        let (hg, plan) = setup();
        let coord = Coordinator::new(Backend::native());
        let fused =
            coord.run(&plan, &hg, SchedulePolicy::FusedSubgraph { workers: 2 }).unwrap();
        let fp_time: f64 = fused
            .profile
            .kernels
            .iter()
            .filter(|k| k.stage == StageId::FeatureProjection)
            .map(|k| k.exec.wall_nanos as f64)
            .sum();
        assert_eq!(fp_time, 0.0, "fused schedule has no separate FP stage");
    }

    #[test]
    fn policy_labels() {
        assert_eq!(SchedulePolicy::Sequential.label(), "sequential");
        assert!(SchedulePolicy::FusedSubgraph { workers: 3 }.label().contains('3'));
    }
}
