//! Schedule analysis: LPT assignment, modeled makespan, and the
//! execution-bound-aware mixing model (§5 guideline 1).

use std::collections::BTreeMap;

use crate::gpumodel::GpuModel;
use crate::kernels::KernelType;
use crate::partition::ShardingInfo;
use crate::profiler::{Profile, StageId};
use crate::reuse::ReuseStats;
use crate::coordinator::SchedulePolicy;

/// Longest-processing-time-first assignment of `costs` onto `workers`
/// bins; returns the worker index per item.
///
/// This is the **canonical** LPT implementation: the modeled schedule
/// analysis, the real NA worker dispatch (`session::exec`), and the
/// graph partitioner ([`crate::partition`] — per-vertex shard assignment
/// *and* shard→thread packing) all call this one function rather than
/// keeping copies.
pub fn lpt_assign(costs: &[f64], workers: usize) -> Vec<usize> {
    let workers = workers.max(1);
    let mut order: Vec<usize> = (0..costs.len()).collect();
    order.sort_by(|&a, &b| costs[b].partial_cmp(&costs[a]).unwrap());
    let mut load = vec![0.0f64; workers];
    let mut assignment = vec![0usize; costs.len()];
    for i in order {
        let (w, _) = load
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        assignment[i] = w;
        load[w] += costs[i];
    }
    assignment
}

/// Modeled schedule analysis of one coordinated run.
#[derive(Debug, Clone)]
pub struct ScheduleReport {
    /// Policy analyzed.
    pub policy: SchedulePolicy,
    /// Worker count used.
    pub workers: usize,
    /// Modeled serial time (sum of all kernels) — the DGL baseline.
    pub modeled_serial_ns: f64,
    /// Modeled makespan under the policy.
    pub modeled_makespan_ns: f64,
    /// serial / makespan.
    pub speedup: f64,
    /// Modeled NA-stage makespan alone (Fig 5c discussion).
    pub na_makespan_ns: f64,
    /// Where (modeled ns) the NA→SA barrier falls.
    pub barrier_at_ns: f64,
    /// Cumulative reuse-cache counters when the run executed through the
    /// cache-aware serving path (`None` for plain runs).
    pub reuse: Option<ReuseStats>,
    /// Partition-quality summary when the run executed through the
    /// sharded path (`None` for monolithic runs).
    pub sharding: Option<ShardingInfo>,
}

impl ScheduleReport {
    /// One-line summary (appends cache hit rates when reuse was active).
    pub fn summary(&self) -> String {
        let mut line = format!(
            "{:<22} makespan {:>12}  (serial {:>12}, speedup {:.2}x)",
            self.policy.label(),
            crate::util::human_time(self.modeled_makespan_ns),
            crate::util::human_time(self.modeled_serial_ns),
            self.speedup
        );
        if let Some(r) = &self.reuse {
            line.push_str(&format!(
                "  [proj hit {:.0}%, agg hit {:.0}%]",
                100.0 * r.proj_hit_rate(),
                100.0 * r.agg_hit_rate()
            ));
        }
        if let Some(s) = &self.sharding {
            line.push_str(&format!("  [{}]", s.label()));
        }
        line
    }
}

/// Analyze a worker-attributed profile under a policy.
///
/// * serial time = Σ modeled kernel times (single stream);
/// * per-stage parallel time = max over workers of that worker's Σ;
/// * mixing: within the FP+NA window, DM kernels are compute-bound and
///   TB/EW/DR kernels memory-bound; co-running them takes
///   `max(Σ_dm, Σ_mem)` instead of `Σ_dm + Σ_mem` — the idealized bound
///   of §5 guideline 1 (perfect overlap, no interference), reported as
///   such in the ablation.
pub fn analyze(
    profile: &Profile,
    workers: usize,
    mixing: bool,
    policy: SchedulePolicy,
    _gpu: &GpuModel,
) -> ScheduleReport {
    let modeled = |k: &crate::profiler::ProfiledKernel| -> f64 {
        k.metrics.as_ref().map(|m| m.time_ns).unwrap_or(0.0)
    };
    let serial: f64 = profile.kernels.iter().map(modeled).sum();

    // per-stage per-worker sums
    let mut stage_worker: BTreeMap<(StageId, usize), f64> = BTreeMap::new();
    for k in &profile.kernels {
        *stage_worker.entry((k.stage, k.worker)).or_insert(0.0) += modeled(k);
    }
    let stage_makespan = |stage: StageId| -> f64 {
        stage_worker
            .iter()
            .filter(|((s, _), _)| *s == stage)
            .map(|(_, &t)| t)
            .fold(0.0, f64::max)
    };

    let fp = stage_makespan(StageId::FeatureProjection);
    let na = stage_makespan(StageId::NeighborAggregation);
    let sa = stage_makespan(StageId::SemanticAggregation);

    let (fp_na, na_end) = if mixing {
        // idealized co-run of compute-bound vs memory-bound kernels over
        // the FP+NA window, still respecting the worker split for NA
        let window: Vec<&crate::profiler::ProfiledKernel> = profile
            .kernels
            .iter()
            .filter(|k| {
                matches!(
                    k.stage,
                    StageId::FeatureProjection | StageId::NeighborAggregation
                )
            })
            .collect();
        let compute: f64 = window
            .iter()
            .filter(|k| k.exec.ktype == KernelType::DenseMatmul)
            .map(|k| modeled(k))
            .sum();
        let memory: f64 = window
            .iter()
            .filter(|k| k.exec.ktype != KernelType::DenseMatmul)
            .map(|k| modeled(k))
            .sum();
        // memory side still parallelizes over workers; compute side is a
        // single co-scheduled stream
        let mem_parallel = memory / workers.max(1) as f64;
        let t = compute.max(mem_parallel).max(na / workers.max(1) as f64);
        (t, t)
    } else {
        (fp + na, fp + na)
    };

    let makespan = fp_na + sa;
    ScheduleReport {
        policy,
        workers,
        modeled_serial_ns: serial,
        modeled_makespan_ns: makespan,
        speedup: if makespan > 0.0 { serial / makespan } else { 1.0 },
        na_makespan_ns: na,
        barrier_at_ns: na_end,
        reuse: None,
        sharding: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpumodel::GpuModel;
    use crate::kernels::{KernelCounters, KernelExec};

    #[test]
    fn lpt_balances() {
        let costs = vec![5.0, 3.0, 3.0, 2.0, 1.0];
        let a = lpt_assign(&costs, 2);
        let mut load = [0.0f64; 2];
        for (i, &w) in a.iter().enumerate() {
            load[w] += costs[i];
        }
        // LPT on these costs gives a 7/7 split
        assert!((load[0] - load[1]).abs() < 1.01, "loads {load:?}");
    }

    #[test]
    fn lpt_single_worker() {
        let a = lpt_assign(&[1.0, 2.0], 1);
        assert_eq!(a, vec![0, 0]);
        let empty = lpt_assign(&[], 4);
        assert!(empty.is_empty());
    }

    fn mk_profile(workers: usize) -> Profile {
        let mut p = Profile::default();
        let exec = |ktype| KernelExec {
            name: "k",
            ktype,
            counters: KernelCounters {
                flops: 1_000_000,
                bytes_read: 4_000_000,
                bytes_written: 4_000_000,
            },
            wall_nanos: 100,
            trace: None,
        };
        p.record(
            vec![exec(KernelType::DenseMatmul)],
            StageId::FeatureProjection,
            None,
            0,
            0,
        );
        for w in 0..workers {
            p.record(
                vec![exec(KernelType::TopologyBased)],
                StageId::NeighborAggregation,
                Some("sg"),
                w,
                0,
            );
        }
        p.record(
            vec![exec(KernelType::ElementWise)],
            StageId::SemanticAggregation,
            None,
            0,
            0,
        );
        p.attach_metrics(&GpuModel::default());
        p
    }

    #[test]
    fn parallel_na_shrinks_makespan() {
        let p1 = mk_profile(1);
        // p2 has the same NA work split over 2 workers... approximate by
        // comparing 2-worker profile with twice the subgraphs
        let r1 = analyze(&p1, 1, false, SchedulePolicy::Sequential, &GpuModel::default());
        let p2 = mk_profile(2);
        let r2 = analyze(
            &p2,
            2,
            false,
            SchedulePolicy::InterSubgraphParallel { workers: 2 },
            &GpuModel::default(),
        );
        // r2 has 2 NA kernels but same makespan contribution as r1's one
        assert!(r2.na_makespan_ns <= r2.modeled_serial_ns);
        assert!(r1.modeled_makespan_ns <= r1.modeled_serial_ns + 1e-9);
        assert!(r2.modeled_makespan_ns < r2.modeled_serial_ns, "overlap should help");
    }

    #[test]
    fn mixing_bounded_by_max_resource() {
        let p = mk_profile(1);
        let plain = analyze(&p, 1, false, SchedulePolicy::Sequential, &GpuModel::default());
        let mixed = analyze(
            &p,
            1,
            true,
            SchedulePolicy::BoundAwareMixing { workers: 1 },
            &GpuModel::default(),
        );
        assert!(mixed.modeled_makespan_ns <= plain.modeled_makespan_ns + 1e-9);
        assert!(mixed.speedup >= plain.speedup - 1e-9);
    }

    #[test]
    fn summary_renders() {
        let p = mk_profile(1);
        let r = analyze(&p, 1, false, SchedulePolicy::Sequential, &GpuModel::default());
        assert!(r.summary().contains("sequential"));
    }
}
