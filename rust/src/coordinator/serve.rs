//! Batched inference serving loop — the end-to-end driver substrate.
//!
//! A minimal but real serving path in the vLLM-router mold: clients
//! submit embedding requests for target nodes — singles
//! ([`Server::submit`]) or typed batches ([`Server::submit_batch`]) —
//! and a dispatcher thread batches them (size- and time-bounded dynamic
//! batching over node ids) and hands each flattened batch to an
//! executor. The canonical executor is a
//! [`crate::session::Session`] built *inside* the dispatcher thread via
//! [`Server::start_session`] — any backend (native or PJRT) × any
//! schedule policy, with the plan, weights and compiled artifacts reused
//! across batches instead of rebuilt per call. Python never appears on
//! this path.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::reuse::ReuseStats;
use crate::session::{Session, SessionBuilder};
use crate::util::stats::Summary;
use crate::{Error, Result};

/// An embedding request: one or more target node ids sharing a reply
/// channel ([`Server::submit`] sends one id, [`Server::submit_batch`] a
/// typed batch).
#[derive(Debug)]
pub struct Request {
    /// Target node ids to embed (never empty).
    pub node_ids: Vec<u32>,
    /// Submission timestamp.
    pub submitted: Instant,
    /// Completion channel.
    pub reply: Reply,
}

/// The reply side of a [`Request`].
#[derive(Debug)]
pub enum Reply {
    /// One embedding row ([`Server::submit`]).
    Single(mpsc::Sender<Vec<f32>>),
    /// All rows of the request, in submission order
    /// ([`Server::submit_batch`]).
    Batch(mpsc::Sender<Vec<Vec<f32>>>),
}

/// Dynamic batching configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Maximum node ids per executor dispatch. The dispatcher stops
    /// filling a batch once this many ids are queued; a flattened queue
    /// that still exceeds it (a single oversized
    /// [`Server::submit_batch`], or a last request overshooting the
    /// fill) is **chunked into `max_batch`-sized dispatches** — so with
    /// sampling configured, every executed subgraph stays batch-sized
    /// instead of ballooning with the request. Each request's rows are
    /// reassembled across chunks before its one reply is sent.
    /// Shard-exposing executors ([`BatchExecutor::shards`] `> 1`) bound
    /// dispatches at `max_batch` ids *per shard* instead, so concurrent
    /// per-shard sub-batches stay batch-sized individually.
    pub max_batch: usize,
    /// Maximum time the dispatcher waits to fill a batch.
    pub flush_after: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { max_batch: 32, flush_after: Duration::from_millis(2) }
    }
}

/// Aggregate serving statistics. Counts are in node ids (embedding
/// rows): a [`Server::submit_batch`] of `k` ids contributes `k` to
/// `completed` but one latency sample.
#[derive(Debug, Clone)]
pub struct ServeStats {
    /// Completed node-id count (embedding rows delivered).
    pub completed: u64,
    /// Executed dispatch count.
    pub batches: u64,
    /// End-to-end latency summary, one sample per request
    /// (nanoseconds).
    pub latency: Summary,
    /// Embedding rows per second over the serving window.
    pub throughput_rps: f64,
    /// Mean node ids per dispatch.
    pub mean_batch: f64,
    /// Cumulative reuse-cache counters of the executor's session, when
    /// it serves through cross-request reuse (`None` otherwise).
    pub reuse: Option<ReuseStats>,
}

/// Batch executor: given the node ids of one batch, return one embedding
/// row per id. Implemented over PJRT in the e2e example. Deliberately
/// not `Send` — the executor lives entirely inside the dispatcher thread
/// (constructed there via [`Server::start_with`]), which is what lets
/// PJRT executables (Rc internals) serve requests.
pub trait BatchExecutor {
    /// Execute one batch.
    fn execute(&mut self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>>;

    /// Cumulative reuse-cache counters, when the executor serves through
    /// a session with cross-request reuse enabled. The dispatcher
    /// snapshots this after every batch into [`ServeStats::reuse`].
    fn reuse_stats(&self) -> Option<ReuseStats> {
        None
    }

    /// Number of shard-affine dispatch lanes this executor exposes.
    /// When `> 1` the dispatcher sorts each flattened queue by
    /// [`BatchExecutor::shard_of`] and dispatches **shard-grouped
    /// rounds**: each `execute` call carries up to `max_batch` ids from
    /// every shard, contiguous per shard, so a sessionized executor
    /// splits it into per-shard sub-batches (each its own
    /// `max_batch`-bounded sampled subgraph, each against its own
    /// reuse-cache lane) and executes them concurrently. The default
    /// (1) keeps plain FIFO `max_batch` chunking.
    fn shards(&self) -> usize {
        1
    }

    /// Owning shard-lane of a node id (only consulted when
    /// [`BatchExecutor::shards`] `> 1`).
    fn shard_of(&self, _node_id: u32) -> usize {
        0
    }
}

impl<F> BatchExecutor for F
where
    F: FnMut(&[u32]) -> Result<Vec<Vec<f32>>>,
{
    fn execute(&mut self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>> {
        self(node_ids)
    }
}

/// The serving coordinator: owns the dispatcher thread.
pub struct Server {
    tx: Option<mpsc::Sender<Request>>,
    handle: Option<std::thread::JoinHandle<()>>,
    stats: Arc<Mutex<RawStats>>,
    started: Instant,
}

#[derive(Debug, Default)]
struct RawStats {
    completed: u64,
    batches: u64,
    latencies_ns: Vec<f64>,
    batch_sizes: Vec<usize>,
    reuse: Option<ReuseStats>,
}

impl Server {
    /// Start the dispatcher with the given (Send) executor.
    pub fn start(config: ServeConfig, executor: impl BatchExecutor + Send + 'static) -> Server {
        Self::start_with(config, move || executor)
    }

    /// Start the dispatcher, constructing the executor *inside* the
    /// dispatcher thread. Needed for executors that are not `Send` —
    /// the PJRT loaded executable holds `Rc` internals, so the e2e
    /// driver compiles its artifact in-thread via this entry point.
    pub fn start_with<E, F>(config: ServeConfig, make_executor: F) -> Server
    where
        E: BatchExecutor + 'static,
        F: FnOnce() -> E + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Request>();
        let stats = Arc::new(Mutex::new(RawStats::default()));
        let stats_w = Arc::clone(&stats);
        let handle = std::thread::spawn(move || {
            let mut executor = make_executor();
            let mut pending: Vec<Request> = Vec::new();
            loop {
                // block for the first request of a batch
                let first = if pending.is_empty() {
                    match rx.recv() {
                        Ok(r) => Some(r),
                        Err(_) => None, // channel closed: drain and exit
                    }
                } else {
                    None
                };
                if let Some(r) = first {
                    pending.push(r);
                } else if pending.is_empty() {
                    break;
                }
                // fill the dispatch until max_batch *ids* are queued or
                // flush_after expires
                let deadline = Instant::now() + config.flush_after;
                let mut queued: usize = pending.iter().map(|r| r.node_ids.len()).sum();
                while queued < config.max_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => {
                            queued += r.node_ids.len();
                            pending.push(r);
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => break,
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                }
                // execute the queued ids: a flattened queue can exceed
                // max_batch (one oversized submit_batch, or a last
                // request overshooting the fill). Single-lane executors
                // take the direct path — max_batch-sized chunks in
                // queue order, so every sampled subgraph stays
                // batch-sized. Shard-exposing executors get
                // shard-grouped *rounds*: each dispatch carries up to
                // max_batch ids from EVERY shard (ids sorted by owner),
                // so the sessionized executor splits it into per-shard
                // sub-batches — each its own max_batch-bounded sampled
                // subgraph — and executes them concurrently. Either
                // way, each request's rows are reassembled before its
                // one reply.
                let batch: Vec<Request> = std::mem::take(&mut pending);
                let ids: Vec<u32> =
                    batch.iter().flat_map(|r| r.node_ids.iter().copied()).collect();
                let cap = config.max_batch.max(1);
                let lanes = executor.shards().max(1);
                // group positions by owner shard before the executor is
                // mutably borrowed by dispatching
                let groups: Option<Vec<Vec<usize>>> = (lanes > 1).then(|| {
                    let mut g: Vec<Vec<usize>> = vec![Vec::new(); lanes];
                    for (pos, &id) in ids.iter().enumerate() {
                        g[executor.shard_of(id).min(lanes - 1)].push(pos);
                    }
                    g
                });
                // one executor dispatch; records stats, None on failure
                let mut run_chunk = |chunk_ids: &[u32]| -> Option<Vec<Vec<f32>>> {
                    match executor.execute(chunk_ids) {
                        Ok(r) if r.len() == chunk_ids.len() => {
                            let mut s = stats_w.lock().unwrap();
                            s.batches += 1;
                            s.batch_sizes.push(chunk_ids.len());
                            Some(r)
                        }
                        Ok(r) => {
                            eprintln!(
                                "serve: executor returned {} rows for {} ids",
                                r.len(),
                                chunk_ids.len()
                            );
                            None
                        }
                        Err(e) => {
                            eprintln!("serve: batch execution failed: {e}");
                            None
                        }
                    }
                };
                let mut rows: Vec<Vec<f32>> = Vec::with_capacity(ids.len());
                let mut failed = false;
                match groups {
                    Some(groups) => {
                        let rounds = groups
                            .iter()
                            .map(|g| g.len().div_ceil(cap))
                            .max()
                            .unwrap_or(0);
                        let mut slots: Vec<Option<Vec<f32>>> =
                            ids.iter().map(|_| None).collect();
                        for round in 0..rounds {
                            let chunk: Vec<usize> = groups
                                .iter()
                                .flat_map(|g| {
                                    g.iter().skip(round * cap).take(cap).copied()
                                })
                                .collect();
                            let chunk_ids: Vec<u32> =
                                chunk.iter().map(|&p| ids[p]).collect();
                            match run_chunk(&chunk_ids) {
                                Some(got) => {
                                    for (&p, row) in chunk.iter().zip(got) {
                                        slots[p] = Some(row);
                                    }
                                }
                                None => {
                                    failed = true;
                                    break;
                                }
                            }
                        }
                        if !failed {
                            rows = slots
                                .into_iter()
                                .map(|r| r.expect("every position dispatched"))
                                .collect();
                        }
                    }
                    None => {
                        // the common single-lane hot path: no grouping,
                        // no position indirection
                        for chunk in ids.chunks(cap) {
                            match run_chunk(chunk) {
                                Some(mut got) => rows.append(&mut got),
                                None => {
                                    failed = true;
                                    break;
                                }
                            }
                        }
                    }
                }
                if failed {
                    // drop the whole flattened batch; clients see a
                    // closed channel — but cache activity from the
                    // chunks that did run still reaches the stats
                    stats_w.lock().unwrap().reuse = executor.reuse_stats();
                    continue;
                }
                let done = Instant::now();
                let mut s = stats_w.lock().unwrap();
                s.reuse = executor.reuse_stats();
                let mut rows = rows.into_iter();
                for req in batch {
                    let take = req.node_ids.len();
                    s.completed += take as u64;
                    s.latencies_ns
                        .push(done.duration_since(req.submitted).as_nanos() as f64);
                    match req.reply {
                        Reply::Single(tx) => {
                            if let Some(row) = rows.next() {
                                let _ = tx.send(row);
                            }
                        }
                        Reply::Batch(tx) => {
                            let _ = tx.send(rows.by_ref().take(take).collect());
                        }
                    }
                }
            }
        });
        Server { tx: Some(tx), handle: Some(handle), stats, started: Instant::now() }
    }

    /// Start the dispatcher around a [`crate::session::Session`] built
    /// from `builder` *inside* the dispatcher thread — the one serving
    /// entry point for any backend × any schedule policy. Non-`Send`
    /// backends (PJRT executables hold `Rc` internals) are constructed
    /// where they run; the session's plan, weights, compiled artifacts
    /// and cached embeddings are reused across batches. If the session
    /// fails to build, every batch reports the build error.
    ///
    /// When the builder carries a sampling spec
    /// (`SessionBuilder::sampling`), each dispatch batches every queued
    /// request — singles and typed batches alike — into **one** sampled
    /// subgraph (chunked at `max_batch` ids, see [`ServeConfig`]) and
    /// executes only that, so serving cost tracks offered load instead
    /// of graph size. With `SessionBuilder::reuse` stacked on top, the
    /// session's reuse caches are shared across every dispatch this
    /// server executes, and their counters surface in
    /// [`ServeStats::reuse`].
    pub fn start_session(config: ServeConfig, builder: SessionBuilder) -> Server {
        Self::start_with(config, move || SessionExecutor {
            session: builder.build().map_err(|e| e.to_string()),
        })
    }

    /// Submit a single-node request; returns the reply receiver.
    pub fn submit(&self, node_id: u32) -> Result<mpsc::Receiver<Vec<f32>>> {
        let (reply, rx) = mpsc::channel();
        self.send(Request {
            node_ids: vec![node_id],
            submitted: Instant::now(),
            reply: Reply::Single(reply),
        })?;
        Ok(rx)
    }

    /// Submit a typed batch of node ids as one request; the returned
    /// receiver yields all embedding rows at once, in `node_ids` order.
    /// The whole batch rides one dispatch (it is never split), so a
    /// client that already knows its batch pays one queue round-trip
    /// instead of `node_ids.len()`.
    pub fn submit_batch(&self, node_ids: &[u32]) -> Result<mpsc::Receiver<Vec<Vec<f32>>>> {
        if node_ids.is_empty() {
            return Err(Error::config("submit_batch: empty batch"));
        }
        let (reply, rx) = mpsc::channel();
        self.send(Request {
            node_ids: node_ids.to_vec(),
            submitted: Instant::now(),
            reply: Reply::Batch(reply),
        })?;
        Ok(rx)
    }

    fn send(&self, req: Request) -> Result<()> {
        self.tx
            .as_ref()
            .ok_or_else(|| Error::Runtime("server stopped".into()))?
            .send(req)
            .map_err(|_| Error::Runtime("dispatcher gone".into()))
    }

    /// Snapshot of the current statistics without stopping the server.
    pub fn stats_snapshot(&self) -> ServeStats {
        let elapsed = self.started.elapsed().as_secs_f64();
        Self::mk_stats(&self.stats.lock().unwrap(), elapsed)
    }

    fn mk_stats(s: &RawStats, elapsed: f64) -> ServeStats {
        ServeStats {
            completed: s.completed,
            batches: s.batches,
            latency: Summary::of(&s.latencies_ns),
            throughput_rps: if elapsed > 0.0 { s.completed as f64 / elapsed } else { 0.0 },
            mean_batch: if s.batch_sizes.is_empty() {
                0.0
            } else {
                s.batch_sizes.iter().sum::<usize>() as f64 / s.batch_sizes.len() as f64
            },
            reuse: s.reuse.clone(),
        }
    }

    /// Stop accepting requests, drain the queue, and join the
    /// dispatcher. Idempotent with [`Drop`]: `shutdown` after an
    /// implicit drop-join returns whatever completed.
    fn stop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }

    /// Stop accepting requests, drain, and return final statistics.
    pub fn shutdown(mut self) -> ServeStats {
        self.stop();
        let elapsed = self.started.elapsed().as_secs_f64();
        let s = self.stats.lock().unwrap();
        Self::mk_stats(&s, elapsed)
    }
}

impl Drop for Server {
    /// Dropping a server without calling [`Server::shutdown`] still
    /// drains in-flight requests and joins the dispatcher — no detached
    /// thread, no lost replies.
    fn drop(&mut self) {
        self.stop();
    }
}

/// The canonical executor behind [`Server::start_session`]: a session
/// built inside the dispatcher thread (or the build error every batch
/// will report). Exposes the session's reuse counters to the stats
/// plumbing, which a plain closure executor cannot.
struct SessionExecutor {
    session: std::result::Result<Session, String>,
}

impl BatchExecutor for SessionExecutor {
    fn execute(&mut self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>> {
        match self.session.as_mut() {
            Ok(s) => s.run_batch(node_ids),
            Err(e) => Err(Error::Runtime(format!("session build failed: {e}"))),
        }
    }

    fn reuse_stats(&self) -> Option<ReuseStats> {
        self.session.as_ref().ok().and_then(|s| s.reuse_stats())
    }

    /// Shard-affine dispatch applies only on the sampled batch path: a
    /// partitioned session without sampling serves from the cached
    /// full-graph forward, where grouping would only fragment dispatches.
    fn shards(&self) -> usize {
        self.session
            .as_ref()
            .ok()
            .filter(|s| s.sampling().is_some())
            .and_then(|s| s.partition())
            .map(|p| p.num_shards())
            .unwrap_or(1)
    }

    fn shard_of(&self, node_id: u32) -> usize {
        self.session
            .as_ref()
            .ok()
            .and_then(|s| s.shard_of(node_id))
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_executor(ids: &[u32]) -> Result<Vec<Vec<f32>>> {
        Ok(ids.iter().map(|&i| vec![i as f32, 2.0 * i as f32]).collect())
    }

    #[test]
    fn serves_and_replies() {
        let server = Server::start(ServeConfig::default(), echo_executor);
        let rx = server.submit(7).unwrap();
        let row = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(row, vec![7.0, 14.0]);
        let stats = server.shutdown();
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.batches, 1);
        assert!(stats.latency.median > 0.0);
    }

    #[test]
    fn batches_multiple_requests() {
        let server = Server::start(
            ServeConfig { max_batch: 8, flush_after: Duration::from_millis(50) },
            echo_executor,
        );
        let rxs: Vec<_> = (0..8).map(|i| server.submit(i).unwrap()).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let row = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(row[0], i as f32);
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 8);
        // with a generous flush window most requests share batches
        assert!(stats.batches <= 8);
        assert!(stats.mean_batch >= 1.0);
    }

    #[test]
    fn submit_batch_returns_rows_in_order() {
        let server = Server::start(ServeConfig::default(), echo_executor);
        let rx = server.submit_batch(&[4, 1, 9]).unwrap();
        let rows = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0], vec![4.0, 8.0]);
        assert_eq!(rows[1], vec![1.0, 2.0]);
        assert_eq!(rows[2], vec![9.0, 18.0]);
        assert!(server.submit_batch(&[]).is_err());
        let stats = server.shutdown();
        assert_eq!(stats.completed, 3);
        assert_eq!(stats.batches, 1);
    }

    #[test]
    fn submit_batch_and_singles_share_a_dispatch() {
        let server = Server::start(
            ServeConfig { max_batch: 16, flush_after: Duration::from_millis(50) },
            echo_executor,
        );
        let single = server.submit(7).unwrap();
        let batch = server.submit_batch(&[1, 2, 3]).unwrap();
        assert_eq!(single.recv_timeout(Duration::from_secs(5)).unwrap(), vec![7.0, 14.0]);
        let rows = batch.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(rows[2], vec![3.0, 6.0]);
        let stats = server.shutdown();
        assert_eq!(stats.completed, 4);
        // with the generous flush window both requests ride one dispatch
        assert!(stats.batches <= 2);
    }

    #[test]
    fn shutdown_with_pending_batches_drains_them() {
        // shutdown immediately after queueing typed batches: every
        // receiver must still get its full row set (drain semantics)
        let server = Server::start(ServeConfig::default(), echo_executor);
        let rxs: Vec<_> =
            (0..10).map(|i| server.submit_batch(&[i, i + 100]).unwrap()).collect();
        let stats = server.shutdown();
        assert_eq!(stats.completed, 20);
        for (i, rx) in rxs.into_iter().enumerate() {
            let rows = rx.try_recv().expect("shutdown must drain pending batches");
            assert_eq!(rows.len(), 2);
            assert_eq!(rows[0][0], i as f32);
            assert_eq!(rows[1][0], (i + 100) as f32);
        }
    }

    #[test]
    fn oversized_batch_chunks_into_max_batch_dispatches() {
        let server = Server::start(
            ServeConfig { max_batch: 4, flush_after: Duration::from_millis(1) },
            echo_executor,
        );
        let ids: Vec<u32> = (0..13).collect();
        let rx = server.submit_batch(&ids).unwrap();
        let rows = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        // the one reply carries every row, in submission order, even
        // though execution was chunked
        assert_eq!(rows.len(), 13);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row[0], i as f32);
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 13);
        assert_eq!(
            stats.batches, 4,
            "13 ids at max_batch 4 execute as ceil(13/4) = 4 dispatches"
        );
        assert!(stats.mean_batch <= 4.0);
    }

    #[test]
    fn executor_error_mid_chunk_drops_the_whole_batch() {
        // executor fails on the second chunk: the client must see a
        // closed channel, not a partial reply
        let mut calls = 0;
        let server = Server::start(
            ServeConfig { max_batch: 4, flush_after: Duration::from_millis(1) },
            move |ids: &[u32]| -> Result<Vec<Vec<f32>>> {
                calls += 1;
                if calls > 1 {
                    return Err(Error::Runtime("chunk 2 boom".into()));
                }
                Ok(ids.iter().map(|&i| vec![i as f32]).collect())
            },
        );
        let ids: Vec<u32> = (0..8).collect();
        let rx = server.submit_batch(&ids).unwrap();
        let stats = server.shutdown();
        assert_eq!(stats.completed, 0);
        assert!(rx.try_recv().is_err(), "failed batches drop their replies");
        assert_eq!(stats.batches, 1, "only the successful chunk counts");
    }

    #[test]
    fn shutdown_drains() {
        let server = Server::start(ServeConfig::default(), echo_executor);
        let mut rxs = Vec::new();
        for i in 0..20 {
            rxs.push(server.submit(i).unwrap());
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 20);
        for rx in rxs {
            assert!(rx.try_recv().is_ok());
        }
    }

    #[test]
    fn executor_error_drops_batch() {
        let server = Server::start(
            ServeConfig::default(),
            |_ids: &[u32]| -> Result<Vec<Vec<f32>>> {
                Err(Error::Runtime("boom".into()))
            },
        );
        let rx = server.submit(1).unwrap();
        let stats = server.shutdown();
        assert_eq!(stats.completed, 0);
        assert!(rx.try_recv().is_err());
    }

    #[test]
    fn throughput_accounting() {
        let server = Server::start(ServeConfig::default(), echo_executor);
        for i in 0..50 {
            let rx = server.submit(i).unwrap();
            let _ = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 50);
        assert!(stats.throughput_rps > 0.0);
    }

    #[test]
    fn drop_joins_dispatcher_and_drains() {
        // dropping without shutdown() must still deliver every pending
        // reply — Drop closes the channel and joins the dispatcher
        let server = Server::start(ServeConfig::default(), echo_executor);
        let rxs: Vec<_> = (0..20).map(|i| server.submit(i).unwrap()).collect();
        drop(server);
        for (i, rx) in rxs.into_iter().enumerate() {
            let row = rx.try_recv().expect("drop must drain pending requests");
            assert_eq!(row[0], i as f32);
        }
    }

    #[test]
    fn idle_shutdown_reports_empty_stats() {
        let server = Server::start(ServeConfig::default(), echo_executor);
        let stats = server.shutdown();
        assert_eq!(stats.completed, 0);
        assert_eq!(stats.batches, 0);
        assert_eq!(stats.mean_batch, 0.0);
    }

    #[test]
    fn stats_snapshot_does_not_stop() {
        let server = Server::start(ServeConfig::default(), echo_executor);
        let rx = server.submit(3).unwrap();
        let _ = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let snap = server.stats_snapshot();
        assert!(snap.completed >= 1);
        // server still serves after a snapshot
        let rx = server.submit(4).unwrap();
        assert!(rx.recv_timeout(Duration::from_secs(5)).is_ok());
        let stats = server.shutdown();
        assert_eq!(stats.completed, 2);
    }

    #[test]
    fn serves_through_a_session() {
        use crate::datasets::{DatasetId, DatasetScale};
        use crate::session::Session;
        let builder = Session::builder()
            .dataset(DatasetId::Imdb)
            .scale(DatasetScale::ci());
        let server = Server::start_session(ServeConfig::default(), builder);
        let rxs: Vec<_> = (0..16).map(|i| server.submit(i).unwrap()).collect();
        for rx in rxs {
            let row = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert!(!row.is_empty());
            assert!(row.iter().all(|v| v.is_finite()));
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 16);
        // the session runs the forward once and reuses it across batches,
        // so 16 requests complete in (far) fewer forward passes than 16
        assert!(stats.batches <= 16);
    }

    #[test]
    fn session_build_failure_reported_per_batch() {
        use crate::session::Session;
        // no graph source: builder.build() fails inside the dispatcher
        let server = Server::start_session(ServeConfig::default(), Session::builder());
        let rx = server.submit(0).unwrap();
        let stats = server.shutdown();
        assert_eq!(stats.completed, 0);
        assert!(rx.try_recv().is_err(), "failed batches drop their replies");
    }
}
