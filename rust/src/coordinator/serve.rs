//! Batched inference serving loop — the end-to-end driver substrate.
//!
//! A minimal but real serving path in the vLLM-router mold: clients
//! submit embedding requests for target nodes; a dispatcher thread
//! batches them (size- and time-bounded dynamic batching) and hands each
//! batch to an executor. The canonical executor is a
//! [`crate::session::Session`] built *inside* the dispatcher thread via
//! [`Server::start_session`] — any backend (native or PJRT) × any
//! schedule policy, with the plan, weights and compiled artifacts reused
//! across batches instead of rebuilt per call. Python never appears on
//! this path.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::session::SessionBuilder;
use crate::util::stats::Summary;
use crate::{Error, Result};

/// A single embedding request.
#[derive(Debug)]
pub struct Request {
    /// Target node id to embed.
    pub node_id: u32,
    /// Submission timestamp.
    pub submitted: Instant,
    /// Completion channel: receives the embedding row.
    pub reply: mpsc::Sender<Vec<f32>>,
}

/// Dynamic batching configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Maximum requests per batch.
    pub max_batch: usize,
    /// Maximum time the dispatcher waits to fill a batch.
    pub flush_after: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { max_batch: 32, flush_after: Duration::from_millis(2) }
    }
}

/// Aggregate serving statistics.
#[derive(Debug, Clone)]
pub struct ServeStats {
    /// Completed request count.
    pub completed: u64,
    /// Executed batch count.
    pub batches: u64,
    /// End-to-end latency summary (nanoseconds).
    pub latency: Summary,
    /// Requests per second over the serving window.
    pub throughput_rps: f64,
    /// Mean batch size.
    pub mean_batch: f64,
}

/// Batch executor: given the node ids of one batch, return one embedding
/// row per id. Implemented over PJRT in the e2e example. Deliberately
/// not `Send` — the executor lives entirely inside the dispatcher thread
/// (constructed there via [`Server::start_with`]), which is what lets
/// PJRT executables (Rc internals) serve requests.
pub trait BatchExecutor {
    /// Execute one batch.
    fn execute(&mut self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>>;
}

impl<F> BatchExecutor for F
where
    F: FnMut(&[u32]) -> Result<Vec<Vec<f32>>>,
{
    fn execute(&mut self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>> {
        self(node_ids)
    }
}

/// The serving coordinator: owns the dispatcher thread.
pub struct Server {
    tx: Option<mpsc::Sender<Request>>,
    handle: Option<std::thread::JoinHandle<()>>,
    stats: Arc<Mutex<RawStats>>,
    started: Instant,
}

#[derive(Debug, Default)]
struct RawStats {
    completed: u64,
    batches: u64,
    latencies_ns: Vec<f64>,
    batch_sizes: Vec<usize>,
}

impl Server {
    /// Start the dispatcher with the given (Send) executor.
    pub fn start(config: ServeConfig, executor: impl BatchExecutor + Send + 'static) -> Server {
        Self::start_with(config, move || executor)
    }

    /// Start the dispatcher, constructing the executor *inside* the
    /// dispatcher thread. Needed for executors that are not `Send` —
    /// the PJRT loaded executable holds `Rc` internals, so the e2e
    /// driver compiles its artifact in-thread via this entry point.
    pub fn start_with<E, F>(config: ServeConfig, make_executor: F) -> Server
    where
        E: BatchExecutor + 'static,
        F: FnOnce() -> E + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Request>();
        let stats = Arc::new(Mutex::new(RawStats::default()));
        let stats_w = Arc::clone(&stats);
        let handle = std::thread::spawn(move || {
            let mut executor = make_executor();
            let mut pending: Vec<Request> = Vec::new();
            loop {
                // block for the first request of a batch
                let first = if pending.is_empty() {
                    match rx.recv() {
                        Ok(r) => Some(r),
                        Err(_) => None, // channel closed: drain and exit
                    }
                } else {
                    None
                };
                if let Some(r) = first {
                    pending.push(r);
                } else if pending.is_empty() {
                    break;
                }
                // fill the batch until max_batch or flush_after expires
                let deadline = Instant::now() + config.flush_after;
                while pending.len() < config.max_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => pending.push(r),
                        Err(mpsc::RecvTimeoutError::Timeout) => break,
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                }
                // execute
                let batch: Vec<Request> = std::mem::take(&mut pending);
                let ids: Vec<u32> = batch.iter().map(|r| r.node_id).collect();
                match executor.execute(&ids) {
                    Ok(rows) => {
                        let done = Instant::now();
                        let mut s = stats_w.lock().unwrap();
                        s.batches += 1;
                        s.batch_sizes.push(batch.len());
                        for (req, row) in batch.into_iter().zip(rows) {
                            s.completed += 1;
                            s.latencies_ns
                                .push(done.duration_since(req.submitted).as_nanos() as f64);
                            let _ = req.reply.send(row);
                        }
                    }
                    Err(e) => {
                        eprintln!("serve: batch execution failed: {e}");
                        // drop the batch; clients see a closed channel
                    }
                }
            }
        });
        Server { tx: Some(tx), handle: Some(handle), stats, started: Instant::now() }
    }

    /// Start the dispatcher around a [`crate::session::Session`] built
    /// from `builder` *inside* the dispatcher thread — the one serving
    /// entry point for any backend × any schedule policy. Non-`Send`
    /// backends (PJRT executables hold `Rc` internals) are constructed
    /// where they run; the session's plan, weights, compiled artifacts
    /// and cached embeddings are reused across batches. If the session
    /// fails to build, every batch reports the build error.
    pub fn start_session(config: ServeConfig, builder: SessionBuilder) -> Server {
        Self::start_with(config, move || {
            let mut session = builder.build().map_err(|e| e.to_string());
            move |ids: &[u32]| -> Result<Vec<Vec<f32>>> {
                match session.as_mut() {
                    Ok(s) => s.run_batch(ids),
                    Err(e) => Err(Error::Runtime(format!("session build failed: {e}"))),
                }
            }
        })
    }

    /// Submit a request; returns the reply receiver.
    pub fn submit(&self, node_id: u32) -> Result<mpsc::Receiver<Vec<f32>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .as_ref()
            .ok_or_else(|| Error::Runtime("server stopped".into()))?
            .send(Request { node_id, submitted: Instant::now(), reply })
            .map_err(|_| Error::Runtime("dispatcher gone".into()))?;
        Ok(rx)
    }

    /// Snapshot of the current statistics without stopping the server.
    pub fn stats_snapshot(&self) -> ServeStats {
        let elapsed = self.started.elapsed().as_secs_f64();
        Self::mk_stats(&self.stats.lock().unwrap(), elapsed)
    }

    fn mk_stats(s: &RawStats, elapsed: f64) -> ServeStats {
        ServeStats {
            completed: s.completed,
            batches: s.batches,
            latency: Summary::of(&s.latencies_ns),
            throughput_rps: if elapsed > 0.0 { s.completed as f64 / elapsed } else { 0.0 },
            mean_batch: if s.batch_sizes.is_empty() {
                0.0
            } else {
                s.batch_sizes.iter().sum::<usize>() as f64 / s.batch_sizes.len() as f64
            },
        }
    }

    /// Stop accepting requests, drain the queue, and join the
    /// dispatcher. Idempotent with [`Drop`]: `shutdown` after an
    /// implicit drop-join returns whatever completed.
    fn stop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }

    /// Stop accepting requests, drain, and return final statistics.
    pub fn shutdown(mut self) -> ServeStats {
        self.stop();
        let elapsed = self.started.elapsed().as_secs_f64();
        let s = self.stats.lock().unwrap();
        Self::mk_stats(&s, elapsed)
    }
}

impl Drop for Server {
    /// Dropping a server without calling [`Server::shutdown`] still
    /// drains in-flight requests and joins the dispatcher — no detached
    /// thread, no lost replies.
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_executor(ids: &[u32]) -> Result<Vec<Vec<f32>>> {
        Ok(ids.iter().map(|&i| vec![i as f32, 2.0 * i as f32]).collect())
    }

    #[test]
    fn serves_and_replies() {
        let server = Server::start(ServeConfig::default(), echo_executor);
        let rx = server.submit(7).unwrap();
        let row = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(row, vec![7.0, 14.0]);
        let stats = server.shutdown();
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.batches, 1);
        assert!(stats.latency.median > 0.0);
    }

    #[test]
    fn batches_multiple_requests() {
        let server = Server::start(
            ServeConfig { max_batch: 8, flush_after: Duration::from_millis(50) },
            echo_executor,
        );
        let rxs: Vec<_> = (0..8).map(|i| server.submit(i).unwrap()).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let row = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(row[0], i as f32);
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 8);
        // with a generous flush window most requests share batches
        assert!(stats.batches <= 8);
        assert!(stats.mean_batch >= 1.0);
    }

    #[test]
    fn shutdown_drains() {
        let server = Server::start(ServeConfig::default(), echo_executor);
        let mut rxs = Vec::new();
        for i in 0..20 {
            rxs.push(server.submit(i).unwrap());
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 20);
        for rx in rxs {
            assert!(rx.try_recv().is_ok());
        }
    }

    #[test]
    fn executor_error_drops_batch() {
        let server = Server::start(
            ServeConfig::default(),
            |_ids: &[u32]| -> Result<Vec<Vec<f32>>> {
                Err(Error::Runtime("boom".into()))
            },
        );
        let rx = server.submit(1).unwrap();
        let stats = server.shutdown();
        assert_eq!(stats.completed, 0);
        assert!(rx.try_recv().is_err());
    }

    #[test]
    fn throughput_accounting() {
        let server = Server::start(ServeConfig::default(), echo_executor);
        for i in 0..50 {
            let rx = server.submit(i).unwrap();
            let _ = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 50);
        assert!(stats.throughput_rps > 0.0);
    }

    #[test]
    fn drop_joins_dispatcher_and_drains() {
        // dropping without shutdown() must still deliver every pending
        // reply — Drop closes the channel and joins the dispatcher
        let server = Server::start(ServeConfig::default(), echo_executor);
        let rxs: Vec<_> = (0..20).map(|i| server.submit(i).unwrap()).collect();
        drop(server);
        for (i, rx) in rxs.into_iter().enumerate() {
            let row = rx.try_recv().expect("drop must drain pending requests");
            assert_eq!(row[0], i as f32);
        }
    }

    #[test]
    fn idle_shutdown_reports_empty_stats() {
        let server = Server::start(ServeConfig::default(), echo_executor);
        let stats = server.shutdown();
        assert_eq!(stats.completed, 0);
        assert_eq!(stats.batches, 0);
        assert_eq!(stats.mean_batch, 0.0);
    }

    #[test]
    fn stats_snapshot_does_not_stop() {
        let server = Server::start(ServeConfig::default(), echo_executor);
        let rx = server.submit(3).unwrap();
        let _ = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let snap = server.stats_snapshot();
        assert!(snap.completed >= 1);
        // server still serves after a snapshot
        let rx = server.submit(4).unwrap();
        assert!(rx.recv_timeout(Duration::from_secs(5)).is_ok());
        let stats = server.shutdown();
        assert_eq!(stats.completed, 2);
    }

    #[test]
    fn serves_through_a_session() {
        use crate::datasets::{DatasetId, DatasetScale};
        use crate::session::Session;
        let builder = Session::builder()
            .dataset(DatasetId::Imdb)
            .scale(DatasetScale::ci());
        let server = Server::start_session(ServeConfig::default(), builder);
        let rxs: Vec<_> = (0..16).map(|i| server.submit(i).unwrap()).collect();
        for rx in rxs {
            let row = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert!(!row.is_empty());
            assert!(row.iter().all(|v| v.is_finite()));
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 16);
        // the session runs the forward once and reuses it across batches,
        // so 16 requests complete in (far) fewer forward passes than 16
        assert!(stats.batches <= 16);
    }

    #[test]
    fn session_build_failure_reported_per_batch() {
        use crate::session::Session;
        // no graph source: builder.build() fails inside the dispatcher
        let server = Server::start_session(ServeConfig::default(), Session::builder());
        let rx = server.submit(0).unwrap();
        let stats = server.shutdown();
        assert_eq!(stats.completed, 0);
        assert!(rx.try_recv().is_err(), "failed batches drop their replies");
    }
}
