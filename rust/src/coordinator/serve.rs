//! Batched inference serving loop — legacy synchronous facade.
//!
//! [`Server`] keeps the original blocking API (`submit` /
//! `submit_batch` / `shutdown`) but is now a thin shim over the async
//! serving runtime in [`crate::serving`]: continuous batching against
//! a live queue, deadline/priority scheduling, token-bucket admission
//! and per-class latency sketches all live there. Requests submitted
//! through this facade ride priority class 0 with no deadline; the one
//! behavioral addition is the bounded queue
//! ([`ServeConfig::queue_cap`]), surfaced here as a typed
//! [`crate::Error::Serve`] instead of silent unbounded queueing.
//! New code should use [`crate::serving::AsyncServer`] (or
//! `SessionBuilder::serve_async`) directly.

use std::sync::mpsc;
use std::time::Duration;

use crate::serving::server::ReplyTo;
use crate::serving::{AsyncServer, ServingConfig, SubmitOpts};
use crate::session::SessionBuilder;
use crate::{Error, Result};

pub use crate::serving::{BatchExecutor, ClassStats, ServeError, ServeStats};

/// Dynamic batching configuration (legacy shape; converts into
/// [`ServingConfig`] with one priority class and no admission rate).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Maximum node ids per executor dispatch. A flattened wave that
    /// exceeds it (a single oversized [`Server::submit_batch`], or a
    /// last request overshooting the fill) is **chunked into
    /// `max_batch`-sized dispatches** — so with sampling configured,
    /// every executed subgraph stays batch-sized instead of ballooning
    /// with the request. Shard-exposing executors
    /// ([`BatchExecutor::shards`] `> 1`) bound dispatches at
    /// `max_batch` ids *per shard* instead, so concurrent per-shard
    /// sub-batches stay batch-sized individually.
    pub max_batch: usize,
    /// Maximum time the dispatcher waits to fill a batch.
    pub flush_after: Duration,
    /// Bound on queued (admitted, not yet dispatched) node ids; beyond
    /// it submissions fail with a typed error instead of queueing
    /// unboundedly.
    pub queue_cap: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 32,
            flush_after: Duration::from_millis(2),
            queue_cap: 4096,
        }
    }
}

impl From<ServeConfig> for ServingConfig {
    fn from(c: ServeConfig) -> ServingConfig {
        ServingConfig {
            max_batch: c.max_batch,
            flush_after: c.flush_after,
            queue_cap: c.queue_cap,
            priority_lanes: 1,
            ..ServingConfig::default()
        }
    }
}

/// The legacy serving coordinator: a blocking facade over
/// [`AsyncServer`]. Owns the dispatcher thread through it.
pub struct Server {
    inner: AsyncServer,
}

impl Server {
    /// Start the dispatcher with the given (Send) executor.
    pub fn start(config: ServeConfig, executor: impl BatchExecutor + Send + 'static) -> Server {
        Self::start_with(config, move || executor)
    }

    /// Start the dispatcher, constructing the executor *inside* the
    /// dispatcher thread. Needed for executors that are not `Send` —
    /// the PJRT loaded executable holds `Rc` internals, so the e2e
    /// driver compiles its artifact in-thread via this entry point.
    pub fn start_with<E, F>(config: ServeConfig, make_executor: F) -> Server
    where
        E: BatchExecutor + 'static,
        F: FnOnce() -> E + Send + 'static,
    {
        Server { inner: AsyncServer::start_with(config.into(), make_executor) }
    }

    /// Start the dispatcher around a [`crate::session::Session`] built
    /// from `builder` *inside* the dispatcher thread — the one serving
    /// entry point for any backend × any schedule policy. Non-`Send`
    /// backends (PJRT executables hold `Rc` internals) are constructed
    /// where they run; the session's plan, weights, compiled artifacts
    /// and cached embeddings are reused across batches. If the session
    /// fails to build, every batch reports the build error.
    ///
    /// When the builder carries a sampling spec
    /// (`SessionBuilder::sampling`), each dispatch batches every queued
    /// request — singles and typed batches alike — into **one** sampled
    /// subgraph (chunked at `max_batch` ids, see [`ServeConfig`]) and
    /// executes only that, so serving cost tracks offered load instead
    /// of graph size. With `SessionBuilder::reuse` stacked on top, the
    /// session's reuse caches are shared across every dispatch this
    /// server executes, and their counters surface in
    /// [`ServeStats::reuse`].
    pub fn start_session(config: ServeConfig, builder: SessionBuilder) -> Server {
        Server { inner: AsyncServer::start_session(config.into(), builder) }
    }

    /// Submit a single-node request; returns the reply receiver. Fails
    /// with [`Error::Serve`] if the bounded queue is full or the server
    /// has stopped.
    pub fn submit(&self, node_id: u32) -> Result<mpsc::Receiver<Vec<f32>>> {
        let (reply, rx) = mpsc::channel();
        self.inner
            .submit_reply(&[node_id], SubmitOpts::default(), ReplyTo::Single(reply))
            .map_err(Error::Serve)?;
        Ok(rx)
    }

    /// Submit a typed batch of node ids as one request; the returned
    /// receiver yields all embedding rows at once, in `node_ids` order.
    /// The whole batch rides one dispatch (it is never split), so a
    /// client that already knows its batch pays one queue round-trip
    /// instead of `node_ids.len()`.
    pub fn submit_batch(&self, node_ids: &[u32]) -> Result<mpsc::Receiver<Vec<Vec<f32>>>> {
        if node_ids.is_empty() {
            return Err(Error::config("submit_batch: empty batch"));
        }
        let (reply, rx) = mpsc::channel();
        self.inner
            .submit_reply(node_ids, SubmitOpts::default(), ReplyTo::Rows(reply))
            .map_err(Error::Serve)?;
        Ok(rx)
    }

    /// Snapshot of the current statistics without stopping the server.
    pub fn stats_snapshot(&self) -> ServeStats {
        self.inner.stats_snapshot()
    }

    /// Stop accepting requests, drain, and return final statistics.
    pub fn shutdown(self) -> ServeStats {
        self.inner.shutdown()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_executor(ids: &[u32]) -> Result<Vec<Vec<f32>>> {
        Ok(ids.iter().map(|&i| vec![i as f32, 2.0 * i as f32]).collect())
    }

    #[test]
    fn serves_and_replies() {
        let server = Server::start(ServeConfig::default(), echo_executor);
        let rx = server.submit(7).unwrap();
        let row = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(row, vec![7.0, 14.0]);
        let stats = server.shutdown();
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.batches, 1);
        assert!(stats.latency.median > 0.0);
    }

    #[test]
    fn batches_multiple_requests() {
        let server = Server::start(
            ServeConfig {
                max_batch: 8,
                flush_after: Duration::from_millis(50),
                ..ServeConfig::default()
            },
            echo_executor,
        );
        let rxs: Vec<_> = (0..8).map(|i| server.submit(i).unwrap()).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let row = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(row[0], i as f32);
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 8);
        // with a generous flush window most requests share batches
        assert!(stats.batches <= 8);
        assert!(stats.mean_batch >= 1.0);
    }

    #[test]
    fn submit_batch_returns_rows_in_order() {
        let server = Server::start(ServeConfig::default(), echo_executor);
        let rx = server.submit_batch(&[4, 1, 9]).unwrap();
        let rows = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0], vec![4.0, 8.0]);
        assert_eq!(rows[1], vec![1.0, 2.0]);
        assert_eq!(rows[2], vec![9.0, 18.0]);
        assert!(server.submit_batch(&[]).is_err());
        let stats = server.shutdown();
        assert_eq!(stats.completed, 3);
        assert_eq!(stats.batches, 1);
    }

    #[test]
    fn submit_batch_and_singles_share_a_dispatch() {
        let server = Server::start(
            ServeConfig {
                max_batch: 16,
                flush_after: Duration::from_millis(50),
                ..ServeConfig::default()
            },
            echo_executor,
        );
        let single = server.submit(7).unwrap();
        let batch = server.submit_batch(&[1, 2, 3]).unwrap();
        assert_eq!(single.recv_timeout(Duration::from_secs(5)).unwrap(), vec![7.0, 14.0]);
        let rows = batch.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(rows[2], vec![3.0, 6.0]);
        let stats = server.shutdown();
        assert_eq!(stats.completed, 4);
        // with the generous flush window both requests ride one dispatch
        assert!(stats.batches <= 2);
    }

    #[test]
    fn shutdown_with_pending_batches_drains_them() {
        // shutdown immediately after queueing typed batches: every
        // receiver must still get its full row set (drain semantics)
        let server = Server::start(ServeConfig::default(), echo_executor);
        let rxs: Vec<_> =
            (0..10).map(|i| server.submit_batch(&[i, i + 100]).unwrap()).collect();
        let stats = server.shutdown();
        assert_eq!(stats.completed, 20);
        for (i, rx) in rxs.into_iter().enumerate() {
            let rows = rx.try_recv().expect("shutdown must drain pending batches");
            assert_eq!(rows.len(), 2);
            assert_eq!(rows[0][0], i as f32);
            assert_eq!(rows[1][0], (i + 100) as f32);
        }
    }

    #[test]
    fn oversized_batch_chunks_into_max_batch_dispatches() {
        let server = Server::start(
            ServeConfig {
                max_batch: 4,
                flush_after: Duration::from_millis(1),
                ..ServeConfig::default()
            },
            echo_executor,
        );
        let ids: Vec<u32> = (0..13).collect();
        let rx = server.submit_batch(&ids).unwrap();
        let rows = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        // the one reply carries every row, in submission order, even
        // though execution was chunked
        assert_eq!(rows.len(), 13);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row[0], i as f32);
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 13);
        assert_eq!(
            stats.batches, 4,
            "13 ids at max_batch 4 execute as ceil(13/4) = 4 dispatches"
        );
        assert!(stats.mean_batch <= 4.0);
    }

    #[test]
    fn executor_error_mid_chunk_drops_the_whole_batch() {
        // executor fails on the second chunk: the client must see a
        // closed channel, not a partial reply
        let mut calls = 0;
        let server = Server::start(
            ServeConfig {
                max_batch: 4,
                flush_after: Duration::from_millis(1),
                ..ServeConfig::default()
            },
            move |ids: &[u32]| -> Result<Vec<Vec<f32>>> {
                calls += 1;
                if calls > 1 {
                    return Err(Error::Runtime("chunk 2 boom".into()));
                }
                Ok(ids.iter().map(|&i| vec![i as f32]).collect())
            },
        );
        let ids: Vec<u32> = (0..8).collect();
        let rx = server.submit_batch(&ids).unwrap();
        let stats = server.shutdown();
        assert_eq!(stats.completed, 0);
        assert!(rx.try_recv().is_err(), "failed batches drop their replies");
        assert_eq!(stats.batches, 1, "only the successful chunk counts");
    }

    #[test]
    fn shutdown_drains() {
        let server = Server::start(ServeConfig::default(), echo_executor);
        let mut rxs = Vec::new();
        for i in 0..20 {
            rxs.push(server.submit(i).unwrap());
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 20);
        for rx in rxs {
            assert!(rx.try_recv().is_ok());
        }
    }

    #[test]
    fn executor_error_drops_batch() {
        let server = Server::start(
            ServeConfig::default(),
            |_ids: &[u32]| -> Result<Vec<Vec<f32>>> {
                Err(Error::Runtime("boom".into()))
            },
        );
        let rx = server.submit(1).unwrap();
        let stats = server.shutdown();
        assert_eq!(stats.completed, 0);
        assert!(rx.try_recv().is_err());
    }

    #[test]
    fn queue_cap_rejects_with_typed_error() {
        // an executor that blocks forever on a gate, so queued ids pile
        // up; the 4th id must be refused with Error::Serve(QueueFull)
        let (gate_tx, gate_rx) = std::sync::mpsc::channel::<()>();
        let (entered_tx, entered_rx) = std::sync::mpsc::channel::<()>();
        let server = Server::start_with(
            ServeConfig {
                max_batch: 1,
                flush_after: Duration::from_millis(1),
                queue_cap: 3,
            },
            move || {
                move |ids: &[u32]| -> Result<Vec<Vec<f32>>> {
                    let _ = entered_tx.send(());
                    let _ = gate_rx.recv();
                    Ok(ids.iter().map(|&i| vec![i as f32]).collect())
                }
            },
        );
        let _first = server.submit(0).unwrap();
        entered_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        for i in 1..=3 {
            server.submit(i).unwrap();
        }
        match server.submit(4) {
            Err(Error::Serve(ServeError::QueueFull { queued: 3, cap: 3 })) => {}
            other => panic!("expected QueueFull, got {:?}", other.err()),
        }
        for _ in 0..4 {
            let _ = gate_tx.send(());
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 4);
        assert_eq!(stats.rejected_queue_full, 1);
    }

    #[test]
    fn throughput_accounting() {
        let server = Server::start(ServeConfig::default(), echo_executor);
        for i in 0..50 {
            let rx = server.submit(i).unwrap();
            let _ = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 50);
        assert!(stats.throughput_rps > 0.0);
    }

    #[test]
    fn drop_joins_dispatcher_and_drains() {
        // dropping without shutdown() must still deliver every pending
        // reply — Drop closes the loop and joins the dispatcher
        let server = Server::start(ServeConfig::default(), echo_executor);
        let rxs: Vec<_> = (0..20).map(|i| server.submit(i).unwrap()).collect();
        drop(server);
        for (i, rx) in rxs.into_iter().enumerate() {
            let row = rx.try_recv().expect("drop must drain pending requests");
            assert_eq!(row[0], i as f32);
        }
    }

    #[test]
    fn idle_shutdown_reports_empty_stats() {
        let server = Server::start(ServeConfig::default(), echo_executor);
        let stats = server.shutdown();
        assert_eq!(stats.completed, 0);
        assert_eq!(stats.batches, 0);
        assert_eq!(stats.mean_batch, 0.0);
    }

    #[test]
    fn stats_snapshot_does_not_stop() {
        let server = Server::start(ServeConfig::default(), echo_executor);
        let rx = server.submit(3).unwrap();
        let _ = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let snap = server.stats_snapshot();
        assert!(snap.completed >= 1);
        // server still serves after a snapshot
        let rx = server.submit(4).unwrap();
        assert!(rx.recv_timeout(Duration::from_secs(5)).is_ok());
        let stats = server.shutdown();
        assert_eq!(stats.completed, 2);
    }

    #[test]
    fn serves_through_a_session() {
        use crate::datasets::{DatasetId, DatasetScale};
        use crate::session::Session;
        let builder = Session::builder()
            .dataset(DatasetId::Imdb)
            .scale(DatasetScale::ci());
        let server = Server::start_session(ServeConfig::default(), builder);
        let rxs: Vec<_> = (0..16).map(|i| server.submit(i).unwrap()).collect();
        for rx in rxs {
            let row = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert!(!row.is_empty());
            assert!(row.iter().all(|v| v.is_finite()));
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 16);
        // the session runs the forward once and reuses it across batches,
        // so 16 requests complete in (far) fewer forward passes than 16
        assert!(stats.batches <= 16);
    }

    #[test]
    fn session_build_failure_reported_per_batch() {
        use crate::session::Session;
        // no graph source: builder.build() fails inside the dispatcher
        let server = Server::start_session(ServeConfig::default(), Session::builder());
        let rx = server.submit(0).unwrap();
        let stats = server.shutdown();
        assert_eq!(stats.completed, 0);
        assert!(rx.try_recv().is_err(), "failed batches drop their replies");
    }
}
