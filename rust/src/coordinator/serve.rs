//! Batched inference serving loop — the end-to-end driver substrate.
//!
//! A minimal but real serving path in the vLLM-router mold: clients
//! submit embedding requests for target nodes; a dispatcher thread
//! batches them (size- and time-bounded dynamic batching) and hands each
//! batch to an executor (the PJRT-compiled HAN forward in
//! `examples/e2e_inference.rs`, or the native engine in tests). Python
//! never appears on this path.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::util::stats::Summary;
use crate::{Error, Result};

/// A single embedding request.
#[derive(Debug)]
pub struct Request {
    /// Target node id to embed.
    pub node_id: u32,
    /// Submission timestamp.
    pub submitted: Instant,
    /// Completion channel: receives the embedding row.
    pub reply: mpsc::Sender<Vec<f32>>,
}

/// Dynamic batching configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Maximum requests per batch.
    pub max_batch: usize,
    /// Maximum time the dispatcher waits to fill a batch.
    pub flush_after: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { max_batch: 32, flush_after: Duration::from_millis(2) }
    }
}

/// Aggregate serving statistics.
#[derive(Debug, Clone)]
pub struct ServeStats {
    /// Completed request count.
    pub completed: u64,
    /// Executed batch count.
    pub batches: u64,
    /// End-to-end latency summary (nanoseconds).
    pub latency: Summary,
    /// Requests per second over the serving window.
    pub throughput_rps: f64,
    /// Mean batch size.
    pub mean_batch: f64,
}

/// Batch executor: given the node ids of one batch, return one embedding
/// row per id. Implemented over PJRT in the e2e example. Deliberately
/// not `Send` — the executor lives entirely inside the dispatcher thread
/// (constructed there via [`Server::start_with`]), which is what lets
/// PJRT executables (Rc internals) serve requests.
pub trait BatchExecutor {
    /// Execute one batch.
    fn execute(&mut self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>>;
}

impl<F> BatchExecutor for F
where
    F: FnMut(&[u32]) -> Result<Vec<Vec<f32>>>,
{
    fn execute(&mut self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>> {
        self(node_ids)
    }
}

/// The serving coordinator: owns the dispatcher thread.
pub struct Server {
    tx: Option<mpsc::Sender<Request>>,
    handle: Option<std::thread::JoinHandle<()>>,
    stats: Arc<Mutex<RawStats>>,
    started: Instant,
}

#[derive(Debug, Default)]
struct RawStats {
    completed: u64,
    batches: u64,
    latencies_ns: Vec<f64>,
    batch_sizes: Vec<usize>,
}

impl Server {
    /// Start the dispatcher with the given (Send) executor.
    pub fn start(config: ServeConfig, executor: impl BatchExecutor + Send + 'static) -> Server {
        Self::start_with(config, move || executor)
    }

    /// Start the dispatcher, constructing the executor *inside* the
    /// dispatcher thread. Needed for executors that are not `Send` —
    /// the PJRT loaded executable holds `Rc` internals, so the e2e
    /// driver compiles its artifact in-thread via this entry point.
    pub fn start_with<E, F>(config: ServeConfig, make_executor: F) -> Server
    where
        E: BatchExecutor + 'static,
        F: FnOnce() -> E + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Request>();
        let stats = Arc::new(Mutex::new(RawStats::default()));
        let stats_w = Arc::clone(&stats);
        let handle = std::thread::spawn(move || {
            let mut executor = make_executor();
            let mut pending: Vec<Request> = Vec::new();
            loop {
                // block for the first request of a batch
                let first = if pending.is_empty() {
                    match rx.recv() {
                        Ok(r) => Some(r),
                        Err(_) => None, // channel closed: drain and exit
                    }
                } else {
                    None
                };
                if let Some(r) = first {
                    pending.push(r);
                } else if pending.is_empty() {
                    break;
                }
                // fill the batch until max_batch or flush_after expires
                let deadline = Instant::now() + config.flush_after;
                while pending.len() < config.max_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => pending.push(r),
                        Err(mpsc::RecvTimeoutError::Timeout) => break,
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                }
                // execute
                let batch: Vec<Request> = std::mem::take(&mut pending);
                let ids: Vec<u32> = batch.iter().map(|r| r.node_id).collect();
                match executor.execute(&ids) {
                    Ok(rows) => {
                        let done = Instant::now();
                        let mut s = stats_w.lock().unwrap();
                        s.batches += 1;
                        s.batch_sizes.push(batch.len());
                        for (req, row) in batch.into_iter().zip(rows) {
                            s.completed += 1;
                            s.latencies_ns
                                .push(done.duration_since(req.submitted).as_nanos() as f64);
                            let _ = req.reply.send(row);
                        }
                    }
                    Err(e) => {
                        log::error!("batch execution failed: {e}");
                        // drop the batch; clients see a closed channel
                    }
                }
            }
        });
        Server { tx: Some(tx), handle: Some(handle), stats, started: Instant::now() }
    }

    /// Submit a request; returns the reply receiver.
    pub fn submit(&self, node_id: u32) -> Result<mpsc::Receiver<Vec<f32>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .as_ref()
            .ok_or_else(|| Error::Runtime("server stopped".into()))?
            .send(Request { node_id, submitted: Instant::now(), reply })
            .map_err(|_| Error::Runtime("dispatcher gone".into()))?;
        Ok(rx)
    }

    /// Stop accepting requests, drain, and return final statistics.
    pub fn shutdown(mut self) -> ServeStats {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        let elapsed = self.started.elapsed().as_secs_f64();
        let s = self.stats.lock().unwrap();
        ServeStats {
            completed: s.completed,
            batches: s.batches,
            latency: Summary::of(&s.latencies_ns),
            throughput_rps: if elapsed > 0.0 { s.completed as f64 / elapsed } else { 0.0 },
            mean_batch: if s.batch_sizes.is_empty() {
                0.0
            } else {
                s.batch_sizes.iter().sum::<usize>() as f64 / s.batch_sizes.len() as f64
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_executor(ids: &[u32]) -> Result<Vec<Vec<f32>>> {
        Ok(ids.iter().map(|&i| vec![i as f32, 2.0 * i as f32]).collect())
    }

    #[test]
    fn serves_and_replies() {
        let server = Server::start(ServeConfig::default(), echo_executor);
        let rx = server.submit(7).unwrap();
        let row = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(row, vec![7.0, 14.0]);
        let stats = server.shutdown();
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.batches, 1);
        assert!(stats.latency.median > 0.0);
    }

    #[test]
    fn batches_multiple_requests() {
        let server = Server::start(
            ServeConfig { max_batch: 8, flush_after: Duration::from_millis(50) },
            echo_executor,
        );
        let rxs: Vec<_> = (0..8).map(|i| server.submit(i).unwrap()).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let row = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(row[0], i as f32);
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 8);
        // with a generous flush window most requests share batches
        assert!(stats.batches <= 8);
        assert!(stats.mean_batch >= 1.0);
    }

    #[test]
    fn shutdown_drains() {
        let server = Server::start(ServeConfig::default(), echo_executor);
        let mut rxs = Vec::new();
        for i in 0..20 {
            rxs.push(server.submit(i).unwrap());
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 20);
        for rx in rxs {
            assert!(rx.try_recv().is_ok());
        }
    }

    #[test]
    fn executor_error_drops_batch() {
        let server = Server::start(
            ServeConfig::default(),
            |_ids: &[u32]| -> Result<Vec<Vec<f32>>> {
                Err(Error::Runtime("boom".into()))
            },
        );
        let rx = server.submit(1).unwrap();
        let stats = server.shutdown();
        assert_eq!(stats.completed, 0);
        assert!(rx.try_recv().is_err());
    }

    #[test]
    fn throughput_accounting() {
        let server = Server::start(ServeConfig::default(), echo_executor);
        for i in 0..50 {
            let rx = server.submit(i).unwrap();
            let _ = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 50);
        assert!(stats.throughput_rps > 0.0);
    }
}
