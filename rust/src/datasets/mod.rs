//! Benchmark datasets.
//!
//! The paper evaluates on three heterogeneous graphs — IMDB, ACM, DBLP —
//! plus the homogeneous Reddit graph for the GNN comparison (Table 2).
//! We have no network access and no licence bundle, so each dataset is
//! *synthesized deterministically* to the paper's published statistics:
//! exact node counts per type, exact feature dimensions per type, exact
//! edge counts per relation, with heavy-tailed degree distributions on
//! the many-to-many relations (see `spec.rs` for the verbatim Table 2
//! numbers and `synth.rs` for the generator). Every profile-level metric
//! the paper reports is a function of these statistics, so the synthetic
//! stand-ins preserve the characterization (DESIGN.md §4).
//!
//! Reddit (233k nodes / 115M edges) does not fit a 1-core CI box at full
//! scale; `reddit.rs` generates a degree-preserving scaled version
//! (DESIGN.md §4, EXPERIMENTS.md records the scale used per run).

pub mod reddit;
pub mod spec;
pub mod synth;

use crate::graph::HeteroGraph;
use crate::{Error, Result};

/// Identifier of a benchmark dataset.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DatasetId {
    /// IMDB heterogeneous graph (movies / directors / actors).
    Imdb,
    /// ACM heterogeneous graph (papers / authors / subjects).
    Acm,
    /// DBLP heterogeneous graph (authors / papers / terms / venues).
    Dblp,
    /// Scaled Reddit-like homogeneous graph (GNN comparison, Fig 5).
    RedditSim,
}

impl DatasetId {
    /// All heterogeneous datasets, in paper order.
    pub const HETERO: [DatasetId; 3] = [DatasetId::Imdb, DatasetId::Acm, DatasetId::Dblp];

    /// Short paper abbreviation (IM / AC / DB / RD).
    pub fn abbrev(self) -> &'static str {
        match self {
            DatasetId::Imdb => "IM",
            DatasetId::Acm => "AC",
            DatasetId::Dblp => "DB",
            DatasetId::RedditSim => "RD",
        }
    }

    /// Full name.
    pub fn name(self) -> &'static str {
        match self {
            DatasetId::Imdb => "IMDB",
            DatasetId::Acm => "ACM",
            DatasetId::Dblp => "DBLP",
            DatasetId::RedditSim => "Reddit-sim",
        }
    }

    /// Parse from a case-insensitive name or abbreviation.
    pub fn parse(s: &str) -> Result<DatasetId> {
        match s.to_ascii_lowercase().as_str() {
            "imdb" | "im" => Ok(DatasetId::Imdb),
            "acm" | "ac" => Ok(DatasetId::Acm),
            "dblp" | "db" => Ok(DatasetId::Dblp),
            "reddit" | "reddit-sim" | "rd" => Ok(DatasetId::RedditSim),
            _ => Err(Error::NotFound(format!("dataset '{s}'"))),
        }
    }

    /// Default metapaths used by the paper's HAN/MAGNN configurations.
    pub fn default_metapaths(self) -> Vec<&'static str> {
        match self {
            // movie-centric semantics: co-director / co-actor
            DatasetId::Imdb => vec!["MDM", "MAM"],
            // paper-centric semantics: co-author / co-subject
            DatasetId::Acm => vec!["PAP", "PSP"],
            // author-centric semantics (the HAN paper's DBLP setting)
            DatasetId::Dblp => vec!["APA", "APTPA", "APVPA"],
            DatasetId::RedditSim => vec![],
        }
    }
}

/// Scale knob for dataset synthesis.
///
/// `paper()` reproduces Table 2 exactly. `ci()` shrinks node counts,
/// feature dims and edge counts by a constant factor so the full test
/// suite runs quickly on a 1-core box; all *shape* conclusions are scale
/// free (the benches default to paper scale).
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetScale {
    /// Multiplier on node counts and edge counts (0 < f <= 1).
    pub topo_factor: f64,
    /// Multiplier on raw feature dims (0 < f <= 1).
    pub feat_factor: f64,
    /// RNG seed for all synthesis.
    pub seed: u64,
}

impl DatasetScale {
    /// Exact paper scale (Table 2).
    pub fn paper() -> DatasetScale {
        DatasetScale { topo_factor: 1.0, feat_factor: 1.0, seed: 0x46474e4e }
    }

    /// Small scale for unit/integration tests (~1/16 topology, 1/16 features).
    pub fn ci() -> DatasetScale {
        DatasetScale { topo_factor: 1.0 / 16.0, feat_factor: 1.0 / 16.0, seed: 0x46474e4e }
    }

    /// Arbitrary uniform scale factor.
    pub fn factor(f: f64) -> DatasetScale {
        DatasetScale { topo_factor: f, feat_factor: f, seed: 0x46474e4e }
    }

    /// Apply the topology factor to a count (at least 1).
    pub fn scale_count(&self, n: usize) -> usize {
        ((n as f64 * self.topo_factor).round() as usize).max(1)
    }

    /// Apply the feature factor to a dimension (at least 4).
    pub fn scale_dim(&self, d: usize) -> usize {
        ((d as f64 * self.feat_factor).round() as usize).max(4)
    }
}

/// Build a dataset at the given scale.
pub fn build(id: DatasetId, scale: &DatasetScale) -> Result<HeteroGraph> {
    match id {
        DatasetId::Imdb => synth::build_hetero(&spec::IMDB, scale),
        DatasetId::Acm => synth::build_hetero(&spec::ACM, scale),
        DatasetId::Dblp => synth::build_hetero(&spec::DBLP, scale),
        DatasetId::RedditSim => reddit::build(&reddit::RedditConfig::scaled(scale)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for id in [DatasetId::Imdb, DatasetId::Acm, DatasetId::Dblp, DatasetId::RedditSim] {
            assert_eq!(DatasetId::parse(id.name()).unwrap(), id);
            assert_eq!(DatasetId::parse(id.abbrev()).unwrap(), id);
        }
        assert!(DatasetId::parse("nope").is_err());
    }

    #[test]
    fn ci_scale_shrinks() {
        let s = DatasetScale::ci();
        assert_eq!(s.scale_count(16000), 1000);
        assert!(s.scale_count(3) >= 1);
        assert!(s.scale_dim(8) >= 4);
    }

    #[test]
    fn metapaths_are_well_formed() {
        for id in DatasetId::HETERO {
            let mps = id.default_metapaths();
            assert!(!mps.is_empty());
            for mp in mps {
                assert!(mp.len() >= 3);
                // symmetric metapaths start and end at the same type
                assert_eq!(mp.chars().next(), mp.chars().last());
            }
        }
    }
}
