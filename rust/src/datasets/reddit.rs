//! Reddit-like homogeneous graph for the GNN comparison (paper Fig 5).
//!
//! The real Reddit graph is 232,965 nodes / 114,615,892 edges / 602-dim
//! features — ~27 GiB of adjacency+features at f32, far beyond this
//! 1-core CI box. Per DESIGN.md §4 we generate a *degree-preserving
//! scaled* power-law graph: node count shrinks by `topo_factor`, the
//! average degree is preserved up to a configurable cap (the paper's avg
//! degree is 492; the default cap of 64 keeps Fig 5 sweeps tractable
//! while leaving the trend intact — the sweep multiplies the degree, and
//! trends, not absolutes, are the claim being reproduced).

use crate::datasets::DatasetScale;
use crate::graph::sparse::Csr;
use crate::graph::{HeteroGraph, HeteroGraphBuilder};
use crate::tensor::Tensor;
use crate::util::Pcg32;
use crate::Result;

/// Paper-published Reddit statistics.
pub const REDDIT_NODES: usize = 232_965;
/// Paper-published Reddit edge count.
pub const REDDIT_EDGES: usize = 114_615_892;
/// Paper-published Reddit feature dimension.
pub const REDDIT_FEAT_DIM: usize = 602;

/// Configuration for the scaled Reddit-like generator.
#[derive(Debug, Clone, PartialEq)]
pub struct RedditConfig {
    /// Number of nodes.
    pub nodes: usize,
    /// Target average degree (in-neighbors per node).
    pub avg_degree: usize,
    /// Feature dimension.
    pub feat_dim: usize,
    /// Power-law exponent for the degree distribution.
    pub alpha: f64,
    /// RNG seed.
    pub seed: u64,
}

impl RedditConfig {
    /// Derive a config from a [`DatasetScale`]; average degree capped at 64.
    pub fn scaled(scale: &DatasetScale) -> RedditConfig {
        let nodes = scale.scale_count(REDDIT_NODES / 10); // default 1/10 scale base
        let natural_avg = REDDIT_EDGES as f64 / REDDIT_NODES as f64; // ~492
        RedditConfig {
            nodes,
            avg_degree: (natural_avg as usize).min(64),
            feat_dim: scale.scale_dim(REDDIT_FEAT_DIM),
            alpha: 2.0,
            seed: scale.seed ^ 0x5EDD17,
        }
    }

    /// Small config for unit tests.
    pub fn tiny() -> RedditConfig {
        RedditConfig { nodes: 200, avg_degree: 8, feat_dim: 32, alpha: 2.0, seed: 7 }
    }
}

/// Build the homogeneous graph as a single-node-type [`HeteroGraph`] with
/// one `"U-U"` relation, so the same engine/kernels run GCN over it.
pub fn build(cfg: &RedditConfig) -> Result<HeteroGraph> {
    let mut rng = Pcg32::new(cfg.seed, 0);
    let edges_target = cfg.nodes * cfg.avg_degree;
    let deg = crate::datasets::synth::degree_sequence(
        crate::datasets::spec::DegreeModel::PowerLaw(cfg.alpha),
        cfg.nodes,
        cfg.nodes,
        edges_target.min(cfg.nodes * cfg.nodes),
        &mut rng,
    )?;
    let adj: Csr = crate::datasets::synth::random_bipartite(&deg, cfg.nodes, &mut rng);
    adj.validate()?;

    let mut frng = Pcg32::new(cfg.seed ^ 0xF00D, 1);
    let feats = Tensor::randn(cfg.nodes, cfg.feat_dim, 0.1, &mut frng);

    let mut b = HeteroGraphBuilder::new("Reddit-sim");
    let u = b.add_node_type("user", 'U', feats);
    b.add_relation("U-U", u, u, adj);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_builds_with_target_degree() {
        let g = build(&RedditConfig::tiny()).unwrap();
        assert_eq!(g.total_nodes(), 200);
        let rel = g.relation(0);
        let avg = rel.adj.avg_degree();
        assert!((avg - 8.0).abs() < 0.5, "avg degree {avg}");
    }

    #[test]
    fn scaled_config_caps_degree() {
        let cfg = RedditConfig::scaled(&DatasetScale::ci());
        assert!(cfg.avg_degree <= 64);
        assert!(cfg.nodes >= 1);
        let g = build(&RedditConfig { nodes: 500, ..cfg }).unwrap();
        g.validate().unwrap();
    }

    #[test]
    fn deterministic() {
        let a = build(&RedditConfig::tiny()).unwrap();
        let b = build(&RedditConfig::tiny()).unwrap();
        assert_eq!(a.relation(0).adj, b.relation(0).adj);
    }

    #[test]
    fn degrees_are_heavy_tailed() {
        let cfg = RedditConfig { nodes: 2000, avg_degree: 16, ..RedditConfig::tiny() };
        let g = build(&cfg).unwrap();
        let adj = &g.relation(0).adj;
        let max = adj.max_degree();
        assert!(
            max as f64 > 4.0 * adj.avg_degree(),
            "expected hubs: max {max} vs avg {}",
            adj.avg_degree()
        );
    }
}
