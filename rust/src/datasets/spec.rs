//! Verbatim Table 2 statistics of the paper's heterogeneous datasets.
//!
//! Each [`HeteroSpec`] pins: node types (name, tag, count, raw feature
//! dim) and relations (name, src tag, dst tag, edge count, degree model).
//! The synthesis in `synth.rs` reproduces these numbers exactly.

/// How destination-node degrees are distributed for a relation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DegreeModel {
    /// Every destination node has exactly one source neighbor
    /// (functional relations: each movie has one director, each paper one
    /// venue / one subject). Requires `edges == dst.count`.
    OnePerDst,
    /// Heavy-tailed (Zipf-ish) degrees with the given exponent; total
    /// edge count is matched exactly.
    PowerLaw(f64),
}

/// A node type row of Table 2.
#[derive(Debug, Clone, Copy)]
pub struct NodeSpec {
    /// Type name (e.g. `"movie"`).
    pub name: &'static str,
    /// Metapath tag (e.g. `'M'`).
    pub tag: char,
    /// Node count.
    pub count: usize,
    /// Raw feature dimension.
    pub feat_dim: usize,
    /// True when features are (row % dim) one-hot rather than dense random
    /// — Table 2's identity-feature types (feat_dim derived from a count).
    pub one_hot: bool,
}

/// A relation row of Table 2 (directed `src -> dst`).
#[derive(Debug, Clone, Copy)]
pub struct RelationSpec {
    /// Relation name as printed in the paper, `"<src>-<dst>"`.
    pub name: &'static str,
    /// Source node-type tag.
    pub src: char,
    /// Destination node-type tag.
    pub dst: char,
    /// Exact edge count.
    pub edges: usize,
    /// Degree distribution of destination nodes.
    pub degree: DegreeModel,
}

/// Full dataset specification.
#[derive(Debug, Clone, Copy)]
pub struct HeteroSpec {
    /// Dataset name.
    pub name: &'static str,
    /// Node type rows.
    pub nodes: &'static [NodeSpec],
    /// Relation rows.
    pub relations: &'static [RelationSpec],
}

/// IMDB: 4278 movies / 2081 directors / 5257 actors;
/// M features dense 3066-dim; D and A one-hot (dim == count).
pub const IMDB: HeteroSpec = HeteroSpec {
    name: "IMDB",
    nodes: &[
        NodeSpec { name: "movie", tag: 'M', count: 4278, feat_dim: 3066, one_hot: false },
        NodeSpec { name: "director", tag: 'D', count: 2081, feat_dim: 2081, one_hot: true },
        NodeSpec { name: "actor", tag: 'A', count: 5257, feat_dim: 5257, one_hot: true },
    ],
    relations: &[
        // Each movie has exactly one director; actors per movie ~3.
        RelationSpec { name: "D-M", src: 'D', dst: 'M', edges: 4278, degree: DegreeModel::OnePerDst },
        RelationSpec { name: "M-D", src: 'M', dst: 'D', edges: 4278, degree: DegreeModel::PowerLaw(2.1) },
        RelationSpec { name: "A-M", src: 'A', dst: 'M', edges: 12828, degree: DegreeModel::PowerLaw(2.1) },
        RelationSpec { name: "M-A", src: 'M', dst: 'A', edges: 12828, degree: DegreeModel::PowerLaw(2.1) },
    ],
};

/// ACM: 5912 authors / 3025 papers / 57 subjects; all features 1902-dim
/// (bag-of-words projected, per the paper).
pub const ACM: HeteroSpec = HeteroSpec {
    name: "ACM",
    nodes: &[
        NodeSpec { name: "author", tag: 'A', count: 5912, feat_dim: 1902, one_hot: false },
        NodeSpec { name: "paper", tag: 'P', count: 3025, feat_dim: 1902, one_hot: false },
        NodeSpec { name: "subject", tag: 'S', count: 57, feat_dim: 1902, one_hot: false },
    ],
    relations: &[
        RelationSpec { name: "A-P", src: 'A', dst: 'P', edges: 9936, degree: DegreeModel::PowerLaw(2.2) },
        RelationSpec { name: "P-A", src: 'P', dst: 'A', edges: 9936, degree: DegreeModel::PowerLaw(2.2) },
        RelationSpec { name: "S-P", src: 'S', dst: 'P', edges: 3025, degree: DegreeModel::OnePerDst },
        RelationSpec { name: "P-S", src: 'P', dst: 'S', edges: 3025, degree: DegreeModel::PowerLaw(1.6) },
    ],
};

/// DBLP: 4057 authors / 14328 papers / 7723 terms / 20 venues;
/// A dense 334-dim; P, T, V one-hot.
pub const DBLP: HeteroSpec = HeteroSpec {
    name: "DBLP",
    nodes: &[
        NodeSpec { name: "author", tag: 'A', count: 4057, feat_dim: 334, one_hot: false },
        NodeSpec { name: "paper", tag: 'P', count: 14328, feat_dim: 14328, one_hot: true },
        NodeSpec { name: "term", tag: 'T', count: 7723, feat_dim: 7723, one_hot: true },
        NodeSpec { name: "venue", tag: 'V', count: 20, feat_dim: 20, one_hot: true },
    ],
    relations: &[
        RelationSpec { name: "A-P", src: 'A', dst: 'P', edges: 19645, degree: DegreeModel::PowerLaw(2.3) },
        RelationSpec { name: "P-A", src: 'P', dst: 'A', edges: 19645, degree: DegreeModel::PowerLaw(2.3) },
        RelationSpec { name: "T-P", src: 'T', dst: 'P', edges: 85810, degree: DegreeModel::PowerLaw(2.0) },
        RelationSpec { name: "P-T", src: 'P', dst: 'T', edges: 85810, degree: DegreeModel::PowerLaw(2.0) },
        RelationSpec { name: "V-P", src: 'V', dst: 'P', edges: 14328, degree: DegreeModel::OnePerDst },
        RelationSpec { name: "P-V", src: 'P', dst: 'V', edges: 14328, degree: DegreeModel::PowerLaw(1.4) },
    ],
};

#[cfg(test)]
mod tests {
    use super::*;

    fn check_spec(spec: &HeteroSpec) {
        // relation endpoints reference declared tags
        let tags: Vec<char> = spec.nodes.iter().map(|n| n.tag).collect();
        for r in spec.relations {
            assert!(tags.contains(&r.src), "{}: src {}", spec.name, r.src);
            assert!(tags.contains(&r.dst), "{}: dst {}", spec.name, r.dst);
            if let DegreeModel::OnePerDst = r.degree {
                let dst = spec.nodes.iter().find(|n| n.tag == r.dst).unwrap();
                assert_eq!(r.edges, dst.count, "{}: OnePerDst needs edges==dst", r.name);
            }
        }
        // forward/backward edge counts match (paper lists both directions)
        for r in spec.relations {
            if let Some(rev) = spec
                .relations
                .iter()
                .find(|q| q.src == r.dst && q.dst == r.src)
            {
                assert_eq!(r.edges, rev.edges, "{}: asymmetric counts", r.name);
            }
        }
    }

    #[test]
    fn specs_are_consistent() {
        check_spec(&IMDB);
        check_spec(&ACM);
        check_spec(&DBLP);
    }

    #[test]
    fn table2_exact_numbers() {
        assert_eq!(IMDB.nodes[0].count, 4278);
        assert_eq!(IMDB.nodes[1].count, 2081);
        assert_eq!(IMDB.nodes[2].count, 5257);
        assert_eq!(IMDB.relations[2].edges, 12828);
        assert_eq!(ACM.nodes[0].count, 5912);
        assert_eq!(ACM.relations[0].edges, 9936);
        assert_eq!(DBLP.nodes[1].count, 14328);
        assert_eq!(DBLP.relations[2].edges, 85810);
        assert_eq!(DBLP.nodes[0].feat_dim, 334);
    }
}
