//! Deterministic synthesis of heterogeneous graphs from a [`HeteroSpec`].
//!
//! The generator matches Table 2 *exactly* at paper scale: node counts,
//! feature dims, and per-relation edge counts. Degree sequences follow
//! the spec's [`DegreeModel`]; edges within a destination row are
//! distinct, so the realized nnz equals the requested edge count.

use crate::datasets::spec::{DegreeModel, HeteroSpec};
use crate::datasets::DatasetScale;
use crate::graph::sparse::Csr;
use crate::graph::{HeteroGraph, HeteroGraphBuilder};
use crate::tensor::Tensor;
use crate::util::Pcg32;
use crate::{Error, Result};

/// Generate a degree sequence of length `n_dst` summing exactly to
/// `edges`, with each degree capped at `n_src` (neighbors are distinct).
pub fn degree_sequence(
    model: DegreeModel,
    n_dst: usize,
    n_src: usize,
    edges: usize,
    rng: &mut Pcg32,
) -> Result<Vec<usize>> {
    if edges > n_dst.saturating_mul(n_src) {
        return Err(Error::config(format!(
            "cannot place {edges} distinct edges in {n_dst}x{n_src}"
        )));
    }
    match model {
        DegreeModel::OnePerDst => {
            if edges != n_dst {
                return Err(Error::config(format!(
                    "OnePerDst requires edges == n_dst ({edges} != {n_dst})"
                )));
            }
            Ok(vec![1; n_dst])
        }
        DegreeModel::PowerLaw(alpha) => {
            // Draw heavy-tailed raw degrees, then rescale/adjust to the
            // exact total. Raw draw: 1 + powerlaw sample.
            let mut deg: Vec<usize> = (0..n_dst)
                .map(|_| 1 + rng.gen_powerlaw(n_src.max(2) - 1, alpha))
                .collect();
            let mut total: usize = deg.iter().sum();
            // Scale multiplicatively towards the target first.
            if total != edges {
                let scale = edges as f64 / total as f64;
                for d in deg.iter_mut() {
                    *d = ((*d as f64 * scale).round() as usize).clamp(0, n_src);
                }
                total = deg.iter().sum();
            }
            // Then adjust one-by-one (deterministic order from rng).
            let mut guard = 0usize;
            while total != edges {
                let i = rng.gen_range(n_dst);
                if total < edges && deg[i] < n_src {
                    deg[i] += 1;
                    total += 1;
                } else if total > edges && deg[i] > 0 {
                    deg[i] -= 1;
                    total -= 1;
                }
                guard += 1;
                if guard > 100 * n_dst.max(1) * (n_src.max(1)) {
                    return Err(Error::config("degree adjustment did not converge"));
                }
            }
            Ok(deg)
        }
    }
}

/// Build a CSR with the given per-row degrees; each row's neighbors are
/// distinct and sorted, chosen with mild popularity skew on sources so
/// that both endpoints of a many-to-many relation are heavy-tailed.
pub fn random_bipartite(
    deg: &[usize],
    n_src: usize,
    rng: &mut Pcg32,
) -> Csr {
    let n_rows = deg.len();
    let mut indptr = vec![0u32; n_rows + 1];
    let mut indices: Vec<u32> = Vec::with_capacity(deg.iter().sum());
    for (r, &d) in deg.iter().enumerate() {
        debug_assert!(d <= n_src);
        let mut picked: Vec<usize> = if d * 4 >= n_src {
            rng.choose_distinct(n_src, d)
        } else {
            // popularity-skewed rejection sampling: mix uniform picks with
            // power-law-ranked picks to create hub sources
            let mut seen = std::collections::BTreeSet::new();
            while seen.len() < d {
                let s = if rng.gen_f32() < 0.5 {
                    rng.gen_range(n_src)
                } else {
                    rng.gen_powerlaw(n_src, 2.0)
                };
                seen.insert(s);
            }
            seen.into_iter().collect()
        };
        picked.sort_unstable();
        indices.extend(picked.into_iter().map(|s| s as u32));
        indptr[r + 1] = indices.len() as u32;
    }
    Csr { n_rows, n_cols: n_src, indptr, indices }
}

/// Synthesize a heterogeneous graph from a spec at the given scale.
pub fn build_hetero(spec: &HeteroSpec, scale: &DatasetScale) -> Result<HeteroGraph> {
    let mut b = HeteroGraphBuilder::new(spec.name);
    let mut rng = Pcg32::new(scale.seed, fxhash(spec.name));

    // node types + features
    let mut ids = std::collections::HashMap::new();
    let mut counts = std::collections::HashMap::new();
    for n in spec.nodes {
        let count = scale.scale_count(n.count);
        let dim = scale.scale_dim(n.feat_dim);
        let feats = if n.one_hot {
            Tensor::one_hot(count, dim)
        } else {
            let mut frng = Pcg32::new(scale.seed ^ 0xFEA7, fxhash(n.name));
            Tensor::randn(count, dim, 0.1, &mut frng)
        };
        let id = b.add_node_type(n.name, n.tag, feats);
        ids.insert(n.tag, id);
        counts.insert(n.tag, count);
    }

    // relations
    for r in spec.relations {
        let n_src = counts[&r.src];
        let n_dst = counts[&r.dst];
        let edges = match r.degree {
            // OnePerDst must track the (scaled) destination count exactly
            DegreeModel::OnePerDst => n_dst,
            DegreeModel::PowerLaw(_) => {
                scale.scale_count(r.edges).min(n_src * n_dst)
            }
        };
        let mut rrng = Pcg32::new(scale.seed ^ 0xED6E, fxhash(r.name));
        let deg = degree_sequence(r.degree, n_dst, n_src, edges, &mut rrng)?;
        let adj = random_bipartite(&deg, n_src, &mut rrng);
        adj.validate()?;
        b.add_relation(r.name, ids[&r.src], ids[&r.dst], adj);
    }
    let _ = rng.next_u32();
    b.build()
}

/// Tiny deterministic string hash (FNV-1a) for per-entity RNG streams.
pub fn fxhash(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::spec;

    #[test]
    fn degree_sequence_exact_totals() {
        let mut rng = Pcg32::seeded(1);
        let deg =
            degree_sequence(DegreeModel::PowerLaw(2.1), 100, 500, 1234, &mut rng).unwrap();
        assert_eq!(deg.iter().sum::<usize>(), 1234);
        assert!(deg.iter().all(|&d| d <= 500));

        let one = degree_sequence(DegreeModel::OnePerDst, 50, 10, 50, &mut rng).unwrap();
        assert_eq!(one, vec![1; 50]);
        assert!(degree_sequence(DegreeModel::OnePerDst, 50, 10, 49, &mut rng).is_err());
    }

    #[test]
    fn degree_sequence_capacity_check() {
        let mut rng = Pcg32::seeded(2);
        assert!(degree_sequence(DegreeModel::PowerLaw(2.0), 2, 3, 7, &mut rng).is_err());
    }

    #[test]
    fn bipartite_rows_distinct_sorted() {
        let mut rng = Pcg32::seeded(3);
        let deg = vec![3, 0, 5, 1];
        let csr = random_bipartite(&deg, 10, &mut rng);
        csr.validate().unwrap();
        assert_eq!(csr.nnz(), 9);
        for r in 0..4 {
            assert_eq!(csr.degree(r), deg[r]);
        }
    }

    #[test]
    fn imdb_paper_scale_matches_table2() {
        let g = build_hetero(&spec::IMDB, &DatasetScale::paper()).unwrap();
        assert_eq!(g.node_type(g.type_by_tag('M').unwrap()).count, 4278);
        assert_eq!(g.node_type(g.type_by_tag('D').unwrap()).count, 2081);
        assert_eq!(g.node_type(g.type_by_tag('A').unwrap()).count, 5257);
        assert_eq!(g.node_type(g.type_by_tag('M').unwrap()).feat_dim, 3066);
        let rel_edges: Vec<(String, usize)> = g
            .relations()
            .iter()
            .map(|r| (r.name.clone(), r.adj.nnz()))
            .collect();
        assert!(rel_edges.contains(&("A-M".to_string(), 12828)));
        assert!(rel_edges.contains(&("D-M".to_string(), 4278)));
        assert!(rel_edges.contains(&("M-A".to_string(), 12828)));
    }

    #[test]
    fn synthesis_is_deterministic() {
        let a = build_hetero(&spec::ACM, &DatasetScale::ci()).unwrap();
        let b = build_hetero(&spec::ACM, &DatasetScale::ci()).unwrap();
        assert_eq!(a.total_edges(), b.total_edges());
        for (ra, rb) in a.relations().iter().zip(b.relations()) {
            assert_eq!(ra.adj, rb.adj, "relation {} differs across runs", ra.name);
        }
        for (i, _) in a.node_types().iter().enumerate() {
            assert!(a.features(i).allclose(b.features(i), 0.0, 0.0));
        }
    }

    #[test]
    fn ci_scale_all_datasets_build() {
        for spec in [&spec::IMDB, &spec::ACM, &spec::DBLP] {
            let g = build_hetero(spec, &DatasetScale::ci()).unwrap();
            g.validate().unwrap();
            assert!(g.total_edges() > 0);
        }
    }

    #[test]
    fn fxhash_distinct() {
        assert_ne!(fxhash("A-P"), fxhash("P-A"));
        assert_ne!(fxhash("IMDB"), fxhash("ACM"));
    }
}
