//! Streaming graph updates with epoch-barrier snapshot serving.
//!
//! The paper characterizes HGNN inference over a frozen graph, and every
//! structure built on top of that characterization here — metapath
//! sub-CSRs, degree-balanced partitions, reuse caches, serving lanes —
//! inherits the freeze. This module opens the dynamic axis without
//! giving up any of them:
//!
//! * An [`UpdateLog`] accepts edge/node insertions and feature/weight
//!   updates **while serving continues** against the current immutable
//!   snapshot (the session's graph + plan are untouched until a flip, so
//!   snapshot isolation is structural, not locked).
//! * An **epoch barrier** (`Session::flip_epoch`; [`EpochBarrier`] is
//!   the serving-side control message) atomically applies the pending
//!   log: affected sub-CSRs are re-derived, the reuse caches drop *only*
//!   the touched `(type, node)` / `(subgraph, dst)` keys, dirty
//!   partition shards rebuild their local CSRs and halo tables, and NA
//!   is recomputed **only for touched destination rows** on a compact
//!   patch sub-CSR (`session::exec::execute_patch`).
//!
//! The risingwave barrier/materialize pattern (`/root/related/`) is the
//! architectural ground: updates buffer in a log, consistency points are
//! explicit barriers, and readers always see a complete epoch.
//!
//! ## What "touched" means, per model
//!
//! Every NA variant is destination-row-local given the projected
//! features (see [`crate::reuse`]), so the touched set of a subgraph is
//! exactly the set of destination rows whose *inputs* changed:
//!
//! * **Structure** — after re-deriving an affected subgraph's adjacency
//!   (relation clone for R-GCN's relation walk, [`walk_metapath`] for
//!   HAN/MAGNN), rows whose neighbor lists differ from the previous
//!   epoch's are touched; appended rows (new destination nodes) always
//!   are. Diffing re-derived rows is exact — no over-approximation from
//!   reasoning about hop composition.
//! * **Features** — a rewritten feature row `(ty, v)` touches every
//!   destination whose neighbor list contains `v` in subgraphs with
//!   source type `ty`, plus row `v` itself in attention models (HAN and
//!   MAGNN consume `h_dst`). R-GCN projects learned embeddings, not raw
//!   features, so feature rewrites touch nothing there — but they are
//!   still applied to the graph for future cold builds.
//! * **Weights** — globally coupled: a weight swap degrades to a full
//!   invalidation (every cached row is a function of the weights).
//!
//! Semantic Aggregation is recomputed in full at each flip: HAN/MAGNN's
//! β weights average attention scores over *all* target rows, so SA is
//! never row-local. The headline guarantee — pinned across models ×
//! shards × reuse by `tests/integration_dynamic.rs` — is that post-flip
//! outputs are **bit-identical** to a cold session built from the
//! fully-applied graph.

use std::collections::BTreeSet;

use crate::graph::{HeteroGraph, NodeTypeId, RelationId};
use crate::metapath::{metapath_uses_relation, walk_metapath};
use crate::models::{ModelId, ModelPlan, ModelWeights};
use crate::{Error, Result};

/// Configuration of a dynamic (streaming-update) session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DynamicSpec {
    /// Maximum updates the log buffers before `apply_updates` rejects
    /// (backpressure toward the updater, never toward serving).
    pub max_pending: usize,
}

impl DynamicSpec {
    /// Explicit pending-update bound.
    pub fn pending(max_pending: usize) -> DynamicSpec {
        DynamicSpec { max_pending }
    }
}

impl Default for DynamicSpec {
    /// 64Ki pending updates.
    fn default() -> Self {
        DynamicSpec { max_pending: 1 << 16 }
    }
}

/// One buffered graph or parameter update.
#[derive(Debug, Clone)]
pub enum GraphUpdate {
    /// Insert a directed edge `src -> dst` into a relation (duplicate
    /// edges are no-ops, matching the CSR's set semantics).
    AddEdge {
        /// Relation receiving the edge.
        relation: RelationId,
        /// Destination node id (a row of the relation's CSR).
        dst: u32,
        /// Source node id (a column).
        src: u32,
    },
    /// Append a node of `ty` with the given raw feature row; it becomes
    /// addressable by subsequent updates in the same batch.
    AddNode {
        /// Node type to grow.
        ty: NodeTypeId,
        /// Raw feature row, `feat_dim` wide.
        features: Vec<f32>,
    },
    /// Overwrite one node's raw feature row.
    SetFeatures {
        /// Node type.
        ty: NodeTypeId,
        /// Node id within the type.
        node: u32,
        /// New raw feature row, `feat_dim` wide.
        features: Vec<f32>,
    },
    /// Swap the full parameter set at the barrier (degrades the flip to
    /// a full reuse invalidation — weights couple every cached row).
    SetWeights(Box<ModelWeights>),
}

/// The bounded buffer of not-yet-applied updates. Serving never reads
/// it; the epoch barrier drains it.
#[derive(Debug, Default)]
pub struct UpdateLog {
    pending: Vec<GraphUpdate>,
    max_pending: usize,
    appended: u64,
}

impl UpdateLog {
    /// Empty log with the spec's pending bound.
    pub fn new(spec: DynamicSpec) -> UpdateLog {
        UpdateLog { pending: Vec::new(), max_pending: spec.max_pending, appended: 0 }
    }

    /// Buffer a batch of updates; returns the pending count after the
    /// append, or an error (buffering nothing) when the batch would
    /// exceed the bound.
    pub fn append(&mut self, updates: Vec<GraphUpdate>) -> Result<usize> {
        if self.pending.len() + updates.len() > self.max_pending {
            return Err(Error::config(format!(
                "update log full: {} pending + {} appended > {} max",
                self.pending.len(),
                updates.len(),
                self.max_pending
            )));
        }
        self.appended += updates.len() as u64;
        self.pending.extend(updates);
        Ok(self.pending.len())
    }

    /// Pending (not yet applied) updates.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    /// True when nothing is buffered.
    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Total updates ever appended (applied or pending).
    pub fn total_appended(&self) -> u64 {
        self.appended
    }

    /// Take every pending update, leaving the log empty.
    pub fn drain(&mut self) -> Vec<GraphUpdate> {
        std::mem::take(&mut self.pending)
    }
}

/// An immutable description of the epoch a session currently serves:
/// what a reader observes between barriers. Structural equality of two
/// snapshots is the test-suite's isolation witness.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GraphSnapshot {
    /// Epoch counter (0 at build; +1 per flip).
    pub epoch: u64,
    /// Per-type node counts.
    pub node_counts: Vec<usize>,
    /// Per-relation edge counts.
    pub edge_counts: Vec<usize>,
    /// Updates buffered but not yet visible.
    pub pending_updates: usize,
}

impl GraphSnapshot {
    /// Describe the epoch `hg` currently serves.
    pub fn of(hg: &HeteroGraph, epoch: u64, pending_updates: usize) -> GraphSnapshot {
        GraphSnapshot {
            epoch,
            node_counts: hg.node_types().iter().map(|t| t.count).collect(),
            edge_counts: hg.relations().iter().map(|r| r.adj.nnz()).collect(),
            pending_updates,
        }
    }
}

/// The serving-side barrier control: carried through the dispatcher's
/// control queue and acknowledged only after in-flight waves drained and
/// the flip completed — so every request dispatched before the barrier
/// sees the old epoch and every request after it sees the new one.
#[derive(Debug)]
pub struct EpochBarrier {
    /// Completion channel the flip's outcome is sent on.
    pub ack: std::sync::mpsc::Sender<std::result::Result<EpochReport, String>>,
}

/// What one epoch flip did — the observability surface the bench and the
/// kernel-count acceptance test read.
#[derive(Debug, Clone)]
pub struct EpochReport {
    /// Epoch after the flip.
    pub epoch: u64,
    /// Updates drained from the log and applied.
    pub updates_applied: usize,
    /// Subgraphs whose adjacency was re-derived (structure changed or
    /// dimensions grew).
    pub rebuilt_subgraphs: usize,
    /// Subgraphs with a non-empty touched set (NA patch executed).
    pub patched_subgraphs: usize,
    /// Distinct (subgraph, dst) rows whose NA was recomputed.
    pub na_rows_recomputed: usize,
    /// Projection-cache keys evicted across lanes.
    pub evicted_proj: u64,
    /// Aggregate-cache keys evicted across lanes.
    pub evicted_agg: u64,
    /// Partition shards that rebuilt their local CSRs and halo tables
    /// (0 for unpartitioned sessions).
    pub shards_patched: usize,
    /// True when a `SetWeights` degraded the flip to full invalidation.
    pub full_invalidation: bool,
    /// Wallclock the barrier held serving (the flip pause).
    pub pause_nanos: u64,
    /// Kernel profile of the incremental recompute (absent when the
    /// session had no materialized full-graph forward to patch).
    pub profile: Option<crate::profiler::Profile>,
}

impl EpochReport {
    /// One-line human summary for the CLI and bench output.
    pub fn line(&self) -> String {
        format!(
            "epoch {}: {} updates, {} subgraphs rebuilt, {} patched, \
             {} NA rows recomputed, {}+{} cache keys evicted, {} shards patched, \
             pause {}{}",
            self.epoch,
            self.updates_applied,
            self.rebuilt_subgraphs,
            self.patched_subgraphs,
            self.na_rows_recomputed,
            self.evicted_proj,
            self.evicted_agg,
            self.shards_patched,
            crate::util::human_time(self.pause_nanos as f64),
            if self.full_invalidation { " (full invalidation)" } else { "" },
        )
    }
}

/// The barrier-side change summary `apply_to_graph` computes while
/// mutating the graph and plan: everything the session needs to patch
/// caches, shards and the materialized forward.
#[derive(Debug)]
pub struct PatchSet {
    /// Per subgraph: sorted distinct destination rows whose NA inputs
    /// changed (structure diff + feature-touch scan).
    pub touched: Vec<Vec<u32>>,
    /// Per subgraph: whether the adjacency was re-derived.
    pub rebuilt: Vec<bool>,
    /// `(type, node)` feature rows rewritten (projection-cache keys).
    pub feat_touched: Vec<(NodeTypeId, u32)>,
    /// `(type, id)` nodes appended this flip.
    pub new_nodes: Vec<(NodeTypeId, u32)>,
    /// Replacement weights, applied by the session after graph growth
    /// (last `SetWeights` in the batch wins).
    pub new_weights: Option<Box<ModelWeights>>,
    /// Updates applied.
    pub updates_applied: usize,
}

impl PatchSet {
    /// Total touched destination rows across subgraphs.
    pub fn touched_rows(&self) -> usize {
        self.touched.iter().map(|t| t.len()).sum()
    }
}

/// Validate a batch against the graph without mutating it, simulating
/// per-type counts as `AddNode`s land — so a bad update rejects the
/// whole batch *before* any mutation and the flip stays atomic.
pub fn validate_updates(hg: &HeteroGraph, updates: &[GraphUpdate]) -> Result<()> {
    let mut counts: Vec<usize> = hg.node_types().iter().map(|t| t.count).collect();
    for (i, u) in updates.iter().enumerate() {
        let err = |msg: String| Err(Error::config(format!("update {i}: {msg}")));
        match u {
            GraphUpdate::AddEdge { relation, dst, src } => {
                let Some(r) = hg.relations().get(*relation) else {
                    return err(format!("unknown relation {relation}"));
                };
                if *dst as usize >= counts[r.dst] {
                    return err(format!("dst {} >= {} {}s", dst, counts[r.dst], r.name));
                }
                if *src as usize >= counts[r.src] {
                    return err(format!("src {} >= {} {}s", src, counts[r.src], r.name));
                }
            }
            GraphUpdate::AddNode { ty, features } => {
                let Some(t) = hg.node_types().get(*ty) else {
                    return err(format!("unknown node type {ty}"));
                };
                if features.len() != t.feat_dim {
                    return err(format!(
                        "{} features for type {} (feat_dim {})",
                        features.len(),
                        t.name,
                        t.feat_dim
                    ));
                }
                counts[*ty] += 1;
            }
            GraphUpdate::SetFeatures { ty, node, features } => {
                let Some(t) = hg.node_types().get(*ty) else {
                    return err(format!("unknown node type {ty}"));
                };
                if *node as usize >= counts[*ty] {
                    return err(format!("node {} >= {} {}s", node, counts[*ty], t.name));
                }
                if features.len() != t.feat_dim {
                    return err(format!(
                        "{} features for type {} (feat_dim {})",
                        features.len(),
                        t.name,
                        t.feat_dim
                    ));
                }
            }
            GraphUpdate::SetWeights(_) => {}
        }
    }
    Ok(())
}

/// Apply a validated batch to the graph and plan, re-deriving affected
/// subgraph adjacencies and computing the exact touched sets.
///
/// Mutations performed here: graph edges/nodes/features, R-GCN embedding
/// growth for appended nodes (deterministic stream extension, see
/// [`ModelWeights::extend_embed`]), and the plan's subgraph sub-CSRs.
/// Weight swaps are *not* applied — they are returned in the patch set
/// for the session to route through its `set_weights` checks after
/// graph growth.
pub fn apply_to_graph(
    hg: &mut HeteroGraph,
    plan: &mut ModelPlan,
    updates: Vec<GraphUpdate>,
) -> Result<PatchSet> {
    validate_updates(hg, &updates)?;
    let updates_applied = updates.len();
    let p = plan.num_subgraphs();

    // 1. mutate the graph, recording which relations changed structurally
    let mut rel_changed: BTreeSet<RelationId> = BTreeSet::new();
    let mut feat_touched: Vec<(NodeTypeId, u32)> = Vec::new();
    let mut new_nodes: Vec<(NodeTypeId, u32)> = Vec::new();
    let mut new_weights: Option<Box<ModelWeights>> = None;
    for u in updates {
        match u {
            GraphUpdate::AddEdge { relation, dst, src } => {
                if hg.insert_edge(relation, dst, src)? {
                    rel_changed.insert(relation);
                }
            }
            GraphUpdate::AddNode { ty, features } => {
                let id = hg.push_node(ty, &features)?;
                new_nodes.push((ty, id));
            }
            GraphUpdate::SetFeatures { ty, node, features } => {
                hg.set_feature_row(ty, node, &features)?;
                feat_touched.push((ty, node));
            }
            GraphUpdate::SetWeights(w) => new_weights = Some(w),
        }
    }

    // 2. grow R-GCN embedding tables for appended nodes (prefix-stable
    // stream extension keeps cold-vs-incremental weights bit-identical)
    for &(ty, _) in &new_nodes {
        let count = hg.node_type(ty).count;
        let config = plan.config.clone();
        plan.weights.extend_embed(ty, count, &config);
    }

    // 3. re-derive affected subgraph adjacencies and diff rows
    let mut touched: Vec<BTreeSet<u32>> = (0..p).map(|_| BTreeSet::new()).collect();
    let mut rebuilt = vec![false; p];
    for si in 0..p {
        let sg = &plan.subgraphs.subgraphs[si];
        let dims_grew = sg.adj.n_rows != hg.node_type(sg.dst_type).count
            || sg.adj.n_cols != hg.node_type(sg.src_type).count;
        let structure = match &sg.metapath {
            // relation walk: subgraph order is relation order
            None => rel_changed.contains(&si),
            Some(mp) => rel_changed.iter().any(|&r| metapath_uses_relation(hg, mp, r)),
        };
        if !dims_grew && !structure {
            continue;
        }
        rebuilt[si] = true;
        let new_adj = match &sg.metapath {
            None => hg.relation(si).adj.clone(),
            Some(mp) => walk_metapath(hg, mp)?,
        };
        let old_adj = &sg.adj;
        for r in 0..new_adj.n_rows {
            if r >= old_adj.n_rows || old_adj.row(r) != new_adj.row(r) {
                touched[si].insert(r as u32);
            }
        }
        plan.subgraphs.subgraphs[si].adj = new_adj;
    }

    // 4. feature-touch scan: rewritten rows reach NA as sources
    // everywhere, and as destinations in the attention models (HAN and
    // MAGNN consume h_dst). R-GCN projects embeddings, not features.
    if plan.model != ModelId::Rgcn {
        for &(ty, v) in &feat_touched {
            for (si, sg) in plan.subgraphs.subgraphs.iter().enumerate() {
                if sg.src_type == ty {
                    for r in 0..sg.adj.n_rows {
                        if sg.adj.row(r).binary_search(&v).is_ok() {
                            touched[si].insert(r as u32);
                        }
                    }
                }
                if plan.model.uses_attention()
                    && sg.dst_type == ty
                    && (v as usize) < sg.adj.n_rows
                {
                    touched[si].insert(v);
                }
            }
        }
    }

    Ok(PatchSet {
        touched: touched.into_iter().map(|s| s.into_iter().collect()).collect(),
        rebuilt,
        feat_touched,
        new_nodes,
        new_weights,
        updates_applied,
    })
}

/// Parse a textual update stream into a batch, resolving relation and
/// node-type *names* against the graph. One update per line:
///
/// ```text
/// # comments and blank lines are skipped
/// edge <relation-name> <dst-id> <src-id>
/// node <type-name> <f0> <f1> ...
/// feat <type-name> <node-id> <f0> <f1> ...
/// ```
///
/// Node ids may reference nodes appended earlier in the same stream
/// (bounds are checked at the barrier by [`validate_updates`], against
/// the simulated growing counts).
pub fn parse_update_stream(text: &str, hg: &HeteroGraph) -> Result<Vec<GraphUpdate>> {
    let mut out = Vec::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let op = parts.next().unwrap_or_default();
        let err =
            |msg: String| Err(Error::config(format!("update stream line {}: {msg}", ln + 1)));
        match op {
            "edge" => {
                let (Some(rel), Some(dst), Some(src)) =
                    (parts.next(), parts.next(), parts.next())
                else {
                    return err("edge needs <relation> <dst> <src>".into());
                };
                let Some(relation) =
                    hg.relations().iter().position(|r| r.name == rel)
                else {
                    return err(format!("unknown relation '{rel}'"));
                };
                let (Ok(dst), Ok(src)) = (dst.parse::<u32>(), src.parse::<u32>()) else {
                    return err(format!("bad edge ids '{dst} {src}'"));
                };
                out.push(GraphUpdate::AddEdge { relation, dst, src });
            }
            "node" => {
                let Some(tyname) = parts.next() else {
                    return err("node needs <type> <features...>".into());
                };
                let ty = match hg.type_by_name(tyname) {
                    Ok(ty) => ty,
                    Err(_) => return err(format!("unknown node type '{tyname}'")),
                };
                let features = parse_floats(parts)
                    .map_err(|m| Error::config(format!("update stream line {}: {m}", ln + 1)))?;
                out.push(GraphUpdate::AddNode { ty, features });
            }
            "feat" => {
                let (Some(tyname), Some(node)) = (parts.next(), parts.next()) else {
                    return err("feat needs <type> <node> <features...>".into());
                };
                let ty = match hg.type_by_name(tyname) {
                    Ok(ty) => ty,
                    Err(_) => return err(format!("unknown node type '{tyname}'")),
                };
                let Ok(node) = node.parse::<u32>() else {
                    return err(format!("bad node id '{node}'"));
                };
                let features = parse_floats(parts)
                    .map_err(|m| Error::config(format!("update stream line {}: {m}", ln + 1)))?;
                out.push(GraphUpdate::SetFeatures { ty, node, features });
            }
            other => return err(format!("unknown update op '{other}'")),
        }
    }
    Ok(out)
}

fn parse_floats<'a>(parts: impl Iterator<Item = &'a str>) -> std::result::Result<Vec<f32>, String> {
    parts
        .map(|s| s.parse::<f32>().map_err(|_| format!("bad feature value '{s}'")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{self, DatasetId, DatasetScale};
    use crate::models::{self, ModelConfig};

    fn imdb() -> HeteroGraph {
        datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap()
    }

    #[test]
    fn log_bounds_and_drain() {
        let mut log = UpdateLog::new(DynamicSpec::pending(2));
        let e = GraphUpdate::AddEdge { relation: 0, dst: 0, src: 0 };
        assert_eq!(log.append(vec![e.clone()]).unwrap(), 1);
        assert!(log.append(vec![e.clone(), e.clone()]).is_err(), "over bound rejects");
        assert_eq!(log.len(), 1, "rejected batch buffered nothing");
        assert_eq!(log.append(vec![e]).unwrap(), 2);
        assert_eq!(log.total_appended(), 2);
        let drained = log.drain();
        assert_eq!(drained.len(), 2);
        assert!(log.is_empty());
        assert_eq!(log.total_appended(), 2, "drain keeps the lifetime counter");
    }

    #[test]
    fn validate_simulates_growing_counts() {
        let hg = imdb();
        let m = hg.type_by_tag('M').unwrap();
        let dim = hg.node_type(m).feat_dim;
        let count = hg.node_type(m).count as u32;
        // referencing the about-to-be-added node is fine within a batch
        let batch = vec![
            GraphUpdate::AddNode { ty: m, features: vec![0.0; dim] },
            GraphUpdate::SetFeatures { ty: m, node: count, features: vec![1.0; dim] },
        ];
        validate_updates(&hg, &batch).unwrap();
        // but out-of-simulated-bounds still rejects
        let bad = vec![GraphUpdate::SetFeatures { ty: m, node: count, features: vec![1.0; dim] }];
        assert!(validate_updates(&hg, &bad).is_err());
        assert!(validate_updates(
            &hg,
            &[GraphUpdate::AddEdge { relation: 99, dst: 0, src: 0 }]
        )
        .is_err());
        assert!(validate_updates(&hg, &[GraphUpdate::AddNode { ty: m, features: vec![] }])
            .is_err());
    }

    #[test]
    fn rebuilt_adjacency_matches_cold_walk() {
        // the patched plan's sub-CSRs must equal a cold build over the
        // applied graph — the structural half of flip bit-identity
        let mut hg = imdb();
        let cfg = ModelConfig::default();
        let mut plan = models::han_plan(&hg, &cfg).unwrap();
        // pick a director that directs at least one movie (so the edge
        // propagates into the composed MDM adjacency) and a movie not
        // already in that director's row (so the insert is genuinely new)
        let md = hg.relations().iter().position(|r| r.name == "M-D").unwrap();
        let dm = hg.relations().iter().position(|r| r.name == "D-M").unwrap();
        let d = (0..hg.relation(dm).adj.n_rows)
            .filter_map(|r| hg.relation(dm).adj.row(r).first().copied())
            .next()
            .unwrap();
        let row = hg.relation(md).adj.row(d as usize);
        let c = (0..hg.relation(md).adj.n_cols as u32)
            .find(|c| row.binary_search(c).is_err())
            .unwrap();
        let updates = vec![GraphUpdate::AddEdge { relation: md, dst: d, src: c }];
        let patch = apply_to_graph(&mut hg, &mut plan, updates).unwrap();
        assert_eq!(patch.updates_applied, 1);
        let cold = models::han_plan(&hg, &cfg).unwrap();
        for (sg, csg) in plan
            .subgraphs
            .subgraphs
            .iter()
            .zip(&cold.subgraphs.subgraphs)
        {
            assert_eq!(sg.adj, csg.adj, "{} adjacency diverged from cold walk", sg.name);
        }
        // MDM composes M-D: it must have been rebuilt, and every touched
        // row's neighbor list indeed differs... while untouched rows kept
        // their previous identity (diff-exactness)
        assert!(patch.rebuilt.iter().any(|&b| b));
        assert!(patch.touched_rows() > 0);
    }

    #[test]
    fn duplicate_edge_touches_nothing() {
        let mut hg = imdb();
        let mut plan = models::rgcn_plan(&hg, &ModelConfig::default()).unwrap();
        // re-insert an existing edge: structure unchanged, no touches
        let rel = 0;
        let adj = &hg.relation(rel).adj;
        let (dst, src) = (0..adj.n_rows)
            .find(|&r| !adj.row(r).is_empty())
            .map(|r| (r as u32, adj.row(r)[0]))
            .unwrap();
        let patch = apply_to_graph(
            &mut hg,
            &mut plan,
            vec![GraphUpdate::AddEdge { relation: rel, dst, src }],
        )
        .unwrap();
        assert_eq!(patch.touched_rows(), 0);
        assert!(patch.rebuilt.iter().all(|&b| !b));
    }

    #[test]
    fn feature_touch_rgcn_vs_attention() {
        // R-GCN projects embeddings: feature rewrites touch no NA rows.
        // HAN consumes h_dst and h_src: the rewritten node's own row and
        // every row listing it as a source are touched.
        let mut hg = imdb();
        let m = hg.type_by_tag('M').unwrap();
        let dim = hg.node_type(m).feat_dim;
        let upd = || vec![GraphUpdate::SetFeatures { ty: m, node: 0, features: vec![2.0; dim] }];

        let mut rplan = models::rgcn_plan(&hg, &ModelConfig::default()).unwrap();
        let patch = apply_to_graph(&mut hg.clone(), &mut rplan, upd()).unwrap();
        assert_eq!(patch.touched_rows(), 0);
        assert_eq!(patch.feat_touched, vec![(m, 0)]);

        let mut hplan = models::han_plan(&hg, &ModelConfig::default()).unwrap();
        let patch = apply_to_graph(&mut hg, &mut hplan, upd()).unwrap();
        assert!(patch.touched_rows() > 0);
        for (si, sg) in hplan.subgraphs.subgraphs.iter().enumerate() {
            // node 0's own row is touched (h_dst), and so is every row
            // whose neighbor list contains node 0
            assert!(patch.touched[si].contains(&0));
            for r in 0..sg.adj.n_rows {
                let expects = sg.adj.row(r).binary_search(&0).is_ok() || r == 0;
                assert_eq!(
                    patch.touched[si].binary_search(&(r as u32)).is_ok(),
                    expects,
                    "{} row {r}",
                    sg.name
                );
            }
        }
    }

    #[test]
    fn add_node_grows_dims_and_embeds() {
        let mut hg = imdb();
        let cfg = ModelConfig::default();
        let mut plan = models::rgcn_plan(&hg, &cfg).unwrap();
        let m = hg.type_by_tag('M').unwrap();
        let old = hg.node_type(m).count;
        let dim = hg.node_type(m).feat_dim;
        let patch = apply_to_graph(
            &mut hg,
            &mut plan,
            vec![GraphUpdate::AddNode { ty: m, features: vec![0.5; dim] }],
        )
        .unwrap();
        assert_eq!(patch.new_nodes, vec![(m, old as u32)]);
        assert_eq!(hg.node_type(m).count, old + 1);
        assert_eq!(plan.weights.embed[&m].rows(), old + 1);
        // every subgraph with M rows grew and marks the appended row touched
        for (si, sg) in plan.subgraphs.subgraphs.iter().enumerate() {
            if sg.dst_type == m {
                assert_eq!(sg.adj.n_rows, old + 1);
                assert!(patch.touched[si].contains(&(old as u32)));
            }
        }
        // cold plan over the applied graph agrees on every adjacency
        let cold = models::rgcn_plan(&hg, &cfg).unwrap();
        for (sg, csg) in plan.subgraphs.subgraphs.iter().zip(&cold.subgraphs.subgraphs) {
            assert_eq!(sg.adj, csg.adj);
        }
        assert!(plan.weights.embed[&m].allclose(&cold.weights.embed[&m], 0.0, 0.0));
    }

    #[test]
    fn snapshot_describes_epoch() {
        let mut hg = imdb();
        let a = GraphSnapshot::of(&hg, 0, 0);
        assert_eq!(a, GraphSnapshot::of(&hg, 0, 0));
        let m = hg.type_by_tag('M').unwrap();
        let dim = hg.node_type(m).feat_dim;
        hg.push_node(m, &vec![0.0; dim]).unwrap();
        let b = GraphSnapshot::of(&hg, 1, 0);
        assert_ne!(a, b);
        assert_eq!(b.node_counts[m], a.node_counts[m] + 1);
    }

    #[test]
    fn stream_parses_and_rejects() {
        let hg = imdb();
        let m_dim = hg.node_type(hg.type_by_tag('M').unwrap()).feat_dim;
        let rel = &hg.relations()[0].name;
        let feats = vec!["0.5"; m_dim].join(" ");
        let text = format!(
            "# a comment\n\nedge {rel} 0 1\nnode movie {feats}\nfeat movie 0 {feats}\n"
        );
        let updates = parse_update_stream(&text, &hg).unwrap();
        assert_eq!(updates.len(), 3);
        assert!(matches!(updates[0], GraphUpdate::AddEdge { dst: 0, src: 1, .. }));
        assert!(matches!(updates[1], GraphUpdate::AddNode { .. }));
        assert!(matches!(updates[2], GraphUpdate::SetFeatures { node: 0, .. }));
        validate_updates(&hg, &updates).unwrap();

        for bad in [
            "edge nope 0 1",
            "edge",
            "node nobody 1.0",
            "feat movie x 1.0",
            "feat movie 0 zork",
            "frobnicate 1 2",
        ] {
            assert!(parse_update_stream(bad, &hg).is_err(), "{bad:?} must reject");
        }
    }

    #[test]
    fn report_line_mentions_the_counts() {
        let r = EpochReport {
            epoch: 3,
            updates_applied: 7,
            rebuilt_subgraphs: 1,
            patched_subgraphs: 2,
            na_rows_recomputed: 9,
            evicted_proj: 4,
            evicted_agg: 5,
            shards_patched: 1,
            full_invalidation: false,
            pause_nanos: 1_000,
            profile: None,
        };
        let line = r.line();
        assert!(line.contains("epoch 3"));
        assert!(line.contains("9 NA rows"));
        assert!(line.contains("4+5 cache keys"));
        assert!(!line.contains("full invalidation"));
    }
}
