//! The staged execution engine.
//!
//! Walks a [`ModelPlan`] through the paper's stages ②–④ on the native
//! kernel substrate, recording every kernel into a [`Profile`] with
//! (stage, subgraph) attribution, then attaches modeled-T4 metrics. The
//! coordinator (L3's scheduling contribution) reuses the per-stage entry
//! points for parallel and fused schedules; this module is the plain
//! sequential reference execution.

pub mod stages;

use crate::gpumodel::GpuModel;
use crate::graph::HeteroGraph;
use crate::kernels::dense::GemmBlocking;
use crate::kernels::Ctx;
use crate::models::ModelPlan;
use crate::profiler::{Profile, StageId};
use crate::tensor::Tensor;
use crate::Result;

pub use stages::{feature_projection, neighbor_aggregation, semantic_aggregation};

/// Execution backend selector.
///
/// `Native` runs the Rust kernel substrate (full profiling fidelity).
/// The AOT PJRT path lives in [`crate::runtime`] and executes whole-model
/// artifacts; integration tests assert both agree numerically.
#[derive(Debug, Clone)]
pub enum Backend {
    /// Native Rust kernels with exact counters and gather traces.
    Native {
        /// sgemm cache-blocking parameters.
        blocking: GemmBlocking,
        /// Record gather traces for the L2 cache model (Table 3 / Fig 4
        /// need this; plain breakdowns can skip it to save memory).
        record_traces: bool,
    },
}

impl Backend {
    /// Default native backend with traces on.
    pub fn native() -> Backend {
        Backend::Native { blocking: GemmBlocking::default(), record_traces: true }
    }

    /// Native backend without trace recording (lighter memory).
    pub fn native_no_traces() -> Backend {
        Backend::Native { blocking: GemmBlocking::default(), record_traces: false }
    }
}

/// Everything a run produces.
#[derive(Debug)]
pub struct RunArtifacts {
    /// Final embeddings of the plan's target node type.
    pub output: Tensor,
    /// Per-subgraph Neighbor Aggregation results (kept for inspection
    /// and for coordinator scheduling experiments).
    pub na_results: Vec<Tensor>,
    /// The full kernel-level profile with modeled T4 metrics attached.
    pub profile: Profile,
}

/// The sequential staged engine.
#[derive(Debug)]
pub struct Engine {
    backend: Backend,
    gpu: GpuModel,
}

impl Engine {
    /// Create an engine over a backend with the default T4 model.
    pub fn new(backend: Backend) -> Engine {
        Engine { backend, gpu: GpuModel::default() }
    }

    /// Replace the GPU model (custom calibration experiments).
    pub fn with_gpu_model(mut self, gpu: GpuModel) -> Engine {
        self.gpu = gpu;
        self
    }

    /// The GPU model in use.
    pub fn gpu_model(&self) -> &GpuModel {
        &self.gpu
    }

    fn ctx(&self) -> Ctx {
        match self.backend {
            Backend::Native { record_traces, .. } => {
                Ctx { events: Vec::new(), record_traces }
            }
        }
    }

    fn blocking(&self) -> GemmBlocking {
        match self.backend {
            Backend::Native { blocking, .. } => blocking,
        }
    }

    /// Run inference, profiling every kernel. Sequential schedule:
    /// FP → NA per subgraph in order → SA (the DGL execution the paper
    /// profiles; the coordinator offers the parallel/fused schedules).
    pub fn run(&mut self, plan: &ModelPlan, hg: &HeteroGraph) -> Result<RunArtifacts> {
        let mut profile = Profile {
            subgraph_build_nanos: plan.subgraphs.build_nanos,
            ..Default::default()
        };
        let blocking = self.blocking();
        let mut wall_cursor = 0u64;

        // ② Feature Projection
        let mut ctx = self.ctx();
        let projected = feature_projection(&mut ctx, plan, hg, blocking)?;
        wall_cursor = record_advance(&mut profile, &mut ctx, StageId::FeatureProjection, None, wall_cursor);

        // ③ Neighbor Aggregation, per subgraph
        let mut na_results = Vec::with_capacity(plan.num_subgraphs());
        for i in 0..plan.num_subgraphs() {
            let name = plan.subgraphs.subgraphs[i].name.clone();
            let out = neighbor_aggregation(&mut ctx, plan, i, &projected, blocking)?;
            wall_cursor = record_advance(
                &mut profile,
                &mut ctx,
                StageId::NeighborAggregation,
                Some(&name),
                wall_cursor,
            );
            na_results.push(out);
        }

        // ④ Semantic Aggregation
        let output = semantic_aggregation(&mut ctx, plan, &na_results, blocking)?;
        let _ = record_advance(
            &mut profile,
            &mut ctx,
            StageId::SemanticAggregation,
            None,
            wall_cursor,
        );

        profile.attach_metrics(&self.gpu);
        Ok(RunArtifacts { output, na_results, profile })
    }

    /// Run only FP + NA (the Fig 5a/5b sweeps time NA in isolation).
    pub fn run_na_only(
        &mut self,
        plan: &ModelPlan,
        hg: &HeteroGraph,
    ) -> Result<(Vec<Tensor>, Profile)> {
        let mut profile = Profile {
            subgraph_build_nanos: plan.subgraphs.build_nanos,
            ..Default::default()
        };
        let blocking = self.blocking();
        let mut ctx = self.ctx();
        let projected = feature_projection(&mut ctx, plan, hg, blocking)?;
        let mut cursor =
            record_advance(&mut profile, &mut ctx, StageId::FeatureProjection, None, 0);
        let mut na_results = Vec::new();
        for i in 0..plan.num_subgraphs() {
            let name = plan.subgraphs.subgraphs[i].name.clone();
            let out = neighbor_aggregation(&mut ctx, plan, i, &projected, blocking)?;
            cursor = record_advance(
                &mut profile,
                &mut ctx,
                StageId::NeighborAggregation,
                Some(&name),
                cursor,
            );
            na_results.push(out);
        }
        profile.attach_metrics(&self.gpu);
        Ok((na_results, profile))
    }
}

/// Drain ctx events into the profile under one attribution; returns the
/// advanced wallclock cursor.
fn record_advance(
    profile: &mut Profile,
    ctx: &mut Ctx,
    stage: StageId,
    subgraph: Option<&str>,
    cursor: u64,
) -> u64 {
    let events = ctx.drain();
    let dur: u64 = events.iter().map(|e| e.wall_nanos).sum();
    profile.record(events, stage, subgraph, 0, cursor);
    cursor + dur
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{self, DatasetId, DatasetScale};
    use crate::models::{self, ModelConfig, ModelId};

    fn run_model(model: ModelId, dataset: DatasetId) -> RunArtifacts {
        let hg = datasets::build(dataset, &DatasetScale::ci()).unwrap();
        let plan = models::build_plan(model, &hg, &ModelConfig::default()).unwrap();
        Engine::new(Backend::native()).run(&plan, &hg).unwrap()
    }

    #[test]
    fn han_imdb_end_to_end() {
        let run = run_model(ModelId::Han, DatasetId::Imdb);
        assert_eq!(run.na_results.len(), 2);
        assert!(run.output.frob_norm() > 0.0);
        // all three GPU stages present
        let pct = run.profile.stage_percentages();
        assert!(pct.values().all(|&v| v >= 0.0));
        assert!((pct.values().sum::<f64>() - 100.0).abs() < 1e-6);
    }

    #[test]
    fn stage_attribution_complete() {
        // every kernel lands in one of the GPU stages; NA contains the
        // TB kernels. (The paper-scale "NA dominates" claim is asserted
        // at realistic scale in rust/tests/integration_pipeline.rs —
        // at 1/16 CI scale launch overheads distort shares.)
        let run = run_model(ModelId::Han, DatasetId::Imdb);
        let pct = run.profile.stage_percentages();
        assert!(pct[&StageId::NeighborAggregation] > 0.0);
        let tb_in_na = run
            .profile
            .kernels
            .iter()
            .filter(|k| k.exec.ktype == crate::kernels::KernelType::TopologyBased)
            .all(|k| k.stage == StageId::NeighborAggregation);
        assert!(tb_in_na, "all TB kernels belong to NA for HAN");
    }

    #[test]
    fn all_models_all_hetero_datasets() {
        for model in ModelId::HGNNS {
            for dataset in DatasetId::HETERO {
                let run = run_model(model, dataset);
                assert!(
                    run.output.frob_norm().is_finite(),
                    "{model:?} on {dataset:?} produced non-finite output"
                );
                assert!(!run.profile.kernels.is_empty());
            }
        }
    }

    #[test]
    fn gcn_on_reddit() {
        let run = run_model(ModelId::Gcn, DatasetId::RedditSim);
        assert_eq!(run.na_results.len(), 1);
        let pct = run.profile.stage_percentages();
        // GCN has no SA work
        assert_eq!(pct[&StageId::SemanticAggregation], 0.0);
    }

    #[test]
    fn determinism_across_runs() {
        let a = run_model(ModelId::Han, DatasetId::Acm);
        let b = run_model(ModelId::Han, DatasetId::Acm);
        assert!(a.output.allclose(&b.output, 0.0, 0.0));
        assert_eq!(a.profile.kernels.len(), b.profile.kernels.len());
    }

    #[test]
    fn na_only_matches_full_run_prefix() {
        let hg = datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap();
        let plan = models::han_plan(&hg, &ModelConfig::default()).unwrap();
        let mut engine = Engine::new(Backend::native());
        let (na, profile) = engine.run_na_only(&plan, &hg).unwrap();
        let full = engine.run(&plan, &hg).unwrap();
        assert_eq!(na.len(), full.na_results.len());
        for (a, b) in na.iter().zip(&full.na_results) {
            assert!(a.allclose(b, 0.0, 0.0));
        }
        // NA-only profile has no SA kernels
        assert!(profile
            .kernels
            .iter()
            .all(|k| k.stage != StageId::SemanticAggregation));
    }
}
