//! The staged execution engine — now a thin, deprecated shim.
//!
//! The execution surface lives in [`crate::session`]: a [`Session`]
//! composes a pluggable [`ExecBackend`] with a [`SchedulePolicy`] and a
//! profiling level, and caches plan/graph/compiled state across runs.
//! [`Engine`] survives as a compatibility wrapper that forwards the old
//! `run(plan, hg)` shape to the session executor's sequential schedule;
//! the per-stage entry points ([`feature_projection`] & friends) remain
//! the shared substrate both the session's [`NativeBackend`] and direct
//! callers use.
//!
//! [`Session`]: crate::session::Session
//! [`ExecBackend`]: crate::session::ExecBackend
//! [`SchedulePolicy`]: crate::session::SchedulePolicy
//! [`NativeBackend`]: crate::session::NativeBackend

pub mod stages;

use crate::gpumodel::GpuModel;
use crate::graph::HeteroGraph;
use crate::kernels::dense::GemmBlocking;
use crate::kernels::Ctx;
use crate::models::ModelPlan;
use crate::profiler::Profile;
use crate::session::{exec, NativeBackend, SchedulePolicy};
use crate::tensor::Tensor;
use crate::Result;

pub use stages::{feature_projection, neighbor_aggregation, semantic_aggregation};

/// Execution backend selector — the legacy single-variant enum.
///
/// **Deprecated:** new code should pass a
/// [`crate::session::NativeBackend`] (or any
/// [`crate::session::ExecBackend`]) to [`crate::session::Session`]. This
/// enum survives only to keep `Engine::new(Backend::native())` call
/// sites compiling; it converts losslessly into a `NativeBackend`.
#[derive(Debug, Clone)]
pub enum Backend {
    /// Native Rust kernels with exact counters and gather traces.
    Native {
        /// sgemm cache-blocking parameters.
        blocking: GemmBlocking,
        /// Record gather traces for the L2 cache model (Table 3 / Fig 4
        /// need this; plain breakdowns can skip it to save memory).
        record_traces: bool,
    },
}

impl Backend {
    /// Default native backend with traces on.
    pub fn native() -> Backend {
        Backend::Native { blocking: GemmBlocking::default(), record_traces: true }
    }

    /// Native backend without trace recording (lighter memory).
    pub fn native_no_traces() -> Backend {
        Backend::Native { blocking: GemmBlocking::default(), record_traces: false }
    }
}

impl From<Backend> for NativeBackend {
    fn from(b: Backend) -> NativeBackend {
        match b {
            Backend::Native { blocking, record_traces } => {
                NativeBackend { blocking, record_traces }
            }
        }
    }
}

/// Everything a run produces.
#[derive(Debug)]
pub struct RunArtifacts {
    /// Final embeddings of the plan's target node type.
    pub output: Tensor,
    /// Per-subgraph Neighbor Aggregation results (kept for inspection
    /// and for scheduling experiments).
    pub na_results: Vec<Tensor>,
    /// The full kernel-level profile with modeled T4 metrics attached.
    pub profile: Profile,
}

/// The sequential staged engine — a deprecated shim over the session
/// executor ([`crate::session::exec`]); see the module docs.
#[derive(Debug)]
pub struct Engine {
    backend: NativeBackend,
    gpu: GpuModel,
    scratch: Ctx,
}

impl Engine {
    /// Create an engine over a backend with the default T4 model.
    ///
    /// **Deprecated:** build a [`crate::session::Session`] instead.
    pub fn new(backend: Backend) -> Engine {
        let backend = NativeBackend::from(backend);
        let scratch = Ctx { record_traces: backend.record_traces, ..Default::default() };
        Engine { backend, gpu: GpuModel::default(), scratch }
    }

    /// Replace the GPU model (custom calibration experiments).
    pub fn with_gpu_model(mut self, gpu: GpuModel) -> Engine {
        self.gpu = gpu;
        self
    }

    /// The GPU model in use.
    pub fn gpu_model(&self) -> &GpuModel {
        &self.gpu
    }

    /// Run inference, profiling every kernel. Sequential schedule:
    /// FP → NA per subgraph in order → SA (the DGL execution the paper
    /// profiles; other schedules are reached through
    /// [`crate::session::Session`]).
    pub fn run(&mut self, plan: &ModelPlan, hg: &HeteroGraph) -> Result<RunArtifacts> {
        let run = exec::execute(
            &self.backend,
            &self.gpu,
            plan,
            hg,
            SchedulePolicy::Sequential,
            &mut self.scratch,
        )?;
        Ok(RunArtifacts { output: run.output, na_results: run.na_results, profile: run.profile })
    }

    /// Run only FP + NA (the Fig 5a/5b sweeps time NA in isolation).
    pub fn run_na_only(
        &mut self,
        plan: &ModelPlan,
        hg: &HeteroGraph,
    ) -> Result<(Vec<Tensor>, Profile)> {
        exec::run_na_only(&self.backend, &self.gpu, plan, hg, &mut self.scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{self, DatasetId, DatasetScale};
    use crate::models::{self, ModelConfig, ModelId};
    use crate::profiler::StageId;

    fn run_model(model: ModelId, dataset: DatasetId) -> RunArtifacts {
        let hg = datasets::build(dataset, &DatasetScale::ci()).unwrap();
        let plan = models::build_plan(model, &hg, &ModelConfig::default()).unwrap();
        Engine::new(Backend::native()).run(&plan, &hg).unwrap()
    }

    #[test]
    fn han_imdb_end_to_end() {
        let run = run_model(ModelId::Han, DatasetId::Imdb);
        assert_eq!(run.na_results.len(), 2);
        assert!(run.output.frob_norm() > 0.0);
        // all three GPU stages present
        let pct = run.profile.stage_percentages();
        assert!(pct.values().all(|&v| v >= 0.0));
        assert!((pct.values().sum::<f64>() - 100.0).abs() < 1e-6);
    }

    #[test]
    fn stage_attribution_complete() {
        // every kernel lands in one of the GPU stages; NA contains the
        // TB kernels. (The paper-scale "NA dominates" claim is asserted
        // at realistic scale in rust/tests/integration_pipeline.rs —
        // at 1/16 CI scale launch overheads distort shares.)
        let run = run_model(ModelId::Han, DatasetId::Imdb);
        let pct = run.profile.stage_percentages();
        assert!(pct[&StageId::NeighborAggregation] > 0.0);
        let tb_in_na = run
            .profile
            .kernels
            .iter()
            .filter(|k| k.exec.ktype == crate::kernels::KernelType::TopologyBased)
            .all(|k| k.stage == StageId::NeighborAggregation);
        assert!(tb_in_na, "all TB kernels belong to NA for HAN");
    }

    #[test]
    fn all_models_all_hetero_datasets() {
        for model in ModelId::HGNNS {
            for dataset in DatasetId::HETERO {
                let run = run_model(model, dataset);
                assert!(
                    run.output.frob_norm().is_finite(),
                    "{model:?} on {dataset:?} produced non-finite output"
                );
                assert!(!run.profile.kernels.is_empty());
            }
        }
    }

    #[test]
    fn gcn_on_reddit() {
        let run = run_model(ModelId::Gcn, DatasetId::RedditSim);
        assert_eq!(run.na_results.len(), 1);
        let pct = run.profile.stage_percentages();
        // GCN has no SA work
        assert_eq!(pct[&StageId::SemanticAggregation], 0.0);
    }

    #[test]
    fn determinism_across_runs() {
        let a = run_model(ModelId::Han, DatasetId::Acm);
        let b = run_model(ModelId::Han, DatasetId::Acm);
        assert!(a.output.allclose(&b.output, 0.0, 0.0));
        assert_eq!(a.profile.kernels.len(), b.profile.kernels.len());
    }

    #[test]
    fn na_only_matches_full_run_prefix() {
        let hg = datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap();
        let plan = models::han_plan(&hg, &ModelConfig::default()).unwrap();
        let mut engine = Engine::new(Backend::native());
        let (na, profile) = engine.run_na_only(&plan, &hg).unwrap();
        let full = engine.run(&plan, &hg).unwrap();
        assert_eq!(na.len(), full.na_results.len());
        for (a, b) in na.iter().zip(&full.na_results) {
            assert!(a.allclose(b, 0.0, 0.0));
        }
        // NA-only profile has no SA kernels
        assert!(profile
            .kernels
            .iter()
            .all(|k| k.stage != StageId::SemanticAggregation));
    }

    #[test]
    fn shim_matches_session() {
        // the deprecated Engine shim and the Session API must produce
        // identical results for the sequential schedule
        let hg = datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap();
        let plan = models::han_plan(&hg, &ModelConfig::default()).unwrap();
        let from_engine = Engine::new(Backend::native()).run(&plan, &hg).unwrap();
        let mut session = crate::session::Session::builder()
            .graph(hg)
            .plan(plan)
            .profiling(crate::session::Profiling::Traces)
            .build()
            .unwrap();
        let from_session = session.run().unwrap();
        assert!(from_engine.output.allclose(&from_session.output, 0.0, 0.0));
        assert_eq!(
            from_engine.profile.kernels.len(),
            from_session.profile.kernels.len()
        );
    }
}
