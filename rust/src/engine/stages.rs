//! Stage implementations: Feature Projection (②), Neighbor Aggregation
//! (③) and Semantic Aggregation (④), expressed purely in terms of the
//! kernel substrate so every table/figure can attribute time exactly.

use std::collections::BTreeMap;

use crate::kernels::dense::{sgemm, sgemm_bias, sgemm_cached, GemmBlocking, PackKey};
use crate::kernels::elementwise::{
    reduce_grouped_rows, reduce_rows_mean, scale_rows, softmax_vec, unary, UnaryOp,
};
use crate::kernels::rearrange::{concat_rows, index_select};
use crate::kernels::sparse_ops::{edge_softmax, sddmm_coo, spmm_csr, SpmmReduce};
use crate::kernels::{Ctx, KernelCounters, KernelType};
use crate::graph::HeteroGraph;
use crate::models::{ModelId, ModelPlan};
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Feature Projection: project every node type the plan touches into the
/// hidden space with a type-specific linear transformation (one `sgemm`
/// per type — the paper's DM-dominated stage). Each type's weight
/// matrix goes through the packed-panel cache ([`sgemm_cached`] keyed
/// by [`PackKey::Proj`]), so a ctx that lives across batches or
/// training steps packs each weight once per weights generation.
pub fn feature_projection(
    ctx: &mut Ctx,
    plan: &ModelPlan,
    hg: &HeteroGraph,
    blocking: GemmBlocking,
) -> Result<BTreeMap<usize, Tensor>> {
    let mut projected = BTreeMap::new();
    for (&ty, w) in &plan.weights.proj {
        // R-GCN consumes learned hidden-dim embeddings (OpenHGNN), other
        // models project the raw per-type features.
        let x = plan.weights.embed.get(&ty).unwrap_or_else(|| hg.features(ty));
        if x.cols() != w.rows() {
            return Err(Error::shape(format!(
                "FP: features of type {} are {}-dim, weight expects {}",
                hg.node_type(ty).name,
                x.cols(),
                w.rows()
            )));
        }
        let h = sgemm_cached(ctx, x, w, PackKey::Proj(ty), blocking)?;
        projected.insert(ty, h);
    }
    Ok(projected)
}

/// Neighbor Aggregation for one subgraph. Returns the per-node
/// aggregation result `[dst_count, hidden]`.
pub fn neighbor_aggregation(
    ctx: &mut Ctx,
    plan: &ModelPlan,
    subgraph_idx: usize,
    projected: &BTreeMap<usize, Tensor>,
    _blocking: GemmBlocking,
) -> Result<Tensor> {
    let sg = &plan.subgraphs.subgraphs[subgraph_idx];
    let h_src = projected
        .get(&sg.src_type)
        .ok_or_else(|| Error::config(format!("NA: type {} not projected", sg.src_type)))?;
    match plan.model {
        ModelId::Rgcn | ModelId::Gcn => {
            // mean aggregation, no attention
            spmm_csr(ctx, &sg.adj, h_src, None, SpmmReduce::Mean)
        }
        ModelId::Han => {
            let h_dst = projected.get(&sg.dst_type).unwrap_or(h_src);
            // attention terms via broadcast-mul + reduce (EW kernels, as
            // DGL's GATConv lowers `(feat * attn).sum(-1)`)
            let s_dst =
                crate::kernels::elementwise::rowwise_dot(ctx, h_dst, &plan.weights.attn_l[subgraph_idx])?;
            let s_src =
                crate::kernels::elementwise::rowwise_dot(ctx, h_src, &plan.weights.attn_r[subgraph_idx])?;
            let logits = sddmm_coo(
                ctx,
                &sg.adj,
                &s_dst,
                &s_src,
                plan.config.leaky_slope,
            )?;
            let weights = edge_softmax(ctx, &sg.adj, &logits)?;
            let agg = spmm_csr(ctx, &sg.adj, h_src, Some(&weights), SpmmReduce::Sum)?;
            let out = unary(ctx, &agg, UnaryOp::Elu);
            ctx.arena.give(agg.into_vec());
            Ok(out)
        }
        ModelId::Magnn => {
            // MAGNN-lite: encode each metapath instance (edge) as the mean
            // of its endpoint embeddings, attend over encoded instances.
            let h_dst = projected.get(&sg.dst_type).unwrap_or(h_src);
            // per-edge endpoint gathers (DR IndexSelect, irregular)
            let src_rows: Vec<u32> = sg.adj.indices.clone();
            let mut dst_rows = Vec::with_capacity(sg.adj.nnz());
            for d in 0..sg.adj.n_rows {
                dst_rows.extend(std::iter::repeat_n(d as u32, sg.adj.degree(d)));
            }
            let e_src = index_select(ctx, h_src, &src_rows)?;
            let e_dst = index_select(ctx, h_dst, &dst_rows)?;
            let sum = crate::kernels::elementwise::binary(
                ctx,
                &e_src,
                &e_dst,
                crate::kernels::elementwise::BinaryOp::Add,
            )?;
            ctx.arena.give(e_src.into_vec());
            ctx.arena.give(e_dst.into_vec());
            let enc = unary(ctx, &sum, UnaryOp::Scale(0.5));
            ctx.arena.give(sum.into_vec());
            // instance attention: logits = leakyrelu(enc · w)  (EW kernels,
            // broadcast-mul + reduce, as DGL lowers it)
            let w_col: Vec<f32> = plan.weights.inst_attn[subgraph_idx].as_slice().to_vec();
            let scores = crate::kernels::elementwise::rowwise_dot(ctx, &enc, &w_col)?;
            let scores_t = Tensor::from_vec(scores.len(), 1, scores)?;
            let logits = unary(ctx, &scores_t, UnaryOp::LeakyRelu(plan.config.leaky_slope));
            let weights = edge_softmax(ctx, &sg.adj, logits.as_slice())?;
            // weighted segment-sum of encoded instances (TB)
            let scaled = scale_rows(ctx, &enc, &weights)?;
            ctx.arena.give(enc.into_vec());
            let agg = segment_sum_edges(ctx, &sg.adj, &scaled)?;
            ctx.arena.give(scaled.into_vec());
            let out = unary(ctx, &agg, UnaryOp::Elu);
            ctx.arena.give(agg.into_vec());
            Ok(out)
        }
    }
}

/// Sum rows of a per-edge feature matrix `[nnz, F]` into their
/// destination segments — DGL lowers this to the same `SpMMCsr` kernel
/// (copy_e + sum message passing), so it is recorded under that name.
/// Parallel over destination-row blocks like [`spmm_csr`]; each row's
/// edge accumulation order is the serial one, so output is
/// bit-identical at every thread count.
pub fn segment_sum_edges(ctx: &mut Ctx, adj: &crate::graph::Csr, edge_feats: &Tensor) -> Result<Tensor> {
    if edge_feats.rows() != adj.nnz() {
        return Err(Error::shape(format!(
            "segment_sum: {} edge rows for {} nonzeros",
            edge_feats.rows(),
            adj.nnz()
        )));
    }
    let f = edge_feats.cols();
    let t0 = std::time::Instant::now();
    let mut out = ctx.scratch_zeros(adj.n_rows, f);
    if f > 0 {
        crate::parallel::parallel_chunks_mut(out.as_mut_slice(), f, 32, |d0, block| {
            for (r, orow) in block.chunks_mut(f).enumerate() {
                let d = d0 + r;
                let lo = adj.indptr[d] as usize;
                let hi = adj.indptr[d + 1] as usize;
                for e in lo..hi {
                    crate::kernels::simd::add_assign(orow, edge_feats.row(e));
                }
            }
        });
    }
    let nanos = t0.elapsed().as_nanos() as u64;
    let nnz = adj.nnz() as u64;
    let counters = KernelCounters {
        flops: nnz * f as u64,
        bytes_read: nnz * f as u64 * 4 + adj.indptr.len() as u64 * 4,
        bytes_written: (adj.n_rows * f) as u64 * 4,
    };
    ctx.push("SpMMCsr", KernelType::TopologyBased, counters, nanos, None);
    Ok(out)
}

/// Semantic Aggregation: combine per-subgraph NA results into final
/// embeddings. HAN/MAGNN use attention (Concat → sgemm → tanh → sgemm →
/// Reduce → softmax → scale → Reduce, the paper's §4.4 pipeline); R-GCN
/// sums; GCN has no SA.
pub fn semantic_aggregation(
    ctx: &mut Ctx,
    plan: &ModelPlan,
    na_results: &[Tensor],
    blocking: GemmBlocking,
) -> Result<Tensor> {
    if na_results.is_empty() {
        return Err(Error::config("SA: no NA results"));
    }
    match plan.model {
        ModelId::Gcn => Ok(na_results[0].clone()),
        ModelId::Rgcn => {
            // stack per-relation results targeting the output type, then
            // a plain sum Reduce (the paper: "RGCN directly performs
            // Reduce ... without attention weights")
            let selected: Vec<&Tensor> = plan
                .subgraphs
                .subgraphs
                .iter()
                .zip(na_results)
                .filter(|(sg, _)| sg.dst_type == plan.target)
                .map(|(_, t)| t)
                .collect();
            if selected.is_empty() {
                return Err(Error::config("SA: no relation targets the output type"));
            }
            if selected.len() == 1 {
                return Ok(selected[0].clone());
            }
            let stacked = concat_rows(ctx, &selected)?;
            reduce_grouped_rows(ctx, &stacked, selected.len())
        }
        ModelId::Han | ModelId::Magnn => {
            let p = na_results.len();
            let n = na_results[0].rows();
            let refs: Vec<&Tensor> = na_results.iter().collect();
            // ① Concat: [P*N, h] — the paper's expensive DR kernel
            let stacked = concat_rows(ctx, &refs)?;
            // ② sgemm + bias + tanh: T = tanh(stacked · W + b)
            let sem_w = plan.weights.sem_w.as_ref().ok_or_else(|| {
                Error::config("SA: model has no semantic attention weights")
            })?;
            let sem_q = plan.weights.sem_q.as_ref().unwrap();
            let lin = sgemm_bias(ctx, &stacked, sem_w, &plan.weights.sem_b, blocking)?;
            let t = unary(ctx, &lin, UnaryOp::Tanh);
            ctx.arena.give(lin.into_vec());
            // ③ sgemm: per-(metapath, node) score = T · q
            let scores = sgemm(ctx, &t, sem_q, blocking)?;
            ctx.arena.give(t.into_vec());
            // ④ Reduce: per-metapath mean score over nodes
            let scores_pn = Tensor::from_vec(p, n, scores.as_slice().to_vec())?;
            ctx.arena.give(scores.into_vec());
            let beta_raw = reduce_rows_mean(ctx, &scores_pn);
            // ⑤ softmax over the P metapaths
            let beta = softmax_vec(ctx, &beta_raw);
            // ⑥ broadcast-scale each metapath block, then Reduce-sum
            let mut row_scale = Vec::with_capacity(p * n);
            for &b in &beta {
                row_scale.extend(std::iter::repeat_n(b, n));
            }
            let scaled = scale_rows(ctx, &stacked, &row_scale)?;
            ctx.arena.give(stacked.into_vec());
            let out = reduce_grouped_rows(ctx, &scaled, p)?;
            ctx.arena.give(scaled.into_vec());
            Ok(out)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{self, DatasetId, DatasetScale};
    use crate::models::{self, ModelConfig};

    fn setup(model: ModelId) -> (HeteroGraph, ModelPlan) {
        let hg = datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap();
        let plan = models::build_plan(model, &hg, &ModelConfig::default()).unwrap();
        (hg, plan)
    }

    #[test]
    fn fp_projects_to_hidden() {
        let (hg, plan) = setup(ModelId::Han);
        let mut ctx = Ctx::default();
        let proj = feature_projection(&mut ctx, &plan, &hg, GemmBlocking::default()).unwrap();
        let m = hg.type_by_tag('M').unwrap();
        assert_eq!(proj[&m].cols(), plan.config.hidden_dim);
        assert_eq!(proj[&m].rows(), hg.node_type(m).count);
        assert!(ctx.events.iter().all(|e| e.name == "sgemm"));
    }

    #[test]
    fn han_na_kernel_sequence() {
        let (hg, plan) = setup(ModelId::Han);
        let mut ctx = Ctx::default();
        let proj = feature_projection(&mut ctx, &plan, &hg, GemmBlocking::default()).unwrap();
        ctx.drain();
        let out =
            neighbor_aggregation(&mut ctx, &plan, 0, &proj, GemmBlocking::default()).unwrap();
        assert_eq!(out.cols(), plan.config.hidden_dim);
        let names: Vec<&str> = ctx.events.iter().map(|e| e.name).collect();
        assert_eq!(
            names,
            vec![
                "vEleWise",
                "Reduce",
                "vEleWise",
                "Reduce",
                "SDDMMCoo",
                "edge_softmax",
                "SpMMCsr",
                "uEleWise"
            ],
            "HAN NA contains no DM kernel, matching the paper's Table 3"
        );
    }

    #[test]
    fn rgcn_na_is_mean_spmm() {
        let (hg, plan) = setup(ModelId::Rgcn);
        let mut ctx = Ctx::default();
        let proj = feature_projection(&mut ctx, &plan, &hg, GemmBlocking::default()).unwrap();
        ctx.drain();
        neighbor_aggregation(&mut ctx, &plan, 0, &proj, GemmBlocking::default()).unwrap();
        assert_eq!(ctx.events.len(), 1);
        assert_eq!(ctx.events[0].name, "SpMMCsr");
    }

    #[test]
    fn magnn_na_heavier_than_han() {
        let (hg, plan_h) = setup(ModelId::Han);
        let plan_m = models::magnn_plan(&hg, &ModelConfig::default()).unwrap();
        let mut ctx = Ctx::default();
        let proj =
            feature_projection(&mut ctx, &plan_m, &hg, GemmBlocking::default()).unwrap();
        ctx.drain();
        neighbor_aggregation(&mut ctx, &plan_h, 0, &proj, GemmBlocking::default()).unwrap();
        let han_bytes = ctx.totals().bytes_read;
        ctx.drain();
        neighbor_aggregation(&mut ctx, &plan_m, 0, &proj, GemmBlocking::default()).unwrap();
        let magnn_bytes = ctx.totals().bytes_read;
        assert!(
            magnn_bytes > han_bytes,
            "MAGNN moves more data: {magnn_bytes} vs {han_bytes}"
        );
    }

    #[test]
    fn han_sa_pipeline_and_output_shape() {
        let (hg, plan) = setup(ModelId::Han);
        let mut ctx = Ctx::default();
        let proj = feature_projection(&mut ctx, &plan, &hg, GemmBlocking::default()).unwrap();
        let na: Vec<Tensor> = (0..plan.num_subgraphs())
            .map(|i| {
                neighbor_aggregation(&mut ctx, &plan, i, &proj, GemmBlocking::default())
                    .unwrap()
            })
            .collect();
        ctx.drain();
        let out = semantic_aggregation(&mut ctx, &plan, &na, GemmBlocking::default()).unwrap();
        let m = hg.type_by_tag('M').unwrap();
        assert_eq!(out.shape(), (hg.node_type(m).count, plan.config.hidden_dim));
        let names: Vec<&str> = ctx.events.iter().map(|e| e.name).collect();
        assert!(names.contains(&"Concat"));
        assert!(names.contains(&"Reduce"));
        assert!(names.iter().filter(|&&n| n == "sgemm").count() >= 2);
    }

    #[test]
    fn sa_output_is_convex_combination() {
        // with beta summing to 1, SA output is bounded by the NA inputs
        let (hg, plan) = setup(ModelId::Han);
        let mut ctx = Ctx::default();
        let proj = feature_projection(&mut ctx, &plan, &hg, GemmBlocking::default()).unwrap();
        let na: Vec<Tensor> = (0..plan.num_subgraphs())
            .map(|i| {
                neighbor_aggregation(&mut ctx, &plan, i, &proj, GemmBlocking::default())
                    .unwrap()
            })
            .collect();
        let out = semantic_aggregation(&mut ctx, &plan, &na, GemmBlocking::default()).unwrap();
        for r in 0..out.rows().min(50) {
            for c in 0..out.cols() {
                let lo = na.iter().map(|t| t.get(r, c)).fold(f32::INFINITY, f32::min);
                let hi = na.iter().map(|t| t.get(r, c)).fold(f32::NEG_INFINITY, f32::max);
                let v = out.get(r, c);
                assert!(
                    v >= lo - 1e-4 && v <= hi + 1e-4,
                    "({r},{c}): {v} outside [{lo}, {hi}]"
                );
            }
        }
    }

    #[test]
    fn rgcn_sa_sums_target_relations() {
        let (hg, plan) = setup(ModelId::Rgcn);
        let mut ctx = Ctx::default();
        let proj = feature_projection(&mut ctx, &plan, &hg, GemmBlocking::default()).unwrap();
        let na: Vec<Tensor> = (0..plan.num_subgraphs())
            .map(|i| {
                neighbor_aggregation(&mut ctx, &plan, i, &proj, GemmBlocking::default())
                    .unwrap()
            })
            .collect();
        ctx.drain();
        let out = semantic_aggregation(&mut ctx, &plan, &na, GemmBlocking::default()).unwrap();
        assert_eq!(out.rows(), hg.node_type(plan.target).count);
        // D-M and A-M both target movies: manual sum must match
        let selected: Vec<&Tensor> = plan
            .subgraphs
            .subgraphs
            .iter()
            .zip(&na)
            .filter(|(sg, _)| sg.dst_type == plan.target)
            .map(|(_, t)| t)
            .collect();
        assert_eq!(selected.len(), 2);
        let manual_00 = selected.iter().map(|t| t.get(0, 0)).sum::<f32>();
        assert!((out.get(0, 0) - manual_00).abs() < 1e-5);
    }

    #[test]
    fn segment_sum_edges_validates() {
        let mut ctx = Ctx::default();
        let adj = crate::graph::sparse::Coo::from_edges(2, 2, vec![(0, 0), (0, 1)])
            .unwrap()
            .to_csr();
        let bad = Tensor::zeros(3, 4);
        assert!(segment_sum_edges(&mut ctx, &adj, &bad).is_err());
        let good = Tensor::from_vec(2, 2, vec![1., 2., 3., 4.]).unwrap();
        let out = segment_sum_edges(&mut ctx, &adj, &good).unwrap();
        assert_eq!(out.row(0), &[4.0, 6.0]);
        assert_eq!(out.row(1), &[0.0, 0.0]);
    }
}
