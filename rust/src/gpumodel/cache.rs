//! Set-associative LRU cache simulator for the T4's L2.
//!
//! Replays the gather traces recorded by TB-type kernels (feature-row
//! gathers of `SpMMCsr` / `SDDMMCoo`) to measure L2 hit rates the way
//! Nsight Compute reports them. Two realism details matter (and are unit
//! tested):
//!
//! 1. **Sector granularity** — the T4 manages 32 B sectors within 64 B
//!    lines; a gathered feature row of F floats touches `4F/32` sectors.
//! 2. **Multi-SM interleaving** — 40 SMs walk *different* destination
//!    nodes concurrently, so the L2 sees an interleave of many gather
//!    streams, not one. The simulator splits the trace into
//!    `concurrent_streams` round-robin segments, which degrades
//!    single-stream locality exactly the way concurrency does.

/// A set-associative LRU cache over byte addresses.
#[derive(Debug)]
pub struct L2Cache {
    sets: Vec<Vec<u64>>, // per-set LRU stack of line tags (front = MRU)
    assoc: usize,
    line: usize,
    n_sets: usize,
    hits: u64,
    misses: u64,
}

impl L2Cache {
    /// Build a cache of `capacity` bytes, `assoc`-way, `line`-byte lines.
    pub fn new(capacity: usize, assoc: usize, line: usize) -> L2Cache {
        let n_sets = (capacity / (assoc * line)).max(1);
        L2Cache {
            sets: vec![Vec::with_capacity(assoc); n_sets],
            assoc,
            line,
            n_sets,
            hits: 0,
            misses: 0,
        }
    }

    /// Access one byte address range `[addr, addr+len)`; every distinct
    /// line touched counts as one access.
    pub fn access(&mut self, addr: u64, len: u32) {
        let first = addr / self.line as u64;
        let last = (addr + len.max(1) as u64 - 1) / self.line as u64;
        for lineno in first..=last {
            self.touch_line(lineno);
        }
    }

    #[inline]
    fn touch_line(&mut self, lineno: u64) {
        let set = (lineno % self.n_sets as u64) as usize;
        let stack = &mut self.sets[set];
        if let Some(pos) = stack.iter().position(|&t| t == lineno) {
            stack.remove(pos);
            stack.insert(0, lineno);
            self.hits += 1;
        } else {
            if stack.len() >= self.assoc {
                stack.pop();
            }
            stack.insert(0, lineno);
            self.misses += 1;
        }
    }

    /// Line-granular hits so far.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Line-granular misses so far.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Hit rate in percent (0 when no accesses).
    pub fn hit_rate_pct(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        100.0 * self.hits as f64 / total as f64
    }

    /// Bytes fetched from DRAM (misses × line size).
    pub fn miss_bytes(&self) -> u64 {
        self.misses * self.line as u64
    }
}

/// Result of replaying a gather trace.
#[derive(Debug, Clone, PartialEq)]
pub struct GatherSim {
    /// L2 hit rate over the gather accesses, percent.
    pub hit_rate_pct: f64,
    /// Bytes the gather stream pulled from DRAM.
    pub dram_bytes: u64,
    /// Total logical bytes the gather stream requested.
    pub logical_bytes: u64,
}

/// Replay a gather trace through a scaled-down effective L2
/// (`l2_effective_fraction`), interleaving it as `streams` concurrent
/// round-robin sub-streams.
pub fn simulate_gather(
    trace: &crate::kernels::GatherTrace,
    capacity: usize,
    assoc: usize,
    line: usize,
    streams: usize,
) -> GatherSim {
    let mut cache = L2Cache::new(capacity.max(line * assoc), assoc, line);
    let rows = &trace.rows;
    let rb = trace.row_bytes as u64;
    let n = rows.len();
    let streams = streams.max(1).min(n.max(1));
    let chunk = n.div_ceil(streams);
    // round-robin across the stream segments: segment s covers
    // rows[s*chunk .. (s+1)*chunk], we take one access from each in turn.
    let mut cursors: Vec<usize> = (0..streams).map(|s| s * chunk).collect();
    let ends: Vec<usize> = (0..streams).map(|s| ((s + 1) * chunk).min(n)).collect();
    let mut remaining = n;
    while remaining > 0 {
        for s in 0..streams {
            if cursors[s] < ends[s] {
                let row = rows[cursors[s]] as u64;
                cache.access(row * rb, trace.row_bytes);
                cursors[s] += 1;
                remaining -= 1;
            }
        }
    }
    GatherSim {
        hit_rate_pct: cache.hit_rate_pct(),
        dram_bytes: cache.miss_bytes(),
        logical_bytes: rb * n as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::GatherTrace;

    #[test]
    fn repeated_access_hits() {
        let mut c = L2Cache::new(1024, 4, 64);
        c.access(0, 32);
        c.access(0, 32);
        c.access(0, 32);
        assert_eq!(c.misses(), 1);
        assert_eq!(c.hits(), 2);
    }

    #[test]
    fn capacity_eviction() {
        // 2 sets x 2 ways x 64B = 256B cache; 8 distinct lines thrash it
        let mut c = L2Cache::new(256, 2, 64);
        for round in 0..2 {
            for i in 0..8u64 {
                c.access(i * 64, 32);
            }
            let _ = round;
        }
        // second round cannot hit: working set (8 lines) > capacity (4)
        assert_eq!(c.hits(), 0);
        assert_eq!(c.misses(), 16);
    }

    #[test]
    fn lru_keeps_hot_line() {
        // 1 set x 2 ways
        let mut c = L2Cache::new(128, 2, 64);
        c.access(0, 32); // miss, lines {0}
        c.access(64, 32); // miss, {64,0}
        c.access(0, 32); // hit, {0,64}
        c.access(128, 32); // miss, evicts 64 -> {128,0}
        c.access(0, 32); // hit
        assert_eq!(c.hits(), 2);
        assert_eq!(c.misses(), 3);
    }

    #[test]
    fn multi_line_access_spans() {
        let mut c = L2Cache::new(1024, 4, 64);
        c.access(0, 256); // touches 4 lines
        assert_eq!(c.misses(), 4);
        c.access(0, 256);
        assert_eq!(c.hits(), 4);
    }

    #[test]
    fn resident_table_high_hit_rate() {
        // table of 64 rows x 256B = 16KB in a 32KB cache: after first
        // touch everything hits
        let rows: Vec<u32> = (0..10_000u32).map(|i| (i * 97) % 64).collect();
        let sim = simulate_gather(
            &GatherTrace { row_bytes: 256, rows },
            32 * 1024,
            8,
            64,
            1,
        );
        assert!(sim.hit_rate_pct > 95.0, "resident table: {}", sim.hit_rate_pct);
    }

    #[test]
    fn oversized_table_low_hit_rate() {
        // random gathers over a table 16x the cache: mostly misses
        let rows: Vec<u32> = (0..20_000u32).map(|i| i.wrapping_mul(2654435761) % 2048).collect();
        let sim = simulate_gather(
            &GatherTrace { row_bytes: 256, rows }, // 512 KB table
            32 * 1024,
            8,
            64,
            1,
        );
        assert!(sim.hit_rate_pct < 30.0, "thrashing table: {}", sim.hit_rate_pct);
        assert!(sim.dram_bytes > sim.logical_bytes / 2);
    }

    #[test]
    fn interleaving_degrades_locality() {
        // a trace with strong sequential-block locality: each block of
        // 64 consecutive accesses reuses one row
        let mut rows = Vec::new();
        for r in 0..256u32 {
            for _ in 0..64 {
                rows.push(r);
            }
        }
        let t = GatherTrace { row_bytes: 256, rows };
        let single = simulate_gather(&t, 4 * 1024, 4, 64, 1);
        let multi = simulate_gather(&t, 4 * 1024, 4, 64, 16);
        assert!(
            multi.hit_rate_pct <= single.hit_rate_pct,
            "interleave {} vs single {}",
            multi.hit_rate_pct,
            single.hit_rate_pct
        );
    }

    #[test]
    fn gather_sim_accounting() {
        let t = GatherTrace { row_bytes: 64, rows: vec![0, 0, 0, 0] };
        let sim = simulate_gather(&t, 1024, 4, 64, 1);
        assert_eq!(sim.logical_bytes, 256);
        assert_eq!(sim.dram_bytes, 64); // one line fetched once
        assert!((sim.hit_rate_pct - 75.0).abs() < 1e-9);
    }
}
