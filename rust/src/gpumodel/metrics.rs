//! Per-kernel metric derivation — the model's Nsight Compute stand-in.
//!
//! For every [`KernelExec`] this derives the Table 3 columns:
//! modeled time, AI, % of peak performance, DRAM bandwidth utilization,
//! shared-memory bandwidth utilization and L2 hit rate. The latency model
//! is a calibrated roofline: `t = launch + max(t_compute, t_dram, t_l2)`.

use crate::gpumodel::cache::simulate_gather;
use crate::gpumodel::GpuModel;
use crate::kernels::{KernelExec, KernelType};

/// Modeled metrics for one kernel invocation (Table 3 columns).
#[derive(Debug, Clone, PartialEq)]
pub struct KernelMetrics {
    /// Kernel name.
    pub name: &'static str,
    /// Kernel class.
    pub ktype: KernelType,
    /// Modeled execution time, nanoseconds.
    pub time_ns: f64,
    /// Arithmetic intensity, FLOP per DRAM byte.
    pub ai: f64,
    /// Achieved GFLOP/s.
    pub achieved_gflops: f64,
    /// Percentage of peak FP32 performance.
    pub peak_perf_pct: f64,
    /// DRAM bandwidth utilization percentage.
    pub dram_bw_util_pct: f64,
    /// Shared-memory bandwidth utilization percentage (DM kernels).
    pub smem_bw_util_pct: f64,
    /// L2 hit rate percentage.
    pub l2_hit_pct: f64,
    /// Modeled DRAM traffic in bytes.
    pub dram_bytes: u64,
    /// Logical traffic in bytes (operands touched once).
    pub logical_bytes: u64,
    /// Exact FLOPs.
    pub flops: u64,
}

/// Derive metrics for a kernel sequence.
pub fn analyze_kernels(model: &GpuModel, kernels: &[KernelExec]) -> Vec<KernelMetrics> {
    kernels.iter().map(|k| analyze_one(model, k)).collect()
}

fn analyze_one(model: &GpuModel, k: &KernelExec) -> KernelMetrics {
    let spec = &model.spec;
    let cal = &model.cal;
    let logical = k.counters.bytes_read + k.counters.bytes_written;

    // --- DRAM traffic + L2 hit rate per kernel class -------------------
    let (dram_bytes, l2_hit_pct, l2_traffic) = match (k.ktype, &k.trace) {
        (_, Some(trace)) if !trace.rows.is_empty() => {
            // TB / gather kernels: replay the gather through the effective
            // L2; everything else in the kernel is streaming.
            let eff_capacity =
                (spec.l2_bytes as f64 * cal.l2_effective_fraction) as usize;
            let sim = simulate_gather(
                trace,
                eff_capacity,
                spec.l2_assoc,
                spec.l2_line,
                cal.concurrent_streams,
            );
            let streaming = logical.saturating_sub(sim.logical_bytes);
            // streaming sectors hit on the second half of each line
            let stream_hits = 0.5;
            let total_accesses = sim.logical_bytes + streaming;
            let combined_hit = if total_accesses == 0 {
                0.0
            } else {
                (sim.hit_rate_pct / 100.0 * sim.logical_bytes as f64
                    + stream_hits * streaming as f64)
                    / total_accesses as f64
                    * 100.0
            };
            (sim.dram_bytes + streaming, combined_hit, logical)
        }
        (KernelType::DenseMatmul, _) => {
            // Tiled-GEMM memory hierarchy: operands stream DRAM→L2 once
            // (high temporal reuse across threadblock tiles), and the
            // register/shared-memory tiling means each L2-read byte
            // feeds ~TILE FMAs — so L2 traffic is flops-proportional,
            // far below the register-level operand demand.
            const TILE: f64 = 64.0;
            let dram = logical; // each operand + output once
            // 2 operand reads per FMA pair (2 flops), amortized by TILE
            let l2_traffic = ((k.counters.flops as f64 * 4.0 / TILE).max(dram as f64)) as u64;
            let hit = (100.0 * (1.0 - dram as f64 / l2_traffic.max(1) as f64)).clamp(0.0, 99.0);
            (dram, hit, l2_traffic)
        }
        (KernelType::ElementWise, _) | (KernelType::DataRearrange, _) | (_, None) => {
            // pure streaming: compulsory DRAM traffic; sector-in-line
            // reuse yields ~50% sector hit rate
            (logical, 50.0, logical)
        }
        (KernelType::TopologyBased, _) => (logical, 50.0, logical),
    };

    // --- latency roofline ----------------------------------------------
    let mem_eff = match k.ktype {
        KernelType::DenseMatmul => cal.stream_mem_eff,
        KernelType::TopologyBased => cal.gather_mem_eff,
        KernelType::ElementWise => cal.stream_mem_eff,
        KernelType::DataRearrange => cal.copy_mem_eff,
    };
    let t_dram = dram_bytes as f64 / (spec.dram_gbps * mem_eff); // ns (B / (GB/s) = ns)
    let t_l2 = l2_traffic as f64 / spec.l2_gbps;
    let t_compute = match k.ktype {
        KernelType::DenseMatmul => {
            // occupancy: small problems cannot fill 40 SMs
            let elems_out = (k.counters.bytes_written / 4).max(1);
            let tiles = (elems_out as f64 / (64.0 * 64.0)).max(1.0);
            let occupancy = (tiles / (2.0 * spec.sm_count as f64)).min(1.0);
            k.counters.flops as f64 / (spec.fp32_gflops * cal.dm_compute_eff * occupancy)
        }
        // non-DM FP pipes run far below peak on scattered data; memory
        // terms dominate anyway, a 10% compute ceiling avoids div-by-tiny
        _ => k.counters.flops as f64 / (spec.fp32_gflops * 0.10),
    };
    let time_ns = spec.launch_overhead_ns + t_compute.max(t_dram).max(t_l2);

    let achieved_gflops = k.counters.flops as f64 / time_ns; // FLOP/ns == GFLOP/s
    let smem_bytes = match k.ktype {
        KernelType::DenseMatmul => {
            // each FMA pair reads 2 operands; register reuse divides
            k.counters.flops as f64 * 4.0 / cal.dm_register_reuse
        }
        _ => 0.0,
    };

    KernelMetrics {
        name: k.name,
        ktype: k.ktype,
        time_ns,
        // Arithmetic intensity over *logical* traffic (operands touched
        // once), the convention under which the paper's Fig 4 numbers
        // (sgemm 26.8, SpMM 0.49, uEleWise 0.1, Reduce 0.34 FLOP/B)
        // reproduce and which is stable across dataset scales — DRAM-
        // measured AI would swing with cache residency of small tables.
        ai: if logical == 0 { 0.0 } else { k.counters.flops as f64 / logical as f64 },
        achieved_gflops,
        peak_perf_pct: 100.0 * achieved_gflops / spec.fp32_gflops,
        dram_bw_util_pct: 100.0 * (dram_bytes as f64 / time_ns) / spec.dram_gbps,
        smem_bw_util_pct: 100.0 * (smem_bytes / time_ns) / spec.smem_gbps,
        l2_hit_pct,
        dram_bytes,
        logical_bytes: logical,
        flops: k.counters.flops,
    }
}

/// Aggregate metrics of several invocations of the same kernel
/// (time-weighted where that is meaningful).
pub fn aggregate(metrics: &[KernelMetrics]) -> Option<KernelMetrics> {
    let first = metrics.first()?;
    let total_time: f64 = metrics.iter().map(|m| m.time_ns).sum();
    let total_flops: u64 = metrics.iter().map(|m| m.flops).sum();
    let total_dram: u64 = metrics.iter().map(|m| m.dram_bytes).sum();
    let total_logical: u64 = metrics.iter().map(|m| m.logical_bytes).sum();
    let wavg = |f: fn(&KernelMetrics) -> f64| -> f64 {
        if total_time == 0.0 {
            return 0.0;
        }
        metrics.iter().map(|m| f(m) * m.time_ns).sum::<f64>() / total_time
    };
    Some(KernelMetrics {
        name: first.name,
        ktype: first.ktype,
        time_ns: total_time,
        ai: if total_logical == 0 {
            0.0
        } else {
            total_flops as f64 / total_logical as f64
        },
        achieved_gflops: if total_time == 0.0 { 0.0 } else { total_flops as f64 / total_time },
        peak_perf_pct: wavg(|m| m.peak_perf_pct),
        dram_bw_util_pct: wavg(|m| m.dram_bw_util_pct),
        smem_bw_util_pct: wavg(|m| m.smem_bw_util_pct),
        l2_hit_pct: wavg(|m| m.l2_hit_pct),
        dram_bytes: total_dram,
        logical_bytes: total_logical,
        flops: total_flops,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{GatherTrace, KernelCounters};

    fn model() -> GpuModel {
        GpuModel::default()
    }

    fn exec(
        name: &'static str,
        ktype: KernelType,
        flops: u64,
        read: u64,
        written: u64,
        trace: Option<GatherTrace>,
    ) -> KernelExec {
        KernelExec {
            name,
            ktype,
            counters: KernelCounters { flops, bytes_read: read, bytes_written: written },
            wall_nanos: 0,
            trace,
        }
    }

    #[test]
    fn big_gemm_near_peak() {
        // 2048^3 gemm: heavily compute bound
        let n = 2048u64;
        let k = exec(
            "sgemm",
            KernelType::DenseMatmul,
            2 * n * n * n,
            2 * n * n * 4,
            n * n * 4,
            None,
        );
        let m = analyze_kernels(&model(), &[k]);
        assert!(m[0].peak_perf_pct > 85.0, "peak {}", m[0].peak_perf_pct);
        assert!(m[0].ai > 9.375, "ai {}", m[0].ai);
        assert!(m[0].l2_hit_pct > 80.0, "l2 {}", m[0].l2_hit_pct);
        assert!(m[0].smem_bw_util_pct > 1.0 && m[0].smem_bw_util_pct < 100.0);
    }

    #[test]
    fn tiny_gemm_occupancy_limited() {
        // 64x64x64: one tile, cannot fill the GPU
        let k = exec(
            "sgemm",
            KernelType::DenseMatmul,
            2 * 64 * 64 * 64,
            2 * 64 * 64 * 4,
            64 * 64 * 4,
            None,
        );
        let m = analyze_kernels(&model(), &[k]);
        assert!(m[0].peak_perf_pct < 10.0, "tiny gemm peak {}", m[0].peak_perf_pct);
    }

    #[test]
    fn elementwise_memory_bound() {
        let n = 64 * 1024 * 1024u64;
        let k = exec("uEleWise", KernelType::ElementWise, n / 4, n, n, None);
        let m = analyze_kernels(&model(), &[k]);
        assert!(m[0].ai < 1.0);
        assert!(m[0].peak_perf_pct < 5.0);
        assert!(m[0].dram_bw_util_pct > 70.0, "bw {}", m[0].dram_bw_util_pct);
        assert_eq!(m[0].l2_hit_pct, 50.0);
    }

    #[test]
    fn gather_thrash_raises_dram_traffic() {
        // random gather over a table far larger than effective L2
        let table_rows = 1_000_000u32; // 256 MB table
        let rows: Vec<u32> =
            (0..200_000u32).map(|i| (i.wrapping_mul(2654435761)) % table_rows).collect();
        let gather_bytes = 200_000u64 * 256;
        let k = exec(
            "SpMMCsr",
            KernelType::TopologyBased,
            200_000 * 64,
            gather_bytes + 200_000 * 8,
            100_000 * 256,
            Some(GatherTrace { row_bytes: 256, rows }),
        );
        let m = analyze_kernels(&model(), &[k]);
        assert!(m[0].l2_hit_pct < 40.0, "thrash l2 {}", m[0].l2_hit_pct);
        assert!(m[0].dram_bw_util_pct > 50.0, "bw {}", m[0].dram_bw_util_pct);
        assert!(m[0].ai < 1.0, "ai {}", m[0].ai);
    }

    #[test]
    fn launch_overhead_floors_tiny_kernels() {
        let k = exec("uEleWise", KernelType::ElementWise, 8, 32, 32, None);
        let m = analyze_kernels(&model(), &[k]);
        assert!(m[0].time_ns >= model().spec.launch_overhead_ns);
    }

    #[test]
    fn aggregate_weighted() {
        let k1 = exec("Reduce", KernelType::ElementWise, 1000, 8_000_000, 4_000, None);
        let k2 = exec("Reduce", KernelType::ElementWise, 1000, 8_000_000, 4_000, None);
        let ms = analyze_kernels(&model(), &[k1, k2]);
        let agg = aggregate(&ms).unwrap();
        assert_eq!(agg.flops, 2000);
        assert!((agg.time_ns - 2.0 * ms[0].time_ns).abs() < 1e-6);
        assert!((agg.dram_bw_util_pct - ms[0].dram_bw_util_pct).abs() < 1e-6);
        assert!(aggregate(&[]).is_none());
    }
}
