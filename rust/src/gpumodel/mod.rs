//! Trace-driven + analytical NVIDIA T4 performance model.
//!
//! The paper profiles kernels with Nsight Compute on a T4; this module is
//! the DESIGN.md §4 substitution for that hardware: given the exact
//! operation counters and gather traces recorded by [`crate::kernels`],
//! it derives the same per-kernel metrics Table 3 and Fig 4 report —
//! modeled execution time, arithmetic intensity (FLOP / DRAM byte),
//! percentage of peak performance, DRAM bandwidth utilization, shared
//! memory bandwidth utilization, and L2 cache hit rate.
//!
//! The model is **calibrated, not fitted per-result**: a handful of
//! per-kernel-class efficiency constants (see [`Calibration`]) are set
//! once from the paper's published Table 3 bands and then applied
//! uniformly to every kernel in every experiment. All *relative* results
//! (stage breakdowns, who dominates, memory- vs compute-bound) emerge
//! from the counters, not the calibration.

pub mod cache;
pub mod metrics;
pub mod roofline;
pub mod spec;

pub use cache::L2Cache;
pub use metrics::{analyze_kernels, KernelMetrics};
pub use roofline::{attainable_flops, RooflinePoint};
pub use spec::{Calibration, T4Spec};

use crate::kernels::KernelExec;

/// The GPU model: a device spec plus calibration constants.
#[derive(Debug, Clone)]
pub struct GpuModel {
    /// Device specification (peaks, cache geometry).
    pub spec: T4Spec,
    /// Per-kernel-class efficiency calibration.
    pub cal: Calibration,
}

impl Default for GpuModel {
    fn default() -> Self {
        GpuModel { spec: T4Spec::t4(), cal: Calibration::default() }
    }
}

impl GpuModel {
    /// Analyze a sequence of executed kernels, producing modeled metrics
    /// per kernel (same order).
    pub fn analyze(&self, kernels: &[KernelExec]) -> Vec<KernelMetrics> {
        analyze_kernels(self, kernels)
    }

    /// Total modeled GPU nanoseconds for a kernel sequence.
    pub fn modeled_total_nanos(&self, kernels: &[KernelExec]) -> f64 {
        self.analyze(kernels).iter().map(|m| m.time_ns).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{GatherTrace, KernelCounters, KernelType};

    fn mk_exec(
        name: &'static str,
        ktype: KernelType,
        flops: u64,
        read: u64,
        written: u64,
        trace: Option<GatherTrace>,
    ) -> KernelExec {
        KernelExec {
            name,
            ktype,
            counters: KernelCounters { flops, bytes_read: read, bytes_written: written },
            wall_nanos: 1000,
            trace,
        }
    }

    #[test]
    fn compute_bound_gemm_vs_memory_bound_spmm() {
        let model = GpuModel::default();
        // big square gemm: high AI
        let gemm = mk_exec(
            "sgemm",
            KernelType::DenseMatmul,
            2 * 1024 * 1024 * 1024,
            2 * 4 * 1024 * 1024,
            4 * 1024 * 1024,
            None,
        );
        // spmm: low AI, random gather
        let rows: Vec<u32> =
            (0..100_000u32).map(|i| i.wrapping_mul(2654435761) % 50_000).collect();
        let spmm = mk_exec(
            "SpMMCsr",
            KernelType::TopologyBased,
            100_000 * 64,
            100_000 * 256,
            50_000 * 256,
            Some(GatherTrace { row_bytes: 256, rows }),
        );
        let ms = model.analyze(&[gemm, spmm]);
        assert!(ms[0].ai > model.spec.ridge_ai(), "gemm above ridge: {}", ms[0].ai);
        assert!(ms[1].ai < model.spec.ridge_ai(), "spmm below ridge: {}", ms[1].ai);
        assert!(ms[0].peak_perf_pct > 50.0, "gemm near peak: {}", ms[0].peak_perf_pct);
        assert!(ms[1].peak_perf_pct < 20.0, "spmm far from peak: {}", ms[1].peak_perf_pct);
        assert!(
            ms[1].dram_bw_util_pct > ms[0].dram_bw_util_pct,
            "spmm more bandwidth-hungry"
        );
    }

    #[test]
    fn calibration_scales_memory_time() {
        // halving the stream efficiency must roughly double a
        // memory-bound kernel's modeled time
        let fast = GpuModel::default();
        let mut slow = GpuModel::default();
        slow.cal.stream_mem_eff = fast.cal.stream_mem_eff / 2.0;
        let k = mk_exec(
            "uEleWise",
            KernelType::ElementWise,
            1_000_000,
            400_000_000,
            400_000_000,
            None,
        );
        let t_fast = fast.modeled_total_nanos(std::slice::from_ref(&k));
        let t_slow = slow.modeled_total_nanos(&[k]);
        let ratio = t_slow / t_fast;
        assert!((ratio - 2.0).abs() < 0.05, "ratio {ratio}");
    }

    #[test]
    fn launch_overhead_dominates_empty_kernel() {
        let model = GpuModel::default();
        let k = mk_exec("uEleWise", KernelType::ElementWise, 0, 0, 0, None);
        let t = model.modeled_total_nanos(&[k]);
        assert!((t - model.spec.launch_overhead_ns).abs() < 1e-9);
    }

    #[test]
    fn modeled_total_adds_up() {
        let model = GpuModel::default();
        let k = mk_exec("uEleWise", KernelType::ElementWise, 1000, 4000, 4000, None);
        let total = model.modeled_total_nanos(&[k.clone(), k]);
        let single = model.modeled_total_nanos(&[mk_exec(
            "uEleWise",
            KernelType::ElementWise,
            1000,
            4000,
            4000,
            None,
        )]);
        assert!((total - 2.0 * single).abs() < 1e-6);
    }
}
