//! Roofline model (paper Fig 4).
//!
//! Attainable performance at arithmetic intensity `ai` is
//! `min(peak, ai × bandwidth)`; the ridge sits at `peak / bandwidth`
//! (9.37 FLOP/byte for the paper's T4 operating point). Kernels above the
//! ridge are compute-bound (sgemm at 26.8 FLOP/byte), kernels below are
//! memory-bound (SpMMCsr at 0.49, uEleWise at 0.1, Reduce at 0.34).

use crate::gpumodel::spec::T4Spec;

/// One kernel's placement on the roofline.
#[derive(Debug, Clone, PartialEq)]
pub struct RooflinePoint {
    /// Kernel name.
    pub name: String,
    /// Arithmetic intensity, FLOP / DRAM byte.
    pub ai: f64,
    /// Achieved GFLOP/s (modeled).
    pub achieved_gflops: f64,
    /// Attainable GFLOP/s at this AI.
    pub attainable_gflops: f64,
    /// True when the kernel sits at/above the ridge.
    pub compute_bound: bool,
}

/// Attainable FLOP/s (in GFLOP/s) at a given arithmetic intensity.
pub fn attainable_flops(spec: &T4Spec, ai: f64) -> f64 {
    (ai * spec.dram_gbps).min(spec.fp32_gflops)
}

/// Build a roofline point for a kernel.
pub fn place(spec: &T4Spec, name: &str, ai: f64, achieved_gflops: f64) -> RooflinePoint {
    RooflinePoint {
        name: name.to_string(),
        ai,
        achieved_gflops,
        attainable_gflops: attainable_flops(spec, ai),
        compute_bound: ai >= spec.ridge_ai(),
    }
}

/// Render an ASCII log-log roofline chart with the given points
/// (x: AI from 0.01 to 100, y: GFLOP/s from 1 to peak).
pub fn ascii_chart(spec: &T4Spec, points: &[RooflinePoint]) -> String {
    const W: usize = 72;
    const H: usize = 20;
    let x_min = 0.01f64.log10();
    let x_max = 100f64.log10();
    let y_min = 1f64.log10();
    let y_max = (spec.fp32_gflops * 1.5).log10();
    let to_col = |ai: f64| -> usize {
        let t = (ai.max(0.011).log10() - x_min) / (x_max - x_min);
        ((t * (W - 1) as f64).round() as isize).clamp(0, W as isize - 1) as usize
    };
    let to_row = |gf: f64| -> usize {
        let t = (gf.max(1.01).log10() - y_min) / (y_max - y_min);
        let r = ((1.0 - t) * (H - 1) as f64).round() as isize;
        r.clamp(0, H as isize - 1) as usize
    };
    let mut grid = vec![vec![' '; W]; H];
    // draw the roof
    for col in 0..W {
        let ai = 10f64.powf(x_min + (x_max - x_min) * col as f64 / (W - 1) as f64);
        let roof = attainable_flops(spec, ai);
        grid[to_row(roof)][col] = '-';
    }
    // ridge marker
    let ridge_col = to_col(spec.ridge_ai());
    for (row, grow) in grid.iter_mut().enumerate() {
        if row % 2 == 0 {
            let c = &mut grow[ridge_col];
            if *c == ' ' {
                *c = ':';
            }
        }
    }
    // points (labelled by first letter)
    for p in points {
        let c = p.name.chars().next().unwrap_or('*');
        grid[to_row(p.achieved_gflops)][to_col(p.ai)] = c;
    }
    let mut out = String::new();
    out.push_str(&format!(
        "GFLOP/s (log)  peak={:.0}  ridge AI={:.2} FLOP/B\n",
        spec.fp32_gflops,
        spec.ridge_ai()
    ));
    for row in grid {
        out.push_str(&row.iter().collect::<String>());
        out.push('\n');
    }
    out.push_str(&format!("{:<36}AI (FLOP/byte, log) ->\n", "0.01"));
    for p in points {
        out.push_str(&format!(
            "  {} = {:<12} AI {:>8.2}  achieved {:>9.1} GF/s  attainable {:>9.1}  [{}]\n",
            p.name.chars().next().unwrap_or('*'),
            p.name,
            p.ai,
            p.achieved_gflops,
            p.attainable_gflops,
            if p.compute_bound { "compute-bound" } else { "memory-bound" }
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attainable_clamps_at_peak() {
        let spec = T4Spec::t4();
        assert!((attainable_flops(&spec, 0.1) - 32.0).abs() < 1e-9);
        assert_eq!(attainable_flops(&spec, 100.0), spec.fp32_gflops);
        assert!(
            (attainable_flops(&spec, spec.ridge_ai()) - spec.fp32_gflops).abs() < 1e-6
        );
    }

    #[test]
    fn placement_bound_classification() {
        let spec = T4Spec::t4();
        let gemm = place(&spec, "sgemm", 26.8, 2877.0);
        assert!(gemm.compute_bound);
        let spmm = place(&spec, "SpMMCsr", 0.49, 117.0);
        assert!(!spmm.compute_bound);
        assert!(spmm.attainable_gflops < 200.0);
    }

    #[test]
    fn chart_renders_all_points() {
        let spec = T4Spec::t4();
        let pts = vec![
            place(&spec, "sgemm", 26.8, 2877.0),
            place(&spec, "SpMMCsr", 0.49, 117.0),
            place(&spec, "uEleWise", 0.1, 27.0),
        ];
        let chart = ascii_chart(&spec, &pts);
        assert!(chart.contains("sgemm"));
        assert!(chart.contains("memory-bound"));
        assert!(chart.contains("compute-bound"));
        assert!(chart.lines().count() > 20);
    }
}
