//! NVIDIA T4 device specification and model calibration constants.

/// Device specification. Defaults model the NVIDIA T4 the paper profiles.
#[derive(Debug, Clone, PartialEq)]
pub struct T4Spec {
    /// Device name for reports.
    pub name: &'static str,
    /// Sustained FP32 peak in GFLOP/s.
    ///
    /// The T4's datasheet boost peak is 8.1 TFLOP/s, but the 70 W card
    /// sustains its base clock under load: 2560 cores × 2 × 585 MHz ≈
    /// 3.0 TFLOP/s. The paper's own numbers pin this: Table 3's sgemm
    /// shows 95.9% peak with 33.6% DRAM utilization and AI 26.8, which is
    /// only consistent with a ~3.0 TFLOP/s peak, and Fig 4 places the
    /// roofline ridge at 9.37 FLOP/byte = 3000 / 320.
    pub fp32_gflops: f64,
    /// DRAM (GDDR6) bandwidth in GB/s.
    pub dram_gbps: f64,
    /// Aggregate shared-memory bandwidth in GB/s.
    pub smem_gbps: f64,
    /// Aggregate L2 bandwidth in GB/s.
    pub l2_gbps: f64,
    /// L2 capacity in bytes.
    pub l2_bytes: usize,
    /// L2 line (sector) size in bytes — T4 manages 32 B sectors.
    pub l2_sector: usize,
    /// Cache line size in bytes (2 sectors).
    pub l2_line: usize,
    /// L2 associativity used by the simulator.
    pub l2_assoc: usize,
    /// Streaming-multiprocessor count.
    pub sm_count: usize,
    /// Kernel launch overhead in nanoseconds (per kernel).
    pub launch_overhead_ns: f64,
}

impl T4Spec {
    /// The NVIDIA T4 (Turing TU104, 70 W).
    pub fn t4() -> T4Spec {
        T4Spec {
            name: "NVIDIA T4",
            fp32_gflops: 3_000.0,
            dram_gbps: 320.0,
            smem_gbps: 8_100.0,
            l2_gbps: 1_300.0,
            l2_bytes: 4 * 1024 * 1024,
            l2_sector: 32,
            l2_line: 64,
            l2_assoc: 16,
            sm_count: 40,
            launch_overhead_ns: 3_000.0,
        }
    }

    /// Roofline ridge point in FLOP/byte: `peak / bandwidth`.
    /// For the T4 model this is 3000/320 = 9.375, matching the paper's
    /// Fig 4 ridge of 9.37.
    pub fn ridge_ai(&self) -> f64 {
        self.fp32_gflops / self.dram_gbps
    }
}

/// Per-kernel-class efficiency calibration (DESIGN.md §4).
///
/// These constants are set once from the paper's Table 3 bands and reused
/// unchanged across every experiment; they encode how far each kernel
/// class sits from theoretical peaks on real silicon (coalescing losses,
/// occupancy, replay overhead), which a pure first-principles model
/// cannot see.
#[derive(Debug, Clone, PartialEq)]
pub struct Calibration {
    /// Compute efficiency ceiling of dense matmul at full occupancy
    /// (paper: sgemm reaches 95.9% peak).
    pub dm_compute_eff: f64,
    /// Memory efficiency of regular streaming access (EW kernels sustain
    /// 82–88% of DRAM bandwidth — Table 3).
    pub stream_mem_eff: f64,
    /// Memory efficiency of irregular gather access (TB kernels sustain
    /// ~75% — SpMMCsr's 74.3% in Table 3).
    pub gather_mem_eff: f64,
    /// Memory efficiency of pure-copy kernels (Concat: 81.6%).
    pub copy_mem_eff: f64,
    /// Register-level operand reuse in the DM micro-kernel (each smem
    /// load feeds this many FMAs) — sets shared-memory traffic.
    pub dm_register_reuse: f64,
    /// Fraction of L2 effectively available to one kernel's reuse window
    /// (multi-SM contention, partitioning, replacement imprecision).
    pub l2_effective_fraction: f64,
    /// Number of concurrent SM access streams the cache simulator
    /// interleaves (destroys single-stream locality the way 40 SMs do).
    pub concurrent_streams: usize,
}

impl Default for Calibration {
    fn default() -> Self {
        Calibration {
            dm_compute_eff: 0.96,
            stream_mem_eff: 0.86,
            gather_mem_eff: 0.75,
            copy_mem_eff: 0.82,
            dm_register_reuse: 8.0,
            l2_effective_fraction: 0.25,
            concurrent_streams: 8,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ridge_matches_paper_fig4() {
        let spec = T4Spec::t4();
        assert!((spec.ridge_ai() - 9.375).abs() < 0.01, "ridge {}", spec.ridge_ai());
    }

    #[test]
    fn geometry_sane() {
        let spec = T4Spec::t4();
        assert_eq!(spec.l2_line, 2 * spec.l2_sector);
        assert!(spec.l2_bytes % (spec.l2_assoc * spec.l2_line) == 0);
    }

    #[test]
    fn calibration_in_unit_range() {
        let c = Calibration::default();
        for v in [
            c.dm_compute_eff,
            c.stream_mem_eff,
            c.gather_mem_eff,
            c.copy_mem_eff,
            c.l2_effective_fraction,
        ] {
            assert!(v > 0.0 && v <= 1.0);
        }
        assert!(c.dm_register_reuse >= 1.0);
        assert!(c.concurrent_streams >= 1);
    }
}
