//! The typed heterogeneous-graph container.
//!
//! Mirrors the paper's Table 2 structure: a set of node types each with a
//! count and a raw feature dimension (features may differ per type — the
//! reason the Feature Projection stage exists), and a set of relations
//! (typed edge sets) stored as CSR blocks `dst_type x src_type`.

use std::collections::HashMap;

use crate::graph::sparse::Csr;
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Index of a node type within a [`HeteroGraph`].
pub type NodeTypeId = usize;
/// Index of a relation within a [`HeteroGraph`].
pub type RelationId = usize;

/// A node type: name, cardinality and raw feature dimension.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeType {
    /// Human name, e.g. `"movie"`.
    pub name: String,
    /// Short tag used in metapath strings, e.g. `'M'`.
    pub tag: char,
    /// Number of nodes of this type.
    pub count: usize,
    /// Raw feature dimension of this type (pre-projection).
    pub feat_dim: usize,
}

/// A relation (typed edge set): directed `src_type -> dst_type` edges,
/// stored as a CSR with one row per *destination* node (the layout
/// neighbor aggregation consumes).
#[derive(Debug, Clone)]
pub struct Relation {
    /// Human name, e.g. `"M-D"` (movie to director).
    pub name: String,
    /// Source node type.
    pub src: NodeTypeId,
    /// Destination node type.
    pub dst: NodeTypeId,
    /// Adjacency: `adj.n_rows == dst.count`, `adj.n_cols == src.count`,
    /// `adj.row(d)` = source neighbors of destination node `d`.
    pub adj: Csr,
}

/// Heterogeneous graph: typed nodes with per-type features + typed edges.
#[derive(Debug, Clone)]
pub struct HeteroGraph {
    /// Dataset name, e.g. `"IMDB"`.
    pub name: String,
    node_types: Vec<NodeType>,
    relations: Vec<Relation>,
    /// Per-type raw feature matrices `[count, feat_dim]`.
    features: Vec<Tensor>,
    tag_index: HashMap<char, NodeTypeId>,
    name_index: HashMap<String, NodeTypeId>,
    rel_index: HashMap<(NodeTypeId, NodeTypeId), Vec<RelationId>>,
}

impl HeteroGraph {
    /// All node types.
    pub fn node_types(&self) -> &[NodeType] {
        &self.node_types
    }

    /// All relations.
    pub fn relations(&self) -> &[Relation] {
        &self.relations
    }

    /// Node type by id.
    pub fn node_type(&self, id: NodeTypeId) -> &NodeType {
        &self.node_types[id]
    }

    /// Relation by id.
    pub fn relation(&self, id: RelationId) -> &Relation {
        &self.relations[id]
    }

    /// Raw features of a node type.
    pub fn features(&self, id: NodeTypeId) -> &Tensor {
        &self.features[id]
    }

    /// Look up a node type by its metapath tag (e.g. `'M'`).
    pub fn type_by_tag(&self, tag: char) -> Result<NodeTypeId> {
        self.tag_index
            .get(&tag)
            .copied()
            .ok_or_else(|| Error::NotFound(format!("node type tag '{tag}' in {}", self.name)))
    }

    /// Look up a node type by name.
    pub fn type_by_name(&self, name: &str) -> Result<NodeTypeId> {
        self.name_index
            .get(name)
            .copied()
            .ok_or_else(|| Error::NotFound(format!("node type '{name}' in {}", self.name)))
    }

    /// Relations going `src -> dst` (usually zero or one).
    pub fn relations_between(&self, src: NodeTypeId, dst: NodeTypeId) -> &[RelationId] {
        self.rel_index.get(&(src, dst)).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Total node count across all types.
    pub fn total_nodes(&self) -> usize {
        self.node_types.iter().map(|t| t.count).sum()
    }

    /// Total edge count across all relations.
    pub fn total_edges(&self) -> usize {
        self.relations.iter().map(|r| r.adj.nnz()).sum()
    }

    /// Total raw feature bytes (f32).
    pub fn feature_bytes(&self) -> usize {
        self.features.iter().map(|f| f.bytes()).sum()
    }

    /// One-line statistics string (used by dataset listings).
    pub fn stats_line(&self) -> String {
        format!(
            "{}: {} node types ({} nodes), {} relations ({} edges), {} feature data",
            self.name,
            self.node_types.len(),
            self.total_nodes(),
            self.relations.len(),
            self.total_edges(),
            crate::util::human_bytes(self.feature_bytes() as f64),
        )
    }

    /// Return a copy with every relation's edges dropped independently
    /// with probability `p` (deterministic in `seed`) — the Fig 5(a)
    /// dropout sweep's graph transform.
    pub fn dropout_edges(&self, p: f64, seed: u64) -> HeteroGraph {
        let mut out = self.clone();
        for (i, rel) in out.relations.iter_mut().enumerate() {
            let mut rng = crate::util::Pcg32::new(seed, i as u64);
            rel.adj = rel.adj.dropout(p, &mut rng);
        }
        out
    }

    /// Insert a directed edge `src node -> dst node` into relation `rel`,
    /// keeping the CSR row sorted. Returns `false` when the edge already
    /// exists. This is the [`crate::dynamic`] update-log primitive; it is
    /// only called at an epoch barrier, never while a snapshot is served.
    pub fn insert_edge(&mut self, rel: RelationId, dst: u32, src: u32) -> Result<bool> {
        let r = self
            .relations
            .get_mut(rel)
            .ok_or_else(|| Error::NotFound(format!("relation id {rel}")))?;
        r.adj.insert(dst as usize, src)
    }

    /// Append a node of type `ty` with the given raw feature row; returns
    /// the new node id. Grows the row/column space of every relation
    /// touching `ty` (the new node starts with no edges).
    pub fn push_node(&mut self, ty: NodeTypeId, features: &[f32]) -> Result<u32> {
        let t = self
            .node_types
            .get(ty)
            .ok_or_else(|| Error::NotFound(format!("node type id {ty}")))?;
        if features.len() != t.feat_dim {
            return Err(Error::shape(format!(
                "push_node({}): {} features, type has feat_dim {}",
                t.name,
                features.len(),
                t.feat_dim
            )));
        }
        let id = t.count as u32;
        let mut data = self.features[ty].as_slice().to_vec();
        data.extend_from_slice(features);
        self.features[ty] = Tensor::from_vec(t.count + 1, t.feat_dim, data)?;
        self.node_types[ty].count += 1;
        for r in &mut self.relations {
            if r.dst == ty {
                r.adj.add_row();
            }
            if r.src == ty {
                r.adj.add_col();
            }
        }
        Ok(id)
    }

    /// Overwrite the raw feature row of one node.
    pub fn set_feature_row(&mut self, ty: NodeTypeId, node: u32, row: &[f32]) -> Result<()> {
        let t = self
            .node_types
            .get(ty)
            .ok_or_else(|| Error::NotFound(format!("node type id {ty}")))?;
        if node as usize >= t.count {
            return Err(Error::shape(format!(
                "set_feature_row({}): node {} >= count {}",
                t.name, node, t.count
            )));
        }
        if row.len() != t.feat_dim {
            return Err(Error::shape(format!(
                "set_feature_row({}): {} features, type has feat_dim {}",
                t.name,
                row.len(),
                t.feat_dim
            )));
        }
        self.features[ty].set_row(node as usize, row);
        Ok(())
    }

    /// Validate the whole container (shapes, CSR structure, index maps).
    pub fn validate(&self) -> Result<()> {
        if self.node_types.len() != self.features.len() {
            return Err(Error::shape("features/node_types length mismatch"));
        }
        for (i, t) in self.node_types.iter().enumerate() {
            let f = &self.features[i];
            if f.shape() != (t.count, t.feat_dim) {
                return Err(Error::shape(format!(
                    "features[{}] shape {:?} != ({}, {})",
                    t.name,
                    f.shape(),
                    t.count,
                    t.feat_dim
                )));
            }
        }
        for r in &self.relations {
            r.adj.validate()?;
            if r.adj.n_rows != self.node_types[r.dst].count
                || r.adj.n_cols != self.node_types[r.src].count
            {
                return Err(Error::shape(format!(
                    "relation {} adjacency {}x{} vs dst {} src {}",
                    r.name,
                    r.adj.n_rows,
                    r.adj.n_cols,
                    self.node_types[r.dst].count,
                    self.node_types[r.src].count
                )));
            }
        }
        Ok(())
    }
}

/// Incremental builder for [`HeteroGraph`].
#[derive(Debug)]
pub struct HeteroGraphBuilder {
    name: String,
    node_types: Vec<NodeType>,
    relations: Vec<Relation>,
    features: Vec<Tensor>,
}

impl HeteroGraphBuilder {
    /// Start building a graph with the given dataset name.
    pub fn new(name: impl Into<String>) -> Self {
        HeteroGraphBuilder {
            name: name.into(),
            node_types: Vec::new(),
            relations: Vec::new(),
            features: Vec::new(),
        }
    }

    /// Add a node type with its feature matrix; returns its id.
    pub fn add_node_type(
        &mut self,
        name: impl Into<String>,
        tag: char,
        features: Tensor,
    ) -> NodeTypeId {
        let id = self.node_types.len();
        self.node_types.push(NodeType {
            name: name.into(),
            tag,
            count: features.rows(),
            feat_dim: features.cols(),
        });
        self.features.push(features);
        id
    }

    /// Add a relation; `adj` must be `dst.count x src.count`. Returns its id.
    pub fn add_relation(
        &mut self,
        name: impl Into<String>,
        src: NodeTypeId,
        dst: NodeTypeId,
        adj: Csr,
    ) -> RelationId {
        let id = self.relations.len();
        self.relations.push(Relation { name: name.into(), src, dst, adj });
        id
    }

    /// Finalize; validates all invariants.
    pub fn build(self) -> Result<HeteroGraph> {
        let mut tag_index = HashMap::new();
        let mut name_index = HashMap::new();
        for (i, t) in self.node_types.iter().enumerate() {
            if tag_index.insert(t.tag, i).is_some() {
                return Err(Error::config(format!("duplicate node tag '{}'", t.tag)));
            }
            if name_index.insert(t.name.clone(), i).is_some() {
                return Err(Error::config(format!("duplicate node type '{}'", t.name)));
            }
        }
        let mut rel_index: HashMap<(NodeTypeId, NodeTypeId), Vec<RelationId>> = HashMap::new();
        for (i, r) in self.relations.iter().enumerate() {
            rel_index.entry((r.src, r.dst)).or_default().push(i);
        }
        let g = HeteroGraph {
            name: self.name,
            node_types: self.node_types,
            relations: self.relations,
            features: self.features,
            tag_index,
            name_index,
            rel_index,
        };
        g.validate()?;
        Ok(g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::sparse::Coo;

    fn tiny_graph() -> HeteroGraph {
        let mut b = HeteroGraphBuilder::new("tiny");
        let m = b.add_node_type("movie", 'M', Tensor::full(3, 4, 1.0));
        let d = b.add_node_type("director", 'D', Tensor::full(2, 5, 2.0));
        let adj = Coo::from_edges(3, 2, vec![(0, 0), (1, 0), (2, 1)]).unwrap().to_csr();
        b.add_relation("D-M", d, m, adj.clone());
        b.add_relation("M-D", m, d, adj.transposed());
        b.build().unwrap()
    }

    #[test]
    fn build_and_lookup() {
        let g = tiny_graph();
        assert_eq!(g.total_nodes(), 5);
        assert_eq!(g.total_edges(), 6);
        assert_eq!(g.type_by_tag('M').unwrap(), 0);
        assert_eq!(g.type_by_name("director").unwrap(), 1);
        assert!(g.type_by_tag('X').is_err());
        assert_eq!(g.relations_between(1, 0), &[0]);
        assert_eq!(g.relations_between(0, 0), &[] as &[usize]);
    }

    #[test]
    fn duplicate_tags_rejected() {
        let mut b = HeteroGraphBuilder::new("dup");
        b.add_node_type("a", 'A', Tensor::zeros(1, 1));
        b.add_node_type("b", 'A', Tensor::zeros(1, 1));
        assert!(b.build().is_err());
    }

    #[test]
    fn bad_relation_shape_rejected() {
        let mut b = HeteroGraphBuilder::new("bad");
        let m = b.add_node_type("m", 'M', Tensor::zeros(3, 2));
        let d = b.add_node_type("d", 'D', Tensor::zeros(2, 2));
        // adjacency claims 4 destination rows but dst type has 3 nodes
        let adj = Csr::empty(4, 2);
        b.add_relation("bad", d, m, adj);
        assert!(b.build().is_err());
    }

    #[test]
    fn insert_edge_and_push_node_mutators() {
        let mut g = tiny_graph();
        // D-M is relation 0: rows = movies, cols = directors
        assert!(g.insert_edge(0, 0, 1).unwrap());
        assert!(!g.insert_edge(0, 0, 1).unwrap(), "duplicate edge is a no-op");
        assert_eq!(g.relation(0).adj.row(0), &[0, 1]);
        g.validate().unwrap();

        // new movie: grows D-M rows and M-D cols
        let id = g.push_node(0, &[9.0, 9.0, 9.0, 9.0]).unwrap();
        assert_eq!(id, 3);
        assert_eq!(g.node_type(0).count, 4);
        assert_eq!(g.relation(0).adj.n_rows, 4);
        assert_eq!(g.relation(1).adj.n_cols, 4);
        assert_eq!(g.features(0).rows(), 4);
        assert_eq!(g.features(0).row(3), &[9.0; 4]);
        g.validate().unwrap();
        // the new node starts edge-less and can receive edges
        assert_eq!(g.relation(0).adj.row(3), &[] as &[u32]);
        assert!(g.insert_edge(0, 3, 1).unwrap());
        g.validate().unwrap();

        // feature overwrite
        g.set_feature_row(0, 3, &[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(g.features(0).row(3), &[1.0, 2.0, 3.0, 4.0]);

        // shape / bounds errors
        assert!(g.push_node(0, &[1.0]).is_err());
        assert!(g.push_node(9, &[1.0]).is_err());
        assert!(g.set_feature_row(0, 99, &[0.0; 4]).is_err());
        assert!(g.set_feature_row(0, 0, &[0.0; 2]).is_err());
        assert!(g.insert_edge(9, 0, 0).is_err());
    }

    #[test]
    fn stats_line_mentions_name() {
        let g = tiny_graph();
        assert!(g.stats_line().contains("tiny"));
    }

    #[test]
    fn dropout_edges_thins_all_relations() {
        let g = tiny_graph();
        let none = g.dropout_edges(1.0, 1);
        assert_eq!(none.total_edges(), 0);
        let all = g.dropout_edges(0.0, 1);
        assert_eq!(all.total_edges(), g.total_edges());
        all.validate().unwrap();
        none.validate().unwrap();
        // deterministic in the seed
        let a = g.dropout_edges(0.5, 7);
        let b = g.dropout_edges(0.5, 7);
        assert_eq!(a.total_edges(), b.total_edges());
    }
}
