//! Heterogeneous-graph data structures.
//!
//! A heterogeneous graph (HG) has typed nodes and typed edges
//! ("relations"). The paper's workloads split an HG into per-relation
//! bipartite blocks (R-GCN's relation walk) or per-metapath homogeneous
//! subgraphs (HAN / MAGNN's metapath walk); both produce sparse adjacency
//! structures consumed by the aggregation kernels. This module provides:
//!
//! * [`sparse`] — COO / CSR / ELL sparse matrix formats with conversions,
//!   boolean sparse-sparse product (for metapath composition), and
//!   topology statistics.
//! * [`hetero`] — the typed-graph container ([`HeteroGraph`]) with node
//!   types, per-type feature matrices, and per-relation CSR blocks.

pub mod hetero;
pub mod sparse;

pub use hetero::{HeteroGraph, HeteroGraphBuilder, NodeType, NodeTypeId, Relation, RelationId};
pub use sparse::{Coo, Csr, Ell};
