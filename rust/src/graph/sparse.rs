//! Sparse adjacency formats: COO, CSR and ELL (padded rows).
//!
//! * **COO** is the edge-list form datasets are generated in and the form
//!   the `SDDMMCoo` kernel consumes (paper §4.1, TB-Type).
//! * **CSR** is what the `SpMMCsr` neighbor-aggregation kernel consumes
//!   and what metapath composition (boolean CSR·CSR) operates on.
//! * **ELL** pads every row to a fixed width `k`; it is the format the
//!   Pallas kernels need (static shapes) and mirrors how GPU SpMM kernels
//!   regularize row lengths. Rows longer than `k` are truncated by
//!   *deterministic top-k by column id* — truncation statistics are
//!   reported so experiments can size `k` to avoid loss.

use crate::{Error, Result};

/// Coordinate-format sparse matrix (edge list), sorted by (row, col).
#[derive(Debug, Clone, PartialEq)]
pub struct Coo {
    /// Number of rows (destination nodes).
    pub n_rows: usize,
    /// Number of columns (source nodes).
    pub n_cols: usize,
    /// Row index per nonzero.
    pub rows: Vec<u32>,
    /// Column index per nonzero.
    pub cols: Vec<u32>,
}

impl Coo {
    /// Build from an unsorted edge list; sorts and deduplicates.
    pub fn from_edges(n_rows: usize, n_cols: usize, mut edges: Vec<(u32, u32)>) -> Result<Coo> {
        for &(r, c) in &edges {
            if r as usize >= n_rows || c as usize >= n_cols {
                return Err(Error::shape(format!(
                    "edge ({r},{c}) out of bounds {n_rows}x{n_cols}"
                )));
            }
        }
        edges.sort_unstable();
        edges.dedup();
        let (rows, cols) = edges.into_iter().unzip();
        Ok(Coo { n_rows, n_cols, rows, cols })
    }

    /// Number of stored nonzeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.rows.len()
    }

    /// Density = nnz / (rows*cols); sparsity = 1 - density.
    pub fn density(&self) -> f64 {
        if self.n_rows == 0 || self.n_cols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.n_rows as f64 * self.n_cols as f64)
    }

    /// Convert to CSR.
    pub fn to_csr(&self) -> Csr {
        let mut indptr = vec![0u32; self.n_rows + 1];
        for &r in &self.rows {
            indptr[r as usize + 1] += 1;
        }
        for i in 0..self.n_rows {
            indptr[i + 1] += indptr[i];
        }
        Csr {
            n_rows: self.n_rows,
            n_cols: self.n_cols,
            indptr,
            indices: self.cols.clone(),
        }
    }
}

/// Compressed-sparse-row adjacency. Column indices within a row are sorted.
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    /// Number of rows.
    pub n_rows: usize,
    /// Number of columns.
    pub n_cols: usize,
    /// Row pointer array, length `n_rows + 1`.
    pub indptr: Vec<u32>,
    /// Column indices, length `nnz`.
    pub indices: Vec<u32>,
}

impl Csr {
    /// Empty matrix with no nonzeros.
    pub fn empty(n_rows: usize, n_cols: usize) -> Csr {
        Csr { n_rows, n_cols, indptr: vec![0; n_rows + 1], indices: Vec::new() }
    }

    /// Identity adjacency (self loops) over `n` nodes.
    pub fn identity(n: usize) -> Csr {
        Csr {
            n_rows: n,
            n_cols: n,
            indptr: (0..=n as u32).collect(),
            indices: (0..n as u32).collect(),
        }
    }

    /// Number of stored nonzeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Neighbors (column ids) of row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> &[u32] {
        &self.indices[self.indptr[r] as usize..self.indptr[r + 1] as usize]
    }

    /// Out-degree of row `r`.
    #[inline]
    pub fn degree(&self, r: usize) -> usize {
        (self.indptr[r + 1] - self.indptr[r]) as usize
    }

    /// Mean degree over rows.
    pub fn avg_degree(&self) -> f64 {
        if self.n_rows == 0 {
            return 0.0;
        }
        self.nnz() as f64 / self.n_rows as f64
    }

    /// Maximum row degree.
    pub fn max_degree(&self) -> usize {
        (0..self.n_rows).map(|r| self.degree(r)).max().unwrap_or(0)
    }

    /// Sparsity = 1 - nnz/(rows·cols). The quantity Fig 6(a) tracks.
    pub fn sparsity(&self) -> f64 {
        if self.n_rows == 0 || self.n_cols == 0 {
            return 1.0;
        }
        1.0 - self.nnz() as f64 / (self.n_rows as f64 * self.n_cols as f64)
    }

    /// Structural validation: monotone indptr, in-bounds sorted indices.
    pub fn validate(&self) -> Result<()> {
        if self.indptr.len() != self.n_rows + 1 {
            return Err(Error::shape("indptr length"));
        }
        if self.indptr[0] != 0 || *self.indptr.last().unwrap() as usize != self.indices.len() {
            return Err(Error::shape("indptr endpoints"));
        }
        for w in self.indptr.windows(2) {
            if w[0] > w[1] {
                return Err(Error::shape("indptr not monotone"));
            }
        }
        for r in 0..self.n_rows {
            let row = self.row(r);
            for w in row.windows(2) {
                if w[0] >= w[1] {
                    return Err(Error::shape(format!("row {r} indices not strictly sorted")));
                }
            }
            if let Some(&last) = row.last() {
                if last as usize >= self.n_cols {
                    return Err(Error::shape(format!("row {r} col {last} out of bounds")));
                }
            }
        }
        Ok(())
    }

    /// Transpose (CSR of the reverse edges).
    pub fn transposed(&self) -> Csr {
        let mut indptr = vec![0u32; self.n_cols + 1];
        for &c in &self.indices {
            indptr[c as usize + 1] += 1;
        }
        for i in 0..self.n_cols {
            indptr[i + 1] += indptr[i];
        }
        let mut cursor = indptr.clone();
        let mut indices = vec![0u32; self.nnz()];
        for r in 0..self.n_rows {
            for &c in self.row(r) {
                let slot = cursor[c as usize];
                indices[slot as usize] = r as u32;
                cursor[c as usize] += 1;
            }
        }
        Csr { n_rows: self.n_cols, n_cols: self.n_rows, indptr, indices }
    }

    /// Boolean sparse–sparse product `self · other` (pattern only).
    ///
    /// This is the metapath-composition primitive: the adjacency of
    /// metapath `t1 → t2 → t3` is `A(t1,t2) · A(t2,t3)` with boolean
    /// semiring. Classic two-pass Gustavson with a dense marker array.
    pub fn bool_matmul(&self, other: &Csr) -> Result<Csr> {
        if self.n_cols != other.n_rows {
            return Err(Error::shape(format!(
                "bool_matmul inner dims {} vs {}",
                self.n_cols, other.n_rows
            )));
        }
        let n_rows = self.n_rows;
        let n_cols = other.n_cols;
        let mut indptr = vec![0u32; n_rows + 1];
        let mut indices: Vec<u32> = Vec::new();
        // marker[c] == current row id  ⇒  column c already emitted
        let mut marker = vec![u32::MAX; n_cols];
        let mut scratch: Vec<u32> = Vec::new();
        for r in 0..n_rows {
            scratch.clear();
            for &mid in self.row(r) {
                for &c in other.row(mid as usize) {
                    if marker[c as usize] != r as u32 {
                        marker[c as usize] = r as u32;
                        scratch.push(c);
                    }
                }
            }
            scratch.sort_unstable();
            indices.extend_from_slice(&scratch);
            indptr[r + 1] = indices.len() as u32;
        }
        Ok(Csr { n_rows, n_cols, indptr, indices })
    }

    /// Drop each nonzero independently with probability `p`, deterministic
    /// in `rng`. Used by the Fig 5(a) edge-dropout sweep.
    pub fn dropout(&self, p: f64, rng: &mut crate::util::Pcg32) -> Csr {
        let mut indptr = vec![0u32; self.n_rows + 1];
        let mut indices = Vec::with_capacity(self.nnz());
        for r in 0..self.n_rows {
            for &c in self.row(r) {
                if rng.gen_f64() >= p {
                    indices.push(c);
                }
            }
            indptr[r + 1] = indices.len() as u32;
        }
        Csr { n_rows: self.n_rows, n_cols: self.n_cols, indptr, indices }
    }

    /// Insert a single nonzero `(r, c)`, keeping the row's column ids
    /// strictly sorted. Returns `false` (and leaves the matrix untouched)
    /// when the entry is already present. The streaming-update path
    /// ([`crate::dynamic`]) uses this to patch relation CSRs in place;
    /// the O(nnz) tail shift is fine at update-log granularity.
    pub fn insert(&mut self, r: usize, c: u32) -> Result<bool> {
        if r >= self.n_rows || c as usize >= self.n_cols {
            return Err(Error::shape(format!(
                "insert ({r},{c}) out of bounds {}x{}",
                self.n_rows, self.n_cols
            )));
        }
        let lo = self.indptr[r] as usize;
        let hi = self.indptr[r + 1] as usize;
        let pos = match self.indices[lo..hi].binary_search(&c) {
            Ok(_) => return Ok(false),
            Err(p) => lo + p,
        };
        self.indices.insert(pos, c);
        for p in &mut self.indptr[r + 1..] {
            *p += 1;
        }
        Ok(true)
    }

    /// Append an empty row (a new destination node with no edges yet).
    pub fn add_row(&mut self) {
        self.n_rows += 1;
        self.indptr.push(*self.indptr.last().unwrap());
    }

    /// Grow the column space by one (a new source node); purely a
    /// dimension change, no nonzeros are added.
    pub fn add_col(&mut self) {
        self.n_cols += 1;
    }

    /// Convert to ELL with row width `k`. Returns the ELL and the number
    /// of nonzeros truncated away (0 when `k >= max_degree`).
    pub fn to_ell(&self, k: usize) -> (Ell, usize) {
        let mut col_idx = vec![0u32; self.n_rows * k];
        let mut mask = vec![false; self.n_rows * k];
        let mut truncated = 0usize;
        for r in 0..self.n_rows {
            let row = self.row(r);
            let take = row.len().min(k);
            truncated += row.len() - take;
            for (j, &c) in row[..take].iter().enumerate() {
                col_idx[r * k + j] = c;
                mask[r * k + j] = true;
            }
        }
        (
            Ell { n_rows: self.n_rows, n_cols: self.n_cols, k, col_idx, mask },
            truncated,
        )
    }

    /// Convert to COO (sorted by construction).
    pub fn to_coo(&self) -> Coo {
        let mut rows = Vec::with_capacity(self.nnz());
        for r in 0..self.n_rows {
            rows.extend(std::iter::repeat_n(r as u32, self.degree(r)));
        }
        Coo {
            n_rows: self.n_rows,
            n_cols: self.n_cols,
            rows,
            cols: self.indices.clone(),
        }
    }
}

/// ELL (ELLPACK) padded-row adjacency: every row stores exactly `k`
/// (column, valid) slots. The static-shape format the Pallas kernels use.
#[derive(Debug, Clone, PartialEq)]
pub struct Ell {
    /// Number of rows.
    pub n_rows: usize,
    /// Number of columns.
    pub n_cols: usize,
    /// Padded row width.
    pub k: usize,
    /// `n_rows * k` column ids (garbage where `!mask`).
    pub col_idx: Vec<u32>,
    /// `n_rows * k` validity flags.
    pub mask: Vec<bool>,
}

impl Ell {
    /// Valid-slot count (equals the source CSR nnz minus truncation).
    pub fn nnz(&self) -> usize {
        self.mask.iter().filter(|&&m| m).count()
    }

    /// Slots (valid or not) for row `r`.
    pub fn row_slots(&self, r: usize) -> (&[u32], &[bool]) {
        (&self.col_idx[r * self.k..(r + 1) * self.k], &self.mask[r * self.k..(r + 1) * self.k])
    }

    /// Convert back to CSR (drops padding; inverse of [`Csr::to_ell`]
    /// up to the truncation it applied).
    pub fn to_csr(&self) -> Csr {
        let mut indptr = vec![0u32; self.n_rows + 1];
        let mut indices = Vec::with_capacity(self.nnz());
        for r in 0..self.n_rows {
            let (cols, mask) = self.row_slots(r);
            for (c, &m) in cols.iter().zip(mask) {
                if m {
                    indices.push(*c);
                }
            }
            indptr[r + 1] = indices.len() as u32;
        }
        Csr { n_rows: self.n_rows, n_cols: self.n_cols, indptr, indices }
    }

    /// Padding overhead ratio: total slots / valid slots.
    pub fn pad_overhead(&self) -> f64 {
        let nnz = self.nnz();
        if nnz == 0 {
            return f64::INFINITY;
        }
        (self.n_rows * self.k) as f64 / nnz as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn sample_csr() -> Csr {
        // 3x4:
        // row0: cols 1,3
        // row1: (empty)
        // row2: cols 0,1,2
        Coo::from_edges(3, 4, vec![(0, 3), (0, 1), (2, 0), (2, 1), (2, 2)])
            .unwrap()
            .to_csr()
    }

    #[test]
    fn coo_sorts_and_dedups() {
        let coo = Coo::from_edges(2, 2, vec![(1, 0), (0, 1), (1, 0)]).unwrap();
        assert_eq!(coo.nnz(), 2);
        assert_eq!(coo.rows, vec![0, 1]);
        assert_eq!(coo.cols, vec![1, 0]);
    }

    #[test]
    fn coo_bounds_checked() {
        assert!(Coo::from_edges(2, 2, vec![(2, 0)]).is_err());
        assert!(Coo::from_edges(2, 2, vec![(0, 2)]).is_err());
    }

    #[test]
    fn csr_roundtrip_and_stats() {
        let csr = sample_csr();
        csr.validate().unwrap();
        assert_eq!(csr.nnz(), 5);
        assert_eq!(csr.row(0), &[1, 3]);
        assert_eq!(csr.row(1), &[] as &[u32]);
        assert_eq!(csr.degree(2), 3);
        assert_eq!(csr.max_degree(), 3);
        assert!((csr.avg_degree() - 5.0 / 3.0).abs() < 1e-12);
        assert!((csr.sparsity() - (1.0 - 5.0 / 12.0)).abs() < 1e-12);
        let coo = csr.to_coo();
        assert_eq!(coo.to_csr(), csr);
    }

    #[test]
    fn transpose_involution() {
        let csr = sample_csr();
        let tt = csr.transposed().transposed();
        assert_eq!(tt, csr);
        csr.transposed().validate().unwrap();
    }

    #[test]
    fn bool_matmul_identity() {
        let csr = sample_csr();
        let id = Csr::identity(4);
        let prod = csr.bool_matmul(&id).unwrap();
        assert_eq!(prod, csr);
    }

    #[test]
    fn bool_matmul_two_hop() {
        // A: 0->1, B: 1->2  ⇒  A·B: 0->2
        let a = Coo::from_edges(2, 2, vec![(0, 1)]).unwrap().to_csr();
        let b = Coo::from_edges(2, 3, vec![(1, 2)]).unwrap().to_csr();
        let p = a.bool_matmul(&b).unwrap();
        assert_eq!(p.n_rows, 2);
        assert_eq!(p.n_cols, 3);
        assert_eq!(p.row(0), &[2]);
        assert_eq!(p.nnz(), 1);
    }

    #[test]
    fn bool_matmul_dedups_paths() {
        // two distinct 2-hop paths 0->{1,2}->3 must yield a single nonzero
        let a = Coo::from_edges(1, 3, vec![(0, 1), (0, 2)]).unwrap().to_csr();
        let b = Coo::from_edges(3, 4, vec![(1, 3), (2, 3)]).unwrap().to_csr();
        let p = a.bool_matmul(&b).unwrap();
        assert_eq!(p.row(0), &[3]);
    }

    #[test]
    fn bool_matmul_dim_check() {
        let a = Csr::identity(3);
        let b = Csr::identity(4);
        assert!(a.bool_matmul(&b).is_err());
    }

    #[test]
    fn insert_keeps_rows_sorted_unique() {
        let mut csr = sample_csr();
        assert!(csr.insert(0, 2).unwrap());
        assert_eq!(csr.row(0), &[1, 2, 3]);
        assert_eq!(csr.nnz(), 6);
        csr.validate().unwrap();
        // duplicate insert is a no-op
        assert!(!csr.insert(0, 2).unwrap());
        assert_eq!(csr.nnz(), 6);
        // insert into a previously empty row
        assert!(csr.insert(1, 0).unwrap());
        assert_eq!(csr.row(1), &[0]);
        assert_eq!(csr.row(2), &[0, 1, 2], "later rows must be unshifted");
        csr.validate().unwrap();
        // bounds
        assert!(csr.insert(3, 0).is_err());
        assert!(csr.insert(0, 4).is_err());
    }

    #[test]
    fn add_row_and_col_grow_dims() {
        let mut csr = sample_csr();
        csr.add_row();
        csr.add_col();
        assert_eq!((csr.n_rows, csr.n_cols), (4, 5));
        assert_eq!(csr.row(3), &[] as &[u32]);
        csr.validate().unwrap();
        assert!(csr.insert(3, 4).unwrap());
        assert_eq!(csr.row(3), &[4]);
        csr.validate().unwrap();
    }

    #[test]
    fn dropout_rates() {
        let mut rng = Pcg32::seeded(9);
        let big = Coo::from_edges(
            100,
            100,
            (0..100u32).flat_map(|r| (0..50u32).map(move |c| (r, c))).collect(),
        )
        .unwrap()
        .to_csr();
        let kept = big.dropout(0.5, &mut rng);
        let ratio = kept.nnz() as f64 / big.nnz() as f64;
        assert!((ratio - 0.5).abs() < 0.05, "keep ratio {ratio}");
        let all = big.dropout(0.0, &mut rng);
        assert_eq!(all.nnz(), big.nnz());
        let none = big.dropout(1.0, &mut rng);
        assert_eq!(none.nnz(), 0);
        kept.validate().unwrap();
    }

    #[test]
    fn ell_padding_and_truncation() {
        let csr = sample_csr();
        let (ell, trunc) = csr.to_ell(3);
        assert_eq!(trunc, 0);
        assert_eq!(ell.nnz(), csr.nnz());
        let (cols, mask) = ell.row_slots(0);
        assert_eq!(&cols[..2], &[1, 3]);
        assert_eq!(mask, &[true, true, false]);
        // k smaller than max degree truncates
        let (ell2, trunc2) = csr.to_ell(2);
        assert_eq!(trunc2, 1);
        assert_eq!(ell2.nnz(), 4);
        assert!(ell2.pad_overhead() >= 1.0);
    }

    #[test]
    fn identity_validates() {
        Csr::identity(10).validate().unwrap();
        Csr::empty(5, 7).validate().unwrap();
    }
}
