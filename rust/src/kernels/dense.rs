//! DM-Type kernels: dense–dense matrix multiplication (`sgemm`).
//!
//! The paper's Feature Projection stage is almost entirely `sgemm`
//! (97.4% of FP time for HAN-DBLP, Table 3), and Semantic Aggregation's
//! attention-weight computation is `sgemm` again. The native
//! implementation here is a cache-blocked, 8-wide-unrolled matmul —
//! the L3 perf pass iterates on the blocking (see EXPERIMENTS.md §Perf)
//! — parallelized over M-dimension macro-row blocks on the
//! [`crate::parallel`] worker pool. Each output row's k-loop order is
//! unchanged by the blocking, so parallel results are **bit-identical**
//! to serial ones at every thread count.

use crate::kernels::{Ctx, KernelCounters, KernelType};
use crate::parallel;
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Cache-blocking parameters for [`sgemm`]. Tuned in the perf pass.
#[derive(Debug, Clone, Copy)]
pub struct GemmBlocking {
    /// Rows of A per macro-tile.
    pub mc: usize,
    /// Columns of B per macro-tile.
    pub nc: usize,
    /// Shared K extent per macro-tile.
    pub kc: usize,
}

impl Default for GemmBlocking {
    fn default() -> Self {
        // Measured best on the perf pass (EXPERIMENTS.md §Perf):
        // 128x256x512 with the 2-row micro-kernel — 14.1 GF/s vs 5.4 at
        // the previous 64x256x256 default on 1024x1024x64.
        GemmBlocking { mc: 128, nc: 256, kc: 512 }
    }
}

/// FLOP count of an (m,k)x(k,n) matmul: one mul + one add per MAC.
#[inline]
pub fn gemm_flops(m: usize, k: usize, n: usize) -> u64 {
    2 * m as u64 * k as u64 * n as u64
}

/// `sgemm`: `out = a · b`. DM-Type.
///
/// Counters follow the GPU convention the paper's Nsight numbers use:
/// logical reads are the A and B operands once each (on-chip reuse is the
/// cache model's job), writes are the output once.
pub fn sgemm(ctx: &mut Ctx, a: &Tensor, b: &Tensor, blocking: GemmBlocking) -> Result<Tensor> {
    let (m, ka) = a.shape();
    let (kb, n) = b.shape();
    if ka != kb {
        return Err(Error::shape(format!("sgemm: a is {m}x{ka}, b is {kb}x{n}")));
    }
    let t0 = std::time::Instant::now();
    let mut out = ctx.scratch_zeros(m, n);
    sgemm_into(a, b, blocking, &mut out);
    let nanos = t0.elapsed().as_nanos() as u64;
    let counters = KernelCounters {
        flops: gemm_flops(m, ka, n),
        bytes_read: (a.bytes() + b.bytes()) as u64,
        bytes_written: out.bytes() as u64,
    };
    ctx.push("sgemm", KernelType::DenseMatmul, counters, nanos, None);
    Ok(out)
}

/// `sgemm` + broadcast bias add fused (DGL lowers Linear to this shape).
pub fn sgemm_bias(
    ctx: &mut Ctx,
    a: &Tensor,
    b: &Tensor,
    bias: &[f32],
    blocking: GemmBlocking,
) -> Result<Tensor> {
    let (m, ka) = a.shape();
    let (kb, n) = b.shape();
    if ka != kb {
        return Err(Error::shape(format!("sgemm_bias: a is {m}x{ka}, b is {kb}x{n}")));
    }
    if bias.len() != n {
        return Err(Error::shape(format!("bias len {} != n {}", bias.len(), n)));
    }
    let t0 = std::time::Instant::now();
    let mut out = ctx.scratch_zeros(m, n);
    sgemm_into(a, b, blocking, &mut out);
    for r in 0..m {
        let row = out.row_mut(r);
        for (o, &bv) in row.iter_mut().zip(bias) {
            *o += bv;
        }
    }
    let nanos = t0.elapsed().as_nanos() as u64;
    let counters = KernelCounters {
        flops: gemm_flops(m, ka, n) + (m * n) as u64,
        bytes_read: (a.bytes() + b.bytes() + bias.len() * 4) as u64,
        bytes_written: out.bytes() as u64,
    };
    ctx.push("sgemm", KernelType::DenseMatmul, counters, nanos, None);
    Ok(out)
}

/// The blocked compute core (no instrumentation). Public so benches can
/// compare blockings directly. Parallelized over M-dimension macro-row
/// blocks (`blk.mc` rows per unit) on the shared worker pool; see
/// [`sgemm_into`] for the bit-identity argument.
pub fn sgemm_compute(a: &Tensor, b: &Tensor, blk: GemmBlocking) -> Tensor {
    let mut out = Tensor::zeros(a.rows(), b.cols());
    sgemm_into(a, b, blk, &mut out);
    out
}

/// Blocked matmul into a caller-owned **zeroed** output (the arena'd
/// entry point behind [`sgemm`]/[`sgemm_bias`]).
///
/// Work splits across the pool in units of `blk.mc` rows — exactly the
/// serial loop's macro-tile boundaries — so every worker executes the
/// same tile/pairing schedule the serial code would for its rows, and
/// each output element's k-accumulation order is unchanged: parallel
/// output is bit-identical to serial.
pub fn sgemm_into(a: &Tensor, b: &Tensor, blk: GemmBlocking, out: &mut Tensor) {
    let (m, k) = a.shape();
    let n = b.cols();
    debug_assert_eq!(out.shape(), (m, n));
    if m == 0 || n == 0 {
        return;
    }
    let av = a.as_slice();
    let bv = b.as_slice();
    let mc = blk.mc.max(1);
    parallel::parallel_chunks_mut(out.as_mut_slice(), mc * n, 1, |u0, block| {
        sgemm_panel(av, bv, block, u0 * mc, k, n, blk);
    });
}

/// Serial macro-kernel over the row panel `[r0, r0 + block.len()/n)`;
/// `block` is that panel of the output. The loop structure (and hence
/// every element's f32 accumulation order) is the original serial
/// blocked matmul, restricted to the panel's rows.
fn sgemm_panel(
    av: &[f32],
    bv: &[f32],
    block: &mut [f32],
    r0: usize,
    k: usize,
    n: usize,
    blk: GemmBlocking,
) {
    let r1 = r0 + block.len() / n;
    for jc in (0..n).step_by(blk.nc) {
        let nc = blk.nc.min(n - jc);
        for pc in (0..k).step_by(blk.kc) {
            let kc = blk.kc.min(k - pc);
            for ic in (r0..r1).step_by(blk.mc) {
                let mc = blk.mc.min(r1 - ic);
                // micro kernel: 2 rows of A at a time against the B
                // panel — halves the O-row traffic per FMA and gives
                // the vectorizer two independent accumulator streams.
                // Sparse A rows (one-hot features) still take the
                // zero-skip path, but only when the whole pair is zero.
                let mut i = ic;
                while i + 1 < ic + mc {
                    let (a0, a1) = (&av[i * k + pc..], &av[(i + 1) * k + pc..]);
                    for p in 0..kc {
                        let (v0, v1) = (a0[p], a1[p]);
                        if v0 == 0.0 && v1 == 0.0 {
                            continue; // one-hot feature rows hit this often
                        }
                        let brow = &bv[(pc + p) * n + jc..(pc + p) * n + jc + nc];
                        let (o0, o1) = block.split_at_mut((i + 1 - r0) * n);
                        let o0 = &mut o0[(i - r0) * n + jc..(i - r0) * n + jc + nc];
                        let o1 = &mut o1[jc..jc + nc];
                        for ((x0, x1), &b) in o0.iter_mut().zip(o1.iter_mut()).zip(brow) {
                            *x0 += v0 * b;
                            *x1 += v1 * b;
                        }
                    }
                    i += 2;
                }
                // odd tail row
                if i < ic + mc {
                    let arow = &av[i * k + pc..i * k + pc + kc];
                    for (p, &aval) in arow.iter().enumerate() {
                        if aval == 0.0 {
                            continue;
                        }
                        let brow = &bv[(pc + p) * n + jc..(pc + p) * n + jc + nc];
                        let orow = &mut block[(i - r0) * n + jc..(i - r0) * n + jc + nc];
                        for (o, &b) in orow.iter_mut().zip(brow) {
                            *o += aval * b;
                        }
                    }
                }
            }
        }
    }
}

/// `sgemm_tn`: `out = aᵀ · b` for `a: [k,m]`, `b: [k,n]`. DM-Type.
///
/// The backward pass's weight-gradient shape (`dW = Xᵀ·dH`). The
/// transpose is materialized once (a DR-style repack, folded into the
/// kernel's read bytes) and the blocked kernel reused, so every output
/// element's k-accumulation order — and hence bit-identity across
/// thread counts — matches [`sgemm`] exactly.
pub fn sgemm_tn(ctx: &mut Ctx, a: &Tensor, b: &Tensor, blocking: GemmBlocking) -> Result<Tensor> {
    let (ka, m) = a.shape();
    let (kb, n) = b.shape();
    if ka != kb {
        return Err(Error::shape(format!("sgemm_tn: a is {ka}x{m}, b is {kb}x{n}")));
    }
    let t0 = std::time::Instant::now();
    let at = a.transposed();
    let mut out = ctx.scratch_zeros(m, n);
    sgemm_into(&at, b, blocking, &mut out);
    let nanos = t0.elapsed().as_nanos() as u64;
    let counters = KernelCounters {
        flops: gemm_flops(m, ka, n),
        // A is read twice: once by the repack, once by the kernel
        bytes_read: (2 * a.bytes() + b.bytes()) as u64,
        bytes_written: out.bytes() as u64,
    };
    ctx.push("sgemm", KernelType::DenseMatmul, counters, nanos, None);
    Ok(out)
}

/// `sgemm_nt`: `out = a · bᵀ` for `a: [m,k]`, `b: [n,k]`. DM-Type.
///
/// The backward pass's activation-gradient shape (`dX = dH·Wᵀ`); same
/// materialize-then-reuse strategy as [`sgemm_tn`].
pub fn sgemm_nt(ctx: &mut Ctx, a: &Tensor, b: &Tensor, blocking: GemmBlocking) -> Result<Tensor> {
    let (m, ka) = a.shape();
    let (n, kb) = b.shape();
    if ka != kb {
        return Err(Error::shape(format!("sgemm_nt: a is {m}x{ka}, b is {n}x{kb}")));
    }
    let t0 = std::time::Instant::now();
    let bt = b.transposed();
    let mut out = ctx.scratch_zeros(m, n);
    sgemm_into(a, &bt, blocking, &mut out);
    let nanos = t0.elapsed().as_nanos() as u64;
    let counters = KernelCounters {
        flops: gemm_flops(m, ka, n),
        bytes_read: (a.bytes() + 2 * b.bytes()) as u64,
        bytes_written: out.bytes() as u64,
    };
    ctx.push("sgemm", KernelType::DenseMatmul, counters, nanos, None);
    Ok(out)
}

/// Naive triple-loop reference (for correctness tests and the perf
/// baseline in EXPERIMENTS.md §Perf).
pub fn sgemm_naive(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.shape();
    let n = b.cols();
    let mut out = Tensor::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += a.get(i, p) * b.get(p, j);
            }
            out.set(i, j, acc);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn blocked_matches_naive() {
        let mut rng = Pcg32::seeded(21);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (17, 33, 9), (64, 64, 64), (65, 130, 31)] {
            let a = Tensor::randn(m, k, 1.0, &mut rng);
            let b = Tensor::randn(k, n, 1.0, &mut rng);
            let blocked = sgemm_compute(&a, &b, GemmBlocking::default());
            let naive = sgemm_naive(&a, &b);
            assert!(
                blocked.allclose(&naive, 1e-4, 1e-5),
                "mismatch at {m}x{k}x{n}: {}",
                blocked.max_abs_diff(&naive).unwrap()
            );
        }
    }

    #[test]
    fn parallel_matches_serial_bitwise() {
        let mut rng = Pcg32::seeded(33);
        let blk = GemmBlocking::default();
        // shapes straddling the mc=128 macro-row boundary (ragged tails)
        for (m, k, n) in [(3, 5, 7), (130, 64, 33), (257, 96, 17)] {
            let a = Tensor::randn(m, k, 1.0, &mut rng);
            let b = Tensor::randn(k, n, 1.0, &mut rng);
            let serial = crate::parallel::with_threads(1, || sgemm_compute(&a, &b, blk));
            for t in [2usize, 4] {
                let par = crate::parallel::with_threads(t, || sgemm_compute(&a, &b, blk));
                assert!(
                    par.allclose(&serial, 0.0, 0.0),
                    "threads {t} not bit-identical at {m}x{k}x{n}"
                );
            }
        }
    }

    #[test]
    fn sgemm_counters() {
        let mut ctx = Ctx::default();
        let a = Tensor::full(4, 3, 1.0);
        let b = Tensor::full(3, 5, 2.0);
        let out = sgemm(&mut ctx, &a, &b, GemmBlocking::default()).unwrap();
        assert_eq!(out.shape(), (4, 5));
        assert_eq!(out.get(0, 0), 6.0);
        let e = &ctx.events[0];
        assert_eq!(e.name, "sgemm");
        assert_eq!(e.ktype, KernelType::DenseMatmul);
        assert_eq!(e.counters.flops, 2 * 4 * 3 * 5);
        assert_eq!(e.counters.bytes_read, (4 * 3 + 3 * 5) * 4);
        assert_eq!(e.counters.bytes_written, 4 * 5 * 4);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut ctx = Ctx::default();
        let a = Tensor::zeros(2, 3);
        let b = Tensor::zeros(4, 5);
        assert!(sgemm(&mut ctx, &a, &b, GemmBlocking::default()).is_err());
    }

    #[test]
    fn bias_fused() {
        let mut ctx = Ctx::default();
        let a = Tensor::full(2, 2, 1.0);
        let b = Tensor::full(2, 2, 1.0);
        let out = sgemm_bias(&mut ctx, &a, &b, &[10.0, 20.0], GemmBlocking::default()).unwrap();
        assert_eq!(out.get(0, 0), 12.0);
        assert_eq!(out.get(1, 1), 22.0);
        assert!(sgemm_bias(&mut ctx, &a, &b, &[1.0], GemmBlocking::default()).is_err());
    }

    #[test]
    fn transposed_variants_match_naive() {
        let mut rng = Pcg32::seeded(44);
        let blk = GemmBlocking::default();
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (33, 17, 9), (65, 130, 31)] {
            let a = Tensor::randn(k, m, 1.0, &mut rng); // stored kxm
            let b = Tensor::randn(k, n, 1.0, &mut rng);
            let mut ctx = Ctx::default();
            let tn = sgemm_tn(&mut ctx, &a, &b, blk).unwrap();
            let naive = sgemm_naive(&a.transposed(), &b);
            assert!(
                tn.allclose(&naive, 1e-4, 1e-5),
                "tn mismatch at {m}x{k}x{n}: {}",
                tn.max_abs_diff(&naive).unwrap()
            );

            let a2 = Tensor::randn(m, k, 1.0, &mut rng);
            let b2 = Tensor::randn(n, k, 1.0, &mut rng); // stored nxk
            let nt = sgemm_nt(&mut ctx, &a2, &b2, blk).unwrap();
            let naive = sgemm_naive(&a2, &b2.transposed());
            assert!(
                nt.allclose(&naive, 1e-4, 1e-5),
                "nt mismatch at {m}x{k}x{n}: {}",
                nt.max_abs_diff(&naive).unwrap()
            );
            assert!(ctx.events.iter().all(|e| e.name == "sgemm"));
        }
    }

    #[test]
    fn transposed_variants_reject_bad_shapes() {
        let mut ctx = Ctx::default();
        let a = Tensor::zeros(3, 2);
        let b = Tensor::zeros(4, 5);
        assert!(sgemm_tn(&mut ctx, &a, &b, GemmBlocking::default()).is_err());
        assert!(sgemm_nt(&mut ctx, &a, &b, GemmBlocking::default()).is_err());
    }

    #[test]
    fn one_hot_fast_path_correct() {
        // one-hot A exercises the aval==0 skip
        let mut rng = Pcg32::seeded(22);
        let a = Tensor::one_hot(10, 6);
        let b = Tensor::randn(6, 4, 1.0, &mut rng);
        let blocked = sgemm_compute(&a, &b, GemmBlocking::default());
        let naive = sgemm_naive(&a, &b);
        assert!(blocked.allclose(&naive, 1e-5, 1e-6));
    }
}
