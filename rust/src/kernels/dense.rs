//! DM-Type kernels: dense–dense matrix multiplication (`sgemm`).
//!
//! The paper's Feature Projection stage is almost entirely `sgemm`
//! (97.4% of FP time for HAN-DBLP, Table 3), and Semantic Aggregation's
//! attention-weight computation is `sgemm` again. The native
//! implementation here is a cache-blocked matmul whose 2-row inner loop
//! runs on the explicit-width SIMD microkernels of
//! [`crate::kernels::simd`] — the L3 perf pass iterates on the blocking
//! (see EXPERIMENTS.md §Perf) — parallelized over M-dimension macro-row
//! blocks on the [`crate::parallel`] worker pool. Each output row's
//! k-loop order is unchanged by the blocking, so parallel results are
//! **bit-identical** to serial ones at every thread count.
//!
//! On top of the blocked core sits a **packed-B tier**: [`PackedB`]
//! lays the weight operand out as contiguous (kc × nc) panel tiles in
//! exactly the order the macro-kernel walks them, so the inner loop
//! streams B sequentially instead of striding `n` floats between rows.
//! [`PackCache`] (one per [`Ctx`], keyed by [`PackKey`]) packs each
//! weight matrix once per weights generation and reuses the panels
//! across served batches and training steps; [`sgemm_cached`] is the
//! instrumented entry point. The packed macro-kernel replays the exact
//! tile walk and per-element accumulation order of the unpacked one, so
//! packed results are bit-identical to unpacked — and [`sgemm_tn`] /
//! [`sgemm_nt`] share the same packed-panel core.

use std::collections::HashMap;

use crate::kernels::{simd, Ctx, KernelCounters, KernelType};
use crate::parallel;
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Cache-blocking parameters for [`sgemm`]. Tuned in the perf pass.
/// Equality matters: [`PackCache::ensure`] repacks when the blocking a
/// panel was packed under differs from the one requested.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmBlocking {
    /// Rows of A per macro-tile.
    pub mc: usize,
    /// Columns of B per macro-tile.
    pub nc: usize,
    /// Shared K extent per macro-tile.
    pub kc: usize,
}

impl Default for GemmBlocking {
    fn default() -> Self {
        // Measured best on the perf pass (EXPERIMENTS.md §Perf):
        // 128x256x512 with the 2-row micro-kernel — 14.1 GF/s vs 5.4 at
        // the previous 64x256x256 default on 1024x1024x64.
        GemmBlocking { mc: 128, nc: 256, kc: 512 }
    }
}

/// FLOP count of an (m,k)x(k,n) matmul: one mul + one add per MAC.
#[inline]
pub fn gemm_flops(m: usize, k: usize, n: usize) -> u64 {
    2 * m as u64 * k as u64 * n as u64
}

/// `sgemm`: `out = a · b`. DM-Type.
///
/// Counters follow the GPU convention the paper's Nsight numbers use:
/// logical reads are the A and B operands once each (on-chip reuse is the
/// cache model's job), writes are the output once.
pub fn sgemm(ctx: &mut Ctx, a: &Tensor, b: &Tensor, blocking: GemmBlocking) -> Result<Tensor> {
    let (m, ka) = a.shape();
    let (kb, n) = b.shape();
    if ka != kb {
        return Err(Error::shape(format!("sgemm: a is {m}x{ka}, b is {kb}x{n}")));
    }
    let t0 = std::time::Instant::now();
    let mut out = ctx.scratch_zeros(m, n);
    sgemm_into(a, b, blocking, &mut out);
    let nanos = t0.elapsed().as_nanos() as u64;
    let counters = KernelCounters {
        flops: gemm_flops(m, ka, n),
        bytes_read: (a.bytes() + b.bytes()) as u64,
        bytes_written: out.bytes() as u64,
    };
    ctx.push("sgemm", KernelType::DenseMatmul, counters, nanos, None);
    Ok(out)
}

/// `sgemm` + broadcast bias add fused (DGL lowers Linear to this shape).
pub fn sgemm_bias(
    ctx: &mut Ctx,
    a: &Tensor,
    b: &Tensor,
    bias: &[f32],
    blocking: GemmBlocking,
) -> Result<Tensor> {
    let (m, ka) = a.shape();
    let (kb, n) = b.shape();
    if ka != kb {
        return Err(Error::shape(format!("sgemm_bias: a is {m}x{ka}, b is {kb}x{n}")));
    }
    if bias.len() != n {
        return Err(Error::shape(format!("bias len {} != n {}", bias.len(), n)));
    }
    let t0 = std::time::Instant::now();
    let mut out = ctx.scratch_zeros(m, n);
    sgemm_into(a, b, blocking, &mut out);
    for r in 0..m {
        let row = out.row_mut(r);
        for (o, &bv) in row.iter_mut().zip(bias) {
            *o += bv;
        }
    }
    let nanos = t0.elapsed().as_nanos() as u64;
    let counters = KernelCounters {
        flops: gemm_flops(m, ka, n) + (m * n) as u64,
        bytes_read: (a.bytes() + b.bytes() + bias.len() * 4) as u64,
        bytes_written: out.bytes() as u64,
    };
    ctx.push("sgemm", KernelType::DenseMatmul, counters, nanos, None);
    Ok(out)
}

/// The blocked compute core (no instrumentation). Public so benches can
/// compare blockings directly. Parallelized over M-dimension macro-row
/// blocks (`blk.mc` rows per unit) on the shared worker pool; see
/// [`sgemm_into`] for the bit-identity argument.
pub fn sgemm_compute(a: &Tensor, b: &Tensor, blk: GemmBlocking) -> Tensor {
    let mut out = Tensor::zeros(a.rows(), b.cols());
    sgemm_into(a, b, blk, &mut out);
    out
}

/// Blocked matmul into a caller-owned **zeroed** output (the arena'd
/// entry point behind [`sgemm`]/[`sgemm_bias`]).
///
/// Work splits across the pool in units of `blk.mc` rows — exactly the
/// serial loop's macro-tile boundaries — so every worker executes the
/// same tile/pairing schedule the serial code would for its rows, and
/// each output element's k-accumulation order is unchanged: parallel
/// output is bit-identical to serial.
pub fn sgemm_into(a: &Tensor, b: &Tensor, blk: GemmBlocking, out: &mut Tensor) {
    let (m, k) = a.shape();
    let n = b.cols();
    debug_assert_eq!(out.shape(), (m, n));
    if m == 0 || n == 0 {
        return;
    }
    let av = a.as_slice();
    let bv = b.as_slice();
    let mc = blk.mc.max(1);
    parallel::parallel_chunks_mut(out.as_mut_slice(), mc * n, 1, |u0, block| {
        sgemm_panel(av, bv, block, u0 * mc, k, n, blk);
    });
}

/// Serial macro-kernel over the row panel `[r0, r0 + block.len()/n)`;
/// `block` is that panel of the output. The loop structure (and hence
/// every element's f32 accumulation order) is the original serial
/// blocked matmul, restricted to the panel's rows.
fn sgemm_panel(
    av: &[f32],
    bv: &[f32],
    block: &mut [f32],
    r0: usize,
    k: usize,
    n: usize,
    blk: GemmBlocking,
) {
    let r1 = r0 + block.len() / n;
    for jc in (0..n).step_by(blk.nc) {
        let nc = blk.nc.min(n - jc);
        for pc in (0..k).step_by(blk.kc) {
            let kc = blk.kc.min(k - pc);
            for ic in (r0..r1).step_by(blk.mc) {
                let mc = blk.mc.min(r1 - ic);
                // micro kernel: 2 rows of A at a time against the B
                // panel — halves the O-row traffic per FMA and gives
                // the vectorizer two independent accumulator streams.
                // Sparse A rows (one-hot features) still take the
                // zero-skip path, but only when the whole pair is zero.
                let mut i = ic;
                while i + 1 < ic + mc {
                    let (a0, a1) = (&av[i * k + pc..], &av[(i + 1) * k + pc..]);
                    for p in 0..kc {
                        let (v0, v1) = (a0[p], a1[p]);
                        if v0 == 0.0 && v1 == 0.0 {
                            continue; // one-hot feature rows hit this often
                        }
                        let brow = &bv[(pc + p) * n + jc..(pc + p) * n + jc + nc];
                        let (o0, o1) = block.split_at_mut((i + 1 - r0) * n);
                        let o0 = &mut o0[(i - r0) * n + jc..(i - r0) * n + jc + nc];
                        let o1 = &mut o1[jc..jc + nc];
                        simd::axpy2(o0, o1, v0, v1, brow);
                    }
                    i += 2;
                }
                // odd tail row
                if i < ic + mc {
                    let arow = &av[i * k + pc..i * k + pc + kc];
                    for (p, &aval) in arow.iter().enumerate() {
                        if aval == 0.0 {
                            continue;
                        }
                        let brow = &bv[(pc + p) * n + jc..(pc + p) * n + jc + nc];
                        let orow = &mut block[(i - r0) * n + jc..(i - r0) * n + jc + nc];
                        simd::axpy(orow, aval, brow);
                    }
                }
            }
        }
    }
}

/// The B operand of a blocked matmul, re-laid-out as contiguous
/// (kc × nc) panel tiles in exactly the order [`sgemm_panel`] walks
/// them (jc-major, then pc). Inside a tile, row `p` holds
/// `B[pc + p, jc..jc + nc]` contiguously, so the packed macro-kernel
/// streams B sequentially instead of striding `n` floats between
/// k-rows. Packing is a pure re-layout — the packed kernel consumes the
/// identical values in the identical order, so results are
/// bit-identical to the unpacked path.
#[derive(Debug, Clone)]
pub struct PackedB {
    k: usize,
    n: usize,
    blk: GemmBlocking,
    data: Vec<f32>,
    /// Tile start offsets in `data`, `n_jc * n_pc + 1` entries
    /// (jc-major), last one a sentinel at `data.len()`.
    tile_off: Vec<usize>,
    n_pc: usize,
}

impl PackedB {
    /// Pack a row-major `b: [k, n]` under `blk`.
    pub fn pack(b: &Tensor, blk: GemmBlocking) -> PackedB {
        let (k, n) = b.shape();
        let bv = b.as_slice();
        Self::pack_rows(k, n, blk, |pc_p, jc, nc, data| {
            data.extend_from_slice(&bv[pc_p * n + jc..pc_p * n + jc + nc]);
        })
    }

    /// Pack the **transpose** of a row-major `bt: [n, k]` — i.e. the
    /// logical B is `btᵀ: [k, n]` — without materializing the
    /// transposed matrix ([`sgemm_nt`]'s shape).
    pub fn pack_transposed(bt: &Tensor, blk: GemmBlocking) -> PackedB {
        let (n, k) = bt.shape();
        let bv = bt.as_slice();
        Self::pack_rows(k, n, blk, |pc_p, jc, nc, data| {
            data.extend((0..nc).map(|j| bv[(jc + j) * k + pc_p]));
        })
    }

    fn pack_rows(
        k: usize,
        n: usize,
        blk: GemmBlocking,
        mut copy_row: impl FnMut(usize, usize, usize, &mut Vec<f32>),
    ) -> PackedB {
        let n_pc = k.div_ceil(blk.kc.max(1));
        let n_jc = n.div_ceil(blk.nc.max(1));
        let mut data = Vec::with_capacity(k * n);
        let mut tile_off = Vec::with_capacity(n_jc * n_pc + 1);
        tile_off.push(0);
        for jc in (0..n).step_by(blk.nc) {
            let nc = blk.nc.min(n - jc);
            for pc in (0..k).step_by(blk.kc) {
                let kc = blk.kc.min(k - pc);
                for p in 0..kc {
                    copy_row(pc + p, jc, nc, &mut data);
                }
                tile_off.push(data.len());
            }
        }
        PackedB { k, n, blk, data, tile_off, n_pc }
    }

    /// The (jc_idx, pc_idx) tile as a flat slice of `kc_eff` rows of
    /// `nc_eff` contiguous elements.
    #[inline]
    fn tile(&self, jc_idx: usize, pc_idx: usize) -> &[f32] {
        let t = jc_idx * self.n_pc + pc_idx;
        &self.data[self.tile_off[t]..self.tile_off[t + 1]]
    }

    /// K extent of the packed operand.
    pub fn k(&self) -> usize {
        self.k
    }

    /// N extent of the packed operand.
    pub fn n(&self) -> usize {
        self.n
    }

    /// The blocking this panel was packed under.
    pub fn blocking(&self) -> GemmBlocking {
        self.blk
    }

    /// Bytes held by the packed layout.
    pub fn bytes(&self) -> usize {
        self.data.len() * 4 + self.tile_off.len() * std::mem::size_of::<usize>()
    }
}

/// Identity of a packed weight panel in a [`PackCache`] — which weight
/// matrix of the plan it holds, not where it lives in memory (pointer
/// keys would alias across reallocated tensors).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PackKey {
    /// Per-type Feature Projection weight `W_ty` (keyed by node type id).
    Proj(usize),
    /// Semantic Aggregation attention weight `sem_w`.
    SemW,
    /// Semantic Aggregation attention query `sem_q`.
    SemQ,
}

/// Per-[`Ctx`] cache of packed B panels: each weight matrix is packed
/// once per (weights-generation, blocking) and the panel reused across
/// served batches and training steps. Generations are detected two
/// ways: `Session::invalidate` clears the cache on every weight swap,
/// and [`PackCache::ensure`] re-fingerprints the source matrix (an
/// FNV-1a fold over the element bits — O(k·n), negligible next to the
/// O(m·k·n) matmul) so a stale panel can never be consumed even through
/// call paths that bypass the session.
#[derive(Debug, Default)]
pub struct PackCache {
    entries: HashMap<PackKey, (u64, PackedB)>,
}

fn content_fingerprint(values: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &v in values {
        h ^= v.to_bits() as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

impl PackCache {
    /// Make sure `key` holds a current pack of `b` under `blk`,
    /// repacking if the entry is absent, shaped differently, packed
    /// under another blocking, or holds different values.
    pub fn ensure(&mut self, key: PackKey, b: &Tensor, blk: GemmBlocking) {
        let fp = content_fingerprint(b.as_slice());
        let fresh = self.entries.get(&key).is_some_and(|(old_fp, p)| {
            *old_fp == fp && (p.k, p.n) == b.shape() && p.blk == blk
        });
        if !fresh {
            self.entries.insert(key, (fp, PackedB::pack(b, blk)));
        }
    }

    /// The packed panel under `key`, if present.
    pub fn get(&self, key: PackKey) -> Option<&PackedB> {
        self.entries.get(&key).map(|(_, p)| p)
    }

    /// Drop every packed panel (weights generation changed).
    pub fn clear(&mut self) {
        self.entries.clear();
    }

    /// Number of cached panels.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Bytes held by all cached panels.
    pub fn bytes(&self) -> usize {
        self.entries.values().map(|(_, p)| p.bytes()).sum()
    }
}

/// [`sgemm`] against a packed-and-cached B panel: the weight matrix is
/// packed once per weights generation into `ctx.packs` under `key` and
/// the panel reused on every subsequent call. Output, event name and
/// counters are identical to [`sgemm`] (packing is a layout change, not
/// a semantic one), so profiles and the pinned kernel-sequence tests
/// see no difference.
pub fn sgemm_cached(
    ctx: &mut Ctx,
    a: &Tensor,
    b: &Tensor,
    key: PackKey,
    blocking: GemmBlocking,
) -> Result<Tensor> {
    let (m, ka) = a.shape();
    let (kb, n) = b.shape();
    if ka != kb {
        return Err(Error::shape(format!("sgemm: a is {m}x{ka}, b is {kb}x{n}")));
    }
    let t0 = std::time::Instant::now();
    ctx.packs.ensure(key, b, blocking);
    let mut out = ctx.scratch_zeros(m, n);
    let pb = ctx.packs.get(key).expect("panel packed by ensure");
    sgemm_packed_into(a, pb, &mut out);
    let nanos = t0.elapsed().as_nanos() as u64;
    let counters = KernelCounters {
        flops: gemm_flops(m, ka, n),
        bytes_read: (a.bytes() + b.bytes()) as u64,
        bytes_written: out.bytes() as u64,
    };
    ctx.push("sgemm", KernelType::DenseMatmul, counters, nanos, None);
    Ok(out)
}

/// Packed-core compute entry (no instrumentation), for benches and
/// bit-identity tests: `out = a · B` where `pb` packs B.
pub fn sgemm_packed_compute(a: &Tensor, pb: &PackedB) -> Tensor {
    let mut out = Tensor::zeros(a.rows(), pb.n);
    sgemm_packed_into(a, pb, &mut out);
    out
}

/// Packed-core matmul into a caller-owned **zeroed** output. Same
/// parallel split and bit-identity argument as [`sgemm_into`].
pub fn sgemm_packed_into(a: &Tensor, pb: &PackedB, out: &mut Tensor) {
    let (m, k) = a.shape();
    let n = pb.n;
    debug_assert_eq!(k, pb.k);
    debug_assert_eq!(out.shape(), (m, n));
    if m == 0 || n == 0 {
        return;
    }
    let av = a.as_slice();
    let mc = pb.blk.mc.max(1);
    parallel::parallel_chunks_mut(out.as_mut_slice(), mc * n, 1, |u0, block| {
        sgemm_panel_packed(av, pb, block, u0 * mc, k, n);
    });
}

/// [`sgemm_panel`] against a packed B: identical jc/pc/ic tile walk and
/// 2-row pairing — only the B-row addressing changes (contiguous tile
/// rows instead of strided matrix rows) — so every output element's
/// accumulation order, and hence its bits, match the unpacked panel.
fn sgemm_panel_packed(av: &[f32], pb: &PackedB, block: &mut [f32], r0: usize, k: usize, n: usize) {
    let blk = pb.blk;
    let r1 = r0 + block.len() / n;
    for (jc_idx, jc) in (0..n).step_by(blk.nc).enumerate() {
        let nc = blk.nc.min(n - jc);
        for (pc_idx, pc) in (0..k).step_by(blk.kc).enumerate() {
            let kc = blk.kc.min(k - pc);
            let tile = pb.tile(jc_idx, pc_idx);
            for ic in (r0..r1).step_by(blk.mc) {
                let mc = blk.mc.min(r1 - ic);
                let mut i = ic;
                while i + 1 < ic + mc {
                    let (a0, a1) = (&av[i * k + pc..], &av[(i + 1) * k + pc..]);
                    for p in 0..kc {
                        let (v0, v1) = (a0[p], a1[p]);
                        if v0 == 0.0 && v1 == 0.0 {
                            continue; // one-hot feature rows hit this often
                        }
                        let brow = &tile[p * nc..(p + 1) * nc];
                        let (o0, o1) = block.split_at_mut((i + 1 - r0) * n);
                        let o0 = &mut o0[(i - r0) * n + jc..(i - r0) * n + jc + nc];
                        let o1 = &mut o1[jc..jc + nc];
                        simd::axpy2(o0, o1, v0, v1, brow);
                    }
                    i += 2;
                }
                // odd tail row
                if i < ic + mc {
                    let arow = &av[i * k + pc..i * k + pc + kc];
                    for (p, &aval) in arow.iter().enumerate() {
                        if aval == 0.0 {
                            continue;
                        }
                        let brow = &tile[p * nc..(p + 1) * nc];
                        let orow = &mut block[(i - r0) * n + jc..(i - r0) * n + jc + nc];
                        simd::axpy(orow, aval, brow);
                    }
                }
            }
        }
    }
}

/// `sgemm_tn`: `out = aᵀ · b` for `a: [k,m]`, `b: [k,n]`. DM-Type.
///
/// The backward pass's weight-gradient shape (`dW = Xᵀ·dH`). The
/// transpose of A is materialized once (a DR-style repack, folded into
/// the kernel's read bytes) and B goes through the shared packed-panel
/// core, so every output element's k-accumulation order — and hence
/// bit-identity across thread counts — matches [`sgemm`] exactly.
pub fn sgemm_tn(ctx: &mut Ctx, a: &Tensor, b: &Tensor, blocking: GemmBlocking) -> Result<Tensor> {
    let (ka, m) = a.shape();
    let (kb, n) = b.shape();
    if ka != kb {
        return Err(Error::shape(format!("sgemm_tn: a is {ka}x{m}, b is {kb}x{n}")));
    }
    let t0 = std::time::Instant::now();
    let at = a.transposed();
    let pb = PackedB::pack(b, blocking);
    let mut out = ctx.scratch_zeros(m, n);
    sgemm_packed_into(&at, &pb, &mut out);
    let nanos = t0.elapsed().as_nanos() as u64;
    let counters = KernelCounters {
        flops: gemm_flops(m, ka, n),
        // A is read twice: once by the repack, once by the kernel
        bytes_read: (2 * a.bytes() + b.bytes()) as u64,
        bytes_written: out.bytes() as u64,
    };
    ctx.push("sgemm", KernelType::DenseMatmul, counters, nanos, None);
    Ok(out)
}

/// `sgemm_nt`: `out = a · bᵀ` for `a: [m,k]`, `b: [n,k]`. DM-Type.
///
/// The backward pass's activation-gradient shape (`dX = dH·Wᵀ`). B's
/// transpose is **not** materialized: [`PackedB::pack_transposed`]
/// gathers it straight into panel layout, and the shared packed core
/// does the rest.
pub fn sgemm_nt(ctx: &mut Ctx, a: &Tensor, b: &Tensor, blocking: GemmBlocking) -> Result<Tensor> {
    let (m, ka) = a.shape();
    let (n, kb) = b.shape();
    if ka != kb {
        return Err(Error::shape(format!("sgemm_nt: a is {m}x{ka}, b is {n}x{kb}")));
    }
    let t0 = std::time::Instant::now();
    let pb = PackedB::pack_transposed(b, blocking);
    let mut out = ctx.scratch_zeros(m, n);
    sgemm_packed_into(a, &pb, &mut out);
    let nanos = t0.elapsed().as_nanos() as u64;
    let counters = KernelCounters {
        flops: gemm_flops(m, ka, n),
        bytes_read: (a.bytes() + 2 * b.bytes()) as u64,
        bytes_written: out.bytes() as u64,
    };
    ctx.push("sgemm", KernelType::DenseMatmul, counters, nanos, None);
    Ok(out)
}

/// Naive triple-loop reference (for correctness tests and the perf
/// baseline in EXPERIMENTS.md §Perf).
pub fn sgemm_naive(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.shape();
    let n = b.cols();
    let mut out = Tensor::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += a.get(i, p) * b.get(p, j);
            }
            out.set(i, j, acc);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn blocked_matches_naive() {
        let mut rng = Pcg32::seeded(21);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (17, 33, 9), (64, 64, 64), (65, 130, 31)] {
            let a = Tensor::randn(m, k, 1.0, &mut rng);
            let b = Tensor::randn(k, n, 1.0, &mut rng);
            let blocked = sgemm_compute(&a, &b, GemmBlocking::default());
            let naive = sgemm_naive(&a, &b);
            assert!(
                blocked.allclose(&naive, 1e-4, 1e-5),
                "mismatch at {m}x{k}x{n}: {}",
                blocked.max_abs_diff(&naive).unwrap()
            );
        }
    }

    #[test]
    fn parallel_matches_serial_bitwise() {
        let mut rng = Pcg32::seeded(33);
        let blk = GemmBlocking::default();
        // shapes straddling the mc=128 macro-row boundary (ragged tails)
        for (m, k, n) in [(3, 5, 7), (130, 64, 33), (257, 96, 17)] {
            let a = Tensor::randn(m, k, 1.0, &mut rng);
            let b = Tensor::randn(k, n, 1.0, &mut rng);
            let serial = crate::parallel::with_threads(1, || sgemm_compute(&a, &b, blk));
            for t in [2usize, 4] {
                let par = crate::parallel::with_threads(t, || sgemm_compute(&a, &b, blk));
                assert!(
                    par.allclose(&serial, 0.0, 0.0),
                    "threads {t} not bit-identical at {m}x{k}x{n}"
                );
            }
        }
    }

    #[test]
    fn sgemm_counters() {
        let mut ctx = Ctx::default();
        let a = Tensor::full(4, 3, 1.0);
        let b = Tensor::full(3, 5, 2.0);
        let out = sgemm(&mut ctx, &a, &b, GemmBlocking::default()).unwrap();
        assert_eq!(out.shape(), (4, 5));
        assert_eq!(out.get(0, 0), 6.0);
        let e = &ctx.events[0];
        assert_eq!(e.name, "sgemm");
        assert_eq!(e.ktype, KernelType::DenseMatmul);
        assert_eq!(e.counters.flops, 2 * 4 * 3 * 5);
        assert_eq!(e.counters.bytes_read, (4 * 3 + 3 * 5) * 4);
        assert_eq!(e.counters.bytes_written, 4 * 5 * 4);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut ctx = Ctx::default();
        let a = Tensor::zeros(2, 3);
        let b = Tensor::zeros(4, 5);
        assert!(sgemm(&mut ctx, &a, &b, GemmBlocking::default()).is_err());
    }

    #[test]
    fn bias_fused() {
        let mut ctx = Ctx::default();
        let a = Tensor::full(2, 2, 1.0);
        let b = Tensor::full(2, 2, 1.0);
        let out = sgemm_bias(&mut ctx, &a, &b, &[10.0, 20.0], GemmBlocking::default()).unwrap();
        assert_eq!(out.get(0, 0), 12.0);
        assert_eq!(out.get(1, 1), 22.0);
        assert!(sgemm_bias(&mut ctx, &a, &b, &[1.0], GemmBlocking::default()).is_err());
    }

    #[test]
    fn transposed_variants_match_naive() {
        let mut rng = Pcg32::seeded(44);
        let blk = GemmBlocking::default();
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (33, 17, 9), (65, 130, 31)] {
            let a = Tensor::randn(k, m, 1.0, &mut rng); // stored kxm
            let b = Tensor::randn(k, n, 1.0, &mut rng);
            let mut ctx = Ctx::default();
            let tn = sgemm_tn(&mut ctx, &a, &b, blk).unwrap();
            let naive = sgemm_naive(&a.transposed(), &b);
            assert!(
                tn.allclose(&naive, 1e-4, 1e-5),
                "tn mismatch at {m}x{k}x{n}: {}",
                tn.max_abs_diff(&naive).unwrap()
            );

            let a2 = Tensor::randn(m, k, 1.0, &mut rng);
            let b2 = Tensor::randn(n, k, 1.0, &mut rng); // stored nxk
            let nt = sgemm_nt(&mut ctx, &a2, &b2, blk).unwrap();
            let naive = sgemm_naive(&a2, &b2.transposed());
            assert!(
                nt.allclose(&naive, 1e-4, 1e-5),
                "nt mismatch at {m}x{k}x{n}: {}",
                nt.max_abs_diff(&naive).unwrap()
            );
            assert!(ctx.events.iter().all(|e| e.name == "sgemm"));
        }
    }

    #[test]
    fn transposed_variants_reject_bad_shapes() {
        let mut ctx = Ctx::default();
        let a = Tensor::zeros(3, 2);
        let b = Tensor::zeros(4, 5);
        assert!(sgemm_tn(&mut ctx, &a, &b, GemmBlocking::default()).is_err());
        assert!(sgemm_nt(&mut ctx, &a, &b, GemmBlocking::default()).is_err());
    }

    #[test]
    fn one_hot_fast_path_correct() {
        // one-hot A exercises the aval==0 skip
        let mut rng = Pcg32::seeded(22);
        let a = Tensor::one_hot(10, 6);
        let b = Tensor::randn(6, 4, 1.0, &mut rng);
        let blocked = sgemm_compute(&a, &b, GemmBlocking::default());
        let naive = sgemm_naive(&a, &b);
        assert!(blocked.allclose(&naive, 1e-5, 1e-6));
    }

    #[test]
    fn packed_matches_unpacked_bitwise() {
        let mut rng = Pcg32::seeded(55);
        // small blockings force multiple ragged tiles; shapes include
        // K and N that are not multiples of the SIMD lane width (8)
        let blockings = [
            GemmBlocking::default(),
            GemmBlocking { mc: 2, nc: 3, kc: 5 },
            GemmBlocking { mc: 7, nc: 8, kc: 16 },
        ];
        for blk in blockings {
            for (m, k, n) in [(1, 1, 1), (3, 5, 7), (17, 33, 9), (65, 130, 31)] {
                let a = Tensor::randn(m, k, 1.0, &mut rng);
                let b = Tensor::randn(k, n, 1.0, &mut rng);
                let unpacked = sgemm_compute(&a, &b, blk);
                let packed = sgemm_packed_compute(&a, &PackedB::pack(&b, blk));
                assert!(
                    packed.allclose(&unpacked, 0.0, 0.0),
                    "packed not bit-identical at {m}x{k}x{n} blk {blk:?}"
                );
            }
        }
    }

    #[test]
    fn pack_transposed_equals_pack_of_transpose() {
        let mut rng = Pcg32::seeded(56);
        let blk = GemmBlocking { mc: 4, nc: 6, kc: 10 };
        let bt = Tensor::randn(13, 21, 1.0, &mut rng); // stored n x k
        let direct = PackedB::pack_transposed(&bt, blk);
        let via_materialize = PackedB::pack(&bt.transposed(), blk);
        assert_eq!(direct.data, via_materialize.data);
        assert_eq!(direct.tile_off, via_materialize.tile_off);
        assert_eq!((direct.k(), direct.n()), (21, 13));
    }

    #[test]
    fn sgemm_cached_matches_sgemm_bitwise_with_same_event() {
        let mut rng = Pcg32::seeded(57);
        let a = Tensor::randn(37, 19, 1.0, &mut rng);
        let b = Tensor::randn(19, 23, 1.0, &mut rng);
        let blk = GemmBlocking::default();
        let mut ctx_plain = Ctx::default();
        let plain = sgemm(&mut ctx_plain, &a, &b, blk).unwrap();
        let mut ctx = Ctx::default();
        let first = sgemm_cached(&mut ctx, &a, &b, PackKey::Proj(0), blk).unwrap();
        assert_eq!(ctx.packs.len(), 1);
        let again = sgemm_cached(&mut ctx, &a, &b, PackKey::Proj(0), blk).unwrap();
        assert_eq!(ctx.packs.len(), 1, "second call must reuse the panel");
        assert!(first.allclose(&plain, 0.0, 0.0));
        assert!(again.allclose(&plain, 0.0, 0.0));
        // instrumentation contract is byte-for-byte the sgemm one
        assert_eq!(ctx.events.len(), 2);
        for e in &ctx.events {
            assert_eq!(e.name, "sgemm");
            assert_eq!(e.ktype, KernelType::DenseMatmul);
            assert_eq!(e.counters, ctx_plain.events[0].counters);
        }
        // shape mismatch still rejected
        let bad = Tensor::zeros(5, 2);
        assert!(sgemm_cached(&mut ctx, &a, &bad, PackKey::Proj(0), blk).is_err());
    }

    #[test]
    fn pack_cache_repacks_on_new_values_blocking_or_shape() {
        let mut rng = Pcg32::seeded(58);
        let a = Tensor::randn(9, 6, 1.0, &mut rng);
        let b1 = Tensor::randn(6, 4, 1.0, &mut rng);
        let b2 = Tensor::randn(6, 4, 1.0, &mut rng); // same shape, new values
        let blk = GemmBlocking::default();
        let mut ctx = Ctx::default();
        let key = PackKey::SemW;
        let o1 = sgemm_cached(&mut ctx, &a, &b1, key, blk).unwrap();
        assert!(o1.allclose(&sgemm_naive(&a, &b1), 1e-4, 1e-5));
        // swapping the weight under the same key must not serve stale panels
        let o2 = sgemm_cached(&mut ctx, &a, &b2, key, blk).unwrap();
        assert!(o2.allclose(&sgemm_naive(&a, &b2), 1e-4, 1e-5));
        assert_eq!(ctx.packs.len(), 1, "same key is replaced in place");
        // a different blocking repacks too
        let blk2 = GemmBlocking { mc: 2, nc: 2, kc: 2 };
        let o3 = sgemm_cached(&mut ctx, &a, &b2, key, blk2).unwrap();
        assert!(o3.allclose(&sgemm_naive(&a, &b2), 1e-4, 1e-5));
        assert_eq!(ctx.packs.get(key).unwrap().blocking(), blk2);
        // and clear() empties the cache
        assert!(ctx.packs.bytes() > 0);
        ctx.packs.clear();
        assert!(ctx.packs.is_empty());
        assert_eq!(ctx.packs.bytes(), 0);
    }

    #[test]
    fn packed_parallel_matches_serial_bitwise() {
        let mut rng = Pcg32::seeded(59);
        let blk = GemmBlocking::default();
        let a = Tensor::randn(257, 96, 1.0, &mut rng);
        let b = Tensor::randn(96, 17, 1.0, &mut rng);
        let pb = PackedB::pack(&b, blk);
        let serial = crate::parallel::with_threads(1, || sgemm_packed_compute(&a, &pb));
        for t in [2usize, 4] {
            let par = crate::parallel::with_threads(t, || sgemm_packed_compute(&a, &pb));
            assert!(par.allclose(&serial, 0.0, 0.0), "threads {t} not bit-identical");
        }
    }
}
