//! DM-Type kernels: dense–dense matrix multiplication (`sgemm`).
//!
//! The paper's Feature Projection stage is almost entirely `sgemm`
//! (97.4% of FP time for HAN-DBLP, Table 3), and Semantic Aggregation's
//! attention-weight computation is `sgemm` again. The native
//! implementation here is a cache-blocked, 8-wide-unrolled matmul —
//! the L3 perf pass iterates on the blocking (see EXPERIMENTS.md §Perf).

use crate::kernels::{timed, Ctx, KernelCounters, KernelType};
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Cache-blocking parameters for [`sgemm`]. Tuned in the perf pass.
#[derive(Debug, Clone, Copy)]
pub struct GemmBlocking {
    /// Rows of A per macro-tile.
    pub mc: usize,
    /// Columns of B per macro-tile.
    pub nc: usize,
    /// Shared K extent per macro-tile.
    pub kc: usize,
}

impl Default for GemmBlocking {
    fn default() -> Self {
        // Measured best on the perf pass (EXPERIMENTS.md §Perf):
        // 128x256x512 with the 2-row micro-kernel — 14.1 GF/s vs 5.4 at
        // the previous 64x256x256 default on 1024x1024x64.
        GemmBlocking { mc: 128, nc: 256, kc: 512 }
    }
}

/// FLOP count of an (m,k)x(k,n) matmul: one mul + one add per MAC.
#[inline]
pub fn gemm_flops(m: usize, k: usize, n: usize) -> u64 {
    2 * m as u64 * k as u64 * n as u64
}

/// `sgemm`: `out = a · b`. DM-Type.
///
/// Counters follow the GPU convention the paper's Nsight numbers use:
/// logical reads are the A and B operands once each (on-chip reuse is the
/// cache model's job), writes are the output once.
pub fn sgemm(ctx: &mut Ctx, a: &Tensor, b: &Tensor, blocking: GemmBlocking) -> Result<Tensor> {
    let (m, ka) = a.shape();
    let (kb, n) = b.shape();
    if ka != kb {
        return Err(Error::shape(format!("sgemm: a is {m}x{ka}, b is {kb}x{n}")));
    }
    let (out, nanos) = timed(|| sgemm_compute(a, b, blocking));
    let counters = KernelCounters {
        flops: gemm_flops(m, ka, n),
        bytes_read: (a.bytes() + b.bytes()) as u64,
        bytes_written: out.bytes() as u64,
    };
    ctx.push("sgemm", KernelType::DenseMatmul, counters, nanos, None);
    Ok(out)
}

/// `sgemm` + broadcast bias add fused (DGL lowers Linear to this shape).
pub fn sgemm_bias(
    ctx: &mut Ctx,
    a: &Tensor,
    b: &Tensor,
    bias: &[f32],
    blocking: GemmBlocking,
) -> Result<Tensor> {
    let (m, ka) = a.shape();
    let (kb, n) = b.shape();
    if ka != kb {
        return Err(Error::shape(format!("sgemm_bias: a is {m}x{ka}, b is {kb}x{n}")));
    }
    if bias.len() != n {
        return Err(Error::shape(format!("bias len {} != n {}", bias.len(), n)));
    }
    let (mut out, nanos) = timed(|| sgemm_compute(a, b, blocking));
    let (_, bias_nanos) = timed(|| {
        for r in 0..m {
            let row = out.row_mut(r);
            for (o, &bv) in row.iter_mut().zip(bias) {
                *o += bv;
            }
        }
    });
    let counters = KernelCounters {
        flops: gemm_flops(m, ka, n) + (m * n) as u64,
        bytes_read: (a.bytes() + b.bytes() + bias.len() * 4) as u64,
        bytes_written: out.bytes() as u64,
    };
    ctx.push("sgemm", KernelType::DenseMatmul, counters, nanos + bias_nanos, None);
    Ok(out)
}

/// The blocked compute core (no instrumentation). Public so benches can
/// compare blockings directly.
pub fn sgemm_compute(a: &Tensor, b: &Tensor, blk: GemmBlocking) -> Tensor {
    let (m, k) = a.shape();
    let n = b.cols();
    let mut out = Tensor::zeros(m, n);
    let av = a.as_slice();
    let bv = b.as_slice();
    let ov = out.as_mut_slice();

    for jc in (0..n).step_by(blk.nc) {
        let nc = blk.nc.min(n - jc);
        for pc in (0..k).step_by(blk.kc) {
            let kc = blk.kc.min(k - pc);
            for ic in (0..m).step_by(blk.mc) {
                let mc = blk.mc.min(m - ic);
                // micro kernel: 2 rows of A at a time against the B
                // panel — halves the O-row traffic per FMA and gives
                // the vectorizer two independent accumulator streams.
                // Sparse A rows (one-hot features) still take the
                // zero-skip path, but only when the whole pair is zero.
                let mut i = ic;
                while i + 1 < ic + mc {
                    let (a0, a1) = (&av[i * k + pc..], &av[(i + 1) * k + pc..]);
                    for p in 0..kc {
                        let (v0, v1) = (a0[p], a1[p]);
                        if v0 == 0.0 && v1 == 0.0 {
                            continue; // one-hot feature rows hit this often
                        }
                        let brow = &bv[(pc + p) * n + jc..(pc + p) * n + jc + nc];
                        let (o0, o1) = ov.split_at_mut((i + 1) * n);
                        let o0 = &mut o0[i * n + jc..i * n + jc + nc];
                        let o1 = &mut o1[jc..jc + nc];
                        for ((x0, x1), &b) in o0.iter_mut().zip(o1.iter_mut()).zip(brow) {
                            *x0 += v0 * b;
                            *x1 += v1 * b;
                        }
                    }
                    i += 2;
                }
                // odd tail row
                if i < ic + mc {
                    let arow = &av[i * k + pc..i * k + pc + kc];
                    for (p, &aval) in arow.iter().enumerate() {
                        if aval == 0.0 {
                            continue;
                        }
                        let brow = &bv[(pc + p) * n + jc..(pc + p) * n + jc + nc];
                        let orow = &mut ov[i * n + jc..i * n + jc + nc];
                        for (o, &b) in orow.iter_mut().zip(brow) {
                            *o += aval * b;
                        }
                    }
                }
            }
        }
    }
    out
}

/// Naive triple-loop reference (for correctness tests and the perf
/// baseline in EXPERIMENTS.md §Perf).
pub fn sgemm_naive(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.shape();
    let n = b.cols();
    let mut out = Tensor::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += a.get(i, p) * b.get(p, j);
            }
            out.set(i, j, acc);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn blocked_matches_naive() {
        let mut rng = Pcg32::seeded(21);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (17, 33, 9), (64, 64, 64), (65, 130, 31)] {
            let a = Tensor::randn(m, k, 1.0, &mut rng);
            let b = Tensor::randn(k, n, 1.0, &mut rng);
            let blocked = sgemm_compute(&a, &b, GemmBlocking::default());
            let naive = sgemm_naive(&a, &b);
            assert!(
                blocked.allclose(&naive, 1e-4, 1e-5),
                "mismatch at {m}x{k}x{n}: {}",
                blocked.max_abs_diff(&naive).unwrap()
            );
        }
    }

    #[test]
    fn sgemm_counters() {
        let mut ctx = Ctx::default();
        let a = Tensor::full(4, 3, 1.0);
        let b = Tensor::full(3, 5, 2.0);
        let out = sgemm(&mut ctx, &a, &b, GemmBlocking::default()).unwrap();
        assert_eq!(out.shape(), (4, 5));
        assert_eq!(out.get(0, 0), 6.0);
        let e = &ctx.events[0];
        assert_eq!(e.name, "sgemm");
        assert_eq!(e.ktype, KernelType::DenseMatmul);
        assert_eq!(e.counters.flops, 2 * 4 * 3 * 5);
        assert_eq!(e.counters.bytes_read, (4 * 3 + 3 * 5) * 4);
        assert_eq!(e.counters.bytes_written, 4 * 5 * 4);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut ctx = Ctx::default();
        let a = Tensor::zeros(2, 3);
        let b = Tensor::zeros(4, 5);
        assert!(sgemm(&mut ctx, &a, &b, GemmBlocking::default()).is_err());
    }

    #[test]
    fn bias_fused() {
        let mut ctx = Ctx::default();
        let a = Tensor::full(2, 2, 1.0);
        let b = Tensor::full(2, 2, 1.0);
        let out = sgemm_bias(&mut ctx, &a, &b, &[10.0, 20.0], GemmBlocking::default()).unwrap();
        assert_eq!(out.get(0, 0), 12.0);
        assert_eq!(out.get(1, 1), 22.0);
        assert!(sgemm_bias(&mut ctx, &a, &b, &[1.0], GemmBlocking::default()).is_err());
    }

    #[test]
    fn one_hot_fast_path_correct() {
        // one-hot A exercises the aval==0 skip
        let mut rng = Pcg32::seeded(22);
        let a = Tensor::one_hot(10, 6);
        let b = Tensor::randn(6, 4, 1.0, &mut rng);
        let blocked = sgemm_compute(&a, &b, GemmBlocking::default());
        let naive = sgemm_naive(&a, &b);
        assert!(blocked.allclose(&naive, 1e-5, 1e-6));
    }
}
