//! EW-Type kernels: element-wise compute and reductions.
//!
//! Named after their CUDA counterparts in the paper's profile:
//! `unrolled_elementwise_kernel` (uEleWise — unary maps),
//! `vectorized_elementwise_kernel` (vEleWise — binary maps), and
//! `reduce_kernel` (Reduce). All are memory-bound with arithmetic
//! intensity well under 1 FLOP/byte (paper Fig 4: 0.1–0.34).

use crate::kernels::{timed, Ctx, KernelCounters, KernelType};
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Unary element-wise ops (lowered as `uEleWise`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum UnaryOp {
    /// tanh activation (HAN's semantic-attention MLP).
    Tanh,
    /// ELU activation (GAT layer output).
    Elu,
    /// Leaky ReLU with the given negative slope.
    LeakyRelu(f32),
    /// Exponential.
    Exp,
    /// Multiply by scalar.
    Scale(f32),
}

impl UnaryOp {
    #[inline]
    fn apply(self, x: f32) -> f32 {
        match self {
            UnaryOp::Tanh => x.tanh(),
            UnaryOp::Elu => {
                if x >= 0.0 {
                    x
                } else {
                    x.exp_m1()
                }
            }
            UnaryOp::LeakyRelu(s) => {
                if x >= 0.0 {
                    x
                } else {
                    s * x
                }
            }
            UnaryOp::Exp => x.exp(),
            UnaryOp::Scale(s) => s * x,
        }
    }

    /// FLOPs charged per element (transcendentals cost > 1 on GPU too,
    /// but Nsight counts retired FP instructions; 1 is the convention the
    /// paper's AI numbers imply for these kernels).
    fn flops_per_elem(self) -> u64 {
        1
    }
}

/// Binary element-wise ops (lowered as `vEleWise`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinaryOp {
    /// Element-wise addition.
    Add,
    /// Element-wise multiplication.
    Mul,
}

/// `uEleWise`: unary map over a tensor.
pub fn unary(ctx: &mut Ctx, x: &Tensor, op: UnaryOp) -> Tensor {
    let (out, nanos) = timed(|| {
        let mut out = x.clone();
        for v in out.as_mut_slice() {
            *v = op.apply(*v);
        }
        out
    });
    let n = x.len() as u64;
    let counters = KernelCounters {
        flops: n * op.flops_per_elem(),
        bytes_read: n * 4,
        bytes_written: n * 4,
    };
    ctx.push("uEleWise", KernelType::ElementWise, counters, nanos, None);
    out
}

/// `vEleWise`: binary map over two same-shape tensors.
pub fn binary(ctx: &mut Ctx, a: &Tensor, b: &Tensor, op: BinaryOp) -> Result<Tensor> {
    if a.shape() != b.shape() {
        return Err(Error::shape(format!("vEleWise: {:?} vs {:?}", a.shape(), b.shape())));
    }
    let (out, nanos) = timed(|| {
        let mut out = a.clone();
        for (o, &bv) in out.as_mut_slice().iter_mut().zip(b.as_slice()) {
            match op {
                BinaryOp::Add => *o += bv,
                BinaryOp::Mul => *o *= bv,
            }
        }
        out
    });
    let n = a.len() as u64;
    let counters =
        KernelCounters { flops: n, bytes_read: 2 * n * 4, bytes_written: n * 4 };
    ctx.push("vEleWise", KernelType::ElementWise, counters, nanos, None);
    Ok(out)
}

/// Broadcast a per-row scalar across columns and multiply
/// (`vEleWise` with broadcasting — how attention weights scale stacked
/// per-metapath embeddings in Semantic Aggregation).
pub fn scale_rows(ctx: &mut Ctx, x: &Tensor, row_scale: &[f32]) -> Result<Tensor> {
    if row_scale.len() != x.rows() {
        return Err(Error::shape(format!(
            "scale_rows: {} scales for {} rows",
            row_scale.len(),
            x.rows()
        )));
    }
    let (out, nanos) = timed(|| {
        let mut out = x.clone();
        for (r, &s) in row_scale.iter().enumerate() {
            for v in out.row_mut(r) {
                *v *= s;
            }
        }
        out
    });
    let n = x.len() as u64;
    let counters = KernelCounters {
        flops: n,
        bytes_read: n * 4 + row_scale.len() as u64 * 4,
        bytes_written: n * 4,
    };
    ctx.push("vEleWise", KernelType::ElementWise, counters, nanos, None);
    Ok(out)
}

/// `Reduce`: sum over groups of `group` consecutive rows.
///
/// Input `[g * n, f]` → output `[n, f]` with
/// `out[i] = Σ_{j<g} x[j * n + i]` — exactly how DGL reduces the stacked
/// `[P, N, F]` per-metapath tensor over the metapath axis in Semantic
/// Aggregation (P = group count, stacked contiguously).
pub fn reduce_grouped_rows(ctx: &mut Ctx, x: &Tensor, group: usize) -> Result<Tensor> {
    if group == 0 || x.rows() % group != 0 {
        return Err(Error::shape(format!(
            "reduce: {} rows not divisible into {} groups",
            x.rows(),
            group
        )));
    }
    let n = x.rows() / group;
    let f = x.cols();
    let (out, nanos) = timed(|| {
        let mut out = Tensor::zeros(n, f);
        for g in 0..group {
            for i in 0..n {
                let src = x.row(g * n + i);
                let dst = out.row_mut(i);
                for (o, &v) in dst.iter_mut().zip(src) {
                    *o += v;
                }
            }
        }
        out
    });
    let counters = KernelCounters {
        flops: x.len() as u64,
        bytes_read: x.len() as u64 * 4,
        bytes_written: (n * f) as u64 * 4,
    };
    ctx.push("Reduce", KernelType::ElementWise, counters, nanos, None);
    Ok(out)
}

/// `Reduce` over columns: row-mean of a matrix → one scalar per row
/// (HAN's semantic attention averages node scores per metapath).
pub fn reduce_rows_mean(ctx: &mut Ctx, x: &Tensor) -> Vec<f32> {
    let (out, nanos) = timed(|| {
        let inv = 1.0 / x.cols().max(1) as f32;
        (0..x.rows())
            .map(|r| x.row(r).iter().sum::<f32>() * inv)
            .collect::<Vec<f32>>()
    });
    let counters = KernelCounters {
        flops: x.len() as u64 + x.rows() as u64,
        bytes_read: x.len() as u64 * 4,
        bytes_written: x.rows() as u64 * 4,
    };
    ctx.push("Reduce", KernelType::ElementWise, counters, nanos, None);
    out
}

/// Row-wise dot with a broadcast vector: `out[i] = Σ_j x[i,j] * a[j]`.
///
/// This is how DGL's GATConv computes attention terms
/// (`(feat * attn).sum(-1)`): a broadcast `vEleWise` multiply followed by
/// a `Reduce` over the feature axis — two EW kernels, *not* an sgemm,
/// which is why the paper's Table 3 NA stage contains no DM kernel.
pub fn rowwise_dot(ctx: &mut Ctx, x: &Tensor, a: &[f32]) -> Result<Vec<f32>> {
    if a.len() != x.cols() {
        return Err(Error::shape(format!(
            "rowwise_dot: vector len {} vs {} cols",
            a.len(),
            x.cols()
        )));
    }
    let n = x.len() as u64;
    // ① vEleWise: broadcast multiply
    let (prod, mul_nanos) = timed(|| {
        let mut prod = x.clone();
        for r in 0..prod.rows() {
            for (v, &av) in prod.row_mut(r).iter_mut().zip(a) {
                *v *= av;
            }
        }
        prod
    });
    ctx.push(
        "vEleWise",
        KernelType::ElementWise,
        KernelCounters {
            flops: n,
            bytes_read: n * 4 + a.len() as u64 * 4,
            bytes_written: n * 4,
        },
        mul_nanos,
        None,
    );
    // ② Reduce: sum over the feature axis
    let (out, red_nanos) = timed(|| {
        (0..prod.rows())
            .map(|r| prod.row(r).iter().sum::<f32>())
            .collect::<Vec<f32>>()
    });
    ctx.push(
        "Reduce",
        KernelType::ElementWise,
        KernelCounters {
            flops: n,
            bytes_read: n * 4,
            bytes_written: x.rows() as u64 * 4,
        },
        red_nanos,
        None,
    );
    Ok(out)
}

/// Row-wise softmax of a small matrix (semantic attention over P
/// metapaths; P is tiny so this is an EW kernel, not TB).
pub fn softmax_vec(ctx: &mut Ctx, x: &[f32]) -> Vec<f32> {
    let (out, nanos) = timed(|| {
        let maxv = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f32> = x.iter().map(|&v| (v - maxv).exp()).collect();
        let denom: f32 = exps.iter().sum();
        exps.iter().map(|e| e / denom).collect::<Vec<f32>>()
    });
    let n = x.len() as u64;
    let counters =
        KernelCounters { flops: 4 * n, bytes_read: n * 4, bytes_written: n * 4 };
    ctx.push("uEleWise", KernelType::ElementWise, counters, nanos, None);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unary_ops() {
        let mut ctx = Ctx::default();
        let x = Tensor::from_vec(1, 4, vec![-2.0, -0.5, 0.0, 2.0]).unwrap();
        let lr = unary(&mut ctx, &x, UnaryOp::LeakyRelu(0.1));
        assert_eq!(lr.as_slice(), &[-0.2, -0.05, 0.0, 2.0]);
        let sc = unary(&mut ctx, &x, UnaryOp::Scale(2.0));
        assert_eq!(sc.as_slice(), &[-4.0, -1.0, 0.0, 4.0]);
        let elu = unary(&mut ctx, &x, UnaryOp::Elu);
        assert!(elu.get(0, 0) > -1.0 && elu.get(0, 0) < 0.0);
        assert_eq!(elu.get(0, 3), 2.0);
        assert_eq!(ctx.events.len(), 3);
        assert!(ctx.events.iter().all(|e| e.name == "uEleWise"));
    }

    #[test]
    fn binary_ops_and_shape_check() {
        let mut ctx = Ctx::default();
        let a = Tensor::full(2, 2, 3.0);
        let b = Tensor::full(2, 2, 4.0);
        assert_eq!(binary(&mut ctx, &a, &b, BinaryOp::Add).unwrap().get(0, 0), 7.0);
        assert_eq!(binary(&mut ctx, &a, &b, BinaryOp::Mul).unwrap().get(1, 1), 12.0);
        let c = Tensor::zeros(3, 2);
        assert!(binary(&mut ctx, &a, &c, BinaryOp::Add).is_err());
    }

    #[test]
    fn scale_rows_broadcast() {
        let mut ctx = Ctx::default();
        let x = Tensor::full(3, 2, 1.0);
        let out = scale_rows(&mut ctx, &x, &[1.0, 2.0, 3.0]).unwrap();
        assert_eq!(out.row(2), &[3.0, 3.0]);
        assert!(scale_rows(&mut ctx, &x, &[1.0]).is_err());
    }

    #[test]
    fn reduce_grouped() {
        let mut ctx = Ctx::default();
        // 2 groups of 2 rows, f=2: group0 = rows 0..2, group1 = rows 2..4
        let x = Tensor::from_vec(4, 2, vec![1., 1., 2., 2., 10., 10., 20., 20.]).unwrap();
        let out = reduce_grouped_rows(&mut ctx, &x, 2).unwrap();
        assert_eq!(out.shape(), (2, 2));
        assert_eq!(out.row(0), &[11.0, 11.0]);
        assert_eq!(out.row(1), &[22.0, 22.0]);
        assert!(reduce_grouped_rows(&mut ctx, &x, 3).is_err());
        assert_eq!(ctx.events[0].name, "Reduce");
    }

    #[test]
    fn reduce_rows_mean_values() {
        let mut ctx = Ctx::default();
        let x = Tensor::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let m = reduce_rows_mean(&mut ctx, &x);
        assert!((m[0] - 2.0).abs() < 1e-6);
        assert!((m[1] - 5.0).abs() < 1e-6);
    }

    #[test]
    fn softmax_vec_sums_to_one() {
        let mut ctx = Ctx::default();
        let s = softmax_vec(&mut ctx, &[1.0, 2.0, 3.0]);
        let sum: f32 = s.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(s[2] > s[1] && s[1] > s[0]);
        // stability at large magnitudes
        let s2 = softmax_vec(&mut ctx, &[1e4, 1e4]);
        assert!((s2[0] - 0.5).abs() < 1e-6);
    }
}
