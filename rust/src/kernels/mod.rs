//! The kernel substrate — the operations HGNN execution decomposes into.
//!
//! The paper classifies every CUDA kernel in the profile into four types
//! (§4.1); we reproduce the taxonomy verbatim and name our kernels after
//! their CUDA counterparts:
//!
//! | Type | Paper examples | Here |
//! |---|---|---|
//! | **DM** dense–dense matmul | `sgemm` | [`dense::sgemm`] |
//! | **TB** topology-based | `SpMMCsr`, `SDDMMCoo` | [`sparse_ops`] |
//! | **EW** element-wise | `uEleWise`, `vEleWise`, `Reduce` | [`elementwise`] |
//! | **DR** data rearrangement | `Concat` (CatArrayBatchedCopy) | [`rearrange`] |
//!
//! Every kernel executes real f32 math on the CPU **and** reports exact
//! operation counters ([`KernelCounters`]): FLOPs, logical bytes read and
//! written, and — for irregular TB kernels — the gather trace that the
//! T4 cache model replays. Wallclock is recorded per invocation; modeled
//! GPU time is derived later by [`crate::gpumodel`].

pub mod dense;
pub mod elementwise;
pub mod quant;
pub mod rearrange;
pub mod simd;
pub mod sparse_ops;

/// The paper's four kernel classes (§4.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum KernelType {
    /// Dense–dense matrix multiplication (compute-bound, regular).
    DenseMatmul,
    /// Graph-topology-based (memory-bound, irregular access).
    TopologyBased,
    /// Element-wise / reduction (memory-bound, low AI).
    ElementWise,
    /// Data rearrangement (memory-bound, pure movement).
    DataRearrange,
}

impl KernelType {
    /// Paper abbreviation: DM / TB / EW / DR.
    pub fn abbrev(self) -> &'static str {
        match self {
            KernelType::DenseMatmul => "DM",
            KernelType::TopologyBased => "TB",
            KernelType::ElementWise => "EW",
            KernelType::DataRearrange => "DR",
        }
    }

    /// All types, in the paper's presentation order.
    pub const ALL: [KernelType; 4] = [
        KernelType::DenseMatmul,
        KernelType::TopologyBased,
        KernelType::ElementWise,
        KernelType::DataRearrange,
    ];
}

/// Exact operation counters for one kernel invocation.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct KernelCounters {
    /// Floating-point operations performed (mul+add counted separately).
    pub flops: u64,
    /// Logical bytes read (before any cache).
    pub bytes_read: u64,
    /// Logical bytes written.
    pub bytes_written: u64,
}

impl KernelCounters {
    /// Arithmetic intensity in FLOP/byte over total traffic.
    pub fn arithmetic_intensity(&self) -> f64 {
        let bytes = self.bytes_read + self.bytes_written;
        if bytes == 0 {
            return 0.0;
        }
        self.flops as f64 / bytes as f64
    }

    /// Merge another counter set into this one.
    pub fn merge(&mut self, other: &KernelCounters) {
        self.flops += other.flops;
        self.bytes_read += other.bytes_read;
        self.bytes_written += other.bytes_written;
    }
}

/// Irregular gather trace: row ids gathered from a feature matrix, in
/// access order. The cache model expands each row into `row_bytes` of
/// contiguous lines at `row * row_bytes` within a private address space.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct GatherTrace {
    /// Bytes per gathered row (feature row width * 4).
    pub row_bytes: u32,
    /// Gathered row ids in access order.
    pub rows: Vec<u32>,
}

/// One executed kernel: identity, class, counters, wallclock and trace.
#[derive(Debug, Clone)]
pub struct KernelExec {
    /// Kernel name (CUDA-counterpart naming: `sgemm`, `SpMMCsr`, ...).
    pub name: &'static str,
    /// Kernel class.
    pub ktype: KernelType,
    /// Exact counters.
    pub counters: KernelCounters,
    /// CPU wallclock nanoseconds of the native execution.
    pub wall_nanos: u64,
    /// Irregular gather trace (TB kernels only).
    pub trace: Option<GatherTrace>,
}

/// Cumulative [`ScratchArena`] counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ArenaStats {
    /// Checkouts served from a recycled buffer (no heap allocation).
    pub hits: u64,
    /// Checkouts that had to allocate fresh.
    pub misses: u64,
    /// Buffers currently parked in the free list.
    pub held: usize,
}

/// Reusable buffer pool behind the hot-path tensor allocations: kernels
/// check out `Tensor::zeros`-shaped buffers ([`ScratchArena::take_zeroed`])
/// and the session executors return the stage outputs they own once a
/// run or served batch is finished ([`ScratchArena::give`]), so
/// steady-state `run`/`run_batch`/serve dispatches stop paying heap
/// allocation for the dominant tensors (FP projections, NA results, the
/// final embeddings). Checkout is best-fit by capacity; the free list
/// is bounded so a pathological shape mix cannot hoard memory.
#[derive(Debug, Default)]
pub struct ScratchArena {
    free: Vec<Vec<f32>>,
    hits: u64,
    misses: u64,
}

impl ScratchArena {
    /// Most buffers the free list will park (beyond this, returned
    /// buffers are simply dropped).
    pub const MAX_FREE: usize = 64;

    /// Byte budget for parked buffers. When a `give` pushes the total
    /// over it, the **largest** parked buffers are evicted first — so a
    /// one-off full-graph run cannot pin graph-scale buffers for the
    /// lifetime of a session that afterwards serves small batches
    /// (best-fit checkout would otherwise never touch, and never free,
    /// the big ones).
    pub const MAX_FREE_BYTES: usize = 256 << 20;

    /// Best-fit checkout: the smallest parked buffer with capacity
    /// `>= len`, counting a hit; `None` (a miss, counted by callers)
    /// when nothing fits.
    fn checkout(&mut self, len: usize) -> Option<Vec<f32>> {
        let mut best: Option<usize> = None;
        for (i, b) in self.free.iter().enumerate() {
            if b.capacity() >= len
                && best.is_none_or(|j: usize| self.free[j].capacity() > b.capacity())
            {
                best = Some(i);
            }
        }
        best.map(|i| {
            self.hits += 1;
            self.free.swap_remove(i)
        })
    }

    /// Check out a zero-filled buffer of exactly `len` elements —
    /// recycled (best capacity fit) when possible, freshly allocated
    /// otherwise.
    pub fn take_zeroed(&mut self, len: usize) -> Vec<f32> {
        if len == 0 {
            return Vec::new();
        }
        match self.checkout(len) {
            Some(mut b) => {
                b.clear();
                b.resize(len, 0.0);
                b
            }
            None => {
                self.misses += 1;
                vec![0.0; len]
            }
        }
    }

    /// Check out a buffer of exactly `len` elements with **unspecified
    /// contents** (stale values from a previous checkout may remain) —
    /// for kernels that overwrite every element anyway (pure-copy DR
    /// kernels like `IndexSelect`), skipping the zero-fill pass that
    /// [`ScratchArena::take_zeroed`] pays.
    pub fn take_any(&mut self, len: usize) -> Vec<f32> {
        if len == 0 {
            return Vec::new();
        }
        match self.checkout(len) {
            Some(mut b) => {
                b.truncate(len);
                if b.len() < len {
                    // only the tail beyond the stale prefix is written
                    b.resize(len, 0.0);
                }
                b
            }
            None => {
                self.misses += 1;
                vec![0.0; len]
            }
        }
    }

    /// Return a buffer for reuse (dropped when the free list is full or
    /// the buffer holds no capacity; largest-first eviction keeps the
    /// parked total under [`ScratchArena::MAX_FREE_BYTES`]).
    pub fn give(&mut self, buf: Vec<f32>) {
        if buf.capacity() == 0 || self.free.len() >= Self::MAX_FREE {
            return;
        }
        self.free.push(buf);
        let mut total: usize = self.free.iter().map(|b| b.capacity() * 4).sum();
        while total > Self::MAX_FREE_BYTES {
            let (i, cap) = self
                .free
                .iter()
                .enumerate()
                .map(|(i, b)| (i, b.capacity()))
                .max_by_key(|&(_, c)| c)
                .expect("free list non-empty while over budget");
            self.free.swap_remove(i);
            total -= cap * 4;
        }
    }

    /// Counter snapshot.
    pub fn stats(&self) -> ArenaStats {
        ArenaStats { hits: self.hits, misses: self.misses, held: self.free.len() }
    }
}

/// Collects [`KernelExec`] records during kernel execution; the engine
/// drains it into the profiler with stage attribution.
#[derive(Debug, Default)]
pub struct Ctx {
    /// Executed kernels, in issue order.
    pub events: Vec<KernelExec>,
    /// When false, gather traces are dropped to save memory (benches that
    /// only need time breakdowns).
    pub record_traces: bool,
    /// Reusable output-buffer pool for the hot kernels (see
    /// [`ScratchArena`]); lives as long as the context, so a
    /// session-held `Ctx` reuses buffers across runs and served
    /// batches.
    pub arena: ScratchArena,
    /// Packed sgemm B-panels keyed per weight matrix (see
    /// [`dense::PackCache`]); like the arena, lives as long as the
    /// context, so a session-held `Ctx` packs each projection weight
    /// once per weights generation and reuses the panels across served
    /// batches and training steps. `Session::invalidate` clears it on
    /// weight swaps; [`dense::PackCache::ensure`] additionally
    /// fingerprints the source matrix so a stale panel can never be
    /// consumed through any other call path.
    pub packs: dense::PackCache,
}

impl Ctx {
    /// Context that records gather traces (needed for Table 3 / Fig 4).
    pub fn with_traces() -> Ctx {
        Ctx { record_traces: true, ..Ctx::default() }
    }

    /// A zero-filled tensor drawn from the scratch arena.
    pub fn scratch_zeros(&mut self, rows: usize, cols: usize) -> crate::tensor::Tensor {
        crate::tensor::Tensor::from_vec(rows, cols, self.arena.take_zeroed(rows * cols))
            .expect("arena buffer sized to rows*cols")
    }

    /// An arena tensor with unspecified contents, for kernels that
    /// overwrite every element (see [`ScratchArena::take_any`]).
    pub fn scratch_any(&mut self, rows: usize, cols: usize) -> crate::tensor::Tensor {
        crate::tensor::Tensor::from_vec(rows, cols, self.arena.take_any(rows * cols))
            .expect("arena buffer sized to rows*cols")
    }

    /// Record one kernel execution.
    pub fn push(
        &mut self,
        name: &'static str,
        ktype: KernelType,
        counters: KernelCounters,
        wall_nanos: u64,
        trace: Option<GatherTrace>,
    ) {
        let trace = if self.record_traces { trace } else { None };
        self.events.push(KernelExec { name, ktype, counters, wall_nanos, trace });
    }

    /// Total counters across all recorded kernels.
    pub fn totals(&self) -> KernelCounters {
        let mut t = KernelCounters::default();
        for e in &self.events {
            t.merge(&e.counters);
        }
        t
    }

    /// Drain all events out of the context.
    pub fn drain(&mut self) -> Vec<KernelExec> {
        std::mem::take(&mut self.events)
    }
}

/// Time a closure, returning (result, elapsed nanoseconds).
#[inline]
pub(crate) fn timed<T>(f: impl FnOnce() -> T) -> (T, u64) {
    let t0 = std::time::Instant::now();
    let out = f();
    (out, t0.elapsed().as_nanos() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ai_computation() {
        let c = KernelCounters { flops: 100, bytes_read: 40, bytes_written: 10 };
        assert!((c.arithmetic_intensity() - 2.0).abs() < 1e-12);
        assert_eq!(KernelCounters::default().arithmetic_intensity(), 0.0);
    }

    #[test]
    fn ctx_records_and_totals() {
        let mut ctx = Ctx::default();
        ctx.push(
            "k1",
            KernelType::ElementWise,
            KernelCounters { flops: 5, bytes_read: 8, bytes_written: 8 },
            100,
            None,
        );
        ctx.push(
            "k2",
            KernelType::DenseMatmul,
            KernelCounters { flops: 10, bytes_read: 4, bytes_written: 4 },
            200,
            None,
        );
        let t = ctx.totals();
        assert_eq!(t.flops, 15);
        assert_eq!(t.bytes_read, 12);
        assert_eq!(ctx.drain().len(), 2);
        assert!(ctx.events.is_empty());
    }

    #[test]
    fn trace_dropped_unless_enabled() {
        let mut ctx = Ctx::default();
        let trace = GatherTrace { row_bytes: 256, rows: vec![1, 2, 3] };
        ctx.push("k", KernelType::TopologyBased, KernelCounters::default(), 1, Some(trace.clone()));
        assert!(ctx.events[0].trace.is_none());
        let mut ctx2 = Ctx::with_traces();
        ctx2.push("k", KernelType::TopologyBased, KernelCounters::default(), 1, Some(trace));
        assert!(ctx2.events[0].trace.is_some());
    }

    #[test]
    fn arena_recycles_and_zeroes() {
        let mut arena = ScratchArena::default();
        let mut a = arena.take_zeroed(8);
        assert_eq!(arena.stats(), ArenaStats { hits: 0, misses: 1, held: 0 });
        a.iter_mut().for_each(|v| *v = 7.0);
        arena.give(a);
        assert_eq!(arena.stats().held, 1);
        // reuse must come back zero-filled, not holding stale values
        let b = arena.take_zeroed(4);
        assert_eq!(b, vec![0.0; 4]);
        assert_eq!(arena.stats().hits, 1);
        // a request larger than any held buffer allocates fresh
        arena.give(b);
        let c = arena.take_zeroed(100);
        assert_eq!(c.len(), 100);
        assert_eq!(arena.stats().misses, 2);
    }

    #[test]
    fn arena_take_any_skips_zero_fill() {
        let mut arena = ScratchArena::default();
        let mut a = arena.take_zeroed(8);
        a.iter_mut().for_each(|v| *v = 7.0);
        arena.give(a);
        // unspecified-contents checkout keeps the stale prefix (the
        // documented contract: callers overwrite every element)
        let b = arena.take_any(4);
        assert_eq!(b, vec![7.0; 4]);
        assert_eq!(arena.stats().hits, 1);
    }

    #[test]
    fn arena_best_fit_prefers_smallest_sufficient() {
        let mut arena = ScratchArena::default();
        arena.give(Vec::with_capacity(100));
        arena.give(Vec::with_capacity(10));
        let b = arena.take_zeroed(8);
        assert!(b.capacity() < 100, "best fit must pick the 10-cap buffer");
    }

    #[test]
    fn ctx_scratch_zeros_shapes() {
        let mut ctx = Ctx::default();
        let t = ctx.scratch_zeros(3, 4);
        assert_eq!(t.shape(), (3, 4));
        assert!(t.as_slice().iter().all(|&v| v == 0.0));
        assert_eq!(ctx.arena.stats().misses, 1);
    }

    #[test]
    fn abbrevs() {
        assert_eq!(KernelType::DenseMatmul.abbrev(), "DM");
        assert_eq!(KernelType::TopologyBased.abbrev(), "TB");
        assert_eq!(KernelType::ElementWise.abbrev(), "EW");
        assert_eq!(KernelType::DataRearrange.abbrev(), "DR");
    }
}
