//! The kernel substrate — the operations HGNN execution decomposes into.
//!
//! The paper classifies every CUDA kernel in the profile into four types
//! (§4.1); we reproduce the taxonomy verbatim and name our kernels after
//! their CUDA counterparts:
//!
//! | Type | Paper examples | Here |
//! |---|---|---|
//! | **DM** dense–dense matmul | `sgemm` | [`dense::sgemm`] |
//! | **TB** topology-based | `SpMMCsr`, `SDDMMCoo` | [`sparse_ops`] |
//! | **EW** element-wise | `uEleWise`, `vEleWise`, `Reduce` | [`elementwise`] |
//! | **DR** data rearrangement | `Concat` (CatArrayBatchedCopy) | [`rearrange`] |
//!
//! Every kernel executes real f32 math on the CPU **and** reports exact
//! operation counters ([`KernelCounters`]): FLOPs, logical bytes read and
//! written, and — for irregular TB kernels — the gather trace that the
//! T4 cache model replays. Wallclock is recorded per invocation; modeled
//! GPU time is derived later by [`crate::gpumodel`].

pub mod dense;
pub mod elementwise;
pub mod rearrange;
pub mod sparse_ops;

/// The paper's four kernel classes (§4.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum KernelType {
    /// Dense–dense matrix multiplication (compute-bound, regular).
    DenseMatmul,
    /// Graph-topology-based (memory-bound, irregular access).
    TopologyBased,
    /// Element-wise / reduction (memory-bound, low AI).
    ElementWise,
    /// Data rearrangement (memory-bound, pure movement).
    DataRearrange,
}

impl KernelType {
    /// Paper abbreviation: DM / TB / EW / DR.
    pub fn abbrev(self) -> &'static str {
        match self {
            KernelType::DenseMatmul => "DM",
            KernelType::TopologyBased => "TB",
            KernelType::ElementWise => "EW",
            KernelType::DataRearrange => "DR",
        }
    }

    /// All types, in the paper's presentation order.
    pub const ALL: [KernelType; 4] = [
        KernelType::DenseMatmul,
        KernelType::TopologyBased,
        KernelType::ElementWise,
        KernelType::DataRearrange,
    ];
}

/// Exact operation counters for one kernel invocation.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct KernelCounters {
    /// Floating-point operations performed (mul+add counted separately).
    pub flops: u64,
    /// Logical bytes read (before any cache).
    pub bytes_read: u64,
    /// Logical bytes written.
    pub bytes_written: u64,
}

impl KernelCounters {
    /// Arithmetic intensity in FLOP/byte over total traffic.
    pub fn arithmetic_intensity(&self) -> f64 {
        let bytes = self.bytes_read + self.bytes_written;
        if bytes == 0 {
            return 0.0;
        }
        self.flops as f64 / bytes as f64
    }

    /// Merge another counter set into this one.
    pub fn merge(&mut self, other: &KernelCounters) {
        self.flops += other.flops;
        self.bytes_read += other.bytes_read;
        self.bytes_written += other.bytes_written;
    }
}

/// Irregular gather trace: row ids gathered from a feature matrix, in
/// access order. The cache model expands each row into `row_bytes` of
/// contiguous lines at `row * row_bytes` within a private address space.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct GatherTrace {
    /// Bytes per gathered row (feature row width * 4).
    pub row_bytes: u32,
    /// Gathered row ids in access order.
    pub rows: Vec<u32>,
}

/// One executed kernel: identity, class, counters, wallclock and trace.
#[derive(Debug, Clone)]
pub struct KernelExec {
    /// Kernel name (CUDA-counterpart naming: `sgemm`, `SpMMCsr`, ...).
    pub name: &'static str,
    /// Kernel class.
    pub ktype: KernelType,
    /// Exact counters.
    pub counters: KernelCounters,
    /// CPU wallclock nanoseconds of the native execution.
    pub wall_nanos: u64,
    /// Irregular gather trace (TB kernels only).
    pub trace: Option<GatherTrace>,
}

/// Collects [`KernelExec`] records during kernel execution; the engine
/// drains it into the profiler with stage attribution.
#[derive(Debug, Default)]
pub struct Ctx {
    /// Executed kernels, in issue order.
    pub events: Vec<KernelExec>,
    /// When false, gather traces are dropped to save memory (benches that
    /// only need time breakdowns).
    pub record_traces: bool,
}

impl Ctx {
    /// Context that records gather traces (needed for Table 3 / Fig 4).
    pub fn with_traces() -> Ctx {
        Ctx { events: Vec::new(), record_traces: true }
    }

    /// Record one kernel execution.
    pub fn push(
        &mut self,
        name: &'static str,
        ktype: KernelType,
        counters: KernelCounters,
        wall_nanos: u64,
        trace: Option<GatherTrace>,
    ) {
        let trace = if self.record_traces { trace } else { None };
        self.events.push(KernelExec { name, ktype, counters, wall_nanos, trace });
    }

    /// Total counters across all recorded kernels.
    pub fn totals(&self) -> KernelCounters {
        let mut t = KernelCounters::default();
        for e in &self.events {
            t.merge(&e.counters);
        }
        t
    }

    /// Drain all events out of the context.
    pub fn drain(&mut self) -> Vec<KernelExec> {
        std::mem::take(&mut self.events)
    }
}

/// Time a closure, returning (result, elapsed nanoseconds).
#[inline]
pub(crate) fn timed<T>(f: impl FnOnce() -> T) -> (T, u64) {
    let t0 = std::time::Instant::now();
    let out = f();
    (out, t0.elapsed().as_nanos() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ai_computation() {
        let c = KernelCounters { flops: 100, bytes_read: 40, bytes_written: 10 };
        assert!((c.arithmetic_intensity() - 2.0).abs() < 1e-12);
        assert_eq!(KernelCounters::default().arithmetic_intensity(), 0.0);
    }

    #[test]
    fn ctx_records_and_totals() {
        let mut ctx = Ctx::default();
        ctx.push(
            "k1",
            KernelType::ElementWise,
            KernelCounters { flops: 5, bytes_read: 8, bytes_written: 8 },
            100,
            None,
        );
        ctx.push(
            "k2",
            KernelType::DenseMatmul,
            KernelCounters { flops: 10, bytes_read: 4, bytes_written: 4 },
            200,
            None,
        );
        let t = ctx.totals();
        assert_eq!(t.flops, 15);
        assert_eq!(t.bytes_read, 12);
        assert_eq!(ctx.drain().len(), 2);
        assert!(ctx.events.is_empty());
    }

    #[test]
    fn trace_dropped_unless_enabled() {
        let mut ctx = Ctx::default();
        let trace = GatherTrace { row_bytes: 256, rows: vec![1, 2, 3] };
        ctx.push("k", KernelType::TopologyBased, KernelCounters::default(), 1, Some(trace.clone()));
        assert!(ctx.events[0].trace.is_none());
        let mut ctx2 = Ctx::with_traces();
        ctx2.push("k", KernelType::TopologyBased, KernelCounters::default(), 1, Some(trace));
        assert!(ctx2.events[0].trace.is_some());
    }

    #[test]
    fn abbrevs() {
        assert_eq!(KernelType::DenseMatmul.abbrev(), "DM");
        assert_eq!(KernelType::TopologyBased.abbrev(), "TB");
        assert_eq!(KernelType::ElementWise.abbrev(), "EW");
        assert_eq!(KernelType::DataRearrange.abbrev(), "DR");
    }
}
