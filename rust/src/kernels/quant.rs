//! Quantized storage for the opt-in low-precision feature-projection
//! path (`SessionBuilder::quantize`, `--quantize f16|int8`).
//!
//! Motivated by SiHGNN's observation that the semantic-graph stages are
//! capacity-bound: projection weights and reuse-cache rows dominate the
//! resident footprint of a serving session, and both tolerate reduced
//! precision because the downstream aggregation stages are
//! averaging/softmax pipelines. Two formats are supported:
//!
//! * [`QuantSpec::F16`] — IEEE 754 binary16 with round-to-nearest-even,
//!   2 bytes/element, no calibration state;
//! * [`QuantSpec::Int8`] — symmetric int8 with a per-column scale for
//!   weight matrices ([`QuantMatrix`]) and a per-row scale for cached
//!   activation rows ([`QuantRow`]), 1 byte/element (+ scales).
//!
//! The compute path stays f32: weights are **fake-quantized** (stored
//! quantized, dequantized once per weights generation into the f32
//! working copy the packed sgemm panels consume) and reuse-cache rows
//! are dequantized on fetch, so every kernel keeps its exact-counter and
//! event-name contract. Accuracy deltas versus the f32 path are
//! reported by `report::quant_delta_table`.

use crate::tensor::Tensor;

/// Quantization format selector, parsed from `--quantize f16|int8`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QuantSpec {
    /// IEEE 754 binary16, round-to-nearest-even.
    F16,
    /// Symmetric int8: per-column scales in [`QuantMatrix`], a per-row
    /// scale in [`QuantRow`]; values clamp to ±127 (no −128, so the
    /// grid is symmetric and negation is exact).
    Int8,
}

impl QuantSpec {
    /// Parse a CLI spelling. Accepts exactly `f16` and `int8`.
    pub fn parse(s: &str) -> Option<QuantSpec> {
        match s {
            "f16" => Some(QuantSpec::F16),
            "int8" => Some(QuantSpec::Int8),
            _ => None,
        }
    }

    /// Canonical CLI spelling (`f16` / `int8`).
    pub fn name(self) -> &'static str {
        match self {
            QuantSpec::F16 => "f16",
            QuantSpec::Int8 => "int8",
        }
    }

    /// Stored bytes per element (excluding scales).
    pub fn bytes_per_element(self) -> usize {
        match self {
            QuantSpec::F16 => 2,
            QuantSpec::Int8 => 1,
        }
    }
}

/// Convert an f32 to IEEE 754 binary16 bits with round-to-nearest-even.
/// Overflow saturates to ±infinity; NaN stays NaN (payload truncated,
/// quiet bit forced if truncation would make it infinity); subnormal
/// halves and the underflow-to-zero boundary round correctly.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf / NaN
        let mut m = (mant >> 13) as u16;
        if mant != 0 && m == 0 {
            m = 0x200; // keep NaN a NaN after payload truncation
        }
        return sign | 0x7c00 | m;
    }
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7c00; // overflow → ±inf
    }
    if unbiased >= -14 {
        // normal half: drop 13 mantissa bits with round-to-nearest-even
        let mut half_exp = (unbiased + 15) as u32;
        let mut half_mant = mant >> 13;
        let rem = mant & 0x1fff;
        if rem > 0x1000 || (rem == 0x1000 && (half_mant & 1) == 1) {
            half_mant += 1;
            if half_mant == 0x400 {
                half_mant = 0;
                half_exp += 1;
                if half_exp >= 31 {
                    return sign | 0x7c00;
                }
            }
        }
        return sign | ((half_exp as u16) << 10) | half_mant as u16;
    }
    if unbiased >= -25 && exp != 0 {
        // subnormal half: shift the full 24-bit significand down with RNE
        let full_mant = mant | 0x0080_0000;
        let shift = (13 + (-14 - unbiased)) as u32;
        let mut hm = full_mant >> shift;
        let rem = full_mant & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        if rem > halfway || (rem == halfway && (hm & 1) == 1) {
            hm += 1; // may carry into the smallest normal (0x0400) — valid bits
        }
        return sign | hm as u16;
    }
    sign // underflow (incl. f32 subnormals) → signed zero
}

/// Smallest positive binary16 subnormal (2⁻²⁴) as an exact f32.
const F16_SUBNORMAL_UNIT: f32 = 5.960_464_5e-8;

/// Convert IEEE 754 binary16 bits back to f32. Exact (every binary16
/// value is representable in binary32); NaN payloads shift up 13 bits.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    if exp == 0x1f {
        return f32::from_bits(sign | 0x7f80_0000 | (mant << 13));
    }
    if exp == 0 {
        if mant == 0 {
            return f32::from_bits(sign); // ±0
        }
        let v = (mant as f32) * F16_SUBNORMAL_UNIT; // exact: mant ≤ 1023
        return if sign != 0 { -v } else { v };
    }
    f32::from_bits(sign | ((exp + 112) << 23) | (mant << 13))
}

/// Round-trip an f32 through binary16 (the fake-quantization step for
/// [`QuantSpec::F16`]).
pub fn f16_roundtrip(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

fn int8_scale(max_abs: f32) -> f32 {
    if max_abs == 0.0 {
        1.0 // all-zero column/row: any scale reproduces it exactly
    } else {
        max_abs / 127.0
    }
}

fn int8_quantize(v: f32, scale: f32) -> i8 {
    (v / scale).round().clamp(-127.0, 127.0) as i8
}

/// A weight matrix stored quantized. Dequantizes back to a [`Tensor`]
/// once per weights generation; the f32 working copy is what the packed
/// sgemm panels consume.
#[derive(Debug, Clone)]
pub enum QuantMatrix {
    /// binary16 elements, row-major.
    F16 {
        /// Row count of the source matrix.
        rows: usize,
        /// Column count of the source matrix.
        cols: usize,
        /// Row-major binary16 bits.
        data: Vec<u16>,
    },
    /// Symmetric int8 with one scale per column (weights vary far more
    /// across output columns than within one, so per-column scales keep
    /// the max-abs error an order of magnitude under a per-tensor scale).
    Int8 {
        /// Row count of the source matrix.
        rows: usize,
        /// Column count of the source matrix.
        cols: usize,
        /// Row-major quantized elements.
        data: Vec<i8>,
        /// One dequantization scale per column (`cols` entries).
        scales: Vec<f32>,
    },
}

impl QuantMatrix {
    /// Quantize a weight matrix under `spec`.
    pub fn quantize(t: &Tensor, spec: QuantSpec) -> QuantMatrix {
        let (rows, cols) = t.shape();
        match spec {
            QuantSpec::F16 => QuantMatrix::F16 {
                rows,
                cols,
                data: t.as_slice().iter().map(|&v| f32_to_f16_bits(v)).collect(),
            },
            QuantSpec::Int8 => {
                let mut max_abs = vec![0.0f32; cols];
                for r in 0..rows {
                    for (m, &v) in max_abs.iter_mut().zip(t.row(r)) {
                        *m = m.max(v.abs());
                    }
                }
                let scales: Vec<f32> = max_abs.into_iter().map(int8_scale).collect();
                let mut data = Vec::with_capacity(rows * cols);
                for r in 0..rows {
                    for (&s, &v) in scales.iter().zip(t.row(r)) {
                        data.push(int8_quantize(v, s));
                    }
                }
                QuantMatrix::Int8 { rows, cols, data, scales }
            }
        }
    }

    /// Dequantize into a fresh f32 tensor.
    pub fn dequantize(&self) -> Tensor {
        match self {
            QuantMatrix::F16 { rows, cols, data } => Tensor::from_vec(
                *rows,
                *cols,
                data.iter().map(|&b| f16_bits_to_f32(b)).collect(),
            )
            .expect("quantized matrix dims are consistent"),
            QuantMatrix::Int8 { rows, cols, data, scales } => {
                let mut out = Vec::with_capacity(rows * cols);
                for row in data.chunks(*cols) {
                    for (&q, &s) in row.iter().zip(scales) {
                        out.push(q as f32 * s);
                    }
                }
                Tensor::from_vec(*rows, *cols, out)
                    .expect("quantized matrix dims are consistent")
            }
        }
    }

    /// Rows of the source matrix.
    pub fn rows(&self) -> usize {
        match self {
            QuantMatrix::F16 { rows, .. } | QuantMatrix::Int8 { rows, .. } => *rows,
        }
    }

    /// Columns of the source matrix.
    pub fn cols(&self) -> usize {
        match self {
            QuantMatrix::F16 { cols, .. } | QuantMatrix::Int8 { cols, .. } => *cols,
        }
    }

    /// The format this matrix is stored in.
    pub fn spec(&self) -> QuantSpec {
        match self {
            QuantMatrix::F16 { .. } => QuantSpec::F16,
            QuantMatrix::Int8 { .. } => QuantSpec::Int8,
        }
    }

    /// Stored bytes (elements + scales), for footprint reports.
    pub fn bytes(&self) -> usize {
        match self {
            QuantMatrix::F16 { data, .. } => data.len() * 2,
            QuantMatrix::Int8 { data, scales, .. } => data.len() + scales.len() * 4,
        }
    }
}

/// One cached activation row stored quantized (the reuse-cache storage
/// format when `SessionBuilder::quantize` is set). Int8 uses a single
/// per-row max-abs scale — activation rows are produced by one node's
/// projection, so their dynamic range is narrow.
#[derive(Debug, Clone)]
pub enum QuantRow {
    /// binary16 elements.
    F16(Vec<u16>),
    /// Symmetric int8 elements with one per-row scale.
    Int8 {
        /// Quantized elements.
        data: Vec<i8>,
        /// Dequantization scale for the whole row.
        scale: f32,
    },
}

impl QuantRow {
    /// Quantize one row under `spec`.
    pub fn quantize(row: &[f32], spec: QuantSpec) -> QuantRow {
        match spec {
            QuantSpec::F16 => {
                QuantRow::F16(row.iter().map(|&v| f32_to_f16_bits(v)).collect())
            }
            QuantSpec::Int8 => {
                let max_abs = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let scale = int8_scale(max_abs);
                QuantRow::Int8 {
                    data: row.iter().map(|&v| int8_quantize(v, scale)).collect(),
                    scale,
                }
            }
        }
    }

    /// Dequantize into `out` (cleared first).
    pub fn dequantize_into(&self, out: &mut Vec<f32>) {
        out.clear();
        match self {
            QuantRow::F16(data) => out.extend(data.iter().map(|&b| f16_bits_to_f32(b))),
            QuantRow::Int8 { data, scale } => {
                out.extend(data.iter().map(|&q| q as f32 * *scale))
            }
        }
    }

    /// Element count of the row.
    pub fn len(&self) -> usize {
        match self {
            QuantRow::F16(data) => data.len(),
            QuantRow::Int8 { data, .. } => data.len(),
        }
    }

    /// True when the row has no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Stored bytes (elements + scale).
    pub fn bytes(&self) -> usize {
        match self {
            QuantRow::F16(data) => data.len() * 2,
            QuantRow::Int8 { data, .. } => data.len() + 4,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn f16_roundtrip_is_identity_for_all_bit_patterns() {
        // every binary16 value is exactly representable in f32, so
        // f16 → f32 → f16 must reproduce the original bits — including
        // ±0, ±inf, subnormals and NaNs (payload shifted up then down).
        for h in 0..=u16::MAX {
            let back = f32_to_f16_bits(f16_bits_to_f32(h));
            assert_eq!(back, h, "bits {h:#06x}");
        }
    }

    #[test]
    fn f32_to_f16_rounds_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and the next half
        // (1 + 2^-10); ties go to the even mantissa (1.0).
        let halfway = 1.0 + (2f32).powi(-11);
        assert_eq!(f16_roundtrip(halfway), 1.0);
        // one ulp above halfway rounds up
        let above = f32::from_bits(halfway.to_bits() + 1);
        assert_eq!(f16_roundtrip(above), 1.0 + (2f32).powi(-10));
        // overflow saturates to inf, sign preserved
        assert_eq!(f16_roundtrip(70000.0), f32::INFINITY);
        assert_eq!(f16_roundtrip(-70000.0), f32::NEG_INFINITY);
        // underflow hits signed zero
        assert_eq!(f16_roundtrip(1e-9).to_bits(), 0.0f32.to_bits());
        assert_eq!(f16_roundtrip(-1e-9).to_bits(), (-0.0f32).to_bits());
        assert!(f16_roundtrip(f32::NAN).is_nan());
    }

    #[test]
    fn f16_relative_error_bounded() {
        let mut rng = Pcg32::seeded(7);
        let t = Tensor::randn(40, 17, 3.0, &mut rng);
        for &v in t.as_slice() {
            let r = f16_roundtrip(v);
            let err = (r - v).abs();
            // binary16 has 11 significand bits → rel err ≤ 2^-11
            assert!(err <= v.abs() * 4.9e-4 + 1e-7, "{v} → {r}");
        }
    }

    #[test]
    fn int8_matrix_error_bounded_by_half_step_per_column() {
        let mut rng = Pcg32::seeded(11);
        let t = Tensor::randn(33, 9, 1.5, &mut rng);
        let q = QuantMatrix::quantize(&t, QuantSpec::Int8);
        let d = q.dequantize();
        assert_eq!(d.shape(), t.shape());
        // per-column max-abs scale → error ≤ scale/2 everywhere
        let scales = match &q {
            QuantMatrix::Int8 { scales, .. } => scales.clone(),
            _ => unreachable!(),
        };
        for r in 0..t.rows() {
            for c in 0..t.cols() {
                let err = (d.get(r, c) - t.get(r, c)).abs();
                assert!(err <= scales[c] * 0.5 + 1e-6, "({r},{c}): err {err}");
            }
        }
    }

    #[test]
    fn int8_all_zero_column_is_exact() {
        let t = Tensor::from_vec(3, 2, vec![0.0, 1.0, 0.0, -2.0, 0.0, 0.5]).unwrap();
        let q = QuantMatrix::quantize(&t, QuantSpec::Int8);
        let d = q.dequantize();
        for r in 0..3 {
            assert_eq!(d.get(r, 0), 0.0);
        }
    }

    #[test]
    fn quant_matrix_metadata_and_bytes() {
        let t = Tensor::full(6, 5, 0.25);
        let f = QuantMatrix::quantize(&t, QuantSpec::F16);
        assert_eq!((f.rows(), f.cols()), (6, 5));
        assert_eq!(f.spec(), QuantSpec::F16);
        assert_eq!(f.bytes(), 6 * 5 * 2);
        let i = QuantMatrix::quantize(&t, QuantSpec::Int8);
        assert_eq!(i.spec(), QuantSpec::Int8);
        assert_eq!(i.bytes(), 6 * 5 + 5 * 4);
        // 0.25 everywhere survives both formats exactly (power of two /
        // full-scale point)
        assert!(f.dequantize().allclose(&t, 0.0, 0.0));
        assert!(i.dequantize().allclose(&t, 1e-7, 0.0));
    }

    #[test]
    fn quant_row_roundtrip_error_bounded() {
        let mut rng = Pcg32::seeded(23);
        let t = Tensor::randn(1, 67, 2.0, &mut rng);
        let row = t.as_slice();
        let max_abs = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let mut dq = Vec::new();
        let q8 = QuantRow::quantize(row, QuantSpec::Int8);
        assert_eq!(q8.len(), 67);
        assert!(!q8.is_empty());
        assert_eq!(q8.bytes(), 67 + 4);
        q8.dequantize_into(&mut dq);
        for (&v, &d) in row.iter().zip(&dq) {
            assert!((v - d).abs() <= max_abs / 127.0 * 0.5 + 1e-6);
        }
        let qh = QuantRow::quantize(row, QuantSpec::F16);
        assert_eq!(qh.bytes(), 67 * 2);
        qh.dequantize_into(&mut dq);
        for (&v, &d) in row.iter().zip(&dq) {
            assert!((v - d).abs() <= v.abs() * 4.9e-4 + 1e-7);
        }
    }

    #[test]
    fn spec_parse_and_names() {
        assert_eq!(QuantSpec::parse("f16"), Some(QuantSpec::F16));
        assert_eq!(QuantSpec::parse("int8"), Some(QuantSpec::Int8));
        assert_eq!(QuantSpec::parse("fp16"), None);
        assert_eq!(QuantSpec::parse("true"), None);
        assert_eq!(QuantSpec::F16.name(), "f16");
        assert_eq!(QuantSpec::Int8.name(), "int8");
        assert_eq!(QuantSpec::F16.bytes_per_element(), 2);
        assert_eq!(QuantSpec::Int8.bytes_per_element(), 1);
    }
}
