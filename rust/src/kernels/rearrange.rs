//! DR-Type kernels: data rearrangement.
//!
//! The paper singles out `CatArrayBatchedCopy` (`Concat`) as an expensive
//! pure-data-movement kernel: Semantic Aggregation concatenates the P
//! per-metapath result matrices into one `[P*N, F]` batch so the
//! attention weights can be computed with a single batched `sgemm`
//! (17.5% of SA time on HAN-DBLP, 81.6% DRAM BW utilization — Table 3).

use crate::kernels::{timed, Ctx, KernelCounters, KernelType};
use crate::tensor::Tensor;
use crate::{Error, Result};

/// `Concat`: stack matrices vertically into one `[Σrows, F]` matrix.
pub fn concat_rows(ctx: &mut Ctx, parts: &[&Tensor]) -> Result<Tensor> {
    if parts.is_empty() {
        return Err(Error::shape("Concat of zero tensors"));
    }
    let f = parts[0].cols();
    for p in parts {
        if p.cols() != f {
            return Err(Error::shape(format!("Concat cols {} vs {}", p.cols(), f)));
        }
    }
    let (out, nanos) = timed(|| {
        let rows: usize = parts.iter().map(|p| p.rows()).sum();
        let mut out = Tensor::zeros(rows, f);
        let mut at = 0usize;
        for p in parts {
            let n = p.rows() * f;
            out.as_mut_slice()[at..at + n].copy_from_slice(p.as_slice());
            at += n;
        }
        out
    });
    let total = out.len() as u64;
    let counters = KernelCounters {
        flops: 0,
        bytes_read: total * 4,
        bytes_written: total * 4,
    };
    ctx.push("Concat", KernelType::DataRearrange, counters, nanos, None);
    Ok(out)
}

/// Split a stacked `[P*N, F]` matrix back into `P` views of `[N, F]`
/// (the inverse rearrangement before the weighted semantic reduction).
pub fn split_rows(ctx: &mut Ctx, x: &Tensor, parts: usize) -> Result<Vec<Tensor>> {
    if parts == 0 || x.rows() % parts != 0 {
        return Err(Error::shape(format!(
            "split: {} rows not divisible by {}",
            x.rows(),
            parts
        )));
    }
    let n = x.rows() / parts;
    let (out, nanos) = timed(|| {
        (0..parts)
            .map(|p| x.slice_rows(p * n, (p + 1) * n).expect("in-bounds"))
            .collect::<Vec<Tensor>>()
    });
    let total = x.len() as u64;
    let counters =
        KernelCounters { flops: 0, bytes_read: total * 4, bytes_written: total * 4 };
    ctx.push("Concat", KernelType::DataRearrange, counters, nanos, None);
    Ok(out)
}

/// Gather rows by index (`IndexSelect`): used when a stage reorders node
/// features (e.g. MAGNN's metapath-instance batching). Parallel over
/// output-row blocks. Bounds checks are hoisted into one validation
/// pass, and runs of **consecutive ascending** indices — the common
/// case for CSR-derived gather lists like MAGNN's per-edge endpoint
/// rows — collapse into a single multi-row `copy_from_slice`, so the
/// copy loop runs at memcpy speed instead of once per row (a pure copy
/// either way, so trivially bit-identical at every thread count).
pub fn index_select(ctx: &mut Ctx, x: &Tensor, idx: &[u32]) -> Result<Tensor> {
    let f = x.cols();
    for &i in idx {
        if i as usize >= x.rows() {
            return Err(Error::shape(format!("index {i} out of {} rows", x.rows())));
        }
    }
    let t0 = std::time::Instant::now();
    // every output row is overwritten below, so skip the zero-fill pass
    let mut out = ctx.scratch_any(idx.len(), f);
    if f > 0 {
        let xs = x.as_slice();
        crate::parallel::parallel_chunks_mut(out.as_mut_slice(), f, 64, |r0, block| {
            let ids = &idx[r0..r0 + block.len() / f];
            let mut r = 0usize;
            while r < ids.len() {
                let start = ids[r] as usize;
                let mut len = 1usize;
                while r + len < ids.len() && ids[r + len] as usize == start + len {
                    len += 1;
                }
                block[r * f..(r + len) * f]
                    .copy_from_slice(&xs[start * f..(start + len) * f]);
                r += len;
            }
        });
    }
    let nanos = t0.elapsed().as_nanos() as u64;
    let total = out.len() as u64;
    let counters = KernelCounters {
        flops: 0,
        bytes_read: total * 4 + idx.len() as u64 * 4,
        bytes_written: total * 4,
    };
    // conditional so the profiling-off hot path skips the index clone
    let trace = ctx
        .record_traces
        .then(|| crate::kernels::GatherTrace { row_bytes: (f * 4) as u32, rows: idx.to_vec() });
    ctx.push("IndexSelect", KernelType::DataRearrange, counters, nanos, trace);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concat_and_split_roundtrip() {
        let mut ctx = Ctx::default();
        let a = Tensor::full(2, 3, 1.0);
        let b = Tensor::full(2, 3, 2.0);
        let cat = concat_rows(&mut ctx, &[&a, &b]).unwrap();
        assert_eq!(cat.shape(), (4, 3));
        assert_eq!(cat.get(3, 0), 2.0);
        let parts = split_rows(&mut ctx, &cat, 2).unwrap();
        assert!(parts[0].allclose(&a, 0.0, 0.0));
        assert!(parts[1].allclose(&b, 0.0, 0.0));
        assert_eq!(ctx.events.len(), 2);
        assert!(ctx.events.iter().all(|e| e.ktype == KernelType::DataRearrange));
    }

    #[test]
    fn concat_validates() {
        let mut ctx = Ctx::default();
        assert!(concat_rows(&mut ctx, &[]).is_err());
        let a = Tensor::zeros(1, 2);
        let b = Tensor::zeros(1, 3);
        assert!(concat_rows(&mut ctx, &[&a, &b]).is_err());
    }

    #[test]
    fn split_validates() {
        let mut ctx = Ctx::default();
        let x = Tensor::zeros(5, 2);
        assert!(split_rows(&mut ctx, &x, 2).is_err());
        assert!(split_rows(&mut ctx, &x, 0).is_err());
    }

    #[test]
    fn index_select_gathers() {
        let mut ctx = Ctx::with_traces();
        let x = Tensor::from_vec(3, 2, vec![0., 0., 1., 1., 2., 2.]).unwrap();
        let out = index_select(&mut ctx, &x, &[2, 0, 2]).unwrap();
        assert_eq!(out.row(0), &[2.0, 2.0]);
        assert_eq!(out.row(1), &[0.0, 0.0]);
        assert_eq!(out.row(2), &[2.0, 2.0]);
        assert!(ctx.events[0].trace.is_some());
        assert!(index_select(&mut ctx, &x, &[3]).is_err());
    }

    #[test]
    fn index_select_run_batching_matches_per_row_oracle() {
        // ascending runs, repeats, descending jumps and singletons all
        // hit the run-collapsing copy; compare to a per-row gather
        let mut ctx = Ctx::default();
        let x = Tensor::from_vec(6, 3, (0..18).map(|v| v as f32).collect::<Vec<f32>>()).unwrap();
        let idx: Vec<u32> = vec![0, 1, 2, 2, 3, 5, 4, 3, 0, 1, 1, 2];
        let out = index_select(&mut ctx, &x, &idx).unwrap();
        assert_eq!(out.shape(), (idx.len(), 3));
        for (r, &i) in idx.iter().enumerate() {
            assert_eq!(out.row(r), x.row(i as usize), "row {r} (index {i})");
        }
        // trace stays zero-cost with profiling off
        assert!(ctx.events[0].trace.is_none());
    }

    #[test]
    fn concat_counts_pure_movement() {
        let mut ctx = Ctx::default();
        let a = Tensor::zeros(4, 4);
        concat_rows(&mut ctx, &[&a]).unwrap();
        let e = &ctx.events[0];
        assert_eq!(e.counters.flops, 0);
        assert_eq!(e.counters.bytes_read, 64);
        assert_eq!(e.counters.bytes_written, 64);
    }
}
