//! Explicit-width SIMD microkernel helpers.
//!
//! Every inner loop in the hot kernels ([`crate::kernels::dense`],
//! [`crate::kernels::sparse_ops`], [`crate::engine::stages`]) funnels
//! through the primitives here. They are written in the
//! fixed-lane-array style that stable Rust's autovectorizer compiles to
//! packed SIMD without any `std::arch` intrinsics or nightly features:
//! the slice is walked in [`LANES`]-wide chunks via `chunks_exact`, each
//! chunk is processed through a `[f32; LANES]` temporary with one
//! straight-line operation per lane, and the sub-lane tail falls back to
//! the scalar loop.
//!
//! # Bit-identity contract
//!
//! [`axpy`], [`axpy2`], [`add_assign`] and [`scale`] are **element-wise**:
//! every output element is produced by exactly the same float operations,
//! in the same per-element order, as the scalar loop they replace
//! (`out[i] += s * x[i]` etc.). Lanes never exchange values, so the
//! results are bit-identical to the scalar path at every slice length —
//! including lengths that are not multiples of [`LANES`] — and therefore
//! at every `--threads` / `--shards` setting. The integration suite
//! (`tests/integration_simd.rs`) pins this with `allclose(_, 0.0, 0.0)`
//! against serial oracles.
//!
//! [`dot_tree`] is the one horizontal reduction: it keeps [`LANES`]
//! partial sums and folds them through a fixed pairwise tree, so the
//! result is deterministic (identical on every run and thread count) but
//! **not** bit-identical to a sequential left-to-right sum — use it only
//! where the consumer tolerates reassociation, e.g. the quantized-path
//! diagnostics.

/// Vector width of the lane-array temporaries: 8 × f32 = 256 bits, one
/// AVX2 register, two NEON registers. Not a tuning knob for callers —
/// the tail loops make every slice length correct regardless.
pub const LANES: usize = 8;

/// `out[i] += s * x[i]` — the axpy inner loop of sgemm panels and
/// weighted SpMM rows. Bit-identical to the scalar loop (see module
/// docs). Panics in debug builds if lengths differ; in release the
/// shorter slice bounds the work.
#[inline]
pub fn axpy(out: &mut [f32], s: f32, x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    let mut oc = out.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (o, v) in oc.by_ref().zip(xc.by_ref()) {
        let mut lane = [0.0f32; LANES];
        for (l, &b) in lane.iter_mut().zip(v) {
            *l = s * b;
        }
        for (o, l) in o.iter_mut().zip(lane) {
            *o += l;
        }
    }
    for (o, &b) in oc.into_remainder().iter_mut().zip(xc.remainder()) {
        *o += s * b;
    }
}

/// Two-row axpy sharing one loaded `x` chunk:
/// `o0[i] += s0 * x[i]; o1[i] += s1 * x[i]` — the register-blocked
/// (2-row) sgemm panel core, halving B-row traffic versus two [`axpy`]
/// calls. Bit-identical to the scalar pair loop.
#[inline]
pub fn axpy2(o0: &mut [f32], o1: &mut [f32], s0: f32, s1: f32, x: &[f32]) {
    debug_assert_eq!(o0.len(), x.len());
    debug_assert_eq!(o1.len(), x.len());
    let mut c0 = o0.chunks_exact_mut(LANES);
    let mut c1 = o1.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for ((a, b), v) in c0.by_ref().zip(c1.by_ref()).zip(xc.by_ref()) {
        let mut l0 = [0.0f32; LANES];
        let mut l1 = [0.0f32; LANES];
        for ((p, q), &b) in l0.iter_mut().zip(l1.iter_mut()).zip(v) {
            *p = s0 * b;
            *q = s1 * b;
        }
        for ((x0, x1), (p, q)) in a.iter_mut().zip(b.iter_mut()).zip(l0.into_iter().zip(l1)) {
            *x0 += p;
            *x1 += q;
        }
    }
    for ((x0, x1), &b) in c0
        .into_remainder()
        .iter_mut()
        .zip(c1.into_remainder().iter_mut())
        .zip(xc.remainder())
    {
        *x0 += s0 * b;
        *x1 += s1 * b;
    }
}

/// `out[i] += x[i]` — the unweighted accumulate of `SpMMCsr` sum/mean
/// rows and `segment_sum_edges`. Bit-identical to the scalar loop.
#[inline]
pub fn add_assign(out: &mut [f32], x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    let mut oc = out.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (o, v) in oc.by_ref().zip(xc.by_ref()) {
        let mut lane = [0.0f32; LANES];
        for (l, &b) in lane.iter_mut().zip(v) {
            *l = b;
        }
        for (o, l) in o.iter_mut().zip(lane) {
            *o += l;
        }
    }
    for (o, &b) in oc.into_remainder().iter_mut().zip(xc.remainder()) {
        *o += b;
    }
}

/// `out[i] *= s` — the mean-rescale pass of `SpMMCsr`. Bit-identical to
/// the scalar loop.
#[inline]
pub fn scale(out: &mut [f32], s: f32) {
    let mut oc = out.chunks_exact_mut(LANES);
    for o in oc.by_ref() {
        for v in o.iter_mut() {
            *v *= s;
        }
    }
    for v in oc.into_remainder().iter_mut() {
        *v *= s;
    }
}

/// Dot product with a deterministic reduction tree: [`LANES`] lane
/// accumulators (`acc[l] += a[i] * b[i]` with `l = i % LANES`), folded
/// pairwise `(0+4)+(2+6)` / `(1+5)+(3+7)`, scalar tail added last. The
/// result is identical on every run and thread count, but reassociated
/// relative to a sequential sum — reserve it for paths that already
/// tolerate rounding (quantized diagnostics, bench verdicts), never for
/// the bit-identity-pinned f32 kernels.
#[inline]
pub fn dot_tree(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; LANES];
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    for (av, bv) in ac.by_ref().zip(bc.by_ref()) {
        for ((s, &x), &y) in acc.iter_mut().zip(av).zip(bv) {
            *s += x * y;
        }
    }
    let mut tail = 0.0f32;
    for (&x, &y) in ac.remainder().iter().zip(bc.remainder()) {
        tail += x * y;
    }
    let q0 = (acc[0] + acc[4]) + (acc[2] + acc[6]);
    let q1 = (acc[1] + acc[5]) + (acc[3] + acc[7]);
    (q0 + q1) + tail
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(n: usize, base: f32) -> Vec<f32> {
        (0..n).map(|i| base + (i as f32) * 0.37 - (i % 5) as f32).collect()
    }

    #[test]
    fn axpy_bit_identical_to_scalar_all_lengths() {
        for n in [0, 1, 7, 8, 9, 15, 16, 17, 63, 64, 100] {
            let x = seq(n, 0.5);
            let mut got = seq(n, -2.0);
            let mut want = got.clone();
            axpy(&mut got, 1.7, &x);
            for (o, &b) in want.iter_mut().zip(&x) {
                *o += 1.7 * b;
            }
            assert_eq!(got, want, "n={n}");
        }
    }

    #[test]
    fn axpy2_bit_identical_to_scalar_pair() {
        for n in [0, 3, 8, 13, 16, 29] {
            let x = seq(n, 1.25);
            let mut g0 = seq(n, 4.0);
            let mut g1 = seq(n, -1.0);
            let mut w0 = g0.clone();
            let mut w1 = g1.clone();
            axpy2(&mut g0, &mut g1, 0.3, -2.5, &x);
            for ((a, b), &v) in w0.iter_mut().zip(w1.iter_mut()).zip(&x) {
                *a += 0.3 * v;
                *b += -2.5 * v;
            }
            assert_eq!(g0, w0, "n={n}");
            assert_eq!(g1, w1, "n={n}");
        }
    }

    #[test]
    fn axpy2_matches_two_axpys_bitwise() {
        // the 2-row core must produce exactly what two 1-row calls do
        let x = seq(21, 0.75);
        let (mut a0, mut a1) = (seq(21, 2.0), seq(21, 3.0));
        let (mut b0, mut b1) = (a0.clone(), a1.clone());
        axpy2(&mut a0, &mut a1, 1.1, -0.4, &x);
        axpy(&mut b0, 1.1, &x);
        axpy(&mut b1, -0.4, &x);
        assert_eq!(a0, b0);
        assert_eq!(a1, b1);
    }

    #[test]
    fn add_assign_and_scale_bit_identical() {
        for n in [0, 5, 8, 19, 32] {
            let x = seq(n, -0.5);
            let mut got = seq(n, 9.0);
            let mut want = got.clone();
            add_assign(&mut got, &x);
            for (o, &v) in want.iter_mut().zip(&x) {
                *o += v;
            }
            assert_eq!(got, want, "add n={n}");
            scale(&mut got, 0.125);
            for v in want.iter_mut() {
                *v *= 0.125;
            }
            assert_eq!(got, want, "scale n={n}");
        }
    }

    #[test]
    fn dot_tree_deterministic_and_close() {
        let a = seq(1003, 0.1);
        let b = seq(1003, -0.2);
        let d1 = dot_tree(&a, &b);
        let d2 = dot_tree(&a, &b);
        assert_eq!(d1, d2, "tree reduction must be run-to-run deterministic");
        let serial: f32 = a.iter().zip(&b).map(|(&x, &y)| x * y).sum();
        let denom = serial.abs().max(1.0);
        assert!((d1 - serial).abs() / denom < 1e-4, "tree {d1} vs serial {serial}");
    }

    #[test]
    fn dot_tree_short_inputs() {
        assert_eq!(dot_tree(&[], &[]), 0.0);
        assert_eq!(dot_tree(&[2.0], &[3.0]), 6.0);
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert_eq!(dot_tree(&a, &b), 32.0);
    }
}
