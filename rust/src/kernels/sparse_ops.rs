//! TB-Type kernels: graph-topology-based operations.
//!
//! These are the paper's Neighbor Aggregation hot-spots:
//!
//! * [`spmm_csr`] — `SpMMCsr`: per destination node, reduce the feature
//!   vectors of its (possibly weighted) neighbors. 85.9% of NA time for
//!   HAN-DBLP (Table 3). Memory-bound, irregular gathers.
//! * [`sddmm_coo`] — `SDDMMCoo`: per edge, combine per-node left/right
//!   attention terms into an edge logit (GAT's `leakyrelu(a_l·h_i +
//!   a_r·h_j)` after the dot products are hoisted into dense matvecs).
//! * [`edge_softmax`] — per destination node, softmax over incident edge
//!   logits (DGL's edge_softmax; topology-indexed like SpMM).
//!
//! Each kernel emits a [`GatherTrace`] of the feature/vector rows it
//! gathers, in access order, for the T4 L2 model.

use crate::graph::sparse::Csr;
use crate::kernels::{simd, timed, Ctx, GatherTrace, KernelCounters, KernelType};
use crate::parallel;
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Reduction semantics for [`spmm_csr`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpmmReduce {
    /// Plain sum of neighbor features.
    Sum,
    /// Degree-normalized mean (R-GCN's neighbor aggregation).
    Mean,
}

/// `SpMMCsr`: `out[d] = reduce_{s in N(d)} w[e] * x[s]`.
///
/// `edge_weights`, when given, must have one weight per nonzero in CSR
/// order (attention-weighted aggregation, HAN/MAGNN); otherwise weights
/// are implicitly 1 (R-GCN sum/mean).
pub fn spmm_csr(
    ctx: &mut Ctx,
    adj: &Csr,
    x: &Tensor,
    edge_weights: Option<&[f32]>,
    reduce: SpmmReduce,
) -> Result<Tensor> {
    if adj.n_cols != x.rows() {
        return Err(Error::shape(format!(
            "spmm: adj {}x{} vs x {}x{}",
            adj.n_rows,
            adj.n_cols,
            x.rows(),
            x.cols()
        )));
    }
    if let Some(w) = edge_weights {
        if w.len() != adj.nnz() {
            return Err(Error::shape(format!(
                "spmm: {} edge weights for {} nonzeros",
                w.len(),
                adj.nnz()
            )));
        }
    }
    let f = x.cols();
    let n = adj.n_rows;
    // parallel over destination-row blocks: each destination row's
    // per-edge accumulation order is exactly the serial loop's, so
    // parallel output is bit-identical to serial at every thread count
    let t0 = std::time::Instant::now();
    let mut out = ctx.scratch_zeros(n, f);
    if f > 0 {
        let xs = x.as_slice();
        parallel::parallel_chunks_mut(out.as_mut_slice(), f, 32, |d0, block| {
            for (r, orow) in block.chunks_mut(f).enumerate() {
                let d = d0 + r;
                let row = adj.row(d);
                if row.is_empty() {
                    continue;
                }
                let lo = adj.indptr[d] as usize;
                match edge_weights {
                    Some(w) => {
                        for (j, &s) in row.iter().enumerate() {
                            let wv = w[lo + j];
                            let src = &xs[s as usize * f..(s as usize + 1) * f];
                            simd::axpy(orow, wv, src);
                        }
                    }
                    None => {
                        for &s in row {
                            let src = &xs[s as usize * f..(s as usize + 1) * f];
                            simd::add_assign(orow, src);
                        }
                    }
                }
                if reduce == SpmmReduce::Mean {
                    simd::scale(orow, 1.0 / row.len() as f32);
                }
            }
        });
    }
    let nanos = t0.elapsed().as_nanos() as u64;

    let nnz = adj.nnz() as u64;
    let weight_flops = if edge_weights.is_some() { nnz * f as u64 } else { 0 };
    let mean_flops = if reduce == SpmmReduce::Mean { (n * f) as u64 } else { 0 };
    let counters = KernelCounters {
        // adds per gathered element (+ mul when weighted, + mean scale)
        flops: nnz * f as u64 + weight_flops + mean_flops,
        // gathered rows + indptr/indices + weights, written output once
        bytes_read: nnz * (f as u64 * 4)
            + (adj.indptr.len() + adj.indices.len()) as u64 * 4
            + edge_weights.map(|w| w.len() as u64 * 4).unwrap_or(0),
        bytes_written: (n * f) as u64 * 4,
    };
    // trace capture is conditional so the profiling-off hot path never
    // pays the indices clone
    let trace = ctx
        .record_traces
        .then(|| GatherTrace { row_bytes: (f * 4) as u32, rows: adj.indices.clone() });
    ctx.push("SpMMCsr", KernelType::TopologyBased, counters, nanos, trace);
    Ok(out)
}

/// `SDDMMCoo`: edge logits `e = leakyrelu(s_dst[d] + s_src[s])` for every
/// nonzero `(d, s)`, where `s_dst`/`s_src` are per-node attention terms
/// (GAT's `a_l·h` and `a_r·h`, computed beforehand as DM kernels).
/// Returns one logit per nonzero in CSR order.
pub fn sddmm_coo(
    ctx: &mut Ctx,
    adj: &Csr,
    s_dst: &[f32],
    s_src: &[f32],
    negative_slope: f32,
) -> Result<Vec<f32>> {
    if s_dst.len() != adj.n_rows || s_src.len() != adj.n_cols {
        return Err(Error::shape(format!(
            "sddmm: terms {}/{} vs adj {}x{}",
            s_dst.len(),
            s_src.len(),
            adj.n_rows,
            adj.n_cols
        )));
    }
    let (logits, nanos) = timed(|| {
        let mut logits = Vec::with_capacity(adj.nnz());
        for d in 0..adj.n_rows {
            let sd = s_dst[d];
            for &s in adj.row(d) {
                let v = sd + s_src[s as usize];
                logits.push(if v >= 0.0 { v } else { negative_slope * v });
            }
        }
        logits
    });
    let nnz = adj.nnz() as u64;
    let counters = KernelCounters {
        flops: 2 * nnz, // add + leaky-relu mul
        bytes_read: nnz * 4 * 2 + (adj.indptr.len() + adj.indices.len()) as u64 * 4,
        bytes_written: nnz * 4,
    };
    // the irregular stream is the s_src gather (s_dst is sequential);
    // rows are 4-byte scalars. Conditional for the same reason as SpMM.
    let trace =
        ctx.record_traces.then(|| GatherTrace { row_bytes: 4, rows: adj.indices.clone() });
    ctx.push("SDDMMCoo", KernelType::TopologyBased, counters, nanos, trace);
    Ok(logits)
}

/// DGL-style `edge_softmax`: normalize edge logits over each destination
/// node's incident edges. Input/output in CSR nonzero order.
pub fn edge_softmax(ctx: &mut Ctx, adj: &Csr, logits: &[f32]) -> Result<Vec<f32>> {
    if logits.len() != adj.nnz() {
        return Err(Error::shape(format!(
            "edge_softmax: {} logits for {} nonzeros",
            logits.len(),
            adj.nnz()
        )));
    }
    let (weights, nanos) = timed(|| {
        let mut out = vec![0.0f32; logits.len()];
        for d in 0..adj.n_rows {
            let lo = adj.indptr[d] as usize;
            let hi = adj.indptr[d + 1] as usize;
            if lo == hi {
                continue;
            }
            let seg = &logits[lo..hi];
            let maxv = seg.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut denom = 0.0f32;
            for (j, &v) in seg.iter().enumerate() {
                let e = (v - maxv).exp();
                out[lo + j] = e;
                denom += e;
            }
            let inv = 1.0 / denom;
            for o in &mut out[lo..hi] {
                *o *= inv;
            }
        }
        out
    });
    let nnz = adj.nnz() as u64;
    let counters = KernelCounters {
        // max scan + exp + sum + scale ≈ 4 ops per element
        flops: 4 * nnz,
        bytes_read: nnz * 4 + adj.indptr.len() as u64 * 4,
        bytes_written: nnz * 4,
    };
    ctx.push("edge_softmax", KernelType::TopologyBased, counters, nanos, None);
    Ok(weights)
}

/// Permutation mapping CSR nonzero order into the transposed CSR's
/// nonzero order: original nonzero `e` of `adj` lands in slot `perm[e]`
/// of `adj.transposed()`. Mirrors the counting-sort cursor walk of
/// [`Csr::transposed`], so per-edge values (attention weights, edge
/// gradients) can ride along with the topology through the backward
/// pass's grad-SpMM: `w_t[perm[e]] = w[e]`.
pub fn transpose_edge_perm(adj: &Csr) -> Vec<u32> {
    let mut cursor = vec![0u32; adj.n_cols + 1];
    for &c in &adj.indices {
        cursor[c as usize + 1] += 1;
    }
    for i in 0..adj.n_cols {
        cursor[i + 1] += cursor[i];
    }
    let mut perm = vec![0u32; adj.nnz()];
    let mut e = 0usize;
    for r in 0..adj.n_rows {
        for &c in adj.row(r) {
            perm[e] = cursor[c as usize];
            cursor[c as usize] += 1;
            e += 1;
        }
    }
    perm
}

/// `SDDMMCoo` (gradient flavor): per-edge dot product between the
/// destination node's row of `dst_feats` and the edge's own row of
/// `edge_feats` — the attention-weight gradient `dα_e = ⟨dAgg[d_e],
/// φ_e⟩` that the training-characterization work (arxiv 2407.11790)
/// identifies as the SDDMM-shaped hot-spot of attention backward.
/// Returns one scalar per nonzero in CSR order.
pub fn sddmm_edge_dot(
    ctx: &mut Ctx,
    adj: &Csr,
    dst_feats: &Tensor,
    edge_feats: &Tensor,
) -> Result<Vec<f32>> {
    if dst_feats.rows() != adj.n_rows || edge_feats.rows() != adj.nnz() {
        return Err(Error::shape(format!(
            "sddmm_edge_dot: feats {}x{} / edge feats {}x{} vs adj {}x{} ({} nnz)",
            dst_feats.rows(),
            dst_feats.cols(),
            edge_feats.rows(),
            edge_feats.cols(),
            adj.n_rows,
            adj.n_cols,
            adj.nnz()
        )));
    }
    if dst_feats.cols() != edge_feats.cols() {
        return Err(Error::shape(format!(
            "sddmm_edge_dot: {} vs {} feature columns",
            dst_feats.cols(),
            edge_feats.cols()
        )));
    }
    let f = dst_feats.cols();
    let (out, nanos) = timed(|| {
        let mut out = Vec::with_capacity(adj.nnz());
        for d in 0..adj.n_rows {
            let drow = dst_feats.row(d);
            let lo = adj.indptr[d] as usize;
            let hi = adj.indptr[d + 1] as usize;
            for e in lo..hi {
                let erow = edge_feats.row(e);
                let mut acc = 0.0f32;
                for (&x, &y) in drow.iter().zip(erow) {
                    acc += x * y;
                }
                out.push(acc);
            }
        }
        out
    });
    let nnz = adj.nnz() as u64;
    let counters = KernelCounters {
        flops: 2 * nnz * f as u64,
        bytes_read: 2 * nnz * f as u64 * 4 + adj.indptr.len() as u64 * 4,
        bytes_written: nnz * 4,
    };
    ctx.push("SDDMMCoo", KernelType::TopologyBased, counters, nanos, None);
    Ok(out)
}

/// Backward of [`edge_softmax`]: given the forward's outputs `weights`
/// (α, per nonzero in CSR order) and the upstream gradient `d_weights`
/// (dα), produce the logit gradient per destination segment:
/// `dlogit_e = α_e · (dα_e − Σ_{e' ∈ row(d)} α_{e'}·dα_{e'})`.
pub fn edge_softmax_backward(
    ctx: &mut Ctx,
    adj: &Csr,
    weights: &[f32],
    d_weights: &[f32],
) -> Result<Vec<f32>> {
    if weights.len() != adj.nnz() || d_weights.len() != adj.nnz() {
        return Err(Error::shape(format!(
            "edge_softmax_backward: {} weights / {} grads for {} nonzeros",
            weights.len(),
            d_weights.len(),
            adj.nnz()
        )));
    }
    let (out, nanos) = timed(|| {
        let mut out = vec![0.0f32; weights.len()];
        for d in 0..adj.n_rows {
            let lo = adj.indptr[d] as usize;
            let hi = adj.indptr[d + 1] as usize;
            if lo == hi {
                continue;
            }
            let mut dot = 0.0f32;
            for e in lo..hi {
                dot += weights[e] * d_weights[e];
            }
            for e in lo..hi {
                out[e] = weights[e] * (d_weights[e] - dot);
            }
        }
        out
    });
    let nnz = adj.nnz() as u64;
    let counters = KernelCounters {
        // dot (2 ops) + sub + mul per element
        flops: 4 * nnz,
        bytes_read: 2 * nnz * 4 + adj.indptr.len() as u64 * 4,
        bytes_written: nnz * 4,
    };
    ctx.push("edge_softmax", KernelType::TopologyBased, counters, nanos, None);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::sparse::Coo;

    fn adj_3x3() -> Csr {
        // d0 <- {s1, s2}; d1 <- {s0}; d2 <- {}
        Coo::from_edges(3, 3, vec![(0, 1), (0, 2), (1, 0)]).unwrap().to_csr()
    }

    fn feats() -> Tensor {
        Tensor::from_vec(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap()
    }

    #[test]
    fn spmm_sum_and_mean() {
        let mut ctx = Ctx::with_traces();
        let out = spmm_csr(&mut ctx, &adj_3x3(), &feats(), None, SpmmReduce::Sum).unwrap();
        assert_eq!(out.row(0), &[8.0, 10.0]); // x1 + x2
        assert_eq!(out.row(1), &[1.0, 2.0]); // x0
        assert_eq!(out.row(2), &[0.0, 0.0]); // empty

        let mean = spmm_csr(&mut ctx, &adj_3x3(), &feats(), None, SpmmReduce::Mean).unwrap();
        assert_eq!(mean.row(0), &[4.0, 5.0]);
        assert_eq!(mean.row(2), &[0.0, 0.0]);
    }

    #[test]
    fn spmm_weighted() {
        let mut ctx = Ctx::default();
        let w = vec![0.5, 0.25, 2.0];
        let out =
            spmm_csr(&mut ctx, &adj_3x3(), &feats(), Some(&w), SpmmReduce::Sum).unwrap();
        // 0.5*x1 + 0.25*x2 = [1.5+1.25, 2+1.5]
        assert_eq!(out.row(0), &[2.75, 3.5]);
        assert_eq!(out.row(1), &[2.0, 4.0]);
    }

    #[test]
    fn spmm_counters_and_trace() {
        let mut ctx = Ctx::with_traces();
        spmm_csr(&mut ctx, &adj_3x3(), &feats(), None, SpmmReduce::Sum).unwrap();
        let e = &ctx.events[0];
        assert_eq!(e.name, "SpMMCsr");
        assert_eq!(e.ktype, KernelType::TopologyBased);
        assert_eq!(e.counters.flops, 3 * 2); // nnz * f adds
        let t = e.trace.as_ref().unwrap();
        assert_eq!(t.row_bytes, 8);
        assert_eq!(t.rows, vec![1, 2, 0]);
    }

    #[test]
    fn spmm_parallel_bit_identical_to_serial() {
        let mut rng = crate::util::Pcg32::seeded(99);
        let nodes = 300;
        let f = 9;
        let mut edges = Vec::new();
        for d in 0..nodes as u32 {
            for _ in 0..(1 + rng.gen_range(6)) {
                edges.push((d, rng.gen_range(nodes) as u32));
            }
        }
        let adj = Coo::from_edges(nodes, nodes, edges).unwrap().to_csr();
        let x = Tensor::randn(nodes, f, 1.0, &mut rng);
        let w: Vec<f32> = (0..adj.nnz()).map(|_| rng.gen_f32()).collect();
        for weights in [None, Some(w.as_slice())] {
            for reduce in [SpmmReduce::Sum, SpmmReduce::Mean] {
                let serial = crate::parallel::with_threads(1, || {
                    let mut ctx = Ctx::default();
                    spmm_csr(&mut ctx, &adj, &x, weights, reduce).unwrap()
                });
                for t in [2usize, 4] {
                    let par = crate::parallel::with_threads(t, || {
                        let mut ctx = Ctx::default();
                        spmm_csr(&mut ctx, &adj, &x, weights, reduce).unwrap()
                    });
                    assert!(
                        par.allclose(&serial, 0.0, 0.0),
                        "threads {t} not bit-identical (weighted={}, {reduce:?})",
                        weights.is_some()
                    );
                }
            }
        }
    }

    #[test]
    fn trace_clone_skipped_when_profiling_off() {
        // the hot path must not pay the indices clone: with traces off
        // the recorded event carries no trace (and none was built)
        let mut ctx = Ctx::default();
        spmm_csr(&mut ctx, &adj_3x3(), &feats(), None, SpmmReduce::Sum).unwrap();
        sddmm_coo(&mut ctx, &adj_3x3(), &[0.0; 3], &[0.0; 3], 0.1).unwrap();
        assert!(ctx.events.iter().all(|e| e.trace.is_none()));
    }

    #[test]
    fn spmm_shape_checks() {
        let mut ctx = Ctx::default();
        let bad = Tensor::zeros(4, 2);
        assert!(spmm_csr(&mut ctx, &adj_3x3(), &bad, None, SpmmReduce::Sum).is_err());
        let w = vec![1.0; 2];
        assert!(spmm_csr(&mut ctx, &adj_3x3(), &feats(), Some(&w), SpmmReduce::Sum).is_err());
    }

    #[test]
    fn sddmm_leaky() {
        let mut ctx = Ctx::default();
        let s_dst = vec![1.0, -5.0, 0.0];
        let s_src = vec![0.0, 1.0, 2.0];
        let logits = sddmm_coo(&mut ctx, &adj_3x3(), &s_dst, &s_src, 0.1).unwrap();
        // edges: (0,1)=1+1=2; (0,2)=1+2=3; (1,0)=-5+0=-5 -> -0.5
        assert_eq!(logits, vec![2.0, 3.0, -0.5]);
        assert!(sddmm_coo(&mut ctx, &adj_3x3(), &s_dst[..2], &s_src, 0.1).is_err());
    }

    #[test]
    fn edge_softmax_normalizes_per_destination() {
        let mut ctx = Ctx::default();
        let adj = adj_3x3();
        let logits = vec![0.0, 0.0, 3.0];
        let w = edge_softmax(&mut ctx, &adj, &logits).unwrap();
        assert!((w[0] - 0.5).abs() < 1e-6);
        assert!((w[1] - 0.5).abs() < 1e-6);
        assert!((w[2] - 1.0).abs() < 1e-6);
        assert!(edge_softmax(&mut ctx, &adj, &logits[..2]).is_err());
    }

    #[test]
    fn edge_softmax_numerically_stable() {
        let mut ctx = Ctx::default();
        let adj = Coo::from_edges(1, 2, vec![(0, 0), (0, 1)]).unwrap().to_csr();
        let w = edge_softmax(&mut ctx, &adj, &[1000.0, 1000.0]).unwrap();
        assert!((w[0] - 0.5).abs() < 1e-6, "no overflow: {w:?}");
    }

    #[test]
    fn transpose_edge_perm_matches_transposed_csr() {
        // carrying a distinct value per edge through the permutation
        // must land each value on the transposed CSR's matching nonzero
        let mut rng = crate::util::Pcg32::seeded(7);
        let mut edges = Vec::new();
        for d in 0..40u32 {
            for _ in 0..(1 + rng.gen_range(4)) {
                edges.push((d, rng.gen_range(25) as u32));
            }
        }
        let adj = Coo::from_edges(40, 25, edges).unwrap().to_csr();
        let adj_t = adj.transposed();
        let perm = transpose_edge_perm(&adj);
        assert_eq!(perm.len(), adj.nnz());

        // edge e of adj is (d, s); slot perm[e] of adj_t must be (s, d)
        let mut e = 0usize;
        for d in 0..adj.n_rows {
            for &s in adj.row(d) {
                let slot = perm[e] as usize;
                assert_eq!(adj_t.indices[slot], d as u32, "edge {e}");
                let owner = (0..adj_t.n_rows)
                    .find(|&r| {
                        (adj_t.indptr[r] as usize..adj_t.indptr[r + 1] as usize)
                            .contains(&slot)
                    })
                    .unwrap();
                assert_eq!(owner, s as usize, "edge {e}");
                e += 1;
            }
        }
    }

    #[test]
    fn sddmm_edge_dot_values_and_checks() {
        let mut ctx = Ctx::default();
        let adj = adj_3x3();
        // dst rows: d0=[1,0], d1=[0,2], d2=[3,3]
        let dst = Tensor::from_vec(3, 2, vec![1.0, 0.0, 0.0, 2.0, 3.0, 3.0]).unwrap();
        // one row per edge in CSR order: e0=(0,1), e1=(0,2), e2=(1,0)
        let ef = Tensor::from_vec(3, 2, vec![2.0, 5.0, 4.0, 7.0, 1.0, 1.0]).unwrap();
        let dots = sddmm_edge_dot(&mut ctx, &adj, &dst, &ef).unwrap();
        // e0: [1,0]·[2,5]=2; e1: [1,0]·[4,7]=4; e2: [0,2]·[1,1]=2
        assert_eq!(dots, vec![2.0, 4.0, 2.0]);
        assert_eq!(ctx.events[0].name, "SDDMMCoo");
        let bad = Tensor::zeros(2, 2);
        assert!(sddmm_edge_dot(&mut ctx, &adj, &bad, &ef).is_err());
        assert!(sddmm_edge_dot(&mut ctx, &adj, &dst, &bad).is_err());
        let wide = Tensor::zeros(3, 5);
        assert!(sddmm_edge_dot(&mut ctx, &adj, &dst, &wide).is_err());
    }

    #[test]
    fn edge_softmax_backward_matches_finite_difference() {
        let mut ctx = Ctx::default();
        let adj = adj_3x3();
        let logits = vec![0.3, -0.7, 1.2];
        let d_weights = vec![0.9, -0.4, 0.25];
        let alpha = edge_softmax(&mut ctx, &adj, &logits).unwrap();
        let grad = edge_softmax_backward(&mut ctx, &adj, &alpha, &d_weights).unwrap();
        // loss L = Σ d_weights[e] * softmax(logits)[e]; dL/dlogit via FD
        let eps = 1e-3f32;
        for e in 0..logits.len() {
            let mut lp = logits.clone();
            lp[e] += eps;
            let mut lm = logits.clone();
            lm[e] -= eps;
            let wp = edge_softmax(&mut ctx, &adj, &lp).unwrap();
            let wm = edge_softmax(&mut ctx, &adj, &lm).unwrap();
            let lossp: f32 = wp.iter().zip(&d_weights).map(|(w, d)| w * d).sum();
            let lossm: f32 = wm.iter().zip(&d_weights).map(|(w, d)| w * d).sum();
            let fd = (lossp - lossm) / (2.0 * eps);
            assert!(
                (fd - grad[e]).abs() < 1e-3,
                "edge {e}: fd {fd} vs analytic {}",
                grad[e]
            );
        }
        assert!(edge_softmax_backward(&mut ctx, &adj, &alpha[..2], &d_weights).is_err());
        assert!(edge_softmax_backward(&mut ctx, &adj, &alpha, &d_weights[..2]).is_err());
    }

    #[test]
    fn spmm_then_softmax_composes_like_gat() {
        // full GAT edge pipeline on the toy graph: SDDMM -> softmax -> SpMM
        let mut ctx = Ctx::with_traces();
        let adj = adj_3x3();
        let s_dst = vec![0.1, 0.2, 0.3];
        let s_src = vec![0.0, 0.5, 1.0];
        let logits = sddmm_coo(&mut ctx, &adj, &s_dst, &s_src, 0.2).unwrap();
        let w = edge_softmax(&mut ctx, &adj, &logits).unwrap();
        let out = spmm_csr(&mut ctx, &adj, &feats(), Some(&w), SpmmReduce::Sum).unwrap();
        // row 0 is a convex combination of x1 and x2
        assert!(out.get(0, 0) > 3.0 && out.get(0, 0) < 5.0);
        assert_eq!(ctx.events.len(), 3);
    }
}
