//! # hgnn-char
//!
//! A full-stack reproduction of *"Characterizing and Understanding HGNNs on
//! GPUs"* (Yan et al., 2022): heterogeneous-graph neural-network workloads
//! (RGCN, HAN, MAGNN, plus a GCN baseline), the kernel substrate their
//! execution decomposes into (DM / TB / EW / DR kernel types), a
//! trace-driven NVIDIA T4 performance model standing in for Nsight
//! Compute, and a characterization harness that regenerates every figure
//! and table of the paper's evaluation.
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the Rust coordinator: dataset synthesis,
//!   metapath subgraph building, the [`session`] execution surface
//!   (schedule policies over a pluggable backend), the mini-batch
//!   [`sampler`] behind the serving path, the cross-request [`reuse`]
//!   caches for served batches, the profiler and GPU model,
//!   and the PJRT runtime that loads AOT-compiled JAX/Pallas artifacts.
//! * **L2 (`python/compile/model.py`)** — JAX stage functions lowered once
//!   to HLO text (`make artifacts`), never on the request path.
//! * **L1 (`python/compile/kernels/`)** — Pallas kernels for the paper's
//!   hot-spots (tiled matmul, ELL segment-reduce SpMM, SDDMM, segment
//!   softmax), `interpret=True`, validated against pure-jnp oracles.
//!
//! ## Quick start
//!
//! Everything executes through a [`session::Session`]: a builder that
//! composes *dataset × model × backend × schedule × profiling* and owns
//! the graph, plan and all cached state across runs.
//!
//! ```no_run
//! use hgnn_char::prelude::*;
//!
//! // DBLP at the paper's published scale, HAN plan, native backend,
//! // inter-subgraph-parallel schedule, full trace profiling.
//! let mut session = Session::builder()
//!     .dataset(DatasetId::Dblp)
//!     .model(ModelId::Han)
//!     .schedule(SchedulePolicy::InterSubgraphParallel { workers: 4 })
//!     .profiling(Profiling::Traces)
//!     .build()?;
//! let run = session.run()?;
//! println!("{}", run.profile.stage_breakdown());
//! println!("{}", run.report.summary());
//!
//! // Batched serving through the same session state (plan, weights and
//! // compiled artifacts are reused across batches); with a sampling
//! // spec each dispatch executes one sampled metapath neighborhood:
//! let server = Session::builder()
//!     .dataset(DatasetId::Imdb)
//!     .scale(DatasetScale::ci())
//!     .sampling(SamplingSpec::uniform(16, 1))
//!     .serve(ServeConfig::default());
//! let reply = server.submit(42)?;
//! # let _ = reply;
//! # Ok::<(), hgnn_char::Error>(())
//! ```
//!
//! Custom execution strategies implement [`session::ExecBackend`]; the
//! trait contract and migration notes from the old `Engine`/
//! `Coordinator` entry points are documented in `docs/API.md`.
//!
//! ## Features
//!
//! * `pjrt` — links the `xla` crate and enables real PJRT
//!   compilation/execution of the AOT artifacts. Off by default so the
//!   crate builds offline with zero dependencies; without it the PJRT
//!   paths construct and read manifests but report runtime errors on
//!   compile/execute (call sites treat that as "artifacts unavailable").
//! * `cluster-sockets` — a real Unix-socket-pair [`cluster::Transport`]
//!   behind `cli run --cluster N`. Off by default; the deterministic
//!   in-process [`cluster::SimTransport`] needs no feature.

pub mod bench;
pub mod cli;
pub mod cluster;
pub mod coordinator;
pub mod datasets;
pub mod dynamic;
pub mod engine;
pub mod gpumodel;
pub mod graph;
pub mod kernels;
pub mod metapath;
pub mod models;
pub mod parallel;
pub mod partition;
pub mod profiler;
pub mod report;
pub mod reuse;
pub mod runtime;
pub mod sampler;
pub mod serving;
pub mod session;
pub mod tensor;
pub mod testutil;
pub mod train;
pub mod util;

/// Crate-wide error type.
#[derive(Debug)]
pub enum Error {
    /// A dataset, model, metapath or kernel was configured inconsistently.
    Config(String),
    /// Shapes of tensors/graphs fed to a kernel do not line up.
    Shape(String),
    /// A named entity (dataset, node type, artifact, ...) was not found.
    NotFound(String),
    /// PJRT runtime failures (compile/execute/transfer).
    Runtime(String),
    /// Typed serving-runtime failures (admission rejects, deadline
    /// expiry, stopped server) surfaced through the legacy blocking
    /// serve API.
    Serve(serving::ServeError),
    /// I/O failures (artifact files, report output).
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Config(msg) => write!(f, "invalid configuration: {msg}"),
            Error::Shape(msg) => write!(f, "shape mismatch: {msg}"),
            Error::NotFound(msg) => write!(f, "not found: {msg}"),
            Error::Runtime(msg) => write!(f, "runtime: {msg}"),
            Error::Serve(e) => write!(f, "serving: {e}"),
            Error::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            Error::Serve(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::Io(e)
    }
}

impl From<serving::ServeError> for Error {
    fn from(e: serving::ServeError) -> Error {
        Error::Serve(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Helper: configuration error from anything displayable.
    pub fn config(msg: impl std::fmt::Display) -> Self {
        Error::Config(msg.to_string())
    }
    /// Helper: shape error from anything displayable.
    pub fn shape(msg: impl std::fmt::Display) -> Self {
        Error::Shape(msg.to_string())
    }
}

/// Compile the top-level README's code examples as doctests so the
/// quickstart can never drift from the API (`cargo test --doc`).
#[doc = include_str!("../../README.md")]
#[cfg(doctest)]
pub struct ReadmeDoctests;

/// One-stop imports for examples, benches and downstream users.
pub mod prelude {
    pub use crate::cluster::{
        Cluster, ClusterSpec, ClusterStats, FaultSpec, SimTransport, Transport, TransportStats,
    };
    pub use crate::datasets::{self, DatasetId, DatasetScale};
    pub use crate::dynamic::{
        parse_update_stream, DynamicSpec, EpochReport, GraphSnapshot, GraphUpdate,
    };
    pub use crate::gpumodel::{GpuModel, T4Spec};
    pub use crate::graph::{HeteroGraph, NodeTypeId, RelationId};
    pub use crate::metapath::{Metapath, SubgraphSet};
    pub use crate::parallel::{self, PoolStats};
    pub use crate::partition::{Partition, PartitionSpec, ShardMap, ShardingInfo};
    pub use crate::profiler::{Profile, StageId};
    pub use crate::report;
    pub use crate::reuse::{ReuseCache, ReuseSpec, ReuseStats};
    pub use crate::sampler::{NeighborSampler, SampledSubgraph, SamplingSpec};
    pub use crate::serving::{
        AsyncServer, BatchReply, ServeError, ServingConfig, SubmitOpts,
    };
    pub use crate::tensor::Tensor;
    pub use crate::train::{
        EpochStats, FitReport, Optimizer, OptimizerSpec, TrainConfig, Trainer,
    };
    pub use crate::{Error, Result};
    // The execution surface: Session + backends + policies.
    pub use crate::session::*;
    // Legacy shims (Engine / Coordinator) and shared types.
    pub use crate::coordinator::*;
    pub use crate::engine::*;
    pub use crate::models::*;
}

#[cfg(test)]
mod error_tests {
    use super::*;

    #[test]
    fn display_formats() {
        assert_eq!(Error::config("x").to_string(), "invalid configuration: x");
        assert_eq!(Error::shape("y").to_string(), "shape mismatch: y");
        assert_eq!(Error::NotFound("z".into()).to_string(), "not found: z");
        assert_eq!(Error::Runtime("r".into()).to_string(), "runtime: r");
        assert_eq!(
            Error::Serve(serving::ServeError::Stopped).to_string(),
            "serving: server stopped"
        );
    }

    #[test]
    fn serve_conversion_and_source() {
        use std::error::Error as StdError;
        let e: Error = serving::ServeError::QueueFull { queued: 2, cap: 1 }.into();
        assert!(matches!(&e, Error::Serve(_)));
        assert!(e.source().is_some());
    }

    #[test]
    fn io_conversion_and_source() {
        use std::error::Error as StdError;
        let e: Error = std::io::Error::new(std::io::ErrorKind::Other, "boom").into();
        assert!(e.to_string().starts_with("io:"));
        assert!(e.source().is_some());
        assert!(Error::config("c").source().is_none());
    }
}
