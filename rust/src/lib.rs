//! # hgnn-char
//!
//! A full-stack reproduction of *"Characterizing and Understanding HGNNs on
//! GPUs"* (Yan et al., 2022): heterogeneous-graph neural-network workloads
//! (RGCN, HAN, MAGNN, plus a GCN baseline), the kernel substrate their
//! execution decomposes into (DM / TB / EW / DR kernel types), a
//! trace-driven NVIDIA T4 performance model standing in for Nsight
//! Compute, and a characterization harness that regenerates every figure
//! and table of the paper's evaluation.
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the Rust coordinator: dataset synthesis,
//!   metapath subgraph building, the staged execution engine, the
//!   inter-subgraph scheduler, the profiler and GPU model, and the PJRT
//!   runtime that loads AOT-compiled JAX/Pallas artifacts.
//! * **L2 (`python/compile/model.py`)** — JAX stage functions lowered once
//!   to HLO text (`make artifacts`), never on the request path.
//! * **L1 (`python/compile/kernels/`)** — Pallas kernels for the paper's
//!   hot-spots (tiled matmul, ELL segment-reduce SpMM, SDDMM, segment
//!   softmax), `interpret=True`, validated against pure-jnp oracles.
//!
//! ## Quick start
//!
//! ```no_run
//! use hgnn_char::prelude::*;
//! use hgnn_char::{datasets, models};
//!
//! // Build the DBLP heterogeneous graph at the paper's published scale.
//! let hg = datasets::build(DatasetId::Dblp, &DatasetScale::paper()).unwrap();
//! // HAN execution plan: metapath subgraphs + FP/NA/SA stages.
//! let plan = models::han_plan(&hg, &ModelConfig::default()).unwrap();
//! // Run on the native backend with full profiling.
//! let mut engine = Engine::new(Backend::native());
//! let run = engine.run(&plan, &hg).unwrap();
//! println!("{}", run.profile.stage_breakdown());
//! ```

pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod datasets;
pub mod engine;
pub mod gpumodel;
pub mod graph;
pub mod kernels;
pub mod metapath;
pub mod models;
pub mod profiler;
pub mod report;
pub mod runtime;
pub mod tensor;
pub mod testutil;
pub mod util;

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// A dataset, model, metapath or kernel was configured inconsistently.
    #[error("invalid configuration: {0}")]
    Config(String),
    /// Shapes of tensors/graphs fed to a kernel do not line up.
    #[error("shape mismatch: {0}")]
    Shape(String),
    /// A named entity (dataset, node type, artifact, ...) was not found.
    #[error("not found: {0}")]
    NotFound(String),
    /// PJRT runtime failures (compile/execute/transfer).
    #[error("runtime: {0}")]
    Runtime(String),
    /// I/O failures (artifact files, report output).
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Helper: configuration error from anything displayable.
    pub fn config(msg: impl std::fmt::Display) -> Self {
        Error::Config(msg.to_string())
    }
    /// Helper: shape error from anything displayable.
    pub fn shape(msg: impl std::fmt::Display) -> Self {
        Error::Shape(msg.to_string())
    }
}

/// One-stop imports for examples, benches and downstream users.
pub mod prelude {
    pub use crate::datasets::{self, DatasetId, DatasetScale};
    pub use crate::gpumodel::{GpuModel, T4Spec};
    pub use crate::graph::{HeteroGraph, NodeTypeId, RelationId};
    pub use crate::metapath::{Metapath, SubgraphSet};
    pub use crate::profiler::{Profile, StageId};
    pub use crate::report;
    pub use crate::tensor::Tensor;
    pub use crate::{Error, Result};
    // Filled in as the corresponding modules land:
    pub use crate::coordinator::*;
    pub use crate::engine::*;
    pub use crate::models::*;
}
