//! `hgnn-char` — the command-line entry point of the L3 coordinator.
//!
//! See [`hgnn_char::cli::USAGE`] for the command grammar. Every command
//! executes through a [`Session`]: the figure and table commands
//! regenerate the paper's evaluation artifacts from the native substrate
//! + T4 model; `artifacts` inspects the AOT manifest and `serve`
//! exercises the batched serving loop over a session.

use hgnn_char::cli::{Args, USAGE};
use hgnn_char::datasets::{self, DatasetId, DatasetScale};
use hgnn_char::dynamic::{parse_update_stream, DynamicSpec, GraphUpdate};
use hgnn_char::gpumodel::{roofline, GpuModel};
use hgnn_char::models::{self, ModelId};
use hgnn_char::profiler::StageId;
use hgnn_char::report;
use hgnn_char::runtime::PjrtRuntime;
use hgnn_char::session::{
    Profiling, SamplingSpec, SchedulePolicy, ServingConfig, Session, SubmitOpts,
};
use hgnn_char::util::human_time;
use hgnn_char::Result;
use std::time::Duration;

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "list" => cmd_list(),
        "run" => cmd_run(args),
        "figure" => cmd_figure(args),
        "table" => cmd_table(args),
        "timeline" => cmd_timeline(args),
        "artifacts" => cmd_artifacts(args),
        "serve" => cmd_serve(args),
        "train" => cmd_train(args),
        "" | "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n\n{USAGE}");
            std::process::exit(2);
        }
    }
}

/// Parse the shared `--policy`/`--workers` pair.
fn policy_from(args: &Args) -> Result<SchedulePolicy> {
    let workers = args.flag_usize("workers", 4)?;
    match args.flag_str("policy", "seq").as_str() {
        "seq" => Ok(SchedulePolicy::Sequential),
        "par" => Ok(SchedulePolicy::InterSubgraphParallel { workers }),
        "fused" => Ok(SchedulePolicy::FusedSubgraph { workers }),
        "mix" => Ok(SchedulePolicy::BoundAwareMixing { workers }),
        other => Err(hgnn_char::Error::config(format!("--policy '{other}'"))),
    }
}

fn cmd_list() -> Result<()> {
    println!("datasets:");
    for id in [DatasetId::Imdb, DatasetId::Acm, DatasetId::Dblp, DatasetId::RedditSim] {
        let hg = datasets::build(id, &DatasetScale::ci())?;
        println!("  {:<12} ({})  {}", id.name(), id.abbrev(), hg.stats_line());
        if !id.default_metapaths().is_empty() {
            println!("    metapaths: {}", id.default_metapaths().join(", "));
        }
    }
    println!("models: RGCN, HAN, MAGNN (HGNNs) + GCN (baseline)");
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let model = ModelId::parse(&args.flag_str("model", "han"))?;
    let dataset = DatasetId::parse(&args.flag_str("dataset", "imdb"))?;
    let mut builder = Session::builder()
        .dataset(dataset)
        .scale(args.scale()?)
        .model(model)
        .schedule(policy_from(args)?)
        .profiling(Profiling::Traces);
    if let Some(t) = args.threads()? {
        builder = builder.threads(t);
        println!("worker pool: {t} thread(s) (intra-kernel row blocks + task schedules)");
    }
    if let Some(spec) = args.partition()? {
        builder = builder.partition(spec);
        if args.flag_str("policy", "seq") != "seq" {
            println!(
                "note: --shards subsumes --policy for the full forward \
                 (FP/NA parallelize across the {} shard thread(s))",
                spec.threads
            );
        }
    }
    if let Some(spec) = args.cluster()? {
        let workers = spec.workers;
        #[cfg(feature = "cluster-sockets")]
        {
            let transport = hgnn_char::cluster::SocketTransport::new(workers)?;
            builder = builder.cluster_transport(spec, Box::new(transport));
            println!("cluster: {workers} worker(s), socket transport (length-prefixed frames)");
        }
        #[cfg(not(feature = "cluster-sockets"))]
        {
            builder = builder.cluster(spec);
            println!(
                "cluster: {workers} worker(s), deterministic sim transport \
                 (build with --features cluster-sockets for real sockets)"
            );
        }
    }
    let quant = args.quantize()?;
    if let Some(spec) = quant {
        builder = builder.quantize(spec);
        println!(
            "quantized feature projection: {} (FP weights round-tripped through the format)",
            spec.name()
        );
    }
    let mut session = builder.build()?;
    println!("{}", session.graph().stats_line());
    println!("{}", session.plan().describe(session.graph()));
    println!("\n{}", report::degree_skew_table(session.graph()));
    if let Some(part) = session.partition() {
        println!("partition: {}", part.info().label());
    }
    let run = session.run()?;
    println!("\n{}", run.profile.stage_breakdown());
    println!("{}", run.report.summary());
    if let Some(stats) = session.cluster_stats() {
        let t = session.cluster().map(|c| c.transport_stats()).unwrap_or_default();
        println!(
            "cluster: {} wave(s), {} frame(s) / {} bytes on the wire, \
             {} retransmit(s), {} worker(s) retired, {} shard(s) re-placed",
            stats.waves, t.delivered, t.bytes, stats.retransmits, stats.retired_workers,
            stats.replaced_shards
        );
    }
    println!("\nkernel table (NA stage):");
    println!(
        "{}",
        report::table3_stage(
            StageId::NeighborAggregation,
            &run.profile.kernel_table(StageId::NeighborAggregation)
        )
    );
    if let Some(spec) = quant {
        // f32 baseline for the accuracy delta: the forward is
        // bit-identical across schedules/shards/threads, so a plain
        // sequential session yields the exact f32 reference logits
        let baseline = Session::builder()
            .dataset(dataset)
            .scale(args.scale()?)
            .model(model)
            .build()?
            .run()?;
        println!(
            "\n{}",
            report::quant_delta_table(spec.name(), &baseline.output, &run.output)
        );
    }
    Ok(())
}

fn cmd_figure(args: &Args) -> Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("2");
    let scale = args.scale()?;
    match which {
        "2" => figure2(&scale),
        "3" => figure3(&scale),
        "4" => figure4(&scale),
        "5a" | "5b" | "5c" => figure5(which, &scale),
        "6a" | "6b" => figure6(which, &scale),
        other => Err(hgnn_char::Error::config(format!("figure '{other}'"))),
    }
}

/// One sequential native run (counters only) — the common figure input.
fn profile_run(
    model: ModelId,
    dataset: DatasetId,
    scale: &DatasetScale,
    profiling: Profiling,
) -> Result<hgnn_char::session::SessionRun> {
    Session::builder()
        .dataset(dataset)
        .scale(scale.clone())
        .model(model)
        .profiling(profiling)
        .build()?
        .run()
}

fn figure2(scale: &DatasetScale) -> Result<()> {
    println!("Fig 2: execution time breakdown of inference (modeled T4)");
    let mut profiles = Vec::new();
    for model in ModelId::HGNNS {
        for dataset in DatasetId::HETERO {
            let run = profile_run(model, dataset, scale, Profiling::Counters)?;
            println!("{}", report::fig2_row(model.name(), dataset.abbrev(), &run.profile));
            profiles.push(run.profile);
        }
    }
    let refs: Vec<&hgnn_char::profiler::Profile> = profiles.iter().collect();
    let avg = report::average_stage_pct(&refs);
    println!("\naverage across models/datasets (paper: FP 19%, NA 74%, SA 7%):");
    for (s, v) in avg {
        println!("  {:<22} {v:>5.1}%", s.name());
    }
    Ok(())
}

fn figure3(scale: &DatasetScale) -> Result<()> {
    println!("Fig 3: execution time breakdown by CUDA-kernel type (modeled T4)");
    for model in ModelId::HGNNS {
        for dataset in DatasetId::HETERO {
            let run = profile_run(model, dataset, scale, Profiling::Counters)?;
            print!("{}", report::fig3_rows(model.name(), dataset.abbrev(), &run.profile));
        }
    }
    Ok(())
}

fn figure4(scale: &DatasetScale) -> Result<()> {
    println!("Fig 4: kernels on the FP32 roofline — HAN on DBLP (modeled T4)");
    let run = profile_run(ModelId::Han, DatasetId::Dblp, scale, Profiling::Traces)?;
    let model = GpuModel::default();
    let mut points = Vec::new();
    for stage in StageId::GPU_STAGES {
        for (name, m, _) in run.profile.kernel_table(stage) {
            points.push(roofline::place(&model.spec, &name, m.ai, m.achieved_gflops));
        }
    }
    points.dedup_by(|a, b| a.name == b.name);
    println!("{}", roofline::ascii_chart(&model.spec, &points));
    Ok(())
}

fn figure5(which: &str, scale: &DatasetScale) -> Result<()> {
    match which {
        "5a" => {
            println!("Fig 5a: NA time vs edge dropout (HAN vs GCN, Reddit-sim)");
            let pts = models::sweeps::fig5a_dropout_sweep(scale)?;
            for (label, series) in pts {
                println!(
                    "{}",
                    report::sweep_series(&label, "dropout", "NA time (ms)", &series)
                );
            }
        }
        "5b" => {
            println!("Fig 5b: NA time vs #metapaths (HAN, DBLP)");
            let series = models::sweeps::fig5b_metapath_sweep(scale)?;
            println!(
                "{}",
                report::sweep_series("HAN-DB", "#metapaths", "NA time (ms)", &series)
            );
        }
        "5c" => {
            println!("Fig 5c: NA/SA timeline with inter-subgraph parallelism + barrier");
            let run = Session::builder()
                .dataset(DatasetId::Dblp)
                .scale(scale.clone())
                .model(ModelId::Han)
                .schedule(SchedulePolicy::InterSubgraphParallel { workers: 4 })
                .build()?
                .run()?;
            println!("{}", run.profile.timeline().render(96));
        }
        _ => unreachable!(),
    }
    Ok(())
}

fn figure6(which: &str, scale: &DatasetScale) -> Result<()> {
    match which {
        "6a" => {
            println!("Fig 6a: subgraph sparsity vs metapath length");
            for (seed, dataset) in
                [("MAM", DatasetId::Imdb), ("PAP", DatasetId::Acm), ("APA", DatasetId::Dblp)]
            {
                let hg = datasets::build(dataset, scale)?;
                let pts = hgnn_char::metapath::sparsity::sparsity_sweep(&hg, seed, 3)?;
                let series: Vec<(f64, f64)> =
                    pts.iter().map(|p| (p.length as f64, p.sparsity)).collect();
                println!(
                    "{}",
                    report::sweep_series(
                        &format!("{} seed {}", dataset.abbrev(), seed),
                        "length",
                        "sparsity",
                        &series
                    )
                );
                if let Some(model) = hgnn_char::metapath::fit_sparsity_model(&pts) {
                    println!(
                        "  §5 correlation model: log10(density) = {:.3} + {:.3}*len (r2 {:.3})\n",
                        model.intercept, model.slope, model.r2
                    );
                }
            }
        }
        "6b" => {
            println!("Fig 6b: total execution time vs #metapaths (HAN, DBLP)");
            let series = models::sweeps::fig6b_total_time_sweep(scale)?;
            println!(
                "{}",
                report::sweep_series("HAN-DB", "#metapaths", "total (ms)", &series)
            );
        }
        _ => unreachable!(),
    }
    Ok(())
}

fn cmd_table(args: &Args) -> Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("3");
    if which != "3" {
        return Err(hgnn_char::Error::config(format!("table '{which}' (only 3 exists)")));
    }
    let scale = args.scale()?;
    println!("Table 3: profiling of major kernels, HAN on DBLP (modeled T4)");
    let run = profile_run(ModelId::Han, DatasetId::Dblp, &scale, Profiling::Traces)?;
    for stage in StageId::GPU_STAGES {
        println!("{}", report::table3_stage(stage, &run.profile.kernel_table(stage)));
    }
    Ok(())
}

fn cmd_timeline(args: &Args) -> Result<()> {
    let model = ModelId::parse(&args.flag_str("model", "han"))?;
    let dataset = DatasetId::parse(&args.flag_str("dataset", "dblp"))?;
    let workers = args.flag_usize("workers", 4)?;
    let mut builder = Session::builder()
        .dataset(dataset)
        .scale(args.scale()?)
        .model(model)
        .schedule(SchedulePolicy::InterSubgraphParallel { workers });
    if let Some(t) = args.threads()? {
        builder = builder.threads(t);
    }
    let run = builder.build()?.run()?;
    println!("{}", run.profile.timeline().render(96));
    println!("{}", run.report.summary());
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = args.flag_str("dir", "artifacts");
    let rt = PjrtRuntime::new(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    let manifest = rt.manifest()?;
    println!("{} artifacts in {dir}/:", manifest.entries.len());
    for e in &manifest.entries {
        println!(
            "  {:<28} model={:<6} dataset={:<6} stage={:<12} inputs={} outputs={}",
            e.name,
            e.model,
            e.dataset,
            e.stage,
            e.inputs.len(),
            e.outputs.len()
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let model = ModelId::parse(&args.flag_str("model", "han"))?;
    let dataset = DatasetId::parse(&args.flag_str("dataset", "imdb"))?;
    let config = args.train_config()?;
    let fanout = args.flag_usize("fanout", 0)?;
    let layers = args.flag_usize("sample-layers", 1)?;
    let mut builder = Session::builder().dataset(dataset).scale(args.scale()?).model(model);
    if let Some(t) = args.threads()? {
        builder = builder.threads(t);
        println!("worker pool: {t} thread(s)");
    }
    if fanout > 0 {
        builder = builder.sampling(SamplingSpec::uniform(fanout, layers));
        println!("mini-batch sampling: fanout {fanout}, {layers} layer(s)");
    }
    if let Some(spec) = args.partition()? {
        builder = builder.partition(spec);
        println!("shards: {} ({} thread(s))", spec.shards, spec.threads);
    }
    let mut session = builder.build()?;
    println!("{}", session.graph().stats_line());
    println!("{}", session.plan().describe(session.graph()));
    session.init_weights(config.seed)?;
    println!(
        "training: {} epoch(s), batch {}, {:?}, backward schedule {}",
        config.epochs,
        config.batch,
        config.optimizer,
        if config.fused { "fused" } else { "unfused" }
    );
    let report = session.fit(&config)?;
    println!("\n{}", report::training_table(&report));
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let n = args.flag_usize("requests", 64)?;
    let batch = args.flag_usize("batch", 1)?.max(1);
    let fanout = args.flag_usize("fanout", 0)?;
    let layers = args.flag_usize("sample-layers", 1)?;
    let reuse_cap = args.flag_usize("reuse-cap", 0)?;
    let stream = args.update_stream()?;
    // the whole serving path lives behind the dispatcher: session
    // construction, then either the one-time full-graph forward (no
    // --fanout) or one sampled subgraph per dispatched batch (--fanout),
    // optionally with the cross-request reuse caches (--reuse-cap)
    let mut builder = Session::builder()
        .dataset(DatasetId::Imdb)
        .scale(DatasetScale::ci())
        .model(ModelId::Han)
        .schedule(policy_from(args)?);
    if let Some(t) = args.threads()? {
        builder = builder.threads(t);
        println!("worker pool: {t} thread(s)");
    }
    if fanout > 0 {
        builder = builder.sampling(SamplingSpec::uniform(fanout, layers));
        println!("mini-batch sampling: fanout {fanout}, {layers} layer(s)");
    }
    if reuse_cap > 0 {
        if fanout == 0 {
            return Err(hgnn_char::Error::config(
                "serve: --reuse-cap requires --fanout (reuse memoizes sampled-batch \
                 stage results)",
            ));
        }
        builder = builder.reuse(hgnn_char::reuse::ReuseSpec::rows(reuse_cap));
        println!("cross-request reuse: {reuse_cap} rows per cache");
    }
    if let Some(spec) = args.quantize()? {
        builder = builder.quantize(spec);
        println!(
            "quantized serving: {} (FP weights + reuse-cache rows stored in the format)",
            spec.name()
        );
    }
    if let Some(spec) = args.partition()? {
        builder = builder.partition(spec);
        if fanout > 0 {
            println!(
                "sharded serving: {} shards, {} threads (batches group by owner shard)",
                spec.shards, spec.threads
            );
        } else {
            println!(
                "sharded forward: {} shards, {} threads (shard-affine batch grouping \
                 needs --fanout; full-graph serving uses the cached forward)",
                spec.shards, spec.threads
            );
        }
    }
    // streaming graph updates: parse the stream against a graph built at
    // the demo's dataset/scale (name → id resolution only; the updates
    // themselves are validated when the dispatcher applies them), then
    // replay it through the epoch barrier while requests are in flight
    let mut pending_updates = std::collections::VecDeque::new();
    if let Some(spec) = &stream {
        let text = std::fs::read_to_string(&spec.path)?;
        let hg = datasets::build(DatasetId::Imdb, &DatasetScale::ci())?;
        pending_updates.extend(parse_update_stream(&text, &hg)?);
        builder = builder.dynamic(DynamicSpec::default());
        println!(
            "streaming updates: {} update(s) from {}, epoch flip every {} batch(es)",
            pending_updates.len(),
            spec.path,
            spec.epoch_every
        );
    }
    // serving-runtime tuning: deadlines, priority classes, admission
    let tuning = args.serve_tuning()?;
    let mut config = ServingConfig { priority_lanes: tuning.priority_lanes, ..Default::default() };
    if let Some(ms) = tuning.deadline_ms {
        config.default_deadline = Some(Duration::from_millis(ms));
        println!("deadline: {ms} ms per request (late requests fail typed)");
    }
    if let Some(qps) = tuning.admission_qps {
        config.admission_qps = Some(qps);
        println!("admission control: token bucket at {qps:.0} ids/s");
    }
    if let Some(cap) = tuning.queue_cap {
        config.queue_cap = cap;
    }
    if tuning.priority_lanes > 1 {
        println!(
            "priority classes: {} (demo round-robins submissions over them)",
            tuning.priority_lanes
        );
    }
    let server = builder.serve_async(config);
    let ids: Vec<u32> = (0..n as u32).collect();
    let mut receivers = Vec::new();
    let mut flip_rxs = Vec::new();
    let (mut rejected, mut failed) = (0u64, 0u64);
    // updates per flip: spread the stream evenly over the flip slots the
    // request count affords, so the whole file lands within the demo
    let num_batches = ids.chunks(batch).len();
    let flip_slots = stream
        .as_ref()
        .map(|s| (num_batches / s.epoch_every).max(1))
        .unwrap_or(0);
    let per_flip =
        if flip_slots > 0 { pending_updates.len().div_ceil(flip_slots).max(1) } else { 0 };
    for (i, chunk) in ids.chunks(batch).enumerate() {
        match server.submit(chunk, SubmitOpts::class(i % tuning.priority_lanes)) {
            Ok(rx) => receivers.push(rx),
            Err(_) => rejected += 1,
        }
        if let Some(spec) = &stream {
            if (i + 1) % spec.epoch_every == 0 && !pending_updates.is_empty() {
                let take = per_flip.min(pending_updates.len());
                let updates: Vec<GraphUpdate> = pending_updates.drain(..take).collect();
                // append errors surface on the flip report's receiver
                let _ = server.apply_updates(updates);
                if let Ok(rx) = server.flip_epoch() {
                    flip_rxs.push(rx);
                }
            }
        }
    }
    // leftover updates (short demo or sparse flip slots): one final flip
    if stream.is_some() && !pending_updates.is_empty() {
        let updates: Vec<GraphUpdate> = pending_updates.drain(..).collect();
        let _ = server.apply_updates(updates);
        if let Ok(rx) = server.flip_epoch() {
            flip_rxs.push(rx);
        }
    }
    let mut ok = 0u64;
    for rx in receivers {
        match rx.recv() {
            Ok(Ok(_rows)) => ok += 1,
            _ => failed += 1,
        }
    }
    for rx in flip_rxs {
        match rx.recv() {
            Ok(Ok(report)) => println!("  {}", report.line()),
            Ok(Err(e)) => println!("  epoch flip failed: {e}"),
            Err(_) => {}
        }
    }
    let stats = server.shutdown();
    println!(
        "served {} ids in {} dispatches (mean batch {:.1}), p50 latency {}, throughput {:.0} ids/s",
        stats.completed,
        stats.batches,
        stats.mean_batch,
        human_time(stats.latency.median),
        stats.throughput_rps
    );
    println!("requests: {ok} ok, {failed} failed, {rejected} rejected at submit");
    if stats.rejected_overloaded + stats.rejected_queue_full + stats.expired > 0 {
        println!(
            "shed load: {} overloaded, {} queue-full, {} expired in queue (peak queue {})",
            stats.rejected_overloaded, stats.rejected_queue_full, stats.expired, stats.peak_queued
        );
    }
    for c in stats.classes.iter().filter(|c| c.submitted > 0 || c.rejected > 0) {
        println!(
            "  class {}: {} reqs, {:.0} ids/s, p50 {} / p95 {} / p99 {}",
            c.class,
            c.requests,
            c.qps,
            human_time(c.p50_ns as f64),
            human_time(c.p95_ns as f64),
            human_time(c.p99_ns as f64)
        );
    }
    if let Some(r) = &stats.reuse {
        println!("{}", r.line());
    }
    if !stats.reuse_lanes.is_empty() {
        println!("{}", hgnn_char::reuse::lane_lines(&stats.reuse_lanes));
    }
    Ok(())
}
