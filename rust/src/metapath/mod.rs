//! Subgraph Build — stage ① of the paper's four-stage HGNN pipeline.
//!
//! Splits a heterogeneous graph into homogeneous subgraphs, one per
//! metapath (HAN / MAGNN, "metapath walk") or one per relation (R-GCN,
//! "relation walk"). The paper executes this stage on the CPU before
//! inference; we do the same — this module is pure Rust topology work and
//! is *not* attributed to the GPU-profiled stages.
//!
//! Also home of the Fig 6(a) sparsity analysis and the §5 guideline-3
//! correlation model (sparsity vs metapath length).

pub mod sparsity;

use crate::graph::sparse::Csr;
use crate::graph::{HeteroGraph, NodeTypeId, RelationId};
use crate::{Error, Result};

pub use sparsity::{fit_sparsity_model, SparsityModel, SparsityPoint};

/// A parsed metapath, e.g. `"MDM"` = movie → director → movie.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Metapath {
    /// Node-type tags along the path (length ≥ 2).
    pub tags: Vec<char>,
}

impl Metapath {
    /// Parse from a tag string such as `"APVPA"`.
    pub fn parse(s: &str) -> Result<Metapath> {
        let tags: Vec<char> = s.chars().collect();
        if tags.len() < 2 {
            return Err(Error::config(format!("metapath '{s}' too short")));
        }
        Ok(Metapath { tags })
    }

    /// Length in *edges* (hops), e.g. `MDM` has length 2.
    pub fn len(&self) -> usize {
        self.tags.len() - 1
    }

    /// True if the path has no hops (never constructible via `parse`).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The tag string, e.g. `"MDM"`.
    pub fn name(&self) -> String {
        self.tags.iter().collect()
    }

    /// Endpoint (destination = first tag) node type in `hg`.
    pub fn endpoint_type(&self, hg: &HeteroGraph) -> Result<NodeTypeId> {
        hg.type_by_tag(self.tags[0])
    }

    /// True when the path starts and ends at the same node type
    /// (required for the symmetric NA the paper's models perform).
    pub fn is_symmetric(&self) -> bool {
        self.tags.first() == self.tags.last()
    }
}

/// A metapath-induced homogeneous subgraph: adjacency between endpoint
/// nodes plus bookkeeping for profiling.
#[derive(Debug, Clone)]
pub struct Subgraph {
    /// The metapath that induced this subgraph (`None` for relation walk).
    pub metapath: Option<Metapath>,
    /// Human name (`"MDM"` or the relation name for R-GCN).
    pub name: String,
    /// Endpoint (destination) node type.
    pub dst_type: NodeTypeId,
    /// Source node type (== dst for metapath subgraphs).
    pub src_type: NodeTypeId,
    /// Adjacency, `dst.count x src.count`.
    pub adj: Csr,
}

impl Subgraph {
    /// Sparsity of the subgraph adjacency (Fig 6a's y-axis).
    pub fn sparsity(&self) -> f64 {
        self.adj.sparsity()
    }
}

/// The output of Subgraph Build: one subgraph per metapath or relation.
#[derive(Debug, Clone)]
pub struct SubgraphSet {
    /// Subgraphs in declaration order.
    pub subgraphs: Vec<Subgraph>,
    /// Wallclock nanoseconds spent building (CPU-side; informational).
    pub build_nanos: u64,
}

impl SubgraphSet {
    /// Number of subgraphs (= #metapaths or #relations).
    pub fn len(&self) -> usize {
        self.subgraphs.len()
    }

    /// True when no subgraphs were built.
    pub fn is_empty(&self) -> bool {
        self.subgraphs.is_empty()
    }
}

/// Walk a metapath over the HG: composes per-hop relation adjacencies with
/// the boolean semiring, yielding the endpoint-to-endpoint adjacency.
///
/// The hop `t_i → t_{i+1}` uses the relation whose *source* type is
/// `t_{i+1}` and *destination* type is `t_i` — adjacency rows are
/// destinations, so composing `A(t1←t2) · A(t2←t3)` gives `t1←t3`
/// reachability, i.e. the metapath-based neighbors of each `t1` node.
pub fn walk_metapath(hg: &HeteroGraph, mp: &Metapath) -> Result<Csr> {
    let mut acc: Option<Csr> = None;
    for w in mp.tags.windows(2) {
        let rel = hop_relation(hg, mp, w[0], w[1])?;
        let hop = &hg.relation(rel).adj;
        acc = Some(match acc {
            None => hop.clone(),
            Some(a) => a.bool_matmul(hop)?,
        });
    }
    Ok(acc.expect("metapath has >= 1 hop"))
}

/// The relation one hop `w0 ← w1` of metapath `mp` resolves to: the first
/// relation with source type `w1` and destination type `w0` — exactly the
/// lookup [`walk_metapath`] composes, factored out so the dynamic-graph
/// patcher ([`crate::dynamic`]) can ask the inverse question.
pub fn hop_relation(hg: &HeteroGraph, mp: &Metapath, w0: char, w1: char) -> Result<RelationId> {
    let dst = hg.type_by_tag(w0)?;
    let src = hg.type_by_tag(w1)?;
    hg.relations_between(src, dst).first().copied().ok_or_else(|| {
        Error::NotFound(format!("relation {w1}->{w0} needed by metapath {}", mp.name()))
    })
}

/// True when re-walking `mp` over `hg` reads relation `rel` — i.e. an
/// edge inserted into `rel` can change the metapath's composed adjacency.
/// Unresolvable hops yield `false` (the walk would fail identically
/// before and after the update).
pub fn metapath_uses_relation(hg: &HeteroGraph, mp: &Metapath, rel: RelationId) -> bool {
    mp.tags
        .windows(2)
        .any(|w| hop_relation(hg, mp, w[0], w[1]).ok() == Some(rel))
}

/// Count metapath *instances* (paths, not distinct endpoints) — the
/// quantity MAGNN's intra-metapath aggregation enumerates.
pub fn count_instances(hg: &HeteroGraph, mp: &Metapath) -> Result<u64> {
    // dynamic programming over hop counts: paths[v] = #instances ending at v
    let mut counts: Option<Vec<u64>> = None;
    for w in mp.tags.windows(2) {
        let dst = hg.type_by_tag(w[0])?;
        let src = hg.type_by_tag(w[1])?;
        let rel = *hg
            .relations_between(src, dst)
            .first()
            .ok_or_else(|| Error::NotFound(format!("relation {}->{}", w[1], w[0])))?;
        let adj = &hg.relation(rel).adj;
        let next = match &counts {
            None => {
                // first hop: one instance per edge, grouped by source node
                let mut c = vec![0u64; adj.n_cols];
                for r in 0..adj.n_rows {
                    for &s in adj.row(r) {
                        c[s as usize] += 1;
                    }
                }
                c
            }
            Some(prev) => {
                let mut c = vec![0u64; adj.n_cols];
                for r in 0..adj.n_rows {
                    // instances reaching r so far fan out over r's neighbors
                    let _ = r;
                }
                // prev is indexed by the *source* side of the previous hop,
                // which is the dst side of this hop's adjacency rows.
                for r in 0..adj.n_rows {
                    let k = prev[r];
                    if k == 0 {
                        continue;
                    }
                    for &s in adj.row(r) {
                        c[s as usize] += k;
                    }
                }
                c
            }
        };
        counts = Some(next);
    }
    Ok(counts.map(|c| c.iter().sum()).unwrap_or(0))
}

/// Build metapath subgraphs (HAN / MAGNN style Subgraph Build).
pub fn build_metapath_subgraphs(hg: &HeteroGraph, paths: &[Metapath]) -> Result<SubgraphSet> {
    let t0 = std::time::Instant::now();
    let mut subgraphs = Vec::with_capacity(paths.len());
    for mp in paths {
        if !mp.is_symmetric() {
            return Err(Error::config(format!(
                "metapath {} is not symmetric; NA needs endpoint==start",
                mp.name()
            )));
        }
        let adj = walk_metapath(hg, mp)?;
        let ty = mp.endpoint_type(hg)?;
        subgraphs.push(Subgraph {
            metapath: Some(mp.clone()),
            name: mp.name(),
            dst_type: ty,
            src_type: ty,
            adj,
        });
    }
    Ok(SubgraphSet { subgraphs, build_nanos: t0.elapsed().as_nanos() as u64 })
}

/// Build relation subgraphs (R-GCN style Subgraph Build): one bipartite
/// subgraph per relation, unchanged adjacency.
pub fn build_relation_subgraphs(hg: &HeteroGraph) -> SubgraphSet {
    let t0 = std::time::Instant::now();
    let subgraphs = hg
        .relations()
        .iter()
        .map(|r| Subgraph {
            metapath: None,
            name: r.name.clone(),
            dst_type: r.dst,
            src_type: r.src,
            adj: r.adj.clone(),
        })
        .collect();
    SubgraphSet { subgraphs, build_nanos: t0.elapsed().as_nanos() as u64 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{self, DatasetId, DatasetScale};
    use crate::graph::sparse::Coo;
    use crate::graph::HeteroGraphBuilder;
    use crate::tensor::Tensor;

    fn toy_hg() -> HeteroGraph {
        // M={0,1,2}, D={0,1}; movie 0,1 -> director 0; movie 2 -> director 1
        let mut b = HeteroGraphBuilder::new("toy");
        let m = b.add_node_type("movie", 'M', Tensor::zeros(3, 2));
        let d = b.add_node_type("director", 'D', Tensor::zeros(2, 2));
        // D-M: rows = movies (dst M), cols = directors (src D)
        let dm = Coo::from_edges(3, 2, vec![(0, 0), (1, 0), (2, 1)]).unwrap().to_csr();
        b.add_relation("D-M", d, m, dm.clone());
        b.add_relation("M-D", m, d, dm.transposed());
        b.build().unwrap()
    }

    #[test]
    fn parse_and_props() {
        let mp = Metapath::parse("MDM").unwrap();
        assert_eq!(mp.len(), 2);
        assert!(mp.is_symmetric());
        assert_eq!(mp.name(), "MDM");
        assert!(Metapath::parse("M").is_err());
        assert!(!Metapath::parse("MD").unwrap().is_symmetric());
    }

    #[test]
    fn mdm_walk_gives_codirector_pairs() {
        let hg = toy_hg();
        let mp = Metapath::parse("MDM").unwrap();
        let adj = walk_metapath(&hg, &mp).unwrap();
        // movies 0,1 share director 0 => {0,1} mutually reachable (and self)
        assert_eq!(adj.row(0), &[0, 1]);
        assert_eq!(adj.row(1), &[0, 1]);
        assert_eq!(adj.row(2), &[2]);
    }

    #[test]
    fn missing_relation_is_reported() {
        let hg = toy_hg();
        let mp = Metapath::parse("MDX").unwrap();
        assert!(walk_metapath(&hg, &mp).is_err());
    }

    #[test]
    fn uses_relation_matches_walk_lookups() {
        let hg = toy_hg();
        let mdm = Metapath::parse("MDM").unwrap();
        // MDM composes D-M (rel 0, hop M<-D) then M-D (rel 1, hop D<-M)
        assert!(metapath_uses_relation(&hg, &mdm, 0));
        assert!(metapath_uses_relation(&hg, &mdm, 1));
        assert!(!metapath_uses_relation(&hg, &mdm, 2));
        // a partially unresolvable path still matches on its resolvable hops
        let mdx = Metapath::parse("MDX").unwrap();
        assert!(metapath_uses_relation(&hg, &mdx, 0));
        assert!(!metapath_uses_relation(&hg, &mdx, 1));
    }

    #[test]
    fn instance_count_matches_manual() {
        let hg = toy_hg();
        let mp = Metapath::parse("MDM").unwrap();
        // instances M->D->M: via director0: 2 movies x 2 movies = 4;
        // via director1: 1x1 = 1 => 5 total
        assert_eq!(count_instances(&hg, &mp).unwrap(), 5);
    }

    #[test]
    fn subgraph_set_build() {
        let hg = toy_hg();
        let set =
            build_metapath_subgraphs(&hg, &[Metapath::parse("MDM").unwrap()]).unwrap();
        assert_eq!(set.len(), 1);
        assert_eq!(set.subgraphs[0].name, "MDM");
        assert!(set.subgraphs[0].sparsity() < 1.0);
        // asymmetric metapath rejected
        assert!(build_metapath_subgraphs(&hg, &[Metapath::parse("MD").unwrap()]).is_err());
    }

    #[test]
    fn relation_walk_covers_all_relations() {
        let hg = toy_hg();
        let set = build_relation_subgraphs(&hg);
        assert_eq!(set.len(), hg.relations().len());
        assert_eq!(set.subgraphs[0].name, "D-M");
    }

    #[test]
    fn imdb_default_metapaths_walk() {
        let hg = datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap();
        let paths: Vec<Metapath> = DatasetId::Imdb
            .default_metapaths()
            .iter()
            .map(|s| Metapath::parse(s).unwrap())
            .collect();
        let set = build_metapath_subgraphs(&hg, &paths).unwrap();
        assert_eq!(set.len(), 2);
        for sg in &set.subgraphs {
            sg.adj.validate().unwrap();
            assert_eq!(sg.adj.n_rows, sg.adj.n_cols, "metapath subgraph is square");
            assert!(sg.adj.nnz() > 0, "{} should be non-empty", sg.name);
        }
    }
}
