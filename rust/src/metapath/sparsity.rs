//! Sparsity-vs-metapath-length analysis.
//!
//! Fig 6(a) of the paper shows subgraph sparsity *decreasing* as metapath
//! length increases (longer paths reach more neighbors). §5's third
//! guideline proposes a correlation model quantifying that relation so
//! sparsity-aware optimizations can be configured without materializing
//! the subgraph. We fit `log10(density) = a + b * length` by OLS, which
//! linearizes the multiplicative fan-out of path composition.

use crate::graph::HeteroGraph;
use crate::metapath::{walk_metapath, Metapath};
use crate::util::stats::ols;
use crate::Result;

/// One measured (metapath, sparsity) observation.
#[derive(Debug, Clone, PartialEq)]
pub struct SparsityPoint {
    /// Metapath name, e.g. `"MDMDM"`.
    pub name: String,
    /// Length in hops.
    pub length: usize,
    /// Measured sparsity `1 - nnz/(n*n)`.
    pub sparsity: f64,
    /// Measured nnz of the subgraph adjacency.
    pub nnz: usize,
}

/// The §5 guideline-3 correlation model: predicts subgraph density from
/// metapath length, `log10(density) ≈ intercept + slope * length`.
#[derive(Debug, Clone, PartialEq)]
pub struct SparsityModel {
    /// OLS intercept (log10 density at length 0).
    pub intercept: f64,
    /// OLS slope per hop (positive: density grows with length).
    pub slope: f64,
    /// Goodness of fit on the training points.
    pub r2: f64,
}

impl SparsityModel {
    /// Predicted density for a metapath of the given hop length.
    pub fn predict_density(&self, length: usize) -> f64 {
        10f64.powf(self.intercept + self.slope * length as f64).clamp(0.0, 1.0)
    }

    /// Predicted sparsity (1 - density).
    pub fn predict_sparsity(&self, length: usize) -> f64 {
        1.0 - self.predict_density(length)
    }
}

/// Measure sparsity for metapaths formed by repeating a symmetric seed
/// pattern, e.g. seed `"MDM"` → `MDM`, `MDMDM`, `MDMDMDM`, ... up to
/// `max_len` repetitions. This is the Fig 6(a) sweep.
pub fn sparsity_sweep(
    hg: &HeteroGraph,
    seed: &str,
    repeats: usize,
) -> Result<Vec<SparsityPoint>> {
    let mut points = Vec::new();
    let mut name = seed.to_string();
    for _ in 0..repeats {
        let mp = Metapath::parse(&name)?;
        let adj = walk_metapath(hg, &mp)?;
        points.push(SparsityPoint {
            name: mp.name(),
            length: mp.len(),
            sparsity: adj.sparsity(),
            nnz: adj.nnz(),
        });
        // extend by one seed period, dropping the duplicated junction tag:
        // "MDM" + "DM" -> "MDMDM"
        name.push_str(&seed[1..]);
    }
    Ok(points)
}

/// Fit the correlation model to measured points (needs ≥ 2 points with
/// nonzero density).
pub fn fit_sparsity_model(points: &[SparsityPoint]) -> Option<SparsityModel> {
    let usable: Vec<&SparsityPoint> = points.iter().filter(|p| p.sparsity < 1.0).collect();
    if usable.len() < 2 {
        return None;
    }
    let xs: Vec<f64> = usable.iter().map(|p| p.length as f64).collect();
    let ys: Vec<f64> = usable
        .iter()
        .map(|p| (1.0 - p.sparsity).max(1e-300).log10())
        .collect();
    let (a, b, r2) = ols(&xs, &ys);
    Some(SparsityModel { intercept: a, slope: b, r2 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{self, DatasetId, DatasetScale};

    #[test]
    fn sweep_lengths_grow_by_seed_period() {
        let hg = datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap();
        let pts = sparsity_sweep(&hg, "MDM", 3).unwrap();
        assert_eq!(pts.len(), 3);
        assert_eq!(pts[0].length, 2);
        assert_eq!(pts[1].length, 4);
        assert_eq!(pts[2].length, 6);
        assert_eq!(pts[1].name, "MDMDM");
    }

    #[test]
    fn sparsity_decreases_with_length() {
        // the paper's Fig 6(a) claim, on synthetic IMDB
        let hg = datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap();
        let pts = sparsity_sweep(&hg, "MAM", 3).unwrap();
        for w in pts.windows(2) {
            assert!(
                w[1].sparsity <= w[0].sparsity + 1e-12,
                "sparsity should not increase: {} -> {}",
                w[0].sparsity,
                w[1].sparsity
            );
        }
    }

    #[test]
    fn model_fits_and_predicts_monotonically() {
        let hg = datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap();
        let pts = sparsity_sweep(&hg, "MAM", 3).unwrap();
        let model = fit_sparsity_model(&pts).expect("fit");
        assert!(model.slope >= 0.0, "density grows with length, slope {}", model.slope);
        assert!(model.predict_density(2) <= model.predict_density(6) + 1e-12);
        assert!(model.r2 >= 0.0 && model.r2 <= 1.0);
    }

    #[test]
    fn fit_requires_two_points() {
        assert!(fit_sparsity_model(&[]).is_none());
        let p = SparsityPoint { name: "X".into(), length: 2, sparsity: 0.5, nnz: 10 };
        assert!(fit_sparsity_model(&[p]).is_none());
    }
}
