//! HGNN model definitions as execution plans.
//!
//! A [`ModelPlan`] is the declarative IR the engine executes: the
//! subgraph set from Subgraph Build (stage ①), per-type projection
//! weights for Feature Projection (②), per-subgraph attention parameters
//! for Neighbor Aggregation (③), and semantic-attention parameters for
//! Semantic Aggregation (④). Table 1 of the paper maps each model to its
//! stage operations:
//!
//! | Model | ① | ② | ③ | ④ |
//! |---|---|---|---|---|
//! | R-GCN | relation walk | linear | mean | sum |
//! | HAN | metapath walk | linear | GAT | attention sum |
//! | MAGNN | metapath walk | linear | GAT over encoded instances | attention sum |
//! | GCN (baseline) | — | linear | mean | — |

pub mod sweeps;
pub mod weights;

use crate::datasets::DatasetId;
use crate::graph::{HeteroGraph, NodeTypeId};
use crate::metapath::{self, Metapath, SubgraphSet};
use crate::{Error, Result};

pub use weights::ModelWeights;

/// Which model a plan executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelId {
    /// Relational GCN (Schlichtkrull et al., ESWC'18).
    Rgcn,
    /// Heterogeneous graph Attention Network (Wang et al., WWW'19).
    Han,
    /// Metapath Aggregated GNN (Fu et al., WWW'20), instance-encoder lite
    /// variant (DESIGN.md §5: mean instance encoder instead of
    /// relational rotation; same kernel classes, same stage structure).
    Magnn,
    /// Homogeneous GCN baseline (Kipf & Welling) for the Fig 5 comparison.
    Gcn,
}

impl ModelId {
    /// The paper's three HGNN models.
    pub const HGNNS: [ModelId; 3] = [ModelId::Rgcn, ModelId::Han, ModelId::Magnn];

    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            ModelId::Rgcn => "RGCN",
            ModelId::Han => "HAN",
            ModelId::Magnn => "MAGNN",
            ModelId::Gcn => "GCN",
        }
    }

    /// Parse from a case-insensitive name.
    pub fn parse(s: &str) -> Result<ModelId> {
        match s.to_ascii_lowercase().as_str() {
            "rgcn" | "r-gcn" => Ok(ModelId::Rgcn),
            "han" => Ok(ModelId::Han),
            "magnn" => Ok(ModelId::Magnn),
            "gcn" => Ok(ModelId::Gcn),
            _ => Err(Error::NotFound(format!("model '{s}'"))),
        }
    }

    /// True for models whose NA uses attention (GAT).
    pub fn uses_attention(self) -> bool {
        matches!(self, ModelId::Han | ModelId::Magnn)
    }
}

/// Hyper-parameters shared by all models.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    /// Hidden (projected) feature dimension.
    pub hidden_dim: usize,
    /// Semantic-attention MLP hidden width (HAN/MAGNN stage ④).
    pub semantic_dim: usize,
    /// LeakyReLU negative slope for GAT logits.
    pub leaky_slope: f32,
    /// Weight-initialization seed.
    pub seed: u64,
}

impl Default for ModelConfig {
    fn default() -> Self {
        // DGL defaults the paper's experiments run with: hidden 64,
        // semantic-attention width 128.
        ModelConfig { hidden_dim: 64, semantic_dim: 128, leaky_slope: 0.2, seed: 0xCAFE }
    }
}

/// A fully-materialized execution plan: model + subgraphs + weights.
#[derive(Debug, Clone)]
pub struct ModelPlan {
    /// Which model.
    pub model: ModelId,
    /// Hyper-parameters.
    pub config: ModelConfig,
    /// Stage-① output.
    pub subgraphs: SubgraphSet,
    /// All learned parameters (deterministically initialized).
    pub weights: ModelWeights,
    /// Node type whose embeddings are the model output (HAN/MAGNN/GCN).
    /// R-GCN updates every destination type; `target` selects which one
    /// is returned as the plan output.
    pub target: NodeTypeId,
}

impl ModelPlan {
    /// Number of subgraphs (metapaths / relations).
    pub fn num_subgraphs(&self) -> usize {
        self.subgraphs.len()
    }

    /// Human description for logs.
    pub fn describe(&self, hg: &HeteroGraph) -> String {
        format!(
            "{} on {}: {} subgraphs [{}], hidden={}, target={}",
            self.model.name(),
            hg.name,
            self.num_subgraphs(),
            self.subgraphs
                .subgraphs
                .iter()
                .map(|s| s.name.as_str())
                .collect::<Vec<_>>()
                .join(", "),
            self.config.hidden_dim,
            hg.node_type(self.target).name,
        )
    }
}

/// Build a HAN plan with the dataset's default metapaths.
pub fn han_plan(hg: &HeteroGraph, config: &ModelConfig) -> Result<ModelPlan> {
    let id = DatasetId::parse(&hg.name).ok();
    let names = id.map(|d| d.default_metapaths()).unwrap_or_default();
    if names.is_empty() {
        return Err(Error::config(format!("no default metapaths for {}", hg.name)));
    }
    let paths: Vec<Metapath> =
        names.iter().map(|s| Metapath::parse(s)).collect::<Result<_>>()?;
    han_plan_with(hg, config, &paths)
}

/// Build a HAN plan over explicit metapaths (all must share an endpoint).
pub fn han_plan_with(
    hg: &HeteroGraph,
    config: &ModelConfig,
    paths: &[Metapath],
) -> Result<ModelPlan> {
    let subgraphs = metapath::build_metapath_subgraphs(hg, paths)?;
    let target = common_endpoint(hg, &subgraphs)?;
    let weights = ModelWeights::init(ModelId::Han, hg, &subgraphs, config);
    Ok(ModelPlan { model: ModelId::Han, config: config.clone(), subgraphs, weights, target })
}

/// Build a MAGNN-lite plan (same subgraphs as HAN; heavier NA).
pub fn magnn_plan(hg: &HeteroGraph, config: &ModelConfig) -> Result<ModelPlan> {
    let mut plan = han_plan(hg, config)?;
    plan.model = ModelId::Magnn;
    plan.weights = ModelWeights::init(ModelId::Magnn, hg, &plan.subgraphs, config);
    Ok(plan)
}

/// Build a MAGNN-lite plan over explicit metapaths.
pub fn magnn_plan_with(
    hg: &HeteroGraph,
    config: &ModelConfig,
    paths: &[Metapath],
) -> Result<ModelPlan> {
    let mut plan = han_plan_with(hg, config, paths)?;
    plan.model = ModelId::Magnn;
    plan.weights = ModelWeights::init(ModelId::Magnn, hg, &plan.subgraphs, config);
    Ok(plan)
}

/// Build an R-GCN plan (relation walk; every relation becomes a subgraph).
pub fn rgcn_plan(hg: &HeteroGraph, config: &ModelConfig) -> Result<ModelPlan> {
    let subgraphs = metapath::build_relation_subgraphs(hg);
    if subgraphs.is_empty() {
        return Err(Error::config("graph has no relations"));
    }
    // output type: the destination type with the most incoming relations
    // (movie for IMDB, paper for ACM/DBLP) — matches OpenHGNN's target.
    let mut counts = vec![0usize; hg.node_types().len()];
    for sg in &subgraphs.subgraphs {
        counts[sg.dst_type] += 1;
    }
    let target = counts
        .iter()
        .enumerate()
        .max_by_key(|(_, &c)| c)
        .map(|(i, _)| i)
        .unwrap_or(0);
    let weights = ModelWeights::init(ModelId::Rgcn, hg, &subgraphs, config);
    Ok(ModelPlan { model: ModelId::Rgcn, config: config.clone(), subgraphs, weights, target })
}

/// Build a GCN plan over a homogeneous graph (single type, one relation).
pub fn gcn_plan(hg: &HeteroGraph, config: &ModelConfig) -> Result<ModelPlan> {
    if hg.node_types().len() != 1 || hg.relations().len() != 1 {
        return Err(Error::config(format!(
            "GCN needs a homogeneous graph; {} has {} types / {} relations",
            hg.name,
            hg.node_types().len(),
            hg.relations().len()
        )));
    }
    let subgraphs = metapath::build_relation_subgraphs(hg);
    let weights = ModelWeights::init(ModelId::Gcn, hg, &subgraphs, config);
    Ok(ModelPlan { model: ModelId::Gcn, config: config.clone(), subgraphs, weights, target: 0 })
}

/// Build a plan by model id using dataset defaults.
pub fn build_plan(model: ModelId, hg: &HeteroGraph, config: &ModelConfig) -> Result<ModelPlan> {
    match model {
        ModelId::Han => han_plan(hg, config),
        ModelId::Magnn => magnn_plan(hg, config),
        ModelId::Rgcn => rgcn_plan(hg, config),
        ModelId::Gcn => gcn_plan(hg, config),
    }
}

fn common_endpoint(hg: &HeteroGraph, set: &SubgraphSet) -> Result<NodeTypeId> {
    let first = set
        .subgraphs
        .first()
        .ok_or_else(|| Error::config("empty subgraph set"))?
        .dst_type;
    for sg in &set.subgraphs {
        if sg.dst_type != first {
            return Err(Error::config(format!(
                "metapaths disagree on endpoint type: {} vs {}",
                hg.node_type(first).name,
                hg.node_type(sg.dst_type).name
            )));
        }
    }
    Ok(first)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{self, DatasetId, DatasetScale};

    fn imdb() -> HeteroGraph {
        datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap()
    }

    #[test]
    fn model_parse() {
        assert_eq!(ModelId::parse("HAN").unwrap(), ModelId::Han);
        assert_eq!(ModelId::parse("r-gcn").unwrap(), ModelId::Rgcn);
        assert!(ModelId::parse("bert").is_err());
        assert!(ModelId::Han.uses_attention());
        assert!(!ModelId::Rgcn.uses_attention());
    }

    #[test]
    fn han_plan_defaults() {
        let hg = imdb();
        let plan = han_plan(&hg, &ModelConfig::default()).unwrap();
        assert_eq!(plan.num_subgraphs(), 2); // MDM, MAM
        assert_eq!(hg.node_type(plan.target).tag, 'M');
        assert!(plan.describe(&hg).contains("HAN"));
    }

    #[test]
    fn rgcn_plan_covers_relations() {
        let hg = imdb();
        let plan = rgcn_plan(&hg, &ModelConfig::default()).unwrap();
        assert_eq!(plan.num_subgraphs(), hg.relations().len());
        // movie receives relations from both D and A: target must be M
        assert_eq!(hg.node_type(plan.target).tag, 'M');
    }

    #[test]
    fn gcn_requires_homogeneous() {
        let hg = imdb();
        assert!(gcn_plan(&hg, &ModelConfig::default()).is_err());
        let rd = datasets::build(DatasetId::RedditSim, &DatasetScale::ci()).unwrap();
        let plan = gcn_plan(&rd, &ModelConfig::default()).unwrap();
        assert_eq!(plan.num_subgraphs(), 1);
    }

    #[test]
    fn mismatched_endpoints_rejected() {
        let hg = imdb();
        let paths =
            vec![Metapath::parse("MDM").unwrap(), Metapath::parse("DMD").unwrap()];
        assert!(han_plan_with(&hg, &ModelConfig::default(), &paths).is_err());
    }

    #[test]
    fn build_plan_dispatch() {
        let hg = imdb();
        for m in ModelId::HGNNS {
            let plan = build_plan(m, &hg, &ModelConfig::default()).unwrap();
            assert_eq!(plan.model, m);
        }
    }
}
