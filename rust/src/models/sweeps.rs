//! Parameter sweeps behind Figs 5(a), 5(b) and 6(b).
//!
//! * **Fig 5a** — Neighbor Aggregation time as edge dropout decreases
//!   (i.e. average #neighbors increases), HAN vs GCN on Reddit-sim.
//! * **Fig 5b** — NA time as the number of metapaths grows (HAN, DBLP).
//! * **Fig 6b** — total execution time as the number of metapaths grows.
//!
//! All y-values are modeled T4 milliseconds (DESIGN.md §4); x-axes match
//! the paper. Shared by the CLI (`hgnn-char figure ...`) and the bench
//! targets.

use crate::datasets::{self, DatasetId, DatasetScale};
use crate::gpumodel::GpuModel;
use crate::metapath::{self, Metapath};
use crate::models::{self, ModelConfig, ModelId, ModelPlan, ModelWeights};
use crate::profiler::StageId;
use crate::session::{exec, ExecBackend, NativeBackend, SchedulePolicy};
use crate::Result;

/// Dropout rates the paper sweeps (decreasing ⇒ denser graph).
pub const FIG5A_DROPOUTS: [f64; 5] = [0.9, 0.75, 0.5, 0.25, 0.0];

/// DBLP metapath pool used for the #metapath sweeps. All author-endpoint,
/// ordered the way the paper adds "one more metapath".
pub const DBLP_METAPATH_POOL: [&str; 6] =
    ["APA", "APVPA", "APTPA", "APAPA", "APVPAPA", "APTPAPA"];

/// Modeled NA milliseconds of one plan (FP+NA through the session
/// executor on the native backend, counters only).
fn na_ms(plan: &ModelPlan, hg: &crate::graph::HeteroGraph) -> Result<f64> {
    let backend = NativeBackend::new();
    let mut ctx = backend.make_ctx();
    let (_, profile) =
        exec::run_na_only(&backend, &GpuModel::default(), plan, hg, &mut ctx)?;
    Ok(profile
        .stage_times()
        .get(&StageId::NeighborAggregation)
        .copied()
        .unwrap_or(0.0)
        / 1e6)
}

/// Build a HAN-style plan over a homogeneous graph's single relation
/// (GAT NA on the full edge set) — "HAN with one metapath" as the paper
/// runs it on Reddit.
fn han_on_homogeneous(
    hg: &crate::graph::HeteroGraph,
    config: &ModelConfig,
) -> Result<ModelPlan> {
    let subgraphs = metapath::build_relation_subgraphs(hg);
    let weights = ModelWeights::init(ModelId::Han, hg, &subgraphs, config);
    Ok(ModelPlan {
        model: ModelId::Han,
        config: config.clone(),
        subgraphs,
        weights,
        target: 0,
    })
}

/// Fig 5a: for HAN and GCN on Reddit-sim, NA time per dropout rate.
/// Returns one `(label, series)` per model; series x = dropout rate.
pub fn fig5a_dropout_sweep(scale: &DatasetScale) -> Result<Vec<(String, Vec<(f64, f64)>)>> {
    let base = datasets::build(DatasetId::RedditSim, scale)?;
    let config = ModelConfig::default();
    let mut han_series = Vec::new();
    let mut gcn_series = Vec::new();
    for &p in &FIG5A_DROPOUTS {
        let hg = base.dropout_edges(p, 0xD20);
        let han = han_on_homogeneous(&hg, &config)?;
        han_series.push((p, na_ms(&han, &hg)?));
        let gcn = models::gcn_plan(&hg, &config)?;
        gcn_series.push((p, na_ms(&gcn, &hg)?));
    }
    Ok(vec![
        ("HAN (GAT NA)".to_string(), han_series),
        ("GCN".to_string(), gcn_series),
    ])
}

/// Fig 5b: HAN on DBLP, NA time vs number of metapaths (1..=pool).
pub fn fig5b_metapath_sweep(scale: &DatasetScale) -> Result<Vec<(f64, f64)>> {
    let hg = datasets::build(DatasetId::Dblp, scale)?;
    let config = ModelConfig::default();
    let mut series = Vec::new();
    for k in 1..=DBLP_METAPATH_POOL.len() {
        let paths: Vec<Metapath> = DBLP_METAPATH_POOL[..k]
            .iter()
            .map(|s| Metapath::parse(s))
            .collect::<Result<_>>()?;
        let plan = models::han_plan_with(&hg, &config, &paths)?;
        series.push((k as f64, na_ms(&plan, &hg)?));
    }
    Ok(series)
}

/// Fig 6b: HAN on DBLP, *total* modeled time vs number of metapaths.
pub fn fig6b_total_time_sweep(scale: &DatasetScale) -> Result<Vec<(f64, f64)>> {
    let hg = datasets::build(DatasetId::Dblp, scale)?;
    let config = ModelConfig::default();
    let mut series = Vec::new();
    for k in 1..=DBLP_METAPATH_POOL.len() {
        let paths: Vec<Metapath> = DBLP_METAPATH_POOL[..k]
            .iter()
            .map(|s| Metapath::parse(s))
            .collect::<Result<_>>()?;
        let plan = models::han_plan_with(&hg, &config, &paths)?;
        let backend = NativeBackend::new();
        let mut ctx = backend.make_ctx();
        let run = exec::execute(
            &backend,
            &GpuModel::default(),
            &plan,
            &hg,
            SchedulePolicy::Sequential,
            &mut ctx,
        )?;
        series.push((k as f64, run.profile.total_modeled_ns() / 1e6));
    }
    Ok(series)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_scale() -> DatasetScale {
        DatasetScale { topo_factor: 1.0 / 64.0, feat_factor: 1.0 / 32.0, ..DatasetScale::ci() }
    }

    #[test]
    fn fig5a_na_time_increases_as_dropout_decreases() {
        let series = fig5a_dropout_sweep(&tiny_scale()).unwrap();
        assert_eq!(series.len(), 2);
        for (label, pts) in &series {
            assert_eq!(pts.len(), FIG5A_DROPOUTS.len());
            // dropout decreases along the sweep => NA time must rise
            assert!(
                pts.last().unwrap().1 > pts.first().unwrap().1,
                "{label}: NA time should grow as edges are kept: {pts:?}"
            );
        }
    }

    #[test]
    fn fig5a_han_slower_than_gcn() {
        // GAT NA does strictly more kernel work than mean NA
        let series = fig5a_dropout_sweep(&tiny_scale()).unwrap();
        let han_t = series[0].1.last().unwrap().1;
        let gcn_t = series[1].1.last().unwrap().1;
        assert!(han_t > gcn_t, "HAN {han_t} vs GCN {gcn_t}");
    }

    #[test]
    fn fig5b_monotone_in_metapaths() {
        let series = fig5b_metapath_sweep(&tiny_scale()).unwrap();
        assert_eq!(series.len(), DBLP_METAPATH_POOL.len());
        for w in series.windows(2) {
            assert!(
                w[1].1 >= w[0].1 * 0.999,
                "NA time should not shrink with more metapaths: {series:?}"
            );
        }
    }

    #[test]
    fn fig6b_total_exceeds_na_sweep() {
        let total = fig6b_total_time_sweep(&tiny_scale()).unwrap();
        let na = fig5b_metapath_sweep(&tiny_scale()).unwrap();
        for (t, n) in total.iter().zip(&na) {
            assert!(t.1 >= n.1, "total {t:?} must be >= NA-only {n:?}");
        }
    }
}
