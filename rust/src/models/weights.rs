//! Deterministic model parameter initialization.
//!
//! Inference-phase characterization does not need trained weights — the
//! kernel mix and data volumes are weight-independent — but the PJRT and
//! native backends must agree numerically, so parameters are generated
//! deterministically (seeded PCG, Glorot-ish scale) and can be exported
//! byte-identically to the Python AOT side.

use std::collections::BTreeMap;

use crate::graph::HeteroGraph;
use crate::metapath::SubgraphSet;
use crate::models::{ModelConfig, ModelId};
use crate::tensor::Tensor;
use crate::util::Pcg32;

/// All learned parameters of a plan.
#[derive(Debug, Clone, Default)]
pub struct ModelWeights {
    /// Feature Projection: per node type (by id), `[feat_dim, hidden]`.
    /// For R-GCN the projection is per *relation* source type but shared
    /// weights per type keep the kernel mix identical; OpenHGNN does the
    /// same for the input layer.
    pub proj: BTreeMap<usize, Tensor>,
    /// R-GCN only: learned per-type node embeddings `[count, hidden]`.
    /// OpenHGNN's RGCN does not consume raw bag-of-words features — every
    /// node type gets a trainable `hidden`-dim embedding, and FP projects
    /// that (a small `[N,h]x[h,h]` sgemm). Empty for other models.
    pub embed: BTreeMap<usize, Tensor>,
    /// Per-subgraph GAT attention vector for destination side `[hidden]`.
    pub attn_l: Vec<Vec<f32>>,
    /// Per-subgraph GAT attention vector for source side `[hidden]`.
    pub attn_r: Vec<Vec<f32>>,
    /// Per-subgraph MAGNN edge-attention matrix `[hidden, 1]` applied to
    /// encoded instances (empty for other models).
    pub inst_attn: Vec<Tensor>,
    /// Semantic attention MLP: `[hidden, semantic_dim]`.
    pub sem_w: Option<Tensor>,
    /// Semantic attention bias `[semantic_dim]`.
    pub sem_b: Vec<f32>,
    /// Semantic attention query vector `[semantic_dim, 1]`.
    pub sem_q: Option<Tensor>,
}

impl ModelWeights {
    /// Initialize weights for a (model, graph, subgraphs, config) tuple.
    pub fn init(
        model: ModelId,
        hg: &HeteroGraph,
        subgraphs: &SubgraphSet,
        config: &ModelConfig,
    ) -> ModelWeights {
        let mut w = ModelWeights::default();
        let h = config.hidden_dim;

        // projection per node type that appears as a subgraph source or
        // destination (R-GCN touches everything; HAN only the endpoint)
        let mut used_types: Vec<usize> = subgraphs
            .subgraphs
            .iter()
            .flat_map(|s| [s.src_type, s.dst_type])
            .collect();
        used_types.sort_unstable();
        used_types.dedup();
        for ty in used_types {
            if model == ModelId::Rgcn {
                // OpenHGNN RGCN: learned hidden-dim embeddings per type,
                // projected by an [h, h] relation weight.
                let count = hg.node_type(ty).count;
                let scale = (1.0 / h as f32).sqrt();
                let mut erng = Pcg32::new(config.seed, 0x5000 + ty as u64);
                w.embed.insert(ty, Tensor::randn(count, h, scale, &mut erng));
                let mut rng = Pcg32::new(config.seed, 0x1000 + ty as u64);
                w.proj.insert(ty, Tensor::randn(h, h, scale, &mut rng));
            } else {
                let dim = hg.node_type(ty).feat_dim;
                let scale = (2.0 / (dim + h) as f32).sqrt();
                let mut rng = Pcg32::new(config.seed, 0x1000 + ty as u64);
                w.proj.insert(ty, Tensor::randn(dim, h, scale, &mut rng));
            }
        }

        // per-subgraph attention parameters
        if model.uses_attention() {
            for (i, _) in subgraphs.subgraphs.iter().enumerate() {
                let mut rng = Pcg32::new(config.seed, 0x2000 + i as u64);
                let scale = (1.0 / h as f32).sqrt();
                w.attn_l.push((0..h).map(|_| rng.gen_normal() * scale).collect());
                w.attn_r.push((0..h).map(|_| rng.gen_normal() * scale).collect());
                if model == ModelId::Magnn {
                    let mut irng = Pcg32::new(config.seed, 0x3000 + i as u64);
                    w.inst_attn.push(Tensor::randn(h, 1, scale, &mut irng));
                }
            }
            // semantic attention (stage ④)
            let mut rng = Pcg32::new(config.seed, 0x4000);
            let s = config.semantic_dim;
            let scale = (2.0 / (h + s) as f32).sqrt();
            w.sem_w = Some(Tensor::randn(h, s, scale, &mut rng));
            w.sem_b = (0..s).map(|_| rng.gen_normal() * 0.01).collect();
            w.sem_q = Some(Tensor::randn(s, 1, (1.0 / s as f32).sqrt(), &mut rng));
        }
        w
    }

    /// Grow the R-GCN embedding table of `ty` to `new_count` rows.
    ///
    /// Appended rows are drawn from the *same* PCG stream cold init uses
    /// (`0x5000 + ty`, sequential row-major fill), so row `i` of the
    /// extended table is bit-identical to row `i` of a cold
    /// [`ModelWeights::init`] over the grown graph — the property the
    /// dynamic-graph flip relies on for cold-vs-incremental bit-identity.
    /// Existing rows are kept as-is (they may have been replaced via
    /// `Session::set_weights`); only rows `>= old count` are generated.
    /// No-op for types without an embedding table or when the table
    /// already has `new_count` rows.
    pub fn extend_embed(&mut self, ty: usize, new_count: usize, config: &ModelConfig) {
        let Some(old) = self.embed.get(&ty) else {
            return;
        };
        let old_count = old.rows();
        if new_count <= old_count {
            return;
        }
        let h = config.hidden_dim;
        let scale = (1.0 / h as f32).sqrt();
        let mut erng = Pcg32::new(config.seed, 0x5000 + ty as u64);
        let full = Tensor::randn(new_count, h, scale, &mut erng);
        let mut data = self.embed[&ty].as_slice().to_vec();
        data.extend_from_slice(&full.as_slice()[old_count * h..]);
        self.embed.insert(
            ty,
            Tensor::from_vec(new_count, h, data).expect("extend_embed shape"),
        );
    }

    /// A structurally identical weight set with every parameter zeroed —
    /// the shape of a gradient accumulator or an optimizer moment buffer.
    pub fn zeros_like(&self) -> ModelWeights {
        ModelWeights {
            proj: self
                .proj
                .iter()
                .map(|(&k, t)| (k, Tensor::zeros(t.rows(), t.cols())))
                .collect(),
            embed: self
                .embed
                .iter()
                .map(|(&k, t)| (k, Tensor::zeros(t.rows(), t.cols())))
                .collect(),
            attn_l: self.attn_l.iter().map(|v| vec![0.0; v.len()]).collect(),
            attn_r: self.attn_r.iter().map(|v| vec![0.0; v.len()]).collect(),
            inst_attn: self
                .inst_attn
                .iter()
                .map(|t| Tensor::zeros(t.rows(), t.cols()))
                .collect(),
            sem_w: self.sem_w.as_ref().map(|t| Tensor::zeros(t.rows(), t.cols())),
            sem_b: vec![0.0; self.sem_b.len()],
            sem_q: self.sem_q.as_ref().map(|t| Tensor::zeros(t.rows(), t.cols())),
        }
    }

    /// Every parameter group as a flat slice, in a fixed deterministic
    /// order (proj by type id, embed by type id, attn_l, attn_r,
    /// inst_attn, sem_w, sem_b, sem_q). Two structurally identical
    /// weight sets — e.g. weights, their gradients from
    /// [`ModelWeights::zeros_like`], and optimizer moments — zip
    /// group-for-group, which is what the optimizer step relies on.
    pub fn params(&self) -> Vec<&[f32]> {
        let mut out: Vec<&[f32]> = Vec::new();
        out.extend(self.proj.values().map(|t| t.as_slice()));
        out.extend(self.embed.values().map(|t| t.as_slice()));
        out.extend(self.attn_l.iter().map(|v| v.as_slice()));
        out.extend(self.attn_r.iter().map(|v| v.as_slice()));
        out.extend(self.inst_attn.iter().map(|t| t.as_slice()));
        out.extend(self.sem_w.as_ref().map(|t| t.as_slice()));
        out.push(self.sem_b.as_slice());
        out.extend(self.sem_q.as_ref().map(|t| t.as_slice()));
        out
    }

    /// Mutable variant of [`ModelWeights::params`], same group order.
    pub fn params_mut(&mut self) -> Vec<&mut [f32]> {
        let mut out: Vec<&mut [f32]> = Vec::new();
        out.extend(self.proj.values_mut().map(|t| t.as_mut_slice()));
        out.extend(self.embed.values_mut().map(|t| t.as_mut_slice()));
        out.extend(self.attn_l.iter_mut().map(|v| v.as_mut_slice()));
        out.extend(self.attn_r.iter_mut().map(|v| v.as_mut_slice()));
        out.extend(self.inst_attn.iter_mut().map(|t| t.as_mut_slice()));
        out.extend(self.sem_w.as_mut().map(|t| t.as_mut_slice()));
        out.push(self.sem_b.as_mut_slice());
        out.extend(self.sem_q.as_mut().map(|t| t.as_mut_slice()));
        out
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        let mut n = 0;
        n += self.proj.values().map(|t| t.len()).sum::<usize>();
        n += self.embed.values().map(|t| t.len()).sum::<usize>();
        n += self.attn_l.iter().map(|v| v.len()).sum::<usize>();
        n += self.attn_r.iter().map(|v| v.len()).sum::<usize>();
        n += self.inst_attn.iter().map(|t| t.len()).sum::<usize>();
        n += self.sem_w.as_ref().map(|t| t.len()).unwrap_or(0);
        n += self.sem_b.len();
        n += self.sem_q.as_ref().map(|t| t.len()).unwrap_or(0);
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{self, DatasetId, DatasetScale};
    use crate::models;

    #[test]
    fn han_weights_shapes() {
        let hg = datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap();
        let cfg = ModelConfig::default();
        let plan = models::han_plan(&hg, &cfg).unwrap();
        let w = &plan.weights;
        // only the movie endpoint type needs projection
        assert_eq!(w.proj.len(), 1);
        let m_ty = hg.type_by_tag('M').unwrap();
        assert_eq!(
            w.proj[&m_ty].shape(),
            (hg.node_type(m_ty).feat_dim, cfg.hidden_dim)
        );
        assert_eq!(w.attn_l.len(), 2);
        assert_eq!(w.attn_l[0].len(), cfg.hidden_dim);
        assert!(w.sem_w.is_some());
        assert_eq!(w.sem_b.len(), cfg.semantic_dim);
        assert!(w.inst_attn.is_empty());
    }

    #[test]
    fn rgcn_projects_every_type_from_embeddings() {
        let hg = datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap();
        let cfg = ModelConfig::default();
        let plan = models::rgcn_plan(&hg, &cfg).unwrap();
        assert_eq!(plan.weights.proj.len(), hg.node_types().len());
        assert_eq!(plan.weights.embed.len(), hg.node_types().len());
        for (ty, e) in &plan.weights.embed {
            assert_eq!(e.shape(), (hg.node_type(*ty).count, cfg.hidden_dim));
            assert_eq!(plan.weights.proj[ty].shape(), (cfg.hidden_dim, cfg.hidden_dim));
        }
        assert!(plan.weights.attn_l.is_empty());
        assert!(plan.weights.sem_w.is_none());
    }

    #[test]
    fn magnn_has_instance_attention() {
        let hg = datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap();
        let plan = models::magnn_plan(&hg, &ModelConfig::default()).unwrap();
        assert_eq!(plan.weights.inst_attn.len(), plan.num_subgraphs());
    }

    #[test]
    fn extend_embed_matches_cold_init_prefix_and_tail() {
        let mut hg = datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap();
        let cfg = ModelConfig::default();
        let mut grown = models::rgcn_plan(&hg, &cfg).unwrap().weights;
        let m_ty = hg.type_by_tag('M').unwrap();
        let old = hg.node_type(m_ty).count;
        let dim = hg.node_type(m_ty).feat_dim;
        hg.push_node(m_ty, &vec![0.0; dim]).unwrap();
        hg.push_node(m_ty, &vec![0.0; dim]).unwrap();
        grown.extend_embed(m_ty, old + 2, &cfg);
        let cold = models::rgcn_plan(&hg, &cfg).unwrap().weights;
        assert_eq!(grown.embed[&m_ty].shape(), (old + 2, cfg.hidden_dim));
        assert!(grown.embed[&m_ty].allclose(&cold.embed[&m_ty], 0.0, 0.0));
        // shrinking / same-size / unknown-type requests are no-ops
        grown.extend_embed(m_ty, old, &cfg);
        assert_eq!(grown.embed[&m_ty].rows(), old + 2);
        grown.extend_embed(999, 10, &cfg);
    }

    #[test]
    fn zeros_like_and_params_align() {
        let hg = datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap();
        let cfg = ModelConfig::default();
        for plan in [
            models::rgcn_plan(&hg, &cfg).unwrap(),
            models::han_plan(&hg, &cfg).unwrap(),
            models::magnn_plan(&hg, &cfg).unwrap(),
        ] {
            let mut w = plan.weights.clone();
            let z = w.zeros_like();
            assert_eq!(z.param_count(), w.param_count());
            assert!(z.params().iter().all(|g| g.iter().all(|&v| v == 0.0)));
            // group-for-group zip: same count, same lengths, fixed order
            let wp = w.params();
            let zp = z.params();
            assert_eq!(wp.len(), zp.len());
            for (a, b) in wp.iter().zip(&zp) {
                assert_eq!(a.len(), b.len());
            }
            let total: usize = wp.iter().map(|g| g.len()).sum();
            assert_eq!(total, w.param_count());
            drop(wp);
            assert_eq!(w.params_mut().len(), zp.len());
        }
    }

    #[test]
    fn deterministic_across_calls() {
        let hg = datasets::build(DatasetId::Acm, &DatasetScale::ci()).unwrap();
        let cfg = ModelConfig::default();
        let a = models::han_plan(&hg, &cfg).unwrap().weights;
        let b = models::han_plan(&hg, &cfg).unwrap().weights;
        assert_eq!(a.attn_l, b.attn_l);
        for (k, t) in &a.proj {
            assert!(t.allclose(&b.proj[k], 0.0, 0.0));
        }
        assert!(a.param_count() > 0);
        assert_eq!(a.param_count(), b.param_count());
    }
}
