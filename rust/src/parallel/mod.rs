//! The intra-kernel parallel runtime: one process-wide pool of
//! persistent `std::thread` workers driving a chunked [`parallel_for`]
//! over row ranges.
//!
//! The paper's characterization shows each HGNN stage saturating a
//! different resource — Feature Projection is compute-bound dense matmul
//! while Neighbor Aggregation is memory-bound and irregular — and both
//! leave data parallelism *inside* every kernel on the table. This
//! module is the substrate that harvests it: `sgemm` parallelizes over
//! M-dimension macro-row blocks, `SpMMCsr` over destination-row blocks,
//! and `IndexSelect` over output rows, all through the same pool.
//!
//! ## Design
//!
//! * **Persistent workers.** Worker threads are spawned lazily on first
//!   demand (never more than the widest job needs, hard-capped at
//!   [`MAX_WORKERS`]) and then parked on a condvar between jobs, so
//!   steady-state kernel dispatch never pays thread creation.
//! * **Chunk claiming.** A job divides `n` work units into chunks; the
//!   submitting thread *and* the woken workers claim chunks from a
//!   shared atomic cursor (dynamic scheduling, so skewed CSR rows
//!   balance), and the submitter blocks until every chunk is done. That
//!   blocking is also the safety argument for the one piece of `unsafe`
//!   here: the borrowed closure is only ever dereferenced for a claimed
//!   chunk, and `parallel_for` cannot return before all claimed chunks
//!   are finished.
//! * **Bit-identity.** Chunks split the *output* rows; each row's inner
//!   accumulation loop is byte-for-byte the serial code, so results are
//!   bit-identical at every thread count (pinned by
//!   `tests/integration_parallel.rs` across R-GCN/HAN/MAGNN).
//! * **Nesting rule.** A `parallel_for` issued from inside a pool job
//!   (or from a chunk the submitting thread is helping with) runs
//!   inline and serial. The session's NA worker schedule and the
//!   sharded executor run their tasks through [`parallel_map`] on this
//!   same pool, so per-subgraph/per-shard parallelism and intra-kernel
//!   parallelism can never multiply into oversubscription.
//! * **Sizing.** The effective width of a job is
//!   [`current_threads`]: a thread-local override installed by
//!   [`with_threads`] (what `SessionBuilder::threads` / the CLI
//!   `--threads` flag plumb through), else the process default —
//!   the `HGNN_THREADS` env var when set, else
//!   `std::thread::available_parallelism()`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Hard upper bound on pool workers (safety valve; real widths come
/// from [`current_threads`]).
pub const MAX_WORKERS: usize = 256;

/// Target chunks per participating thread — enough slack for dynamic
/// load balancing over skewed rows without drowning in claim traffic.
const CHUNKS_PER_THREAD: usize = 4;

thread_local! {
    /// True while this thread is executing a pool chunk (worker threads
    /// set it permanently) — makes nested `parallel_for` run inline.
    static IN_JOB: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
    /// Thread-local width override installed by [`with_threads`].
    static CAP: std::cell::Cell<Option<usize>> = const { std::cell::Cell::new(None) };
}

/// Process default width: `HGNN_THREADS` (when a positive integer — the
/// CI lever that forces the parallel paths on small runners), else the
/// machine's available parallelism.
pub fn default_threads() -> usize {
    static DEFAULT: OnceLock<usize> = OnceLock::new();
    *DEFAULT.get_or_init(|| {
        match std::env::var("HGNN_THREADS").ok().and_then(|v| v.parse::<usize>().ok()) {
            Some(n) if n >= 1 => n,
            _ => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        }
    })
}

/// The width the next job submitted *from this thread* will use.
pub fn current_threads() -> usize {
    CAP.with(|c| c.get()).unwrap_or_else(default_threads)
}

/// True while the calling thread is executing inside a pool chunk
/// (where any nested data-parallel call runs inline and serial).
pub fn in_parallel_region() -> bool {
    IN_JOB.with(|c| c.get())
}

/// Run `f` with the pool width capped at `threads` (min 1) for every
/// job submitted from the calling thread — the scoped, thread-local
/// knob behind `SessionBuilder::threads`. Restores the previous cap on
/// exit (including unwinds), so concurrent sessions and tests never
/// fight over a process global.
pub fn with_threads<R>(threads: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(Option<usize>);
    impl Drop for Restore {
        fn drop(&mut self) {
            let prev = self.0;
            CAP.with(|c| c.set(prev));
        }
    }
    let _restore = Restore(CAP.with(|c| c.replace(Some(threads.max(1)))));
    f()
}

/// Cumulative pool counters (process lifetime).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Jobs that actually went parallel (serial fallbacks not counted).
    pub jobs: u64,
    /// Chunks executed across all parallel jobs.
    pub chunks: u64,
    /// Worker threads currently spawned.
    pub workers: usize,
}

/// Snapshot of the global pool's counters.
pub fn pool_stats() -> PoolStats {
    let pool = pool();
    PoolStats {
        jobs: pool.jobs.load(Ordering::Relaxed),
        chunks: pool.chunks.load(Ordering::Relaxed),
        workers: pool.inner.lock().unwrap_or_else(|e| e.into_inner()).spawned,
    }
}

/// Type-erased pointer to the job's borrowed chunk closure. Sharing it
/// across threads is sound because the pointee is `Sync`, and the
/// lifetime is enforced by protocol: `parallel_for` blocks until every
/// claimed chunk has finished, and the pointer is only dereferenced
/// between claiming a valid chunk and marking it done.
struct FnPtr(*const (dyn Fn(usize, usize) + Sync));
unsafe impl Send for FnPtr {}
unsafe impl Sync for FnPtr {}

/// One chunked data-parallel job: the closure plus claim/completion
/// state. Queued as `Arc` clones (one per helper worker).
struct Job {
    f: FnPtr,
    n: usize,
    chunk: usize,
    chunks: usize,
    next: AtomicUsize,
    done: AtomicUsize,
    panicked: AtomicBool,
    finished: Mutex<bool>,
    cv: Condvar,
}

impl Job {
    /// Claim and execute chunks until the cursor is exhausted.
    fn run(&self) {
        loop {
            let c = self.next.fetch_add(1, Ordering::Relaxed);
            if c >= self.chunks {
                return;
            }
            let lo = c * self.chunk;
            let hi = self.n.min(lo + self.chunk);
            // SAFETY: see `FnPtr` — the submitter blocks in `wait()`
            // until this chunk is marked done below, so the borrowed
            // closure is alive for the whole call.
            let f = unsafe { &*self.f.0 };
            if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(lo, hi))).is_err() {
                self.panicked.store(true, Ordering::SeqCst);
            }
            if self.done.fetch_add(1, Ordering::SeqCst) + 1 == self.chunks {
                let mut fin = self.finished.lock().unwrap_or_else(|e| e.into_inner());
                *fin = true;
                self.cv.notify_all();
            }
        }
    }

    /// Block until every chunk is done.
    fn wait(&self) {
        let mut fin = self.finished.lock().unwrap_or_else(|e| e.into_inner());
        while !*fin {
            fin = self.cv.wait(fin).unwrap_or_else(|e| e.into_inner());
        }
    }
}

struct PoolInner {
    queue: VecDeque<Arc<Job>>,
    spawned: usize,
}

struct Pool {
    inner: Mutex<PoolInner>,
    work: Condvar,
    jobs: AtomicU64,
    chunks: AtomicU64,
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| Pool {
        inner: Mutex::new(PoolInner { queue: VecDeque::new(), spawned: 0 }),
        work: Condvar::new(),
        jobs: AtomicU64::new(0),
        chunks: AtomicU64::new(0),
    })
}

impl Pool {
    /// Enqueue `helpers` claims on the job and make sure that many
    /// workers exist to take them. Exactly `helpers` workers can ever
    /// join a job (each queue entry is consumed once), which is what
    /// caps a job's width at the submitter's `current_threads()`.
    fn submit(&self, job: &Arc<Job>, helpers: usize) {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        while inner.spawned < helpers.min(MAX_WORKERS) {
            let name = format!("hgnn-pool-{}", inner.spawned);
            match std::thread::Builder::new().name(name).spawn(worker_loop) {
                Ok(_) => inner.spawned += 1,
                // spawn failure degrades gracefully: the submitting
                // thread still drives the job to completion
                Err(_) => break,
            }
        }
        for _ in 0..helpers {
            inner.queue.push_back(job.clone());
        }
        drop(inner);
        self.work.notify_all();
    }
}

/// Worker body: park on the condvar, pop a job claim, drain it, repeat.
/// Workers are daemons — they live for the process and die with it.
fn worker_loop() {
    IN_JOB.with(|c| c.set(true));
    let pool = pool();
    loop {
        let job: Arc<Job> = {
            let mut inner = pool.inner.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                if let Some(j) = inner.queue.pop_front() {
                    break j;
                }
                inner = pool.work.wait(inner).unwrap_or_else(|e| e.into_inner());
            }
        };
        job.run();
    }
}

/// Chunked data-parallel loop over `0..n`: `f(lo, hi)` is called for
/// disjoint, exhaustive ranges (never smaller than `min_chunk` units
/// except the last). Runs inline and serial when the effective width is
/// 1, when `n` is too small to split, or when the caller is already
/// inside a pool chunk (the nesting rule). Panics in any chunk are
/// caught on the executing thread and re-raised here after all chunks
/// finish.
pub fn parallel_for(n: usize, min_chunk: usize, f: impl Fn(usize, usize) + Sync) {
    if n == 0 {
        return;
    }
    let min_chunk = min_chunk.max(1);
    let cap = current_threads();
    if cap <= 1 || n <= min_chunk || in_parallel_region() {
        f(0, n);
        return;
    }
    let chunks = (cap * CHUNKS_PER_THREAD).min(n.div_ceil(min_chunk));
    if chunks <= 1 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(chunks);
    let chunks = n.div_ceil(chunk);
    let obj: &(dyn Fn(usize, usize) + Sync) = &f;
    let job = Arc::new(Job {
        f: FnPtr(obj as *const (dyn Fn(usize, usize) + Sync)),
        n,
        chunk,
        chunks,
        next: AtomicUsize::new(0),
        done: AtomicUsize::new(0),
        panicked: AtomicBool::new(false),
        finished: Mutex::new(false),
        cv: Condvar::new(),
    });
    let pool = pool();
    pool.jobs.fetch_add(1, Ordering::Relaxed);
    pool.chunks.fetch_add(chunks as u64, Ordering::Relaxed);
    pool.submit(&job, (cap - 1).min(chunks - 1));
    {
        // the submitter helps; its own nested parallel calls inline
        struct Exit(bool);
        impl Drop for Exit {
            fn drop(&mut self) {
                let prev = self.0;
                IN_JOB.with(|c| c.set(prev));
            }
        }
        let _exit = Exit(IN_JOB.with(|c| c.replace(true)));
        job.run();
    }
    job.wait();
    if job.panicked.load(Ordering::SeqCst) {
        panic!("parallel_for task panicked");
    }
}

/// Raw-pointer wrapper that lets disjoint sub-slices of one `&mut [T]`
/// be written from multiple pool threads.
struct SendPtr<T>(*mut T);
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Parallel loop over a mutable slice viewed as consecutive units of
/// `unit` elements (a row-major matrix's rows, a macro-block of rows,
/// ...). `f(first_unit, block)` receives the index of its first unit
/// and the mutable sub-slice covering its units; the final block may be
/// ragged when `data.len()` is not a unit multiple. Blocks are disjoint
/// and exhaustive — this is the safe mutable-output face of
/// [`parallel_for`] that the row-blocked kernels build on.
pub fn parallel_chunks_mut<T: Send>(
    data: &mut [T],
    unit: usize,
    min_units: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    if data.is_empty() || unit == 0 {
        return;
    }
    let len = data.len();
    let units = len.div_ceil(unit);
    let base = SendPtr(data.as_mut_ptr());
    parallel_for(units, min_units, move |u0, u1| {
        let lo = u0 * unit;
        let hi = len.min(u1 * unit);
        // SAFETY: `parallel_for` hands out disjoint, in-bounds unit
        // ranges, so these sub-slices never alias; the borrow of `data`
        // outlives the blocking `parallel_for` call.
        let block = unsafe { std::slice::from_raw_parts_mut(base.0.add(lo), hi - lo) };
        f(u0, block);
    });
}

/// Run `tasks` independent closures on the pool and collect their
/// results in index order. This is what the session's NA worker
/// schedule and the sharded executor dispatch through, so task-level
/// and intra-kernel parallelism share one set of threads (tasks run
/// with nested data parallelism inlined).
pub fn parallel_map<T: Send>(tasks: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    let mut slots: Vec<Option<T>> = (0..tasks).map(|_| None).collect();
    parallel_chunks_mut(&mut slots, 1, 1, |i0, block| {
        for (j, slot) in block.iter_mut().enumerate() {
            *slot = Some(f(i0 + j));
        }
    });
    slots
        .into_iter()
        .enumerate()
        .map(|(i, s)| s.unwrap_or_else(|| panic!("parallel_map task {i} never ran")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn covers_every_index_exactly_once() {
        let marks: Vec<AtomicU32> = (0..1000).map(|_| AtomicU32::new(0)).collect();
        with_threads(4, || {
            parallel_for(1000, 1, |lo, hi| {
                for m in &marks[lo..hi] {
                    m.fetch_add(1, Ordering::SeqCst);
                }
            });
        });
        assert!(marks.iter().all(|m| m.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn chunks_respect_min_chunk_and_tail() {
        // n=10, unit=4 → blocks [0..4), [4..8), [8..10)
        let mut data: Vec<u32> = vec![0; 10];
        with_threads(4, || {
            parallel_chunks_mut(&mut data, 4, 1, |u0, block| {
                for (j, v) in block.iter_mut().enumerate() {
                    *v = (u0 * 4 + j) as u32 + 1;
                }
            });
        });
        let expect: Vec<u32> = (1..=10).collect();
        assert_eq!(data, expect);
    }

    #[test]
    fn nested_parallel_runs_inline() {
        let total = AtomicU32::new(0);
        with_threads(4, || {
            parallel_for(8, 1, |lo, hi| {
                assert!(in_parallel_region() || current_threads() == 1);
                // nested call must execute inline, still covering all
                parallel_for(hi - lo, 1, |a, b| {
                    total.fetch_add((b - a) as u32, Ordering::SeqCst);
                });
            });
        });
        assert_eq!(total.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn width_one_is_serial_and_inline() {
        let concurrent = AtomicU32::new(0);
        let peak = AtomicU32::new(0);
        with_threads(1, || {
            parallel_for(64, 1, |_, _| {
                let c = concurrent.fetch_add(1, Ordering::SeqCst) + 1;
                peak.fetch_max(c, Ordering::SeqCst);
                concurrent.fetch_sub(1, Ordering::SeqCst);
            });
        });
        assert_eq!(peak.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn width_caps_job_participants() {
        // at width 2 at most 2 threads (submitter + 1 helper) can ever
        // be inside chunks of one job simultaneously
        let concurrent = AtomicU32::new(0);
        let peak = AtomicU32::new(0);
        with_threads(2, || {
            parallel_for(64, 1, |_, _| {
                let c = concurrent.fetch_add(1, Ordering::SeqCst) + 1;
                peak.fetch_max(c, Ordering::SeqCst);
                std::thread::sleep(std::time::Duration::from_micros(200));
                concurrent.fetch_sub(1, Ordering::SeqCst);
            });
        });
        assert!(peak.load(Ordering::SeqCst) <= 2, "peak {}", peak.load(Ordering::SeqCst));
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = with_threads(4, || parallel_map(37, |i| i * i));
        assert_eq!(out.len(), 37);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn with_threads_restores_previous_cap() {
        let outer = current_threads();
        with_threads(3, || {
            assert_eq!(current_threads(), 3);
            with_threads(1, || assert_eq!(current_threads(), 1));
            assert_eq!(current_threads(), 3);
        });
        assert_eq!(current_threads(), outer);
    }

    #[test]
    #[should_panic(expected = "parallel_for task panicked")]
    fn chunk_panic_propagates_to_submitter() {
        with_threads(4, || {
            parallel_for(16, 1, |lo, _| {
                if lo == 0 {
                    panic!("boom");
                }
            });
        });
    }

    #[test]
    fn pool_stats_count_parallel_jobs() {
        let before = pool_stats();
        with_threads(4, || parallel_for(256, 1, |_, _| {}));
        let after = pool_stats();
        assert!(after.jobs > before.jobs);
        assert!(after.chunks > before.chunks);
    }
}
