//! Degree-balanced heterogeneous-graph partitioning — the sharded
//! execution subsystem.
//!
//! The paper's central observation is that Neighbor Aggregation
//! dominates HGNN inference and suffers severe load imbalance from
//! degree skew (most destination vertices have few neighbors, a few
//! have very many), and HiHGNN (arXiv 2307.12765) shows that exploiting
//! inter-partition parallelism is the key lever for scaling HGNN
//! execution. This module turns those findings into a real partitioner:
//! [`Partition::build`] splits the graph into `K` shards, **per node
//! type**, by greedy LPT over each destination vertex's aggregation
//! cost (its total degree across the plan's subgraph CSRs, the same
//! `nnz`-dominated cost model the schedule analysis uses) — reusing the
//! canonical [`lpt_assign`] from `coordinator::schedule`, not a second
//! implementation.
//!
//! Each [`Shard`] materializes:
//!
//! * **per-shard sub-CSRs** — every subgraph restricted to the
//!   destination rows the shard owns, in a compact local id space;
//! * **halo tables** — the foreign-owned source nodes a shard reads
//!   during NA (its replication/communication cost, exchanged before
//!   the NA stage by [`crate::session::exec::execute_sharded`]);
//! * an **owner-computes merge plan** — `(local row, global row)` pairs
//!   per type, disjoint across shards and jointly covering every node,
//!   which scatters per-shard NA outputs back into the global tensors
//!   Semantic Aggregation consumes.
//!
//! ## Bit-identical by construction
//!
//! Sharded outputs must equal the unsharded forward **bit for bit**, or
//! no serving system could ever turn sharding on. Two invariants make
//! that hold:
//!
//! 1. **Owner computes.** Every destination row is aggregated by exactly
//!    one shard, over its *complete* neighbor list (sources may be halo
//!    nodes) — never split and re-combined, so no f32 re-association.
//! 2. **Canonical accumulation order.** Shard-local ids ascend with
//!    global ids (the same invariant [`crate::sampler`] pins for the
//!    reuse caches), and CSR construction sorts column indices, so every
//!    local row lists its sources in exactly the global row's order.
//!    Row-local kernels therefore accumulate in the same order, and
//!    stage-② rows are bit-identical because the projection sgemm is
//!    row-local (pinned by `native_project_features_is_row_sliced_fp`).
//!
//! `tests/integration_partition.rs` pins both properties for
//! RGCN/HAN/MAGNN across 1/2/4 shards.

use std::collections::HashMap;

use crate::coordinator::schedule::lpt_assign;
use crate::dynamic::PatchSet;
use crate::graph::sparse::Coo;
use crate::graph::HeteroGraph;
use crate::metapath::{Subgraph, SubgraphSet};
use crate::models::ModelPlan;
use crate::tensor::Tensor;
use crate::util::stats;
use crate::{Error, Result};

/// How the graph is sharded: how many shards, and how many OS threads
/// drive them (shards are LPT-packed onto threads when `threads <
/// shards`, again via [`lpt_assign`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PartitionSpec {
    /// Number of shards `K >= 1`.
    pub shards: usize,
    /// Concurrent shard-executor threads (defaults to `shards`).
    pub threads: usize,
}

impl PartitionSpec {
    /// `shards` shards driven by `shards` threads.
    pub fn new(shards: usize) -> PartitionSpec {
        PartitionSpec { shards, threads: shards }
    }

    /// Cap the executor thread count (oversubscribed shards are
    /// LPT-packed onto the available threads).
    pub fn with_threads(mut self, threads: usize) -> PartitionSpec {
        self.threads = threads;
        self
    }

    fn validate(&self) -> Result<()> {
        if self.shards == 0 {
            return Err(Error::config("PartitionSpec: --shards must be >= 1"));
        }
        if self.threads == 0 {
            return Err(Error::config("PartitionSpec: --shard-threads must be >= 1"));
        }
        Ok(())
    }
}

/// Partition quality summary, surfaced through
/// [`crate::coordinator::schedule::ScheduleReport`] and the CLI so the
/// balance/communication trade-off is observable per run.
#[derive(Debug, Clone)]
pub struct ShardingInfo {
    /// Shard count `K`.
    pub shards: usize,
    /// Executor threads driving the shards.
    pub threads: usize,
    /// Total halo rows across shards and types — the feature rows
    /// exchanged between shards before NA (replication cost).
    pub halo_rows: usize,
    /// max/mean modeled NA cost across shards (1.0 = perfect balance).
    pub imbalance: f64,
    /// Gini coefficient of the per-shard modeled NA cost (0 = equal).
    pub cost_gini: f64,
}

impl ShardingInfo {
    /// Compact summary fragment for report lines.
    pub fn label(&self) -> String {
        format!(
            "{} shards x{} thr, halo {} rows, imbalance {:.2}",
            self.shards, self.threads, self.halo_rows, self.imbalance
        )
    }
}

/// One shard: compact local node spaces, the restricted sub-CSRs
/// packaged as an executable [`ModelPlan`], halo tables and the merge
/// plan. All per-type vectors are indexed by [`crate::graph::NodeTypeId`].
#[derive(Debug)]
pub struct Shard {
    /// Per type: local id → global id, ascending in global id (the
    /// canonical ordering that pins f32 accumulation order). Contains
    /// the owned nodes plus this shard's halo.
    pub nodes: Vec<Vec<u32>>,
    /// Per type: global ids this shard owns (ascending). Owned sets are
    /// disjoint across shards and jointly cover every node of the type.
    pub owned: Vec<Vec<u32>>,
    /// Per type: global ids of *foreign-owned* nodes this shard reads as
    /// NA sources (ascending; disjoint from `owned`).
    pub halo: Vec<Vec<u32>>,
    /// Per type: `(local row, global row)` of owned nodes — the
    /// owner-computes merge plan for NA outputs.
    pub merge: Vec<Vec<(u32, u32)>>,
    /// The shard's executable plan: same model/config/weights as the
    /// parent, subgraphs replaced by the local sub-CSRs (halo rows carry
    /// no edges), R-GCN embedding tables sliced to the local rows.
    pub plan: ModelPlan,
}

impl Shard {
    /// Total local nodes across types (owned + halo).
    pub fn total_nodes(&self) -> usize {
        self.nodes.iter().map(|v| v.len()).sum()
    }

    /// Total halo rows across types.
    pub fn halo_rows(&self) -> usize {
        self.halo.iter().map(|v| v.len()).sum()
    }
}

/// Shard ownership of one node type as a plain owner table — cheap to
/// clone out of a [`Partition`] and safe to share across threads
/// (unlike the partition, which is pinned to the executor thread).
/// Node ids outside the table map to shard 0.
#[derive(Debug, Clone)]
pub struct ShardMap {
    owners: Vec<u32>,
    shards: usize,
}

impl ShardMap {
    /// Owning shard lane of `node`. Ids wrap modulo the table length —
    /// the same wrap `Session::run_batch` (and so the serving executor)
    /// applies — so submit-side lane accounting agrees with where the
    /// dispatcher actually routes the id. 0 on an empty table.
    pub fn shard_of(&self, node: u32) -> usize {
        if self.owners.is_empty() {
            return 0;
        }
        self.owners[node as usize % self.owners.len()] as usize
    }

    /// Number of shard lanes.
    pub fn num_shards(&self) -> usize {
        self.shards
    }

    /// Number of nodes in the table.
    pub fn len(&self) -> usize {
        self.owners.len()
    }

    /// Whether the table is empty.
    pub fn is_empty(&self) -> bool {
        self.owners.is_empty()
    }
}

/// The materialized K-way partition of one (graph, plan) pair, cached by
/// `SessionBuilder::partition` and reused across every run and served
/// batch of the session.
#[derive(Debug)]
pub struct Partition {
    spec: PartitionSpec,
    /// Per type: `owners[ty][node]` = owning shard.
    owners: Vec<Vec<u32>>,
    /// The materialized shards, `spec.shards` of them.
    pub shards: Vec<Shard>,
    /// Per-shard modeled NA cost (Σ sub-CSR nnz + rows), used to LPT-pack
    /// shards onto executor threads.
    costs: Vec<f64>,
    /// Wallclock nanoseconds spent partitioning (CPU-side, one-off).
    pub build_nanos: u64,
}

impl Partition {
    /// Partition `hg` under `plan` into `spec.shards` degree-balanced
    /// shards. Costs are per *destination* vertex: `1 + Σ degree` across
    /// the plan's subgraphs targeting the vertex's type, assigned to
    /// shards with [`lpt_assign`] per node type.
    pub fn build(hg: &HeteroGraph, plan: &ModelPlan, spec: &PartitionSpec) -> Result<Partition> {
        spec.validate()?;
        let t0 = std::time::Instant::now();
        let k = spec.shards;
        let n_types = hg.node_types().len();

        // per-destination-vertex aggregation cost over the plan subgraphs
        let mut costs: Vec<Vec<f64>> = hg
            .node_types()
            .iter()
            .map(|t| vec![1.0f64; t.count])
            .collect();
        for sg in &plan.subgraphs.subgraphs {
            for d in 0..sg.adj.n_rows {
                costs[sg.dst_type][d] += sg.adj.degree(d) as f64;
            }
        }

        // degree-balanced owners, one LPT per node type
        let owners: Vec<Vec<u32>> = costs
            .iter()
            .map(|c| lpt_assign(c, k).into_iter().map(|w| w as u32).collect())
            .collect();

        // owned sets (ascending: nodes iterated in id order)
        let mut owned: Vec<Vec<Vec<u32>>> = vec![vec![Vec::new(); n_types]; k];
        for (ty, type_owners) in owners.iter().enumerate() {
            for (node, &s) in type_owners.iter().enumerate() {
                owned[s as usize][ty].push(node as u32);
            }
        }

        // halo: foreign-owned sources referenced by owned destination rows
        let mut halo: Vec<Vec<Vec<u32>>> = vec![vec![Vec::new(); n_types]; k];
        for sg in &plan.subgraphs.subgraphs {
            for d in 0..sg.adj.n_rows {
                let s = owners[sg.dst_type][d] as usize;
                for &src in sg.adj.row(d) {
                    if owners[sg.src_type][src as usize] as usize != s {
                        halo[s][sg.src_type].push(src);
                    }
                }
            }
        }
        for shard_halo in halo.iter_mut() {
            for list in shard_halo.iter_mut() {
                list.sort_unstable();
                list.dedup();
            }
        }

        // local node spaces (owned ∪ halo, ascending) + reverse maps
        let mut shards = Vec::with_capacity(k);
        for s in 0..k {
            shards.push(materialize_shard(
                plan,
                std::mem::take(&mut owned[s]),
                std::mem::take(&mut halo[s]),
            )?);
        }

        let costs: Vec<f64> = shards
            .iter()
            .map(|sh| {
                sh.plan
                    .subgraphs
                    .subgraphs
                    .iter()
                    .map(|sg| sg.adj.nnz() as f64 + 1.0)
                    .sum()
            })
            .collect();

        Ok(Partition {
            spec: *spec,
            owners,
            shards,
            costs,
            build_nanos: t0.elapsed().as_nanos() as u64,
        })
    }

    /// The spec this partition was built under.
    pub fn spec(&self) -> PartitionSpec {
        self.spec
    }

    /// Number of shards.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Owning shard of a node.
    pub fn owner_of(&self, ty: usize, node: u32) -> usize {
        self.owners[ty][node as usize] as usize
    }

    /// A `Send + Sync` snapshot of the ownership table for one node
    /// type. The serving runtime publishes this from the dispatcher
    /// thread so the *submit* side can account queued ids per shard
    /// lane (the [`Partition`] itself lives inside the non-`Send`
    /// executor). Out-of-range types yield an empty map.
    pub fn shard_map(&self, ty: usize) -> ShardMap {
        ShardMap {
            owners: self.owners.get(ty).cloned().unwrap_or_default(),
            shards: self.num_shards(),
        }
    }

    /// Per-shard modeled NA costs (LPT input for thread packing).
    pub fn shard_costs(&self) -> &[f64] {
        &self.costs
    }

    /// Partition quality summary.
    pub fn info(&self) -> ShardingInfo {
        let halo_rows = self.shards.iter().map(|s| s.halo_rows()).sum();
        let mean = self.costs.iter().sum::<f64>() / self.costs.len().max(1) as f64;
        let max = self.costs.iter().fold(0.0f64, |a, &b| a.max(b));
        ShardingInfo {
            shards: self.num_shards(),
            threads: self.spec.threads,
            halo_rows,
            imbalance: if mean > 0.0 { max / mean } else { 1.0 },
            cost_gini: stats::gini(&self.costs),
        }
    }

    /// Re-derive every shard plan's weights from `plan` (same shapes,
    /// new values) after a weight reload — R-GCN embedding tables are
    /// re-sliced to each shard's local rows. Topology is untouched.
    pub fn refresh_weights(&mut self, plan: &ModelPlan) {
        for shard in &mut self.shards {
            shard.plan.weights = shard_weights(plan, &shard.nodes);
        }
    }

    /// Incrementally patch the partition after an epoch flip: only the
    /// shards owning touched destinations (plus the shards receiving
    /// appended nodes) rematerialize their local spaces, sub-CSRs, halo
    /// tables and weight slices — clean shards are left byte-for-byte
    /// untouched. Returns the number of shards rebuilt.
    ///
    /// Existing nodes never migrate (their owner entries are stable);
    /// appended nodes go to the shard owning the fewest nodes of their
    /// type (ties to the lowest shard id). That greedy placement can
    /// diverge from what a cold LPT over the grown graph would choose —
    /// deliberately so: the bit-identity invariants at the top of this
    /// module hold for *any* ownership (owner computes over complete
    /// neighbor lists, canonical ascending local order), which
    /// `tests/integration_dynamic.rs` pins by comparing a patched
    /// sharded session against a cold unsharded one.
    ///
    /// `plan` must be the *post-flip* plan (its sub-CSRs already
    /// re-derived by [`crate::dynamic::apply_to_graph`]).
    pub fn patch(&mut self, plan: &ModelPlan, patch: &PatchSet) -> Result<usize> {
        let t0 = std::time::Instant::now();
        let k = self.num_shards();
        let n_types = self.owners.len();
        let mut dirty = vec![false; k];

        // appended nodes: extend the owner tables
        let mut counts_by_ty: HashMap<usize, Vec<usize>> = HashMap::new();
        for &(ty, id) in &patch.new_nodes {
            if ty >= n_types {
                return Err(Error::config(format!("patch: unknown node type {ty}")));
            }
            if id as usize != self.owners[ty].len() {
                return Err(Error::config(format!(
                    "patch: appended node {id} of type {ty} is not the next id ({})",
                    self.owners[ty].len()
                )));
            }
            let counts = counts_by_ty.entry(ty).or_insert_with(|| {
                let mut c = vec![0usize; k];
                for &o in &self.owners[ty] {
                    c[o as usize] += 1;
                }
                c
            });
            let s = (0..k).min_by_key(|&s| counts[s]).unwrap_or(0);
            counts[s] += 1;
            self.owners[ty].push(s as u32);
            dirty[s] = true;
        }

        // owners of structure/feature-touched destination rows
        for (si, touched) in patch.touched.iter().enumerate() {
            let ty = plan.subgraphs.subgraphs[si].dst_type;
            for &d in touched {
                dirty[self.owners[ty][d as usize] as usize] = true;
            }
        }

        // rematerialize dirty shards from the patched plan
        let mut rebuilt = 0;
        for s in 0..k {
            if !dirty[s] {
                continue;
            }
            let owned: Vec<Vec<u32>> = (0..n_types)
                .map(|ty| {
                    self.owners[ty]
                        .iter()
                        .enumerate()
                        .filter(|&(_, &o)| o as usize == s)
                        .map(|(g, _)| g as u32)
                        .collect()
                })
                .collect();
            let mut halo: Vec<Vec<u32>> = vec![Vec::new(); n_types];
            for sg in &plan.subgraphs.subgraphs {
                for &d in &owned[sg.dst_type] {
                    for &src in sg.adj.row(d as usize) {
                        if self.owners[sg.src_type][src as usize] as usize != s {
                            halo[sg.src_type].push(src);
                        }
                    }
                }
            }
            for list in halo.iter_mut() {
                list.sort_unstable();
                list.dedup();
            }
            self.shards[s] = materialize_shard(plan, owned, halo)?;
            self.costs[s] = self.shards[s]
                .plan
                .subgraphs
                .subgraphs
                .iter()
                .map(|sg| sg.adj.nnz() as f64 + 1.0)
                .sum();
            rebuilt += 1;
        }
        self.build_nanos += t0.elapsed().as_nanos() as u64;
        Ok(rebuilt)
    }
}

/// Materialize one shard from its owned and halo id lists: compact local
/// node spaces (owned ∪ halo, ascending in global id — the canonical
/// ordering that pins f32 accumulation order), restricted sub-CSRs
/// (owned destination rows keep their complete neighbor lists; halo rows
/// exist but carry no edges), the owner-computes merge plan, and the
/// shard-local weight slices. Shared by [`Partition::build`] (all
/// shards) and [`Partition::patch`] (dirty shards only).
fn materialize_shard(
    plan: &ModelPlan,
    owned: Vec<Vec<u32>>,
    halo: Vec<Vec<u32>>,
) -> Result<Shard> {
    let n_types = owned.len();
    let mut nodes: Vec<Vec<u32>> = Vec::with_capacity(n_types);
    let mut merge: Vec<Vec<(u32, u32)>> = Vec::with_capacity(n_types);
    let mut local: Vec<HashMap<u32, u32>> = Vec::with_capacity(n_types);
    for ty in 0..n_types {
        let mut list = owned[ty].clone();
        list.extend_from_slice(&halo[ty]);
        list.sort_unstable();
        let map: HashMap<u32, u32> =
            list.iter().enumerate().map(|(l, &g)| (g, l as u32)).collect();
        let m: Vec<(u32, u32)> = owned[ty].iter().map(|&g| (map[&g], g)).collect();
        nodes.push(list);
        merge.push(m);
        local.push(map);
    }

    let mut subgraphs = Vec::with_capacity(plan.num_subgraphs());
    for sg in &plan.subgraphs.subgraphs {
        let mut edges = Vec::new();
        for &d in &owned[sg.dst_type] {
            let l_dst = local[sg.dst_type][&d];
            for &src in sg.adj.row(d as usize) {
                edges.push((l_dst, local[sg.src_type][&src]));
            }
        }
        let adj =
            Coo::from_edges(nodes[sg.dst_type].len(), nodes[sg.src_type].len(), edges)?
                .to_csr();
        subgraphs.push(Subgraph {
            metapath: sg.metapath.clone(),
            name: sg.name.clone(),
            dst_type: sg.dst_type,
            src_type: sg.src_type,
            adj,
        });
    }

    let shard_plan = ModelPlan {
        model: plan.model,
        config: plan.config.clone(),
        subgraphs: SubgraphSet { subgraphs, build_nanos: 0 },
        weights: shard_weights(plan, &nodes),
        target: plan.target,
    };
    Ok(Shard { nodes, owned, halo, merge, plan: shard_plan })
}

/// Shard-local copy of the plan weights: every field cloned except the
/// R-GCN embedding tables, which are sliced (never cloned whole — they
/// are the one weight object that scales with the graph) to the shard's
/// local rows.
fn shard_weights(plan: &ModelPlan, nodes: &[Vec<u32>]) -> crate::models::ModelWeights {
    crate::models::ModelWeights {
        proj: plan.weights.proj.clone(),
        embed: plan
            .weights
            .embed
            .iter()
            .map(|(&ty, e)| (ty, gather_rows(e, &nodes[ty])))
            .collect(),
        attn_l: plan.weights.attn_l.clone(),
        attn_r: plan.weights.attn_r.clone(),
        inst_attn: plan.weights.inst_attn.clone(),
        sem_w: plan.weights.sem_w.clone(),
        sem_b: plan.weights.sem_b.clone(),
        sem_q: plan.weights.sem_q.clone(),
    }
}

/// Gather rows of `x` at `ids` into a compact `[ids.len(), cols]` tensor.
fn gather_rows(x: &Tensor, ids: &[u32]) -> Tensor {
    let mut out = Tensor::zeros(ids.len(), x.cols());
    for (l, &g) in ids.iter().enumerate() {
        out.set_row(l, x.row(g as usize));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{self, DatasetId, DatasetScale};
    use crate::models::{self, ModelConfig, ModelId};

    fn imdb(model: ModelId) -> (HeteroGraph, ModelPlan) {
        let hg = datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap();
        let plan = models::build_plan(model, &hg, &ModelConfig::default()).unwrap();
        (hg, plan)
    }

    #[test]
    fn spec_validation() {
        assert_eq!(PartitionSpec::new(4).threads, 4);
        assert_eq!(PartitionSpec::new(4).with_threads(2).threads, 2);
        let (hg, plan) = imdb(ModelId::Han);
        assert!(Partition::build(&hg, &plan, &PartitionSpec::new(0)).is_err());
        assert!(
            Partition::build(&hg, &plan, &PartitionSpec::new(2).with_threads(0)).is_err()
        );
    }

    #[test]
    fn owned_sets_are_a_disjoint_cover() {
        for model in [ModelId::Han, ModelId::Rgcn, ModelId::Magnn] {
            let (hg, plan) = imdb(model);
            for k in [1, 2, 4] {
                let part = Partition::build(&hg, &plan, &PartitionSpec::new(k)).unwrap();
                assert_eq!(part.num_shards(), k);
                for (ty, t) in hg.node_types().iter().enumerate() {
                    let mut seen = vec![0u32; t.count];
                    for shard in &part.shards {
                        for &g in &shard.owned[ty] {
                            seen[g as usize] += 1;
                        }
                    }
                    assert!(
                        seen.iter().all(|&c| c == 1),
                        "{model:?} k={k}: type {ty} not a disjoint cover"
                    );
                }
            }
        }
    }

    #[test]
    fn shard_map_mirrors_owner_of() {
        let (hg, plan) = imdb(ModelId::Han);
        let part = Partition::build(&hg, &plan, &PartitionSpec::new(3)).unwrap();
        for (ty, t) in hg.node_types().iter().enumerate() {
            let map = part.shard_map(ty);
            assert_eq!(map.num_shards(), 3);
            assert_eq!(map.len(), t.count);
            for node in 0..t.count as u32 {
                assert_eq!(map.shard_of(node), part.owner_of(ty, node));
            }
        }
        // out-of-range type is total, not a panic
        let empty = part.shard_map(999);
        assert!(empty.is_empty());
        assert_eq!(empty.shard_of(0), 0);
        // ids wrap modulo the table length, like Session::run_batch
        let map = part.shard_map(0);
        let n = map.len() as u32;
        assert_eq!(map.shard_of(u32::MAX), part.owner_of(0, u32::MAX % n));
        // the map is Send + Sync (what the serving submit side needs)
        fn assert_send_sync<T: Send + Sync>(_: &T) {}
        assert_send_sync(&part.shard_map(0));
    }

    #[test]
    fn halo_references_only_foreign_nodes() {
        let (hg, plan) = imdb(ModelId::Rgcn);
        let part = Partition::build(&hg, &plan, &PartitionSpec::new(3)).unwrap();
        for (s, shard) in part.shards.iter().enumerate() {
            for (ty, list) in shard.halo.iter().enumerate() {
                for &g in list {
                    assert_ne!(
                        part.owner_of(ty, g),
                        s,
                        "shard {s} halo holds its own node {g} of type {ty}"
                    );
                }
            }
        }
    }

    #[test]
    fn owned_rows_keep_complete_neighbor_lists() {
        let (hg, plan) = imdb(ModelId::Han);
        let part = Partition::build(&hg, &plan, &PartitionSpec::new(2)).unwrap();
        // every owned destination row's local sources map back to exactly
        // the global row, in ascending order
        for shard in &part.shards {
            for (si, sg) in shard.plan.subgraphs.subgraphs.iter().enumerate() {
                let global = &plan.subgraphs.subgraphs[si];
                for &(l, g) in &shard.merge[sg.dst_type] {
                    let local_srcs: Vec<u32> = sg
                        .adj
                        .row(l as usize)
                        .iter()
                        .map(|&ls| shard.nodes[sg.src_type][ls as usize])
                        .collect();
                    assert_eq!(local_srcs, global.adj.row(g as usize).to_vec());
                }
            }
        }
    }

    #[test]
    fn halo_rows_carry_no_edges() {
        let (hg, plan) = imdb(ModelId::Han);
        let part = Partition::build(&hg, &plan, &PartitionSpec::new(2)).unwrap();
        for (s, shard) in part.shards.iter().enumerate() {
            for sg in &shard.plan.subgraphs.subgraphs {
                for (l, &g) in shard.nodes[sg.dst_type].iter().enumerate() {
                    if part.owner_of(sg.dst_type, g) != s {
                        assert_eq!(sg.adj.degree(l), 0, "halo row {g} has edges");
                    }
                }
            }
        }
    }

    #[test]
    fn local_ids_ascend_with_global_ids() {
        let (hg, plan) = imdb(ModelId::Magnn);
        let part = Partition::build(&hg, &plan, &PartitionSpec::new(4)).unwrap();
        for shard in &part.shards {
            for list in &shard.nodes {
                assert!(list.windows(2).all(|w| w[0] < w[1]));
            }
        }
    }

    #[test]
    fn single_shard_owns_everything_with_no_halo() {
        let (hg, plan) = imdb(ModelId::Han);
        let part = Partition::build(&hg, &plan, &PartitionSpec::new(1)).unwrap();
        let info = part.info();
        assert_eq!(info.halo_rows, 0);
        assert!((info.imbalance - 1.0).abs() < 1e-12);
        for (ty, t) in hg.node_types().iter().enumerate() {
            assert_eq!(part.shards[0].owned[ty].len(), t.count);
        }
    }

    #[test]
    fn costs_are_roughly_balanced() {
        let (hg, plan) = imdb(ModelId::Han);
        let part = Partition::build(&hg, &plan, &PartitionSpec::new(4)).unwrap();
        let info = part.info();
        // LPT over per-vertex costs keeps the max shard within 2x of the
        // mean on any non-degenerate graph
        assert!(info.imbalance < 2.0, "imbalance {}", info.imbalance);
        assert!(info.cost_gini < 0.5, "gini {}", info.cost_gini);
        assert!(info.label().contains("4 shards"));
    }

    #[test]
    fn patch_rebuilds_only_dirty_shards_and_keeps_invariants() {
        use crate::dynamic::{apply_to_graph, GraphUpdate};
        let (mut hg, mut plan) = imdb(ModelId::Han);
        let mut part = Partition::build(&hg, &plan, &PartitionSpec::new(4)).unwrap();
        // remember which sub-CSRs each shard held before the flip
        let before: Vec<Vec<crate::graph::sparse::Csr>> = part
            .shards
            .iter()
            .map(|sh| sh.plan.subgraphs.subgraphs.iter().map(|sg| sg.adj.clone()).collect())
            .collect();

        // one new movie node plus an edge wiring it into M-D
        let m = hg.type_by_tag('M').unwrap();
        let dim = hg.node_type(m).feat_dim;
        let md = hg.relations().iter().position(|r| r.name == "M-D").unwrap();
        let new_id = hg.node_type(m).count as u32;
        let ps = apply_to_graph(
            &mut hg,
            &mut plan,
            vec![
                GraphUpdate::AddNode { ty: m, features: vec![0.0; dim] },
                GraphUpdate::AddEdge { relation: md, dst: 0, src: new_id },
            ],
        )
        .unwrap();
        let rebuilt = part.patch(&plan, &ps).unwrap();
        assert!(rebuilt >= 1 && rebuilt <= 4);

        // dirty shards = owners of touched dsts + the new node's shard
        let mut expect_dirty = vec![false; 4];
        expect_dirty[part.owner_of(m, new_id)] = true;
        for (si, touched) in ps.touched.iter().enumerate() {
            let ty = plan.subgraphs.subgraphs[si].dst_type;
            for &d in touched {
                expect_dirty[part.owner_of(ty, d)] = true;
            }
        }
        assert_eq!(rebuilt, expect_dirty.iter().filter(|&&b| b).count());
        // clean shards kept their materialization byte-for-byte
        for (s, shard) in part.shards.iter().enumerate() {
            if !expect_dirty[s] {
                for (si, sg) in shard.plan.subgraphs.subgraphs.iter().enumerate() {
                    assert_eq!(sg.adj, before[s][si], "clean shard {s} was rebuilt");
                }
            }
        }

        // global invariants hold over the grown graph: disjoint cover...
        for (ty, t) in hg.node_types().iter().enumerate() {
            let mut seen = vec![0u32; t.count];
            for shard in &part.shards {
                for &g in &shard.owned[ty] {
                    seen[g as usize] += 1;
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "type {ty} cover broken after patch");
        }
        // ...complete neighbor lists in canonical order on every shard
        for shard in &part.shards {
            for (si, sg) in shard.plan.subgraphs.subgraphs.iter().enumerate() {
                let global = &plan.subgraphs.subgraphs[si];
                for &(l, g) in &shard.merge[sg.dst_type] {
                    let local_srcs: Vec<u32> = sg
                        .adj
                        .row(l as usize)
                        .iter()
                        .map(|&ls| shard.nodes[sg.src_type][ls as usize])
                        .collect();
                    assert_eq!(local_srcs, global.adj.row(g as usize).to_vec());
                }
            }
        }
    }

    #[test]
    fn patch_rejects_gapped_node_ids() {
        use crate::dynamic::PatchSet;
        let (hg, plan) = imdb(ModelId::Han);
        let mut part = Partition::build(&hg, &plan, &PartitionSpec::new(2)).unwrap();
        let m = hg.type_by_tag('M').unwrap();
        let bogus = PatchSet {
            touched: vec![Vec::new(); plan.num_subgraphs()],
            rebuilt: vec![false; plan.num_subgraphs()],
            feat_touched: Vec::new(),
            new_nodes: vec![(m, hg.node_type(m).count as u32 + 5)],
            new_weights: None,
            updates_applied: 1,
        };
        assert!(part.patch(&plan, &bogus).is_err());
    }

    #[test]
    fn rgcn_embeddings_slice_to_local_rows() {
        let (hg, plan) = imdb(ModelId::Rgcn);
        let mut part = Partition::build(&hg, &plan, &PartitionSpec::new(2)).unwrap();
        for shard in &part.shards {
            for (&ty, embed) in &shard.plan.weights.embed {
                assert_eq!(embed.rows(), shard.nodes[ty].len());
                for (l, &g) in shard.nodes[ty].iter().enumerate() {
                    assert_eq!(embed.row(l), plan.weights.embed[&ty].row(g as usize));
                }
            }
        }
        // refresh re-slices from the (possibly new) parent weights
        part.refresh_weights(&plan);
        for shard in &part.shards {
            for (&ty, embed) in &shard.plan.weights.embed {
                assert_eq!(embed.rows(), shard.nodes[ty].len());
            }
        }
    }
}
