//! Profiling infrastructure — the Nsight Systems stand-in.
//!
//! Attributes every executed kernel to (stage, subgraph, worker), keeps
//! wallclock begin/end timestamps for timeline rendering (Fig 5c), and
//! aggregates into the breakdowns the paper reports: per-stage execution
//! time (Fig 2), per-kernel-type time within each stage (Fig 3), and the
//! per-kernel metric table (Table 3).
//!
//! Two time bases coexist:
//! * **wall** — CPU nanoseconds of the native Rust kernels (real, but a
//!   CPU is not a T4);
//! * **modeled** — the [`crate::gpumodel`] T4 latency per kernel, which is
//!   the basis every paper-figure bench reports (DESIGN.md §4).

pub mod timeline;

use std::collections::BTreeMap;

use crate::gpumodel::{GpuModel, KernelMetrics};
use crate::kernels::{KernelExec, KernelType};

pub use timeline::{Timeline, TimelineSpan};

/// The paper's execution stages (§2). `SubgraphBuild` runs on the CPU
/// before inference and is excluded from GPU breakdowns, as in Fig 2.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum StageId {
    /// ① Subgraph Build (CPU-side; excluded from the GPU profile).
    SubgraphBuild,
    /// ② Feature Projection.
    FeatureProjection,
    /// ③ Neighbor Aggregation.
    NeighborAggregation,
    /// ④ Semantic Aggregation.
    SemanticAggregation,
}

impl StageId {
    /// The GPU-profiled stages, in paper order.
    pub const GPU_STAGES: [StageId; 3] = [
        StageId::FeatureProjection,
        StageId::NeighborAggregation,
        StageId::SemanticAggregation,
    ];

    /// Paper abbreviation.
    pub fn abbrev(self) -> &'static str {
        match self {
            StageId::SubgraphBuild => "SB",
            StageId::FeatureProjection => "FP",
            StageId::NeighborAggregation => "NA",
            StageId::SemanticAggregation => "SA",
        }
    }

    /// Full stage name as the paper prints it.
    pub fn name(self) -> &'static str {
        match self {
            StageId::SubgraphBuild => "Subgraph Build",
            StageId::FeatureProjection => "Feature Projection",
            StageId::NeighborAggregation => "Neighbor Aggregation",
            StageId::SemanticAggregation => "Semantic Aggregation",
        }
    }
}

/// One profiled kernel: execution record + attribution + modeled metrics.
#[derive(Debug, Clone)]
pub struct ProfiledKernel {
    /// The raw execution record.
    pub exec: KernelExec,
    /// Stage this kernel belongs to.
    pub stage: StageId,
    /// Subgraph (metapath/relation) name, when stage work is per-subgraph.
    pub subgraph: Option<String>,
    /// Worker/stream index that issued the kernel (0 when sequential).
    pub worker: usize,
    /// Wallclock begin, nanoseconds since profile start.
    pub wall_begin: u64,
    /// Modeled T4 metrics (filled by [`Profile::attach_metrics`]).
    pub metrics: Option<KernelMetrics>,
}

/// A complete profile of one inference run.
#[derive(Debug, Clone, Default)]
pub struct Profile {
    /// All profiled kernels in issue order.
    pub kernels: Vec<ProfiledKernel>,
    /// CPU nanoseconds spent in Subgraph Build (stage ①).
    pub subgraph_build_nanos: u64,
    /// Cumulative reuse-cache counters when the run executed through the
    /// cache-aware serving path (`None` for plain runs).
    pub reuse: Option<crate::reuse::ReuseStats>,
    /// Worker-pool width in effect while the kernels executed (the
    /// intra-kernel `parallel_for` cap — see [`crate::parallel`]); 0
    /// when the producer predates the pool or did not record it. Kernel
    /// `wall_nanos` are real elapsed wallclock around the (possibly
    /// parallel) kernel, so this is the context that keeps wall-derived
    /// numbers honest.
    pub pool_threads: usize,
}

impl Profile {
    /// Record a batch of kernel executions under one attribution.
    pub fn record(
        &mut self,
        mut execs: Vec<KernelExec>,
        stage: StageId,
        subgraph: Option<&str>,
        worker: usize,
        wall_begin: u64,
    ) {
        self.record_drain(&mut execs, stage, subgraph, worker, wall_begin);
    }

    /// Record by draining an event buffer in place — the buffer's
    /// allocation survives, so a session-held [`crate::kernels::Ctx`]
    /// stops allocating after its first run.
    pub fn record_drain(
        &mut self,
        execs: &mut Vec<KernelExec>,
        stage: StageId,
        subgraph: Option<&str>,
        worker: usize,
        wall_begin: u64,
    ) {
        let mut at = wall_begin;
        for exec in execs.drain(..) {
            let dur = exec.wall_nanos;
            self.kernels.push(ProfiledKernel {
                exec,
                stage,
                subgraph: subgraph.map(|s| s.to_string()),
                worker,
                wall_begin: at,
                metrics: None,
            });
            at += dur;
        }
    }

    /// Run the GPU model over every kernel and attach metrics.
    pub fn attach_metrics(&mut self, model: &GpuModel) {
        for pk in &mut self.kernels {
            let m = model.analyze(std::slice::from_ref(&pk.exec));
            pk.metrics = m.into_iter().next();
        }
    }

    /// Modeled nanoseconds of one kernel (0 when metrics not attached).
    fn modeled_ns(pk: &ProfiledKernel) -> f64 {
        pk.metrics.as_ref().map(|m| m.time_ns).unwrap_or(0.0)
    }

    /// Total modeled time across GPU stages.
    pub fn total_modeled_ns(&self) -> f64 {
        self.kernels.iter().map(Self::modeled_ns).sum()
    }

    /// Total wallclock time of native kernels.
    pub fn total_wall_ns(&self) -> u64 {
        self.kernels.iter().map(|k| k.exec.wall_nanos).sum()
    }

    /// Per-stage modeled time (Fig 2 input).
    pub fn stage_times(&self) -> BTreeMap<StageId, f64> {
        let mut out = BTreeMap::new();
        for pk in &self.kernels {
            *out.entry(pk.stage).or_insert(0.0) += Self::modeled_ns(pk);
        }
        out
    }

    /// Per-stage percentage breakdown over GPU stages (Fig 2).
    pub fn stage_percentages(&self) -> BTreeMap<StageId, f64> {
        let times = self.stage_times();
        let total: f64 = StageId::GPU_STAGES
            .iter()
            .map(|s| times.get(s).copied().unwrap_or(0.0))
            .sum();
        let mut out = BTreeMap::new();
        for s in StageId::GPU_STAGES {
            let t = times.get(&s).copied().unwrap_or(0.0);
            out.insert(s, if total == 0.0 { 0.0 } else { 100.0 * t / total });
        }
        out
    }

    /// Per-(stage, kernel-type) modeled time (Fig 3 input).
    pub fn kernel_type_times(&self) -> BTreeMap<(StageId, KernelType), f64> {
        let mut out = BTreeMap::new();
        for pk in &self.kernels {
            *out.entry((pk.stage, pk.exec.ktype)).or_insert(0.0) += Self::modeled_ns(pk);
        }
        out
    }

    /// Per-kernel-name aggregation within a stage (Table 3 input):
    /// returns (name, aggregated metrics, % of stage time), sorted by
    /// descending time share.
    pub fn kernel_table(&self, stage: StageId) -> Vec<(String, KernelMetrics, f64)> {
        let mut by_name: BTreeMap<&'static str, Vec<KernelMetrics>> = BTreeMap::new();
        for pk in &self.kernels {
            if pk.stage == stage {
                if let Some(m) = &pk.metrics {
                    by_name.entry(pk.exec.name).or_default().push(m.clone());
                }
            }
        }
        let stage_total: f64 = by_name.values().flatten().map(|m| m.time_ns).sum();
        let mut rows: Vec<(String, KernelMetrics, f64)> = by_name
            .into_iter()
            .filter_map(|(name, ms)| {
                crate::gpumodel::metrics::aggregate(&ms).map(|agg| {
                    let share = if stage_total == 0.0 {
                        0.0
                    } else {
                        100.0 * agg.time_ns / stage_total
                    };
                    (name.to_string(), agg, share)
                })
            })
            .collect();
        rows.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
        rows
    }

    /// Human-readable stage breakdown (quickstart output).
    pub fn stage_breakdown(&self) -> String {
        let pct = self.stage_percentages();
        let times = self.stage_times();
        let mut out = String::from("stage breakdown (modeled T4 time):\n");
        for s in StageId::GPU_STAGES {
            out.push_str(&format!(
                "  {:<22} {:>8.1}%  {}\n",
                s.name(),
                pct.get(&s).copied().unwrap_or(0.0),
                crate::util::human_time(times.get(&s).copied().unwrap_or(0.0)),
            ));
        }
        out.push_str(&format!(
            "  (Subgraph Build on CPU: {}, excluded as in the paper)\n",
            crate::util::human_time(self.subgraph_build_nanos as f64)
        ));
        if self.pool_threads > 1 {
            out.push_str(&format!(
                "  (native kernel wallclock measured at pool width {})\n",
                self.pool_threads
            ));
        }
        if let Some(r) = &self.reuse {
            out.push_str(&format!("  {}\n", r.line()));
        }
        out
    }

    /// Build a modeled-time timeline (Fig 5c input): one lane per
    /// (worker, stage), spans scheduled at each kernel's modeled start.
    pub fn timeline(&self) -> Timeline {
        timeline::build_timeline(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{Ctx, KernelCounters};

    fn fake_exec(name: &'static str, ktype: KernelType, wall: u64) -> KernelExec {
        KernelExec {
            name,
            ktype,
            counters: KernelCounters {
                flops: 1_000_000,
                bytes_read: 8_000_000,
                bytes_written: 4_000_000,
            },
            wall_nanos: wall,
            trace: None,
        }
    }

    fn sample_profile() -> Profile {
        let mut p = Profile::default();
        p.record(
            vec![fake_exec("sgemm", KernelType::DenseMatmul, 100)],
            StageId::FeatureProjection,
            None,
            0,
            0,
        );
        p.record(
            vec![
                fake_exec("SpMMCsr", KernelType::TopologyBased, 500),
                fake_exec("SpMMCsr", KernelType::TopologyBased, 400),
            ],
            StageId::NeighborAggregation,
            Some("MDM"),
            0,
            100,
        );
        p.record(
            vec![fake_exec("Concat", KernelType::DataRearrange, 50)],
            StageId::SemanticAggregation,
            None,
            0,
            1000,
        );
        p.attach_metrics(&GpuModel::default());
        p
    }

    #[test]
    fn record_orders_wall_begin() {
        let p = sample_profile();
        assert_eq!(p.kernels[1].wall_begin, 100);
        assert_eq!(p.kernels[2].wall_begin, 600); // 100 + 500
        assert_eq!(p.total_wall_ns(), 1050);
    }

    #[test]
    fn stage_percentages_sum_to_100() {
        let p = sample_profile();
        let pct = p.stage_percentages();
        let sum: f64 = pct.values().sum();
        assert!((sum - 100.0).abs() < 1e-6, "sum {sum}");
        // NA has two identical kernels; with equal counters each stage's
        // share is proportional to kernel count
        assert!(pct[&StageId::NeighborAggregation] > pct[&StageId::FeatureProjection]);
    }

    #[test]
    fn kernel_type_times_keyed_correctly() {
        let p = sample_profile();
        let ktt = p.kernel_type_times();
        assert!(ktt
            .contains_key(&(StageId::NeighborAggregation, KernelType::TopologyBased)));
        assert!(!ktt.contains_key(&(StageId::FeatureProjection, KernelType::TopologyBased)));
    }

    #[test]
    fn kernel_table_shares() {
        let p = sample_profile();
        let rows = p.kernel_table(StageId::NeighborAggregation);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].0, "SpMMCsr");
        assert!((rows[0].2 - 100.0).abs() < 1e-6);
        assert!(p.kernel_table(StageId::SubgraphBuild).is_empty());
    }

    #[test]
    fn breakdown_renders() {
        let p = sample_profile();
        let s = p.stage_breakdown();
        assert!(s.contains("Neighbor Aggregation"));
        assert!(s.contains("Subgraph Build"));
    }

    #[test]
    fn record_from_ctx_drain() {
        let mut ctx = Ctx::default();
        ctx.push(
            "uEleWise",
            KernelType::ElementWise,
            KernelCounters::default(),
            42,
            None,
        );
        let mut p = Profile::default();
        p.record(ctx.drain(), StageId::SemanticAggregation, None, 1, 7);
        assert_eq!(p.kernels.len(), 1);
        assert_eq!(p.kernels[0].worker, 1);
        assert_eq!(p.kernels[0].wall_begin, 7);
    }
}
