//! Modeled-time timeline rendering (paper Fig 5c).
//!
//! Fig 5(c) is an Nsight Systems screenshot showing (1) the per-subgraph
//! Neighbor Aggregation kernels of HAN running on independent streams —
//! *inter-subgraph parallelism* — and (2) the synchronization *barrier*
//! before Semantic Aggregation, which needs every subgraph's result to
//! compute attention weights. We reproduce the same information as an
//! ASCII lane chart over modeled T4 time: one lane per (worker, stage),
//! spans scheduled by the coordinator.

use std::collections::BTreeMap;

use crate::profiler::{Profile, StageId};

/// One scheduled span on the timeline.
#[derive(Debug, Clone, PartialEq)]
pub struct TimelineSpan {
    /// Label: kernel or subgraph name.
    pub label: String,
    /// Stage the span belongs to.
    pub stage: StageId,
    /// Start, modeled nanoseconds from run begin.
    pub begin_ns: f64,
    /// End, modeled nanoseconds.
    pub end_ns: f64,
}

/// A set of named lanes holding non-overlapping spans.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    /// Lane name → spans (sorted by begin).
    pub lanes: BTreeMap<String, Vec<TimelineSpan>>,
    /// Barrier positions (modeled ns), e.g. the NA→SA barrier.
    pub barriers: Vec<(String, f64)>,
}

impl Timeline {
    /// Add a span to a lane.
    pub fn push(&mut self, lane: &str, span: TimelineSpan) {
        self.lanes.entry(lane.to_string()).or_default().push(span);
    }

    /// Mark a labelled barrier at the given time.
    pub fn add_barrier(&mut self, label: &str, at_ns: f64) {
        self.barriers.push((label.to_string(), at_ns));
    }

    /// Latest span end across lanes.
    pub fn end_ns(&self) -> f64 {
        self.lanes
            .values()
            .flatten()
            .map(|s| s.end_ns)
            .fold(0.0, f64::max)
    }

    /// True if any two lanes have temporally overlapping spans — the
    /// signature of inter-subgraph parallelism.
    pub fn has_cross_lane_overlap(&self) -> bool {
        let lanes: Vec<&Vec<TimelineSpan>> = self.lanes.values().collect();
        for i in 0..lanes.len() {
            for j in i + 1..lanes.len() {
                for a in lanes[i] {
                    for b in lanes[j] {
                        if a.begin_ns < b.end_ns && b.begin_ns < a.end_ns {
                            return true;
                        }
                    }
                }
            }
        }
        false
    }

    /// Render as an ASCII chart, `width` characters across.
    pub fn render(&self, width: usize) -> String {
        let end = self.end_ns().max(1.0);
        let scale = |t: f64| -> usize {
            (((t / end) * (width - 1) as f64).round() as usize).min(width - 1)
        };
        let mut out = String::new();
        out.push_str(&format!(
            "timeline (modeled T4 time, total {})\n",
            crate::util::human_time(end)
        ));
        for (lane, spans) in &self.lanes {
            let mut row = vec![' '; width];
            for s in spans {
                let b = scale(s.begin_ns);
                let e = scale(s.end_ns).max(b);
                let ch = s.label.chars().next().unwrap_or('#');
                for c in row.iter_mut().take(e + 1).skip(b) {
                    *c = ch;
                }
            }
            for (_, at) in &self.barriers {
                let col = scale(*at);
                if row[col] == ' ' {
                    row[col] = '|';
                } else {
                    row[col] = '!';
                }
            }
            out.push_str(&format!(
                "  {:<18} {}\n",
                lane,
                row.iter().collect::<String>()
            ));
        }
        for (label, at) in &self.barriers {
            out.push_str(&format!(
                "  barrier '{}' at {}\n",
                label,
                crate::util::human_time(*at)
            ));
        }
        out
    }
}

/// Build a timeline from a profile: kernels are laid out lane-by-lane
/// using modeled durations, preserving the worker attribution the
/// coordinator recorded. Within a (worker, stage) lane spans are placed
/// back-to-back following issue order; stages are serialized in paper
/// order with a barrier where NA hands off to SA.
pub fn build_timeline(profile: &Profile) -> Timeline {
    let mut tl = Timeline::default();
    let mut stage_start = 0.0f64;
    for stage in [
        StageId::FeatureProjection,
        StageId::NeighborAggregation,
        StageId::SemanticAggregation,
    ] {
        // per-worker cursors within this stage
        let mut cursors: BTreeMap<usize, f64> = BTreeMap::new();
        for pk in profile.kernels.iter().filter(|k| k.stage == stage) {
            let dur = pk.metrics.as_ref().map(|m| m.time_ns).unwrap_or(0.0);
            let cur = cursors.entry(pk.worker).or_insert(stage_start);
            let begin = *cur;
            let end = begin + dur;
            *cur = end;
            let lane = match &pk.subgraph {
                Some(sg) => format!("{} w{} [{}]", stage.abbrev(), pk.worker, sg),
                None => format!("{} w{}", stage.abbrev(), pk.worker),
            };
            tl.push(
                &lane,
                TimelineSpan {
                    label: pk.exec.name.to_string(),
                    stage,
                    begin_ns: begin,
                    end_ns: end,
                },
            );
        }
        let stage_end = cursors.values().cloned().fold(stage_start, f64::max);
        if stage == StageId::NeighborAggregation && stage_end > stage_start {
            tl.add_barrier("NA→SA", stage_end);
        }
        stage_start = stage_end;
    }
    tl
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(label: &str, b: f64, e: f64) -> TimelineSpan {
        TimelineSpan {
            label: label.into(),
            stage: StageId::NeighborAggregation,
            begin_ns: b,
            end_ns: e,
        }
    }

    #[test]
    fn overlap_detection() {
        let mut tl = Timeline::default();
        tl.push("a", span("x", 0.0, 10.0));
        tl.push("b", span("y", 20.0, 30.0));
        assert!(!tl.has_cross_lane_overlap());
        tl.push("b", span("z", 5.0, 8.0));
        assert!(tl.has_cross_lane_overlap());
    }

    #[test]
    fn render_contains_lanes_and_barriers() {
        let mut tl = Timeline::default();
        tl.push("NA w0 [MDM]", span("SpMMCsr", 0.0, 50.0));
        tl.push("NA w1 [MAM]", span("SpMMCsr", 0.0, 40.0));
        tl.add_barrier("NA→SA", 50.0);
        let r = tl.render(60);
        assert!(r.contains("NA w0 [MDM]"));
        assert!(r.contains("barrier 'NA→SA'"));
        assert!(r.contains('S')); // span initial
    }

    #[test]
    fn end_ns_tracks_max() {
        let mut tl = Timeline::default();
        assert_eq!(tl.end_ns(), 0.0);
        tl.push("a", span("x", 0.0, 10.0));
        tl.push("b", span("y", 3.0, 25.0));
        assert_eq!(tl.end_ns(), 25.0);
    }
}
