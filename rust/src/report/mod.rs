//! Report rendering: ASCII tables, CSV, and paper-figure printers.
//!
//! Every bench target funnels through here so the figures/tables come out
//! in the same format: a header naming the paper artifact, the measured
//! series, and (where the paper gives numbers) the paper's value next to
//! ours for an honest comparison.

use std::collections::BTreeMap;

use crate::gpumodel::KernelMetrics;
use crate::kernels::KernelType;
use crate::profiler::{Profile, StageId};
use crate::tensor::Tensor;
use crate::util::fmt::{pad_left, pad_right};

/// A simple ASCII table builder.
#[derive(Debug, Default)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Start a table with column headers.
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row (must match header count).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells.to_vec());
        self
    }

    /// Render with padding; first column left-aligned, rest right-aligned.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("| ");
            for i in 0..ncols {
                let cell = if i == 0 {
                    pad_right(&cells[i], widths[i])
                } else {
                    pad_left(&cells[i], widths[i])
                };
                line.push_str(&cell);
                line.push_str(" | ");
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        let sep: String = widths
            .iter()
            .map(|w| format!("|{}", "-".repeat(w + 2)))
            .collect::<String>()
            + "|";
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// An ASCII horizontal bar chart for percentage breakdowns
/// (the Fig 2 / Fig 3 stacked bars, unrolled).
pub fn bar_chart(title: &str, series: &[(String, f64)], width: usize) -> String {
    let max = series.iter().map(|(_, v)| *v).fold(0.0f64, f64::max).max(1e-9);
    let mut out = format!("{title}\n");
    for (label, value) in series {
        let bars = ((value / max) * width as f64).round() as usize;
        out.push_str(&format!(
            "  {:<24} {:>8.1}  {}\n",
            label,
            value,
            "█".repeat(bars)
        ));
    }
    out
}

/// Per-type destination-degree skew table of a heterogeneous graph —
/// the NA load-imbalance fingerprint (paper §4.2: skewed destination
/// degrees serialize the dominant stage) and the quantity the
/// degree-balanced partitioner ([`crate::partition`]) flattens across
/// shards. Degrees are summed over every relation targeting the type.
pub fn degree_skew_table(hg: &crate::graph::HeteroGraph) -> String {
    let mut table = Table::new(&["type", "nodes", "mean deg", "max deg", "max/mean", "gini"]);
    for (ty, t) in hg.node_types().iter().enumerate() {
        let mut degrees = vec![0.0f64; t.count];
        for rel in hg.relations() {
            if rel.dst == ty {
                for (d, deg) in degrees.iter_mut().enumerate() {
                    *deg += rel.adj.degree(d) as f64;
                }
            }
        }
        let skew = crate::util::stats::degree_skew(&degrees);
        table.row(&[
            t.name.clone(),
            format!("{}", t.count),
            format!("{:.2}", skew.mean),
            format!("{:.0}", skew.max),
            format!("{:.2}", skew.max_mean_ratio),
            format!("{:.3}", skew.gini),
        ]);
    }
    format!("per-type degree skew (NA load-imbalance driver):\n{}", table.render())
}

/// Render the Fig 2 stage breakdown for one (model, dataset) run.
pub fn fig2_row(model: &str, dataset: &str, profile: &Profile) -> String {
    let pct = profile.stage_percentages();
    format!(
        "{:<7} {:<4} | FP {:>5.1}% | NA {:>5.1}% | SA {:>5.1}%",
        model,
        dataset,
        pct.get(&StageId::FeatureProjection).copied().unwrap_or(0.0),
        pct.get(&StageId::NeighborAggregation).copied().unwrap_or(0.0),
        pct.get(&StageId::SemanticAggregation).copied().unwrap_or(0.0),
    )
}

/// Render the Fig 3 per-stage kernel-type breakdown for one run.
pub fn fig3_rows(model: &str, dataset: &str, profile: &Profile) -> String {
    let ktt = profile.kernel_type_times();
    let mut out = String::new();
    for stage in StageId::GPU_STAGES {
        let total: f64 = KernelType::ALL
            .iter()
            .map(|&t| ktt.get(&(stage, t)).copied().unwrap_or(0.0))
            .sum();
        if total == 0.0 {
            continue;
        }
        let mut parts = Vec::new();
        for t in KernelType::ALL {
            let v = ktt.get(&(stage, t)).copied().unwrap_or(0.0);
            parts.push(format!("{} {:>5.1}%", t.abbrev(), 100.0 * v / total));
        }
        out.push_str(&format!(
            "{:<7} {:<4} {:<3} | {}\n",
            model,
            dataset,
            stage.abbrev(),
            parts.join(" | ")
        ));
    }
    out
}

/// Render a Table 3-style kernel metrics table for one stage.
pub fn table3_stage(stage: StageId, rows: &[(String, KernelMetrics, f64)]) -> String {
    let mut t = Table::new(&[
        "Kernel",
        "Type",
        "Time(%)",
        "PeakPerf(%)",
        "DRAM BW(%)",
        "SMEM BW(%)",
        "L2 Hit(%)",
        "AI(F/B)",
    ]);
    for (name, m, share) in rows {
        t.row(&[
            name.clone(),
            m.ktype.abbrev().to_string(),
            format!("{share:.1}"),
            format!("{:.1}", m.peak_perf_pct),
            format!("{:.1}", m.dram_bw_util_pct),
            format!("{:.1}", m.smem_bw_util_pct),
            format!("{:.1}", m.l2_hit_pct),
            format!("{:.2}", m.ai),
        ]);
    }
    format!("{} ({})\n{}", stage.name(), stage.abbrev(), t.render())
}

/// Paper-vs-measured comparison row for EXPERIMENTS.md.
pub fn compare(metric: &str, paper: f64, measured: f64, unit: &str) -> String {
    let ratio = if paper != 0.0 { measured / paper } else { f64::NAN };
    format!(
        "  {:<42} paper {:>9.2}{:<7} measured {:>9.2}{:<7} ratio {:>5.2}",
        metric, paper, unit, measured, unit, ratio
    )
}

/// Series printer for sweep figures (Fig 5a/5b/6a/6b): x, y pairs plus a
/// monotonicity note.
pub fn sweep_series(title: &str, xlabel: &str, ylabel: &str, pts: &[(f64, f64)]) -> String {
    let mut out = format!("{title}\n  {xlabel:>16} | {ylabel}\n");
    for (x, y) in pts {
        out.push_str(&format!("  {x:>16.3} | {y:.4}\n"));
    }
    let increasing = pts.windows(2).all(|w| w[1].1 >= w[0].1 - 1e-12);
    let decreasing = pts.windows(2).all(|w| w[1].1 <= w[0].1 + 1e-12);
    out.push_str(&format!(
        "  trend: {}\n",
        if increasing {
            "monotonically increasing"
        } else if decreasing {
            "monotonically decreasing"
        } else {
            "non-monotone"
        }
    ));
    out
}

/// Per-epoch training table (loss, accuracy, backward dispatches,
/// wall time) — the `cli train` output and the fused-schedule evidence
/// the training bench prints.
pub fn training_table(report: &crate::train::FitReport) -> String {
    let mut t = Table::new(&["epoch", "loss", "accuracy", "batches", "bwd dispatches", "time"]);
    for e in &report.epochs {
        t.row(&[
            format!("{}", e.epoch),
            format!("{:.4}", e.loss),
            format!("{:.3}", e.accuracy),
            format!("{}", e.batches),
            format!("{}", e.backward_dispatches),
            crate::util::fmt::human_time(e.epoch_nanos as f64),
        ]);
    }
    let trend = if report.monotonic_loss() {
        "monotonically decreasing"
    } else {
        "non-monotone"
    };
    format!("per-epoch training metrics:\n{}loss trend: {trend}\n", t.render())
}

/// Accuracy-delta table for the quantized feature-projection path
/// (`SessionBuilder::quantize`): compares the quantized session's output
/// logits against the f32 baseline's, row for row — max-abs and mean-abs
/// logit error plus argmax (predicted-label) agreement. The two tensors
/// must be the same shape (same graph, model and seeds).
pub fn quant_delta_table(spec_name: &str, f32_out: &Tensor, quant_out: &Tensor) -> String {
    assert_eq!(
        f32_out.shape(),
        quant_out.shape(),
        "quant_delta_table: baseline and quantized outputs must be the same shape"
    );
    let (rows, cols) = f32_out.shape();
    let mut max_abs = 0.0f64;
    let mut sum_abs = 0.0f64;
    let mut agree = 0usize;
    for r in 0..rows {
        let (a, b) = (f32_out.row(r), quant_out.row(r));
        for (&x, &y) in a.iter().zip(b) {
            let d = (x as f64 - y as f64).abs();
            max_abs = max_abs.max(d);
            sum_abs += d;
        }
        if argmax(a) == argmax(b) {
            agree += 1;
        }
    }
    let n = (rows * cols).max(1) as f64;
    let mut t = Table::new(&[
        "format",
        "rows",
        "max abs logit err",
        "mean abs logit err",
        "label agreement",
    ]);
    t.row(&[
        spec_name.to_string(),
        format!("{rows}"),
        format!("{:.6}", max_abs),
        format!("{:.6}", sum_abs / n),
        format!("{:.2}%", 100.0 * agree as f64 / rows.max(1) as f64),
    ]);
    format!("quantized-projection accuracy delta vs f32:\n{}", t.render())
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

/// Group modeled stage times over several runs into a map for averaging.
pub fn average_stage_pct(profiles: &[&Profile]) -> BTreeMap<StageId, f64> {
    let mut acc: BTreeMap<StageId, f64> = BTreeMap::new();
    for p in profiles {
        for (s, v) in p.stage_percentages() {
            *acc.entry(s).or_insert(0.0) += v;
        }
    }
    for v in acc.values_mut() {
        *v /= profiles.len().max(1) as f64;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_rendering() {
        let mut t = Table::new(&["Kernel", "Time"]);
        t.row(&["sgemm".into(), "97.4".into()]);
        t.row(&["SpMMCsr".into(), "85.9".into()]);
        let r = t.render();
        assert!(r.contains("sgemm"));
        assert!(r.lines().count() == 4);
        let csv = t.to_csv();
        assert!(csv.starts_with("Kernel,Time\n"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new(&["a"]);
        t.row(&["x,y".into()]);
        t.row(&["q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    fn quant_delta_table_reports_errors_and_agreement() {
        let base = Tensor::from_vec(2, 2, vec![1.0, 2.0, 3.0, 0.0]).unwrap();
        // row 0 keeps its argmax (col 1), row 1 flips to col 1
        let quant = Tensor::from_vec(2, 2, vec![1.1, 2.0, 3.0, 3.5]).unwrap();
        let s = quant_delta_table("int8", &base, &quant);
        assert!(s.contains("int8"));
        assert!(s.contains("3.500000"), "max abs err is |0.0 - 3.5|: {s}");
        assert!(s.contains("50.00%"), "one of two rows agrees: {s}");
        let exact = quant_delta_table("f16", &base, &base);
        assert!(exact.contains("0.000000"));
        assert!(exact.contains("100.00%"));
    }

    #[test]
    fn bar_chart_scales() {
        let s = bar_chart("test", &[("a".into(), 100.0), ("b".into(), 50.0)], 10);
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[1].matches('█').count() == 10);
        assert!(lines[2].matches('█').count() == 5);
    }

    #[test]
    fn sweep_trend_detection() {
        let up = sweep_series("t", "x", "y", &[(1.0, 1.0), (2.0, 2.0)]);
        assert!(up.contains("increasing"));
        let down = sweep_series("t", "x", "y", &[(1.0, 2.0), (2.0, 1.0)]);
        assert!(down.contains("decreasing"));
        let mixed = sweep_series("t", "x", "y", &[(1.0, 1.0), (2.0, 3.0), (3.0, 2.0)]);
        assert!(mixed.contains("non-monotone"));
    }

    #[test]
    fn compare_ratio() {
        let s = compare("NA share", 74.0, 70.0, "%");
        assert!(s.contains("0.95"));
    }

    #[test]
    fn average_stage_pct_of_uniform_profiles() {
        use crate::session::Session;
        let mut session = Session::builder()
            .dataset(crate::datasets::DatasetId::Imdb)
            .scale(crate::datasets::DatasetScale::ci())
            .build()
            .unwrap();
        let a = session.run().unwrap().profile;
        let b = session.run().unwrap().profile;
        let avg = average_stage_pct(&[&a, &b]);
        // identical runs => average equals each run's percentages
        for (s, v) in a.stage_percentages() {
            assert!((avg[&s] - v).abs() < 1e-9);
        }
        let total: f64 = avg.values().sum();
        assert!((total - 100.0).abs() < 1e-6);
    }

    #[test]
    fn training_table_lists_epochs_and_trend() {
        let e = |epoch: usize, loss: f64| crate::train::EpochStats {
            epoch,
            loss,
            accuracy: 0.5,
            batches: 2,
            examples: 8,
            backward_dispatches: 12,
            epoch_nanos: 1_500,
        };
        let report = crate::train::FitReport { epochs: vec![e(1, 1.4), e(2, 1.2)] };
        let s = training_table(&report);
        assert!(s.contains("1.4000") && s.contains("1.2000"));
        assert!(s.contains("monotonically decreasing"));
        let bad = crate::train::FitReport { epochs: vec![e(1, 1.0), e(2, 1.1)] };
        assert!(training_table(&bad).contains("non-monotone"));
    }

    #[test]
    fn degree_skew_table_lists_every_type() {
        let hg = crate::datasets::build(
            crate::datasets::DatasetId::Imdb,
            &crate::datasets::DatasetScale::ci(),
        )
        .unwrap();
        let table = degree_skew_table(&hg);
        for t in hg.node_types() {
            assert!(table.contains(&t.name), "missing type {}", t.name);
        }
        assert!(table.contains("gini"));
        assert!(table.contains("max/mean"));
    }

    #[test]
    fn fig2_and_fig3_renderers_shape() {
        use crate::session::Session;
        let run = Session::builder()
            .dataset(crate::datasets::DatasetId::Acm)
            .scale(crate::datasets::DatasetScale::ci())
            .build()
            .unwrap()
            .run()
            .unwrap();
        let row = fig2_row("HAN", "AC", &run.profile);
        assert!(row.contains("FP") && row.contains("NA") && row.contains("SA"));
        let rows = fig3_rows("HAN", "AC", &run.profile);
        assert_eq!(rows.lines().count(), 3, "one line per GPU stage");
        assert!(rows.contains("DM") && rows.contains("TB"));
    }
}
