//! Cross-request reuse caches for served batches — memoizing the
//! batch-invariant stage results that overlapping requests keep
//! recomputing.
//!
//! The paper's stage breakdown (Fig 2: FP ≈ 19%, NA ≈ 74%, SA ≈ 7% on
//! average) concentrates per-batch cost in the compute-bound Feature
//! Projection sgemms and the dominant, memory-bound Neighbor
//! Aggregation, and HiHGNN (arXiv 2307.12765) identifies *data
//! reusability* across semantic graphs as the key software lever on
//! top of parallelism. The serving path samples a fresh metapath
//! neighborhood per dispatched batch (PR 2), so under overlapping
//! request streams — the Zipfian access patterns of the ROADMAP's
//! "millions of users" north star — the same nodes are re-projected
//! and re-aggregated over and over. This module caches both stages'
//! rows, behind a capacity bound:
//!
//! * **Projection cache** — per `(node type, parent node id)`, the
//!   stage-② output row. FP is row-local (`h[v] = x[v] · W_ty`), so a
//!   projected row is **seed-set independent**: it never depends on
//!   which other nodes share the sampled subgraph, which layer the node
//!   was reached at, or the fanout. Any sampled batch may gather a
//!   cached row and only project the misses.
//! * **Aggregate cache** — per `(metapath subgraph, parent destination
//!   node)`, the stage-③ output row. NA is destination-row-local
//!   (attention terms, edge softmax and the weighted reduce all operate
//!   within one destination's edge segment), so the row is
//!   batch-invariant **only at full-fanout coverage**: it is cached and
//!   substituted only for rows whose entire parent neighbor list was
//!   kept (`degree ≤ fanout`). Truncated rows depend on the sampling
//!   spec and are never cached.
//!
//! ## Bit-identical substitution
//!
//! Cached rows are substituted byte-for-byte for what a cache-cold run
//! would compute, which rests on two invariants enforced elsewhere:
//!
//! 1. the sampler's **canonical local ordering** (local node ids ascend
//!    with parent ids, see [`crate::sampler`]), which pins the f32
//!    accumulation order of every row-local kernel regardless of which
//!    other nodes co-occupy the batch; and
//! 2. **node-set preservation**: a cache hit removes a destination
//!    row's *edges* from the sampled sub-CSR (the miss-only sub-CSR)
//!    but still registers its sources, so the materialized node set —
//!    and hence HAN/MAGNN's semantic-attention average, which runs over
//!    all sampled nodes of the target type — is identical to a cold
//!    run.
//!
//! `tests/integration_reuse.rs` pins cached-vs-cold bit-identity across
//! overlapping batches for both the row-local models and the
//! semantic-attention models.
//!
//! ## Generation-based invalidation
//!
//! Cached rows are functions of the weights and features they were
//! computed from. [`ReuseCache::invalidate`] — called by
//! `Session::invalidate` and `Session::set_weights` — clears both
//! caches and bumps a generation counter, so stage results computed
//! under stale parameters can never leak into post-reload batches. The
//! generation and an invalidation count are reported in [`ReuseStats`].
//!
//! ## Targeted eviction at epoch barriers
//!
//! The streaming-update path ([`crate::dynamic`]) must *not* pay a full
//! invalidation per epoch flip — reusability across epochs is the whole
//! point of incremental patching. [`ReuseCache::evict_proj`] and
//! [`ReuseCache::evict_agg`] drop exactly one `(type, node)` /
//! `(subgraph, dst)` key, so a flip evicts only the keys whose inputs
//! the update batch touched; untouched entries survive the flip, keep
//! their generation, and keep hitting (`tests/prop_invariants.rs` pins
//! this minimality).
//!
//! ## Eviction
//!
//! Both caches are bounded in **rows** ([`ReuseSpec`]) and evict with
//! the clock (second-chance) policy: a hit sets a reference bit; an
//! insert into a full cache sweeps the hand, clearing bits, and evicts
//! the first unreferenced slot — an O(1)-amortized LRU approximation
//! that needs no ordered index. Capacity 0 disables a cache (every
//! lookup misses, inserts are dropped).

use std::collections::HashMap;

use crate::kernels::quant::{QuantRow, QuantSpec};

/// Capacities of the two reuse caches, in rows.
///
/// Sizing intuition: a projection row is `hidden_dim` f32s, an
/// aggregate row likewise, so a capacity of `n` rows bounds each cache
/// at `n × hidden_dim × 4` bytes. `benches/reuse_serving.rs` sweeps
/// capacity × request overlap to locate the knee.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReuseSpec {
    /// Capacity of the stage-② projection cache, in feature rows.
    pub proj_rows: usize,
    /// Capacity of the stage-③ aggregate cache, in result rows.
    pub agg_rows: usize,
}

impl ReuseSpec {
    /// The same capacity for both caches.
    pub fn rows(n: usize) -> ReuseSpec {
        ReuseSpec { proj_rows: n, agg_rows: n }
    }

    /// Explicit per-cache capacities.
    pub fn caps(proj_rows: usize, agg_rows: usize) -> ReuseSpec {
        ReuseSpec { proj_rows, agg_rows }
    }

    /// Projection cache only (aggregate reuse disabled) — useful under
    /// aggressively truncating fanouts where few rows reach full
    /// coverage anyway.
    pub fn projection_only(n: usize) -> ReuseSpec {
        ReuseSpec { proj_rows: n, agg_rows: 0 }
    }
}

impl Default for ReuseSpec {
    /// 64Ki rows per cache (16 MiB per cache at `hidden_dim = 64`).
    fn default() -> Self {
        ReuseSpec::rows(1 << 16)
    }
}

/// Cumulative counters of one [`ReuseCache`] over its session lifetime.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ReuseStats {
    /// Projection-cache lookups that found a row.
    pub proj_hits: u64,
    /// Projection-cache lookups that missed.
    pub proj_misses: u64,
    /// Aggregate-cache lookups that found a row.
    pub agg_hits: u64,
    /// Aggregate-cache lookups that missed (fully-covered rows only;
    /// truncated rows are never looked up).
    pub agg_misses: u64,
    /// Rows evicted by the clock hand across both caches.
    pub evictions: u64,
    /// Rows dropped by targeted per-key eviction at epoch flips
    /// ([`ReuseCache::evict_proj`] / [`ReuseCache::evict_agg`]).
    pub targeted_evictions: u64,
    /// Generation bumps ([`ReuseCache::invalidate`] calls).
    pub invalidations: u64,
}

impl ReuseStats {
    /// Projection hit rate in `[0, 1]` (0 when no lookups happened).
    pub fn proj_hit_rate(&self) -> f64 {
        rate(self.proj_hits, self.proj_misses)
    }

    /// Aggregate hit rate in `[0, 1]` (0 when no lookups happened).
    pub fn agg_hit_rate(&self) -> f64 {
        rate(self.agg_hits, self.agg_misses)
    }

    /// Accumulate another cache's counters into this one. The sharded
    /// serving path keeps one [`ReuseCache`] lane per shard (each
    /// shard-affine sub-batch touches only its seed-owner's lane, so
    /// lanes never contend); the session aggregates the lanes through
    /// this into the single `ReuseStats` view the stats plumbing
    /// reports.
    pub fn absorb(&mut self, other: &ReuseStats) {
        self.proj_hits += other.proj_hits;
        self.proj_misses += other.proj_misses;
        self.agg_hits += other.agg_hits;
        self.agg_misses += other.agg_misses;
        self.evictions += other.evictions;
        self.targeted_evictions += other.targeted_evictions;
        self.invalidations += other.invalidations;
    }

    /// One-line human summary for the CLI and bench output.
    pub fn line(&self) -> String {
        format!(
            "reuse: proj {}/{} hits ({:.1}%), agg {}/{} hits ({:.1}%), \
             {} evictions ({} targeted), {} invalidations",
            self.proj_hits,
            self.proj_hits + self.proj_misses,
            100.0 * self.proj_hit_rate(),
            self.agg_hits,
            self.agg_hits + self.agg_misses,
            100.0 * self.agg_hit_rate(),
            self.evictions,
            self.targeted_evictions,
            self.invalidations,
        )
    }
}

/// Render per-shard-lane counter snapshots, one line per lane — the
/// serving runtime surfaces these so lane-level imbalance (one hot
/// shard monopolizing its cache) is visible, not averaged away in the
/// aggregate [`ReuseStats::line`].
pub fn lane_lines(lanes: &[ReuseStats]) -> String {
    lanes
        .iter()
        .enumerate()
        .map(|(i, s)| format!("  lane {i}: {}", s.line()))
        .collect::<Vec<_>>()
        .join("\n")
}

fn rate(hits: u64, misses: u64) -> f64 {
    let total = hits + misses;
    if total == 0 {
        0.0
    } else {
        hits as f64 / total as f64
    }
}

/// The per-batch aggregate-cache overlay the sampler hands to the
/// executor alongside the miss-only sub-CSRs: which destination rows to
/// fill from the cache, and which freshly computed rows to publish back.
#[derive(Debug, Default)]
pub struct AggOverlay {
    /// Per subgraph: `(local dst row, cached stage-③ row)` pairs to
    /// scatter over the NA output (those rows carry no edges in the
    /// miss-only sub-CSR, so NA leaves them zero).
    pub prefilled: Vec<Vec<(u32, Vec<f32>)>>,
    /// Per subgraph: `(local dst row, parent dst id)` of rows whose full
    /// parent neighbor list was kept this batch — exact at full-fanout
    /// coverage, hence cacheable.
    pub computed: Vec<Vec<(u32, u32)>>,
}

impl AggOverlay {
    /// Empty overlay for `p` subgraphs.
    pub fn new(p: usize) -> AggOverlay {
        AggOverlay { prefilled: vec![Vec::new(); p], computed: vec![Vec::new(); p] }
    }

    /// Total prefilled (cache-hit) rows across subgraphs.
    pub fn prefilled_rows(&self) -> usize {
        self.prefilled.iter().map(|v| v.len()).sum()
    }
}

/// One bounded row store with clock (second-chance) eviction.
/// Rows are stored as plain f32 by default; with a [`QuantSpec`] they
/// are stored quantized ([`QuantRow`]) and dequantized on fetch into a
/// store-owned scratch row, so residency shrinks 2× (f16) or ~4× (int8)
/// at the cost of a decode per hit.
#[derive(Debug)]
struct RowCache {
    cap: usize,
    quant: Option<QuantSpec>,
    slots: Vec<Slot>,
    index: HashMap<u64, usize>,
    hand: usize,
    /// Dequantization scratch handed out by `get` in quantized mode —
    /// valid until the next call that takes `&mut self`.
    dq: Vec<f32>,
}

#[derive(Debug)]
struct Slot {
    key: u64,
    row: Stored,
    referenced: bool,
}

/// Storage format of one cached row.
#[derive(Debug)]
enum Stored {
    F32(Vec<f32>),
    Quant(QuantRow),
}

fn encode(quant: Option<QuantSpec>, row: &[f32]) -> Stored {
    match quant {
        None => Stored::F32(row.to_vec()),
        Some(spec) => Stored::Quant(QuantRow::quantize(row, spec)),
    }
}

impl RowCache {
    fn new(cap: usize, quant: Option<QuantSpec>) -> RowCache {
        RowCache { cap, quant, slots: Vec::new(), index: HashMap::new(), hand: 0, dq: Vec::new() }
    }

    fn len(&self) -> usize {
        self.slots.len()
    }

    fn get(&mut self, key: u64) -> Option<&[f32]> {
        let &i = self.index.get(&key)?;
        self.slots[i].referenced = true;
        match &self.slots[i].row {
            Stored::F32(v) => Some(v),
            Stored::Quant(q) => {
                q.dequantize_into(&mut self.dq);
                Some(&self.dq)
            }
        }
    }

    /// Insert (or refresh) a row; returns true when a victim was evicted.
    fn insert(&mut self, key: u64, row: &[f32]) -> bool {
        if self.cap == 0 {
            return false;
        }
        if let Some(&i) = self.index.get(&key) {
            if let Stored::F32(v) = &mut self.slots[i].row {
                v.clear();
                v.extend_from_slice(row);
            } else {
                self.slots[i].row = encode(self.quant, row);
            }
            self.slots[i].referenced = true;
            return false;
        }
        if self.slots.len() < self.cap {
            self.index.insert(key, self.slots.len());
            self.slots.push(Slot { key, row: encode(self.quant, row), referenced: true });
            return false;
        }
        // clock sweep: clear reference bits until an unreferenced victim
        // turns up (terminates within two sweeps of the full cache)
        loop {
            let i = self.hand;
            self.hand = (self.hand + 1) % self.slots.len();
            if self.slots[i].referenced {
                self.slots[i].referenced = false;
            } else {
                self.index.remove(&self.slots[i].key);
                self.index.insert(key, i);
                self.slots[i] = Slot { key, row: encode(self.quant, row), referenced: true };
                return true;
            }
        }
    }

    /// Drop one key if resident; returns whether a row was removed. The
    /// vacated slot is back-filled by `swap_remove`, so the store stays
    /// dense; the clock hand is re-wrapped if it pointed past the end
    /// (a harmless perturbation of the second-chance order).
    fn remove(&mut self, key: u64) -> bool {
        let Some(i) = self.index.remove(&key) else {
            return false;
        };
        self.slots.swap_remove(i);
        if i < self.slots.len() {
            self.index.insert(self.slots[i].key, i);
        }
        if self.hand >= self.slots.len() {
            self.hand = 0;
        }
        true
    }

    fn clear(&mut self) {
        self.slots.clear();
        self.index.clear();
        self.hand = 0;
    }
}

/// The session-owned reuse cache: bounded projection + aggregate row
/// stores, hit/miss accounting, and generation-based invalidation. One
/// instance is shared across every batch a session (and hence a serving
/// dispatcher) executes.
#[derive(Debug)]
pub struct ReuseCache {
    spec: ReuseSpec,
    quant: Option<QuantSpec>,
    generation: u64,
    proj: RowCache,
    agg: RowCache,
    stats: ReuseStats,
}

fn key(hi: usize, lo: u32) -> u64 {
    ((hi as u64) << 32) | lo as u64
}

impl ReuseCache {
    /// Empty cache with the given capacities storing rows as plain f32.
    pub fn new(spec: ReuseSpec) -> ReuseCache {
        ReuseCache::with_quant(spec, None)
    }

    /// Empty cache whose resident rows are stored quantized per `quant`
    /// (f32 when `None`). Quantized rows are dequantized on every hit,
    /// so hits return values that differ from the originally inserted
    /// f32 rows by the format's rounding error — callers opt in via
    /// `SessionBuilder::quantize` and accept tolerance-based checks.
    pub fn with_quant(spec: ReuseSpec, quant: Option<QuantSpec>) -> ReuseCache {
        ReuseCache {
            spec,
            quant,
            generation: 0,
            proj: RowCache::new(spec.proj_rows, quant),
            agg: RowCache::new(spec.agg_rows, quant),
            stats: ReuseStats::default(),
        }
    }

    /// The capacities this cache was built with.
    pub fn spec(&self) -> ReuseSpec {
        self.spec
    }

    /// The row-storage quantization format, if any.
    pub fn quant(&self) -> Option<QuantSpec> {
        self.quant
    }

    /// Bytes one resident row of `len` f32 values occupies in this
    /// cache's storage format (int8 includes its per-row scale). Used
    /// by the executor's `ReuseGather` counters so profiled traffic
    /// reflects the quantized footprint.
    pub fn stored_row_bytes(&self, len: usize) -> u64 {
        match self.quant {
            None => len as u64 * 4,
            Some(QuantSpec::F16) => len as u64 * 2,
            Some(QuantSpec::Int8) => len as u64 + 4,
        }
    }

    /// Current generation; bumped by every [`ReuseCache::invalidate`].
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Whether the aggregate cache can ever hold a row. The sampler
    /// consults this before doing per-row lookups so a
    /// [`ReuseSpec::projection_only`] session pays no aggregate-side
    /// overhead (and reports no phantom misses).
    pub fn agg_enabled(&self) -> bool {
        self.spec.agg_rows > 0
    }

    /// Whether the projection cache can ever hold a row — the mirror of
    /// [`ReuseCache::agg_enabled`], consulted by the cache-aware FP path
    /// so a `ReuseSpec::caps(0, n)` (aggregate-only) session pays no
    /// projection-side lookups and reports no phantom misses.
    pub fn proj_enabled(&self) -> bool {
        self.spec.proj_rows > 0
    }

    /// Cumulative hit/miss/eviction counters.
    pub fn stats(&self) -> &ReuseStats {
        &self.stats
    }

    /// Resident projection rows.
    pub fn proj_len(&self) -> usize {
        self.proj.len()
    }

    /// Resident aggregate rows.
    pub fn agg_len(&self) -> usize {
        self.agg.len()
    }

    /// Look up the cached stage-② row of `(node type, parent node id)`.
    pub fn proj_get(&mut self, ty: usize, node: u32) -> Option<&[f32]> {
        let row = self.proj.get(key(ty, node));
        if row.is_some() {
            self.stats.proj_hits += 1;
        } else {
            self.stats.proj_misses += 1;
        }
        row
    }

    /// Publish a freshly projected row.
    pub fn proj_insert(&mut self, ty: usize, node: u32, row: &[f32]) {
        if self.proj.insert(key(ty, node), row) {
            self.stats.evictions += 1;
        }
    }

    /// Look up the cached stage-③ row of `(subgraph, parent dst id)`.
    /// Callers must only ask for rows whose full neighbor list the
    /// current fanout would keep (full-fanout validity).
    pub fn agg_get(&mut self, subgraph: usize, node: u32) -> Option<&[f32]> {
        let row = self.agg.get(key(subgraph, node));
        if row.is_some() {
            self.stats.agg_hits += 1;
        } else {
            self.stats.agg_misses += 1;
        }
        row
    }

    /// Publish a freshly aggregated row (fully-covered rows only).
    pub fn agg_insert(&mut self, subgraph: usize, node: u32, row: &[f32]) {
        if self.agg.insert(key(subgraph, node), row) {
            self.stats.evictions += 1;
        }
    }

    /// Targeted eviction of one projection key — the epoch-flip path
    /// drops exactly the `(type, node)` keys whose raw features the
    /// update batch rewrote, leaving the rest of the cache (and the
    /// generation) intact. Returns whether a row was resident.
    pub fn evict_proj(&mut self, ty: usize, node: u32) -> bool {
        let hit = self.proj.remove(key(ty, node));
        if hit {
            self.stats.targeted_evictions += 1;
        }
        hit
    }

    /// Targeted eviction of one aggregate key — dropped for every
    /// `(subgraph, dst)` whose NA row an epoch flip recomputes.
    pub fn evict_agg(&mut self, subgraph: usize, node: u32) -> bool {
        let hit = self.agg.remove(key(subgraph, node));
        if hit {
            self.stats.targeted_evictions += 1;
        }
        hit
    }

    /// Drop every cached row and bump the generation — required after
    /// any weight or feature change, since cached rows are functions of
    /// the parameters they were computed from.
    pub fn invalidate(&mut self) {
        self.proj.clear();
        self.agg.clear();
        self.generation += 1;
        self.stats.invalidations += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_lines_renders_one_line_per_lane() {
        let a = ReuseStats { proj_hits: 3, proj_misses: 1, ..Default::default() };
        let b = ReuseStats { agg_hits: 2, ..Default::default() };
        let out = lane_lines(&[a, b]);
        assert_eq!(out.lines().count(), 2);
        assert!(out.contains("lane 0: reuse: proj 3/4"));
        assert!(out.contains("lane 1:"));
        assert_eq!(lane_lines(&[]), "");
    }

    #[test]
    fn spec_constructors() {
        assert_eq!(ReuseSpec::rows(8), ReuseSpec { proj_rows: 8, agg_rows: 8 });
        assert_eq!(ReuseSpec::caps(4, 2), ReuseSpec { proj_rows: 4, agg_rows: 2 });
        let p = ReuseSpec::projection_only(16);
        assert_eq!(p.agg_rows, 0);
        assert_eq!(ReuseSpec::default().proj_rows, 1 << 16);
    }

    #[test]
    fn hit_miss_accounting_and_roundtrip() {
        let mut c = ReuseCache::new(ReuseSpec::rows(8));
        assert!(c.proj_get(0, 1).is_none());
        c.proj_insert(0, 1, &[1.0, 2.0]);
        assert_eq!(c.proj_get(0, 1).unwrap(), &[1.0, 2.0]);
        // distinct types do not collide on the same node id
        assert!(c.proj_get(1, 1).is_none());
        assert!(c.agg_get(0, 1).is_none());
        c.agg_insert(0, 1, &[3.0]);
        assert_eq!(c.agg_get(0, 1).unwrap(), &[3.0]);
        let s = c.stats();
        assert_eq!((s.proj_hits, s.proj_misses), (1, 2));
        assert_eq!((s.agg_hits, s.agg_misses), (1, 1));
        assert_eq!(s.evictions, 0);
        assert!((s.proj_hit_rate() - 1.0 / 3.0).abs() < 1e-12);
        assert!(s.line().contains("evictions"));
    }

    #[test]
    fn capacity_bounds_and_clock_eviction() {
        let mut c = ReuseCache::new(ReuseSpec::rows(3));
        c.proj_insert(0, 0, &[0.0]);
        c.proj_insert(0, 1, &[1.0]);
        c.proj_insert(0, 2, &[2.0]);
        assert_eq!(c.proj_len(), 3);
        // all reference bits set: the sweep clears them all and evicts
        // the first slot the hand re-reaches (node 0)
        c.proj_insert(0, 3, &[3.0]);
        assert_eq!(c.proj_len(), 3);
        assert_eq!(c.stats().evictions, 1);
        assert!(c.proj_get(0, 0).is_none());
        // re-reference node 2; node 1 is now the only unreferenced
        // resident, so the next insert must evict exactly it
        assert!(c.proj_get(0, 2).is_some());
        c.proj_insert(0, 4, &[4.0]);
        assert_eq!(c.stats().evictions, 2);
        assert!(c.proj_get(0, 1).is_none(), "unreferenced slot must be the victim");
        assert!(c.proj_get(0, 2).is_some(), "re-referenced slot must survive");
        assert!(c.proj_get(0, 3).is_some());
        assert_eq!(c.proj_len(), 3);
    }

    #[test]
    fn reinsert_refreshes_in_place() {
        let mut c = ReuseCache::new(ReuseSpec::rows(2));
        c.agg_insert(0, 7, &[1.0]);
        c.agg_insert(0, 7, &[9.0]);
        assert_eq!(c.agg_len(), 1);
        assert_eq!(c.stats().evictions, 0);
        assert_eq!(c.agg_get(0, 7).unwrap(), &[9.0]);
    }

    #[test]
    fn zero_capacity_disables() {
        let mut c = ReuseCache::new(ReuseSpec::projection_only(4));
        c.agg_insert(0, 0, &[1.0]);
        assert_eq!(c.agg_len(), 0);
        assert!(c.agg_get(0, 0).is_none());
        c.proj_insert(0, 0, &[1.0]);
        assert!(c.proj_get(0, 0).is_some());
    }

    #[test]
    fn targeted_eviction_spares_untouched_keys() {
        let mut c = ReuseCache::new(ReuseSpec::rows(8));
        c.proj_insert(0, 1, &[1.0]);
        c.proj_insert(0, 2, &[2.0]);
        c.agg_insert(3, 1, &[3.0]);
        c.agg_insert(3, 2, &[4.0]);
        assert!(c.evict_proj(0, 1));
        assert!(!c.evict_proj(0, 1), "second eviction finds nothing");
        assert!(c.evict_agg(3, 2));
        assert!(!c.evict_agg(9, 9));
        // touched keys gone, untouched keys survive, generation intact
        assert!(c.proj_get(0, 1).is_none());
        assert_eq!(c.proj_get(0, 2).unwrap(), &[2.0]);
        assert_eq!(c.agg_get(3, 1).unwrap(), &[3.0]);
        assert!(c.agg_get(3, 2).is_none());
        assert_eq!(c.generation(), 0);
        assert_eq!(c.stats().targeted_evictions, 2);
        assert_eq!(c.stats().evictions, 0, "clock evictions unaffected");
        assert_eq!(c.stats().invalidations, 0);
        // the back-filled store still inserts and evicts normally
        c.proj_insert(0, 5, &[5.0]);
        assert_eq!(c.proj_get(0, 5).unwrap(), &[5.0]);
    }

    #[test]
    fn remove_backfills_and_rewraps_hand() {
        // fill to capacity, remove the middle slot, then force a clock
        // sweep: the dense backfill must leave the index consistent
        let mut c = ReuseCache::new(ReuseSpec::rows(3));
        c.proj_insert(0, 0, &[0.0]);
        c.proj_insert(0, 1, &[1.0]);
        c.proj_insert(0, 2, &[2.0]);
        assert!(c.evict_proj(0, 1));
        assert_eq!(c.proj_len(), 2);
        // slot of node 2 was swapped into the vacated slot; both resident
        assert_eq!(c.proj_get(0, 0).unwrap(), &[0.0]);
        assert_eq!(c.proj_get(0, 2).unwrap(), &[2.0]);
        c.proj_insert(0, 3, &[3.0]);
        c.proj_insert(0, 4, &[4.0]); // full again -> clock sweep
        assert_eq!(c.proj_len(), 3);
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn absorb_sums_every_counter() {
        // pin the lane aggregation: every field participates, so a new
        // counter can never silently vanish from the serving stats view
        let a = ReuseStats {
            proj_hits: 1,
            proj_misses: 2,
            agg_hits: 3,
            agg_misses: 4,
            evictions: 5,
            targeted_evictions: 6,
            invalidations: 7,
        };
        let mut acc = a.clone();
        acc.absorb(&a);
        assert_eq!(
            acc,
            ReuseStats {
                proj_hits: 2,
                proj_misses: 4,
                agg_hits: 6,
                agg_misses: 8,
                evictions: 10,
                targeted_evictions: 12,
                invalidations: 14,
            }
        );
        assert!(a.line().contains("(6 targeted)"));
    }

    #[test]
    fn invalidate_clears_and_bumps_generation() {
        let mut c = ReuseCache::new(ReuseSpec::rows(4));
        c.proj_insert(0, 0, &[1.0]);
        c.agg_insert(0, 0, &[2.0]);
        assert_eq!(c.generation(), 0);
        c.invalidate();
        assert_eq!(c.generation(), 1);
        assert_eq!(c.proj_len() + c.agg_len(), 0);
        assert!(c.proj_get(0, 0).is_none());
        assert_eq!(c.stats().invalidations, 1);
    }

    #[test]
    fn overlay_counts() {
        let mut ov = AggOverlay::new(2);
        assert_eq!(ov.prefilled_rows(), 0);
        ov.prefilled[1].push((0, vec![1.0]));
        assert_eq!(ov.prefilled_rows(), 1);
        assert_eq!(ov.computed.len(), 2);
    }

    #[test]
    fn quantized_rows_roundtrip_within_format_error() {
        let row: Vec<f32> = (0..32).map(|i| (i as f32 - 16.0) * 0.37).collect();
        for (quant, tol) in [(QuantSpec::F16, 1e-3_f32), (QuantSpec::Int8, 0.05_f32)] {
            let mut c = ReuseCache::with_quant(ReuseSpec::rows(8), Some(quant));
            assert_eq!(c.quant(), Some(quant));
            c.proj_insert(0, 1, &row);
            c.agg_insert(2, 3, &row);
            let max_abs = row.iter().fold(0.0_f32, |m, v| m.max(v.abs()));
            for got in [c.proj_get(0, 1).unwrap().to_vec(), c.agg_get(2, 3).unwrap().to_vec()] {
                assert_eq!(got.len(), row.len());
                for (g, w) in got.iter().zip(&row) {
                    assert!((g - w).abs() <= tol * max_abs, "{quant:?}: {g} vs {w}");
                }
            }
            // refresh-in-place re-quantizes the new values
            let row2: Vec<f32> = row.iter().map(|v| -v).collect();
            c.proj_insert(0, 1, &row2);
            let got = c.proj_get(0, 1).unwrap();
            assert!((got[0] - row2[0]).abs() <= tol * max_abs);
        }
    }

    #[test]
    fn f32_mode_stays_bit_exact() {
        let mut c = ReuseCache::with_quant(ReuseSpec::rows(2), None);
        assert_eq!(c.quant(), None);
        let row = [0.1_f32, -2.5e-30, 3.0e30];
        c.proj_insert(0, 0, &row);
        assert_eq!(c.proj_get(0, 0).unwrap(), &row);
    }

    #[test]
    fn stored_row_bytes_reflects_format() {
        let f32c = ReuseCache::new(ReuseSpec::rows(1));
        let f16c = ReuseCache::with_quant(ReuseSpec::rows(1), Some(QuantSpec::F16));
        let i8c = ReuseCache::with_quant(ReuseSpec::rows(1), Some(QuantSpec::Int8));
        assert_eq!(f32c.stored_row_bytes(64), 256);
        assert_eq!(f16c.stored_row_bytes(64), 128);
        assert_eq!(i8c.stored_row_bytes(64), 68); // 64 i8 + one f32 scale
    }
}
