//! The artifact manifest: what `python/compile/aot.py` produced.
//!
//! `artifacts/manifest.json` lists every lowered computation with its
//! model/dataset/stage identity and the dense input/output tensor specs
//! the Rust side must honor. Shapes are static — one artifact per
//! (model, dataset-scale, stage) tuple.

use std::path::Path;

use crate::util::json::Json;
use crate::{Error, Result};

/// One named tensor: `[rows, cols]` f32.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    /// Logical name (`"x_movie"`, `"w_proj"`, ...).
    pub name: String,
    /// Shape (2-D).
    pub shape: [usize; 2],
}

/// One AOT artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactEntry {
    /// Unique artifact name, e.g. `"han_imdb_full"`.
    pub name: String,
    /// HLO text file, relative to the artifact root.
    pub file: String,
    /// Model ("han", "rgcn", "gcn").
    pub model: String,
    /// Dataset ("imdb", ...).
    pub dataset: String,
    /// Stage ("fp" | "na" | "sa" | "full" | kernel name).
    pub stage: String,
    /// Ordered input tensor specs.
    pub inputs: Vec<TensorSpec>,
    /// Ordered output tensor specs.
    pub outputs: Vec<TensorSpec>,
}

/// The whole manifest.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Manifest {
    /// All artifacts.
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load from a JSON file.
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref()).map_err(|e| {
            Error::Runtime(format!(
                "read manifest {}: {e} (run `make artifacts` first)",
                path.as_ref().display()
            ))
        })?;
        Self::parse(&text)
    }

    /// Parse from a JSON string.
    pub fn parse(text: &str) -> Result<Manifest> {
        let root = Json::parse(text)?;
        let arr = root
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| Error::config("manifest missing 'artifacts' array"))?;
        let mut entries = Vec::with_capacity(arr.len());
        for item in arr {
            entries.push(parse_entry(item)?);
        }
        Ok(Manifest { entries })
    }

    /// Find an artifact by name.
    pub fn find(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// All artifacts for a (model, dataset) pair.
    pub fn for_model_dataset(&self, model: &str, dataset: &str) -> Vec<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| e.model == model && e.dataset == dataset)
            .collect()
    }
}

fn parse_entry(item: &Json) -> Result<ArtifactEntry> {
    let field = |k: &str| -> Result<String> {
        item.get(k)
            .and_then(|v| v.as_str())
            .map(|s| s.to_string())
            .ok_or_else(|| Error::config(format!("manifest entry missing '{k}'")))
    };
    let specs = |k: &str| -> Result<Vec<TensorSpec>> {
        let arr = item
            .get(k)
            .and_then(|v| v.as_arr())
            .ok_or_else(|| Error::config(format!("manifest entry missing '{k}'")))?;
        arr.iter()
            .map(|spec| {
                let name = spec
                    .get("name")
                    .and_then(|v| v.as_str())
                    .unwrap_or("unnamed")
                    .to_string();
                let shape = spec
                    .get("shape")
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| Error::config("tensor spec missing 'shape'"))?;
                if shape.len() != 2 {
                    return Err(Error::config(format!(
                        "tensor '{name}' is {}-d; runtime handles 2-d",
                        shape.len()
                    )));
                }
                Ok(TensorSpec {
                    name,
                    shape: [
                        shape[0].as_usize().unwrap_or(0),
                        shape[1].as_usize().unwrap_or(0),
                    ],
                })
            })
            .collect()
    };
    Ok(ArtifactEntry {
        name: field("name")?,
        file: field("file")?,
        model: field("model")?,
        dataset: field("dataset")?,
        stage: field("stage")?,
        inputs: specs("inputs")?,
        outputs: specs("outputs")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": [
        {
          "name": "han_imdb_full",
          "file": "han_imdb_full.hlo.txt",
          "model": "han", "dataset": "imdb", "stage": "full",
          "inputs": [
            {"name": "x_movie", "shape": [267, 192]},
            {"name": "w_proj", "shape": [192, 64]}
          ],
          "outputs": [{"name": "z", "shape": [267, 64]}]
        },
        {
          "name": "kernel_matmul",
          "file": "kernel_matmul.hlo.txt",
          "model": "kernel", "dataset": "none", "stage": "dense_matmul",
          "inputs": [{"name": "a", "shape": [64, 64]}, {"name": "b", "shape": [64, 64]}],
          "outputs": [{"name": "c", "shape": [64, 64]}]
        }
      ]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = m.find("han_imdb_full").unwrap();
        assert_eq!(e.inputs[0].shape, [267, 192]);
        assert_eq!(e.outputs[0].name, "z");
        assert!(m.find("missing").is_none());
        assert_eq!(m.for_model_dataset("han", "imdb").len(), 1);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"artifacts":[{"name":"x"}]}"#).is_err());
        let bad_shape = r#"{"artifacts":[{"name":"x","file":"f","model":"m",
          "dataset":"d","stage":"s",
          "inputs":[{"name":"a","shape":[1,2,3]}],"outputs":[]}]}"#;
        assert!(Manifest::parse(bad_shape).is_err());
    }
}
