//! PJRT runtime: load and execute AOT-compiled JAX/Pallas artifacts.
//!
//! `make artifacts` runs `python/compile/aot.py` once, lowering each
//! (model, dataset, stage) JAX function to **HLO text** under
//! `artifacts/` plus a `manifest.json` describing inputs and shapes. This
//! module is the only place that touches the `xla` crate: it loads the
//! text, compiles it on the PJRT CPU client, and executes it from the L3
//! hot path. Python never runs at inference time.
//!
//! HLO *text* (not serialized `HloModuleProto`) is the interchange
//! format: jax ≥ 0.5 emits protos with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md).
//!
//! ## The `pjrt` feature
//!
//! The `xla` crate (and its bundled PJRT runtime) is a heavyweight,
//! non-crates.io dependency. It is gated behind the **`pjrt`** cargo
//! feature so the default build is dependency-free: without the feature,
//! [`PjrtRuntime`] still constructs and reads manifests, but
//! `compile`/`execute` report a clear [`Error::Runtime`]. Call sites and
//! tests treat that exactly like a missing artifact directory.

pub mod manifest;

use std::path::{Path, PathBuf};

use crate::graph::Csr;
use crate::tensor::Tensor;
use crate::{Error, Result};

pub use manifest::{ArtifactEntry, Manifest, TensorSpec};

/// ELL arrays (idx, mask) as f32 tensors for a CSR, truncated at `k`
/// slots per row, plus the truncated CSR (for native cross-checks on the
/// identical adjacency). This is the input convention of the AOT
/// artifacts' gather stage.
pub fn ell_inputs(adj: &Csr, k: usize) -> (Tensor, Tensor, Csr) {
    let (ell, _) = adj.to_ell(k);
    let mut idx = Tensor::zeros(adj.n_rows, k);
    let mut mask = Tensor::zeros(adj.n_rows, k);
    for r in 0..adj.n_rows {
        let (cols, valid) = ell.row_slots(r);
        for j in 0..k {
            idx.set(r, j, cols[j] as f32);
            mask.set(r, j, if valid[j] { 1.0 } else { 0.0 });
        }
    }
    (idx, mask, ell.to_csr())
}

/// A compiled PJRT executable plus its metadata.
pub struct CompiledArtifact {
    /// Manifest entry this was compiled from.
    pub entry: ArtifactEntry,
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
}

impl std::fmt::Debug for CompiledArtifact {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CompiledArtifact").field("entry", &self.entry).finish()
    }
}

/// The PJRT runtime: one CPU client, many compiled executables.
pub struct PjrtRuntime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    /// Artifact directory root.
    pub root: PathBuf,
}

impl std::fmt::Debug for PjrtRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtRuntime").field("root", &self.root).finish()
    }
}

impl PjrtRuntime {
    /// Create a PJRT runtime rooted at an artifact directory. With the
    /// `pjrt` feature this starts a CPU PJRT client; without it, the
    /// runtime can still read manifests but not compile or execute.
    pub fn new(root: impl AsRef<Path>) -> Result<PjrtRuntime> {
        #[cfg(feature = "pjrt")]
        {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| Error::Runtime(format!("PjRtClient::cpu: {e}")))?;
            Ok(PjrtRuntime { client, root: root.as_ref().to_path_buf() })
        }
        #[cfg(not(feature = "pjrt"))]
        {
            Ok(PjrtRuntime { root: root.as_ref().to_path_buf() })
        }
    }

    /// PJRT platform name (`"cpu"` here; the paper's testbed says
    /// `"cuda"`). Without the `pjrt` feature: `"unavailable"`.
    pub fn platform(&self) -> String {
        #[cfg(feature = "pjrt")]
        {
            self.client.platform_name()
        }
        #[cfg(not(feature = "pjrt"))]
        {
            "unavailable (built without the 'pjrt' feature)".to_string()
        }
    }

    /// Load the artifact manifest from `<root>/manifest.json`.
    pub fn manifest(&self) -> Result<Manifest> {
        Manifest::load(self.root.join("manifest.json"))
    }

    /// Load + compile one artifact by manifest entry.
    #[cfg(feature = "pjrt")]
    pub fn compile(&self, entry: &ArtifactEntry) -> Result<CompiledArtifact> {
        let path = self.root.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::Runtime("non-utf8 path".into()))?,
        )
        .map_err(|e| Error::Runtime(format!("parse {}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("compile {}: {e}", entry.name)))?;
        Ok(CompiledArtifact { entry: entry.clone(), exe })
    }

    /// Load + compile one artifact by manifest entry (stub: the crate
    /// was built without the `pjrt` feature).
    #[cfg(not(feature = "pjrt"))]
    pub fn compile(&self, entry: &ArtifactEntry) -> Result<CompiledArtifact> {
        Err(Error::Runtime(format!(
            "cannot compile artifact '{}': hgnn-char was built without the \
             'pjrt' feature (rebuild with `--features pjrt` and the xla crate \
             available)",
            entry.name
        )))
    }

    /// Load + compile an artifact by name.
    pub fn compile_by_name(&self, name: &str) -> Result<CompiledArtifact> {
        let manifest = self.manifest()?;
        let entry = manifest
            .find(name)
            .ok_or_else(|| Error::NotFound(format!("artifact '{name}'")))?;
        self.compile(entry)
    }
}

impl CompiledArtifact {
    /// Execute with dense `f32` tensor inputs; returns the tuple of
    /// output tensors (jax lowers with `return_tuple=True`).
    #[cfg(feature = "pjrt")]
    pub fn execute(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.entry.inputs.len() {
            return Err(Error::shape(format!(
                "artifact {} expects {} inputs, got {}",
                self.entry.name,
                self.entry.inputs.len(),
                inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (t, spec) in inputs.iter().zip(&self.entry.inputs) {
            if t.shape() != (spec.shape[0], spec.shape[1]) {
                return Err(Error::shape(format!(
                    "artifact {} input '{}': expected {:?}, got {:?}",
                    self.entry.name,
                    spec.name,
                    spec.shape,
                    t.shape()
                )));
            }
            let lit = xla::Literal::vec1(t.as_slice())
                .reshape(&[t.rows() as i64, t.cols() as i64])
                .map_err(|e| Error::Runtime(format!("reshape input: {e}")))?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::Runtime(format!("execute {}: {e}", self.entry.name)))?;
        let buffer = &result[0][0];
        let tuple = buffer
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("to_literal: {e}")))?
            .to_tuple()
            .map_err(|e| Error::Runtime(format!("to_tuple: {e}")))?;
        tuple
            .into_iter()
            .map(|lit| {
                let shape = lit
                    .array_shape()
                    .map_err(|e| Error::Runtime(format!("shape: {e}")))?;
                let dims = shape.dims();
                let (rows, cols) = match dims.len() {
                    0 => (1, 1),
                    1 => (dims[0] as usize, 1),
                    2 => (dims[0] as usize, dims[1] as usize),
                    _ => {
                        // collapse leading dims
                        let last = *dims.last().unwrap() as usize;
                        (
                            dims[..dims.len() - 1].iter().product::<i64>() as usize,
                            last,
                        )
                    }
                };
                let data = lit
                    .to_vec::<f32>()
                    .map_err(|e| Error::Runtime(format!("to_vec: {e}")))?;
                Tensor::from_vec(rows, cols, data)
            })
            .collect()
    }

    /// Execute stub (the crate was built without the `pjrt` feature).
    #[cfg(not(feature = "pjrt"))]
    pub fn execute(&self, _inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        Err(Error::Runtime(format!(
            "cannot execute artifact '{}': built without the 'pjrt' feature",
            self.entry.name
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime tests that need real artifacts live in
    // rust/tests/integration_runtime.rs (they require `make artifacts`).
    // Here we test the pieces that do not need artifacts.

    #[test]
    fn client_creation_and_missing_manifest() {
        let rt = PjrtRuntime::new("/nonexistent").unwrap();
        assert!(rt.manifest().is_err(), "missing manifest must error");
        #[cfg(feature = "pjrt")]
        assert_eq!(rt.platform(), "cpu");
        #[cfg(not(feature = "pjrt"))]
        assert!(rt.platform().contains("unavailable"));
    }

    #[test]
    fn compile_missing_artifact_errors() {
        let rt = PjrtRuntime::new("/tmp").unwrap();
        let entry = ArtifactEntry {
            name: "nope".into(),
            file: "nope.hlo.txt".into(),
            model: "han".into(),
            dataset: "imdb".into(),
            stage: "full".into(),
            inputs: vec![],
            outputs: vec![],
        };
        assert!(rt.compile(&entry).is_err());
    }

    #[test]
    fn ell_inputs_shapes_and_mask() {
        let adj = crate::graph::sparse::Coo::from_edges(
            3,
            4,
            vec![(0, 0), (0, 2), (1, 3), (0, 1)],
        )
        .unwrap()
        .to_csr();
        let (idx, mask, trunc) = ell_inputs(&adj, 2);
        assert_eq!(idx.shape(), (3, 2));
        assert_eq!(mask.shape(), (3, 2));
        // row 0 had degree 3, truncated to 2 slots
        assert_eq!(mask.row(0), &[1.0, 1.0]);
        // row 1 has one valid slot
        assert_eq!(mask.get(1, 0), 1.0);
        assert_eq!(mask.get(1, 1), 0.0);
        // row 2 is empty
        assert_eq!(mask.row(2), &[0.0, 0.0]);
        assert_eq!(trunc.nnz(), 3, "one edge truncated away");
    }
}
