//! Mini-batch metapath neighbor sampling — the serving-path subsystem.
//!
//! The paper's serving-relevant finding is that HGNN inference is
//! stage-wise execution over per-relation/per-metapath subgraphs, so a
//! served batch does not need the full graph: it needs the seeds'
//! metapath neighborhoods. [`NeighborSampler`] walks the metapaths
//! *backward* through the plan's precomputed subgraph CSRs (stage-①
//! output), samples up to `fanout` neighbors per node per layer, and
//! materializes a [`SampledSubgraph`]: a compact node-id remapping,
//! per-subgraph sub-CSRs, and gathered feature/embedding slices — a
//! self-contained (graph, plan) pair the session executes through the
//! ordinary [`crate::session::ExecBackend`] stage entry points. The
//! serving hot path then scales with batch size instead of graph size
//! (the mini-batch argument of arXiv 2408.08490 and HiHGNN's
//! data-reusability analysis, arXiv 2307.12765).
//!
//! Sampling is deterministic: the kept neighbor set of a node depends
//! only on ([`SamplingSpec::seed`], layer, subgraph, node id), so
//! identical seed batches always materialize identical subgraphs, and a
//! *seed's* own neighborhood (always expanded at layer 0) never depends
//! on which other ids share its batch. Under multi-layer truncating
//! fanouts an interior node's kept set keys on the layer it was reached
//! at, which can differ between batches that reach it at different
//! depths.
//!
//! ## Canonical local ordering
//!
//! Within every node type, local ids ascend with parent ids (the seeds
//! of the target type land wherever their parent ids sort;
//! [`SampledSubgraph::seed_rows`] maps seed → output row). Because CSR
//! construction sorts column indices, every sub-CSR row therefore
//! accumulates its sources in *parent* order no matter which other
//! nodes co-occupy the batch — which pins the f32 summation order of
//! every row-local kernel. This is the invariant that lets the
//! cross-request reuse caches ([`crate::reuse`]) substitute rows
//! computed under one batch composition into another, bit for bit.
//!
//! ## Reuse integration
//!
//! [`NeighborSampler::sample_with_cache`] threads a
//! [`crate::reuse::ReuseCache`] through the walk. A destination row
//! whose **entire** parent neighbor list the fanout keeps (full-fanout
//! coverage — the only condition under which its stage-③ aggregate is
//! batch-invariant) is looked up in the aggregate cache:
//!
//! * on a **hit**, the row's edges are omitted from the sub-CSR (the
//!   *miss-only sub-CSR*: Neighbor Aggregation cost tracks misses) and
//!   the cached row is carried in the returned
//!   [`crate::reuse::AggOverlay`] for the executor to scatter — but the
//!   row's sources are **still registered**, so the materialized node
//!   set (and HAN/MAGNN's semantic-attention average over it) is
//!   identical to a cache-cold run;
//! * on a **miss**, the row is marked `computed` so the executor can
//!   publish its freshly aggregated value back to the cache.
//!
//! Truncated rows (degree > fanout) are never looked up or published:
//! their aggregates depend on the sampling spec, not just the graph.
//! Cache entries survive until evicted or invalidated by a generation
//! bump ([`crate::reuse::ReuseCache::invalidate`]) on weight/feature
//! change.
//!
//! ## Exactness
//!
//! Stage ② (Feature Projection) is row-local and stages ③/④ aggregate
//! per destination row, so a seed's embedding computed on the sampled
//! subgraph equals the full-graph embedding whenever the fanout covers
//! every neighbor — exactly for R-GCN/GCN (mean/sum aggregation), and
//! for HAN/MAGNN up to the semantic-attention weights `beta`, which the
//! paper's §4.4 pipeline averages over *all* nodes of the target type.
//! On a sampled subgraph that average runs over the sampled nodes only;
//! deeper [`SamplingSpec::fanouts`] tighten the approximation. The
//! integration suite pins both behaviors (see
//! `tests/integration_sampler.rs`).

use std::collections::HashMap;

use crate::graph::sparse::Coo;
use crate::graph::{HeteroGraph, HeteroGraphBuilder, NodeTypeId};
use crate::metapath::{Subgraph, SubgraphSet};
use crate::models::ModelPlan;
use crate::reuse::{AggOverlay, ReuseCache};
use crate::tensor::Tensor;
use crate::util::Pcg32;
use crate::{Error, Result};

/// How a mini-batch neighborhood is sampled: one fanout per layer of
/// backward expansion through the subgraph adjacencies.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SamplingSpec {
    /// Per-layer neighbor cap, outermost (seed) layer first. A node's
    /// neighbors beyond the cap are dropped by deterministic sampling
    /// without replacement; `usize::MAX` keeps every neighbor.
    pub fanouts: Vec<usize>,
    /// Seed for the deterministic per-row sampling streams.
    pub seed: u64,
}

impl SamplingSpec {
    /// Uniform spec: the same `fanout` for `layers` expansion layers.
    pub fn uniform(fanout: usize, layers: usize) -> SamplingSpec {
        SamplingSpec { fanouts: vec![fanout; layers.max(1)], seed: 0x5A3D }
    }

    /// Explicit per-layer fanouts (outermost first).
    pub fn with_fanouts(fanouts: Vec<usize>) -> SamplingSpec {
        SamplingSpec { fanouts, seed: 0x5A3D }
    }

    /// Override the sampling seed.
    pub fn with_seed(mut self, seed: u64) -> SamplingSpec {
        self.seed = seed;
        self
    }

    /// Number of expansion layers.
    pub fn layers(&self) -> usize {
        self.fanouts.len()
    }
}

/// A materialized mini-batch subgraph: compacted node sets, per-subgraph
/// sub-CSRs remapped to local ids, and gathered feature slices — packaged
/// as a (graph, plan) pair the session executor runs unchanged.
#[derive(Debug)]
pub struct SampledSubgraph {
    /// Compact graph: same node-type ids/tags as the parent, counts
    /// shrunk to the sampled sets, features gathered row-wise. Types the
    /// expansion never reached keep zero nodes. Carries no relations —
    /// the plan's sub-CSRs are the only topology the stages consume.
    pub graph: HeteroGraph,
    /// Compact plan: same model/config/weights as the parent, subgraphs
    /// replaced by the sampled sub-CSRs (R-GCN per-type embedding tables
    /// are sliced to the sampled rows).
    pub plan: ModelPlan,
    /// Per node type, local id → parent-graph node id, ascending in
    /// parent id (the canonical ordering cross-batch reuse relies on).
    pub nodes: Vec<Vec<u32>>,
    /// The deduplicated seed ids (parent-graph ids of the target type),
    /// in submission order.
    pub seeds: Vec<u32>,
    /// Local row of each seed: seed `j` is local node `seed_rows[j]` of
    /// the target type, and row `seed_rows[j]` of the executed output.
    pub seed_rows: Vec<u32>,
    /// Aggregate-cache overlay when the batch was sampled through
    /// [`NeighborSampler::sample_with_cache`]: cache-hit rows to scatter
    /// over the NA output and fresh rows to publish back.
    pub overlay: Option<AggOverlay>,
}

impl SampledSubgraph {
    /// Total sampled nodes across all types.
    pub fn total_nodes(&self) -> usize {
        self.nodes.iter().map(|v| v.len()).sum()
    }

    /// Total edges across the sampled sub-CSRs (with a reuse cache,
    /// only the miss rows' edges — cache-hit rows carry none).
    pub fn total_edges(&self) -> usize {
        self.plan.subgraphs.subgraphs.iter().map(|sg| sg.adj.nnz()).sum()
    }

    /// Seed id → executed output row, combining [`SampledSubgraph::seeds`]
    /// and [`SampledSubgraph::seed_rows`] — the lookup both the plain and
    /// the shard-affine batch paths use to map requested ids (duplicates
    /// collapse onto one seed) back onto embedding rows.
    pub fn seed_row_map(&self) -> HashMap<u32, usize> {
        self.seeds
            .iter()
            .zip(&self.seed_rows)
            .map(|(&g, &r)| (g, r as usize))
            .collect()
    }

    /// One-line statistics string for logs and the serving demo.
    pub fn stats_line(&self) -> String {
        format!(
            "sampled batch: {} seeds -> {} nodes, {} edges over {} subgraphs",
            self.seeds.len(),
            self.total_nodes(),
            self.total_edges(),
            self.plan.subgraphs.len(),
        )
    }
}

/// Walks metapaths backward from seed nodes and materializes
/// [`SampledSubgraph`]s. Stateless apart from its [`SamplingSpec`]; a
/// session caches one and samples per served batch.
#[derive(Debug, Clone)]
pub struct NeighborSampler {
    spec: SamplingSpec,
}

impl NeighborSampler {
    /// Sampler from a spec. Fails on an empty fanout list.
    pub fn new(spec: SamplingSpec) -> Result<NeighborSampler> {
        if spec.fanouts.is_empty() {
            return Err(Error::config("SamplingSpec needs at least one fanout layer"));
        }
        if spec.fanouts.iter().any(|&f| f == 0) {
            return Err(Error::config("SamplingSpec fanouts must be >= 1"));
        }
        Ok(NeighborSampler { spec })
    }

    /// The spec this sampler applies.
    pub fn spec(&self) -> &SamplingSpec {
        &self.spec
    }

    /// Sample the mini-batch neighborhood of `seed_ids` (parent-graph
    /// node ids of `plan.target`; duplicates are deduplicated, order of
    /// first occurrence preserved) and materialize the compact
    /// (graph, plan) pair.
    pub fn sample(
        &self,
        hg: &HeteroGraph,
        plan: &ModelPlan,
        seed_ids: &[u32],
    ) -> Result<SampledSubgraph> {
        self.sample_impl(hg, plan, seed_ids, None)
    }

    /// Like [`NeighborSampler::sample`], but threads the reuse cache
    /// through the walk: fully-covered destination rows with cached
    /// stage-③ aggregates contribute no edges (miss-only sub-CSRs) and
    /// come back in the [`SampledSubgraph::overlay`] instead. See the
    /// module docs for the exactness argument.
    pub fn sample_with_cache(
        &self,
        hg: &HeteroGraph,
        plan: &ModelPlan,
        seed_ids: &[u32],
        cache: &mut ReuseCache,
    ) -> Result<SampledSubgraph> {
        self.sample_impl(hg, plan, seed_ids, Some(cache))
    }

    fn sample_impl(
        &self,
        hg: &HeteroGraph,
        plan: &ModelPlan,
        seed_ids: &[u32],
        mut cache: Option<&mut ReuseCache>,
    ) -> Result<SampledSubgraph> {
        let t0 = std::time::Instant::now();
        if seed_ids.is_empty() {
            return Err(Error::config("sample: empty seed batch"));
        }
        let n_types = hg.node_types().len();
        let target_count = hg.node_type(plan.target).count;

        // local id registries, one per node type (walk order; remapped
        // to the canonical parent-ascending order below)
        let mut local: Vec<HashMap<u32, u32>> = vec![HashMap::new(); n_types];
        let mut nodes: Vec<Vec<u32>> = vec![Vec::new(); n_types];
        // interns `id` into type `ty`'s local id space; true when fresh
        fn register(
            ty: NodeTypeId,
            id: u32,
            local: &mut [HashMap<u32, u32>],
            nodes: &mut [Vec<u32>],
        ) -> (u32, bool) {
            if let Some(&l) = local[ty].get(&id) {
                return (l, false);
            }
            let l = nodes[ty].len() as u32;
            local[ty].insert(id, l);
            nodes[ty].push(id);
            (l, true)
        }

        let mut seeds = Vec::with_capacity(seed_ids.len());
        for &id in seed_ids {
            if id as usize >= target_count {
                return Err(Error::config(format!(
                    "sample: seed {id} out of range for type '{}' ({} nodes)",
                    hg.node_type(plan.target).name,
                    target_count
                )));
            }
            let (_, fresh) = register(plan.target, id, &mut local, &mut nodes);
            if fresh {
                seeds.push(id);
            }
        }

        // frontier per type: nodes registered last layer, to expand next
        let mut frontier: Vec<Vec<u32>> = vec![Vec::new(); n_types];
        frontier[plan.target] = seeds.clone();

        // per-subgraph edge lists in walk-order local ids
        let p = plan.num_subgraphs();
        let mut edges: Vec<Vec<(u32, u32)>> = vec![Vec::new(); p];
        let mut overlay = cache.as_ref().map(|_| AggOverlay::new(p));
        // skip per-row aggregate lookups entirely when that cache can
        // never hold a row (ReuseSpec::projection_only)
        let agg_on = cache.as_ref().is_some_and(|c| c.agg_enabled());

        for (layer, &fanout) in self.spec.fanouts.iter().enumerate() {
            let mut next: Vec<Vec<u32>> = vec![Vec::new(); n_types];
            for (si, sg) in plan.subgraphs.subgraphs.iter().enumerate() {
                for &dst in &frontier[sg.dst_type] {
                    let l_dst = local[sg.dst_type][&dst];
                    let row = sg.adj.row(dst as usize);
                    // a row's aggregate is batch-invariant only when the
                    // fanout keeps every parent neighbor; empty rows are
                    // free to recompute (NA yields zeros), so they never
                    // consult or occupy the bounded cache
                    let covered = row.len() <= fanout;
                    let mut hit = false;
                    if covered && agg_on && !row.is_empty() {
                        if let (Some(c), Some(ov)) = (cache.as_deref_mut(), overlay.as_mut())
                        {
                            if let Some(cached) = c.agg_get(si, dst) {
                                ov.prefilled[si].push((l_dst, cached.to_vec()));
                                hit = true;
                            } else {
                                ov.computed[si].push((l_dst, dst));
                            }
                        }
                    }
                    let kept = sample_row(row, fanout, self.spec.seed, layer, si, dst);
                    for src in kept {
                        // sources register even on a hit so the node set
                        // (and the semantic-attention average over it)
                        // matches a cache-cold run; only the hit row's
                        // edges are dropped — the miss-only sub-CSR
                        let (l_src, fresh) =
                            register(sg.src_type, src, &mut local, &mut nodes);
                        if fresh {
                            next[sg.src_type].push(src);
                        }
                        if !hit {
                            edges[si].push((l_dst, l_src));
                        }
                    }
                }
            }
            frontier = next;
        }

        // canonical remap: within each type, local ids ascend with
        // parent ids, pinning every row's f32 accumulation order across
        // batch compositions
        let mut remap: Vec<Vec<u32>> = Vec::with_capacity(n_types);
        for list in nodes.iter_mut() {
            let mut order: Vec<u32> = (0..list.len() as u32).collect();
            order.sort_unstable_by_key(|&l| list[l as usize]);
            let mut m = vec![0u32; list.len()];
            for (new, &old) in order.iter().enumerate() {
                m[old as usize] = new as u32;
            }
            let sorted: Vec<u32> = order.iter().map(|&l| list[l as usize]).collect();
            *list = sorted;
            remap.push(m);
        }
        let seed_rows: Vec<u32> = {
            let m = &remap[plan.target];
            let loc = &local[plan.target];
            seeds.iter().map(|g| m[loc[g] as usize]).collect()
        };
        if let Some(ov) = overlay.as_mut() {
            for (si, sg) in plan.subgraphs.subgraphs.iter().enumerate() {
                let m = &remap[sg.dst_type];
                for e in ov.prefilled[si].iter_mut() {
                    e.0 = m[e.0 as usize];
                }
                for e in ov.computed[si].iter_mut() {
                    e.0 = m[e.0 as usize];
                }
            }
        }

        // compact graph: same types/tags, gathered features, no relations
        let mut gb = HeteroGraphBuilder::new(format!("{}[batch]", hg.name));
        for (ty, t) in hg.node_types().iter().enumerate() {
            gb.add_node_type(
                t.name.clone(),
                t.tag,
                gather_rows(hg.features(ty), &nodes[ty]),
            );
        }
        let graph = gb.build()?;

        // compact subgraphs: sub-CSRs over the canonical local id spaces
        let mut subgraphs = Vec::with_capacity(p);
        for (si, sg) in plan.subgraphs.subgraphs.iter().enumerate() {
            let md = &remap[sg.dst_type];
            let ms = &remap[sg.src_type];
            let remapped: Vec<(u32, u32)> = std::mem::take(&mut edges[si])
                .into_iter()
                .map(|(d, s)| (md[d as usize], ms[s as usize]))
                .collect();
            let n_rows = nodes[sg.dst_type].len();
            let n_cols = nodes[sg.src_type].len();
            let adj = Coo::from_edges(n_rows, n_cols, remapped)?.to_csr();
            subgraphs.push(Subgraph {
                metapath: sg.metapath.clone(),
                name: sg.name.clone(),
                dst_type: sg.dst_type,
                src_type: sg.src_type,
                adj,
            });
        }

        // compact plan: shared weights, sliced R-GCN embedding tables
        let mut weights = plan.weights.clone();
        for (&ty, embed) in &plan.weights.embed {
            weights.embed.insert(ty, gather_rows(embed, &nodes[ty]));
        }
        let plan = ModelPlan {
            model: plan.model,
            config: plan.config.clone(),
            subgraphs: SubgraphSet {
                subgraphs,
                build_nanos: t0.elapsed().as_nanos() as u64,
            },
            weights,
            target: plan.target,
        };
        Ok(SampledSubgraph { graph, plan, nodes, seeds, seed_rows, overlay })
    }
}

/// Keep up to `fanout` entries of a neighbor row, deterministically in
/// (`seed`, `layer`, `subgraph`, `dst`): rows at or under the cap pass
/// through untouched, longer rows are sampled without replacement.
fn sample_row(
    row: &[u32],
    fanout: usize,
    seed: u64,
    layer: usize,
    subgraph: usize,
    dst: u32,
) -> Vec<u32> {
    if row.len() <= fanout {
        return row.to_vec();
    }
    let stream = ((layer as u64) << 48) ^ ((subgraph as u64) << 40) ^ dst as u64;
    let mut rng = Pcg32::new(seed, stream);
    rng.choose_distinct(row.len(), fanout)
        .into_iter()
        .map(|i| row[i])
        .collect()
}

/// Gather rows of `x` at `ids` into a compact `[ids.len(), cols]` tensor.
fn gather_rows(x: &Tensor, ids: &[u32]) -> Tensor {
    let mut out = Tensor::zeros(ids.len(), x.cols());
    for (l, &g) in ids.iter().enumerate() {
        out.set_row(l, x.row(g as usize));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{self, DatasetId, DatasetScale};
    use crate::models::{self, ModelConfig, ModelId};
    use crate::reuse::{ReuseCache, ReuseSpec};

    fn imdb_han() -> (HeteroGraph, ModelPlan) {
        let hg = datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap();
        let plan = models::build_plan(ModelId::Han, &hg, &ModelConfig::default()).unwrap();
        (hg, plan)
    }

    #[test]
    fn spec_constructors_and_validation() {
        let s = SamplingSpec::uniform(8, 2);
        assert_eq!(s.fanouts, vec![8, 8]);
        assert_eq!(s.layers(), 2);
        let s = SamplingSpec::with_fanouts(vec![4, 2]).with_seed(7);
        assert_eq!(s.seed, 7);
        assert!(NeighborSampler::new(SamplingSpec { fanouts: vec![], seed: 0 }).is_err());
        assert!(NeighborSampler::new(SamplingSpec { fanouts: vec![0], seed: 0 }).is_err());
        assert!(NeighborSampler::new(SamplingSpec::uniform(1, 1)).is_ok());
    }

    #[test]
    fn seeds_dedup_and_canonical_order() {
        let (hg, plan) = imdb_han();
        let sampler = NeighborSampler::new(SamplingSpec::uniform(4, 1)).unwrap();
        let s = sampler.sample(&hg, &plan, &[5, 2, 5, 9, 2]).unwrap();
        assert_eq!(s.seeds, vec![5, 2, 9]);
        assert!(s.overlay.is_none());
        // canonical ordering: every type's local list ascends in parent id
        for list in &s.nodes {
            assert!(list.windows(2).all(|w| w[0] < w[1]), "locals not canonical: {list:?}");
        }
        // seed_rows maps each seed onto its local row
        assert_eq!(s.seed_rows.len(), s.seeds.len());
        for (j, &g) in s.seeds.iter().enumerate() {
            assert_eq!(s.nodes[plan.target][s.seed_rows[j] as usize], g);
        }
        // validity of the materialized pieces
        s.graph.validate().unwrap();
        for sg in &s.plan.subgraphs.subgraphs {
            sg.adj.validate().unwrap();
            assert_eq!(sg.adj.n_rows, s.nodes[sg.dst_type].len());
            assert_eq!(sg.adj.n_cols, s.nodes[sg.src_type].len());
        }
    }

    #[test]
    fn fanout_caps_degrees_of_expanded_rows() {
        let (hg, plan) = imdb_han();
        let sampler = NeighborSampler::new(SamplingSpec::uniform(3, 1)).unwrap();
        let seeds: Vec<u32> = (0..16).collect();
        let s = sampler.sample(&hg, &plan, &seeds).unwrap();
        for sg in &s.plan.subgraphs.subgraphs {
            for &r in &s.seed_rows {
                let d = sg.adj.degree(r as usize);
                assert!(d <= 3, "seed row degree {d} > 3");
            }
        }
        // full fanout reproduces the parent rows exactly (remapped)
        let full = NeighborSampler::new(SamplingSpec::uniform(usize::MAX, 1)).unwrap();
        let s = full.sample(&hg, &plan, &seeds).unwrap();
        for (sg, parent) in s.plan.subgraphs.subgraphs.iter().zip(&plan.subgraphs.subgraphs) {
            for (j, &seed) in seeds.iter().enumerate() {
                assert_eq!(
                    sg.adj.degree(s.seed_rows[j] as usize),
                    parent.adj.degree(seed as usize)
                );
            }
        }
    }

    #[test]
    fn sampling_is_deterministic() {
        let (hg, plan) = imdb_han();
        let sampler = NeighborSampler::new(SamplingSpec::uniform(2, 2)).unwrap();
        let a = sampler.sample(&hg, &plan, &[0, 1, 2, 3]).unwrap();
        let b = sampler.sample(&hg, &plan, &[0, 1, 2, 3]).unwrap();
        assert_eq!(a.nodes, b.nodes);
        assert_eq!(a.seed_rows, b.seed_rows);
        for (x, y) in a.plan.subgraphs.subgraphs.iter().zip(&b.plan.subgraphs.subgraphs) {
            assert_eq!(x.adj, y.adj);
        }
    }

    #[test]
    fn layers_expand_the_frontier() {
        let (hg, plan) = imdb_han();
        let one = NeighborSampler::new(SamplingSpec::uniform(4, 1)).unwrap();
        let two = NeighborSampler::new(SamplingSpec::uniform(4, 2)).unwrap();
        let a = one.sample(&hg, &plan, &[0]).unwrap();
        let b = two.sample(&hg, &plan, &[0]).unwrap();
        assert!(b.total_nodes() >= a.total_nodes());
        assert!(b.total_edges() >= a.total_edges());
        assert!(b.stats_line().contains("1 seeds"));
    }

    #[test]
    fn bad_seeds_are_rejected() {
        let (hg, plan) = imdb_han();
        let sampler = NeighborSampler::new(SamplingSpec::uniform(4, 1)).unwrap();
        assert!(sampler.sample(&hg, &plan, &[]).is_err());
        let count = hg.node_type(plan.target).count as u32;
        assert!(sampler.sample(&hg, &plan, &[count]).is_err());
    }

    #[test]
    fn rgcn_embeddings_are_sliced() {
        let hg = datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap();
        let plan = models::build_plan(ModelId::Rgcn, &hg, &ModelConfig::default()).unwrap();
        let sampler = NeighborSampler::new(SamplingSpec::uniform(4, 1)).unwrap();
        let s = sampler.sample(&hg, &plan, &[1, 3]).unwrap();
        for (&ty, embed) in &s.plan.weights.embed {
            assert_eq!(embed.rows(), s.nodes[ty].len());
            // sliced rows match the parent table's rows
            for (l, &g) in s.nodes[ty].iter().enumerate() {
                assert_eq!(embed.row(l), plan.weights.embed[&ty].row(g as usize));
            }
        }
    }

    #[test]
    fn cache_hits_build_miss_only_subcsrs() {
        let (hg, plan) = imdb_han();
        let sampler = NeighborSampler::new(SamplingSpec::uniform(usize::MAX, 1)).unwrap();
        let mut cache = ReuseCache::new(ReuseSpec::rows(1 << 12));
        let a = sampler.sample_with_cache(&hg, &plan, &[0, 1, 2], &mut cache).unwrap();
        let a_ov = a.overlay.as_ref().expect("cache-threaded sample carries an overlay");
        assert_eq!(a_ov.prefilled_rows(), 0, "cold cache cannot prefill");
        let computed: usize = a_ov.computed.iter().map(|v| v.len()).sum();
        assert!(computed > 0, "fully-covered rows must be marked computed");
        // publish the computed rows as the executor would
        let stub = vec![0.5f32; plan.config.hidden_dim];
        for (si, rows) in a_ov.computed.iter().enumerate() {
            for &(_, parent) in rows {
                cache.agg_insert(si, parent, &stub);
            }
        }
        // same seeds again: every covered row hits, edges disappear, but
        // the node set still matches a cache-cold sample exactly
        let b = sampler.sample_with_cache(&hg, &plan, &[0, 1, 2], &mut cache).unwrap();
        let b_ov = b.overlay.as_ref().unwrap();
        assert_eq!(b_ov.prefilled_rows(), computed);
        let cold = sampler.sample(&hg, &plan, &[0, 1, 2]).unwrap();
        assert_eq!(b.nodes, cold.nodes);
        assert_eq!(b.seed_rows, cold.seed_rows);
        assert!(b.total_edges() <= cold.total_edges());
        if cold.total_edges() > 0 {
            assert!(b.total_edges() < cold.total_edges(), "hit rows must shed their edges");
        }
        assert!(cache.stats().agg_hits >= computed as u64);
    }

    #[test]
    fn projection_only_spec_skips_aggregate_lookups() {
        let (hg, plan) = imdb_han();
        let sampler = NeighborSampler::new(SamplingSpec::uniform(usize::MAX, 1)).unwrap();
        let mut cache = ReuseCache::new(ReuseSpec::projection_only(64));
        let s = sampler.sample_with_cache(&hg, &plan, &[0, 1, 2], &mut cache).unwrap();
        let ov = s.overlay.as_ref().unwrap();
        assert_eq!(ov.prefilled_rows(), 0);
        assert!(ov.computed.iter().all(|v| v.is_empty()));
        assert_eq!(
            cache.stats().agg_misses,
            0,
            "a disabled aggregate cache must never be consulted"
        );
    }

    #[test]
    fn truncated_rows_bypass_the_aggregate_cache() {
        let (hg, plan) = imdb_han();
        // fanout 1 truncates every multi-neighbor row; only degree<=1
        // rows may consult the cache
        let sampler = NeighborSampler::new(SamplingSpec::uniform(1, 1)).unwrap();
        let mut cache = ReuseCache::new(ReuseSpec::rows(1 << 12));
        let s = sampler.sample_with_cache(&hg, &plan, &[0, 1, 2, 3], &mut cache).unwrap();
        let ov = s.overlay.as_ref().unwrap();
        for (si, sg) in plan.subgraphs.subgraphs.iter().enumerate() {
            for &(_, parent) in &ov.computed[si] {
                assert!(sg.adj.degree(parent as usize) <= 1);
            }
        }
        // lookups happened only for covered rows
        let stats = cache.stats();
        let covered: u64 = ov.computed.iter().map(|v| v.len() as u64).sum();
        assert_eq!(stats.agg_misses, covered);
    }
}
