//! Token-bucket admission control.
//!
//! The serving runtime meters admission in *node ids* (the unit of
//! executor work), not requests, so a 64-id `submit_batch` draws 64×
//! the tokens of a singleton. Refill happens lazily from explicit
//! caller-supplied timestamps ([`crate::serving::Nanos`]), which keeps
//! the bucket pure state — no hidden `Instant::now()` — and therefore
//! drivable by the virtual-clock test harness.

use super::clock::Nanos;

/// A token bucket: capacity `burst`, refilled continuously at `rate`
/// tokens per second. One token admits one node id.
#[derive(Debug, Clone)]
pub struct TokenBucket {
    rate_per_ns: f64,
    burst: f64,
    tokens: f64,
    last: Nanos,
}

impl TokenBucket {
    /// Bucket that starts full. `rate_per_sec` must be positive (tiny
    /// rates are clamped away from zero); `burst` is clamped to ≥ 1
    /// token. Requests larger than `burst` ids can never be admitted —
    /// size the burst to at least the largest batch you accept.
    pub fn new(rate_per_sec: f64, burst: f64, now: Nanos) -> TokenBucket {
        let burst = burst.max(1.0);
        TokenBucket {
            rate_per_ns: rate_per_sec.max(1e-9) / 1e9,
            burst,
            tokens: burst,
            last: now,
        }
    }

    /// Try to take `n` tokens at time `now`. On refusal returns the
    /// nanoseconds until the deficit would refill at the configured
    /// rate — a `retry-after` hint surfaced to the client.
    pub fn try_take(&mut self, n: f64, now: Nanos) -> Result<(), u64> {
        self.refill(now);
        if self.tokens + 1e-9 >= n {
            self.tokens -= n;
            Ok(())
        } else {
            let deficit = n - self.tokens;
            Err((deficit / self.rate_per_ns).ceil() as u64)
        }
    }

    /// Current token level (after refilling to `now`).
    pub fn level(&mut self, now: Nanos) -> f64 {
        self.refill(now);
        self.tokens
    }

    fn refill(&mut self, now: Nanos) {
        if now > self.last {
            let gained = (now - self.last) as f64 * self.rate_per_ns;
            self.tokens = (self.tokens + gained).min(self.burst);
            self.last = now;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_full_and_drains() {
        let mut b = TokenBucket::new(1000.0, 4.0, 0);
        assert!(b.try_take(4.0, 0).is_ok());
        assert!(b.try_take(1.0, 0).is_err());
    }

    #[test]
    fn refills_at_rate() {
        // 1000 tokens/sec = 1 token per millisecond
        let mut b = TokenBucket::new(1000.0, 2.0, 0);
        assert!(b.try_take(2.0, 0).is_ok());
        assert!(b.try_take(1.0, 0).is_err());
        assert!(b.try_take(1.0, 500_000).is_err(), "0.5 tokens is not enough");
        assert!(b.try_take(1.0, 1_000_000).is_ok(), "1ms refills one token");
    }

    #[test]
    fn burst_caps_refill() {
        let mut b = TokenBucket::new(1000.0, 2.0, 0);
        // after a long idle period the bucket holds exactly `burst`
        assert!((b.level(10_000_000_000) - 2.0).abs() < 1e-9);
        assert!(b.try_take(3.0, 10_000_000_000).is_err());
    }

    #[test]
    fn retry_after_reflects_deficit() {
        let mut b = TokenBucket::new(1000.0, 1.0, 0);
        assert!(b.try_take(1.0, 0).is_ok());
        let retry = b.try_take(1.0, 0).unwrap_err();
        // a full token at 1/ms: ~1ms away
        assert!((900_000..=1_100_000).contains(&retry), "retry {retry}");
    }

    #[test]
    fn time_never_runs_backwards() {
        let mut b = TokenBucket::new(1000.0, 4.0, 1_000_000);
        assert!(b.try_take(4.0, 1_000_000).is_ok());
        // an earlier timestamp must not mint tokens
        assert!(b.try_take(1.0, 0).is_err());
    }
}
