//! Time source abstraction for the serving runtime.
//!
//! The dispatcher loop never calls `Instant::now()` or sleeps directly;
//! every timed decision (batch close, deadline expiry, token refill)
//! goes through a [`Clock`]. Production uses [`SystemClock`]; tests use
//! [`crate::testutil::VirtualClock`], which only moves when the test
//! calls `advance`, so size-vs-timeout closing, expiry and refill are
//! exercised deterministically without real sleeps.

use std::sync::{Arc, Condvar, MutexGuard};
use std::time::Instant;

/// Nanoseconds since the clock's epoch.
pub type Nanos = u64;

/// A monotonic time source plus the blocking primitives the dispatcher
/// loop parks on. Implementations must wake waiters when time (by their
/// notion) passes `deadline`; callers always re-check their predicate
/// after a wake, so spurious wakeups are harmless.
pub trait Clock: Send + Sync + 'static {
    /// Current time in nanoseconds since this clock's epoch.
    fn now(&self) -> Nanos;

    /// Register a condvar the clock should notify whenever its time
    /// jumps (no-op for real clocks — the OS wakes timed waits itself).
    fn register_waker(&self, cv: &Arc<Condvar>) {
        let _ = cv;
    }

    /// Block on `cv` until notified (used when there is nothing timed
    /// to wait for, e.g. an empty queue).
    fn wait<'a, T>(&self, cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
        cv.wait(guard).unwrap_or_else(|e| e.into_inner())
    }

    /// Block on `cv` until notified or the clock reaches `deadline`.
    fn wait_deadline<'a, T>(
        &self,
        cv: &Condvar,
        guard: MutexGuard<'a, T>,
        deadline: Nanos,
    ) -> MutexGuard<'a, T>;
}

/// Wall-clock time anchored at construction.
#[derive(Debug)]
pub struct SystemClock {
    epoch: Instant,
}

impl SystemClock {
    /// A clock whose epoch is "now".
    pub fn new() -> SystemClock {
        SystemClock { epoch: Instant::now() }
    }
}

impl Default for SystemClock {
    fn default() -> Self {
        SystemClock::new()
    }
}

impl Clock for SystemClock {
    fn now(&self) -> Nanos {
        self.epoch.elapsed().as_nanos() as Nanos
    }

    fn wait_deadline<'a, T>(
        &self,
        cv: &Condvar,
        guard: MutexGuard<'a, T>,
        deadline: Nanos,
    ) -> MutexGuard<'a, T> {
        let now = self.now();
        if now >= deadline {
            return guard;
        }
        let timeout = std::time::Duration::from_nanos(deadline - now);
        cv.wait_timeout(guard, timeout).unwrap_or_else(|e| e.into_inner()).0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn system_clock_is_monotonic() {
        let c = SystemClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn wait_deadline_returns_after_timeout() {
        let c = SystemClock::new();
        let m = Mutex::new(());
        let cv = Condvar::new();
        let g = m.lock().unwrap();
        let start = c.now();
        let _g = c.wait_deadline(&cv, g, start + 1_000_000); // 1ms
        assert!(c.now() >= start + 1_000_000);
    }

    #[test]
    fn wait_deadline_past_deadline_is_immediate() {
        let c = SystemClock::new();
        let m = Mutex::new(());
        let cv = Condvar::new();
        let g = m.lock().unwrap();
        let _g = c.wait_deadline(&cv, g, 0);
    }
}
