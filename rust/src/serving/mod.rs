//! Async serving runtime: continuous batching, admission control and
//! deadline-aware dispatch.
//!
//! This subsystem replaces the synchronous [`crate::coordinator::serve`]
//! dispatcher (which still exists as a thin shim over it) with a real
//! server loop:
//!
//! * **Continuous batching** — the dispatcher forms one *wave* at a
//!   time against the live queue. A wave closes on size (enough ids to
//!   fill every shard lane) or timeout (`flush_after` from the oldest
//!   pending request), whichever comes first; between waves the queue
//!   is re-read, so newly arrived or newly urgent requests join the
//!   next wave instead of waiting out a frozen lockstep round.
//! * **Deadline/priority scheduling** — requests carry an optional
//!   deadline and a priority class. Classes are served in strict
//!   priority order; within a class, earliest-deadline-first with FIFO
//!   tie-break (so a large batch cannot be starved by later
//!   singletons). Requests whose deadline passes while queued are
//!   failed fast with [`ServeError::DeadlineExceeded`] instead of
//!   wasting executor capacity.
//! * **Admission control** — a token-bucket (metered in node ids)
//!   plus a bounded queue and per-shard-lane in-flight accounting shed
//!   excess load at submit time with typed errors
//!   ([`ServeError::Overloaded`], [`ServeError::QueueFull`]) rather
//!   than queueing unboundedly.
//! * **Per-class telemetry** — [`ServeStats`] reports per-priority-
//!   class QPS and p50/p95/p99 latency from a streaming
//!   [`crate::util::stats::QuantileSketch`].
//!
//! Every timed decision goes through the [`Clock`] trait, so the whole
//! loop can be driven by the deterministic `testutil::VirtualClock`.

pub mod admission;
pub mod clock;
pub mod server;

pub use admission::TokenBucket;
pub use clock::{Clock, Nanos, SystemClock};
pub use server::{AsyncServer, BatchExecutor, BatchReply};

use crate::util::Summary;
use std::time::Duration;

/// Configuration for the async serving runtime.
#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// Per-lane dispatch size: a wave closes once `max_batch × lanes`
    /// ids are pending, and each executor call carries at most
    /// `max_batch` ids.
    pub max_batch: usize,
    /// Maximum time a wave stays open waiting to fill, measured from
    /// the oldest pending request's arrival.
    pub flush_after: Duration,
    /// Bound on queued (admitted, not yet dispatched) node ids; beyond
    /// it submissions fail with [`ServeError::QueueFull`].
    pub queue_cap: usize,
    /// Bound on queued + in-flight ids per shard lane; beyond it
    /// submissions touching that lane fail with
    /// [`ServeError::Overloaded`]. `None` = `queue_cap` (effectively
    /// no extra per-lane bound).
    pub lane_cap: Option<usize>,
    /// Token-bucket admission rate in node ids per second; `None`
    /// disables rate admission.
    pub admission_qps: Option<f64>,
    /// Token-bucket burst in ids. `None` = `max(admission_qps,
    /// max_batch)`, i.e. at least one full dispatch.
    pub admission_burst: Option<f64>,
    /// Number of priority classes (≥ 1). Class 0 is served first.
    pub priority_lanes: usize,
    /// Deadline applied to submissions that do not carry their own.
    pub default_deadline: Option<Duration>,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            max_batch: 32,
            flush_after: Duration::from_millis(2),
            queue_cap: 4096,
            lane_cap: None,
            admission_qps: None,
            admission_burst: None,
            priority_lanes: 2,
            default_deadline: None,
        }
    }
}

/// Per-submission options: priority class and deadline.
#[derive(Debug, Clone, Copy, Default)]
pub struct SubmitOpts {
    /// Priority class, 0 = highest. Clamped to the configured number
    /// of [`ServingConfig::priority_lanes`].
    pub class: usize,
    /// Relative deadline from submission; `None` falls back to
    /// [`ServingConfig::default_deadline`] (or no deadline at all).
    pub deadline: Option<Duration>,
}

impl SubmitOpts {
    /// Options for a given priority class.
    pub fn class(class: usize) -> SubmitOpts {
        SubmitOpts { class, deadline: None }
    }

    /// Options with a relative deadline in milliseconds.
    pub fn deadline_ms(ms: u64) -> SubmitOpts {
        SubmitOpts { class: 0, deadline: Some(Duration::from_millis(ms)) }
    }

    /// Attach a relative deadline.
    pub fn with_deadline(mut self, deadline: Duration) -> SubmitOpts {
        self.deadline = Some(deadline);
        self
    }
}

/// Typed submission/serving failures surfaced to clients.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Admission control shed the request (token bucket empty or a
    /// shard lane saturated); retry after the hinted delay.
    Overloaded {
        /// Suggested client backoff in nanoseconds.
        retry_after_ns: u64,
    },
    /// The bounded queue is full.
    QueueFull {
        /// Ids queued at rejection time.
        queued: usize,
        /// Configured queue capacity in ids.
        cap: usize,
    },
    /// The server loop has been stopped; no further submissions.
    Stopped,
    /// The request's deadline passed before it could be dispatched.
    DeadlineExceeded {
        /// How late the request was, in nanoseconds.
        late_ns: u64,
    },
    /// The executor failed while running the wave containing this
    /// request.
    Exec(String),
    /// The submission itself was malformed (e.g. empty id list).
    Invalid(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded { retry_after_ns } => {
                write!(f, "overloaded: retry after {}ns", retry_after_ns)
            }
            ServeError::QueueFull { queued, cap } => {
                write!(f, "queue full: {queued} of {cap} ids queued")
            }
            ServeError::Stopped => write!(f, "server stopped"),
            ServeError::DeadlineExceeded { late_ns } => {
                write!(f, "deadline exceeded by {}ns", late_ns)
            }
            ServeError::Exec(msg) => write!(f, "executor failed: {msg}"),
            ServeError::Invalid(msg) => write!(f, "invalid request: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Per-priority-class serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ClassStats {
    /// Priority class index (0 = highest).
    pub class: usize,
    /// Node ids admitted into the queue.
    pub submitted: u64,
    /// Node ids completed (rows returned).
    pub completed: u64,
    /// Requests completed.
    pub requests: u64,
    /// Requests that expired in the queue (deadline exceeded).
    pub expired: u64,
    /// Requests rejected at submit (`Overloaded` + `QueueFull`).
    pub rejected: u64,
    /// Completed ids per second of server lifetime.
    pub qps: f64,
    /// p50 queue-to-reply latency in nanoseconds.
    pub p50_ns: u64,
    /// p95 queue-to-reply latency in nanoseconds.
    pub p95_ns: u64,
    /// p99 queue-to-reply latency in nanoseconds.
    pub p99_ns: u64,
    /// Mean queue-to-reply latency in nanoseconds.
    pub mean_ns: f64,
    /// Max queue-to-reply latency in nanoseconds.
    pub max_ns: u64,
}

/// Aggregate statistics for one server lifetime (also used by the
/// legacy [`crate::coordinator::serve::Server`] shim).
#[derive(Debug, Clone)]
pub struct ServeStats {
    /// Total node ids completed.
    pub completed: u64,
    /// Executor dispatches issued.
    pub batches: u64,
    /// Per-request latency summary (submit → reply), nanoseconds.
    pub latency: Summary,
    /// Completed ids per second of server lifetime.
    pub throughput_rps: f64,
    /// Mean ids per executor dispatch.
    pub mean_batch: f64,
    /// Requests rejected by the token bucket or lane saturation.
    pub rejected_overloaded: u64,
    /// Requests rejected by the bounded queue.
    pub rejected_queue_full: u64,
    /// Requests that expired in the queue.
    pub expired: u64,
    /// Waves whose executor call failed.
    pub exec_failures: u64,
    /// High-water mark of queued ids.
    pub peak_queued: usize,
    /// Per-priority-class breakdown (indexed by class).
    pub classes: Vec<ClassStats>,
    /// Cross-request reuse-cache counters, when the executor exposes a
    /// reuse cache (aggregated across shard lanes).
    pub reuse: Option<crate::reuse::ReuseStats>,
    /// Per-shard-lane reuse counters, when sharded reuse is active.
    pub reuse_lanes: Vec<crate::reuse::ReuseStats>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_display_is_informative() {
        let e = ServeError::Overloaded { retry_after_ns: 5 };
        assert!(e.to_string().contains("retry after 5ns"));
        let e = ServeError::QueueFull { queued: 9, cap: 8 };
        assert!(e.to_string().contains("9 of 8"));
        assert_eq!(ServeError::Stopped.to_string(), "server stopped");
        let e = ServeError::DeadlineExceeded { late_ns: 3 };
        assert!(e.to_string().contains("by 3ns"));
        assert!(ServeError::Exec("boom".into()).to_string().contains("boom"));
        assert!(ServeError::Invalid("empty".into()).to_string().contains("empty"));
    }

    #[test]
    fn submit_opts_builders() {
        let o = SubmitOpts::class(3);
        assert_eq!(o.class, 3);
        assert!(o.deadline.is_none());
        let o = SubmitOpts::deadline_ms(7);
        assert_eq!(o.deadline, Some(Duration::from_millis(7)));
        let o = SubmitOpts::class(1).with_deadline(Duration::from_secs(1));
        assert_eq!(o.class, 1);
        assert_eq!(o.deadline, Some(Duration::from_secs(1)));
    }

    #[test]
    fn config_defaults_are_sane() {
        let c = ServingConfig::default();
        assert_eq!(c.max_batch, 32);
        assert_eq!(c.queue_cap, 4096);
        assert!(c.priority_lanes >= 1);
        assert!(c.admission_qps.is_none());
    }
}
