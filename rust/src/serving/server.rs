//! The async server loop: a dispatcher thread forming *waves* against
//! a live priority queue.
//!
//! ```text
//!  submit(ids, opts)                dispatcher thread
//!  ───────────────►  admission ──►  ┌──────────────────────────────┐
//!   typed errors:     · stopped?    │ loop:                        │
//!   Overloaded        · queue cap   │   expire overdue requests    │
//!   QueueFull         · lane cap    │   wait: size OR timeout OR   │
//!   DeadlineExceeded  · token       │         earliest deadline    │
//!   Stopped             bucket      │   pop wave (class, deadline, │
//!                          │        │            age order)        │
//!                          ▼        │   group by shard lane        │
//!                    per-class      │   execute ≤max_batch rounds  │
//!                    binary heaps   │   reassemble rows per request│
//!                                   │   reply + record stats       │
//!                                   └──────────────────────────────┘
//! ```
//!
//! Unlike the old lockstep dispatcher (freeze queue → chunk → drain →
//! repeat), the loop re-reads the queue between waves: requests that
//! arrive while a wave executes join the next wave immediately, and a
//! backlog left behind by a full wave closes the next wave without
//! waiting out the flush window — shard lanes refill as they free up.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard, OnceLock};

use crate::dynamic::{EpochBarrier, EpochReport, GraphUpdate};
use crate::partition::ShardMap;
use crate::reuse::ReuseStats;
use crate::session::{Session, SessionBuilder};
use crate::util::stats::{QuantileSketch, Summary};
use crate::{Error, Result};

use super::admission::TokenBucket;
use super::clock::{Clock, Nanos, SystemClock};
use super::{ClassStats, ServeError, ServeStats, ServingConfig, SubmitOpts};

/// Reply payload of one submission: all embedding rows of the request
/// in submission order, or the typed serving failure.
pub type BatchReply = std::result::Result<Vec<Vec<f32>>, ServeError>;

/// Cap on raw latency samples kept for the legacy [`Summary`]; the
/// per-class [`QuantileSketch`]es keep recording past it.
const LATENCY_SAMPLE_CAP: usize = 1 << 17;

/// Batch executor: given the node ids of one dispatch, return one
/// embedding row per id. Deliberately not `Send` — the executor lives
/// entirely inside the dispatcher thread (constructed there via
/// [`AsyncServer::start_with`]), which is what lets PJRT executables
/// (`Rc` internals) serve requests.
pub trait BatchExecutor {
    /// Execute one dispatch.
    fn execute(&mut self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>>;

    /// Cumulative reuse-cache counters, when the executor serves
    /// through a session with cross-request reuse enabled.
    fn reuse_stats(&self) -> Option<ReuseStats> {
        None
    }

    /// Per-shard-lane reuse counters, when sharded reuse is active.
    fn reuse_lane_stats(&self) -> Option<Vec<ReuseStats>> {
        None
    }

    /// Number of shard-affine dispatch lanes this executor exposes.
    /// When `> 1` each wave is grouped by [`BatchExecutor::shard_of`]
    /// and dispatched as rounds carrying up to `max_batch` ids from
    /// every lane, contiguous per lane.
    fn shards(&self) -> usize {
        1
    }

    /// Owning shard-lane of a node id (only consulted when
    /// [`BatchExecutor::shards`] `> 1`).
    fn shard_of(&self, _node_id: u32) -> usize {
        0
    }

    /// A `Send + Sync` snapshot of the shard ownership table, if the
    /// executor has one. Published once by the dispatcher thread so the
    /// *submit* side can account queued ids per lane and reject
    /// submissions that would saturate a lane.
    fn shard_map(&self) -> Option<ShardMap> {
        None
    }

    /// Buffer graph updates for the next epoch flip (dynamic sessions
    /// only; see [`crate::dynamic`]). Executors without streaming
    /// support reject the control.
    fn apply_updates(&mut self, _updates: Vec<GraphUpdate>) -> Result<usize> {
        Err(Error::config("executor does not support streaming graph updates"))
    }

    /// Flip the epoch barrier: apply every buffered update atomically.
    /// Only ever called between waves by the dispatcher thread.
    fn flip_epoch(&mut self) -> Result<EpochReport> {
        Err(Error::config("executor does not support epoch flips"))
    }

    /// The epoch the executor currently serves (0 for static executors).
    fn epoch(&self) -> u64 {
        0
    }

    /// Retire a dead cluster worker and re-place its shards (cluster
    /// sessions only; see [`crate::cluster`]). Only ever called between
    /// waves by the dispatcher thread — worker loss is an
    /// epoch-barrier-style control event, so the in-flight wave
    /// completes (replaying lost sub-batches internally) before the
    /// placement visibly changes. Returns the number of shards moved.
    fn handle_worker_down(&mut self, _worker: usize) -> Result<usize> {
        Err(Error::config("executor does not support cluster workers"))
    }
}

impl<F> BatchExecutor for F
where
    F: FnMut(&[u32]) -> Result<Vec<Vec<f32>>>,
{
    fn execute(&mut self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>> {
        self(node_ids)
    }
}

/// Where a request's rows go once its wave completes.
#[derive(Debug)]
pub(crate) enum ReplyTo {
    /// Legacy single-row reply; dropped on failure.
    Single(mpsc::Sender<Vec<f32>>),
    /// Legacy batch reply; dropped on failure.
    Rows(mpsc::Sender<Vec<Vec<f32>>>),
    /// Typed reply: always receives `Ok(rows)` or the `ServeError`.
    Typed(mpsc::Sender<BatchReply>),
}

/// One admitted request waiting in a class heap. Ordered by
/// `(deadline, admission sequence)` — earliest deadline first,
/// FIFO tie-break for deadline-less requests — so a large batch
/// admitted early cannot be starved by a stream of later singletons.
#[derive(Debug)]
struct PendingReq {
    /// `(deadline or u64::MAX, admission seq)` — the heap key.
    key: (Nanos, u64),
    class: usize,
    ids: Vec<u32>,
    enqueued: Nanos,
    deadline: Option<Nanos>,
    /// Per-lane id counts at admission (when a shard map was
    /// published); mirrors the exact decrement on pop/expiry.
    lane_counts: Option<Vec<usize>>,
    reply: ReplyTo,
}

impl PartialEq for PendingReq {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}
impl Eq for PendingReq {}
impl PartialOrd for PendingReq {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for PendingReq {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key.cmp(&other.key)
    }
}

/// A control message for the dispatcher, drained only **between**
/// waves — the epoch-barrier ordering: an in-flight wave always
/// completes against the snapshot it was dispatched on, and every wave
/// dispatched after the control observes its effect.
pub(crate) enum ControlMsg {
    /// Buffer updates in the executor's log; ack carries the pending
    /// count or the executor's rejection.
    Apply {
        /// The update batch to buffer.
        updates: Vec<GraphUpdate>,
        /// Completion channel.
        ack: mpsc::Sender<std::result::Result<usize, String>>,
    },
    /// Flip the epoch barrier ([`crate::dynamic::EpochBarrier`]).
    Flip(EpochBarrier),
    /// Retire a dead cluster worker between waves; ack carries the
    /// number of re-placed shards or the executor's rejection.
    WorkerDown {
        /// The worker reported dead.
        worker: usize,
        /// Completion channel.
        ack: mpsc::Sender<std::result::Result<usize, String>>,
    },
}

/// Mutable queue state behind the submit/dispatch mutex.
struct QueueState {
    /// One min-heap (via `Reverse`) per priority class.
    classes: Vec<BinaryHeap<Reverse<PendingReq>>>,
    /// Total queued (admitted, undispatched) node ids.
    queued_ids: usize,
    /// Queued ids per shard lane (only maintained once a shard map is
    /// published).
    lane_queued: Vec<usize>,
    /// In-flight (dispatched, not yet replied) ids per shard lane.
    lane_inflight: Vec<usize>,
    /// Token-bucket admission, when configured.
    bucket: Option<TokenBucket>,
    /// Pending epoch-barrier controls, drained between waves.
    controls: Vec<ControlMsg>,
    /// When the currently-filling wave must close: set to
    /// `arrival + flush_after` when the queue goes non-empty, and to
    /// "now" when a wave leaves a backlog behind (a backlog means load
    /// ≥ capacity — no point waiting to fill).
    fill_deadline: Option<Nanos>,
    stopped: bool,
    seq: u64,
}

/// Per-class raw counters.
#[derive(Default)]
struct RawClass {
    submitted: u64,
    completed: u64,
    requests: u64,
    expired: u64,
    rejected_overloaded: u64,
    rejected_queue_full: u64,
    sketch: QuantileSketch,
}

/// Raw aggregate counters behind the stats mutex.
struct RawStats {
    completed: u64,
    batches: u64,
    batch_id_sum: u64,
    latencies_ns: Vec<f64>,
    exec_failures: u64,
    peak_queued: usize,
    reuse: Option<ReuseStats>,
    reuse_lanes: Vec<ReuseStats>,
    classes: Vec<RawClass>,
}

impl RawStats {
    fn new(classes: usize) -> RawStats {
        RawStats {
            completed: 0,
            batches: 0,
            batch_id_sum: 0,
            latencies_ns: Vec::new(),
            exec_failures: 0,
            peak_queued: 0,
            reuse: None,
            reuse_lanes: Vec::new(),
            classes: (0..classes).map(|_| RawClass::default()).collect(),
        }
    }
}

/// Lane topology published once by the dispatcher thread after the
/// executor is constructed.
struct LaneInfo {
    lanes: usize,
    map: Option<ShardMap>,
    lane_cap: usize,
}

/// State shared between the submit side and the dispatcher thread.
/// Lock order where both are held: `state` then `stats`.
struct Shared<C: Clock> {
    config: ServingConfig,
    clock: Arc<C>,
    state: Mutex<QueueState>,
    cv: Arc<Condvar>,
    stats: Mutex<RawStats>,
    lanes: OnceLock<LaneInfo>,
    started: Nanos,
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// The async serving runtime: owns the dispatcher thread. Generic over
/// the [`Clock`] so tests drive it with a deterministic virtual clock;
/// production code uses the [`SystemClock`] default.
pub struct AsyncServer<C: Clock = SystemClock> {
    shared: Arc<Shared<C>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl AsyncServer<SystemClock> {
    /// Start the dispatcher with the given (Send) executor on the wall
    /// clock.
    pub fn start(
        config: ServingConfig,
        executor: impl BatchExecutor + Send + 'static,
    ) -> AsyncServer {
        Self::start_with(config, move || executor)
    }

    /// Start the dispatcher, constructing the executor *inside* the
    /// dispatcher thread (required for non-`Send` executors, e.g. PJRT
    /// executables holding `Rc` internals).
    pub fn start_with<E, F>(config: ServingConfig, make_executor: F) -> AsyncServer
    where
        E: BatchExecutor + 'static,
        F: FnOnce() -> E + Send + 'static,
    {
        Self::start_with_clock(config, Arc::new(SystemClock::new()), make_executor)
    }

    /// Start the dispatcher around a [`Session`] built from `builder`
    /// inside the dispatcher thread — any backend × any schedule
    /// policy, with plan/weights/artifacts reused across waves. If the
    /// session fails to build, every wave reports the build error.
    pub fn start_session(config: ServingConfig, builder: SessionBuilder) -> AsyncServer {
        Self::start_with(config, move || SessionExecutor {
            session: builder.build().map_err(|e| e.to_string()),
        })
    }
}

impl<C: Clock> AsyncServer<C> {
    /// Start the dispatcher on an explicit clock (tests pass a
    /// `testutil::VirtualClock`).
    pub fn start_with_clock<E, F>(
        config: ServingConfig,
        clock: Arc<C>,
        make_executor: F,
    ) -> AsyncServer<C>
    where
        E: BatchExecutor + 'static,
        F: FnOnce() -> E + Send + 'static,
    {
        let classes = config.priority_lanes.max(1);
        let now = clock.now();
        let bucket = config.admission_qps.map(|qps| {
            let burst = config.admission_burst.unwrap_or(qps.max(config.max_batch as f64));
            TokenBucket::new(qps, burst, now)
        });
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState {
                classes: (0..classes).map(|_| BinaryHeap::new()).collect(),
                queued_ids: 0,
                lane_queued: Vec::new(),
                lane_inflight: Vec::new(),
                bucket,
                controls: Vec::new(),
                fill_deadline: None,
                stopped: false,
                seq: 0,
            }),
            cv: Arc::new(Condvar::new()),
            stats: Mutex::new(RawStats::new(classes)),
            lanes: OnceLock::new(),
            started: now,
            clock,
            config,
        });
        shared.clock.register_waker(&shared.cv);
        let sh = Arc::clone(&shared);
        let handle = std::thread::spawn(move || {
            let mut executor = make_executor();
            dispatch_loop(&sh, &mut executor);
        });
        AsyncServer { shared, handle: Some(handle) }
    }

    /// Session-backed start on an explicit clock.
    pub fn start_session_with_clock(
        config: ServingConfig,
        clock: Arc<C>,
        builder: SessionBuilder,
    ) -> AsyncServer<C> {
        Self::start_with_clock(config, clock, move || SessionExecutor {
            session: builder.build().map_err(|e| e.to_string()),
        })
    }

    /// Submit one request (any number of node ids ≥ 1). On admission
    /// returns a receiver that yields exactly one [`BatchReply`]:
    /// `Ok(rows)` in `node_ids` order, or the typed failure
    /// (deadline expiry, executor error, shutdown drop). Admission
    /// itself can refuse with [`ServeError::Overloaded`] /
    /// [`ServeError::QueueFull`] / [`ServeError::Stopped`].
    pub fn submit(
        &self,
        node_ids: &[u32],
        opts: SubmitOpts,
    ) -> std::result::Result<mpsc::Receiver<BatchReply>, ServeError> {
        let (tx, rx) = mpsc::channel();
        self.submit_reply(node_ids, opts, ReplyTo::Typed(tx))?;
        Ok(rx)
    }

    /// Shared admission path for the typed API and the legacy shims.
    pub(crate) fn submit_reply(
        &self,
        node_ids: &[u32],
        opts: SubmitOpts,
        reply: ReplyTo,
    ) -> std::result::Result<(), ServeError> {
        let sh = &self.shared;
        if node_ids.is_empty() {
            return Err(ServeError::Invalid("empty request: no node ids".into()));
        }
        let classes = sh.config.priority_lanes.max(1);
        let class = opts.class.min(classes - 1);
        let now = sh.clock.now();
        let rel = opts.deadline.or(sh.config.default_deadline);
        if rel == Some(std::time::Duration::ZERO) {
            lock(&sh.stats).classes[class].expired += 1;
            return Err(ServeError::DeadlineExceeded { late_ns: 0 });
        }
        let deadline = rel.map(|d| now.saturating_add(d.as_nanos() as Nanos));
        let mut st = lock(&sh.state);
        if st.stopped {
            return Err(ServeError::Stopped);
        }
        // bounded queue (in ids)
        let cap = sh.config.queue_cap.max(1);
        if st.queued_ids + node_ids.len() > cap {
            let queued = st.queued_ids;
            drop(st);
            lock(&sh.stats).classes[class].rejected_queue_full += 1;
            return Err(ServeError::QueueFull { queued, cap });
        }
        // per-lane saturation (only once the dispatcher published the
        // shard map; earlier submissions skip the lane check)
        let lane_counts = sh.lanes.get().and_then(|li| {
            li.map.as_ref().map(|m| {
                let mut counts = vec![0usize; li.lanes];
                for &id in node_ids {
                    counts[m.shard_of(id).min(li.lanes - 1)] += 1;
                }
                counts
            })
        });
        if let (Some(counts), Some(li)) = (&lane_counts, sh.lanes.get()) {
            for (lane, &add) in counts.iter().enumerate() {
                if add == 0 {
                    continue;
                }
                let depth = st.lane_queued.get(lane).copied().unwrap_or(0)
                    + st.lane_inflight.get(lane).copied().unwrap_or(0);
                if depth + add > li.lane_cap {
                    drop(st);
                    lock(&sh.stats).classes[class].rejected_overloaded += 1;
                    return Err(ServeError::Overloaded {
                        retry_after_ns: sh.config.flush_after.as_nanos() as u64,
                    });
                }
            }
        }
        // token-bucket admission, metered in ids; checked last so a
        // request bounced by the caps above does not burn tokens
        if let Some(bucket) = st.bucket.as_mut() {
            if let Err(retry_after_ns) = bucket.try_take(node_ids.len() as f64, now) {
                drop(st);
                lock(&sh.stats).classes[class].rejected_overloaded += 1;
                return Err(ServeError::Overloaded { retry_after_ns });
            }
        }
        // admitted: enqueue
        if st.queued_ids == 0 {
            st.fill_deadline =
                Some(now.saturating_add(sh.config.flush_after.as_nanos() as Nanos));
        }
        st.seq += 1;
        let key = (deadline.unwrap_or(Nanos::MAX), st.seq);
        st.queued_ids += node_ids.len();
        if let Some(counts) = &lane_counts {
            if st.lane_queued.len() < counts.len() {
                st.lane_queued.resize(counts.len(), 0);
            }
            for (lane, &n) in counts.iter().enumerate() {
                st.lane_queued[lane] += n;
            }
        }
        st.classes[class].push(Reverse(PendingReq {
            key,
            class,
            ids: node_ids.to_vec(),
            enqueued: now,
            deadline,
            lane_counts,
            reply,
        }));
        let queued = st.queued_ids;
        drop(st);
        {
            let mut s = lock(&sh.stats);
            s.peak_queued = s.peak_queued.max(queued);
            s.classes[class].submitted += node_ids.len() as u64;
        }
        sh.cv.notify_all();
        Ok(())
    }

    /// Queue a batch of graph updates for the executor's update log.
    /// The dispatcher applies them between waves; the returned receiver
    /// yields the executor's answer (number of pending updates after
    /// the append, or the error message). Updates do not take effect
    /// until the next [`AsyncServer::flip_epoch`].
    pub fn apply_updates(
        &self,
        updates: Vec<GraphUpdate>,
    ) -> std::result::Result<mpsc::Receiver<std::result::Result<usize, String>>, ServeError> {
        let (tx, rx) = mpsc::channel();
        {
            let mut st = lock(&self.shared.state);
            if st.stopped {
                return Err(ServeError::Stopped);
            }
            st.controls.push(ControlMsg::Apply { updates, ack: tx });
        }
        self.shared.cv.notify_all();
        Ok(rx)
    }

    /// Queue an epoch flip. The dispatcher honours it strictly between
    /// waves: every request admitted before the flip that made it into
    /// an earlier wave completes on the old snapshot, and everything
    /// still queued when the barrier runs executes on the new epoch.
    /// The receiver yields the executor's [`EpochReport`] (or the error
    /// message when the flip failed and was rolled back).
    pub fn flip_epoch(
        &self,
    ) -> std::result::Result<mpsc::Receiver<std::result::Result<EpochReport, String>>, ServeError>
    {
        let (tx, rx) = mpsc::channel();
        {
            let mut st = lock(&self.shared.state);
            if st.stopped {
                return Err(ServeError::Stopped);
            }
            st.controls.push(ControlMsg::Flip(EpochBarrier { ack: tx }));
        }
        self.shared.cv.notify_all();
        Ok(rx)
    }

    /// Report a cluster worker as dead. The dispatcher honours it
    /// strictly **between waves**, exactly like an epoch flip: the
    /// in-flight wave completes first (the cluster protocol replays any
    /// sub-batches the dead worker was serving, so its replies are
    /// unaffected), then the worker is retired and its shards re-placed
    /// before the next wave dispatches. Queued requests never fail from
    /// the loss. The receiver yields the number of shards moved, or the
    /// executor's rejection (non-cluster sessions, last worker
    /// standing).
    pub fn report_worker_down(
        &self,
        worker: usize,
    ) -> std::result::Result<mpsc::Receiver<std::result::Result<usize, String>>, ServeError>
    {
        let (tx, rx) = mpsc::channel();
        {
            let mut st = lock(&self.shared.state);
            if st.stopped {
                return Err(ServeError::Stopped);
            }
            st.controls.push(ControlMsg::WorkerDown { worker, ack: tx });
        }
        self.shared.cv.notify_all();
        Ok(rx)
    }

    /// Snapshot of the current statistics without stopping the server.
    pub fn stats_snapshot(&self) -> ServeStats {
        self.mk_stats()
    }

    /// Stop accepting requests and join the dispatcher after it drains
    /// the queue. Idempotent; [`Drop`] calls it too. Submissions after
    /// `stop` fail with [`ServeError::Stopped`].
    pub fn stop(&mut self) {
        {
            let mut st = lock(&self.shared.state);
            st.stopped = true;
        }
        self.shared.cv.notify_all();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }

    /// Stop, drain, and return the final statistics.
    pub fn shutdown(mut self) -> ServeStats {
        self.stop();
        self.mk_stats()
    }

    fn mk_stats(&self) -> ServeStats {
        let sh = &self.shared;
        let elapsed =
            sh.clock.now().saturating_sub(sh.started) as f64 / 1e9;
        let s = lock(&sh.stats);
        let per_sec = |count: u64| if elapsed > 0.0 { count as f64 / elapsed } else { 0.0 };
        ServeStats {
            completed: s.completed,
            batches: s.batches,
            latency: Summary::of(&s.latencies_ns),
            throughput_rps: per_sec(s.completed),
            mean_batch: if s.batches == 0 {
                0.0
            } else {
                s.batch_id_sum as f64 / s.batches as f64
            },
            rejected_overloaded: s.classes.iter().map(|c| c.rejected_overloaded).sum(),
            rejected_queue_full: s.classes.iter().map(|c| c.rejected_queue_full).sum(),
            expired: s.classes.iter().map(|c| c.expired).sum(),
            exec_failures: s.exec_failures,
            peak_queued: s.peak_queued,
            classes: s
                .classes
                .iter()
                .enumerate()
                .map(|(class, rc)| ClassStats {
                    class,
                    submitted: rc.submitted,
                    completed: rc.completed,
                    requests: rc.requests,
                    expired: rc.expired,
                    rejected: rc.rejected_overloaded + rc.rejected_queue_full,
                    qps: per_sec(rc.completed),
                    p50_ns: rc.sketch.quantile(0.50),
                    p95_ns: rc.sketch.quantile(0.95),
                    p99_ns: rc.sketch.quantile(0.99),
                    mean_ns: rc.sketch.mean(),
                    max_ns: rc.sketch.max(),
                })
                .collect(),
            reuse: s.reuse.clone(),
            reuse_lanes: s.reuse_lanes.clone(),
        }
    }
}

impl<C: Clock> Drop for AsyncServer<C> {
    /// Dropping without [`AsyncServer::shutdown`] still drains pending
    /// requests and joins the dispatcher — no detached thread, no lost
    /// replies.
    fn drop(&mut self) {
        self.stop();
    }
}

/// Earliest queued deadline across every class heap (`u64::MAX` if no
/// queued request carries one).
fn earliest_deadline(st: &QueueState) -> Nanos {
    st.classes
        .iter()
        .filter_map(|h| h.peek().map(|Reverse(p)| p.key.0))
        .min()
        .unwrap_or(Nanos::MAX)
}

/// Pop every queued request whose deadline has passed, failing each
/// with [`ServeError::DeadlineExceeded`]. Called with the state lock
/// held (nested stats lock follows the `state → stats` order).
fn expire<C: Clock>(sh: &Shared<C>, st: &mut QueueState, now: Nanos) {
    for heap in st.classes.iter_mut() {
        loop {
            let overdue = matches!(
                heap.peek(),
                Some(Reverse(p)) if p.deadline.is_some_and(|d| d < now)
            );
            if !overdue {
                break;
            }
            let Reverse(p) = heap.pop().expect("peeked");
            st.queued_ids = st.queued_ids.saturating_sub(p.ids.len());
            if let Some(counts) = &p.lane_counts {
                for (lane, &n) in counts.iter().enumerate() {
                    if let Some(q) = st.lane_queued.get_mut(lane) {
                        *q = q.saturating_sub(n);
                    }
                }
            }
            let late_ns = now - p.deadline.expect("overdue implies deadline");
            lock(&sh.stats).classes[p.class].expired += 1;
            match p.reply {
                ReplyTo::Typed(tx) => {
                    let _ = tx.send(Err(ServeError::DeadlineExceeded { late_ns }));
                }
                // legacy replies drop their channel on failure
                ReplyTo::Single(_) | ReplyTo::Rows(_) => {}
            }
        }
    }
}

/// Drain queued epoch-barrier controls and run them against the
/// executor. Called by the dispatcher strictly between waves, so a
/// flip never observes a half-executed batch: the in-flight wave has
/// fully completed on the old snapshot, and every request still queued
/// executes on the new epoch. Controls run without the state lock —
/// submissions keep being admitted (they just wait for the flip).
fn handle_controls<C: Clock, E: BatchExecutor>(sh: &Shared<C>, executor: &mut E) {
    let controls = {
        let mut st = lock(&sh.state);
        std::mem::take(&mut st.controls)
    };
    for control in controls {
        match control {
            ControlMsg::Apply { updates, ack } => {
                let _ = ack.send(executor.apply_updates(updates).map_err(|e| e.to_string()));
            }
            ControlMsg::Flip(barrier) => {
                let _ = barrier.ack.send(executor.flip_epoch().map_err(|e| e.to_string()));
            }
            ControlMsg::WorkerDown { worker, ack } => {
                let _ =
                    ack.send(executor.handle_worker_down(worker).map_err(|e| e.to_string()));
            }
        }
    }
}

/// The dispatcher loop (runs on the dispatcher thread until stopped
/// and drained).
fn dispatch_loop<C: Clock, E: BatchExecutor>(sh: &Shared<C>, executor: &mut E) {
    let lanes = executor.shards().max(1);
    let cap = sh.config.max_batch.max(1);
    let budget = cap * lanes;
    let lane_cap = sh.config.lane_cap.unwrap_or(sh.config.queue_cap.max(1));
    let _ = sh.lanes.set(LaneInfo { lanes, map: executor.shard_map(), lane_cap });
    {
        let mut st = lock(&sh.state);
        st.lane_queued.resize(lanes.max(st.lane_queued.len()), 0);
        st.lane_inflight.resize(lanes.max(st.lane_inflight.len()), 0);
    }
    loop {
        // ---- epoch barrier: controls run strictly between waves ----
        handle_controls(sh, executor);
        // ---- wait until a wave can close, then pop it ----
        let wave: Vec<PendingReq> = {
            let mut st = lock(&sh.state);
            loop {
                let now = sh.clock.now();
                expire(sh, &mut st, now);
                // a pending control wakes an idle dispatcher: break with
                // an empty wave so the outer loop drains it before any
                // request admitted after the control can execute
                if !st.controls.is_empty() {
                    break;
                }
                if st.queued_ids == 0 {
                    st.fill_deadline = None;
                    if st.stopped {
                        drop(st);
                        handle_controls(sh, executor);
                        return;
                    }
                    st = sh.clock.wait(&sh.cv, st);
                    continue;
                }
                if st.stopped || st.queued_ids >= budget {
                    break;
                }
                // close on fill timeout or the earliest queued deadline,
                // whichever is sooner — a deadline-carrying request must
                // not wait out a fill window it cannot afford
                let close_at = st
                    .fill_deadline
                    .unwrap_or(now)
                    .min(earliest_deadline(&st));
                if now >= close_at {
                    break;
                }
                st = sh.clock.wait_deadline(&sh.cv, st, close_at);
            }
            // a pending control leaves the queue untouched: requests
            // queued behind the barrier execute on the new epoch, only
            // waves popped *before* the control complete on the old one
            if !st.controls.is_empty() {
                drop(st);
                continue;
            }
            // pop in (class, deadline, age) order until the wave budget
            // is met; requests are popped whole (a reply is one unit),
            // so the last pop may overshoot — rounds below re-chunk
            let mut wave = Vec::new();
            let mut total = 0usize;
            for heap in st.classes.iter_mut() {
                while total < budget {
                    match heap.pop() {
                        Some(Reverse(p)) => {
                            total += p.ids.len();
                            wave.push(p);
                        }
                        None => break,
                    }
                }
                if total >= budget {
                    break;
                }
            }
            st.queued_ids = st.queued_ids.saturating_sub(total);
            for p in &wave {
                if let Some(counts) = &p.lane_counts {
                    for (lane, &n) in counts.iter().enumerate() {
                        if let Some(q) = st.lane_queued.get_mut(lane) {
                            *q = q.saturating_sub(n);
                        }
                    }
                }
            }
            // a leftover backlog means load ≥ capacity: close the next
            // wave immediately instead of waiting out the fill window
            st.fill_deadline =
                if st.queued_ids > 0 { Some(sh.clock.now()) } else { None };
            wave
        };
        if wave.is_empty() {
            continue;
        }
        // ---- flatten, lane-group, register in-flight ----
        let ids: Vec<u32> = wave.iter().flat_map(|p| p.ids.iter().copied()).collect();
        let groups: Option<Vec<Vec<usize>>> = (lanes > 1).then(|| {
            let mut g: Vec<Vec<usize>> = vec![Vec::new(); lanes];
            for (pos, &id) in ids.iter().enumerate() {
                g[executor.shard_of(id).min(lanes - 1)].push(pos);
            }
            g
        });
        let inflight: Vec<usize> = match &groups {
            Some(g) => g.iter().map(|lane| lane.len()).collect(),
            None => vec![ids.len()],
        };
        {
            let mut st = lock(&sh.state);
            for (lane, &n) in inflight.iter().enumerate() {
                if let Some(q) = st.lane_inflight.get_mut(lane) {
                    *q += n;
                }
            }
        }
        // ---- execute as ≤max_batch rounds per lane ----
        let mut fail_msg: Option<String> = None;
        let mut run_chunk = |executor: &mut E, chunk_ids: &[u32]| -> Option<Vec<Vec<f32>>> {
            match executor.execute(chunk_ids) {
                Ok(r) if r.len() == chunk_ids.len() => {
                    let mut s = lock(&sh.stats);
                    s.batches += 1;
                    s.batch_id_sum += chunk_ids.len() as u64;
                    Some(r)
                }
                Ok(r) => {
                    let msg = format!(
                        "executor returned {} rows for {} ids",
                        r.len(),
                        chunk_ids.len()
                    );
                    eprintln!("serve: {msg}");
                    fail_msg = Some(msg);
                    None
                }
                Err(e) => {
                    eprintln!("serve: batch execution failed: {e}");
                    fail_msg = Some(e.to_string());
                    None
                }
            }
        };
        let mut rows: Vec<Vec<f32>> = Vec::with_capacity(ids.len());
        let mut failed = false;
        match &groups {
            Some(groups) => {
                let rounds =
                    groups.iter().map(|g| g.len().div_ceil(cap)).max().unwrap_or(0);
                let mut slots: Vec<Option<Vec<f32>>> = ids.iter().map(|_| None).collect();
                for round in 0..rounds {
                    let chunk: Vec<usize> = groups
                        .iter()
                        .flat_map(|g| g.iter().skip(round * cap).take(cap).copied())
                        .collect();
                    let chunk_ids: Vec<u32> = chunk.iter().map(|&p| ids[p]).collect();
                    match run_chunk(executor, &chunk_ids) {
                        Some(got) => {
                            for (&p, row) in chunk.iter().zip(got) {
                                slots[p] = Some(row);
                            }
                        }
                        None => {
                            failed = true;
                            break;
                        }
                    }
                }
                if !failed {
                    rows = slots
                        .into_iter()
                        .map(|r| r.expect("every position dispatched"))
                        .collect();
                }
            }
            None => {
                // the common single-lane hot path: no position indirection
                for chunk in ids.chunks(cap) {
                    match run_chunk(executor, chunk) {
                        Some(mut got) => rows.append(&mut got),
                        None => {
                            failed = true;
                            break;
                        }
                    }
                }
            }
        }
        // ---- release the lanes ----
        {
            let mut st = lock(&sh.state);
            for (lane, &n) in inflight.iter().enumerate() {
                if let Some(q) = st.lane_inflight.get_mut(lane) {
                    *q = q.saturating_sub(n);
                }
            }
        }
        // ---- reply + record ----
        if failed {
            // cache activity from the chunks that did run still reaches
            // the stats; typed clients get the error, legacy clients a
            // dropped channel
            {
                let mut s = lock(&sh.stats);
                s.exec_failures += 1;
                s.reuse = executor.reuse_stats();
                s.reuse_lanes = executor.reuse_lane_stats().unwrap_or_default();
            }
            let msg = fail_msg.unwrap_or_else(|| "execution failed".into());
            for p in wave {
                if let ReplyTo::Typed(tx) = p.reply {
                    let _ = tx.send(Err(ServeError::Exec(msg.clone())));
                }
            }
            continue;
        }
        let done = sh.clock.now();
        let mut s = lock(&sh.stats);
        s.reuse = executor.reuse_stats();
        s.reuse_lanes = executor.reuse_lane_stats().unwrap_or_default();
        let mut rows = rows.into_iter();
        for p in wave {
            let take = p.ids.len();
            s.completed += take as u64;
            let lat = done.saturating_sub(p.enqueued);
            if s.latencies_ns.len() < LATENCY_SAMPLE_CAP {
                s.latencies_ns.push(lat as f64);
            }
            let rc = &mut s.classes[p.class];
            rc.requests += 1;
            rc.completed += take as u64;
            rc.sketch.record(lat);
            match p.reply {
                ReplyTo::Single(tx) => {
                    if let Some(row) = rows.next() {
                        let _ = tx.send(row);
                    }
                }
                ReplyTo::Rows(tx) => {
                    let _ = tx.send(rows.by_ref().take(take).collect());
                }
                ReplyTo::Typed(tx) => {
                    let _ = tx.send(Ok(rows.by_ref().take(take).collect()));
                }
            }
        }
    }
}

/// The canonical executor behind [`AsyncServer::start_session`]: a
/// session built inside the dispatcher thread (or the build error
/// every wave will report).
struct SessionExecutor {
    session: std::result::Result<Session, String>,
}

impl BatchExecutor for SessionExecutor {
    fn execute(&mut self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>> {
        match self.session.as_mut() {
            Ok(s) => s.run_batch(node_ids),
            Err(e) => Err(Error::Runtime(format!("session build failed: {e}"))),
        }
    }

    fn reuse_stats(&self) -> Option<ReuseStats> {
        self.session.as_ref().ok().and_then(|s| s.reuse_stats())
    }

    fn reuse_lane_stats(&self) -> Option<Vec<ReuseStats>> {
        self.session.as_ref().ok().and_then(|s| s.reuse_lane_stats())
    }

    /// Shard-affine dispatch applies only on the sampled batch path: a
    /// partitioned session without sampling serves from the cached
    /// full-graph forward, where grouping would only fragment
    /// dispatches.
    fn shards(&self) -> usize {
        self.session
            .as_ref()
            .ok()
            .filter(|s| s.sampling().is_some())
            .and_then(|s| s.partition())
            .map(|p| p.num_shards())
            .unwrap_or(1)
    }

    fn shard_of(&self, node_id: u32) -> usize {
        self.session.as_ref().ok().and_then(|s| s.shard_of(node_id)).unwrap_or(0)
    }

    fn shard_map(&self) -> Option<ShardMap> {
        self.session
            .as_ref()
            .ok()
            .filter(|s| s.sampling().is_some())
            .and_then(|s| s.shard_map())
    }

    fn apply_updates(&mut self, updates: Vec<GraphUpdate>) -> Result<usize> {
        match self.session.as_mut() {
            Ok(s) => s.apply_updates(updates),
            Err(e) => Err(Error::Runtime(format!("session build failed: {e}"))),
        }
    }

    fn flip_epoch(&mut self) -> Result<EpochReport> {
        match self.session.as_mut() {
            Ok(s) => s.flip_epoch(),
            Err(e) => Err(Error::Runtime(format!("session build failed: {e}"))),
        }
    }

    fn epoch(&self) -> u64 {
        self.session.as_ref().ok().map(|s| s.epoch()).unwrap_or(0)
    }

    fn handle_worker_down(&mut self, worker: usize) -> Result<usize> {
        match self.session.as_mut() {
            Ok(s) => s.handle_worker_down(worker),
            Err(e) => Err(Error::Runtime(format!("session build failed: {e}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn echo(ids: &[u32]) -> Result<Vec<Vec<f32>>> {
        Ok(ids.iter().map(|&i| vec![i as f32, 2.0 * i as f32]).collect())
    }

    fn cfg() -> ServingConfig {
        ServingConfig { flush_after: Duration::from_millis(1), ..Default::default() }
    }

    #[test]
    fn typed_submit_round_trips() {
        let server = AsyncServer::start(cfg(), echo);
        let rx = server.submit(&[3, 5], SubmitOpts::default()).unwrap();
        let rows = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!(rows, vec![vec![3.0, 6.0], vec![5.0, 10.0]]);
        let stats = server.shutdown();
        assert_eq!(stats.completed, 2);
        assert_eq!(stats.classes[0].requests, 1);
        assert_eq!(stats.classes[0].completed, 2);
        assert_eq!(stats.classes[0].submitted, 2);
    }

    #[test]
    fn empty_submit_is_invalid() {
        let server = AsyncServer::start(cfg(), echo);
        match server.submit(&[], SubmitOpts::default()) {
            Err(ServeError::Invalid(_)) => {}
            other => panic!("expected Invalid, got {other:?}"),
        }
    }

    #[test]
    fn submit_after_stop_is_typed_stopped() {
        let mut server = AsyncServer::start(cfg(), echo);
        server.stop();
        match server.submit(&[1], SubmitOpts::default()) {
            Err(ServeError::Stopped) => {}
            other => panic!("expected Stopped, got {:?}", other.err()),
        }
    }

    #[test]
    fn zero_deadline_fails_fast() {
        let server = AsyncServer::start(cfg(), echo);
        let err = server
            .submit(&[1], SubmitOpts::default().with_deadline(Duration::ZERO))
            .unwrap_err();
        assert_eq!(err, ServeError::DeadlineExceeded { late_ns: 0 });
        let stats = server.shutdown();
        assert_eq!(stats.expired, 1);
    }

    #[test]
    fn class_is_clamped_to_configured_lanes() {
        let server =
            AsyncServer::start(ServingConfig { priority_lanes: 2, ..cfg() }, echo);
        let rx = server.submit(&[9], SubmitOpts::class(17)).unwrap();
        assert!(rx.recv_timeout(Duration::from_secs(5)).unwrap().is_ok());
        let stats = server.shutdown();
        assert_eq!(stats.classes.len(), 2);
        assert_eq!(stats.classes[1].requests, 1, "overflow class lands in last lane");
    }

    #[test]
    fn executor_failure_is_typed_for_async_clients() {
        let server = AsyncServer::start(
            cfg(),
            |_ids: &[u32]| -> Result<Vec<Vec<f32>>> { Err(Error::Runtime("boom".into())) },
        );
        let rx = server.submit(&[1], SubmitOpts::default()).unwrap();
        match rx.recv_timeout(Duration::from_secs(5)).unwrap() {
            Err(ServeError::Exec(msg)) => assert!(msg.contains("boom")),
            other => panic!("expected Exec error, got {other:?}"),
        }
        let stats = server.shutdown();
        assert_eq!(stats.exec_failures, 1);
        assert_eq!(stats.completed, 0);
    }

    #[test]
    fn shutdown_while_pending_drains_typed_replies() {
        // every admitted request must resolve its receiver on shutdown
        let server = AsyncServer::start(cfg(), echo);
        let rxs: Vec<_> = (0..10)
            .map(|i| server.submit(&[i, i + 50], SubmitOpts::default()).unwrap())
            .collect();
        let stats = server.shutdown();
        assert_eq!(stats.completed, 20);
        for (i, rx) in rxs.into_iter().enumerate() {
            let rows = rx.try_recv().expect("drained").expect("ok");
            assert_eq!(rows[0][0], i as f32);
            assert_eq!(rows[1][0], (i + 50) as f32);
        }
    }

    #[test]
    fn queue_cap_is_enforced() {
        // an executor that blocks until released, so the queue backs up
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let (entered_tx, entered_rx) = mpsc::channel::<()>();
        let server = AsyncServer::start_with(
            ServingConfig { max_batch: 1, queue_cap: 3, ..cfg() },
            move || {
                move |ids: &[u32]| -> Result<Vec<Vec<f32>>> {
                    let _ = entered_tx.send(());
                    let _ = gate_rx.recv();
                    Ok(ids.iter().map(|&i| vec![i as f32]).collect())
                }
            },
        );
        let first = server.submit(&[0], SubmitOpts::default()).unwrap();
        entered_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        // dispatcher now blocked in execute(); fill the queue to cap
        let queued: Vec<_> = (1..=3)
            .map(|i| server.submit(&[i], SubmitOpts::default()).unwrap())
            .collect();
        match server.submit(&[4], SubmitOpts::default()) {
            Err(ServeError::QueueFull { queued, cap }) => {
                assert_eq!(queued, 3);
                assert_eq!(cap, 3);
            }
            other => panic!("expected QueueFull, got {other:?}"),
        }
        for _ in 0..4 {
            let _ = gate_tx.send(());
        }
        assert!(first.recv_timeout(Duration::from_secs(5)).unwrap().is_ok());
        for rx in queued {
            assert!(rx.recv_timeout(Duration::from_secs(5)).unwrap().is_ok());
        }
        let stats = server.shutdown();
        assert_eq!(stats.rejected_queue_full, 1);
        assert_eq!(stats.peak_queued, 3);
        assert_eq!(stats.completed, 4);
    }

    #[test]
    fn static_executor_rejects_controls_through_the_server() {
        // the control still round-trips: the dispatcher acks with the
        // executor's refusal instead of hanging or panicking
        let server = AsyncServer::start(cfg(), echo);
        let rx = server.apply_updates(Vec::new()).unwrap();
        let err = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap_err();
        assert!(err.contains("streaming graph updates"), "got: {err}");
        let rx = server.flip_epoch().unwrap();
        let err = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap_err();
        assert!(err.contains("epoch flips"), "got: {err}");
        // serving still works after rejected controls
        let rx = server.submit(&[7], SubmitOpts::default()).unwrap();
        let rows = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!(rows, vec![vec![7.0, 14.0]]);
        let mut server = server;
        server.stop();
        assert!(matches!(server.apply_updates(Vec::new()), Err(ServeError::Stopped)));
        assert!(matches!(server.flip_epoch(), Err(ServeError::Stopped)));
    }

    #[test]
    fn worker_down_control_round_trips_between_waves() {
        // a static executor rejects the control, but the ack still
        // arrives and serving continues untouched
        let server = AsyncServer::start(cfg(), echo);
        let rx = server.report_worker_down(1).unwrap();
        let err = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap_err();
        assert!(err.contains("cluster workers"), "got: {err}");
        let rx = server.submit(&[4], SubmitOpts::default()).unwrap();
        let rows = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!(rows, vec![vec![4.0, 8.0]]);
        let mut server = server;
        server.stop();
        assert!(matches!(server.report_worker_down(0), Err(ServeError::Stopped)));
    }
}
